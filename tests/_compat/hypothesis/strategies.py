"""Strategies for the hypothesis stand-in: fixed-seed draws with
boundary biasing (min/max get drawn early and often, which is where
off-by-one bugs in cycle/tiling math live)."""

from __future__ import annotations

import random


class SearchStrategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random | None = None):
        return self._draw(rng if rng is not None else random.Random())

    def map(self, fn):
        return SearchStrategy(lambda rng: fn(self._draw(rng)))


def integers(min_value: int, max_value: int) -> SearchStrategy:
    def draw(rng: random.Random) -> int:
        r = rng.random()
        if r < 0.15:
            return min_value
        if r < 0.30:
            return max_value
        return rng.randint(min_value, max_value)
    return SearchStrategy(draw)


def sampled_from(elements) -> SearchStrategy:
    pool = list(elements)
    return SearchStrategy(lambda rng: pool[rng.randrange(len(pool))])


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.random() < 0.5)


def lists(elements: SearchStrategy, *, min_size: int = 0,
          max_size: int = 10) -> SearchStrategy:
    def draw(rng: random.Random) -> list:
        n = integers(min_size, max_size).example(rng)  # boundary-biased
        return [elements.example(rng) for _ in range(n)]
    return SearchStrategy(draw)


def permutations(values) -> SearchStrategy:
    pool = list(values)

    def draw(rng: random.Random) -> list:
        out = list(pool)
        rng.shuffle(out)
        return out
    return SearchStrategy(draw)


def builds(target, *arg_strategies, **kw_strategies) -> SearchStrategy:
    def draw(rng: random.Random):
        args = [s.example(rng) for s in arg_strategies]
        kwargs = {k: s.example(rng) for k, s in kw_strategies.items()}
        return target(*args, **kwargs)
    return SearchStrategy(draw)
