"""Minimal deterministic stand-in for the slice of the `hypothesis` API
this suite uses (`given`, `settings`, `strategies.integers /
sampled_from / builds`, `.map`).

Activated by tests/conftest.py ONLY when the real package is not
installed.  Examples are drawn from a fixed-seed RNG with boundary
biasing (see strategies.py), so runs are reproducible; the real package
remains strictly better (shrinking, coverage-guided generation) and is
declared in pyproject.toml.
"""

from __future__ import annotations

import functools
import inspect
import random

from . import strategies

__all__ = ["given", "settings", "strategies"]

_DEFAULT_MAX_EXAMPLES = 20


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._stub_settings = {"max_examples": max_examples}
        return fn
    return deco


def given(*arg_strategies, **kw_strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper():
            cfg = (getattr(wrapper, "_stub_settings", None)
                   or getattr(fn, "_stub_settings", None)
                   or {"max_examples": _DEFAULT_MAX_EXAMPLES})
            rng = random.Random(0)
            for _ in range(cfg["max_examples"]):
                args = [s.example(rng) for s in arg_strategies]
                kwargs = {k: s.example(rng)
                          for k, s in kw_strategies.items()}
                fn(*args, **kwargs)
        # pytest resolves fixtures from the signature; the wrapper
        # supplies every argument itself, so present an empty one.
        wrapper.__signature__ = inspect.Signature()
        del wrapper.__wrapped__
        return wrapper
    return deco
