"""IS is the transposed WS problem, end to end (satellite, ISSUE 3).

The paper treats input-stationary as weight-stationary with the roles of
the two operands swapped: O = A @ B with A stationary is exactly
O^T = B^T @ A^T with A^T as the stationary "weight".  The identity must
hold at every layer of the stack:

  * core.simulator.simulate_gemm — outputs transpose-equal AND the
    cycle counts match (the Eq. 4 streaming term is symmetric);
  * the Pallas kernel — IS dispatch equals WS on (B^T, A^T) transposed;
  * the plane-2 cost model — estimate() is invariant under the swap.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.dataflow import Dataflow
from repro.core.simulator import simulate_gemm
from repro.core.tpu_model import TPUKernelConfig, estimate, hbm_traffic
from repro.engine.backends import pallas_gemm

dims = st.integers(1, 24)


@given(dims, dims, dims)
@settings(max_examples=15, deadline=None)
def test_simulator_is_equals_transposed_ws(m, k, n):
    rng = np.random.default_rng(m * 31 + k * 7 + n)
    a = rng.normal(size=(m, k))
    b = rng.normal(size=(k, n))
    out_is, cyc_is = simulate_gemm(a, b, Dataflow.IS)
    out_ws, cyc_ws = simulate_gemm(b.T, a.T, Dataflow.WS)
    assert cyc_is == cyc_ws
    np.testing.assert_allclose(np.asarray(out_is), np.asarray(out_ws).T,
                               rtol=1e-6, atol=1e-6)
    # and both are the GEMM
    np.testing.assert_allclose(np.asarray(out_is), a @ b,
                               rtol=1e-6, atol=1e-6)


@given(st.integers(1, 200), st.integers(1, 200), st.integers(1, 200))
@settings(max_examples=8, deadline=None)
def test_pallas_is_equals_transposed_ws(m, k, n):
    rng = np.random.default_rng(m * 13 + k * 5 + n)
    a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    out_is = pallas_gemm(a, b, dataflow="is", interpret=True)
    out_ws_t = pallas_gemm(b.T, a.T, dataflow="ws", interpret=True)
    np.testing.assert_allclose(np.asarray(out_is), np.asarray(out_ws_t).T,
                               rtol=2e-5, atol=5e-4)


@given(st.integers(1, 4096), st.integers(1, 4096), st.integers(1, 4096),
       st.sampled_from((128, 256)), st.sampled_from((128, 256)),
       st.sampled_from((8, 128)))
@settings(max_examples=20, deadline=None)
def test_cost_model_is_equals_transposed_ws(m, k, n, bk, bn, bm):
    """estimate(m,k,n, IS(bm,bk,bn)) == estimate(n,k,m, WS(bn,bk,bm)):
    traffic AND seconds — the plane-2 cycle-count half of the identity."""
    cfg_is = TPUKernelConfig("is", bm, bk, bn)
    cfg_ws = TPUKernelConfig("ws", bn, bk, bm)
    assert hbm_traffic(m, k, n, cfg_is) == hbm_traffic(n, k, m, cfg_ws)
    c_is = estimate(m, k, n, cfg_is)
    c_ws = estimate(n, k, m, cfg_ws)
    assert np.isclose(c_is.seconds, c_ws.seconds, rtol=1e-12)
    assert np.isclose(c_is.compute_s, c_ws.compute_s, rtol=1e-12)
