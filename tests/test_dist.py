"""Distribution: sharding rules, multi-device train step, tiny dry-run.

Multi-device cases run in a subprocess with
xla_force_host_platform_device_count=8 so the main test process keeps its
single-device view (the brief's requirement that smoke tests see 1
device)."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist import sharding as shd
from repro.launch.mesh import make_test_mesh

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_subprocess(code: str) -> dict:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_spec_divisibility_safe():
    mesh = make_test_mesh()
    with shd.use_mesh(mesh):
        # dims that do not divide the axis degrade to replication
        s = shd.spec((7, 13), ("batch", "heads"), mesh)
        assert isinstance(s, P)


def test_auto_spec_rules():
    sizes = {"pod": 2, "data": 16, "model": 16}
    # expert weights: expert dim on model (EP)
    spec = shd._auto_spec("experts/wi", (32, 64, 128), sizes)
    assert spec[0] == "model"
    # embedding: vocab only (and only if divisible), never the gathered
    # feature dim — 50280 % 16 != 0 -> fully replicated
    spec_e = shd._auto_spec("mu/embed", (50280, 1536), sizes)
    assert all(p is None for p in spec_e)
    spec_e2 = shd._auto_spec("embed", (151936, 1536), sizes)
    assert spec_e2[0] == "model" and len(spec_e2) == 1
    # stacked params: leading axis never sharded; TP+FSDP on the rest
    spec_s = shd._auto_spec("stack/b0/attn/wq/w", (14, 64, 128), sizes)
    assert len(spec_s) == 0 or spec_s[0] is None
    assert "model" in spec_s and "data" in spec_s
    # dims that do not divide degrade gracefully
    spec_o = shd._auto_spec("w", (7, 13), sizes)
    assert all(p is None for p in spec_o)


def test_constrain_noop_without_mesh():
    x = jax.numpy.ones((4, 4))
    assert shd.constrain(x, "batch", None) is x


@pytest.mark.slow
def test_multidevice_train_and_dryrun():
    code = textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.configs import get_config
        from repro.dist import sharding as shd
        from repro.data.pipeline import DataConfig, make_source
        from repro.train_lib import train as train_lib

        from repro.optim.adamw import AdamWConfig
        assert len(jax.devices()) == 8
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = get_config("qwen2-1.5b", smoke=True)
        tcfg = train_lib.TrainConfig(microbatches=2,
                                     compute_dtype=jnp.float32,
                                     optimizer=AdamWConfig(lr=5e-3))
        with mesh, shd.use_mesh(mesh):
            state = train_lib.init_state(jax.random.PRNGKey(0), cfg, tcfg)
            sh = shd.params_shardings(state, mesh)
            state = jax.tree.map(jax.device_put, state, sh)
            step = jax.jit(train_lib.make_train_step(cfg, tcfg),
                           in_shardings=(sh, None), donate_argnums=(0,))
            src = make_source(cfg, DataConfig(batch=8, seq_len=32))
            losses = []
            for s in range(6):
                state, m = step(state, jax.tree.map(jnp.asarray,
                                                    src.batch(s)))
                losses.append(float(m["ce"]))
        # single-device reference: SPMD must not change the math
        cfg2 = get_config("qwen2-1.5b", smoke=True)
        state2 = train_lib.init_state(jax.random.PRNGKey(0), cfg2, tcfg)
        step2 = jax.jit(train_lib.make_train_step(cfg2, tcfg),
                        donate_argnums=(0,))
        src2 = make_source(cfg2, DataConfig(batch=8, seq_len=32))
        ref = []
        for s in range(6):
            state2, m2 = step2(state2, jax.tree.map(jnp.asarray,
                                                    src2.batch(s)))
            ref.append(float(m2["ce"]))
        err = max(abs(a - b) for a, b in zip(losses, ref, strict=True))
        print(json.dumps({"losses": losses, "ref": ref, "err": err}))
    """)
    out = _run_subprocess(code)
    assert out["losses"][-1] < out["losses"][0] - 0.1
    assert out["err"] < 5e-3, out  # SPMD == single-device math


@pytest.mark.slow
def test_elastic_reshard_restore():
    """Checkpoint on a (4,2) mesh, restore onto (2,4) — elastic scaling."""
    code = textwrap.dedent("""
        import json, tempfile
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.dist import sharding as shd
        from repro.checkpoint.checkpoint import Checkpointer
        from repro.train_lib import train as train_lib

        cfg = get_config("qwen2-1.5b", smoke=True)
        tcfg = train_lib.TrainConfig(compute_dtype=jnp.float32)
        d = tempfile.mkdtemp()
        mesh1 = jax.make_mesh((4, 2), ("data", "model"))
        with mesh1, shd.use_mesh(mesh1):
            state = train_lib.init_state(jax.random.PRNGKey(0), cfg, tcfg)
            sh1 = shd.params_shardings(state, mesh1)
            state = jax.tree.map(jax.device_put, state, sh1)
            ck = Checkpointer(d)
            ck.save(1, state, blocking=True)
        mesh2 = jax.make_mesh((2, 4), ("data", "model"))
        with mesh2, shd.use_mesh(mesh2):
            like = jax.eval_shape(lambda: train_lib.init_state(
                jax.random.PRNGKey(0), cfg, tcfg))
            sh2 = shd.params_shardings(like, mesh2)
            restored = Checkpointer(d).restore(1, like, sh2)
        a = np.asarray(jax.tree.leaves(state)[3])
        b = np.asarray(jax.tree.leaves(restored)[3])
        print(json.dumps({"equal": bool(np.allclose(a, b))}))
    """)
    out = _run_subprocess(code)
    assert out["equal"]


@pytest.mark.slow
def test_tiny_dryrun_cell_multipod():
    """A 2x2x2 'multi-pod' mesh lowers+compiles a smoke train cell, and
    the roofline walker returns nonzero loop-multiplied terms."""
    code = textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.dist import sharding as shd
        from repro.launch import specs as S
        from repro.configs.shapes import ShapeSpec
        from repro.train_lib.train import TrainConfig, make_train_step
        from repro.roofline import hlo_costs

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = get_config("qwen2-1.5b", smoke=True)
        shape = ShapeSpec("tiny_train", 64, 8, "train")
        tcfg = TrainConfig(microbatches=2, compute_dtype=jnp.bfloat16)
        with mesh, shd.use_mesh(mesh):
            args, sh = S.input_specs(cfg, shape, mesh, tcfg)
            comp = jax.jit(make_train_step(cfg, tcfg), in_shardings=sh,
                           donate_argnums=(0,)).lower(*args).compile()
        cost = hlo_costs.module_costs(comp.as_text())
        print(json.dumps({"flops": cost.flops, "coll": cost.coll_bytes}))
    """)
    out = _run_subprocess(code)
    assert out["flops"] > 0
    assert out["coll"] > 0
