"""The int8 quantization plane (ISSUE 5): round-trip bounds, the
quant GEMM backends, engine precision keying, the quantized KV cache,
and quantized-vs-bf16 scheduler parity."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import engine as engine_mod
from repro.configs import get_config
from repro.kernels import quant_gemm as qg
from repro.models import transformer as T
from repro.models.layers import dense
from repro.quant import (QuantizedTensor, dequantize, kv_dequantize,
                         kv_quantize, quantize, quantize_params, tree_bytes)
from repro.serve_lib import serve as serve_lib
from repro.serve_lib.scheduler import Request, Scheduler


# --------------------------------------------------------------------------
# Round-trip error bounds (satellite: property test)
# --------------------------------------------------------------------------


@settings(max_examples=25)
@given(st.integers(2, 48), st.integers(1, 48), st.integers(0, 2**31 - 1),
       st.sampled_from([0.01, 1.0, 37.5]))
def test_quantize_roundtrip_error_bound(k, n, seed, spread):
    """Per-channel symmetric int8: |x - deq(q(x))| <= scale/2 per
    element, with scale constant along the reduced (contraction) axis."""
    rng = np.random.default_rng(seed)
    w = (rng.normal(size=(k, n)) * spread).astype(np.float32)
    qt = quantize(jnp.asarray(w))
    assert qt.q.dtype == jnp.int8
    assert qt.scale.shape == (1, n)
    err = np.abs(np.asarray(dequantize(qt)) - w)
    bound = np.asarray(qt.scale) / 2.0 + 1e-7
    assert (err <= bound).all(), (err.max(), bound.max())


@settings(max_examples=25)
@given(st.integers(1, 9), st.integers(1, 64), st.integers(0, 2**31 - 1))
def test_kv_codec_roundtrip_error_bound(rows, hd, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(rows, 3, hd)).astype(np.float32) * 4.2
    q, scale = kv_quantize(jnp.asarray(x))
    assert q.dtype == jnp.int8 and scale.shape == (rows, 3)
    err = np.abs(np.asarray(kv_dequantize(q, scale)) - x)
    assert (err <= np.asarray(scale)[..., None] / 2.0 + 1e-7).all()


def test_quantize_zero_channel_is_exact():
    w = jnp.zeros((8, 4), jnp.float32)
    qt = quantize(w)
    assert np.asarray(qt.scale == 1.0).all()  # no div-by-zero scales
    np.testing.assert_array_equal(np.asarray(dequantize(qt)), np.zeros((8, 4)))


def test_quantize_grouped_weights_per_group_channels():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(3, 16, 8)), jnp.float32)
    qt = quantize(w)
    assert qt.scale.shape == (3, 1, 8)


# --------------------------------------------------------------------------
# quantize_params: targets, skips, pytree behavior
# --------------------------------------------------------------------------


def test_quantize_params_targets_dense_and_skips_raw_matmul_weights():
    cfg = get_config("qwen2-1.5b", smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    qp = quantize_params(params)
    blk = qp["stack"]["b0"]
    assert isinstance(blk["attn"]["wq"]["w"], QuantizedTensor)
    assert isinstance(blk["mlp"]["wi"]["w"], QuantizedTensor)
    # embeddings / norms are consumed raw and stay float
    assert not isinstance(qp["embed"], QuantizedTensor)
    assert qp["final_norm"].dtype == jnp.float32
    assert tree_bytes(qp) < tree_bytes(params)


def test_quantize_params_skips_router_and_ssm_projections():
    cfg = get_config("granite-moe-1b-a400m", smoke=True)
    qp = quantize_params(T.init_params(jax.random.PRNGKey(0), cfg))
    blk = qp["stack"]["b0"]
    assert not isinstance(blk["moe"]["router"]["w"], QuantizedTensor)
    # expert stacks stay float (grouped path)
    assert not isinstance(blk["moe"]["experts"]["wi"], QuantizedTensor)
    scfg = get_config("mamba2-780m", smoke=True)
    qps = quantize_params(T.init_params(jax.random.PRNGKey(0), scfg))
    ssm_p = qps["stack"]["b0"]["ssm"]
    assert not isinstance(ssm_p["in_proj"]["w"], QuantizedTensor)
    assert not isinstance(ssm_p["out_proj"]["w"], QuantizedTensor)


def test_quantized_tensor_scans_like_a_param_leaf():
    """lax.scan must slice a stacked QuantizedTensor per period exactly
    like a raw stacked weight (the transformer scan contract)."""
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(4, 8, 6)), jnp.float32)
    qt = quantize(w)

    def body(c, qt_slice):
        assert qt_slice.q.shape == (8, 6)
        return c, qt_slice.dequantize()

    _, outs = jax.lax.scan(body, 0, qt)
    np.testing.assert_allclose(
        np.asarray(outs), np.asarray(dequantize(qt)), rtol=1e-6)


# --------------------------------------------------------------------------
# The int8 GEMM backends
# --------------------------------------------------------------------------


def test_quant_gemm_xla_close_to_float():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(40, 96)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(96, 56)), jnp.float32)
    out = qg.quant_gemm(a, b, use_pallas=False)
    ref = a @ b
    denom = float(jnp.max(jnp.abs(ref)))
    assert float(jnp.max(jnp.abs(out - ref))) / denom < 0.03


def test_quant_gemm_pallas_interpret_matches_xla_exactly():
    """Same quantization decomposition, two execution paths: the int32
    accumulations must agree bit-for-bit."""
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.normal(size=(33, 130)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(130, 70)), jnp.float32)
    out_x = qg.quant_gemm(a, b, use_pallas=False)
    out_p = qg.quant_gemm(a, b, use_pallas=True, interpret=True,
                          bm=64, bk=256, bn=128)
    np.testing.assert_array_equal(np.asarray(out_x), np.asarray(out_p))


def test_quant_gemm_integer_inputs_exact():
    """Inputs already on the int8 grid with max-abs 127 quantize at
    scale 1 exactly, so the quantized GEMM equals the float GEMM."""
    rng = np.random.default_rng(2)
    a = np.asarray(rng.integers(-127, 128, size=(16, 32)), np.float32)
    b = np.asarray(rng.integers(-127, 128, size=(32, 24)), np.float32)
    a[:, 0] = 127.0   # pin every row's amax -> scale exactly 1
    b[0, :] = -127.0  # pin every column's amax
    out = qg.quant_gemm(jnp.asarray(a), jnp.asarray(b), use_pallas=False)
    np.testing.assert_allclose(np.asarray(out), a @ b, rtol=1e-6)


def test_quant_gemm_w8_matches_dequantized_reference():
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.normal(size=(24, 48)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(48, 40)), jnp.float32)
    qt = quantize(w)
    out = qg.quant_gemm_w8(a, qt.q, qt.scale, use_pallas=False)
    a_q, s_a = qg.quantize_rows(a)
    ref = (a_q.astype(jnp.int32) @ qt.q.astype(jnp.int32)).astype(jnp.float32)
    ref = ref * s_a[:, None] * qt.scale.reshape(1, -1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)


def test_int8_backends_registered_and_dispatch():
    reg = engine_mod.default_registry()
    for backend in engine_mod.INT8_BACKENDS:
        for op in ("gemm", "gemm_w8", "grouped_gemm", "attention"):
            assert reg.has(backend, op), (backend, op)
    rng = np.random.default_rng(4)
    a = jnp.asarray(rng.normal(size=(16, 64)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    outs = {}
    for backend in engine_mod.INT8_BACKENDS:
        with engine_mod.use_engine(backend=backend) as eng:
            outs[backend] = np.asarray(eng.matmul(a, b))
            assert eng.int8
    # both int8 backends run the same decomposition
    np.testing.assert_array_equal(outs["pallas-tpu-int8"], outs["xla-int8"])


def test_int8_grouped_matmul_close():
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(3, 8, 32)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 32, 16)), jnp.float32)
    ref = jnp.einsum("ecd,edf->ecf", x, w)
    with engine_mod.use_engine(backend="xla-int8") as eng:
        out = eng.grouped_matmul(x, w)
    denom = float(jnp.max(jnp.abs(ref)))
    assert float(jnp.max(jnp.abs(out - ref))) / denom < 0.05


def test_int8_vjp_cotangents_stay_float():
    """Training flows: the quantized forward has a dispatch-layer VJP
    whose cotangent GEMMs are float (close to the float-GEMM grads)."""
    rng = np.random.default_rng(6)
    a = jnp.asarray(rng.normal(size=(16, 48)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(48, 24)), jnp.float32)

    def loss_q(a, b):
        with engine_mod.use_engine(backend="xla-int8") as eng:
            return jnp.sum(eng.matmul(a, b) ** 2)

    with engine_mod.use_engine(backend="xla-int8"):
        ga, gb = jax.grad(loss_q, argnums=(0, 1))(a, b)
    ra, rb = jax.grad(lambda a, b: jnp.sum((a @ b) ** 2), argnums=(0, 1))(a, b)
    assert ga.dtype == a.dtype and gb.dtype == b.dtype
    for g, r in ((ga, ra), (gb, rb)):
        denom = float(jnp.max(jnp.abs(r)))
        assert float(jnp.max(jnp.abs(g - r))) / denom < 0.06


# --------------------------------------------------------------------------
# Engine precision keying + cost-model width awareness
# --------------------------------------------------------------------------


def test_int8_backend_keys_plan_at_one_byte():
    rng = np.random.default_rng(7)
    a = jnp.asarray(rng.normal(size=(16, 64)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    with engine_mod.use_engine(backend="xla-int8") as eng:
        eng.matmul(a, b)
    (req, _), = list(eng.plan)
    # operands key at the quantized width; the output keeps the float
    # compute width (the kernel rescales the int32 accumulator).
    assert req.in_bytes == 1 and req.out_bytes == 4


def test_precision_is_part_of_the_decision_cache_key():
    r1 = engine_mod.KernelRequest("gemm", 512, 512, 512, in_bytes=1,
                                  out_bytes=1)
    r2 = engine_mod.KernelRequest("gemm", 512, 512, 512, in_bytes=2,
                                  out_bytes=2)
    assert r1.key() != r2.key()
    plan = engine_mod.ExecutionPlan()
    model = engine_mod.TPUModel()
    plan.add(r1, model.decide(r1))
    assert plan.lookup(r2) is None  # bf16 must not reuse the int8 plan


def test_tpu_model_int8_widens_tile_space_and_speeds_plans():
    """Byte width reaches the cost model: the Eq. 2 VMEM gate admits
    tile configs at 1 byte that it rejects at 2 (int8 plans may pick
    larger tiles), and the modeled int8 GEMM is strictly faster (2x MXU
    peak + halved HBM traffic)."""
    from repro.core import tpu_model as tm

    cfg = tm.TPUKernelConfig("os", 512, 2048, 2048)
    assert cfg.vmem_bytes(in_bytes=2) > tm.VMEM     # rejected for bf16
    assert cfg.vmem_bytes(in_bytes=1) <= tm.VMEM    # admitted for int8
    model = engine_mod.TPUModel()
    big = dict(m=4096, k=4096, n=4096)
    d8 = model.decide(engine_mod.KernelRequest("gemm", **big, in_bytes=1,
                                               out_bytes=1))
    d16 = model.decide(engine_mod.KernelRequest("gemm", **big, in_bytes=2,
                                                out_bytes=2))
    assert d8.seconds < d16.seconds


def test_asic_cost_model_honors_request_width():
    """The ASIC multi-mode buffer holds capacity/word_bytes words: a
    2-byte request must never get a LARGER modeled tile space than the
    native int8 one."""
    model = engine_mod.AnalyticalCostModel()
    d1 = model.decide(engine_mod.KernelRequest("gemm", 1024, 1024, 1024,
                                               in_bytes=1, out_bytes=1))
    d2 = model.decide(engine_mod.KernelRequest("gemm", 1024, 1024, 1024,
                                               in_bytes=2, out_bytes=2))
    tile = lambda d: d.bm * d.bk * d.bn
    assert tile(d2) <= tile(d1)


# --------------------------------------------------------------------------
# dense() with quantized weights
# --------------------------------------------------------------------------


def test_dense_dequantizes_outside_int8_engine():
    rng = np.random.default_rng(8)
    p = {"w": jnp.asarray(rng.normal(size=(32, 16)), jnp.float32)}
    x = jnp.asarray(rng.normal(size=(4, 32)), jnp.float32)
    pq = {"w": quantize(p["w"])}
    ref = np.asarray(dense(p, x))
    out = np.asarray(dense(pq, x))  # no engine: dequantized float matmul
    assert np.max(np.abs(out - ref)) / np.max(np.abs(ref)) < 0.02
    with engine_mod.use_engine(backend="xla-einsum"):  # float engine
        out2 = np.asarray(dense(pq, x))
    np.testing.assert_allclose(out2, out, rtol=1e-5, atol=1e-5)


def test_dense_dispatches_gemm_w8_on_int8_engine():
    rng = np.random.default_rng(9)
    p = {"w": quantize(jnp.asarray(rng.normal(size=(32, 16)), jnp.float32)),
         "b": jnp.zeros((16,), jnp.float32)}
    x = jnp.asarray(rng.normal(size=(4, 32)), jnp.float32)
    with engine_mod.use_engine(backend="xla-int8") as eng:
        out = dense(p, x)
    ops = {req.op for req, _ in eng.plan}
    assert ops == {"gemm_w8"}
    assert out.shape == (4, 16)


# --------------------------------------------------------------------------
# The shared cache-dtype validator (satellite)
# --------------------------------------------------------------------------


def test_cache_dtype_validator_rejects_unsupported_dtype():
    with pytest.raises(ValueError, match="int8.*quantized KV|supported"):
        serve_lib.ServeConfig(max_seq=8, batch=1, cache_dtype=jnp.int16)
    with pytest.raises(ValueError, match="not a dtype"):
        serve_lib.ServeConfig(max_seq=8, batch=1, cache_dtype="not-a-dtype")


def test_cache_dtype_validator_rejects_int8_recurrent_only_arch():
    cfg = get_config("mamba2-780m", smoke=True)
    scfg = serve_lib.ServeConfig(max_seq=16, batch=1,
                                 compute_dtype=jnp.float32,
                                 cache_dtype=jnp.int8)
    with pytest.raises(ValueError, match="SSM/RG-LRU state is unsupported"):
        serve_lib.init_cache(cfg, scfg)


def test_compute_dtype_must_be_floating():
    with pytest.raises(ValueError, match="compute_dtype must be floating"):
        serve_lib.ServeConfig(max_seq=8, batch=1, compute_dtype=jnp.int8)


def test_quantize_knob_upgrades_backend():
    scfg = serve_lib.ServeConfig(max_seq=8, batch=1, quantize=True)
    assert scfg.kernel_backend == "xla-int8"
    scfg = serve_lib.ServeConfig(max_seq=8, batch=1, quantize=True,
                                 kernel_backend="pallas-tpu")
    assert scfg.kernel_backend == "pallas-tpu-int8"
    with pytest.raises(ValueError, match="cannot upgrade"):
        serve_lib.ServeConfig(max_seq=8, batch=1, quantize=True,
                              kernel_backend="simulator")


def test_train_config_quantize_knob():
    from repro.train_lib.train import TrainConfig
    tcfg = TrainConfig(quantize=True)
    assert tcfg.kernel_backend == "xla-int8"


# --------------------------------------------------------------------------
# Quantized KV cache: layout + hybrid archs
# --------------------------------------------------------------------------


def test_int8_cache_layout_rows_and_scales():
    cfg = get_config("qwen2-1.5b", smoke=True)
    scfg = serve_lib.ServeConfig(max_seq=24, batch=2,
                                 compute_dtype=jnp.float32,
                                 cache_dtype=jnp.int8)
    cache = serve_lib.init_cache(cfg, scfg)
    slot = cache["slots"]["b0"]
    assert slot["k"].dtype == jnp.int8
    assert slot["k_scale"].dtype == jnp.float32
    assert slot["k_scale"].shape == slot["k"].shape[:-1]
    assert {"k", "v", "k_scale", "v_scale"} <= set(slot)


def test_int8_cache_hybrid_arch_keeps_recurrent_state_bf16():
    cfg = get_config("recurrentgemma-2b", smoke=True)
    assert "rglru" in cfg.layer_pattern and "local" in cfg.layer_pattern
    scfg = serve_lib.ServeConfig(max_seq=24, batch=2,
                                 compute_dtype=jnp.float32,
                                 cache_dtype=jnp.int8)
    cache = serve_lib.init_cache(cfg, scfg)
    kinds = dict(zip([f"b{j}" for j in range(len(cfg.layer_pattern))],
                     cfg.layer_pattern, strict=True))
    for name, kind in kinds.items():
        slot = cache["slots"][name]
        if kind in ("attn", "local"):
            assert slot["k"].dtype == jnp.int8
        else:
            assert slot["conv"].dtype == jnp.bfloat16
            assert slot["h"].dtype == jnp.bfloat16


def test_int8_cache_bytes_shrink():
    cfg = dataclasses.replace(get_config("qwen2-1.5b", smoke=True),
                              head_dim=64)
    mk = lambda dt: serve_lib.init_cache(cfg, serve_lib.ServeConfig(
        max_seq=32, batch=2, compute_dtype=jnp.float32, cache_dtype=dt))
    ratio = tree_bytes(mk(jnp.bfloat16)) / tree_bytes(mk(jnp.int8))
    assert ratio >= 1.8, ratio


# --------------------------------------------------------------------------
# Scheduler parity: quantized cache vs bf16 on a mixed-length trace
# --------------------------------------------------------------------------


TRACE = [(6, 8), (10, 2), (6, 5), (14, 9), (10, 3), (6, 7), (14, 2), (10, 6)]


def _mk_requests(cfg):
    rng = np.random.default_rng(0)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab, p).astype(np.int32),
                    max_new_tokens=g)
            for i, (p, g) in enumerate(TRACE)]


def _run_sched(cfg, params, cache_dtype, **scfg_kw):
    max_seq = max(p + g for p, g in TRACE) + 1
    scfg = serve_lib.ServeConfig(max_seq=max_seq, batch=3,
                                 compute_dtype=jnp.float32,
                                 cache_dtype=cache_dtype, **scfg_kw)
    sched = Scheduler(params, cfg, scfg)
    return sched.run(_mk_requests(cfg))


def test_scheduler_int8_cache_greedy_parity():
    """The KV codec's ~0.4% row error must not flip any greedy token on
    the mixed-length smoke trace (full attention cache)."""
    cfg = get_config("qwen2-1.5b", smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    base = _run_sched(cfg, params, jnp.bfloat16)
    quant = _run_sched(cfg, params, jnp.int8)
    assert set(base) == set(quant)
    for uid in base:
        np.testing.assert_array_equal(base[uid].tokens, quant[uid].tokens,
                                      err_msg=f"request {uid}")


def test_scheduler_int8_ring_cache_flips_near_ties_only():
    """Ring (sliding-window) caches quantize too; greedy streams may
    flip a token whose baseline top-2 margin is a near-tie (measured
    6.8e-3 on this trace vs ~0.5 typical), so the gate is stepwise:
    >= 95% agreement across the trace."""
    cfg = get_config("gemma3-12b", smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    base = _run_sched(cfg, params, jnp.bfloat16)
    quant = _run_sched(cfg, params, jnp.int8)
    agree = total = 0
    for uid in base:
        tb, tq = base[uid].tokens, quant[uid].tokens
        n = min(len(tb), len(tq))
        agree += int((tb[:n] == tq[:n]).sum())
        total += n
    assert agree / total >= 0.95, (agree, total)


def test_scheduler_full_int8_posture_runs_and_mostly_agrees():
    """Weights + matmuls + cache all int8: sequences may diverge after a
    near-tie flip (documented), but stepwise agreement stays high and
    everything dispatches through the engine."""
    cfg = get_config("qwen2-1.5b", smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    base = _run_sched(cfg, params, jnp.bfloat16)
    quant = _run_sched(cfg, quantize_params(params), jnp.int8, quantize=True)
    agree = total = 0
    for uid in base:
        tb, tq = base[uid].tokens, quant[uid].tokens
        n = min(len(tb), len(tq))
        agree += int((tb[:n] == tq[:n]).sum())
        total += n
    assert total > 0 and agree / total > 0.5, (agree, total)
