"""Speculative decoding (PR 7): draft/verify/commit parity and rollback.

The load-bearing property is EXACT greedy parity: the verify pass
scores the draft with the target model itself and the accept rule keeps
only tokens the target's own argmax would have produced, so the
speculative token stream must be BITWISE identical to the plain decode
path — for every cache kind (full attention, sliding window, SSM,
RG-LRU), under rejection-heavy drafts (clock-decrement rollback every
tick), and composed with the paged layout and int8 caches.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serve_lib import serve as serve_lib
from repro.serve_lib.scheduler import Request, Scheduler

KINDS = ["qwen2-1.5b", "mixtral-8x7b", "mamba2-780m", "recurrentgemma-2b"]


def _cfg(arch):
    cfg = get_config(arch, smoke=True)
    if cfg.moe is not None:  # avoid capacity drops in exactness checks
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    return cfg


def _setup(arch, batch, max_seq=48, k=3, **scfg_kw):
    cfg = _cfg(arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    base = serve_lib.ServeConfig(max_seq=max_seq, batch=batch,
                                 compute_dtype=jnp.float32,
                                 cache_dtype=jnp.float32, **scfg_kw)
    spec = dataclasses.replace(base, speculate_k=k, draft="self")
    return cfg, params, base, spec


def _requests(cfg, n, rng, max_prompt=16, max_gen=8):
    reqs = []
    for uid in range(n):
        plen = int(rng.integers(3, max_prompt))
        gen = int(rng.integers(2, max_gen + 1))
        reqs.append(Request(
            uid=uid, prompt=rng.integers(0, cfg.vocab, plen).astype(np.int32),
            max_new_tokens=gen))
    return reqs


def _clone(reqs):
    return [dataclasses.replace(r) for r in reqs]


def _parity(a, b, tag):
    assert sorted(a) == sorted(b)
    for uid in a:
        np.testing.assert_array_equal(a[uid].tokens, b[uid].tokens,
                                      err_msg=f"{tag} uid={uid}")


# --------------------------------------------------------------------------
# Parity across every cache kind, accepting and rejecting drafts
# --------------------------------------------------------------------------


@pytest.mark.parametrize("arch", KINDS)
def test_spec_matches_plain_decode(arch):
    """Self-draft (accept rate 1 under greedy): the speculative server
    emits bitwise the plain server's tokens on all four cache kinds."""
    cfg, params, base, spec = _setup(arch, batch=2)
    rng = np.random.default_rng(0)
    reqs = _requests(cfg, 4, rng)
    a = Scheduler(params, cfg, base).run(_clone(reqs), max_steps=300)
    ss = Scheduler(params, cfg, spec)
    b = ss.run(_clone(reqs), max_steps=300)
    _parity(a, b, arch)
    st = ss.stats
    assert st["spec_ticks"] > 0 and st["draft_tokens"] > 0
    # the self-draft IS the target: greedy verify accepts everything
    assert st["accepted_draft_tokens"] == st["draft_tokens"]


@pytest.mark.parametrize("arch", KINDS)
def test_spec_rollback_under_disagreeing_draft(arch):
    """A draft from DIFFERENT weights mostly disagrees with the target,
    so nearly every tick rejects and rolls the caches back (ring-row
    restore, recurrent-state select, clock decrement) — parity must
    survive the rejection-heavy regime on every cache kind."""
    cfg, params, base, spec = _setup(arch, batch=2)
    draft_params = T.init_params(jax.random.PRNGKey(7), cfg)
    rng = np.random.default_rng(1)
    reqs = _requests(cfg, 3, rng)
    a = Scheduler(params, cfg, base).run(_clone(reqs), max_steps=300)
    ss = Scheduler(params, cfg, spec,
                   draft_params=draft_params, draft_cfg=cfg)
    b = ss.run(_clone(reqs), max_steps=300)
    _parity(a, b, arch)
    st = ss.stats
    # random disagreeing weights: rejection dominates, rollback exercised
    assert st["accepted_draft_tokens"] < st["draft_tokens"]


# --------------------------------------------------------------------------
# Composition: paged layout, int8 caches, int8 self-draft
# --------------------------------------------------------------------------


@pytest.mark.parametrize("k", [1, 4])
def test_spec_paged_int8_composition(k):
    """Speculation over the paged int8 cache: verify writes k rows past
    the frontier into pool pages, rejection derefs the vacated pages
    (`PagedKV.rollback`), and the page accounting stays clean after
    every tick."""
    cfg = _cfg("qwen2-1.5b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    base = serve_lib.ServeConfig(max_seq=48, batch=2,
                                 compute_dtype=jnp.float32,
                                 cache_dtype=jnp.int8,
                                 cache_layout="paged", page_size=8)
    spec = dataclasses.replace(base, speculate_k=k, draft="self")
    rng = np.random.default_rng(0)
    reqs = _requests(cfg, 4, rng)
    a = Scheduler(params, cfg, base).run(_clone(reqs), max_steps=300)
    ss = Scheduler(params, cfg, spec)
    for r in _clone(reqs):
        ss.submit(r)
    steps = 0
    while ss.queue or ss.n_active:
        ss.step()
        ss.paged.check_invariants()
        steps += 1
        assert steps < 300, "speculative paged scheduler did not drain"
    _parity(a, ss.completions, f"paged-int8 k={k}")


def test_spec_self_int8_draft():
    """draft='self-int8': the int8-quantized copy of the target drafts;
    parity is still exact because verify always rescores with the
    float target (the draft only proposes)."""
    cfg, params, base, spec = _setup("qwen2-1.5b", batch=2)
    spec = dataclasses.replace(spec, draft="self-int8")
    rng = np.random.default_rng(2)
    reqs = _requests(cfg, 3, rng)
    a = Scheduler(params, cfg, base).run(_clone(reqs), max_steps=300)
    b = Scheduler(params, cfg, spec).run(_clone(reqs), max_steps=300)
    _parity(a, b, "self-int8")


# --------------------------------------------------------------------------
# Config/API surface
# --------------------------------------------------------------------------


def test_spec_config_validation():
    with pytest.raises(ValueError, match="speculate_k"):
        serve_lib.ServeConfig(max_seq=32, batch=2, speculate_k=-1)
    with pytest.raises(ValueError, match="draft"):
        serve_lib.ServeConfig(max_seq=32, batch=2, draft="self")
    with pytest.raises(ValueError, match="draft"):
        serve_lib.ServeConfig(max_seq=32, batch=2, speculate_k=2,
                              draft="gpt-tiny")


def test_spec_rejects_sampling_and_overflow():
    cfg, params, _, spec = _setup("qwen2-1.5b", batch=2, max_seq=32, k=3)
    sched = Scheduler(params, cfg, spec)
    with pytest.raises(ValueError, match="greedy"):
        sched.submit(Request(uid=0, prompt=np.zeros(4, np.int32),
                             max_new_tokens=2, temperature=0.5,
                             key=jax.random.PRNGKey(0)))
    # headroom: prompt + budget + k must fit below max_seq
    with pytest.raises(ValueError, match="max_seq"):
        sched.submit(Request(uid=1, prompt=np.zeros(20, np.int32),
                             max_new_tokens=10))


def test_spec_window_too_small_fails_with_intent():
    """A verify width wider than the sliding window cannot reproduce
    the sequential ring state: constructor refuses, not corrupts."""
    cfg = _cfg("recurrentgemma-2b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    window = min(cfg.window, 48)
    spec = serve_lib.ServeConfig(max_seq=48, batch=2,
                                 compute_dtype=jnp.float32,
                                 cache_dtype=jnp.float32,
                                 speculate_k=window, draft="self")
    with pytest.raises(ValueError, match="window"):
        Scheduler(params, cfg, spec)


def test_spec_draft_pairing_validation():
    cfg, params, base, spec = _setup("qwen2-1.5b", batch=2)
    with pytest.raises(ValueError, match="draft_cfg"):
        Scheduler(params, cfg, spec, draft_params=params)
    with pytest.raises(ValueError, match="speculate_k"):
        Scheduler(params, cfg, base,
                  draft_params=params, draft_cfg=cfg)
