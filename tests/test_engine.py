"""repro.engine: unified decisions, plan cache, registry dispatch."""

import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.engine import (AnalyticalCostModel, BACKENDS, CostModel, Engine,
                          ExecutionPlan, KernelDecision, KernelRequest,
                          TPUModel, active_engine, default_registry,
                          plan_arch, use_engine)
from repro.kernels.ref import matmul_ref


def test_import_repro_is_jax_free():
    """Satellite: `import repro` (and planning) must not import jax."""
    code = (
        "import sys\n"
        "import repro\n"
        "assert 'jax' not in sys.modules, 'repro pulled jax'\n"
        "assert repro.__version__\n"
        "import repro.engine\n"
        "assert 'jax' not in sys.modules, 'repro.engine pulled jax'\n"
        "cfg = repro.get_config('qwen2-1.5b', smoke=True)\n"
        "plan = repro.plan_arch(cfg, seq_len=32, backend='pallas-interpret')\n"
        "assert 'jax' not in sys.modules, 'planning pulled jax'\n"
        "assert len(plan) > 0\n"
    )
    subprocess.run([sys.executable, "-c", code], check=True,
                   env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
                   cwd=__file__.rsplit("/tests/", 1)[0])


def test_cost_model_protocol():
    assert isinstance(TPUModel(), CostModel)
    assert isinstance(AnalyticalCostModel(), CostModel)


def test_unified_decision_both_planes():
    """The acceptance claim: ReDasMapper and the TPU dispatch answer the
    same KernelRequest with the same KernelDecision dataclass."""
    req = KernelRequest("gemm", 43264, 144, 32, name="tinyyolo_l2")
    tpu = TPUModel().decide(req)
    asic = AnalyticalCostModel().decide(req)
    assert isinstance(tpu, KernelDecision) and isinstance(asic, KernelDecision)
    assert tpu.dataflow in ("os", "ws", "is")
    assert asic.dataflow in ("os", "ws", "is")
    # the ASIC decision carries its full mapping for the simulator backend
    cfg = AnalyticalCostModel.mapping_config(asic)
    assert cfg.tile_m == asic.bm and cfg.tile_k == asic.bk
    assert tpu.seconds > 0 and asic.seconds > 0


def test_decision_cache_stats():
    eng = Engine(backend="pallas-interpret")
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(16, 64)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(32, 48)), jnp.float32)
    eng.matmul(a, b)                      # miss
    eng.matmul(a, b)                      # hit (memo)
    eng.matmul(a, b)                      # hit
    eng.matmul(b, c)                      # second shape: miss
    st = eng.plan.stats
    assert st["decisions"] == 2
    assert st["misses"] == 2
    assert st["hits"] == 2
    assert 0 < st["hit_rate"] < 1


def test_plan_json_roundtrip_byte_identical(tmp_path):
    cfg_path = tmp_path / "plan.json"
    eng = Engine(backend="pallas-interpret")
    eng.plan_gemms([(128, 256, 512), (1, 1024, 16), (43264, 144, 32)])
    eng.plan.save(cfg_path)
    text1 = cfg_path.read_text()
    plan2 = ExecutionPlan.load(cfg_path)
    path2 = tmp_path / "plan2.json"
    plan2.save(path2)
    assert path2.read_text() == text1          # byte-identical round trip
    assert len(plan2) == 3
    # decisions survive with full fidelity
    for (req, dec), (req2, dec2) in zip(eng.plan, plan2, strict=True):
        assert req == req2 and dec == dec2


def test_plan_load_rejects_other_json(tmp_path):
    p = tmp_path / "x.json"
    p.write_text('{"hello": 1}')
    with pytest.raises(ValueError, match="not an execution plan"):
        ExecutionPlan.load(p)


def test_plan_arch_covers_trace():
    from repro.configs import get_config
    from repro.core.workloads import arch_gemms

    cfg = get_config("qwen2-1.5b", smoke=True)
    plan = plan_arch(cfg, seq_len=64, backend="pallas-interpret")
    trace = arch_gemms(cfg, seq_len=64)
    distinct = {(g.M, g.K, g.N) for g in trace}
    assert len(plan) == len(distinct)
    assert plan.backend == "pallas-interpret"
    assert plan.misses == len(distinct)
    assert plan.hits == len(trace) - len(distinct)


def test_plan_arch_verify_k_roundtrip_byte_identical(tmp_path):
    """plan_arch(..., verify_k=K) declares the K+1-wide speculative
    verify GEMMs next to the decode/admit widths, and the augmented
    plan still round-trips byte-identical through JSON."""
    from repro.configs import get_config

    cfg = get_config("qwen2-1.5b", smoke=True)
    kw = dict(seq_len=16, dtype_bytes=4, decode_batch=3,
              admit_widths=(8, 16), backend="pallas-interpret")
    base = plan_arch(cfg, **kw)
    plan = plan_arch(cfg, verify_k=4, **kw)
    assert len(plan) > len(base)           # the verify width added shapes
    assert any(req.m == 3 * 5 for req, _ in plan)  # m = pool * (k+1)
    p1, p2 = tmp_path / "plan.json", tmp_path / "plan2.json"
    plan.save(p1)
    plan2 = ExecutionPlan.load(p1)
    plan2.save(p2)
    assert p2.read_text() == p1.read_text()
    import dataclasses
    for (req, dec), (req2, dec2) in zip(plan, plan2, strict=True):
        # `name` is a human label, excluded from the key and the JSON
        assert dataclasses.replace(req, name="") == req2 and dec == dec2


def test_spec_serve_replayed_from_plan_no_misses(tmp_path):
    """A speculative server warm-started from a saved verify_k plan
    serves its whole trace as pure cache lookups: zero new misses."""
    import dataclasses

    import jax

    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.serve_lib import serve as serve_lib
    from repro.serve_lib.scheduler import Request, Scheduler

    cfg = get_config("qwen2-1.5b", smoke=True)
    pool, k, bucket = 2, 3, 8
    plan_arch(cfg, seq_len=16, dtype_bytes=4, decode_batch=pool,
              admit_widths=(8, 16), verify_k=k,
              backend="xla-einsum").save(tmp_path / "plan.json")
    eng = Engine(backend="xla-einsum",
                 plan=ExecutionPlan.load(tmp_path / "plan.json"))
    scfg = serve_lib.ServeConfig(max_seq=32, batch=pool,
                                 compute_dtype=jnp.float32,
                                 cache_dtype=jnp.float32,
                                 speculate_k=k, draft="self")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=u, prompt=rng.integers(0, cfg.vocab, p)
                    .astype(np.int32), max_new_tokens=g)
            for u, (p, g) in enumerate([(6, 8), (12, 6), (9, 10)])]
    misses_before = eng.plan.misses
    sched = Scheduler(params, cfg, scfg, engine=eng, prefill_bucket=bucket)
    comps = sched.run([dataclasses.replace(r) for r in reqs], max_steps=200)
    assert sorted(comps) == [0, 1, 2]
    assert sched.stats["spec_ticks"] > 0
    assert eng.plan.misses == misses_before   # replay re-plans nothing
    assert eng.plan.hits > 0


def test_warm_start_plan_skips_search(tmp_path):
    """Serve warm-start: a loaded plan answers without cost-model work."""
    cfg_path = tmp_path / "plan.json"
    eng = Engine(backend="pallas-interpret")
    eng.plan_gemms([(16, 64, 32)], in_bytes=4)  # match the f32 arrays below
    eng.plan.save(cfg_path)

    class Exploding:
        name = "exploding"
        default_backend = None

        def decide(self, req):  # pragma: no cover - must not be called
            raise AssertionError("warm-started plan should not re-search")

    warm = Engine(Exploding(), backend="pallas-interpret",
                  plan=ExecutionPlan.load(cfg_path))
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.normal(size=(16, 64)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    got = warm.matmul(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(matmul_ref(a, b)),
                               rtol=2e-5, atol=2e-4)


def test_asic_plan_rejected_on_pallas_backend(tmp_path):
    """An AnalyticalCostModel plan (ASIC tile dims, not Pallas-aligned)
    must fail with intent when loaded into a Pallas-backend engine."""
    p = tmp_path / "asic.json"
    asic = Engine(AnalyticalCostModel())
    asic.plan_gemms([(300, 144, 32)], in_bytes=4)
    asic.plan.save(p)
    warm = Engine(backend="pallas-interpret", plan=ExecutionPlan.load(p))
    rng = np.random.default_rng(9)
    a = jnp.asarray(rng.normal(size=(300, 144)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(144, 32)), jnp.float32)
    with pytest.raises(ValueError, match="ASIC cost model"):
        warm.matmul(a, b)


def test_asic_cost_model_on_pallas_backend_fails_with_intent():
    """Fresh ASIC decisions (not just warm-start hits) on a Pallas
    backend must raise the re-plan message, not a block-alignment error."""
    eng = Engine(AnalyticalCostModel(), backend="pallas-interpret")
    with pytest.raises(ValueError, match="ASIC cost model"):
        eng.matmul(jnp.ones((300, 144), jnp.float32),
                   jnp.ones((144, 32), jnp.float32))


def test_engine_matmul_accepts_numpy_inputs():
    """The pre-engine auto_matmul accepted numpy via jit auto-conversion;
    the aval-keyed engine path must too (migration compatibility)."""
    rng = np.random.default_rng(10)
    a = rng.normal(size=(8, 16)).astype(np.float32)
    b = rng.normal(size=(16, 8)).astype(np.float32)
    got = Engine(backend="pallas-interpret").matmul(a, b)
    np.testing.assert_allclose(np.asarray(got), a @ b, rtol=2e-5, atol=2e-4)


def test_warm_start_engine_cached_per_config(tmp_path):
    from repro.serve_lib import serve as serve_lib

    p = tmp_path / "plan.json"
    Engine(backend="pallas-interpret").plan_gemms([(16, 64, 32)],
                                                  in_bytes=4).plan.save(p)
    scfg = serve_lib.ServeConfig(
        max_seq=8, batch=1, compute_dtype=jnp.float32,
        kernel_backend="pallas-interpret", plan_path=str(p))
    e1 = serve_lib.warm_start_engine(scfg)
    e2 = serve_lib.warm_start_engine(scfg)
    assert e1 is e2   # repeated generate() calls share the decision memo


def test_warm_start_dtype_mismatch_warns(tmp_path):
    from repro.serve_lib import serve as serve_lib

    p = tmp_path / "plan.json"
    Engine(backend="pallas-interpret").plan_gemms([(16, 64, 32)],
                                                  in_bytes=2).plan.save(p)
    scfg = serve_lib.ServeConfig(
        max_seq=8, batch=1, compute_dtype=jnp.float32,
        kernel_backend="pallas-interpret", plan_path=str(p))
    with pytest.warns(UserWarning, match="in_bytes=4"):
        serve_lib.warm_start_engine(scfg)


def test_attention_block_hint_never_degenerates():
    from repro.kernels.flash_attention import _legal_block

    assert _legal_block(1024, 512) == 512
    assert _legal_block(9, 512) == 9
    assert _legal_block(1021, 512) == 1021   # prime: one block, not 1-row


def test_registry_backends_complete():
    reg = default_registry()
    assert set(BACKENDS) <= set(reg.backends())
    for backend in ("pallas-tpu", "pallas-interpret", "xla-einsum"):
        assert set(reg.ops(backend)) == {"attention", "gemm", "grouped_gemm",
                                         "paged_attention"}
    assert reg.ops("simulator") == ("gemm",)
    with pytest.raises(KeyError, match="no kernel registered"):
        reg.get("simulator", "attention")


def test_backend_parity_gemm():
    """The same engine decisions execute identically on xla-einsum and
    pallas-interpret."""
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.normal(size=(33, 150)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(150, 65)), jnp.float32)
    outs = {}
    for backend in ("xla-einsum", "pallas-interpret"):
        outs[backend] = np.asarray(Engine(backend=backend).matmul(a, b))
    np.testing.assert_allclose(outs["xla-einsum"], outs["pallas-interpret"],
                               rtol=2e-5, atol=5e-4)


def test_backend_parity_grouped():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(4, 12, 40)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(4, 40, 24)), jnp.float32)
    outs = {}
    for backend in ("xla-einsum", "pallas-interpret"):
        eng = Engine(backend=backend)
        outs[backend] = np.asarray(eng.grouped_matmul(x, w))
    np.testing.assert_allclose(outs["xla-einsum"], outs["pallas-interpret"],
                               rtol=2e-5, atol=5e-4)


def test_grouped_decision_is_vmem_gated():
    from repro.kernels.redas_gemm import VMEM_BYTES, vmem_bytes

    dec = TPUModel().decide(
        KernelRequest("grouped_gemm", 4096, 8192, 4096, groups=8))
    assert dec.dataflow == "os"
    assert vmem_bytes(dec.bm, dec.bk, dec.bn) <= VMEM_BYTES


def test_simulator_backend_executes_asic_decision():
    eng = Engine(AnalyticalCostModel())
    assert eng.backend == "simulator"
    rng = np.random.default_rng(4)
    a = jnp.asarray(rng.normal(size=(10, 6)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(6, 8)), jnp.float32)
    got = eng.matmul(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(a @ b),
                               rtol=1e-4, atol=1e-4)


def test_use_engine_nesting_and_active():
    assert active_engine() is None
    with use_engine(backend="xla-einsum") as outer:
        assert active_engine() is outer
        with use_engine(backend="pallas-interpret") as inner:
            assert active_engine() is inner
        assert active_engine() is outer
    assert active_engine() is None
    with pytest.raises(ValueError, match="not both"):
        with use_engine(Engine(), backend="xla-einsum"):
            pass


def test_engine_attention_matches_reference():
    from repro.models.layers import flash_attention

    rng = np.random.default_rng(5)
    b, h, s, d = 1, 2, 64, 16
    q = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.float32)
    eng = Engine(backend="pallas-interpret")
    got = eng.attention(q, k, v, causal=True)
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    kv_len = jnp.full((b,), s, jnp.int32)
    want = flash_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                           v.transpose(0, 2, 1, 3), positions, kv_len,
                           True, 0, s).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-3)


def test_engine_matmul_differentiable():
    """The dispatch-layer custom VJP: grads through the Pallas backend
    match XLA (training with kernel_backend set depends on this)."""
    import jax

    def loss_x(w, x):
        return jnp.sum(jnp.tanh(x @ w))

    def loss_eng(w, x):
        return jnp.sum(jnp.tanh(active_engine().matmul(x, w)))

    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(12, 40)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(40, 24)), jnp.float32)
    g_ref = jax.grad(loss_x)(w, x)
    with use_engine(backend="pallas-interpret"):
        g_eng = jax.grad(loss_eng)(w, x)
    np.testing.assert_allclose(np.asarray(g_eng), np.asarray(g_ref),
                               rtol=2e-5, atol=5e-4)


def test_grouped_matmul_differentiable():
    import jax
    from repro.kernels.grouped_gemm import grouped_matmul

    rng = np.random.default_rng(8)
    x = jnp.asarray(rng.normal(size=(3, 10, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 16, 8)), jnp.float32)

    def loss_ref(w_):
        return jnp.sum(jnp.tanh(jnp.einsum("ecd,edf->ecf", x, w_)))

    def loss_eng(w_):
        return jnp.sum(jnp.tanh(active_engine().grouped_matmul(x, w_)))

    g_ref = jax.grad(loss_ref)(w)
    with use_engine(backend="pallas-interpret"):
        g_eng = jax.grad(loss_eng)(w)
    np.testing.assert_allclose(np.asarray(g_eng), np.asarray(g_ref),
                               rtol=2e-5, atol=5e-4)
    assert grouped_matmul is not None  # direct entry stays importable


def test_moe_block_through_engine():
    """The sorted-dispatch MoE path routes its expert FFN through the
    engine's grouped_gemm decision and matches the XLA einsum path."""
    import dataclasses

    import jax
    from repro.configs import get_config
    from repro.models import moe

    cfg = get_config("mixtral-8x7b", smoke=True)
    cfg = dataclasses.replace(              # sorted dispatch: the grouped path
        cfg, moe=dataclasses.replace(cfg.moe, impl="sort"))
    params = moe.moe_init(jax.random.PRNGKey(0), cfg)
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (1, 16, cfg.d_model),
                                jnp.float32)
    ref, _ = moe.moe_block(params, cfg, x)
    with use_engine(backend="pallas-interpret") as eng:
        got, _ = moe.moe_block(params, cfg, x)
    assert any(req.op == "grouped_gemm" for req, _ in eng.plan)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-3)
