"""Property tests for Eq. (1) logical-shape enumeration and dataflows."""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.dataflow import (ALL_DATAFLOWS, LogicalShape,
                                 bypass_cycles, enumerate_logical_shapes,
                                 n_logical_shapes, pe_usage,
                                 subarray_decomposition, tile_dims_for)

even_sides = st.integers(min_value=1, max_value=64).map(lambda k: 2 * k)


@given(even_sides)
@settings(max_examples=50, deadline=None)
def test_shape_count_matches_closed_form(r_p):
    shapes = enumerate_logical_shapes(r_p)
    assert len(shapes) == n_logical_shapes(r_p) == r_p + 1
    assert len(set(shapes)) == len(shapes)  # no duplicates


@given(even_sides)
@settings(max_examples=50, deadline=None)
def test_shapes_satisfy_eq1(r_p):
    for s in enumerate_logical_shapes(r_p):
        wide = 0 < s.rows <= r_p // 2 and s.cols == 4 * (r_p - s.rows)
        tall = 0 < s.cols <= r_p // 2 and s.rows == 4 * (r_p - s.cols)
        native = s.rows == s.cols == r_p
        assert wide or tall or native
        # reshaped shapes never exceed the physical PE count
        (r_s, c_s), n = subarray_decomposition(s, r_p)
        assert r_s * c_s * n <= r_p * r_p
        assert 0 < pe_usage(s, r_p) <= 1.0


@given(even_sides, st.integers(min_value=1, max_value=8))
@settings(max_examples=50, deadline=None)
def test_granularity_restricts_multiples(r_p, g):
    for s in enumerate_logical_shapes(r_p, granularity=g):
        if s.rows == s.cols == r_p:
            continue
        assert min(s.rows, s.cols) % g == 0


def test_paper_6x6_example():
    got = {str(s) for s in enumerate_logical_shapes(6)}
    assert got == {"1x20", "20x1", "2x16", "16x2", "3x12", "12x3", "6x6"}


def test_paper_128_count():
    assert n_logical_shapes(128) == 129  # the paper's headline count
    assert n_logical_shapes(128, granularity=4) == 33


@given(even_sides)
@settings(max_examples=30, deadline=None)
def test_bypass_cycles(r_p):
    for s in enumerate_logical_shapes(r_p):
        b = bypass_cycles(s)
        assert b == (0 if s.is_square else 4 * min(s.rows, s.cols))


def test_tile_dims_pin_two_of_three():
    s = LogicalShape(16, 448)
    for df in ALL_DATAFLOWS:
        dims = tile_dims_for(df, s)
        pinned = {k for k in dims if k.endswith("_t")}
        assert len(pinned) == 2 and dims["free"] not in pinned


def test_invalid_physical_sides():
    with pytest.raises(ValueError):
        enumerate_logical_shapes(7)
    with pytest.raises(ValueError):
        enumerate_logical_shapes(0)
