"""Clean fixture: index maps agree with their grid and blocks."""

import jax
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] + b_ref[...]


def add_blocks(a, b):
    return pl.pallas_call(
        _kernel,
        grid=(2, 2),
        in_specs=[pl.BlockSpec((8, 128), lambda i, j: (i, 0)),
                  pl.BlockSpec((8, 128), lambda i, j: (0, j))],
        out_specs=pl.BlockSpec((8, 128), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((16, 256), a.dtype),
    )(a, b)
