present = 1
