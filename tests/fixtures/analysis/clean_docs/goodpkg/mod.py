"""Fixture module citing a real section: DESIGN.md §1."""
