"""Clean fixture: the sanctioned patterns — a memoized jit factory,
a metadata-only dtype branch, and a jitted fn over immutable globals."""

import functools

import jax
import jax.numpy as jnp

_SCALE = 2.0


@functools.lru_cache(maxsize=8)
def jitted_step(fn):
    return jax.jit(fn)


def cast(x):
    if jnp.issubdtype(x.dtype, jnp.floating):  # metadata, not a tracer
        return x
    return x.astype(jnp.float32)


@jax.jit
def apply(x):
    return x * _SCALE
