"""Planted fixture: a fresh jax.jit per call, no memoized factory
(JD001), plus a Python branch on a traced reduction (JD002) and a
module-level jitted closure over a mutable global (JD003)."""

import jax
import jax.numpy as jnp

_SCALES = {"attn": 2.0}


def make_step(fn):
    return jax.jit(fn)  # planted JD001


def forward(x):
    if jnp.sum(x) > 0:  # planted JD002
        return x
    return -x


@jax.jit
def apply(x):
    return x * _SCALES["attn"]  # planted JD003
