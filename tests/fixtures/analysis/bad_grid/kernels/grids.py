"""Planted fixture: one KL005 (index_map arity != grid rank) and one
KL006 (index_map return tuple != BlockSpec block rank)."""

import jax
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] + b_ref[...]


def bad_arity(a, b):
    return pl.pallas_call(
        _kernel,
        grid=(2, 2),
        in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0)),  # planted KL005
                  pl.BlockSpec((8, 128), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((8, 128), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((16, 256), a.dtype),
    )(a, b)


def bad_return(a, b):
    return pl.pallas_call(
        _kernel,
        grid=(2,),
        in_specs=[pl.BlockSpec((8, 128), lambda i: (0, i, 0)),  # planted KL006
                  pl.BlockSpec((8, 128), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((16, 128), a.dtype),
    )(a, b)
