"""Fixture module.

Cites a section that does not exist: DESIGN.md §77 (DC001, line 3).
"""
