present = 1
