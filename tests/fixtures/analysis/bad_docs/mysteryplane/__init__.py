# undocumented package: no README module-map row (DC002)
