"""Planted fixture for the cache-rule checks: the "conv" rule is
deleted (SH001), "state" lost an axis entry (SH003), "h" names an
unknown logical axis (SH007), and "cells" matches nothing (SH002)."""

LOGICAL_AXIS_RULES = {
    "batch": ("pod", "data"),
    "seq": ("data",),
    "seq_kv": ("data",),
    "embed": ("model",),
    "residual": ("model",),
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "experts": ("model",),
}

_CACHE_AXES = {
    "k": (None, "batch", "seq_kv", "kv_heads", None),
    "v": (None, "batch", "seq_kv", "kv_heads", None),
    "k_scale": (None, "batch", "seq_kv", "kv_heads"),
    "v_scale": (None, "batch", "seq_kv", "kv_heads"),
    # planted SH001: the "conv" rule (ssm/rglru conv leaf) is deleted
    # planted SH003: "state" dropped its trailing axis (leaf is rank 5)
    "state": (None, "batch", "heads", None),
    # planted SH007: "mlpz" is not in LOGICAL_AXIS_RULES
    "h": (None, "batch", "mlpz"),
    "k_pages": (None, "seq_kv", None, "kv_heads", None),
    "v_pages": (None, "seq_kv", None, "kv_heads", None),
    "k_scale_pages": (None, "seq_kv", None, "kv_heads"),
    "v_scale_pages": (None, "seq_kv", None, "kv_heads"),
    # planted SH002: no config produces a "cells" leaf
    "cells": (None, "batch", None),
}


def _auto_spec(name, shape, sizes):
    return ()
