"""Clean fixture: every ladder aligns and caps on its VREG floor."""

SUBLANE, LANE = 8, 128
VMEM = 16 * 2**20


def _ladder(dim, align, cap):
    return [min(align, cap)]


def choose_kernel_config(m, k, n, in_bytes=2):
    best = None
    for bm in _ladder(m, SUBLANE, 512):
        for bk in _ladder(k, LANE, 2048):
            for bn in _ladder(n, LANE, 512):
                best = (bm, bk, bn)
    return best
