"""Planted fixture: registers an op outside KNOWN_OPS (KL001)."""


def _gemm(a, b, decision):
    return a @ b


def register_into(registry):
    registry.register("pallas-tpu", "gemm", _gemm)
    registry.register("pallas-tpu", "gemm_typo", _gemm)  # planted KL001
