"""Paged KV cache (PR 6): allocator/index units, layout parity across
every cache kind, int8 composition, prefix sharing, and a scheduler
stress test that checks the page-accounting invariants every tick.

The load-bearing property is EXACT parity: the paged layout gathers the
same logical rows in the same order as the contiguous cache and masked
scores underflow to exact 0.0, so greedy tokens must match bitwise —
any drift is a page-table bug, not numerics.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import engine as engine_mod
from repro.configs import get_config
from repro.kernels.paged_attention import (paged_attention_reference,
                                           paged_attention_tpu)
from repro.models import layers
from repro.models import transformer as T
from repro.serve_lib import serve as serve_lib
from repro.serve_lib.paged import (PageAllocator, PagedKV, PoolExhausted,
                                   PrefixIndex)
from repro.serve_lib.scheduler import Request, Scheduler

# the four cache kinds plus the local+attn hybrid: paging arms only on
# archs with full-attention layers, and prefix sharing only when EVERY
# layer is shareable (pure attention)
KINDS = ["qwen2-1.5b", "mixtral-8x7b", "mamba2-780m", "recurrentgemma-2b",
         "gemma3-12b"]


def _cfg(arch):
    cfg = get_config(arch, smoke=True)
    if cfg.moe is not None:  # avoid capacity drops in exactness checks
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    return cfg


def _setup(arch, batch, max_seq=48, page_size=8, **scfg_kw):
    cfg = _cfg(arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    contig = serve_lib.ServeConfig(max_seq=max_seq, batch=batch,
                                   compute_dtype=jnp.float32,
                                   cache_dtype=jnp.float32, **scfg_kw)
    paged = dataclasses.replace(contig, cache_layout="paged",
                                page_size=page_size)
    return cfg, params, contig, paged


def _requests(cfg, n, rng, max_prompt=20, max_gen=8, prefix=None):
    reqs = []
    for uid in range(n):
        plen = int(rng.integers(3, max_prompt))
        gen = int(rng.integers(2, max_gen + 1))
        prompt = rng.integers(0, cfg.vocab, plen).astype(np.int32)
        if prefix is not None:
            prompt = np.concatenate([prefix, prompt])
        reqs.append(Request(uid=uid, prompt=prompt, max_new_tokens=gen))
    return reqs


def _clone(reqs):
    return [dataclasses.replace(r) for r in reqs]


# --------------------------------------------------------------------------
# Host plane units: allocator, prefix index, PagedKV lifecycle
# --------------------------------------------------------------------------


def test_page_allocator_accounting():
    a = PageAllocator(4)
    assert a.free_count == 4
    pages = a.alloc(3)
    assert pages == [0, 1, 2]  # deterministic hand-out order
    assert a.free_count == 1
    a.ref([1])
    assert a.deref([0, 1]) == [0]       # 1 stays alive at refcount 1
    assert a.deref([1]) == [1]
    assert a.free_count == 3
    with pytest.raises(PoolExhausted):
        a.alloc(4)
    assert a.free_count == 3            # failed alloc mutates nothing
    with pytest.raises(ValueError):
        PageAllocator(0)


def test_prefix_index_lookup_insert_evict():
    a = PageAllocator(8)
    idx = PrefixIndex(page_size=4)
    toks = list(range(10))              # 2 full pages + remainder
    pages = a.alloc(3)
    assert idx.lookup(toks) == []
    assert idx.insert(toks, pages, a) == 2      # only FULL pages indexed
    assert a.refcount[pages[0]] == 2 and a.refcount[pages[2]] == 1
    assert idx.lookup(toks) == pages[:2]
    assert idx.lookup(toks[:7]) == pages[:1]    # partial second page
    assert idx.lookup([99] + toks[1:]) == []
    # re-inserting the same prefix keeps the original pages
    other = a.alloc(2)
    assert idx.insert(toks[:8], other, a) == 0
    assert idx.lookup(toks) == pages[:2]
    # owner releases; the index alone keeps the prefix alive
    a.deref(pages)
    assert a.refcount[pages[0]] == 1 and len(idx) == 2
    # eviction drops the LRU leaf first (deepest page of the prefix)
    free0 = a.free_count
    assert idx.evict(free0 + 1, a) == 1
    assert idx.lookup(toks) == pages[:1]


def test_pagedkv_admit_share_release():
    kv = PagedKV(batch=2, max_seq=32, page_size=4, n_pages=16)
    p1 = list(range(10))                # pages: 2 full + 1 partial
    assert kv.admit(0, p1) == 0         # cold: nothing shared
    kv.note_prefilled(0, p1)
    kv.check_invariants()
    # second request, same full-page prefix, different tail
    p2 = p1[:8] + [77, 78, 79]
    hist = kv.admit(1, p2)
    assert hist == 8                    # both full pages reused
    assert list(kv.tables[1][:2]) == list(kv.tables[0][:2])
    assert kv.alloc.refcount[kv.tables[0][0]] == 3  # 2 slots + index
    kv.check_invariants()
    kv.release(0)
    kv.check_invariants()
    assert kv.alloc.refcount[kv.tables[1][0]] == 2  # slot 1 + index
    kv.release(1)
    kv.check_invariants()
    assert len(kv.index) == 2           # prefix survives in the index
    assert kv.shared_tokens == 8


def test_sharing_caps_leave_private_frontier():
    """A prompt that is ENTIRELY a cached prefix still gets >= 1 private
    suffix token: the write frontier is never a shared page."""
    kv = PagedKV(batch=2, max_seq=32, page_size=4, n_pages=16)
    p1 = list(range(8))                 # exactly 2 full pages
    kv.admit(0, p1)
    kv.note_prefilled(0, p1)
    hist = kv.admit(1, list(p1))        # identical prompt
    assert hist == 4                    # capped: last page re-owned
    assert kv.tables[1][1] != kv.tables[0][1]
    assert kv.alloc.refcount[kv.tables[1][1]] == 1
    kv.check_invariants()


def test_decode_frontier_never_shared():
    """ensure_decode_page refuses a refcount>1 write target: divergence
    after a shared prefix must never scribble into donor pages."""
    kv = PagedKV(batch=2, max_seq=32, page_size=4, n_pages=16)
    kv.admit(0, list(range(10)))
    kv.note_prefilled(0, list(range(10)))
    kv.admit(1, list(range(10)) + [5])
    # private frontiers are fine (and allocate holes on demand)
    kv.ensure_decode_page(0, 10)
    kv.ensure_decode_page(1, 12)
    kv.check_invariants()
    # point slot 1's frontier at a shared page artificially
    kv.tables[1][3] = -1
    with pytest.raises(AssertionError, match="re-own"):
        kv.ensure_decode_page(1, kv.page * 1)   # page 1 is shared
    # and a hole past the slot table bounds is a hard error
    with pytest.raises(AssertionError):
        kv.ensure_decode_page(0, 32)


def test_pool_exhaustion_is_atomic():
    kv = PagedKV(batch=2, max_seq=64, page_size=4, n_pages=3,
                 prefix_sharing=False)
    kv.admit(0, list(range(9)))         # 3 pages: pool now full
    with pytest.raises(PoolExhausted):
        kv.admit(1, list(range(5)))
    assert (kv.tables[1] < 0).all()     # failed admit left no state
    kv.check_invariants()


def test_admit_pool_pressure_does_not_free_matched_prefix():
    """Regression: admit() must pin matched shared pages BEFORE
    allocating the suffix — under pool pressure _alloc evicts index
    entries, and an unpinned match could be freed (and re-issued as the
    suffix's fresh pages) mid-admit.  An infeasible request fails with
    PoolExhausted and leaves the accounting clean, never a crash or an
    aliased table."""
    kv = PagedKV(batch=1, max_seq=8, page_size=1, n_pages=5)
    kv.admit(0, [1, 2, 3])
    kv.note_prefilled(0, [1, 2, 3])
    kv.release(0)                       # prefix lives only in the index
    with pytest.raises(PoolExhausted):
        kv.admit(0, [1, 2, 3, 4, 5, 6])  # needs 6 pages of a 5-page pool
    assert (kv.tables[0] < 0).all()
    kv.check_invariants()


def test_admit_under_pressure_evicts_only_unshared_entries():
    """When eviction during admit CAN free enough pages, it reclaims
    LRU index entries while the just-matched shared prefix survives
    pinned — the suffix never aliases onto the shared pages."""
    kv = PagedKV(batch=2, max_seq=8, page_size=1, n_pages=6)
    kv.admit(0, [1, 2, 3])
    kv.note_prefilled(0, [1, 2, 3])
    kv.release(0)
    kv.admit(0, [9, 9])
    kv.note_prefilled(0, [9, 9])
    kv.release(0)                       # 5 indexed pages, 1 free
    shared_before = kv.index.lookup([1, 2, 3])
    hist = kv.admit(1, [1, 2, 3, 4, 5])  # needs 2 fresh: evicts [9, 9]
    assert hist == 3
    row = [int(p) for p in kv.tables[1][:5]]
    assert row[:3] == shared_before     # matched pages survived eviction
    assert len(set(row)) == 5           # fresh pages never alias shared
    kv.check_invariants()


# --------------------------------------------------------------------------
# Parity: paged scheduler == contiguous scheduler, every cache kind
# --------------------------------------------------------------------------


@pytest.mark.parametrize("arch", KINDS)
def test_paged_matches_contiguous(arch):
    """Same trace through the contiguous and the paged Scheduler emits
    bitwise-identical greedy tokens.  On window/SSM/RG-LRU-only archs
    the paged config passes through to the contiguous plane (nothing to
    page); the hybrid pages its attention layers only."""
    cfg, params, contig, paged = _setup(arch, batch=2)
    rng = np.random.default_rng(0)
    reqs = _requests(cfg, 4, rng, max_prompt=18, max_gen=6)
    a = Scheduler(params, cfg, contig).run(_clone(reqs), max_steps=300)
    sp = Scheduler(params, cfg, paged)
    b = sp.run(_clone(reqs), max_steps=300)
    has_attn = "attn" in cfg.layer_pattern
    assert (sp.paged is not None) == has_attn
    if sp.paged is not None:
        sp.paged.check_invariants()
        assert (sp.paged.index is not None) == (
            set(cfg.layer_pattern) == {"attn"})
    for uid in a:
        np.testing.assert_array_equal(a[uid].tokens, b[uid].tokens,
                                      err_msg=f"{arch} uid={uid}")


@given(page=st.integers(1, 8), seed=st.integers(0, 5))
@settings(max_examples=6)
def test_paged_reference_matches_contiguous_oracle(page, seed):
    """Property: for random page sizes and ADVERSARIAL (permuted,
    hole-riddled) page tables, the paged gather-attention equals the
    contiguous decode attention on the same logical rows — bitwise."""
    rng = np.random.default_rng(seed)
    b, h, kv, d, n_bt = 3, 4, 2, 8, int(rng.integers(2, 5))
    n_pool = b * n_bt + 3
    q = jnp.asarray(rng.normal(size=(b, 1, h, d)), jnp.float32)
    k_pages = jnp.asarray(rng.normal(size=(n_pool, page, kv, d)), jnp.float32)
    v_pages = jnp.asarray(rng.normal(size=(n_pool, page, kv, d)), jnp.float32)
    lens = rng.integers(1, n_bt * page + 1, size=(b,)).astype(np.int32)
    # adversarial table: physical pages permuted across the pool, slots
    # interleaved, everything past the live span left as -1 holes
    perm = rng.permutation(n_pool)
    bt = np.full((b, n_bt), -1, np.int32)
    ptr = 0
    for i in range(b):
        need = -(-int(lens[i]) // page)
        bt[i, :need] = perm[ptr:ptr + need]
        ptr += need
    o = paged_attention_reference(q, k_pages, v_pages, jnp.asarray(bt),
                                  jnp.asarray(lens))
    # contiguous oracle: gather each slot's logical rows, then run the
    # production decode attention (identity wo keeps the raw heads)
    S = n_bt * page
    kc = np.zeros((b, S, kv, d), np.float32)
    vc = np.zeros((b, S, kv, d), np.float32)
    for i in range(b):
        for j in range(n_bt):
            if bt[i, j] >= 0:
                kc[i, j * page:(j + 1) * page] = np.asarray(k_pages[bt[i, j]])
                vc[i, j * page:(j + 1) * page] = np.asarray(v_pages[bt[i, j]])
    stub_cfg = dataclasses.replace(_cfg("qwen2-1.5b"), n_heads=h, head_dim=d)
    ident = {"wo": {"w": jnp.eye(h * d, dtype=jnp.float32)}}
    ref = layers.cached_attention(
        ident, stub_cfg, q, jnp.asarray(kc), jnp.asarray(vc),
        jnp.asarray(lens - 1), jnp.asarray(lens))
    np.testing.assert_array_equal(np.asarray(o).reshape(b, 1, h * d),
                                  np.asarray(ref))


@given(seed=st.integers(0, 7))
@settings(max_examples=8)
def test_paged_reference_pool_placement_invariant(seed):
    """Permuting the PHYSICAL placement (pool rows + remapped tables)
    cannot change the output: only the logical gather order matters."""
    rng = np.random.default_rng(seed)
    b, h, kv, d, page, n_bt, n_pool = 2, 4, 2, 8, 4, 3, 10
    q = jnp.asarray(rng.normal(size=(b, 1, h, d)), jnp.float32)
    k_pages = rng.normal(size=(n_pool, page, kv, d)).astype(np.float32)
    v_pages = rng.normal(size=(n_pool, page, kv, d)).astype(np.float32)
    lens = jnp.asarray(rng.integers(1, n_bt * page + 1, size=(b,)), jnp.int32)
    bt = rng.permutation(n_pool)[: b * n_bt].reshape(b, n_bt).astype(np.int32)
    base = paged_attention_reference(q, jnp.asarray(k_pages),
                                     jnp.asarray(v_pages), jnp.asarray(bt),
                                     lens)
    perm = rng.permutation(n_pool)
    inv = np.argsort(perm)
    moved = paged_attention_reference(
        q, jnp.asarray(k_pages[inv]), jnp.asarray(v_pages[inv]),
        jnp.asarray(perm[bt].astype(np.int32)), lens)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(moved))


def test_paged_kernel_matches_reference():
    """The Pallas scalar-prefetch kernel (interpret mode off-TPU) agrees
    with the gather reference, float and int8."""
    rng = np.random.default_rng(0)
    b, h, kv, d, page, n_bt, n_pool = 3, 4, 2, 16, 8, 5, 32
    q = jnp.asarray(rng.normal(size=(b, 1, h, d)), jnp.float32)
    lens = jnp.asarray([1, 17, 37], jnp.int32)
    bt = np.full((b, n_bt), -1, np.int32)
    perm = rng.permutation(n_pool)
    ptr = 0
    for i in range(b):
        need = -(-int(lens[i]) // page)
        bt[i, :need] = perm[ptr:ptr + need]
        ptr += need
    bt = jnp.asarray(bt)
    kf = jnp.asarray(rng.normal(size=(n_pool, page, kv, d)), jnp.float32)
    vf = jnp.asarray(rng.normal(size=(n_pool, page, kv, d)), jnp.float32)
    ref = paged_attention_reference(q, kf, vf, bt, lens)
    out = paged_attention_tpu(q, kf, vf, bt, lens, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    k8 = jnp.asarray(rng.integers(-127, 128, (n_pool, page, kv, d)), jnp.int8)
    v8 = jnp.asarray(rng.integers(-127, 128, (n_pool, page, kv, d)), jnp.int8)
    ks = jnp.asarray(rng.uniform(1e-3, 2e-2, (n_pool, page, kv)), jnp.float32)
    vs = jnp.asarray(rng.uniform(1e-3, 2e-2, (n_pool, page, kv)), jnp.float32)
    ref8 = paged_attention_reference(q, k8, v8, bt, lens,
                                     k_scale=ks, v_scale=vs)
    out8 = paged_attention_tpu(q, k8, v8, bt, lens, ks, vs, interpret=True)
    np.testing.assert_allclose(np.asarray(out8), np.asarray(ref8),
                               rtol=2e-5, atol=2e-5)


def test_paged_kernel_fully_masked_slot_is_exact_zero():
    """A slot with kv_len == 0 (inactive) has EVERY position masked; the
    kernel's online softmax must emit exact zeros for it rather than an
    average of clamped page-0 v rows (the m == NEG_INF guard)."""
    rng = np.random.default_rng(1)
    b, h, kv, d, page, n_bt, n_pool = 2, 4, 2, 16, 8, 3, 8
    q = jnp.asarray(rng.normal(size=(b, 1, h, d)), jnp.float32)
    kf = jnp.asarray(rng.normal(size=(n_pool, page, kv, d)), jnp.float32)
    vf = jnp.asarray(rng.normal(size=(n_pool, page, kv, d)), jnp.float32)
    bt = jnp.asarray([[0, 1, 2], [-1, -1, -1]], jnp.int32)
    lens = jnp.asarray([11, 0], jnp.int32)
    out = paged_attention_tpu(q, kf, vf, bt, lens, interpret=True)
    np.testing.assert_array_equal(np.asarray(out[1]), 0.0)
    # and the live slot is untouched by the guard
    ref = paged_attention_reference(q, kf, vf, bt, lens)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(ref[0]),
                               rtol=2e-5, atol=2e-5)


def test_paged_through_engine_backends():
    """Inside an engine the registered paged_attention kernel serves the
    decode path (xla reference and Pallas interpret both): tokens stay
    bitwise-equal to the no-engine contiguous run."""
    cfg, params, contig, paged = _setup("qwen2-1.5b", batch=2)
    rng = np.random.default_rng(0)
    reqs = _requests(cfg, 3, rng, max_prompt=14, max_gen=5)
    a = Scheduler(params, cfg, contig).run(_clone(reqs), max_steps=300)
    for backend in ("xla-einsum", "pallas-interpret"):
        eng = engine_mod.Engine(backend=backend)
        assert eng.registry.has(backend, "paged_attention")
        b = Scheduler(params, cfg, paged, engine=eng).run(
            _clone(reqs), max_steps=300)
        for uid in a:
            np.testing.assert_array_equal(a[uid].tokens, b[uid].tokens,
                                          err_msg=f"{backend} uid={uid}")
        assert eng.plan.hits > 0


# --------------------------------------------------------------------------
# int8 composition: rows and their per-row scales page together
# --------------------------------------------------------------------------


def test_int8_paged_matches_int8_contiguous():
    cfg, params, contig, paged = _setup("qwen2-1.5b", batch=2)
    contig = dataclasses.replace(contig, cache_dtype=jnp.int8)
    paged = dataclasses.replace(paged, cache_dtype=jnp.int8)
    rng = np.random.default_rng(0)
    reqs = _requests(cfg, 4, rng, max_prompt=18, max_gen=6)
    a = Scheduler(params, cfg, contig).run(_clone(reqs), max_steps=300)
    sp = Scheduler(params, cfg, paged)
    b = sp.run(_clone(reqs), max_steps=300)
    sp.paged.check_invariants()
    for uid in a:
        np.testing.assert_array_equal(a[uid].tokens, b[uid].tokens,
                                      err_msg=f"uid={uid}")
    # scale placement: every pool's scale leaf is page-shaped alongside
    # its rows — one block-table lookup fetches row AND scale
    slot = sp.cache["slots"]["b0"]
    assert slot["k_pages"].dtype == jnp.int8
    assert slot["k_scale_pages"].shape == slot["k_pages"].shape[:-1]
    assert slot["v_scale_pages"].shape == slot["v_pages"].shape[:-1]


# --------------------------------------------------------------------------
# Prefix sharing: identical tokens, measurably less prefill
# --------------------------------------------------------------------------


def test_shared_prefix_parity_and_prefill_drop():
    cfg, params, contig, paged = _setup("qwen2-1.5b", batch=2, max_seq=96)
    rng = np.random.default_rng(0)
    prefix = rng.integers(0, cfg.vocab, 40).astype(np.int32)
    reqs = _requests(cfg, 6, rng, max_prompt=7, max_gen=4, prefix=prefix)
    sc = Scheduler(params, cfg, contig)
    a = sc.run(_clone(reqs), max_steps=400)
    sp = Scheduler(params, cfg, paged)
    b = sp.run(_clone(reqs), max_steps=400)
    sp.paged.check_invariants()
    for uid in a:
        np.testing.assert_array_equal(a[uid].tokens, b[uid].tokens,
                                      err_msg=f"uid={uid}")
    # the FLOP counter the sharing exists to drive down
    assert sp.stats["prefill_tokens"] < sc.stats["prefill_tokens"]
    assert sp.stats["shared_prefix_tokens"] > 0
    assert sp.paged.shared_tokens == sp.stats["shared_prefix_tokens"]


def test_mixed_history_admits_bucket_by_hist_pages():
    """Admits are bucketed by shared-history page count: a prefix-cache
    hit prefills at ITS OWN suffix width instead of paying the widest
    fresh prompt admitted in the same tick (the PR 6 width bug)."""
    cfg, params, contig, paged = _setup("qwen2-1.5b", batch=2, max_seq=64)
    rng = np.random.default_rng(3)
    prefix = rng.integers(0, cfg.vocab, 24).astype(np.int32)  # 3 full pages
    fresh = rng.integers(0, cfg.vocab, 24).astype(np.int32)
    suffix = rng.integers(0, cfg.vocab, 4).astype(np.int32)
    reqs = [Request(uid=0, prompt=prefix.copy(), max_new_tokens=2),
            Request(uid=1, prompt=np.concatenate([prefix, suffix]),
                    max_new_tokens=2),
            Request(uid=2, prompt=fresh.copy(), max_new_tokens=2)]

    sp = Scheduler(params, cfg, paged, prefill_bucket=8)
    sp.run([_clone(reqs)[0]], max_steps=50)     # indexes the prefix pages
    calls0 = sp.stats["prefill_calls"]
    wsum0 = sp.stats["prefill_width_sum"]
    sp.run(_clone(reqs)[1:], max_steps=100)     # B (hit) + C (cold) together
    sp.paged.check_invariants()
    assert sorted(sp.completions) == [0, 1, 2]
    # one prefill call per hist bucket, each at its own group width:
    # B's 4-token suffix rounds to 8, C's cold 24 stays 24 — under the
    # old single-call admit both slots paid width 24 (sum 48)
    assert sp.stats["prefill_calls"] - calls0 == 2
    assert sp.stats["prefill_widths"] >= {8, 24}
    assert sp.stats["prefill_width_sum"] - wsum0 == 8 + 24

    # bucketing only reshapes the admit calls — tokens stay bitwise
    sc = Scheduler(params, cfg, contig, prefill_bucket=8)
    sc.run([_clone(reqs)[0]], max_steps=50)
    sc.run(_clone(reqs)[1:], max_steps=100)
    for uid in sc.completions:
        np.testing.assert_array_equal(sc.completions[uid].tokens,
                                      sp.completions[uid].tokens,
                                      err_msg=f"uid={uid}")


# --------------------------------------------------------------------------
# Stress: random admission/eviction/readmission under a tight pool
# --------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1])
def test_scheduler_stress_invariants(seed):
    """A tight page pool (forced index eviction + admission
    backpressure), shared and cold prompts interleaved: the accounting
    invariants hold after EVERY tick and the tokens still match the
    contiguous scheduler bitwise."""
    cfg, params, contig, paged = _setup("qwen2-1.5b", batch=3, max_seq=64,
                                        page_size=4)
    # barely past the validation floor: ~1.6 slots' worth of pages
    paged = dataclasses.replace(paged, n_pages=26)
    rng = np.random.default_rng(seed)
    prefixes = [rng.integers(0, cfg.vocab, n).astype(np.int32)
                for n in (12, 9)]
    reqs = []
    for uid in range(10):
        head = prefixes[int(rng.integers(0, 3)) % 2] \
            if rng.integers(0, 3) else np.zeros((0,), np.int32)
        body = rng.integers(0, cfg.vocab,
                            int(rng.integers(3, 12))).astype(np.int32)
        reqs.append(Request(uid=uid, prompt=np.concatenate([head, body]),
                            max_new_tokens=int(rng.integers(2, 6))))
    a = Scheduler(params, cfg, contig).run(_clone(reqs), max_steps=600)
    sp = Scheduler(params, cfg, paged)
    for r in _clone(reqs):
        sp.submit(r)
    steps = 0
    while sp.queue or sp.n_active:
        sp.step()
        sp.paged.check_invariants()
        steps += 1
        assert steps < 600, "paged scheduler did not drain"
    assert sorted(sp.completions) == sorted(a)
    for uid in a:
        np.testing.assert_array_equal(a[uid].tokens,
                                      sp.completions[uid].tokens,
                                      err_msg=f"seed={seed} uid={uid}")
    # drained pool: only index entries may still hold pages
    held = len(sp.paged.index.pages()) if sp.paged.index else 0
    assert sp.paged.alloc.free_count == sp.paged.n_pages - held


def test_pool_too_small_fails_with_intent():
    cfg, params, _, paged = _setup("qwen2-1.5b", batch=2, max_seq=64,
                                   page_size=4)
    paged = dataclasses.replace(paged, n_pages=16)  # exactly one slot
    sched = Scheduler(params, cfg, paged)
    big = Request(uid=0, prompt=np.arange(60, dtype=np.int32) % cfg.vocab,
                  max_new_tokens=2)
    small = Request(uid=1, prompt=np.arange(5, dtype=np.int32) % cfg.vocab,
                    max_new_tokens=2)
    comps = sched.run([big, small], max_steps=200)  # backpressure serializes
    assert sorted(comps) == [0, 1]
    sched.paged.check_invariants()


# --------------------------------------------------------------------------
# Engine plan: paged decode shapes are fully pre-decided
# --------------------------------------------------------------------------


def test_paged_decode_plan_coverage():
    """plan_arch(..., paged_pages=..., page_size=...) covers every
    request a paged decode-step trace makes: zero new plan misses."""
    cfg = _cfg("qwen2-1.5b")
    B, page, max_seq = 3, 8, 32
    spec = T.CacheSpec(max_seq=max_seq, batch=B, page_size=page,
                       n_pages=3 * (max_seq // page))
    slot_pages = max_seq // page
    plan = engine_mod.plan_arch(cfg, seq_len=16, dtype_bytes=4,
                                decode_batch=B, backend="xla-einsum",
                                paged_pages=slot_pages, page_size=page)
    eng = engine_mod.Engine(backend="xla-einsum", plan=plan)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    cache = T.init_cache(cfg, spec, dtype=jnp.float32)
    cache = {**cache, "t": jnp.array([5, 9, 2], jnp.int32)}
    bt = jnp.asarray(np.arange(B * slot_pages).reshape(B, slot_pages),
                     jnp.int32)
    misses_before = plan.misses
    with engine_mod.use_engine(eng):
        step = jax.jit(lambda p, c, tok: T.decode_step(
            p, cfg, c, tok, compute_dtype=jnp.float32,
            active=jnp.array([True, True, False]), block_tables=bt))
        logits, _ = step(params, cache, jnp.zeros((B, 1), jnp.int32))
        logits.block_until_ready()
    assert plan.misses == misses_before
    assert plan.hits > 0


# --------------------------------------------------------------------------
# Config/API surface
# --------------------------------------------------------------------------


def test_serveconfig_paged_validation():
    ok = serve_lib.ServeConfig(max_seq=32, batch=2, cache_layout="paged",
                               page_size=8)
    assert ok.slot_pages == 4
    assert ok.resolved_n_pages >= ok.batch * ok.slot_pages
    with pytest.raises(ValueError, match="cache_layout"):
        serve_lib.ServeConfig(max_seq=32, batch=2, cache_layout="ragged")
    with pytest.raises(ValueError, match="page_size"):
        serve_lib.ServeConfig(max_seq=32, batch=2, cache_layout="paged",
                              page_size=0)
    with pytest.raises(ValueError, match="n_pages"):
        serve_lib.ServeConfig(max_seq=32, batch=2, cache_layout="paged",
                              page_size=8, n_pages=3)


def test_generate_rejects_paged():
    cfg, params, _, paged = _setup("qwen2-1.5b", batch=2, max_seq=32)
    with pytest.raises(NotImplementedError, match="Scheduler"):
        serve_lib.generate(params, cfg, paged,
                           jnp.zeros((2, 4), jnp.int32), 2)


def test_paged_prefill_requires_ragged_call():
    cfg = _cfg("qwen2-1.5b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    spec = T.CacheSpec(max_seq=32, batch=2, page_size=8, n_pages=10)
    cache = T.init_cache(cfg, spec, dtype=jnp.float32)
    bt = jnp.asarray(np.arange(8).reshape(2, 4), jnp.int32)
    with pytest.raises(NotImplementedError, match="ragged"):
        T.prefill(params, cfg, jnp.zeros((2, 8), jnp.int32), cache,
                  compute_dtype=jnp.float32, block_tables=bt)


def test_cache_shardings_cover_paged_leaves():
    from jax.sharding import NamedSharding

    from repro.dist import sharding as shd
    from repro.launch.mesh import make_test_mesh

    cfg = _cfg("qwen2-1.5b")
    spec = T.CacheSpec(max_seq=32, batch=2, page_size=8, n_pages=10)
    cache = jax.eval_shape(
        lambda: T.init_cache(cfg, spec, dtype=jnp.float32))
    mesh = make_test_mesh()
    shards = shd.cache_shardings(cache, mesh)
    names = {getattr(p[-1], "key", None)
             for p, _ in jax.tree_util.tree_flatten_with_path(cache)[0]}
    assert "k_pages" in names  # the paged leaves are really in the tree
    for leaf in jax.tree.leaves(shards):
        assert isinstance(leaf, NamedSharding)
