"""Eq. 3-5 analytical model: invariants + loop-nest reuse vs LRU oracle."""

import math

from hypothesis import given, settings, strategies as st

from repro.core.analytical_model import (AnalyticalModel, GEMM, MappingConfig,
                                         _operand_fetch_count,
                                         dram_access_cycles, dram_efficiency)
from repro.core.dataflow import Dataflow, LogicalShape

MODEL = AnalyticalModel()


def _cfg(**kw):
    base = dict(dataflow=Dataflow.OS, shape=LogicalShape(128, 128),
                tile_m=128, tile_k=128, tile_n=128, loop_order="mnk",
                alloc=(0.3, 0.3, 0.4))
    base.update(kw)
    return MappingConfig(**base)


gemms = st.builds(
    GEMM,
    M=st.integers(1, 4096), K=st.integers(1, 4096), N=st.integers(1, 4096))


@given(gemms)
@settings(max_examples=50, deadline=None)
def test_report_sanity(g):
    rep = MODEL.estimate(g, _cfg())
    assert rep.valid
    assert rep.cycles >= rep.compute_cycles > 0
    assert rep.num_tiles == (math.ceil(g.M / 128) * math.ceil(g.K / 128)
                             * math.ceil(g.N / 128))
    assert 0 < rep.pe_utilization <= 1.0
    assert rep.dram_read_bytes >= g.M * g.K + g.K * g.N  # at least one pass


@given(gemms, st.integers(1, 4))
@settings(max_examples=30, deadline=None)
def test_count_scales_linearly(g, count):
    one = MODEL.estimate(g, _cfg())
    many = MODEL.estimate(GEMM(g.M, g.K, g.N, count=count), _cfg())
    assert math.isclose(many.cycles, one.cycles * count, rel_tol=1e-9)


def test_runtime_monotone_in_volume():
    base = MODEL.estimate(GEMM(512, 512, 512), _cfg())
    big = MODEL.estimate(GEMM(1024, 512, 512), _cfg())
    assert big.cycles > base.cycles


def test_dram_efficiency_monotone():
    xs = [64, 256, 1024, 4096, 65536, 2**20, 2**23]
    effs = [dram_efficiency(x) for x in xs]
    assert all(a <= b for a, b in zip(effs, effs[1:], strict=False))
    assert dram_access_cycles(0, 1.0) == 0.0
    assert dram_access_cycles(1024, 1.0) > 1024  # latency + <1.0 efficiency


# --- loop-nest reuse model vs an explicit LRU-of-tiles walk ----------------


def _lru_fetches(order, trips, index_dims, capacity_tiles):
    """Ground truth: walk the full loop nest, LRU cache of tiles."""
    from collections import OrderedDict
    cache: OrderedDict = OrderedDict()
    fetches = 0
    dims = list(order)

    def rec(i, idx):
        nonlocal fetches
        if i == len(dims):
            key = tuple(idx[d] for d in sorted(index_dims))
            if key in cache:
                cache.move_to_end(key)
            else:
                fetches += 1
                cache[key] = True
                if len(cache) > capacity_tiles:
                    cache.popitem(last=False)
            return
        for v in range(trips[dims[i]]):
            idx[dims[i]] = v
            rec(i + 1, idx)

    rec(0, {})
    return fetches


@given(
    st.sampled_from(["mnk", "mkn", "nmk", "nkm", "kmn", "knm"]),
    st.integers(1, 4), st.integers(1, 4), st.integers(1, 4),
    st.sampled_from([frozenset("mk"), frozenset("kn"), frozenset("mn")]),
    st.integers(1, 20),
)
@settings(max_examples=120, deadline=None)
def test_fetch_count_matches_lru(order, tm, tk, tn, index_dims, cap):
    trips = {"m": tm, "k": tk, "n": tn}
    got = _operand_fetch_count(order, trips, index_dims, cap)
    want = _lru_fetches(order, trips, index_dims, cap)
    # The closed form assumes refetch-per-trip when the working set
    # overflows; LRU can do slightly better on partial overflow, so the
    # model is a safe upper bound and exact when no overflow is partial.
    assert got >= want
    if cap >= math.prod(trips[d] for d in sorted(index_dims)) or cap == 1:
        assert got == want


def test_infeasible_tile_rejected():
    g = GEMM(128, 128, 128)
    # allocation too small to hold one tile
    rep = MODEL.estimate(g, _cfg(alloc=(0.0001, 0.5, 0.4)))
    assert not rep.valid
