"""Per-arch smoke: reduced config, one forward + one train step on CPU,
asserting output shapes + no NaNs (brief requirement)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.configs.shapes import cells
from repro.data.pipeline import DataConfig, make_source
from repro.models import transformer as T
from repro.train_lib import train as train_lib

B, S = 2, 32


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_shapes_and_finiteness(arch):
    cfg = get_config(arch, smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    kw = {}
    tokens = None
    if cfg.embed_inputs:
        kw["embeds"] = jnp.ones((B, S, cfg.d_model), jnp.float32)
        expect_s = S
    elif cfg.prefix_tokens:
        tokens = jnp.ones((B, S), jnp.int32)
        kw["embeds"] = 0.02 * jnp.ones((B, cfg.prefix_tokens, cfg.d_model))
        expect_s = S + cfg.prefix_tokens
    else:
        tokens = jnp.ones((B, S), jnp.int32)
        expect_s = S
    logits, aux = T.forward(params, cfg, tokens, compute_dtype=jnp.float32,
                            **kw)
    assert logits.shape == (B, expect_s, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_one_train_step(arch):
    cfg = get_config(arch, smoke=True)
    tcfg = train_lib.TrainConfig(microbatches=1, compute_dtype=jnp.float32)
    state = train_lib.init_state(jax.random.PRNGKey(0), cfg, tcfg)
    src = make_source(cfg, DataConfig(batch=B, seq_len=S))
    step = jax.jit(train_lib.make_train_step(cfg, tcfg), donate_argnums=(0,))
    state, metrics = step(state, jax.tree.map(jnp.asarray, src.batch(0)))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    assert int(state["opt"]["step"]) == 1
    for leaf in jax.tree.leaves(state["params"]):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_full_configs_match_assignment():
    """The exact public numbers from the assignment brief."""
    want = {
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "mamba2-780m": (48, 1536, 0, 0, 0, 50280),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
    }
    for arch, (nl, d, h, kv, ff, v) in want.items():
        cfg = get_config(arch)
        got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.d_ff,
               cfg.vocab)
        assert got == (nl, d, h, kv, ff, v), (arch, got)
    assert get_config("mixtral-8x7b").moe.n_experts == 8
    assert get_config("mixtral-8x7b").moe.top_k == 2
    assert get_config("granite-moe-1b-a400m").moe.n_experts == 32
    assert get_config("granite-moe-1b-a400m").moe.top_k == 8
    assert get_config("mamba2-780m").ssm.d_state == 128


def test_cell_grid_is_40_with_documented_skips():
    from repro.configs import all_configs
    grid = list(cells(all_configs()))
    assert len(grid) == 40
    skips = {(a, s.name): why for a, _, s, runs, why in grid if not runs}
    assert ("hubert-xlarge", "decode_32k") in skips
    assert ("hubert-xlarge", "long_500k") in skips
    for arch in ("qwen2-1.5b", "mistral-large-123b", "qwen3-14b",
                 "internvl2-1b", "granite-moe-1b-a400m"):
        assert (arch, "long_500k") in skips
    for arch in ("recurrentgemma-2b", "gemma3-12b", "mixtral-8x7b",
                 "mamba2-780m"):
        assert (arch, "long_500k") not in skips
    assert len(skips) == 7  # 33 runnable cells


def test_param_counts_near_nameplate():
    """Parameter counts land near the names on the tin."""
    approx = {
        "qwen2-1.5b": (1.5e9, 0.30),
        "mistral-large-123b": (123e9, 0.05),
        "qwen3-14b": (14e9, 0.10),
        "mamba2-780m": (780e6, 0.15),
        "mixtral-8x7b": (46.7e9, 0.10),     # total params
    }
    for arch, (want, tol) in approx.items():
        got = get_config(arch).param_count()
        assert abs(got - want) / want < tol, (arch, got)
    # MoE active < total
    mx = get_config("mixtral-8x7b")
    assert mx.active_param_count() < 0.4 * mx.param_count()
