"""Cycle-level systolic simulator vs jnp GEMM + roundabout geometry."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.dataflow import Dataflow, LogicalShape
from repro.core.simulator import (eq4_stream_term, logical_to_physical,
                                  pinwheel_decomposition, simulate_gemm,
                                  simulate_gemm_batch, simulate_mapping,
                                  validate_roundabout)

dims = st.integers(min_value=1, max_value=12)


@given(dims, dims, dims, st.sampled_from(list(Dataflow)))
@settings(max_examples=40, deadline=None)
def test_simulator_matches_gemm(m, k, n, df):
    rng = np.random.default_rng(42)
    a = rng.normal(size=(m, k)).astype(np.float32)
    b = rng.normal(size=(k, n)).astype(np.float32)
    out, cycles = simulate_gemm(a, b, df)
    np.testing.assert_allclose(np.asarray(out), a @ b, rtol=1e-5, atol=1e-5)
    shape = {Dataflow.OS: LogicalShape(m, n), Dataflow.WS: LogicalShape(k, n),
             Dataflow.IS: LogicalShape(m, k)}[df]
    assert cycles == eq4_stream_term(df, shape, m, k, n) - 1


@given(dims, dims, dims, st.sampled_from([Dataflow.OS, Dataflow.WS]))
@settings(max_examples=20, deadline=None)
def test_simulator_on_larger_array(m, k, n, df):
    """A tile smaller than the logical array still computes exactly."""
    rng = np.random.default_rng(7)
    a = rng.normal(size=(m, k)).astype(np.float32)
    b = rng.normal(size=(k, n)).astype(np.float32)
    if df == Dataflow.OS:
        shape = LogicalShape(m + 3, n + 2)
    else:
        shape = LogicalShape(k + 1, n + 4)
    out, _ = simulate_gemm(a, b, df, shape)
    np.testing.assert_allclose(np.asarray(out), a @ b, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("r_p", [6, 8, 16, 32])
def test_roundabout_neighbor_only(r_p):
    """Every reshaped configuration uses Manhattan-adjacent hops only, and
    corner transits cost exactly 4*R_l (Eq. 4's bypass term)."""
    for r_l in range(1, r_p // 2 + 1):
        stats = validate_roundabout(r_l, r_p)
        assert stats["bypass_hops_per_lane"] == 4 * r_l
        assert stats["used_pes"] == r_p * r_p - (r_p - 2 * r_l) ** 2


def test_pinwheel_shapes():
    strips = pinwheel_decomposition(2, 6)
    assert len(strips) == 4
    mapping = logical_to_physical(2, 6)
    assert mapping.shape == (2, 16, 2)  # R_l x 4*C_s x (row, col)


# --- batched execution path (PR 2) -----------------------------------------


@pytest.mark.parametrize("df", list(Dataflow))
def test_batch_matches_per_tile_simulation(df):
    rng = np.random.default_rng(3)
    a = rng.normal(size=(5, 4, 6)).astype(np.float32)
    b = rng.normal(size=(5, 6, 3)).astype(np.float32)
    out, cycles = simulate_gemm_batch(a, b, df)
    np.testing.assert_allclose(np.asarray(out), a @ b, rtol=1e-4, atol=1e-4)
    for i in range(a.shape[0]):
        single, c1 = simulate_gemm(a[i], b[i], df)
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(single),
                                   rtol=1e-5, atol=1e-5)
        assert cycles == c1


def test_mapper_decision_executes_functionally():
    """A batched-engine mapping decision, run tile-by-tile through the
    cycle-level simulator, reproduces a @ b (incl. reshaped arrays)."""
    from repro.core.accelerators import make_specs
    from repro.core.analytical_model import GEMM
    from repro.core.mapper import ReDasMapper

    mapper = ReDasMapper(make_specs(8)["redas"], array_size=8)
    rng = np.random.default_rng(11)
    for m, k, n in ((13, 9, 17), (8, 24, 4), (1, 30, 20)):
        dec = mapper.map_gemm(GEMM(m, k, n))
        a = rng.normal(size=(m, k)).astype(np.float32)
        b = rng.normal(size=(k, n)).astype(np.float32)
        out, cycles = simulate_mapping(a, b, dec.config)
        np.testing.assert_allclose(np.asarray(out), a @ b, rtol=1e-3, atol=1e-3)
        assert cycles > 0
