"""Flash attention (fwd + custom VJP), local attention, norms, rotary."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers


def naive_attention(q, k, v, causal, window, kv_len=None):
    b, sq, h, d = q.shape
    kv = k.shape[2]
    kr = jnp.repeat(k, h // kv, axis=2)
    vr = jnp.repeat(v, h // kv, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kr) / np.sqrt(d)
    qi = jnp.arange(sq)[:, None]
    ki = jnp.arange(k.shape[1])[None, :]
    m = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        m &= ki <= qi
    if window:
        m &= (qi - ki) < window
    m = jnp.broadcast_to(m[None], (b,) + m.shape)
    if kv_len is not None:
        m &= ki[None] < kv_len[:, None, None]
    s = jnp.where(m[:, None], s, -1e30)
    return jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), vr)


def _rand(b=2, s=67, h=8, kv=2, d=16, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kv, d)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    kvl = jnp.full((b,), s, jnp.int32)
    return q, k, v, pos, kvl


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [0, 16])
@pytest.mark.parametrize("chunk", [16, 32, 512])
def test_flash_matches_naive(causal, window, chunk):
    q, k, v, pos, kvl = _rand()
    o = layers.flash_attention(q, k, v, pos, kvl, causal, window, chunk)
    r = naive_attention(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                               rtol=1e-4, atol=1e-5)


def test_flash_grads_match_naive():
    q, k, v, pos, kvl = _rand(s=40)

    def lf(q, k, v):
        return jnp.sum(layers.flash_attention(q, k, v, pos, kvl, True, 0, 16) ** 2)

    def lr(q, k, v):
        return jnp.sum(naive_attention(q, k, v, True, 0) ** 2)

    gf = jax.grad(lf, (0, 1, 2))(q, k, v)
    gr = jax.grad(lr, (0, 1, 2))(q, k, v)
    for a, b in zip(gf, gr, strict=True):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_flash_kv_len_masks_tail():
    q, k, v, pos, _ = _rand(s=32)
    kvl = jnp.asarray([20, 32], jnp.int32)
    o = layers.flash_attention(q, k, v, pos, kvl, False, 0, 16)
    r = naive_attention(q, k, v, False, 0, kv_len=kvl)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("s,window", [(64, 16), (50, 16), (16, 16), (100, 25)])
def test_local_attention_exact(s, window):
    q, k, v, *_ = _rand(s=s)
    o = layers.local_attention(q, k, v, window)
    r = naive_attention(q, k, v, True, window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                               rtol=1e-4, atol=1e-5)


def test_local_attention_flops_linear():
    """local_attention cost is O(S*w): jaxpr dot sizes stay constant as S
    grows (the long_500k viability argument)."""
    def dots_flops(s):
        q, k, v, *_ = _rand(s=s, seed=1)
        jaxpr = jax.make_jaxpr(
            lambda q, k, v: layers.local_attention(q, k, v, 16))(q, k, v)
        total = 0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "dot_general":
                out = eqn.outvars[0].aval
                total += out.size
        return total

    f64, f128 = dots_flops(64), dots_flops(128)
    assert f128 <= 2.2 * f64  # linear, not quadratic (x4)


def test_rotary_preserves_norm_and_relativity():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1, 8, 2, 16)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(8), (1, 8))
    r = layers.rotary(x, pos, 10000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(r), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)
    # relative property: <rot(q,i), rot(k,j)> depends only on i-j
    q = jnp.asarray(rng.normal(size=(1, 1, 1, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 1, 16)), jnp.float32)

    def dot_at(i, j):
        qi = layers.rotary(q, jnp.full((1, 1), i), 1e4)
        kj = layers.rotary(k, jnp.full((1, 1), j), 1e4)
        return float(jnp.sum(qi * kj))

    assert abs(dot_at(5, 3) - dot_at(12, 10)) < 1e-4


def test_rms_norm_unit_scale():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 32)) * 10,
                    jnp.float32)
    y = layers.rms_norm(jnp.zeros((32,)), x)
    rms = np.sqrt(np.mean(np.square(np.asarray(y)), axis=-1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)
