"""Tests for `repro.analysis` — the static invariant checker.

Three layers:

* planted fixtures: each check id fires on its fixture tree at the
  planted file:line, and the matching clean fixture stays silent;
* in-process plants for the dynamic passes (a verify width removed
  from a real plan, an orphan param leaf injected into the classifier);
* drift tests: every stdlib mirror inside the analyzer (int8/sparse
  executed-block derivations, the param/cache leaf trees, `_auto_spec`)
  is pinned against the real jax implementation it mirrors, so the
  jax-free analysis cannot silently diverge from what executes.
"""

import os
import subprocess
import sys

import jax
import pytest

from repro import analysis
from repro.analysis import kernel_legality as KL
from repro.analysis import plan_coverage as PC
from repro.analysis import sharding_rules as SH
from repro.configs import all_configs, get_config

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures", "analysis")
SRC = os.path.dirname(analysis.REAL_ROOT)


def run_pass(pass_name: str, fixture: str):
    return analysis.run_passes(root=os.path.join(FIXTURES, fixture),
                               passes=(pass_name,))


# ---------------------------------------------------------------------------
# The CLI contract: clean tree, exit 0, jax-free
# ---------------------------------------------------------------------------


def test_cli_clean_tree_exits_zero_and_never_imports_jax():
    code = ("import sys\n"
            "import repro.analysis.__main__ as m\n"
            "rc = m.main([])\n"
            "assert 'jax' not in sys.modules, 'analysis imported jax'\n"
            "sys.exit(rc)\n")
    env = {**os.environ, "PYTHONPATH": SRC}
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"


def test_cli_nonzero_with_file_line_on_planted_fixture():
    root = os.path.join(FIXTURES, "bad_ladder")
    code = ("import sys\n"
            "import repro.analysis.__main__ as m\n"
            f"sys.exit(m.main(['--root', {root!r}, "
            "'--passes', 'kernel-legality', '--allowlist', '-']))\n")
    env = {**os.environ, "PYTHONPATH": SRC}
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 1, r.stdout + r.stderr
    # the planted _ladder(m, 4, 512) call sits on line 14 of the fixture
    assert "core/tpu_model.py:14: KL002" in r.stdout


def test_unknown_pass_is_an_error():
    with pytest.raises(ValueError, match="unknown pass"):
        analysis.run_passes(passes=("no-such-pass",))


def test_allowlist_rejects_missing_justification(tmp_path):
    p = tmp_path / "allow.txt"
    p.write_text("KL002 src/x.py::f -- \n")
    with pytest.raises(ValueError, match="justification"):
        analysis.load_allowlist(str(p))


def test_committed_allowlist_parses_and_every_entry_is_used():
    allow = analysis.load_allowlist()
    assert allow  # the burn-down left intentional entries behind
    idents = {f.ident for f in analysis.run_passes()}
    assert set(allow) <= idents, f"stale entries: {set(allow) - idents}"
    assert not (idents - set(allow)), \
        f"unsuppressed findings: {idents - set(allow)}"


# ---------------------------------------------------------------------------
# Kernel legality: planted fixtures
# ---------------------------------------------------------------------------


def test_kl002_fires_on_misaligned_ladder():
    found = [f for f in run_pass("kernel-legality", "bad_ladder")
             if f.check_id == "KL002"]
    assert len(found) == 1
    f = found[0]
    assert f.file.endswith("core/tpu_model.py") and f.line == 14
    assert "align=4" in f.message and "SUBLANE" in f.message


def test_clean_ladder_fixture_is_silent():
    assert run_pass("kernel-legality", "clean_ladder") == []


def test_kl005_kl006_fire_on_planted_grids():
    found = run_pass("kernel-legality", "bad_grid")
    by_id = {f.check_id: f for f in found}
    assert set(by_id) == {"KL005", "KL006"}
    assert by_id["KL005"].symbol == "bad_arity"
    assert by_id["KL005"].line == 16  # the 1-arg lambda
    assert by_id["KL006"].symbol == "bad_return"
    assert by_id["KL006"].line == 27  # the 3-tuple lambda


def test_clean_grid_fixture_is_silent():
    assert run_pass("kernel-legality", "clean_grid") == []


def test_kl001_fires_on_unknown_op():
    found = [f for f in run_pass("kernel-legality", "bad_registry")
             if f.check_id == "KL001"]
    assert len(found) == 1
    assert "gemm_typo" in found[0].message
    assert found[0].file.endswith("kernels/reg.py") and found[0].line == 10


# ---------------------------------------------------------------------------
# Plan coverage: in-process plants against a real plan
# ---------------------------------------------------------------------------


def _coverage_cfg():
    for cfg in all_configs().values():
        if PC.servable(cfg) and "attn" in cfg.layer_pattern \
                and cfg.moe is None:
            return cfg
    raise AssertionError("no plain attention config")


def test_pc001_catches_removed_verify_width():
    cfg = _coverage_cfg()
    surface = PC.Surface("contiguous", False, False, PC.SPECULATE_K)
    plan = PC.build_plan(cfg, surface)
    assert PC.check_plan(cfg, surface, plan, file="f", line=1) == []
    verify_m = PC.BATCH * (PC.SPECULATE_K + 1)
    kept = {k: v for k, v in plan.decisions.items() if k[1] != verify_m}
    assert len(kept) < len(plan.decisions)  # the width was actually planned
    plan.decisions.clear()
    plan.decisions.update(kept)
    found = PC.check_plan(cfg, surface, plan, file="f", line=1)
    assert found and all(f.check_id == "PC001" for f in found)
    assert any(f"w={PC.SPECULATE_K + 1}" in f.message for f in found)


def test_pc001_catches_removed_admit_bucket():
    cfg = _coverage_cfg()
    surface = PC.Surface("contiguous", False, False, 0)
    plan = PC.build_plan(cfg, surface)
    bucket_m = PC.BATCH * PC.admit_widths()[0]
    kept = {k: v for k, v in plan.decisions.items() if k[1] != bucket_m}
    assert len(kept) < len(plan.decisions)
    plan.decisions.clear()
    plan.decisions.update(kept)
    found = PC.check_plan(cfg, surface, plan, file="f", line=1)
    assert found and all(f.check_id == "PC001" for f in found)
    assert any(f"w={PC.admit_widths()[0]}" in f.message for f in found)


def test_paged_surface_requires_the_gather_shape():
    cfg = _coverage_cfg()
    surface = PC.Surface("paged", False, False, 0)
    plan = PC.build_plan(cfg, surface)
    assert PC.check_plan(cfg, surface, plan, file="f", line=1) == []
    kept = {k: v for k, v in plan.decisions.items()
            if k[0] != "paged_attention"}
    assert len(kept) < len(plan.decisions)
    plan.decisions.clear()
    plan.decisions.update(kept)
    found = PC.check_plan(cfg, surface, plan, file="f", line=1)
    assert [f.check_id for f in found] == ["PC001"]
    assert "paged-gather" in found[0].message


# ---------------------------------------------------------------------------
# Sharding rules: planted cache table + injected param leaves
# ---------------------------------------------------------------------------


def test_cache_table_plants_each_fire_once():
    found = [f for f in run_pass("sharding-rules", "bad_cache_axes")
             if f.check_id.startswith("SH00") and f.check_id != "SH006"]
    by_id = {}
    for f in found:
        by_id.setdefault(f.check_id, []).append(f)
    assert set(by_id) == {"SH001", "SH002", "SH003", "SH007"}
    assert [f.symbol for f in by_id["SH001"]] == ["conv"]
    assert [f.symbol for f in by_id["SH002"]] == ["cells"]
    assert [f.symbol for f in by_id["SH003"]] == ["state"]
    assert [f.symbol for f in by_id["SH007"]] == ["h"]
    # findings anchor to the planted table lines in the fixture
    assert by_id["SH003"][0].line == 25
    assert by_id["SH007"][0].line == 27


def test_sh004_orphan_param_leaf():
    found = SH.check_param_leaves(
        [("stack/b0/weird", (2, 8, 16, 32))], file="f", line=1, arch="x")
    assert [f.check_id for f in found] == ["SH004"]
    assert "weird" in found[0].message


def test_sh005_ambiguous_param_leaf():
    found = SH.check_param_leaves(
        [("moe/experts/embed", (4, 8, 16))], file="f", line=1, arch="x")
    assert [f.check_id for f in found] == ["SH005"]
    assert "embed" in found[0].message and "experts" in found[0].message


def test_sh006_fully_replicated_matmul_leaf():
    found = SH.check_param_leaves(
        [("mlp/wi/w", (15, 33))], file="f", line=1, arch="x")
    assert [f.check_id for f in found] == ["SH006"]


# ---------------------------------------------------------------------------
# Jit discipline: planted fixtures
# ---------------------------------------------------------------------------


def test_jit_plants_each_fire_once():
    found = run_pass("jit-discipline", "bad_jit")
    by_id = {}
    for f in found:
        by_id.setdefault(f.check_id, []).append(f)
    assert set(by_id) == {"JD001", "JD002", "JD003"}
    assert by_id["JD001"][0].symbol == "make_step"
    assert by_id["JD001"][0].line == 12
    assert by_id["JD002"][0].symbol == "forward"
    assert by_id["JD002"][0].line == 16
    assert by_id["JD003"][0].symbol == "apply"


def test_clean_jit_fixture_is_silent():
    assert run_pass("jit-discipline", "clean_jit") == []


# ---------------------------------------------------------------------------
# Docs consistency: planted fixtures + the real tree staying clean
# ---------------------------------------------------------------------------


def test_docs_plants_each_fire_once():
    found = run_pass("docs-consistency", "bad_docs")
    by_id = {}
    for f in found:
        by_id.setdefault(f.check_id, []).append(f)
    assert set(by_id) == {"DC001", "DC002", "DC003"}
    # DC001: the stale README citation and the stale docstring citation
    assert [(f.symbol, f.line) for f in by_id["DC001"]] == [
        ("§99", 5), ("§77", 3)]
    assert by_id["DC001"][1].file.endswith("goodpkg/mod.py")
    # DC002: the undocumented package, anchored to the module-map header
    assert [(f.symbol, f.line) for f in by_id["DC002"]] == [
        ("mysteryplane", 7)]
    # DC003: one dead path ref + one dead dotted ref, at the planted lines
    assert [(f.symbol, f.line) for f in by_id["DC003"]] == [
        ("goodpkg/gone.py", 13), ("repro.goodpkg.vanished", 14)]
    # the "is removed" paragraph is exempt — documenting a removal is fine
    assert not any("olde" in f.message for f in found)


def test_clean_docs_fixture_is_silent():
    assert run_pass("docs-consistency", "clean_docs") == []


def test_real_docs_have_no_stale_findings():
    """Acceptance gate: stale-doc findings are burned down in the docs,
    never allowlisted — the DC pass must be clean on the real tree."""
    found = analysis.run_passes(passes=("docs-consistency",))
    assert found == [], "\n".join(f.text() for f in found)


# ---------------------------------------------------------------------------
# Drift tests: the stdlib mirrors vs the real jax implementations
# ---------------------------------------------------------------------------


def test_int8_block_mirror_matches_kernel():
    from repro.kernels.quant_gemm import align_int8_blocks

    for triple in [(8, 128, 128), (32, 256, 128), (64, 512, 256),
                   (256, 2048, 512), (96, 1024, 384), (512, 1536, 512)]:
        assert KL.mirror_align_int8(*triple) == align_int8_blocks(*triple), \
            triple


def test_sparse_block_mirror_matches_kernel():
    from repro.kernels.sparse_gemm import default_sparse_blocks

    for m, k_dense, n in [(1, 512, 512), (4, 896, 896), (64, 2048, 2048),
                          (128, 8960, 1536), (12, 4864, 1536),
                          (256, 11008, 4096)]:
        for n_keep, m_group in ((2, 4), (1, 4), (4, 8)):
            got = KL.mirror_sparse_blocks(m, k_dense, n, n_keep, m_group)
            want = default_sparse_blocks(m, k_dense, n, n_keep, m_group)
            assert got == want, (m, k_dense, n, n_keep, m_group)


def _leaf_paths(tree):
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            else:
                parts.append(str(getattr(p, "name", p)))
        out["/".join(parts)] = tuple(leaf.shape)
    return out


@pytest.mark.parametrize("arch", sorted(all_configs()))
def test_param_leaf_mirror_matches_init_params(arch):
    from repro.models import transformer as T

    cfg = get_config(arch, smoke=True)
    real = _leaf_paths(jax.eval_shape(
        lambda: T.init_params(jax.random.PRNGKey(0), cfg)))
    mirror = dict(SH.param_leaves(cfg))
    assert real == mirror, (
        f"{arch}: only-real {sorted(set(real) - set(mirror))[:5]} "
        f"only-mirror {sorted(set(mirror) - set(real))[:5]}")


@pytest.mark.parametrize("arch", sorted(all_configs()))
def test_cache_leaf_mirror_matches_slot_cache_shape(arch):
    import jax.numpy as jnp

    from repro.models import transformer as T

    cfg = get_config(arch, smoke=True)
    kinds = set(cfg.layer_pattern)
    int8_ok = bool(kinds & {"attn", "local"})
    for paged in (False, True) if "attn" in kinds else (False,):
        for int8 in (False, True) if int8_ok else (False,):
            spec = T.CacheSpec(
                max_seq=32, batch=2,
                page_size=16 if paged else None,
                n_pages=8 if paged else None)
            dtype = jnp.int8 if int8 else jnp.bfloat16
            mirror: dict[str, int] = {}
            for kind in sorted(kinds):
                real = jax.eval_shape(
                    lambda k=kind: T._slot_cache_shape(k, cfg, spec, dtype))
                got = {name: len(leaf.shape) for name, leaf in real.items()}
                want = {
                    name: nd for name, nd in SH.cache_slot_leaves(
                        cfg, paged=paged, int8=int8).items()
                    if name in _kind_leaves(kind, paged, int8)}
                assert got == want, (arch, kind, paged, int8)
                mirror.update(got)
            assert mirror == SH.cache_slot_leaves(cfg, paged=paged,
                                                  int8=int8)


def _kind_leaves(kind, paged, int8):
    if kind == "attn" and paged:
        return {"k_pages", "v_pages"} | (
            {"k_scale_pages", "v_scale_pages"} if int8 else set())
    if kind in ("attn", "local"):
        return {"k", "v"} | ({"k_scale", "v_scale"} if int8 else set())
    if kind == "ssm":
        return {"conv", "state"}
    if kind == "rglru":
        return {"conv", "h"}
    return set()


def test_mirror_spec_matches_auto_spec():
    from repro.dist.sharding import _auto_spec

    sizes = {"data": 2, "model": 2}
    for arch in sorted(all_configs()):
        cfg = get_config(arch, smoke=True)
        for name, shape in SH.param_leaves(cfg):
            got = SH.mirror_spec(name, shape, sizes)
            want = tuple(_auto_spec(name, shape, sizes))
            assert got == want, (arch, name, shape)


def test_expected_requests_match_decode_requests_per_width():
    """The coverage pass's independent runtime-shape derivation agrees
    with `engine.decode_requests` (the thing `plan_arch` consumes) on
    every surface of the reference posture: same request set at decode
    width 1 plus each admit width, per surface."""
    from repro.engine.context import backend_in_bytes, decode_requests

    for cfg in all_configs().values():
        if not PC.servable(cfg):
            continue
        for surface in PC.surfaces(cfg):
            widths = (1,) + PC.admit_widths()
            if surface.speculate_k:
                widths += (surface.speculate_k + 1,)
            backend = PC.backend_for(surface)
            slot_pages = -(-PC.MAX_SEQ // PC.PAGE_SIZE)
            want = set()
            for width in sorted(set(widths)):
                for req in decode_requests(
                        cfg, batch=PC.BATCH, seq=width,
                        dtype_bytes=backend_in_bytes(backend, 2),
                        out_bytes=2,  # plan_arch keeps the compute width
                        quantized_weights=surface.quantize,
                        sparse_weights=surface.sparse, density=0.5,
                        paged_pages=(slot_pages if surface.layout == "paged"
                                     else 0),
                        page_size=(PC.PAGE_SIZE if surface.layout == "paged"
                                   else 0)):
                    want.add(req.key())
            got = {req.key() for req, _ in PC.expected_requests(cfg, surface)}
            assert got == want, (cfg.name, surface.label(),
                                 sorted(got ^ want)[:4])
