"""Activate the deterministic hypothesis stand-in (tests/_compat) only
when the real package is absent — some containers ship the jax toolchain
without hypothesis, and property tests should still run there rather
than kill collection.  pyproject.toml declares the real dependency."""

import os
import sys

import pytest

try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_compat"))


@pytest.fixture(scope="module", autouse=True)
def _bounded_compile_state():
    # Executables are never shared across test modules (each builds its
    # own model shapes), but jit caches pin every one of them for the
    # whole pytest process.  With ~400 tests the accumulated XLA CPU
    # state eventually segfaults backend_compile mid-suite, so drop the
    # caches at each module boundary to keep live state per-module.
    yield
    import jax

    jax.clear_caches()
