"""Activate the deterministic hypothesis stand-in (tests/_compat) only
when the real package is absent — some containers ship the jax toolchain
without hypothesis, and property tests should still run there rather
than kill collection.  pyproject.toml declares the real dependency."""

import os
import sys

try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_compat"))
