"""System-level behaviour: the paper's end-to-end claims hold in-direction
on the full plane-1 stack (mapper + analytical model + energy)."""

import pytest

from repro.core.accelerators import SPECS
from repro.core.energy import model_energy
from repro.core.mapper import ReDasMapper
from repro.core.workloads import WORKLOADS


@pytest.fixture(scope="module")
def mappings():
    out = {}
    for acc in ("tpu", "redas"):
        for m in ("TY", "GN", "VI"):
            out[acc, m] = ReDasMapper(SPECS[acc]).map_model(
                WORKLOADS[m].gemms)
    return out


def test_redas_faster_than_tpu_everywhere(mappings):
    for m in ("TY", "GN", "VI"):
        assert mappings["redas", m].total_cycles < \
            mappings["tpu", m].total_cycles


def test_rnn_benefits_most(mappings):
    """GNMT (matrix-vector GEMMs) gains more than TinyYOLO (fat convs) —
    the paper's Sec. 5.2 observation."""
    s = {m: (mappings["tpu", m].total_cycles
             / mappings["redas", m].total_cycles) for m in ("TY", "GN")}
    assert s["GN"] > s["TY"]


def test_utilization_improves(mappings):
    for m in ("TY", "GN", "VI"):
        assert mappings["redas", m].pe_utilization(128) > \
            mappings["tpu", m].pe_utilization(128)


def test_edp_improves(mappings):
    """Clear EDP wins on the RNN/attention suites (GN, VI); on fat-conv
    TY the ReDas mux/register energy overhead (Table 5: 2.79x MAC energy)
    almost exactly cancels the speedup — matching Fig. 16 where TY shows
    the smallest EDP gain."""
    for m in ("GN", "VI"):
        e_t = model_energy(SPECS["tpu"], mappings["tpu", m],
                           WORKLOADS[m].vector_elements)
        e_r = model_energy(SPECS["redas"], mappings["redas", m],
                           WORKLOADS[m].vector_elements)
        assert e_r.edp < e_t.edp
    e_t = model_energy(SPECS["tpu"], mappings["tpu", "TY"],
                       WORKLOADS["TY"].vector_elements)
    e_r = model_energy(SPECS["redas"], mappings["redas", "TY"],
                       WORKLOADS["TY"].vector_elements)
    assert e_r.edp < e_t.edp * 1.1  # parity-or-better


def test_workload_gemm_inventory():
    """Headline GEMMs the paper quotes exist in the traces."""
    re_shapes = {(g.M, g.K, g.N) for g in WORKLOADS["RE"].gemms}
    assert (49, 2048, 512) in re_shapes or (49, 512, 2048) in re_shapes
    assert (12544, 147, 64) in re_shapes
    ty = [g for g in WORKLOADS["TY"].gemms if g.name == "conv2"][0]
    assert (ty.M, ty.K, ty.N) == (43264, 144, 32)
    vi_shapes = {(g.M, g.K, g.N) for g in WORKLOADS["VI"].gemms}
    assert (50, 768, 3072) in vi_shapes and (50, 3072, 768) in vi_shapes
    be_shapes = {(g.M, g.K, g.N) for g in WORKLOADS["BE"].gemms}
    assert (128, 1024, 4096) in be_shapes
