"""End-to-end: training converges, checkpoints restart exactly, data is
deterministic, the launcher entry points run."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import Checkpointer, resume_or_init
from repro.configs import get_config
from repro.data.pipeline import DataConfig, make_source
from repro.optim.adamw import AdamWConfig
from repro.optim.schedule import linear_warmup_cosine
from repro.train_lib import train as train_lib


def _setup(arch="qwen2-1.5b", lr=1e-2, micro=2):
    cfg = get_config(arch, smoke=True)
    tcfg = train_lib.TrainConfig(
        microbatches=micro, compute_dtype=jnp.float32,
        optimizer=AdamWConfig(lr=linear_warmup_cosine(lr, 5, 100)))
    state = train_lib.init_state(jax.random.PRNGKey(0), cfg, tcfg)
    src = make_source(cfg, DataConfig(batch=8, seq_len=32))
    step = jax.jit(train_lib.make_train_step(cfg, tcfg), donate_argnums=(0,))
    return cfg, tcfg, state, src, step


def test_loss_decreases():
    _, _, state, src, step = _setup()
    losses = []
    for s in range(20):
        state, m = step(state, jax.tree.map(jnp.asarray, src.batch(s)))
        losses.append(float(m["ce"]))
    assert losses[-1] < losses[0] - 0.3, losses


def test_microbatching_equivalent_to_full_batch():
    """Grad accumulation must not change the update (same data)."""
    cfg, _, s1, src, step1 = _setup(micro=1)
    *_, s4, _, step4 = _setup(micro=4)
    b = jax.tree.map(jnp.asarray, src.batch(0))
    n1, _ = step1(s1, b)
    n4, _ = step4(s4, b)
    for a, c in zip(jax.tree.leaves(n1["params"]), jax.tree.leaves(n4["params"]),
                    strict=True):
        # f32 GEMM reduction order differs between one batch-8 grad and
        # four accumulated batch-2 grads; observed worst case ~9e-5 abs.
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=2e-4, atol=2e-4)


def test_checkpoint_restart_bitexact():
    """Train 6 steps straight == train 3, checkpoint, restore, train 3."""
    _, tcfg, state, src, step = _setup()
    batches = [jax.tree.map(jnp.asarray, src.batch(s)) for s in range(6)]
    ref = state
    for b in batches:
        ref, _ = step(ref, b)
    # restart path
    _, _, state2, _, step2 = _setup()
    for b in batches[:3]:
        state2, _ = step2(state2, b)
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(3, state2, blocking=True)
        like = jax.eval_shape(lambda: train_lib.init_state(
            jax.random.PRNGKey(0), get_config("qwen2-1.5b", smoke=True), tcfg))
        restored = ck.restore(3, like)
    for b in batches[3:]:
        restored, _ = step2(restored, b)
    for a, c in zip(jax.tree.leaves(ref), jax.tree.leaves(restored), strict=True):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=1e-5, atol=1e-6)


def test_checkpointer_mechanics():
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2)
        state = {"w": jnp.arange(4.0)}
        for s in (1, 2, 3):
            ck.save(s, state, blocking=True)
        assert ck.all_steps() == [2, 3]  # gc keeps 2
        assert ck.latest_step() == 3
        # async save + wait
        ck.save(4, state)
        ck.wait()
        assert ck.latest_step() == 4
        assert not [f for f in os.listdir(d) if f.startswith("tmp")]
        step, got = resume_or_init(ck, lambda: {"w": jnp.zeros(4)})
        assert step == 4
        np.testing.assert_array_equal(np.asarray(got["w"]),
                                      np.arange(4.0))


def test_data_pipeline_deterministic():
    cfg = get_config("qwen2-1.5b", smoke=True)
    src = make_source(cfg, DataConfig(batch=4, seq_len=16, seed=7))
    b1, b2 = src.batch(5), src.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(src.batch(6)["tokens"], b1["tokens"])
    # memmap source
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "toks.bin")
        np.arange(10000, dtype=np.int32).tofile(path)
        m = make_source(cfg, DataConfig(batch=2, seq_len=8, seed=0), path)
        mb = m.batch(0)
        assert mb["tokens"].shape == (2, 9)
        np.testing.assert_array_equal(m.batch(0)["tokens"], mb["tokens"])


def test_train_launcher_end_to_end():
    from repro.launch.train import main
    with tempfile.TemporaryDirectory() as d:
        out = main(["--arch", "qwen2-1.5b", "--smoke", "--steps", "12",
                    "--batch", "4", "--seq", "32", "--lr", "1e-2",
                    "--ckpt-dir", d, "--ckpt-every", "6"])
        assert out["final_ce"] < out["first_ce"]
        # resume picks up the saved step
        out2 = main(["--arch", "qwen2-1.5b", "--smoke", "--steps", "14",
                     "--batch", "4", "--seq", "32", "--lr", "1e-2",
                     "--ckpt-dir", d, "--resume", "auto"])
        assert out2["steps"] == 14


def test_serve_launcher_end_to_end():
    from repro.launch.serve import main
    out = main(["--arch", "qwen2-1.5b", "--smoke", "--batch", "2",
                "--prompt-len", "8", "--gen", "4"])
    assert out["shape"] == (2, 4)


def test_encoder_arch_trains():
    """hubert (embed-input encoder) goes through the same train path."""
    _, _, state, src, step = _setup(arch="hubert-xlarge", lr=3e-3)
    for s in range(4):
        state, m = step(state, jax.tree.map(jnp.asarray, src.batch(s)))
        assert bool(jnp.isfinite(m["loss"]))
