"""MoE dispatch invariants + dense-computation oracle at high capacity."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig, MoEConfig
from repro.models.moe import capacity, moe_block, moe_init

CFG = ArchConfig(
    name="t", kind="decoder", n_layers=1, d_model=16, n_heads=2, n_kv=1,
    d_ff=32, vocab=100, head_dim=8,
    moe=MoEConfig(n_experts=4, top_k=2, capacity_factor=8.0))


def dense_oracle(p, cfg, x):
    """Every token through its top-k experts, no capacity limit."""
    logits = x.astype(jnp.float32) @ p["router"]["w"]
    gates, sel = jax.lax.top_k(logits, cfg.moe.top_k)
    gates = jax.nn.softmax(gates, axis=-1)
    we = p["experts"]
    y = jnp.zeros_like(x)
    for kk in range(cfg.moe.top_k):
        for e in range(cfg.moe.n_experts):
            mask = (sel[..., kk] == e).astype(x.dtype)
            h = x @ we["wi"][e]
            g = jax.nn.silu(x @ we["wg"][e]) * h
            out = g @ we["wo"][e]
            y += out * (mask * gates[..., kk].astype(x.dtype))[..., None]
    return y


def test_moe_matches_dense_oracle_at_high_capacity():
    p = moe_init(jax.random.PRNGKey(0), CFG)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 12, 16)), jnp.float32)
    y, aux = moe_block(p, CFG, x)
    want = dense_oracle(p, CFG, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    assert float(aux) > 0


def test_capacity_drops_bounded():
    """With cf=1.0 some tokens drop; output stays finite and close-ish."""
    cfg = dataclasses.replace(
        CFG, moe=MoEConfig(n_experts=4, top_k=2, capacity_factor=1.0))
    p = moe_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(2, 32, 16)), jnp.float32)
    y, _ = moe_block(p, cfg, x)
    assert bool(jnp.all(jnp.isfinite(y)))
    # dropped tokens produce zero update, never garbage
    dense = dense_oracle(p, cfg, x)
    diff_norm = float(jnp.linalg.norm(y - dense))
    assert diff_norm < float(jnp.linalg.norm(dense))


def test_capacity_formula():
    assert capacity(CFG, 12) >= int(np.ceil(12 * 2 * 8.0 / 4))
    assert capacity(CFG, 12) % 4 == 0
    tiny = dataclasses.replace(
        CFG, moe=MoEConfig(n_experts=32, top_k=8, capacity_factor=1.25))
    assert capacity(tiny, 1) >= 1  # decode: one token still dispatchable


def test_aux_loss_uniform_router_is_one():
    """Perfectly uniform routing gives aux ~= 1 (Switch normalization)."""
    p = moe_init(jax.random.PRNGKey(0), CFG)
    p["router"]["w"] = jnp.zeros_like(p["router"]["w"])  # uniform logits
    x = jnp.asarray(np.random.default_rng(2).normal(size=(4, 64, 16)),
                    jnp.float32)
    _, aux = moe_block(p, CFG, x)
    assert abs(float(aux) - 1.0) < 0.35
