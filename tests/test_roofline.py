"""Roofline: loop-aware HLO walker vs known-FLOPs programs; term math."""

import jax
import jax.numpy as jnp
import pytest

from repro.roofline import analysis, hlo_costs


def test_walker_multiplies_scan_trips():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=14)
        return out

    x, w = jnp.zeros((256, 512)), jnp.zeros((512, 512))
    comp = jax.jit(f).lower(x, w).compile()
    cost = hlo_costs.module_costs(comp.as_text())
    assert cost.flops == pytest.approx(2 * 256 * 512 * 512 * 14)
    # XLA's own analysis counts the body once — the walker must not
    raw = analysis.raw_cost_analysis(comp)
    assert raw["flops"] < cost.flops / 10


def test_walker_nested_scans():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out

    x, w = jnp.zeros((64, 64)), jnp.zeros((64, 64))
    comp = jax.jit(f).lower(x, w).compile()
    cost = hlo_costs.module_costs(comp.as_text())
    assert cost.flops == pytest.approx(2 * 64 ** 3 * 15)


def test_walker_plain_matmul():
    comp = jax.jit(lambda a, b: a @ b).lower(
        jnp.zeros((128, 256)), jnp.zeros((256, 64))).compile()
    cost = hlo_costs.module_costs(comp.as_text())
    assert cost.flops == pytest.approx(2 * 128 * 256 * 64)
    assert cost.bytes >= (128 * 256 + 256 * 64 + 128 * 64) * 4


def test_collective_parse_fixture():
    text = """
ENTRY %main (p: f32[16,16]) -> f32[16,16] {
  %p = f32[16,16]{1,0} parameter(0)
  %ag = f32[64,16]{1,0} all-gather(%p), replica_groups={}, dimensions={0}
  %ar = f32[16,16]{1,0} all-reduce(%p), to_apply=%add
  ROOT %out = f32[16,16]{1,0} add(%p, %p)
}
"""
    c = hlo_costs.module_costs(text)
    assert c.coll_by_kind["all-gather"] == 64 * 16 * 4
    assert c.coll_by_kind["all-reduce"] == 16 * 16 * 4


def test_roofline_terms_and_bottleneck():
    r = analysis.Roofline(
        flops_per_device=197e12,        # exactly 1s of compute
        hbm_bytes_per_device=819e9 / 2,  # 0.5s memory
        coll_bytes_per_device=50e9 / 4,  # 0.25s collective
        model_flops_per_device=98.5e12)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(0.5)
    assert r.collective_s == pytest.approx(0.25)
    assert r.bottleneck == "compute"
    assert r.useful_flops_ratio == pytest.approx(0.5)
    assert r.roofline_fraction == pytest.approx(0.5)


def test_model_flops_kinds():
    from repro.configs import get_config
    from repro.configs.shapes import SHAPES
    cfg = get_config("qwen2-1.5b")
    n = cfg.param_count()
    t = analysis.model_flops(cfg, SHAPES["train_4k"])
    assert t == pytest.approx(6.0 * n * 256 * 4096)
    p = analysis.model_flops(cfg, SHAPES["prefill_32k"])
    assert p == pytest.approx(2.0 * n * 32 * 32768)
    d = analysis.model_flops(cfg, SHAPES["decode_32k"])
    assert d == pytest.approx(2.0 * n * 128)
    # MoE: active params, not total
    mx = get_config("mixtral-8x7b")
    assert analysis.model_flops(mx, SHAPES["train_4k"]) < \
        6.0 * mx.param_count() * 256 * 4096
