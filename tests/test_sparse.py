"""The N:M structured-sparsity plane (ISSUE 8): round-trip properties,
the sparse GEMM backends, the VJP masking posture, engine density
keying, sparse×int8 composition, sharding/scan pytree behavior, and
pruned-vs-densified scheduler parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import engine as engine_mod
from repro.configs import get_config
from repro.kernels import sparse_gemm as sg
from repro.models import transformer as T
from repro.models.layers import dense
from repro.quant import QuantizedTensor, tree_bytes
from repro.serve_lib import serve as serve_lib
from repro.serve_lib.scheduler import Request, Scheduler
from repro.sparse import (SparseTensor, densify, densify_params,
                          parse_sparsity, prune_params, sparsify)


# --------------------------------------------------------------------------
# N:M round-trip properties (satellite: property tests)
# --------------------------------------------------------------------------


@settings(max_examples=20)
@given(st.integers(1, 12), st.integers(1, 24), st.integers(0, 2**31 - 1),
       st.sampled_from([(1, 2), (2, 4), (1, 4), (4, 8)]))
def test_sparsify_roundtrip_properties(groups, n_cols, seed, nm):
    """prune -> densify preserves the kept values exactly, zeros at
    least M-N positions per group, and keeps the N largest magnitudes
    (ties broken toward the earlier row: stable argsort)."""
    n, m = nm
    k = groups * m
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(k, n_cols)).astype(np.float32)
    st_ = sparsify(jnp.asarray(w), n, m)
    assert st_.values.shape == (groups * n, n_cols)
    assert st_.indices.dtype == jnp.int8
    assert st_.shape == (k, n_cols) and st_.density == n / m
    d = np.asarray(st_.densify())
    wg = w.reshape(groups, m, n_cols)
    dg = d.reshape(groups, m, n_cols)
    for g in range(groups):
        for c in range(n_cols):
            kept = np.flatnonzero(dg[g, :, c])
            assert len(kept) <= n
            # kept entries reproduce the source exactly
            np.testing.assert_array_equal(dg[g, kept, c], wg[g, kept, c])
            # magnitude property: nothing pruned beats the kept minimum
            pruned = np.setdiff1d(np.arange(m), kept)
            if len(kept) == n and len(pruned):
                assert np.abs(wg[g, pruned, c]).min() <= \
                    np.abs(wg[g, kept, c]).min() + 1e-7
            assert len(pruned) >= m - n


@settings(max_examples=15)
@given(st.integers(1, 8), st.integers(1, 16), st.integers(0, 2**31 - 1))
def test_sparsify_idempotent_on_already_sparse(groups, n_cols, seed):
    """densify(sparsify(.)) is a fixed point: re-pruning an already
    2:4-sparse matrix reproduces it bit-for-bit."""
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(groups * 4, n_cols)).astype(np.float32)
    d1 = densify(sparsify(jnp.asarray(w), 2, 4))
    d2 = densify(sparsify(d1, 2, 4))
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))


def test_sparsify_pads_ragged_k():
    """K not a multiple of M zero-pads the tail group; densify slices
    back to the dense K."""
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(10, 6)), jnp.float32)  # 10 % 4 != 0
    st_ = sparsify(w, 2, 4)
    assert st_.k_dense == 10 and st_.values.shape == (6, 6)
    assert st_.densify().shape == (10, 6)


def test_parse_sparsity_validates():
    assert parse_sparsity("2:4") == (2, 4)
    assert parse_sparsity("1:2") == (1, 2)
    for bad in ("4:2", "0:4", "2:2", "2-4", "2:", "a:b"):
        with pytest.raises(ValueError):
            parse_sparsity(bad)


def test_quantized_sparsify_stores_int8_with_scales():
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(size=(16, 8)), jnp.float32)
    st_ = sparsify(w, 2, 4, quantize=True)
    assert st_.quantized and st_.values.dtype == jnp.int8
    assert st_.scale.shape == (1, 8)
    rel = float(jnp.max(jnp.abs(st_.densify() - densify(sparsify(w, 2, 4))))
                / jnp.max(jnp.abs(w)))
    assert rel < 0.02  # int8 rounding only


# --------------------------------------------------------------------------
# The sparse GEMM backends: bit-exactness
# --------------------------------------------------------------------------


def test_sparse_gemm_pallas_matches_xla_bit_exact():
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.normal(size=(48, 256)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(256, 128)), jnp.float32)
    st_ = sparsify(w, 2, 4)
    y_x = sg.sparse_gemm(a, st_.values, st_.indices, n_keep=2, m_group=4,
                         use_pallas=False)
    y_p = sg.sparse_gemm(a, st_.values, st_.indices, n_keep=2, m_group=4,
                         use_pallas=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(y_x), np.asarray(y_p))
    # and both ARE the dense matmul over the densified weight (f32)
    np.testing.assert_array_equal(np.asarray(y_x),
                                  np.asarray(a @ st_.densify()))


def test_sparse_gemm_quantized_pallas_matches_xla_bit_exact():
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.normal(size=(24, 64)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    st_ = sparsify(w, 2, 4, quantize=True)
    y_x = sg.sparse_gemm(a, st_.values, st_.indices, st_.scale,
                         n_keep=2, m_group=4, use_pallas=False)
    y_p = sg.sparse_gemm(a, st_.values, st_.indices, st_.scale,
                         n_keep=2, m_group=4, use_pallas=True,
                         interpret=True)
    np.testing.assert_array_equal(np.asarray(y_x), np.asarray(y_p))


def test_sparse_gemm_ragged_shapes_pad_correctly():
    """Non-block-multiple M/N and ragged K still agree with the
    densified reference on both backends."""
    rng = np.random.default_rng(4)
    a = jnp.asarray(rng.normal(size=(13, 44)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(44, 21)), jnp.float32)
    st_ = sparsify(w, 2, 4)
    ref = np.asarray(a @ st_.densify())
    for use_pallas in (False, True):
        y = sg.sparse_gemm(a, st_.values, st_.indices, n_keep=2, m_group=4,
                           use_pallas=use_pallas, interpret=True)
        np.testing.assert_allclose(np.asarray(y), ref, rtol=1e-6, atol=1e-5)


def test_sparse_backends_registered_and_dispatch():
    reg = engine_mod.default_registry()
    for b in engine_mod.SPARSE_BACKENDS:
        assert b in reg.backends()
        assert "gemm_sparse" in reg.ops(b)
        assert "gemm" in reg.ops(b)  # skip-listed weights stay dense
    rng = np.random.default_rng(5)
    a = jnp.asarray(rng.normal(size=(16, 32)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(32, 16)), jnp.float32)
    st_ = sparsify(w, 2, 4)
    outs = {}
    for b in engine_mod.SPARSE_BACKENDS:
        with engine_mod.use_engine(backend=b) as eng:
            assert eng.sparse
            outs[b] = np.asarray(eng.sparse_matmul(a, st_))
    np.testing.assert_array_equal(outs["pallas-tpu-sparse"],
                                  outs["xla-sparse"])


# --------------------------------------------------------------------------
# VJP masking posture
# --------------------------------------------------------------------------


def test_sparse_vjp_masks_pruned_weight_grads():
    """Activation cotangents match the dense oracle exactly; value
    cotangents are the dense weight grad GATHERED at the kept indices —
    scattered back to dense, every pruned position is exactly zero."""
    rng = np.random.default_rng(6)
    a = jnp.asarray(rng.normal(size=(16, 48)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(48, 24)), jnp.float32)
    st_ = sparsify(w, 2, 4)

    with engine_mod.use_engine(backend="xla-sparse") as eng:
        def loss(a_, v_):
            st2 = SparseTensor(v_, st_.indices, n=2, m=4,
                               k_dense=st_.k_dense)
            return jnp.sum(eng.sparse_matmul(a_, st2) ** 2)
        da, dv = jax.grad(loss, argnums=(0, 1))(a, st_.values)

    wd = st_.densify()
    da_ref, dw_ref = jax.grad(
        lambda a_, w_: jnp.sum((a_ @ w_) ** 2), argnums=(0, 1))(a, wd)
    np.testing.assert_allclose(np.asarray(da), np.asarray(da_ref),
                               rtol=1e-5, atol=1e-5)
    # scatter dv to dense: pruned positions exactly zero, kept match
    dv_dense = np.asarray(densify(
        SparseTensor(dv, st_.indices, n=2, m=4, k_dense=st_.k_dense)))
    mask = np.asarray(wd) != 0
    assert (dv_dense[~mask] == 0).all()
    np.testing.assert_allclose(dv_dense[mask], np.asarray(dw_ref)[mask],
                               rtol=1e-5, atol=1e-5)


def test_sparse_int8_vjp_is_activation_only():
    """sparse×int8: int8 storage is data, not a trainable leaf — the
    activation grad is the only cotangent, close to the float oracle."""
    rng = np.random.default_rng(7)
    a = jnp.asarray(rng.normal(size=(8, 32)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(32, 16)), jnp.float32)
    st_ = sparsify(w, 2, 4, quantize=True)

    with engine_mod.use_engine(backend="xla-sparse") as eng:
        da = jax.grad(
            lambda a_: jnp.sum(eng.sparse_matmul(a_, st_) ** 2))(a)
    da_ref = jax.grad(
        lambda a_: jnp.sum((a_ @ st_.densify()) ** 2))(a)
    denom = float(jnp.max(jnp.abs(da_ref)))
    assert float(jnp.max(jnp.abs(da - da_ref))) / denom < 1e-5


# --------------------------------------------------------------------------
# Engine density keying + cost-model awareness
# --------------------------------------------------------------------------


def test_density_is_part_of_the_decision_cache_key():
    r_dense = engine_mod.KernelRequest("gemm", 64, 256, 64)
    r_sparse = engine_mod.KernelRequest("gemm_sparse", 64, 256, 64,
                                        density=0.5)
    assert r_dense.key() != r_sparse.key()
    plan = engine_mod.ExecutionPlan()
    model = engine_mod.TPUModel()
    plan.add(r_dense, model.decide(r_dense))
    assert plan.lookup(r_sparse) is None  # sparse never reuses dense
    # different densities key apart too (1:4 vs 2:4)
    r_q = engine_mod.KernelRequest("gemm_sparse", 64, 256, 64, density=0.25)
    plan.add(r_sparse, model.decide(r_sparse))
    assert plan.lookup(r_q) is None


def test_density_survives_plan_json_roundtrip(tmp_path):
    plan = engine_mod.ExecutionPlan()
    model = engine_mod.TPUModel()
    req = engine_mod.KernelRequest("gemm_sparse", 32, 128, 64, density=0.5)
    plan.add(req, model.decide(req))
    p = tmp_path / "plan.json"
    plan.save(p)
    loaded = engine_mod.ExecutionPlan.load(p)
    assert loaded.lookup(req) is not None


def test_kernel_request_rejects_bad_density():
    with pytest.raises(ValueError):
        engine_mod.KernelRequest("gemm_sparse", 8, 8, 8, density=0.0)
    with pytest.raises(ValueError):
        engine_mod.KernelRequest("gemm_sparse", 8, 8, 8, density=1.5)


def test_tpu_model_ranks_sparse_above_dense():
    model = engine_mod.TPUModel()
    dense_d = model.decide(engine_mod.KernelRequest("gemm", 256, 2048, 512))
    sparse_d = model.decide(
        engine_mod.KernelRequest("gemm_sparse", 256, 2048, 512, density=0.5))
    assert sparse_d.seconds < dense_d.seconds
    meta = dict(sparse_d.meta)
    assert meta["density"] == 0.5 and meta["k_effective"] == 1024


def test_asic_mapper_ranks_sparse_above_dense():
    model = engine_mod.AnalyticalCostModel()
    dense_d = model.decide(engine_mod.KernelRequest("gemm", 49, 2048, 512))
    sparse_d = model.decide(
        engine_mod.KernelRequest("gemm_sparse", 49, 2048, 512, density=0.5))
    assert sparse_d.seconds < dense_d.seconds


def test_sparse_int8_storage_keys_at_one_byte():
    rng = np.random.default_rng(8)
    a = jnp.asarray(rng.normal(size=(8, 32)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(32, 16)), jnp.float32)
    with engine_mod.use_engine(backend="xla-sparse") as eng:
        eng.sparse_matmul(a, sparsify(w, 2, 4))
        eng.sparse_matmul(a, sparsify(w, 2, 4, quantize=True))
    by_bytes = {req.in_bytes for req, _ in eng.plan}
    assert by_bytes == {4, 1}  # float sparse at f32 width, ×int8 at 1


def test_decode_requests_sparse_weights():
    cfg = get_config("qwen2-1.5b", smoke=True)
    reqs = engine_mod.decode_requests(cfg, batch=2, sparse_weights=True,
                                      density=0.5, dtype_bytes=4)
    sparse_ops = [r for r in reqs if r.op == "gemm_sparse"]
    assert sparse_ops and all(r.density == 0.5 for r in sparse_ops)
    # dense posture emits no sparse ops
    reqs_d = engine_mod.decode_requests(cfg, batch=2, dtype_bytes=4)
    assert not [r for r in reqs_d if r.op == "gemm_sparse"]
    # sparse×int8: the compressed stream moves at one byte
    reqs_q = engine_mod.decode_requests(cfg, batch=2, sparse_weights=True,
                                        density=0.5, quantized_weights=True,
                                        dtype_bytes=4)
    assert all(r.in_bytes == 1 for r in reqs_q if r.op == "gemm_sparse")


# --------------------------------------------------------------------------
# sparse×int8 composition
# --------------------------------------------------------------------------


def test_sparse_int8_composition_close_to_float_sparse():
    rng = np.random.default_rng(9)
    a = jnp.asarray(rng.normal(size=(16, 64)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    ref = np.asarray(a @ densify(sparsify(w, 2, 4)))
    st_q = sparsify(w, 2, 4, quantize=True)
    outs = {}
    for b in engine_mod.SPARSE_BACKENDS:
        with engine_mod.use_engine(backend=b) as eng:
            outs[b] = np.asarray(eng.sparse_matmul(a, st_q))
    np.testing.assert_array_equal(outs["pallas-tpu-sparse"],
                                  outs["xla-sparse"])
    denom = np.max(np.abs(ref))
    assert np.max(np.abs(outs["xla-sparse"] - ref)) / denom < 0.03


def test_prune_params_quantize_composes():
    cfg = get_config("qwen2-1.5b", smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    sp = prune_params(params, 2, 4, quantize=True)
    leaves = [x for x in jax.tree.leaves(
        sp, is_leaf=lambda x: isinstance(x, SparseTensor))
        if isinstance(x, SparseTensor)]
    assert leaves and all(st_.quantized for st_ in leaves)
    assert tree_bytes(sp) < tree_bytes(prune_params(params, 2, 4))


# --------------------------------------------------------------------------
# Config knobs
# --------------------------------------------------------------------------


def test_serve_config_sparsity_knob_upgrades_backend():
    scfg = serve_lib.ServeConfig(max_seq=8, batch=1, sparsity="2:4")
    assert scfg.kernel_backend == "xla-sparse"
    scfg = serve_lib.ServeConfig(max_seq=8, batch=1, sparsity="2:4",
                                 kernel_backend="pallas-tpu")
    assert scfg.kernel_backend == "pallas-tpu-sparse"
    # sparse subsumes int8 when both knobs are set (ordering matters)
    scfg = serve_lib.ServeConfig(max_seq=8, batch=1, sparsity="2:4",
                                 quantize=True)
    assert scfg.kernel_backend == "xla-sparse"
    with pytest.raises(ValueError, match="cannot upgrade"):
        serve_lib.ServeConfig(max_seq=8, batch=1, sparsity="2:4",
                              kernel_backend="simulator")
    with pytest.raises(ValueError):
        serve_lib.ServeConfig(max_seq=8, batch=1, sparsity="4:2")


def test_train_config_sparsity_knob():
    from repro.train_lib.train import TrainConfig
    tcfg = TrainConfig(sparsity="2:4")
    assert tcfg.kernel_backend == "xla-sparse"
    with pytest.raises(ValueError):
        TrainConfig(sparsity="nope")


# --------------------------------------------------------------------------
# prune_params: targets, skips, pytree behavior
# --------------------------------------------------------------------------


def test_prune_params_targets_dense_and_skips_like_quantize():
    cfg = get_config("qwen2-1.5b", smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    sp = prune_params(params, 2, 4)
    blk = sp["stack"]["b0"]
    assert isinstance(blk["attn"]["wq"]["w"], SparseTensor)
    assert isinstance(blk["mlp"]["wi"]["w"], SparseTensor)
    assert not isinstance(sp["embed"], SparseTensor)

    moe_cfg = get_config("granite-moe-1b-a400m", smoke=True)
    sp_moe = prune_params(T.init_params(jax.random.PRNGKey(0), moe_cfg), 2, 4)
    moe_blk = sp_moe["stack"]["b0"]["moe"]
    assert not isinstance(moe_blk["router"]["w"], SparseTensor)
    assert not isinstance(moe_blk["experts"]["wi"], SparseTensor)

    ssm_cfg = get_config("mamba2-780m", smoke=True)
    sp_ssm = prune_params(T.init_params(jax.random.PRNGKey(0), ssm_cfg), 2, 4)
    ssm_p = sp_ssm["stack"]["b0"]["ssm"]
    assert not isinstance(ssm_p["in_proj"]["w"], SparseTensor)
    assert not isinstance(ssm_p["out_proj"]["w"], SparseTensor)


def test_sparse_tensor_scans_like_a_param_leaf():
    """lax.scan must slice a stacked SparseTensor per period exactly
    like a raw stacked weight (the transformer scan contract)."""
    rng = np.random.default_rng(10)
    w = jnp.asarray(rng.normal(size=(3, 16, 8)), jnp.float32)
    st_ = sparsify(w, 2, 4)
    assert st_.shape == (3, 16, 8)

    def body(c, sl):
        assert sl.values.shape == (8, 8)
        return c, sl.densify()

    _, outs = jax.lax.scan(body, 0, st_)
    np.testing.assert_allclose(np.asarray(outs), np.asarray(st_.densify()),
                               rtol=1e-6)


def test_sharding_places_indices_with_values():
    """dist.sharding resolves identical PartitionSpecs for a pruned
    leaf's values and indices (shape-driven rules, integer child
    paths), so index metadata shards alongside the values it decodes."""
    from repro.dist import sharding as shd
    from repro.launch.mesh import make_test_mesh

    cfg = get_config("qwen2-1.5b", smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    sp = prune_params(params, 2, 4)
    mesh = make_test_mesh()
    pspecs = shd.params_pspecs(sp, mesh)

    flat_s = jax.tree_util.tree_flatten_with_path(pspecs)[0]
    by_path = {tuple(str(k) for k in path): spec
               for path, spec in flat_s}
    checked = 0
    for keys in by_path:
        # SparseTensor children flatten as (values, indices, scale)
        # under FlattenedIndexKey paths "[<flat index i>]"
        if keys[-1] == "[<flat index 1>]":  # an indices child
            values_key = keys[:-1] + ("[<flat index 0>]",)
            assert by_path[keys] == by_path[values_key], keys
            checked += 1
    assert checked > 0


# --------------------------------------------------------------------------
# layers.dense dispatch + scheduler parity
# --------------------------------------------------------------------------


def test_dense_densifies_outside_sparse_engine():
    rng = np.random.default_rng(11)
    p = {"w": jnp.asarray(rng.normal(size=(32, 16)), jnp.float32)}
    x = jnp.asarray(rng.normal(size=(4, 32)), jnp.float32)
    sp = {"w": sparsify(p["w"], 2, 4)}
    ref = np.asarray(x @ np.asarray(sp["w"].densify()))
    out = np.asarray(dense(sp, x))  # no engine: densified float matmul
    np.testing.assert_allclose(out, ref, rtol=1e-6, atol=1e-6)
    with engine_mod.use_engine(backend="xla-einsum"):  # float engine
        out2 = np.asarray(dense(sp, x))
    np.testing.assert_allclose(out2, ref, rtol=1e-5, atol=1e-5)


def test_dense_dispatches_gemm_sparse_on_sparse_engine():
    rng = np.random.default_rng(12)
    p = {"w": sparsify(jnp.asarray(rng.normal(size=(32, 16)), jnp.float32),
                       2, 4),
         "b": jnp.zeros((16,), jnp.float32)}
    x = jnp.asarray(rng.normal(size=(4, 32)), jnp.float32)
    with engine_mod.use_engine(backend="xla-sparse") as eng:
        out = dense(p, x)
    assert {req.op for req, _ in eng.plan} == {"gemm_sparse"}
    assert out.shape == (4, 16)


TRACE = [(6, 8), (10, 2), (6, 5), (14, 9), (10, 3), (6, 7), (14, 2), (10, 6)]


def _mk_requests(cfg):
    rng = np.random.default_rng(0)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab, p).astype(np.int32),
                    max_new_tokens=g)
            for i, (p, g) in enumerate(TRACE)]


def test_scheduler_sparse_greedy_parity_vs_densified_oracle():
    """A pruned model on the sparse engine serves the smoke trace with
    EXACTLY the densified oracle's greedy tokens — the float sparse
    path is the same matmul by construction (bit-exact kernel)."""
    cfg = get_config("qwen2-1.5b", smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    sp = prune_params(params, 2, 4)
    oracle = densify_params(sp)
    max_seq = max(p + g for p, g in TRACE) + 1

    scfg_sp = serve_lib.ServeConfig(max_seq=max_seq, batch=3,
                                    compute_dtype=jnp.float32,
                                    sparsity="2:4")
    scfg_dn = serve_lib.ServeConfig(max_seq=max_seq, batch=3,
                                    compute_dtype=jnp.float32)
    got = Scheduler(sp, cfg, scfg_sp).run(_mk_requests(cfg))
    ref = Scheduler(oracle, cfg, scfg_dn).run(_mk_requests(cfg))
    assert set(got) == set(ref)
    for uid in ref:
        np.testing.assert_array_equal(got[uid].tokens, ref[uid].tokens,
                                      err_msg=f"request {uid}")


def test_plan_arch_sparse_weights_warm_serve_no_new_misses():
    """plan_arch(..., sparse_weights=True) pre-decides every shape a
    pruned server dispatches: replaying the trace logs zero new misses
    after warm-up."""
    cfg = get_config("qwen2-1.5b", smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    sp = prune_params(params, 2, 4)
    max_seq = max(p + g for p, g in TRACE) + 1
    scfg = serve_lib.ServeConfig(max_seq=max_seq, batch=3,
                                 compute_dtype=jnp.float32, sparsity="2:4")
    bucket = 8
    width = -(-max(p for p, _ in TRACE) // bucket) * bucket
    plan = engine_mod.plan_arch(
        cfg, seq_len=width, decode_batch=3,
        admit_widths=tuple(range(bucket, width + 1, bucket)),
        backend=scfg.kernel_backend, sparse_weights=True, dtype_bytes=4)
    eng = engine_mod.Engine(backend=scfg.kernel_backend, plan=plan)
    sched = Scheduler(sp, cfg, scfg, engine=eng, prefill_bucket=bucket)
    for r in _mk_requests(cfg):
        sched.submit(r)
    for _ in range(3):
        sched.step()
    warm = dict(plan.stats)
    while sched.queue or sched.n_active:
        sched.step()
    final = dict(plan.stats)
    assert final["misses"] - warm["misses"] == 0
    assert "gemm_sparse" in {req.op for req, _ in plan}


def test_quantized_tensor_not_confused_with_sparse():
    """The two wrapped-leaf planes coexist: prune_params leaves
    QuantizedTensor construction to quantize_params and vice versa."""
    assert not issubclass(SparseTensor, QuantizedTensor)
    rng = np.random.default_rng(13)
    w = jnp.asarray(rng.normal(size=(16, 8)), jnp.float32)
    st_ = sparsify(w, 2, 4)
    assert isinstance(st_, SparseTensor)
    assert not isinstance(st_, QuantizedTensor)
