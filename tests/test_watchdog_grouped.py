"""Straggler-mitigation watchdog + grouped-expert Pallas GEMM."""

import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.grouped_gemm import grouped_matmul
from repro.launch.watchdog import StepWatchdog, run_with_recovery


@pytest.mark.parametrize("shape", [(4, 128, 128, 128), (3, 100, 64, 200),
                                   (8, 16, 512, 32), (2, 5, 7, 9)])
def test_grouped_matmul_matches_ref(shape):
    e, c, d, f = shape
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(e, c, d)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(e, d, f)), jnp.float32)
    got = grouped_matmul(x, w, interpret=True)
    want = jnp.stack([x[i] @ w[i] for i in range(e)])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-3)


def test_grouped_matmul_bf16():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(2, 64, 128)), jnp.bfloat16)
    w = jnp.asarray(rng.normal(size=(2, 128, 64)), jnp.bfloat16)
    got = grouped_matmul(x, w, interpret=True)
    assert got.dtype == jnp.bfloat16


def test_watchdog_recovers_from_crash():
    """Crash mid-run -> restore from last checkpoint -> identical stream
    (determinism makes re-execution exact)."""
    state = {"ckpt": 0}
    crashed = {"done": False}

    def run_step(s):
        if s == 5 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("injected node failure")
        return float(100 - s)

    out = run_with_recovery(
        steps=10, start_step=0, run_step=run_step,
        save=lambda s: state.update(ckpt=s), restore=lambda: state["ckpt"],
        ckpt_every=2, watchdog=StepWatchdog(min_timeout_s=5))
    assert out["restarts"] == 1
    assert out["final_step"] == 10
    assert out["losses"] == [float(100 - s) for s in range(10)]


def test_watchdog_detects_straggler():
    state = {"ckpt": 0}
    stalled = {"done": False}
    wd = StepWatchdog(timeout_factor=3.0, min_timeout_s=0.05)

    def run_step(s):
        if s == 4 and not stalled["done"]:
            stalled["done"] = True
            time.sleep(0.4)  # >> 3x median(0.01)
        else:
            time.sleep(0.01)
        return float(s)

    out = run_with_recovery(
        steps=6, start_step=0, run_step=run_step,
        save=lambda s: state.update(ckpt=s), restore=lambda: state["ckpt"],
        ckpt_every=2, watchdog=wd)
    assert out["restarts"] == 1
    assert out["final_step"] == 6


def test_watchdog_gives_up_after_max_restarts():
    def run_step(s):
        raise RuntimeError("persistent failure")

    with pytest.raises(RuntimeError):
        run_with_recovery(
            steps=3, start_step=0, run_step=run_step,
            save=lambda s: None, restore=lambda: 0,
            max_restarts=2, watchdog=StepWatchdog(min_timeout_s=5))
