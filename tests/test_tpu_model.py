"""Plane-2 TPU cost model: constraints, traffic accounting, search."""

from hypothesis import given, settings, strategies as st

from repro.core.tpu_model import (MXU, VMEM, TPUKernelConfig,
                                  choose_kernel_config, estimate,
                                  fixed_square_cost, hbm_traffic)

dims = st.integers(1, 8192)


@given(dims, dims, dims)
@settings(max_examples=25, deadline=None)
def test_chosen_config_fits_vmem_and_beats_fixed(m, k, n):
    cfg = choose_kernel_config(m, k, n)
    assert cfg.vmem_bytes() <= VMEM
    opt = estimate(m, k, n, cfg)
    fix = fixed_square_cost(m, k, n)
    assert opt.seconds <= fix.seconds * 1.0001
    assert 0 < opt.mxu_utilization <= 1.0 + 1e-9


def test_os_traffic_writes_output_once():
    cfg = TPUKernelConfig("os", 128, 128, 128)
    t = hbm_traffic(1024, 1024, 1024, cfg)
    # A refetched per n-trip (8), B per m-trip (8), O once
    assert t == 1024 * 1024 * 2 * 8 * 2 + 1024 * 1024 * 2


def test_ws_traffic_streams_partials():
    cfg = TPUKernelConfig("ws", 128, 128, 128)
    t_1k = hbm_traffic(1024, 128, 1024, cfg)   # gk=1: no partial stream
    t_2k = hbm_traffic(1024, 256, 1024, cfg)   # gk=2: f32 partials round-trip
    acc_extra = 1024 * 1024 * 4 * 2            # one extra read+write
    assert t_2k > t_1k + acc_extra * 0.9


def test_skinny_gemm_prefers_nonsquare():
    cfg = choose_kernel_config(43264, 144, 32)
    assert (cfg.bm, cfg.bn) != (MXU, MXU)
    opt = estimate(43264, 144, 32, cfg)
    fix = fixed_square_cost(43264, 144, 32)
    assert fix.seconds / opt.seconds > 1.2  # the ReDas effect on TPU


def test_padding_efficiency_accounting():
    c = estimate(100, 100, 100, TPUKernelConfig("os", 128, 128, 128))
    assert c.padding_efficiency < 0.5  # heavy padding waste visible
    c2 = estimate(128, 128, 128, TPUKernelConfig("os", 128, 128, 128))
    assert c2.padding_efficiency == 1.0
