"""Batched mapper search engine vs the scalar oracle (the PR-2 gate).

Property tests run under the real hypothesis package or the
deterministic tests/_compat shim, whichever conftest activated.
"""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.accelerators import SPECS
from repro.core.analytical_model import GEMM, LOOP_ORDERS, MappingConfig
from repro.core.dataflow import Dataflow
from repro.core.mapper import _STREAM_DIM, ALLOC_CANDIDATES, ReDasMapper

MODEL = SPECS["redas"].model(128)

gemms = st.builds(GEMM, M=st.integers(1, 2048), K=st.integers(1, 2048),
                  N=st.integers(1, 2048))
tiles = st.integers(1, 4096)


@given(gemms, st.sampled_from(list(Dataflow)),
       st.sampled_from(SPECS["redas"].shapes), tiles, tiles, tiles,
       st.integers(0, len(LOOP_ORDERS) - 1),
       st.integers(0, len(ALLOC_CANDIDATES) - 1))
@settings(max_examples=80, deadline=None)
def test_batched_cost_matches_scalar_on_random_candidates(
        g, df, shape, tm, tk, tn, oid, aid):
    """estimate_batch == estimate bit-for-bit on arbitrary candidates,
    including invalid ones (inf) — the shared-kernel contract."""
    cfg = MappingConfig(dataflow=df, shape=shape, tile_m=tm, tile_k=tk,
                        tile_n=tn, loop_order=LOOP_ORDERS[oid],
                        alloc=ALLOC_CANDIDATES[aid])
    rep = MODEL.estimate(g, cfg)
    res = MODEL.estimate_batch(
        g,
        rows=np.array([shape.rows]), cols=np.array([shape.cols]),
        tile_m=np.array([tm]), tile_k=np.array([tk]), tile_n=np.array([tn]),
        order_ids=np.array([oid]),
        stream_dims=np.array([_STREAM_DIM[df]]),
        alloc=np.array([ALLOC_CANDIDATES[aid]]))
    assert bool(res["valid"][0]) == rep.valid
    want = rep.cycles if rep.valid else float("inf")
    assert res["cycles"][0] == want


@given(gemms)
@settings(max_examples=10, deadline=None)
def test_batched_search_picks_scalar_oracle_decision(g):
    batched = ReDasMapper(SPECS["redas"]).map_gemm(g)
    scalar = ReDasMapper(SPECS["redas"], vectorized=False).map_gemm(g)
    assert batched.config == scalar.config
    assert batched.report.cycles == scalar.report.cycles
    assert batched.candidates_evaluated == scalar.candidates_evaluated


def test_candidate_batch_mirrors_generator_order():
    g = GEMM(784, 256, 128)
    mapper = ReDasMapper(SPECS["redas"])
    batch = mapper.candidate_batch(g)
    cands = list(mapper.candidates(g))
    assert len(batch) == len(cands)
    step = max(1, len(cands) // 97)  # spot-check a spread of rows
    for i in range(0, len(cands), step):
        assert batch.config(i) == cands[i]


def test_all_specs_agree_on_headline_gemm():
    g = GEMM(43264, 144, 32)  # the Fig. 22 case-study layer
    for name in ("tpu", "gemmini", "planaria", "dynnamic", "sara", "redas"):
        b = ReDasMapper(SPECS[name]).map_gemm(g)
        s = ReDasMapper(SPECS[name], vectorized=False).map_gemm(g)
        assert b.config == s.config, name
        assert b.report == s.report, name


def test_decision_cache_returns_identical_objects():
    mapper = ReDasMapper(SPECS["redas"])
    first = mapper.map_gemm(GEMM(784, 256, 128))
    second = mapper.map_gemm(GEMM(784, 256, 128))
    assert second.config is first.config  # cached object, not a re-search
    assert second.candidates_evaluated == 0
    counted = mapper.map_gemm(GEMM(784, 256, 128, count=5))
    assert counted.config is first.config
    assert counted.report.cycles > first.report.cycles  # count-scaled


def test_arch_traces_map_cleanly():
    """Every registered arch config lowers to GEMMs the engine can map."""
    from repro.core.workloads import arch_traces

    mapper = ReDasMapper(SPECS["redas"])  # shared decision cache across archs
    for name, gemms in arch_traces(smoke=True, seq_len=64).items():
        assert gemms, name
        mapping = mapper.map_model(gemms)
        assert mapping.total_cycles > 0, name


def test_arch_trace_tolerates_truncated_layer_pattern():
    """n_layers shorter than the pattern period leaves some block kinds
    with zero instances; they are skipped, not emitted as count=0."""
    import dataclasses

    from repro.configs import get_config
    from repro.core.workloads import arch_gemms

    cfg = dataclasses.replace(get_config("recurrentgemma-2b"), n_layers=1)
    gemms = arch_gemms(cfg, seq_len=64)
    assert gemms and all(g.count >= 1 for g in gemms)
