"""Continuous-batching scheduler: ragged parity, slot lifecycle, clocks.

The four cache kinds are covered through their serving archs:
  qwen2-1.5b        full attention
  mixtral-8x7b      sliding-window ring cache (+ MoE)
  mamba2-780m       SSM (conv + SSD state)
  recurrentgemma-2b RG-LRU (+ local ring)
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine as engine_mod
from repro.configs import get_config
from repro.models import transformer as T
from repro.serve_lib import serve as serve_lib
from repro.serve_lib.scheduler import Request, Scheduler

KINDS = ["qwen2-1.5b", "mixtral-8x7b", "mamba2-780m", "recurrentgemma-2b"]

CHUNK = 8  # > one page / bucket, small enough that smoke prompts span it


def _cfg(arch):
    cfg = get_config(arch, smoke=True)
    if cfg.moe is not None:  # avoid capacity drops in exactness checks
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    return cfg


def _setup(arch, batch, max_seq=48):
    cfg = _cfg(arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    scfg = serve_lib.ServeConfig(max_seq=max_seq, batch=batch,
                                 compute_dtype=jnp.float32,
                                 cache_dtype=jnp.float32)
    return cfg, params, scfg


def _requests(cfg, n, rng, max_prompt=20, max_gen=8):
    reqs = []
    for uid in range(n):
        plen = int(rng.integers(3, max_prompt))
        gen = int(rng.integers(2, max_gen + 1))
        prompt = rng.integers(0, cfg.vocab, plen).astype(np.int32)
        reqs.append(Request(uid=uid, prompt=prompt, max_new_tokens=gen))
    return reqs


# --------------------------------------------------------------------------
# Parity: continuous batching == per-request generate (greedy)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("arch", KINDS)
def test_scheduler_matches_generate(arch):
    """Mixed-length prompts served continuously through a 2-slot pool
    emit exactly the tokens per-request `generate` produces."""
    cfg, params, scfg = _setup(arch, batch=2)
    rng = np.random.default_rng(0)
    reqs = _requests(cfg, 5, rng)
    sched = Scheduler(params, cfg, scfg)
    comps = sched.run(reqs, max_steps=300)
    assert sorted(comps) == [r.uid for r in reqs]

    scfg1 = dataclasses.replace(scfg, batch=1)
    for r in reqs:
        ref = serve_lib.generate(params, cfg, scfg1,
                                 jnp.asarray(r.prompt)[None],
                                 r.max_new_tokens)
        np.testing.assert_array_equal(
            comps[r.uid].tokens, np.asarray(ref)[0],
            err_msg=f"{arch} uid={r.uid}")
        assert comps[r.uid].finish_reason == "length"


def test_scheduler_bucketed_prefill_still_correct():
    """prefill_bucket > 1 pads admit widths; outputs stay identical
    (prompt padding is masked out of every cache kind)."""
    cfg, params, scfg = _setup("qwen2-1.5b", batch=2)
    rng = np.random.default_rng(1)
    reqs = _requests(cfg, 4, rng)
    a = Scheduler(params, cfg, scfg).run(reqs, max_steps=300)
    reqs2 = [dataclasses.replace(r) for r in reqs]
    b = Scheduler(params, cfg, scfg, prefill_bucket=8).run(
        reqs2, max_steps=300)
    for uid in a:
        np.testing.assert_array_equal(a[uid].tokens, b[uid].tokens)


# --------------------------------------------------------------------------
# Slot lifecycle: eviction frees slots, freed slots readmit from queue
# --------------------------------------------------------------------------


def test_slot_eviction_and_readmission():
    cfg, params, scfg = _setup("qwen2-1.5b", batch=2)
    rng = np.random.default_rng(2)
    reqs = _requests(cfg, 6, rng, max_gen=5)
    sched = Scheduler(params, cfg, scfg)
    comps = sched.run(reqs, max_steps=300)
    assert sched.stats["admitted"] == 6
    assert sched.stats["finished"] == 6
    assert sched.n_active == 0 and not sched.queue
    # more requests than slots => freed slots were reused by later admits
    assert sched.stats["prefill_calls"] >= 2
    first_finish = min(c.finish_step for c in comps.values())
    late_admits = [c for c in comps.values() if c.admit_step > first_finish]
    assert late_admits, "no request was admitted into a freed slot"
    # decode compute tracks only live slots, never the whole pool blindly
    assert sched.stats["decode_tokens"] <= 2 * sched.stats["decode_steps"]


def test_eos_evicts_early():
    cfg, params, scfg = _setup("qwen2-1.5b", batch=1)
    prompt = np.arange(7, dtype=np.int32) % cfg.vocab
    free = Scheduler(params, cfg, scfg).run(
        [Request(uid=0, prompt=prompt, max_new_tokens=8)], max_steps=100)
    toks = free[0].tokens
    eos = int(toks[3])
    capped = Scheduler(params, cfg, scfg).run(
        [Request(uid=1, prompt=prompt, max_new_tokens=8, eos_id=eos)],
        max_steps=100)
    got = capped[1]
    assert got.finish_reason == "eos"
    assert got.tokens[-1] == eos
    assert len(got.tokens) <= 4
    np.testing.assert_array_equal(got.tokens, toks[: len(got.tokens)])


def test_scheduler_validations():
    cfg, params, scfg = _setup("qwen2-1.5b", batch=2, max_seq=16)
    sched = Scheduler(params, cfg, scfg)
    ok = Request(uid=0, prompt=np.ones(4, np.int32), max_new_tokens=2)
    sched.submit(ok)
    with pytest.raises(ValueError, match="duplicate"):
        sched.submit(dataclasses.replace(ok))
    with pytest.raises(ValueError, match="max_seq"):
        sched.submit(Request(uid=1, prompt=np.ones(15, np.int32),
                             max_new_tokens=3))
    with pytest.raises(ValueError, match="PRNG key"):
        sched.submit(Request(uid=2, prompt=np.ones(3, np.int32),
                             max_new_tokens=2, temperature=0.5))
    with pytest.raises(ValueError, match="empty"):
        sched.submit(Request(uid=3, prompt=np.zeros(0, np.int32),
                             max_new_tokens=2))


def test_scheduler_temperature_runs():
    cfg, params, scfg = _setup("qwen2-1.5b", batch=2)
    rng = np.random.default_rng(3)
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab, 5 + i, np.int64)
                    .astype(np.int32), max_new_tokens=3, temperature=1.0,
                    key=jax.random.PRNGKey(i))
            for i in range(3)]
    comps = Scheduler(params, cfg, scfg).run(reqs, max_steps=100)
    assert sorted(comps) == [0, 1, 2]
    assert all(len(c.tokens) == 3 for c in comps.values())


# --------------------------------------------------------------------------
# Per-kind cache clocks: ragged prefill state == exact per-request state
# --------------------------------------------------------------------------


def _slot_view(cache, i):
    """One slot's cache: slots leaves are (n_periods, B, ...), tail
    leaves (B, ...), the clock (B,)."""
    return {"t": cache["t"][i],
            "slots": jax.tree.map(lambda a: a[:, i], cache["slots"]),
            "tail": jax.tree.map(lambda a: a[i], cache["tail"])}


def _assert_slot_state_matches(cfg, view, ref, length):
    """Compare one ragged-prefill slot against an exact batch=1 prefill:
    recurrent leaves and ring contents exactly, attention rows [0, L)."""
    assert int(view["t"]) == int(ref["t"][0]) == length
    for j, kind in enumerate(cfg.layer_pattern):
        c, r = view["slots"][f"b{j}"], jax.tree.map(
            lambda a: a[:, 0], ref["slots"][f"b{j}"])
        if kind in ("attn", "local"):
            size = c["k"].shape[1]
            rows = size if length >= size else length
            for leaf in ("k", "v"):
                np.testing.assert_allclose(
                    np.asarray(c[leaf][:, :rows]),
                    np.asarray(r[leaf][:, :rows]),
                    rtol=2e-5, atol=2e-5, err_msg=f"{kind}/{leaf}")
        else:
            for leaf in c:
                np.testing.assert_allclose(
                    np.asarray(c[leaf]), np.asarray(r[leaf]),
                    rtol=2e-5, atol=2e-5, err_msg=f"{kind}/{leaf}")


@pytest.mark.parametrize("arch", KINDS)
def test_ragged_prefill_state_per_kind(arch):
    """One padded ragged prefill writes, per slot, the same cache state
    (clock, attention rows, ring placement, conv/SSD/RG-LRU states) an
    exact-length per-request prefill produces.  Lengths straddle the
    smoke window (16) so rings wrap for one slot and not the other."""
    cfg, params, _ = _setup(arch, batch=3)
    lens = np.array([9, 24, 17], np.int32)
    rng = np.random.default_rng(4)
    toks = rng.integers(0, cfg.vocab, (3, 24)).astype(np.int32)
    for i, ln in enumerate(lens):
        toks[i, ln:] = 0
    cache = T.init_cache(cfg, T.CacheSpec(max_seq=40, batch=3),
                         dtype=jnp.float32)
    lg, cache_r = T.prefill(params, cfg, jnp.asarray(toks), cache,
                            compute_dtype=jnp.float32,
                            lengths=jnp.asarray(lens))
    for i, ln in enumerate(lens):
        c1 = T.init_cache(cfg, T.CacheSpec(max_seq=40, batch=1),
                          dtype=jnp.float32)
        lg1, c1 = T.prefill(params, cfg, jnp.asarray(toks[i: i + 1, :ln]),
                            c1, compute_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(lg[i]), np.asarray(lg1[0]),
                                   rtol=1e-4, atol=1e-4)
        _assert_slot_state_matches(cfg, _slot_view(cache_r, i), c1, int(ln))


@pytest.mark.parametrize("arch", KINDS)
def test_decode_inactive_slots_frozen(arch):
    """A masked decode step leaves inactive slots' cache (every kind of
    leaf) and clock bitwise untouched while active slots advance."""
    cfg, params, _ = _setup(arch, batch=3)
    lens = np.array([5, 12, 8], np.int32)
    rng = np.random.default_rng(5)
    toks = rng.integers(0, cfg.vocab, (3, 12)).astype(np.int32)
    cache = T.init_cache(cfg, T.CacheSpec(max_seq=32, batch=3),
                         dtype=jnp.float32)
    _, cache = T.prefill(params, cfg, jnp.asarray(toks), cache,
                         compute_dtype=jnp.float32,
                         lengths=jnp.asarray(lens))
    active = jnp.asarray(np.array([True, False, True]))
    tok = jnp.asarray(rng.integers(0, cfg.vocab, (3, 1)).astype(np.int32))
    logits, cache2 = T.decode_step(params, cfg, cache, tok,
                                   compute_dtype=jnp.float32, active=active)
    frozen_before = jax.tree.leaves(_slot_view(cache, 1))
    frozen_after = jax.tree.leaves(_slot_view(cache2, 1))
    for a, b in zip(frozen_before, frozen_after, strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(cache2["t"]),
                                  np.asarray(cache["t"]) + [1, 0, 1])
    # and the active slots see exactly what an all-active step computes
    logits_all, _ = T.decode_step(params, cfg, cache, tok,
                                  compute_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(logits[0]),
                                  np.asarray(logits_all[0]))


# --------------------------------------------------------------------------
# Engine: the decode step's fixed shapes are fully covered by plan_arch
# --------------------------------------------------------------------------


@pytest.mark.parametrize("arch", KINDS)
def test_decode_plan_coverage(arch):
    """plan_arch(decode_batch=B) pre-decides every engine request a
    decode-step trace makes: tracing inside a warm-started engine adds
    hits but ZERO new plan misses (no per-step re-planning)."""
    cfg = _cfg(arch)
    B = 3
    plan = engine_mod.plan_arch(cfg, seq_len=16, dtype_bytes=4,
                                decode_batch=B, backend="xla-einsum")
    eng = engine_mod.Engine(backend="xla-einsum", plan=plan)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    cache = T.init_cache(cfg, T.CacheSpec(max_seq=32, batch=B),
                         dtype=jnp.float32)
    cache = {**cache, "t": jnp.array([5, 9, 2], jnp.int32)}
    misses_before = plan.misses
    with engine_mod.use_engine(eng):
        step = jax.jit(lambda p, c, tok: T.decode_step(
            p, cfg, c, tok, compute_dtype=jnp.float32,
            active=jnp.array([True, True, False])))
        logits, _ = step(params, cache, jnp.zeros((B, 1), jnp.int32))
        logits.block_until_ready()
    assert plan.misses == misses_before
    if any(k in ("attn", "local", "rglru") for k in cfg.layer_pattern):
        assert plan.hits > 0  # ssm-only archs route no decode matmuls


# --------------------------------------------------------------------------
# Chunked prefill (DESIGN.md §12): chunked == unchunked, all postures
# --------------------------------------------------------------------------


def _mix_requests(cfg, rng, n_short=3, long_len=24):
    """One ingestion-forcing long prompt plus short interactive ones,
    with prompts fixed once so both serves see identical requests."""
    reqs = [Request(uid=0,
                    prompt=rng.integers(0, cfg.vocab, long_len)
                    .astype(np.int32),
                    max_new_tokens=6)]
    for uid in range(1, n_short + 1):
        plen = int(rng.integers(3, 8))
        reqs.append(Request(
            uid=uid, prompt=rng.integers(0, cfg.vocab, plen)
            .astype(np.int32), max_new_tokens=6))
    return reqs


def _clone(reqs):
    return [dataclasses.replace(r) for r in reqs]


@pytest.mark.parametrize("arch", KINDS)
def test_chunked_prefill_matches_unchunked(arch):
    """A long prompt streamed in CHUNK-token slices emits exactly the
    tokens monolithic admission produces, on every cache kind."""
    cfg, params, scfg = _setup(arch, batch=2)
    reqs = _mix_requests(cfg, np.random.default_rng(3))
    plain = Scheduler(params, cfg, scfg).run(_clone(reqs), max_steps=300)
    chunked_scfg = dataclasses.replace(scfg, prefill_chunk=CHUNK)
    sched = Scheduler(params, cfg, chunked_scfg)
    chunked = sched.run(_clone(reqs), max_steps=300)
    assert sorted(chunked) == sorted(plain)
    for uid in plain:
        np.testing.assert_array_equal(chunked[uid].tokens, plain[uid].tokens,
                                      err_msg=f"{arch} uid={uid}")
    # the long prompt actually went through the ingestion plane
    assert CHUNK in sched.stats["prefill_widths"]


@pytest.mark.parametrize("posture", ["paged", "int8", "paged-int8"])
def test_chunked_prefill_paged_and_int8(posture):
    """Chunk boundaries stay exact on the paged and int8 cache layouts
    (chunk % page_size == 0 keeps hist page-aligned; the int8 contract
    is greedy-token parity, as everywhere in the int8 plane)."""
    cfg, params, scfg = _setup("qwen2-1.5b", batch=2)
    over = {}
    if "paged" in posture:
        over.update(cache_layout="paged", page_size=8)
    if "int8" in posture:
        over.update(cache_dtype=jnp.int8)
    scfg = dataclasses.replace(scfg, **over)
    reqs = _mix_requests(cfg, np.random.default_rng(4))
    plain = Scheduler(params, cfg, scfg).run(_clone(reqs), max_steps=300)
    chunked_scfg = dataclasses.replace(scfg, prefill_chunk=CHUNK)
    chunked = Scheduler(params, cfg, chunked_scfg).run(_clone(reqs),
                                                       max_steps=300)
    for uid in plain:
        np.testing.assert_array_equal(chunked[uid].tokens, plain[uid].tokens,
                                      err_msg=f"{posture} uid={uid}")


def test_chunked_composes_with_speculative():
    """speculate_k drafts only after a slot finishes ingesting, so
    chunking + speculation stays bitwise identical to plain greedy."""
    cfg, params, scfg = _setup("qwen2-1.5b", batch=2, max_seq=50)
    reqs = _mix_requests(cfg, np.random.default_rng(5))
    plain = Scheduler(params, cfg, scfg).run(_clone(reqs), max_steps=300)
    spec_scfg = dataclasses.replace(scfg, prefill_chunk=CHUNK,
                                    speculate_k=2, draft="self")
    chunked = Scheduler(params, cfg, spec_scfg).run(_clone(reqs),
                                                    max_steps=300)
    for uid in plain:
        np.testing.assert_array_equal(chunked[uid].tokens, plain[uid].tokens,
                                      err_msg=f"uid={uid}")


def test_chunk_width_validation():
    cfg, params, scfg = _setup("qwen2-1.5b", batch=2)
    bad = dataclasses.replace(scfg, prefill_chunk=6)
    with pytest.raises(ValueError, match="prefill_bucket"):
        Scheduler(params, cfg, bad, prefill_bucket=4)
    with pytest.raises(ValueError, match="page_size"):
        dataclasses.replace(scfg, prefill_chunk=12,
                            cache_layout="paged", page_size=8)


# --------------------------------------------------------------------------
# Async ingestion plane (DESIGN.md §12): parity, backpressure, shutdown
# --------------------------------------------------------------------------


def test_serve_async_matches_run():
    """Futures resolve to exactly the Completions the synchronous loop
    produces, including through the chunked ingestion path."""
    cfg, params, scfg = _setup("qwen2-1.5b", batch=2)
    scfg = dataclasses.replace(scfg, prefill_chunk=CHUNK)
    reqs = _mix_requests(cfg, np.random.default_rng(6))
    ref = Scheduler(params, cfg, scfg).run(_clone(reqs), max_steps=300)
    sched = Scheduler(params, cfg, scfg)
    with sched.serve_async(max_queue=len(reqs)) as srv:
        futs = {r.uid: srv.submit(r) for r in _clone(reqs)}
        comps = {uid: f.result(timeout=120) for uid, f in futs.items()}
    for uid in ref:
        np.testing.assert_array_equal(comps[uid].tokens, ref[uid].tokens)
        assert comps[uid].finish_reason == ref[uid].finish_reason
    assert not sched.n_active and not sched.queue


def test_async_backpressure_and_clean_shutdown():
    """A full bounded queue raises queue.Full under a submit timeout;
    shutdown drains accepted work and then refuses new submissions."""
    import queue as queue_mod

    cfg, params, scfg = _setup("qwen2-1.5b", batch=1)
    reqs = _mix_requests(cfg, np.random.default_rng(7), n_short=1)
    sched = Scheduler(params, cfg, scfg)
    srv = sched.serve_async(max_queue=1, start=False)  # worker not running
    fut0 = srv.submit(reqs[0])            # fills the queue
    with pytest.raises(queue_mod.Full):
        srv.submit(reqs[1], timeout=0.05)  # backpressure surfaces
    srv.start()
    srv.shutdown(wait=True)               # drains the accepted request
    assert fut0.result(timeout=5).finish_reason == "length"
    with pytest.raises(RuntimeError, match="shutdown"):
        srv.submit(reqs[1])
    # a rejected request surfaces on ITS future, not in the worker
    sched2 = Scheduler(params, cfg, scfg)
    with sched2.serve_async() as srv2:
        good = srv2.submit(reqs[0])
        bad = srv2.submit(Request(uid=reqs[0].uid,  # duplicate uid
                                  prompt=reqs[1].prompt, max_new_tokens=2))
        assert good.result(timeout=120).finish_reason == "length"
        with pytest.raises(ValueError):
            bad.result(timeout=120)
