"""Pallas GEMM kernel sweeps vs the pure-jnp oracle (interpret mode),
dispatched through the repro.engine surface."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.tpu_model import VMEM
from repro.engine import Engine, KernelRequest, TPUModel
from repro.engine.backends import default_blocks, pallas_gemm
from repro.kernels.grouped_gemm import default_group_blocks, grouped_matmul
from repro.kernels.redas_gemm import VMEM_BYTES, vmem_bytes
from repro.kernels.ref import grouped_matmul_ref, matmul_ref

DATAFLOWS = ("os", "ws", "is")


@pytest.mark.parametrize("dataflow", DATAFLOWS)
@pytest.mark.parametrize("shape", [
    (256, 256, 256),      # exact blocks
    (384, 144, 32),       # paper case-study aspect
    (100, 50, 300),       # all dims odd vs blocks
    (8, 128, 128),        # minimum sublane
    (513, 257, 129),      # prime-ish, multi-k accumulation
    (1, 1024, 16),        # matrix-vector
])
def test_kernel_matches_oracle_f32(dataflow, shape):
    m, k, n = shape
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    got = pallas_gemm(a, b, dataflow=dataflow, interpret=True)
    want = matmul_ref(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-4)


@pytest.mark.parametrize("dataflow", DATAFLOWS)
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_kernel_dtypes(dataflow, dtype):
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.normal(size=(64, 256)), dtype)
    b = jnp.asarray(rng.normal(size=(256, 128)), dtype)
    got = pallas_gemm(a, b, dataflow=dataflow, interpret=True)
    assert got.dtype == dtype
    want = matmul_ref(a, b, jnp.float32)
    tol = 0.15 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("blocks", [(8, 128, 128), (16, 256, 128),
                                    (64, 128, 256)])
@pytest.mark.parametrize("dataflow", DATAFLOWS)
def test_kernel_block_shapes(blocks, dataflow):
    bm, bk, bn = blocks
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.normal(size=(3 * bm, 2 * bk)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(2 * bk, 2 * bn)), jnp.float32)
    got = pallas_gemm(a, b, dataflow=dataflow, bm=bm, bk=bk, bn=bn,
                      interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(matmul_ref(a, b)),
                               rtol=2e-5, atol=2e-4)


@given(st.integers(1, 300), st.integers(1, 300), st.integers(1, 300),
       st.sampled_from(DATAFLOWS))
@settings(max_examples=12, deadline=None)
def test_kernel_random_shapes(m, k, n, dataflow):
    rng = np.random.default_rng(m * 7 + k * 3 + n)
    a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    got = pallas_gemm(a, b, dataflow=dataflow, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(matmul_ref(a, b)),
                               rtol=2e-5, atol=5e-4)


def test_engine_matmul_uses_mapper():
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.normal(size=(50, 3072)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(3072, 768)), jnp.float32)
    eng = Engine(backend="pallas-interpret")
    got = eng.matmul(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(matmul_ref(a, b)),
                               rtol=2e-5, atol=2e-3)
    assert eng.plan.stats["decisions"] == 1


def test_vmem_budget_enforced():
    with pytest.raises(ValueError, match="VMEM"):
        pallas_gemm(jnp.zeros((4096, 4096)), jnp.zeros((4096, 4096)),
                    bm=4096, bk=4096, bn=4096, interpret=True)
    bm, bk, bn = default_blocks(4096, 4096, 4096)
    assert vmem_bytes(bm, bk, bn) <= VMEM


def test_mapper_configs_fit_vmem():
    model = TPUModel()
    for (m, k, n) in [(43264, 144, 32), (50, 3072, 768), (4096, 4096, 4096)]:
        dec = model.decide(KernelRequest("gemm", m, k, n))
        assert vmem_bytes(dec.bm, dec.bk, dec.bn) <= VMEM


def test_grouped_ref_consistency():
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(10, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 16, 8)), jnp.float32)
    got = grouped_matmul_ref(x, w, [4, 3, 3])
    want = jnp.concatenate([x[:4] @ w[0], x[4:7] @ w[1], x[7:] @ w[2]])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


def test_grouped_blocks_vmem_gated():
    """Satellite: grouped block selection goes through the shared Eq.-2
    gate — defaults fit VMEM for any problem, oversized blocks raise."""
    for dims in [(16, 16, 16), (4096, 8192, 4096), (700, 3000, 500)]:
        bc, bd, bf = default_group_blocks(*dims)
        assert vmem_bytes(bc, bd, bf) <= VMEM_BYTES
        assert bc % 8 == 0 and bd % 128 == 0 and bf % 128 == 0
    with pytest.raises(ValueError, match="VMEM"):
        grouped_matmul(jnp.zeros((2, 4096, 4096)),
                       jnp.zeros((2, 4096, 4096)),
                       bc=4096, bd=4096, bf=4096, interpret=True)


def test_grouped_matmul_default_blocks_match_oracle():
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(3, 20, 48)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 48, 24)), jnp.float32)
    got = grouped_matmul(x, w, interpret=True)  # blocks via the VMEM gate
    want = jnp.einsum("ecd,edf->ecf", x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-4)


def test_model_forward_through_engine():
    """models route matmuls through the engine-dispatched Pallas GEMM
    under use_engine and produce the same logits."""
    import jax
    from repro.configs import get_config
    from repro.engine import use_engine
    from repro.models import transformer as T

    cfg = get_config("qwen2-1.5b", smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0, cfg.vocab)
    ref, _ = T.forward(params, cfg, toks, compute_dtype=jnp.float32)
    with use_engine(backend="pallas-interpret") as eng:
        got, _ = T.forward(params, cfg, toks, compute_dtype=jnp.float32)
    assert eng.plan.stats["decisions"] > 0
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-3)


def test_pre_engine_dispatch_surface_removed():
    """The PR 3 `kernels.ops` DeprecationWarning shims are gone: the
    engine API (`repro.engine.matmul` / `use_engine` /
    `backends.pallas_gemm`) is the only dispatch surface."""
    with pytest.raises(ImportError):
        from repro.kernels import ops  # noqa: F401
