"""Mamba-2 SSD chunked form and RG-LRU vs sequential-recurrence oracles."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.models.config import ArchConfig, SSMConfig
from repro.models.rglru import rglru_decode_step, rglru_init, rglru_scan
from repro.models.ssm import ssd_chunked, ssm_block, ssm_decode_step, ssm_init


def naive_ssd(x, dt, a_log, b_mat, c_mat, d_skip):
    bsz, slen, h, p = x.shape
    g = b_mat.shape[2]
    rep = h // g
    a = -np.exp(np.asarray(a_log))
    s = np.zeros((bsz, h, b_mat.shape[3], p))
    ys = []
    for t in range(slen):
        dtt = np.asarray(dt[:, t])
        dec = np.exp(dtt * a)
        bt = np.repeat(np.asarray(b_mat[:, t]), rep, axis=1)
        ct = np.repeat(np.asarray(c_mat[:, t]), rep, axis=1)
        xbar = np.asarray(x[:, t]) * dtt[..., None]
        s = s * dec[..., None, None] + np.einsum("bhn,bhp->bhnp", bt, xbar)
        y = np.einsum("bhn,bhnp->bhp", ct, s) \
            + np.asarray(d_skip)[None, :, None] * np.asarray(x[:, t])
        ys.append(y)
    return np.stack(ys, 1), s


@given(st.integers(1, 40), st.sampled_from([4, 8, 16]))
@settings(max_examples=15, deadline=None)
def test_ssd_chunked_matches_recurrence(length, chunk):
    rng = np.random.default_rng(length)
    bsz, h, p, g, n = 2, 4, 8, 2, 8
    x = jnp.asarray(rng.normal(size=(bsz, length, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(bsz, length, h)), jnp.float32)
    a_log = jnp.asarray(rng.uniform(-1, 1, size=(h,)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(bsz, length, g, n)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(bsz, length, g, n)), jnp.float32)
    d = jnp.asarray(rng.normal(size=(h,)), jnp.float32)
    y, s = ssd_chunked(x, dt, a_log, b, c, d, chunk=chunk)
    yr, sr = naive_ssd(x, dt, a_log, b, c, d)
    np.testing.assert_allclose(np.asarray(y), yr, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s), sr, rtol=1e-4, atol=1e-4)


SSM_CFG = ArchConfig(
    name="t", kind="decoder", n_layers=1, d_model=32, n_heads=0, n_kv=0,
    d_ff=0, vocab=100, layer_pattern=("ssm",),
    ssm=SSMConfig(d_state=16, head_dim=8, chunk=8))


def test_ssm_decode_matches_block():
    params = ssm_init(jax.random.PRNGKey(0), SSM_CFG)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 12, 32)), jnp.float32)
    full = ssm_block(params, SSM_CFG, x)
    s = SSM_CFG.ssm
    d_in = s.expand * 32
    heads = d_in // s.head_dim
    conv_ch = d_in + 2 * s.n_groups * s.d_state
    conv = jnp.zeros((2, s.conv_width - 1, conv_ch))
    state = jnp.zeros((2, heads, s.d_state, s.head_dim))
    outs = []
    for t in range(12):
        o, conv, state = ssm_decode_step(params, SSM_CFG, x[:, t:t + 1],
                                         conv, state)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(full), rtol=2e-4, atol=2e-4)


RG_CFG = ArchConfig(
    name="t", kind="decoder", n_layers=1, d_model=24, n_heads=2, n_kv=1,
    d_ff=48, vocab=100, layer_pattern=("rglru",), rglru_width=24,
    head_dim=12)


def test_rglru_scan_matches_sequential():
    p = rglru_init(jax.random.PRNGKey(1), RG_CFG)
    rng = np.random.default_rng(1)
    u = jnp.asarray(rng.normal(size=(2, 20, 24)), jnp.float32)
    h_par, h_last = rglru_scan(p, u)
    # sequential oracle
    from repro.models.rglru import _gates
    a, b = _gates(p, u)
    hs = np.zeros((2, 24))
    seq = []
    for t in range(20):
        hs = np.asarray(a[:, t]) * hs + np.asarray(b[:, t])
        seq.append(hs.copy())
    seq = np.stack(seq, 1)
    np.testing.assert_allclose(np.asarray(h_par), seq, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_last), seq[:, -1], rtol=1e-4,
                               atol=1e-5)


def test_rglru_stability():
    """|a_t| < 1 so the recurrence cannot blow up."""
    p = rglru_init(jax.random.PRNGKey(2), RG_CFG)
    rng = np.random.default_rng(2)
    u = jnp.asarray(rng.normal(size=(1, 500, 24)) * 5, jnp.float32)
    h, _ = rglru_scan(p, u)
    assert bool(jnp.all(jnp.isfinite(h)))
    from repro.models.rglru import _gates
    a, _ = _gates(p, u)
    # contraction: a = exp(-c*softplus(lam)*r) <= 1, equality only at the
    # f32 rounding limit for r -> 0
    assert float(a.max()) <= 1.0
    assert float(a.mean()) < 1.0


def test_rglru_decode_matches_scan():
    p = rglru_init(jax.random.PRNGKey(3), RG_CFG)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(2, 10, 24)), jnp.float32)
    from repro.models.rglru import rglru_block
    full = rglru_block(p, RG_CFG, x)
    conv = jnp.zeros((2, 3, 24))
    h = jnp.zeros((2, 24))
    outs = []
    for t in range(10):
        o, conv, h = rglru_decode_step(p, RG_CFG, x[:, t:t + 1], conv, h)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(full), rtol=2e-4, atol=2e-4)
