"""Pallas TPU flash-attention kernel vs naive oracle (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import (attention_hbm_bytes,
                                           flash_attention_tpu)


def naive(q, k, v, causal, window):
    d = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(d)
    sq, sk = q.shape[2], k.shape[2]
    qi = jnp.arange(sq)[:, None]
    ki = jnp.arange(sk)[None, :]
    m = jnp.ones((sq, sk), bool)
    if causal:
        m &= ki <= qi
    if window:
        m &= (qi - ki) < window
    s = jnp.where(m[None, None], s, -1e30)
    return jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(s, -1), v)


@pytest.mark.parametrize("sq,bq,bk,causal,window", [
    (128, 32, 32, True, 0),
    (128, 64, 32, False, 0),
    (256, 64, 64, True, 64),
    (64, 64, 64, True, 0),
    (128, 32, 64, True, 32),
])
def test_flash_kernel_matches_naive(sq, bq, bk, causal, window):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(2, 3, sq, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 3, sq, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 3, sq, 32)), jnp.float32)
    o = flash_attention_tpu(q, k, v, causal=causal, window=window,
                            bq=bq, bk=bk, interpret=True)
    r = naive(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                               rtol=1e-4, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_kernel_dtypes(dtype):
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 2, 64, 32)), dtype)
    k = jnp.asarray(rng.normal(size=(1, 2, 64, 32)), dtype)
    v = jnp.asarray(rng.normal(size=(1, 2, 64, 32)), dtype)
    o = flash_attention_tpu(q, k, v, bq=32, bk=32, interpret=True)
    assert o.dtype == dtype
    r = naive(q.astype(jnp.float32), k.astype(jnp.float32),
              v.astype(jnp.float32), True, 0)
    tol = 0.05 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(o, np.float32), np.asarray(r),
                               rtol=tol, atol=tol)


def test_kernelized_traffic_model():
    """The §Perf memory-term projection: q+k+v+o only, vs the XLA-lowered
    chunked attention that streams S x C intermediates through HBM."""
    b, h, s, d = 32, 14, 32768, 64
    kernel_bytes = attention_hbm_bytes(b, h, s, s, d)
    assert kernel_bytes == 2 * b * h * d * 4 * s
    # XLA-lowered chunked attention moves >= S^2-scale f32 intermediates
    xla_intermediates = 4 * b * h * s * s  # one f32 logits pass, lower bound
    assert xla_intermediates / kernel_bytes > 100  # the kernelization win
