"""Serving: prefill+decode == full forward; ring caches; generate()."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serve_lib import serve as serve_lib

FAMILIES = ["qwen2-1.5b", "mixtral-8x7b", "recurrentgemma-2b",
            "mamba2-780m", "gemma3-12b", "internvl2-1b"]


def _cfg(arch):
    cfg = get_config(arch, smoke=True)
    if cfg.moe is not None:  # avoid capacity drops in exactness checks
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    return cfg


@pytest.mark.parametrize("arch", FAMILIES)
def test_prefill_then_decode_matches_forward(arch):
    cfg = _cfg(arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    embeds = None
    if cfg.prefix_tokens:
        embeds = 0.02 * jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.prefix_tokens, cfg.d_model))
    full, _ = T.forward(params, cfg, toks, embeds=embeds,
                        compute_dtype=jnp.float32)
    total = S + cfg.prefix_tokens
    cache = T.init_cache(cfg, T.CacheSpec(max_seq=total, batch=B),
                         dtype=jnp.float32)
    half = S // 2
    lg, cache = T.prefill(params, cfg, toks[:, :half], cache, embeds=embeds,
                          compute_dtype=jnp.float32)
    scale = float(jnp.abs(full).max()) + 1e-9
    assert float(jnp.abs(lg[:, 0] - full[:, cfg.prefix_tokens + half - 1]).max()) / scale < 5e-3
    outs = []
    for t in range(half, S):
        lg, cache = T.decode_step(params, cfg, cache, toks[:, t:t + 1],
                                  compute_dtype=jnp.float32)
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    err = float(jnp.abs(dec - full[:, cfg.prefix_tokens + half:]).max()) / scale
    assert err < 5e-3, (arch, err)


def test_ring_cache_beyond_window():
    """Sliding-window decode far past the window stays exact."""
    cfg = _cfg("mixtral-8x7b")  # window 16 in smoke config
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 1, 50  # > 3x window
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full, _ = T.forward(params, cfg, toks, compute_dtype=jnp.float32)
    cache = T.init_cache(cfg, T.CacheSpec(max_seq=S, batch=B),
                         dtype=jnp.float32)
    # ring caches are bounded by the window regardless of max_seq
    assert cache["slots"]["b0"]["k"].shape[2] == cfg.window
    outs = []
    for t in range(S):
        lg, cache = T.decode_step(params, cfg, cache, toks[:, t:t + 1],
                                  compute_dtype=jnp.float32)
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    err = float(jnp.abs(dec - full).max()) / (float(jnp.abs(full).max()) + 1e-9)
    assert err < 5e-3, err


def test_recurrent_cache_is_constant_memory():
    cfg = get_config("mamba2-780m", smoke=True)
    small = T.init_cache(cfg, T.CacheSpec(max_seq=64, batch=1))
    big = T.init_cache(cfg, T.CacheSpec(max_seq=4096, batch=1))
    sz = lambda c: sum(x.size for x in jax.tree.leaves(c))
    assert sz(small) == sz(big)  # O(1) state: the long_500k story


def test_generate_greedy_deterministic():
    cfg = _cfg("qwen2-1.5b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    scfg = serve_lib.ServeConfig(max_seq=48, batch=2,
                                 compute_dtype=jnp.float32,
                                 cache_dtype=jnp.float32)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    out1 = serve_lib.generate(params, cfg, scfg, prompt, 8)
    out2 = serve_lib.generate(params, cfg, scfg, prompt, 8)
    assert out1.shape == (2, 8)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
