"""Serving: prefill+decode == full forward; ring caches; generate()."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serve_lib import serve as serve_lib

FAMILIES = ["qwen2-1.5b", "mixtral-8x7b", "recurrentgemma-2b",
            "mamba2-780m", "gemma3-12b", "internvl2-1b"]


def _cfg(arch):
    cfg = get_config(arch, smoke=True)
    if cfg.moe is not None:  # avoid capacity drops in exactness checks
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    return cfg


@pytest.mark.parametrize("arch", FAMILIES)
def test_prefill_then_decode_matches_forward(arch):
    cfg = _cfg(arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    embeds = None
    if cfg.prefix_tokens:
        embeds = 0.02 * jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.prefix_tokens, cfg.d_model))
    full, _ = T.forward(params, cfg, toks, embeds=embeds,
                        compute_dtype=jnp.float32)
    total = S + cfg.prefix_tokens
    cache = T.init_cache(cfg, T.CacheSpec(max_seq=total, batch=B),
                         dtype=jnp.float32)
    half = S // 2
    lg, cache = T.prefill(params, cfg, toks[:, :half], cache, embeds=embeds,
                          compute_dtype=jnp.float32)
    scale = float(jnp.abs(full).max()) + 1e-9
    assert float(jnp.abs(lg[:, 0] - full[:, cfg.prefix_tokens + half - 1]).max()) / scale < 5e-3
    outs = []
    for t in range(half, S):
        lg, cache = T.decode_step(params, cfg, cache, toks[:, t:t + 1],
                                  compute_dtype=jnp.float32)
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    err = float(jnp.abs(dec - full[:, cfg.prefix_tokens + half:]).max()) / scale
    assert err < 5e-3, (arch, err)


def test_ring_cache_beyond_window():
    """Sliding-window decode far past the window stays exact."""
    cfg = _cfg("mixtral-8x7b")  # window 16 in smoke config
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 1, 50  # > 3x window
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full, _ = T.forward(params, cfg, toks, compute_dtype=jnp.float32)
    cache = T.init_cache(cfg, T.CacheSpec(max_seq=S, batch=B),
                         dtype=jnp.float32)
    # ring caches are bounded by the window regardless of max_seq
    assert cache["slots"]["b0"]["k"].shape[2] == cfg.window
    outs = []
    for t in range(S):
        lg, cache = T.decode_step(params, cfg, cache, toks[:, t:t + 1],
                                  compute_dtype=jnp.float32)
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    err = float(jnp.abs(dec - full).max()) / (float(jnp.abs(full).max()) + 1e-9)
    assert err < 5e-3, err


def test_recurrent_cache_is_constant_memory():
    cfg = get_config("mamba2-780m", smoke=True)
    small = T.init_cache(cfg, T.CacheSpec(max_seq=64, batch=1))
    big = T.init_cache(cfg, T.CacheSpec(max_seq=4096, batch=1))
    sz = lambda c: sum(x.size for x in jax.tree.leaves(c))
    assert sz(small) == sz(big)  # O(1) state: the long_500k story


def test_generate_greedy_deterministic():
    cfg = _cfg("qwen2-1.5b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    scfg = serve_lib.ServeConfig(max_seq=48, batch=2,
                                 compute_dtype=jnp.float32,
                                 cache_dtype=jnp.float32)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    out1 = serve_lib.generate(params, cfg, scfg, prompt, 8)
    out2 = serve_lib.generate(params, cfg, scfg, prompt, 8)
    assert out1.shape == (2, 8)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def _qwen_setup(batch=2):
    cfg = _cfg("qwen2-1.5b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    scfg = serve_lib.ServeConfig(max_seq=32, batch=batch,
                                 compute_dtype=jnp.float32,
                                 cache_dtype=jnp.float32)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (batch, 6), 0,
                                cfg.vocab)
    return cfg, params, scfg, prompt


def test_generate_temperature_requires_key():
    cfg, params, scfg, prompt = _qwen_setup()
    with pytest.raises(ValueError, match="PRNG key"):
        serve_lib.generate(params, cfg, scfg, prompt, 4, temperature=0.7)
    with pytest.raises(ValueError, match="n_tokens"):
        serve_lib.generate(params, cfg, scfg, prompt, 0)


def test_generate_samples_first_token():
    """The first output token comes from the prefill logits and must be
    SAMPLED when temperature > 0 (it used to be argmax'd always)."""
    cfg, params, scfg, prompt = _qwen_setup(batch=1)
    firsts = {
        int(serve_lib.generate(params, cfg, scfg, prompt, 1,
                               temperature=4.0,
                               key=jax.random.PRNGKey(k))[0, 0])
        for k in range(12)
    }
    assert len(firsts) > 1, "first token ignored temperature"


def test_generate_decode_step_budget(monkeypatch):
    """n_tokens outputs take exactly n_tokens - 1 decode steps (the
    first token comes from prefill; no trailing discarded step)."""
    calls = {"n": 0}
    real = serve_lib.make_decode_step

    def counting(cfg, scfg):
        f = real(cfg, scfg)

        def wrapped(params, cache, token):
            calls["n"] += 1
            return f(params, cache, token)
        return wrapped

    # identity jit so the per-call counter isn't swallowed by tracing,
    # and a cleared step memo so the patched builder is actually used
    # (and the unjitted steps don't leak into later tests)
    serve_lib._jitted_steps.cache_clear()
    monkeypatch.setattr(serve_lib.jax, "jit", lambda f, **kw: f)
    monkeypatch.setattr(serve_lib, "make_decode_step", counting)
    try:
        cfg, params, scfg, prompt = _qwen_setup(batch=1)
        out = serve_lib.generate(params, cfg, scfg, prompt, 1)
        assert out.shape == (1, 1) and calls["n"] == 0
        out = serve_lib.generate(params, cfg, scfg, prompt, 4)
        assert out.shape == (1, 4) and calls["n"] == 3
    finally:
        serve_lib._jitted_steps.cache_clear()


def test_serveconfig_normalizes_dtypes():
    """"bfloat16" and jnp.bfloat16 must spell the SAME config, so the
    serve engine memo holds one engine (and one decision cache), not
    one per dtype spelling."""
    a = serve_lib.ServeConfig(max_seq=8, batch=1, compute_dtype="bfloat16",
                              cache_dtype="bfloat16",
                              kernel_backend="xla-einsum")
    b = serve_lib.ServeConfig(max_seq=8, batch=1,
                              compute_dtype=jnp.bfloat16,
                              cache_dtype=jnp.dtype(jnp.bfloat16),
                              kernel_backend="xla-einsum")
    assert a == b and hash(a) == hash(b)
    assert a.compute_dtype == jnp.dtype(jnp.bfloat16)
    eng_a = serve_lib.warm_start_engine(a)
    eng_b = serve_lib.warm_start_engine(b)
    assert eng_a is eng_b, "dtype spelling built a duplicate engine"
