"""ReDas mapper: optimality vs exhaustive candidates, caching, baselines."""

from hypothesis import given, settings, strategies as st

from repro.core.accelerators import SPECS, make_specs
from repro.core.analytical_model import GEMM
from repro.core.dataflow import Dataflow
from repro.core.mapper import ReDasMapper, fixed_baseline_decision

gemms = st.builds(GEMM, M=st.integers(1, 2048), K=st.integers(1, 2048),
                  N=st.integers(1, 2048))


@given(gemms)
@settings(max_examples=15, deadline=None)
def test_interval_sampling_near_optimal(g):
    """Interval sampling stays within a few percent of a denser search
    (the paper reports 0.1-2% loss vs brute force)."""
    fast = ReDasMapper(SPECS["redas"]).map_gemm(g)
    dense = ReDasMapper(SPECS["redas"], mode="exhaustive-orders",
                        free_dim_ratio=1.4).map_gemm(g)
    assert fast.report.cycles <= dense.report.cycles * 1.10


@given(gemms)
@settings(max_examples=10, deadline=None)
def test_mapper_beats_fixed_baseline(g):
    redas = ReDasMapper(SPECS["redas"]).map_gemm(g)
    fixed = fixed_baseline_decision(SPECS["tpu"], g)
    assert redas.report.cycles <= fixed.report.cycles * 1.001


def test_decision_cache_reused():
    m = ReDasMapper(SPECS["redas"])
    g = GEMM(784, 256, 128)
    first = m.map_gemm(g)
    second = m.map_gemm(GEMM(784, 256, 128, count=3))
    assert second.candidates_evaluated == 0  # cache hit
    assert second.config == first.config
    assert second.report.cycles > first.report.cycles  # count-scaled


def test_baseline_spaces_restrict_configs():
    g = GEMM(43264, 144, 32)
    tpu = ReDasMapper(SPECS["tpu"]).map_gemm(g)
    assert tpu.config.shape.rows == tpu.config.shape.cols == 128
    assert tpu.config.dataflow == Dataflow.WS
    dyn = ReDasMapper(SPECS["dynnamic"]).map_gemm(g)
    assert dyn.config.dataflow == Dataflow.OS
    ReDasMapper(SPECS["planaria"]).map_gemm(g)  # restricted space still maps
    assert len(SPECS["planaria"].shapes) == 5


def test_flexibility_ordering_on_skinny_gemm():
    """Reshapable accelerators beat fixed arrays on the paper's
    case-study GEMM.  (Per-GEMM, Planaria's bypass-free 256x64 can edge
    out ReDas's 384x32 + roundabout cycles by a few percent — the paper's
    1.62x advantage over Planaria is a suite geomean, covered by fig11.)"""
    g = GEMM(43264, 144, 32)
    cycles = {name: ReDasMapper(SPECS[name]).map_gemm(g).report.cycles
              for name in ("tpu", "gemmini", "planaria", "redas")}
    assert cycles["redas"] < cycles["tpu"] * 0.6
    assert cycles["planaria"] < cycles["tpu"] * 0.6
    assert cycles["redas"] <= cycles["planaria"] * 1.10
    assert cycles["gemmini"] <= cycles["tpu"]


def test_space_size_scale():
    m = ReDasMapper(SPECS["redas"])
    assert m.space_size(GEMM(784, 256, 128)) > 1e10  # paper: >5.7e10


def test_array_size_sensitivity():
    """ReDas's advantage over the fixed array exists at every scale on
    matrix-vector GEMMs (the Fig. 18 geomean trend across whole DNNs is
    exercised by benchmarks/fig18_sensitivity.py)."""
    g = GEMM(1, 1024, 4096)
    for size in (16, 64, 128):
        specs = make_specs(size)
        t = ReDasMapper(specs["tpu"], array_size=size).map_gemm(g)
        r = ReDasMapper(specs["redas"], array_size=size).map_gemm(g)
        assert t.report.cycles / r.report.cycles > 1.5, size
