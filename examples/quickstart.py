"""Quickstart: the ReDas decision surface in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py

Both planes answer through ONE API now (`repro.engine`): a `CostModel`
turns a `KernelRequest` into a `KernelDecision`, an `ExecutionPlan`
caches decisions per shape, and a `KernelRegistry` backend executes
them.

1. Plane 1 — the paper's mapper (`AnalyticalCostModel`) plans a DNN
   layer's GEMM on the reconfigurable array vs a fixed 128x128 array.
2. Plane 2 — the same request against the TPU v5e roofline (`TPUModel`),
   then executed through the mapper-chosen Pallas schedule and checked
   numerically in interpret mode on CPU.
"""

import jax.numpy as jnp
import numpy as np

from repro.core.accelerators import SPECS
from repro.core.tpu_model import fixed_square_cost
from repro.engine import (AnalyticalCostModel, Engine, KernelRequest,
                          TPUModel)
from repro.kernels.ref import matmul_ref

req = KernelRequest("gemm", 43264, 144, 32, name="tinyyolo-v2/conv2")

# --- Plane 1: the paper's accelerator --------------------------------------
redas = AnalyticalCostModel(SPECS["redas"]).decide(req)
fixed = AnalyticalCostModel(SPECS["tpu"]).decide(req)
meta = redas.meta_dict
print(f"[plane 1] {req.name}: ReDas picks "
      f"{meta['shape_rows']}x{meta['shape_cols']} {redas.dataflow.upper()} "
      f"-> {fixed.seconds / redas.seconds:.2f}x vs fixed array "
      f"(PE util {meta['pe_utilization']:.0%} vs "
      f"{fixed.meta_dict['pe_utilization']:.0%})")

# --- Plane 2: the same request on the TPU v5e roofline ----------------------
tpu = TPUModel().decide(req)
fix = fixed_square_cost(req.m, req.k, req.n)
print(f"[plane 2] mapper picks {tpu.dataflow}({tpu.bm},{tpu.bk},{tpu.bn}) "
      f"-> {fix.seconds / tpu.seconds:.2f}x vs fixed 128^3 on v5e model")

# --- Execute through the engine (decision cache + registry dispatch) --------
eng = Engine(backend="pallas-interpret")   # CPU host: interpret-mode Pallas
rng = np.random.default_rng(0)
a = jnp.asarray(rng.normal(size=(256, 144)), jnp.float32)
b = jnp.asarray(rng.normal(size=(144, 32)), jnp.float32)
out = eng.matmul(a, b)
eng.matmul(a, b)  # repeated shape: served from the plan cache
err = float(jnp.abs(out - matmul_ref(a, b)).max())
print(f"[engine]  Pallas dispatch vs jnp oracle: max err {err:.2e}; "
      f"plan stats {eng.plan.stats}")
