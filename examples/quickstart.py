"""Quickstart: the ReDas decision surface in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py

1. Plane 1 — map a DNN layer's GEMM onto the reconfigurable array with
   the paper's mapper and compare against a fixed 128x128 TPU-like array.
2. Plane 2 — the same decision surface on TPU: mapper-chosen Pallas
   (dataflow, blocks) vs the fixed square schedule, validated numerically
   in interpret mode on CPU.
"""

import jax.numpy as jnp
import numpy as np

from repro.core.accelerators import SPECS
from repro.core.analytical_model import GEMM
from repro.core.mapper import ReDasMapper
from repro.core.tpu_model import choose_kernel_config, estimate, fixed_square_cost
from repro.kernels.ops import redas_matmul
from repro.kernels.ref import matmul_ref

# --- Plane 1: the paper's accelerator --------------------------------------
layer = GEMM(43264, 144, 32, name="tinyyolo-v2/conv2")  # Fig. 22 case study
redas = ReDasMapper(SPECS["redas"]).map_gemm(layer)
tpu = ReDasMapper(SPECS["tpu"]).map_gemm(layer)
print(f"[plane 1] {layer.name}: ReDas picks {redas.config.shape} "
      f"{redas.config.dataflow.value.upper()} "
      f"-> {tpu.report.cycles / redas.report.cycles:.2f}x vs fixed array "
      f"(PE util {redas.report.pe_utilization:.0%} vs "
      f"{tpu.report.pe_utilization:.0%})")

# --- Plane 2: the same idea as a Pallas schedule on TPU ---------------------
m, k, n = 43264, 144, 32
cfg = choose_kernel_config(m, k, n)
opt, fix = estimate(m, k, n, cfg), fixed_square_cost(m, k, n)
print(f"[plane 2] mapper picks {cfg.dataflow}({cfg.bm},{cfg.bk},{cfg.bn}) "
      f"-> {fix.seconds / opt.seconds:.2f}x vs fixed 128^3 on v5e model")

rng = np.random.default_rng(0)
a = jnp.asarray(rng.normal(size=(256, 144)), jnp.float32)
b = jnp.asarray(rng.normal(size=(144, 32)), jnp.float32)
out = redas_matmul(a, b, dataflow=cfg.dataflow, interpret=True)
err = float(jnp.abs(out - matmul_ref(a, b)).max())
print(f"[plane 2] Pallas kernel ({cfg.dataflow}) vs jnp oracle: "
      f"max err {err:.2e}")
