"""Serve a small model with batched requests: prefill + batched greedy
decode over the per-arch cache (full KV / ring / recurrent state).

    PYTHONPATH=src python examples/serve_tiny_lm.py [--arch mixtral-8x7b]
"""

import argparse

from repro.launch.serve import main as serve_main

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen2-1.5b")
args = ap.parse_args()

for arch in dict.fromkeys([args.arch, "mamba2-780m", "recurrentgemma-2b"]):
    print(f"=== {arch}")
    serve_main(["--arch", arch, "--smoke", "--batch", "4",
                "--prompt-len", "24", "--gen", "24"])

# the same launcher in continuous-batching mode: a ragged request trace
# served by serve_lib.scheduler.Scheduler over a 3-slot pool (mixed
# prompt lengths AND budgets — slots free up and readmit mid-flight)
print("=== continuous batching (request trace)")
serve_main(["--arch", args.arch, "--smoke", "--batch", "3",
            "--trace", "8x12,16x4*2,12x20,6x6"])
