"""End-to-end driver: train a tiny qwen2-family LM for a few hundred
steps with checkpointing, then resume.

    PYTHONPATH=src python examples/train_tiny_lm.py [--steps 300]
"""

import argparse
import tempfile

from repro.launch.train import main as train_main

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--arch", default="qwen2-1.5b")
args = ap.parse_args()

ckpt = tempfile.mkdtemp(prefix="redas_tiny_lm_")
half = args.steps // 2

print(f"=== phase 1: train to step {half}, checkpointing into {ckpt}")
train_main(["--arch", args.arch, "--smoke", "--steps", str(half),
            "--batch", "8", "--seq", "64", "--lr", "5e-3",
            "--microbatches", "2",
            "--ckpt-dir", ckpt, "--ckpt-every", "50"])

print("=== phase 2: resume (simulated restart after failure)")
out = train_main(["--arch", args.arch, "--smoke", "--steps",
                  str(args.steps), "--batch", "8", "--seq", "64",
                  "--lr", "5e-3", "--microbatches", "2",
                  "--ckpt-dir", ckpt, "--resume", "auto"])
print(f"final ce {out['final_ce']:.4f} (start {out['first_ce']:.4f})")
assert out["final_ce"] < out["first_ce"]
