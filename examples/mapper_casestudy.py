"""Fig. 22 reproduction as a runnable example: sweep every (logical
shape x dataflow) for four DNN layers and print the runtime landscape.

    PYTHONPATH=src python examples/mapper_casestudy.py
"""

from repro.core.accelerators import SPECS
from repro.core.analytical_model import GEMM
from repro.core.dataflow import pe_usage
from repro.core.mapper import ReDasMapper

LAYERS = [
    GEMM(43264, 144, 32, name="TinyYOLO-V2 L2"),
    GEMM(50, 3072, 768, name="ViT FFN2"),
    GEMM(128, 1024, 4096, name="BERT FFN1"),
    GEMM(1, 1024, 4096, name="GNMT cell"),
]

mapper = ReDasMapper(SPECS["redas"])
for g in LAYERS:
    # landscape: best runtime per (shape, dataflow)
    best: dict = {}
    for cand in mapper.candidates(g):
        rep = mapper.model.estimate(g, cand)
        if not rep.valid:
            continue
        key = (str(cand.shape), cand.dataflow.value)
        if key not in best or rep.cycles < best[key]:
            best[key] = rep.cycles
    top = sorted(best.items(), key=lambda kv: kv[1])[:5]
    worst = max(best.values())
    print(f"\n=== {g.name}  (M,K,N)=({g.M},{g.K},{g.N})  "
          f"{len(best)} configs")
    for (shape, df), cycles in top:
        r, c = (int(x) for x in shape.split("x"))
        from repro.core.dataflow import LogicalShape
        pe = pe_usage(LogicalShape(r, c), 128)
        print(f"  {shape:>9s} {df}  {cycles:12.0f} cycles  "
              f"({worst / cycles:5.1f}x vs worst, PE {pe:.0%})")
