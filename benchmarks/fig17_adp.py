"""Fig. 17: area-delay product vs TPU.  Paper: ReDas ~3.4x ADP reduction
vs TPU; ADP 68% lower than DyNNamic and 71% lower than SARA."""

from __future__ import annotations

from repro.core.accelerators import SPECS

from .common import ACCELERATORS, MODELS, csv_row, energy_for, geomean, timed


def compute() -> dict:
    return {
        acc: {m: energy_for(acc, m).adp(SPECS[acc].area_mm2) for m in MODELS}
        for acc in ACCELERATORS
    }


def main() -> list[str]:
    with timed() as t:
        adp = compute()
    rows = [csv_row(
        "fig17.redas_adp_reduction_vs_tpu", t.us,
        f"{geomean(adp['tpu'][m] / adp['redas'][m] for m in MODELS):.2f}x "
        f"(paper ~3.4x)")]
    for acc, paper in (("dynnamic", 68), ("sara", 71)):
        frac = geomean(1 - adp["redas"][m] / adp[acc][m] for m in MODELS
                       if adp["redas"][m] < adp[acc][m])
        rows.append(csv_row(f"fig17.redas_adp_lower_than_{acc}", 0,
                            f"{frac * 100:.0f}% (paper {paper}%)"))
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
