"""Fig. 12: power efficiency (throughput per watt) vs TPU.

Paper: ReDas 1.32-2.52x vs TPU; ~2.11x avg vs SARA; Gemmini beats ReDas
by ~1.13x on BERT-Large (big square GEMMs — ReDas's roundabout paths
only add energy there)."""

from __future__ import annotations

from repro.core.workloads import WORKLOADS

from .common import ACCELERATORS, MODELS, csv_row, energy_for, geomean, timed


def compute() -> dict:
    eff = {
        acc: {m: energy_for(acc, m).power_efficiency(
            sum(g.flops for g in WORKLOADS[m].gemms)) for m in MODELS}
        for acc in ACCELERATORS
    }
    rel = {acc: {m: eff[acc][m] / eff["tpu"][m] for m in MODELS}
           for acc in ACCELERATORS}
    return rel


def main() -> list[str]:
    with timed() as t:
        rel = compute()
    rows = [csv_row("fig12.redas_power_eff_geomean_vs_tpu", t.us,
                    f"{geomean(rel['redas'].values()):.2f}x (paper 1.32-2.52x)")]
    rows.append(csv_row(
        "fig12.redas_vs_sara", 0,
        f"{geomean(rel['redas'][m] / rel['sara'][m] for m in MODELS):.2f}x "
        f"(paper ~2.11x)"))
    rows.append(csv_row(
        "fig12.gemmini_vs_redas_bert", 0,
        f"{rel['gemmini']['BE'] / rel['redas']['BE']:.2f}x (paper ~1.13x)"))
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
