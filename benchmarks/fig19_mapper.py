"""Fig. 19: mapping time — interval sampling vs brute force.

Brute-force time is estimated as space_size x the *scalar oracle's*
measured per-candidate evaluation cost (the paper's brute force runs
took days-months of per-candidate CPU time; extrapolating from the
vectorized engine's amortized cost would understate them).  The interval
bars themselves are timed on the default batched engine, whose speedup
over the scalar loop is reported alongside.  Paper: ~10^6x reduction at
0.1-2% runtime loss; ~0.7s per GEMM workload; ResNet-50 space 2.8e10 ->
~1923 candidates."""

from __future__ import annotations

import time

from repro.core.accelerators import SPECS
from repro.core.analytical_model import GEMM
from repro.core.mapper import ReDasMapper
from repro.core.workloads import WORKLOADS

from .common import MODELS, csv_row, geomean, timed


def _scalar_per_candidate_s() -> float:
    """Measured cost of one scalar-oracle candidate evaluation."""
    mapper = ReDasMapper(SPECS["redas"], vectorized=False)
    t0 = time.time()
    dec = mapper.map_gemm(GEMM(784, 256, 128))
    return (time.time() - t0) / max(dec.candidates_evaluated, 1)


def compute() -> dict:
    out = {}
    per_eval = _scalar_per_candidate_s()
    for m in MODELS:
        mapper = ReDasMapper(SPECS["redas"])
        t0 = time.time()
        mapping = mapper.map_model(WORKLOADS[m].gemms)
        dt = time.time() - t0
        n_gemms = len(mapping.decisions)
        evals = sum(d.candidates_evaluated for d in mapping.decisions)
        space = sum(mapper.space_size(d.gemm) for d in mapping.decisions)
        brute_s = space * per_eval
        scalar_s = evals * per_eval  # the pre-vectorization interval cost
        # runtime loss vs a denser search (finer tile ladder + all orders)
        dense = ReDasMapper(SPECS["redas"], mode="exhaustive-orders",
                            free_dim_ratio=1.3)
        dense_cycles = dense.map_model(WORKLOADS[m].gemms).total_cycles
        loss = mapping.total_cycles / dense_cycles - 1.0
        out[m] = {
            "interval_s": dt, "per_gemm_s": dt / n_gemms,
            "evals": evals, "space": space,
            "speedup": brute_s / dt, "loss": loss,
            "batched_speedup": scalar_s / dt if dt else float("inf"),
        }
    return out


def main() -> list[str]:
    with timed() as t:
        r = compute()
    rows = [csv_row(
        "fig19.search_reduction_geomean", t.us,
        f"{geomean(r[m]['speedup'] for m in MODELS):.2e}x (paper ~1e6x)")]
    rows.append(csv_row(
        "fig19.per_gemm_seconds", 0,
        f"{geomean(r[m]['per_gemm_s'] for m in MODELS):.3f}s (paper ~0.7s)"))
    worst = max(r[m]["loss"] for m in MODELS)
    rows.append(csv_row("fig19.runtime_loss_vs_dense_search", 0,
                        f"{worst * 100:.2f}% worst (paper 0.1-2%)"))
    rows.append(csv_row("fig19.resnet_space_size", 0,
                        f"{r['RE']['space']:.2e} (paper 2.8e10+)"))
    rows.append(csv_row(
        "fig19.batched_engine_speedup_vs_scalar", 0,
        f"{geomean(r[m]['batched_speedup'] for m in MODELS):.0f}x"))
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
