"""Fig. 19: mapping time — interval sampling vs brute force.

Brute-force time is estimated as space_size x measured per-candidate
evaluation cost (the paper's brute force runs took days-months of CPU
time; ours would too, so we extrapolate exactly like their Fig. 19 bars
report CPU time).  Paper: ~10^6x reduction at 0.1-2% runtime loss; ~0.7s
per GEMM workload; ResNet-50 space 2.8e10 -> ~1923 candidates."""

from __future__ import annotations

import time

from repro.core.accelerators import SPECS
from repro.core.mapper import ReDasMapper
from repro.core.workloads import WORKLOADS

from .common import MODELS, csv_row, geomean, timed


def compute() -> dict:
    out = {}
    for m in MODELS:
        mapper = ReDasMapper(SPECS["redas"])
        t0 = time.time()
        mapping = mapper.map_model(WORKLOADS[m].gemms)
        dt = time.time() - t0
        n_gemms = len(mapping.decisions)
        evals = sum(d.candidates_evaluated for d in mapping.decisions)
        per_eval = dt / max(evals, 1)
        space = sum(mapper.space_size(d.gemm) for d in mapping.decisions)
        brute_s = space * per_eval
        # runtime loss vs a denser search (finer tile ladder + all orders)
        dense = ReDasMapper(SPECS["redas"], mode="exhaustive-orders",
                            free_dim_ratio=1.3)
        dense_cycles = dense.map_model(WORKLOADS[m].gemms).total_cycles
        loss = mapping.total_cycles / dense_cycles - 1.0
        out[m] = {
            "interval_s": dt, "per_gemm_s": dt / n_gemms,
            "evals": evals, "space": space,
            "speedup": brute_s / dt, "loss": loss,
        }
    return out


def main() -> list[str]:
    with timed() as t:
        r = compute()
    rows = [csv_row(
        "fig19.search_reduction_geomean", t.us,
        f"{geomean(r[m]['speedup'] for m in MODELS):.2e}x (paper ~1e6x)")]
    rows.append(csv_row(
        "fig19.per_gemm_seconds", 0,
        f"{geomean(r[m]['per_gemm_s'] for m in MODELS):.3f}s (paper ~0.7s)"))
    worst = max(r[m]["loss"] for m in MODELS)
    rows.append(csv_row("fig19.runtime_loss_vs_dense_search", 0,
                        f"{worst * 100:.2f}% worst (paper 0.1-2%)"))
    rows.append(csv_row("fig19.resnet_space_size", 0,
                        f"{r['RE']['space']:.2e} (paper 2.8e10+)"))
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
