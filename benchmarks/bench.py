"""Benchmark lane: batched mapper engine vs the scalar oracle + kernel benches.

Times the vectorized search (`ReDasMapper`, the default) against the
per-candidate scalar loop (`vectorized=False`) over the paper's Table-3
DNN traces *and* the GEMM traces of every assigned LM architecture in
``src/repro/configs``, plus kernel micro-benches (candidate-tensor
evaluation, plane-2 config search, batched tile simulation).  Emits
machine-readable ``BENCH_PR2.json`` rows ``{name, us_per_call,
speedup_vs_scalar}`` and enforces the regression gate: batched and
scalar chosen-mapping modeled cycles must agree per GEMM within 0.1%.

PR 3 adds the engine-dispatch-overhead microbench (``BENCH_PR3.json``):
a plan-cached `Engine.matmul` call must stay within 5% of the direct
kernel call (`engine.backends.pallas_gemm`) — the unified decision path
may not tax the hot dispatch.

    PYTHONPATH=src python -m benchmarks.bench [--smoke] [--out BENCH_PR2.json]
                                              [--out-engine BENCH_PR3.json]
                                              [--min-speedup 20]

Exit code: 0 iff the parity gate, the dispatch-overhead gate (and, when
given, --min-speedup) all hold.  The CI `bench` job runs ``--smoke`` and
uploads both JSON artifacts.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

PARITY_THRESHOLD = 1e-3  # 0.1% modeled-cycles divergence (the CI gate)
DISPATCH_OVERHEAD_THRESHOLD = 0.05  # engine vs direct kernel call (PR 3)
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Paper Table-3 workloads benched per mode (abbr); arch traces always cover
# every registered config — the acceptance gate spans src/repro/configs.
PAPER_MODELS = {"smoke": ("TY", "VI"), "full": None}  # None -> all


def _row(name: str, us_per_call: float, speedup) -> dict:
    return {"name": name, "us_per_call": round(us_per_call, 3),
            "speedup_vs_scalar": None if speedup is None else round(speedup, 3)}


def _bench_mapper_suite(traces: dict, results: list, parity: dict) -> list[float]:
    """Map every trace with both engines; record timing + per-GEMM parity."""
    from repro.core.accelerators import SPECS
    from repro.core.mapper import ReDasMapper

    speedups = []
    for name, gemms in traces.items():
        t0 = time.perf_counter()
        batched = ReDasMapper(SPECS["redas"]).map_model(gemms)
        t_b = time.perf_counter() - t0
        t0 = time.perf_counter()
        scalar = ReDasMapper(SPECS["redas"], vectorized=False).map_model(gemms)
        t_s = time.perf_counter() - t0
        div = max(
            abs(db.report.cycles - ds.report.cycles) / ds.report.cycles
            for db, ds in zip(batched.decisions, scalar.decisions,
                              strict=True))
        speedups.append(t_s / t_b)
        parity[name] = div
        results.append(_row(f"mapper/{name}", t_b * 1e6 / len(gemms), t_s / t_b))
        print(f"  mapper/{name:24s} batched {t_b * 1e3:8.1f} ms  "
              f"scalar {t_s * 1e3:9.1f} ms  {t_s / t_b:7.1f}x  "
              f"divergence {div:.2e}", flush=True)
    return speedups


def _bench_kernels(results: list, *, smoke: bool) -> None:
    """Micro-benches of the engines under the mapper (no parity gate)."""
    import numpy as np

    from repro.core.accelerators import SPECS
    from repro.core.analytical_model import GEMM
    from repro.core.mapper import ReDasMapper

    # candidate-tensor evaluation throughput (one full pruned space / call)
    g = GEMM(43264, 144, 32, name="tinyyolo_l2")
    mapper = ReDasMapper(SPECS["redas"])
    reps = 20 if smoke else 100
    t0 = time.perf_counter()
    for _ in range(reps):
        mapper._search_batched(g)
    us = (time.perf_counter() - t0) * 1e6 / reps
    n_cand = len(mapper.candidate_batch(g))
    results.append(_row(f"kernel/estimate_batch_{n_cand}cand", us, None))
    print(f"  kernel/estimate_batch      {us:9.1f} us/search "
          f"({n_cand} candidates)", flush=True)

    # plane-2 TPU mapper search (interval-sampled ladder, lru-cached)
    from repro.core.tpu_model import choose_kernel_config
    choose_kernel_config.cache_clear()
    t0 = time.perf_counter()
    choose_kernel_config(12544, 147, 64)
    us = (time.perf_counter() - t0) * 1e6
    results.append(_row("kernel/tpu_choose_config", us, None))
    print(f"  kernel/tpu_choose_config   {us:9.1f} us/search", flush=True)

    # batched cycle-level tile simulation vs a per-tile Python loop
    from repro.core.dataflow import Dataflow
    from repro.core.simulator import simulate_gemm, simulate_gemm_batch
    rng = np.random.default_rng(0)
    n_tiles, side = (16, 8) if smoke else (64, 16)
    a = rng.normal(size=(n_tiles, side, side))
    b = rng.normal(size=(n_tiles, side, side))
    simulate_gemm_batch(a, b, Dataflow.OS)  # jit warmup
    simulate_gemm(a[0], b[0], Dataflow.OS)
    t0 = time.perf_counter()
    simulate_gemm_batch(a, b, Dataflow.OS)[0].block_until_ready()
    t_b = time.perf_counter() - t0
    t0 = time.perf_counter()
    for i in range(n_tiles):
        simulate_gemm(a[i], b[i], Dataflow.OS)[0].block_until_ready()
    t_s = time.perf_counter() - t0
    results.append(_row(f"kernel/simulate_tiles_x{n_tiles}",
                        t_b * 1e6 / n_tiles, t_s / t_b))
    print(f"  kernel/simulate_tiles      {t_b * 1e6 / n_tiles:9.1f} us/tile  "
          f"{t_s / t_b:6.1f}x vs loop", flush=True)


def _bench_engine_dispatch(out_path: str, *, smoke: bool) -> bool:
    """Engine-dispatch overhead: plan-cached Engine.matmul vs the direct
    kernel entry point, same jit cache entry on both sides (the only
    per-call difference is the engine's memoized shape lookup, ~1-2 us).

    Methodology: paired per-call medians with alternating call order —
    loop-level best-of timing is bimodal on noisy shared CPUs.  The <=5%
    gate applies to workload-sized GEMMs (execution dominates, as in
    production); the dispatch-bound 8x128x128 shape is reported as an
    informational absolute-overhead row.  Writes BENCH_PR3.json; returns
    gate pass/fail."""
    import statistics

    import jax.numpy as jnp
    import numpy as np

    from repro.engine import Engine, KernelRequest
    from repro.engine.backends import pallas_gemm

    gated = [(128, 512, 512)] if smoke else [(128, 512, 512), (256, 512, 512)]
    shapes = ([(8, 128, 128, False), (64, 256, 256, False)]
              + [(m, k, n, True) for m, k, n in gated])
    pairs = 100 if smoke else 300
    rows = []
    print("engine dispatch overhead (plan-cached vs direct kernel call):",
          flush=True)
    for m, k, n, in_gate in shapes:
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
        eng = Engine(backend="pallas-interpret")
        dec = eng.decide(KernelRequest("gemm", m, k, n, in_bytes=4,
                                       out_bytes=4))

        def direct():
            return pallas_gemm(a, b, dataflow=dec.dataflow, bm=dec.bm,
                               bk=dec.bk, bn=dec.bn, interpret=True,
                               out_dtype=None)

        def engined():
            return eng.matmul(a, b)

        direct().block_until_ready()   # shared jit warmup
        engined().block_until_ready()
        t_d, t_e = [], []
        for i in range(pairs):
            order = (((direct, t_d), (engined, t_e)) if i % 2 == 0
                     else ((engined, t_e), (direct, t_d)))
            for fn, acc in order:
                t0 = time.perf_counter()
                fn().block_until_ready()
                acc.append(time.perf_counter() - t0)
        d_us = statistics.median(t_d) * 1e6
        e_us = statistics.median(t_e) * 1e6
        overhead = e_us / d_us - 1.0
        rows.append({
            "name": f"dispatch/{m}x{k}x{n}",
            "direct_us": round(d_us, 3),
            "engine_us": round(e_us, 3),
            "overhead": round(overhead, 4),
            "overhead_us": round(e_us - d_us, 3),
            "gated": in_gate,
        })
        print(f"  {m}x{k}x{n}: direct {d_us:8.1f} us  engine {e_us:8.1f} us "
              f" overhead {100 * overhead:+.2f}% ({e_us - d_us:+.1f} us)"
              f"{'' if in_gate else '  [informational]'}", flush=True)
    max_overhead = max(r["overhead"] for r in rows if r["gated"])
    ok = max_overhead <= DISPATCH_OVERHEAD_THRESHOLD
    payload = {
        "bench": "BENCH_PR3",
        "mode": "smoke" if smoke else "full",
        "results": rows,
        "gate": {"threshold": DISPATCH_OVERHEAD_THRESHOLD,
                 "max_overhead": max_overhead, "ok": ok,
                 "note": "gate spans workload-sized GEMMs; the tiny "
                         "dispatch-bound shape is informational"},
    }
    with open(out_path, "w") as fh:
        json.dump(payload, fh, indent=2)
        fh.write("\n")
    print(f"wrote {out_path}  (max gated overhead {100 * max_overhead:+.2f}%"
          f", gate {'ok' if ok else 'FAIL'})", flush=True)
    if not ok:
        print(f"FAIL: engine dispatch overhead {max_overhead:.3f} > "
              f"{DISPATCH_OVERHEAD_THRESHOLD}", file=sys.stderr)
    return ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: paper-model subset + smoke arch configs")
    ap.add_argument("--out", default=os.path.join(ROOT, "BENCH_PR2.json"))
    ap.add_argument("--out-engine", default=os.path.join(ROOT, "BENCH_PR3.json"))
    ap.add_argument("--min-speedup", type=float, default=0.0,
                    help="fail unless the geomean mapper speedup reaches this")
    ap.add_argument("--seq", type=int, default=None,
                    help="arch-trace prefill length (default 512, smoke 128)")
    args = ap.parse_args(argv)

    from repro.core.workloads import WORKLOADS, arch_traces

    mode = "smoke" if args.smoke else "full"
    papers = PAPER_MODELS[mode] or tuple(WORKLOADS)
    traces = {m: WORKLOADS[m].gemms for m in papers}
    seq = args.seq or (128 if args.smoke else 512)
    traces.update(arch_traces(smoke=args.smoke, seq_len=seq))

    results: list[dict] = []
    parity: dict[str, float] = {}
    print(f"bench ({mode}): {len(traces)} mapper traces", flush=True)
    speedups = _bench_mapper_suite(traces, results, parity)
    _bench_kernels(results, smoke=args.smoke)
    dispatch_ok = _bench_engine_dispatch(args.out_engine, smoke=args.smoke)

    geo = 1.0
    for s in speedups:
        geo *= s
    geo **= 1.0 / len(speedups)
    max_div = max(parity.values())
    gate_ok = max_div <= PARITY_THRESHOLD
    speed_ok = geo >= args.min_speedup
    payload = {
        "bench": "BENCH_PR2",
        "mode": mode,
        "results": results,
        "parity": {"threshold": PARITY_THRESHOLD, "max_divergence": max_div,
                   "per_model": {k: round(v, 9) for k, v in parity.items()},
                   "ok": gate_ok},
        "summary": {"mapper_speedup_geomean": round(geo, 2),
                    "min_speedup_gate": args.min_speedup or None,
                    "speedup_ok": speed_ok},
    }
    with open(args.out, "w") as fh:
        json.dump(payload, fh, indent=2)
        fh.write("\n")
    print(f"\nwrote {args.out}")
    print(f"mapper speedup geomean: {geo:.1f}x   max divergence: {max_div:.2e}")
    if not gate_ok:
        print(f"FAIL: batched-vs-scalar divergence {max_div:.2e} > "
              f"{PARITY_THRESHOLD}", file=sys.stderr)
    if not speed_ok:
        print(f"FAIL: speedup {geo:.1f}x < --min-speedup {args.min_speedup}",
              file=sys.stderr)
    return 0 if (gate_ok and speed_ok and dispatch_ok) else 1


if __name__ == "__main__":
    raise SystemExit(main())
