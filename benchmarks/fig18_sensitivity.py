"""Fig. 18: design-point sensitivity across PE-array scales 16..128.

ReDas-MD: multiple dataflows only (fixed square shape).
ReDas-FR: fine-grained reshaping only (WS dataflow).
ReDas-Both: both.  Paper @128: MD ~2.5x, FR ~3.5x, Both ~4.6x vs TPU,
with the advantage growing with array size."""

from __future__ import annotations

import dataclasses

from repro.core.accelerators import make_specs
from repro.core.dataflow import Dataflow, LogicalShape
from repro.core.energy import vector_cycles
from repro.core.mapper import ReDasMapper
from repro.core.workloads import WORKLOADS

from .common import MODELS, csv_row, geomean, timed

SIZES = (16, 32, 64, 128)


def _variants(size: int) -> dict:
    specs = make_specs(size)
    redas = specs["redas"]
    fixed = (LogicalShape(size, size),)
    return {
        "tpu": specs["tpu"],
        "ReDas-MD": dataclasses.replace(redas, shapes=fixed),
        "ReDas-FR": dataclasses.replace(redas, dataflows=(Dataflow.WS,)),
        "ReDas-Both": redas,
    }


def compute() -> dict:
    out: dict = {}
    for size in SIZES:
        variants = _variants(size)
        cyc = {
            name: {m: (ReDasMapper(spec, array_size=size)
                       .map_model(WORKLOADS[m].gemms).total_cycles
                       + vector_cycles(WORKLOADS[m].vector_elements))
                   for m in MODELS}
            for name, spec in variants.items()
        }
        out[size] = {
            name: geomean(cyc["tpu"][m] / cyc[name][m] for m in MODELS)
            for name in ("ReDas-MD", "ReDas-FR", "ReDas-Both")
        }
    return out


def main() -> list[str]:
    with timed() as t:
        r = compute()
    rows = []
    paper = {"ReDas-MD": 2.5, "ReDas-FR": 3.5, "ReDas-Both": 4.6}
    for name, p in paper.items():
        rows.append(csv_row(f"fig18.{name}@128", t.us if name == "ReDas-MD" else 0,
                            f"{r[128][name]:.2f}x (paper ~{p}x)"))
    trend = all(r[s]["ReDas-Both"] <= r[n]["ReDas-Both"] + 0.3
                for s, n in zip(SIZES, SIZES[1:], strict=False))
    rows.append(csv_row("fig18.rising_trend_with_size", 0,
                        f"{[round(r[s]['ReDas-Both'], 2) for s in SIZES]} "
                        f"monotone~{trend}"))
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
