"""Serving-throughput bench: continuous batching vs static padded batching.

A ragged request trace (mixed prompt lengths x mixed generation
budgets) is served two ways over the same model and slot count:

  static      the pre-scheduler host loop (`serve_lib.generate`): a
              batch admits only same-length prompts, every sequence in
              it decodes to the batch's LONGEST budget (finished slots
              burn compute), and the pool idles between batches —
              underfull same-length groups still pay full-pool compute.
  continuous  `serve_lib.scheduler.Scheduler`: per-slot cache clocks,
              ragged admits into free slots, one fixed-shape fused
              decode step, eviction on budget so freed slots readmit
              immediately.

Both serve greedy and must emit identical per-request tokens (checked).
A separate engine-posture pass serves the trace through a
`plan_arch(decode_batch=pool)`-warmed `repro.engine` and records the
decision-cache stats: after the warm-up steps the decode path must add
ZERO new plan misses (no per-step re-planning — the scheduler's decode
shapes never change).

Emits ``BENCH_PR4.json``; with ``--check`` exits nonzero unless
continuous beats static in useful tokens/s AND the engine steady state
is miss-free.

    PYTHONPATH=src python -m benchmarks.serve_bench --smoke --check \
        --out BENCH_PR4.json

``--shared-prefix`` switches to the PR-6 trace: every request shares a
long common prompt prefix and the SAME Scheduler serves it twice — once
with the contiguous per-slot cache, once with
``cache_layout="paged"`` where the radix prefix index lets later
requests reuse the already-prefilled prefix pages.  Emits
``BENCH_PR6.json``; ``--check`` gates paged >= 1.5x useful tokens/s,
exact greedy parity, prefill-token reuse > 1x, and a miss-free engine
steady state.

    PYTHONPATH=src python -m benchmarks.serve_bench --smoke --check \
        --shared-prefix --out BENCH_PR6.json

``--latency`` switches to the PR-10 latency-SLO trace (DESIGN.md §12):
steady decoders plus (long, short) arrival pairs, served with and
without ``ServeConfig.prefill_chunk``.  Reports p50/p99 time-to-first-
token and inter-token latency per mode; ``--check`` gates interactive
p99 TTFT improving >= 2x under chunking, exact greedy parity, and a
miss-free engine steady state (the chunk width is pre-planned).  Emits
``BENCH_PR10.json``.

    PYTHONPATH=src python -m benchmarks.serve_bench --smoke --check \
        --latency --out BENCH_PR10.json
"""

from __future__ import annotations

import argparse
import json
import sys
import time


def make_trace(smoke: bool) -> tuple[int, list[tuple[int, int]]]:
    """(pool_size, [(prompt_len, gen_len), ...]) — prompt lengths repeat
    across a few classes (so static batching gets real same-length
    groups to batch) while budgets stay ragged (so static still wastes
    decode on its max-budget padding)."""
    if smoke:
        pool = 3
        lens = [6, 10, 6, 14, 10, 6, 14, 10]
        gens = [8, 2, 5, 9, 3, 7, 2, 6]
    else:
        pool = 4
        lens = [8, 16, 8, 24, 16, 8, 24, 16, 8, 12, 12, 16, 8, 24, 12, 8]
        gens = [24, 4, 12, 20, 6, 28, 4, 16, 8, 24, 4, 12, 20, 6, 28, 10]
    return pool, list(zip(lens, gens, strict=True))


def make_shared_trace(smoke: bool) -> tuple[int, int, int, list[tuple[int, int]]]:
    """(pool, page_size, prefix_len, [(suffix_len, gen_len), ...]) —
    one long prompt prefix common to every request (a page-multiple, so
    the whole prefix is shareable full pages) plus short unique
    suffixes and small budgets: prefill dominates, which is exactly the
    work prefix sharing removes."""
    if smoke:
        pool, page, prefix = 3, 16, 384
        sufs = [5, 8, 6, 7, 5, 8, 6, 5, 7, 8, 6, 5]
        gens = [3, 2, 4, 2, 3, 2, 4, 3, 2, 3, 2, 4]
    else:
        pool, page, prefix = 4, 16, 448
        sufs = [5, 8, 6, 7, 5, 8, 6, 5, 7, 8, 6, 5, 8, 7, 6, 5]
        gens = [3, 2, 4, 2, 3, 2, 4, 3, 2, 3, 2, 4, 2, 3, 4, 2]
    return pool, page, prefix, list(zip(sufs, gens, strict=True))


def _build(arch: str, pool: int, max_seq: int, backend=None):
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.serve_lib import serve as serve_lib

    cfg = get_config(arch, smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    scfg = serve_lib.ServeConfig(max_seq=max_seq, batch=pool,
                                 compute_dtype=jnp.float32,
                                 cache_dtype=jnp.float32,
                                 kernel_backend=backend)
    return cfg, params, scfg


def _requests(cfg, trace):
    import numpy as np

    from repro.serve_lib.scheduler import Request

    rng = np.random.default_rng(0)
    return [Request(uid=i, prompt=rng.integers(0, cfg.vocab, p)
                    .astype(np.int32), max_new_tokens=g)
            for i, (p, g) in enumerate(trace)]


def _shared_requests(cfg, prefix_len: int, trace):
    """Shared-prefix request list: one common `prefix_len` prompt head,
    per-request unique suffixes (deterministic, so both layouts and the
    posture pass serve byte-identical traces)."""
    import numpy as np

    from repro.serve_lib.scheduler import Request

    rng = np.random.default_rng(0)
    prefix = rng.integers(0, cfg.vocab, prefix_len).astype(np.int32)
    reqs = []
    for i, (s, g) in enumerate(trace):
        suffix = rng.integers(0, cfg.vocab, s).astype(np.int32)
        reqs.append(Request(uid=i, prompt=np.concatenate([prefix, suffix]),
                            max_new_tokens=g))
    return reqs


def run_continuous(cfg, params, scfg, trace, bucket: int, reqs_fn=None):
    """Serve through the Scheduler; returns (report_row, {uid: tokens})."""
    from repro.serve_lib.scheduler import Scheduler

    make = reqs_fn or (lambda: _requests(cfg, trace))

    def serve_once():
        sched = Scheduler(params, cfg, scfg, prefill_bucket=bucket)
        t0 = time.time()
        comps = sched.run(make())
        return time.time() - t0, sched, comps

    serve_once()  # warm-up: jit compiles for the decode + admit widths
    dt, sched, comps = min((serve_once() for _ in range(3)),
                           key=lambda r: r[0])  # best-of-3 vs host noise
    tokens = sum(len(c.tokens) for c in comps.values())
    stats = dict(sched.stats)
    stats["prefill_widths"] = sorted(stats["prefill_widths"])
    row = {"seconds": round(dt, 4), "useful_tokens": tokens,
           "tokens_per_s": round(tokens / dt, 2), **stats}
    return row, {u: c.tokens.tolist() for u, c in comps.items()}


def run_static(cfg, params, scfg, trace):
    """The old static-batch loop: same-length groups of up to pool
    requests, each padded to the pool size and decoded to the group's
    max budget.  Returns (report_row, {uid: tokens})."""
    import numpy as np

    from repro.serve_lib import serve as serve_lib

    reqs = _requests(cfg, trace)
    groups: list[list] = []
    by_len: dict[int, list] = {}
    for r in reqs:  # arrival order, same-length batching, max size = pool
        g = by_len.setdefault(len(r.prompt), [])
        g.append(r)
        if len(g) == scfg.batch:
            groups.append(g)
            by_len[len(r.prompt)] = []
    groups.extend(g for g in by_len.values() if g)

    def serve_once():
        out: dict[int, list[int]] = {}
        decode_steps = 0
        t0 = time.time()
        for g in groups:
            prompts = np.stack([r.prompt for r in g])
            if len(g) < scfg.batch:  # underfull batch still pays full pool
                pad = np.repeat(prompts[-1:], scfg.batch - len(g), axis=0)
                prompts = np.concatenate([prompts, pad])
            budget = max(r.max_new_tokens for r in g)
            toks = np.asarray(serve_lib.generate(
                params, cfg, scfg, prompts, budget))
            decode_steps += budget - 1
            for i, r in enumerate(g):
                out[r.uid] = toks[i, : r.max_new_tokens].tolist()
        return time.time() - t0, decode_steps, out

    serve_once()  # warm-up
    dt, decode_steps, out = min((serve_once() for _ in range(3)),
                                key=lambda r: r[0])  # best-of-3
    tokens = sum(len(t) for t in out.values())
    row = {"seconds": round(dt, 4), "useful_tokens": tokens,
           "tokens_per_s": round(tokens / dt, 2),
           "batches": len(groups), "decode_steps": decode_steps,
           "decode_tokens": decode_steps * scfg.batch}
    return row, out


def run_engine_posture(arch, pool, max_seq, trace, bucket, warmup_steps=3):
    """Serve the trace through a warm-started engine; report decision-
    cache stats and the steady-state miss delta (must be 0)."""
    from repro import engine as engine_mod
    from repro.serve_lib.scheduler import Scheduler

    cfg, params, scfg = _build(arch, pool, max_seq, backend="xla-einsum")
    width = -(-max(p for p, _ in trace) // bucket) * bucket
    plan = engine_mod.plan_arch(
        cfg, seq_len=width, dtype_bytes=4, decode_batch=pool,
        admit_widths=tuple(range(bucket, width + 1, bucket)),
        backend="xla-einsum")
    eng = engine_mod.Engine(backend="xla-einsum", plan=plan)
    planned = len(plan)
    sched = Scheduler(params, cfg, scfg, engine=eng, prefill_bucket=bucket)
    for r in _requests(cfg, trace):
        sched.submit(r)
    for _ in range(warmup_steps):
        sched.step()
    warm = dict(plan.stats)
    while sched.queue or sched.n_active:
        sched.step()
    final = dict(plan.stats)
    return {
        "backend": "xla-einsum",
        "planned_decisions": planned,
        "after_warmup": warm,
        "final": final,
        # no per-step re-planning: every post-warm-up step hits the cache
        "steady_state_new_misses": final["misses"] - warm["misses"],
        "steady_state_new_hits": final["hits"] - warm["hits"],
    }


def run_engine_posture_paged(arch, pool, page, prefix_len, max_seq, trace,
                             bucket):
    """Serve the shared-prefix trace twice through ONE warm-started
    engine (paged layout): the first pass populates the runtime memo on
    top of the plan_arch(..., paged_pages=...) warm start, the second
    identical pass must add ZERO new plan misses — the paged-decode and
    shared-admit shapes are fully pre-decided."""
    import dataclasses

    from repro import engine as engine_mod
    from repro.serve_lib.scheduler import Scheduler

    cfg, params, scfg = _build(arch, pool, max_seq, backend="xla-einsum")
    scfg = dataclasses.replace(scfg, cache_layout="paged", page_size=page)
    width = -(-(prefix_len + max(s for s, _ in trace)) // bucket) * bucket
    plan = engine_mod.plan_arch(
        cfg, seq_len=width, dtype_bytes=4, decode_batch=pool,
        admit_widths=tuple(range(bucket, width + 1, bucket)),
        backend="xla-einsum",
        paged_pages=scfg.slot_pages, page_size=page)
    eng = engine_mod.Engine(backend="xla-einsum", plan=plan)
    planned = len(plan)
    reqs = lambda: _shared_requests(cfg, prefix_len, trace)
    Scheduler(params, cfg, scfg, engine=eng, prefill_bucket=bucket).run(reqs())
    warm = dict(plan.stats)
    Scheduler(params, cfg, scfg, engine=eng, prefill_bucket=bucket).run(reqs())
    final = dict(plan.stats)
    return {
        "backend": "xla-einsum",
        "planned_decisions": planned,
        "after_warmup": warm,
        "final": final,
        # a repeat serve of the same trace re-plans nothing
        "steady_state_new_misses": final["misses"] - warm["misses"],
        "steady_state_new_hits": final["hits"] - warm["hits"],
    }


def make_latency_trace(smoke: bool):
    """Adversarial prompt-length-mix trace for the chunked-prefill
    latency bench (DESIGN.md §12): a few STEADY decoders occupy slots
    for the whole horizon (their inter-token gaps are the head-of-line
    victims), while (long, short) request pairs arrive together at
    spaced ticks — the long prompt is the blocker, the short one is the
    interactive class whose time-to-first-token the chunked scheduler
    must protect.  Returns (pool, chunk, steady, long_len, short_len,
    pair_gens, arrival_ticks)."""
    if smoke:
        return 5, 16, [(8, 70)] * 3, 288, 8, (2, 8), [5, 30]
    return 6, 32, [(8, 140)] * 4, 448, 8, (2, 8), [5, 35, 65, 95]


def _latency_schedule(cfg, smoke: bool):
    """[(arrival_tick, Request)] for the latency trace; interactive
    (short-prompt) uids are >= 200."""
    import numpy as np

    from repro.serve_lib.scheduler import Request

    pool, chunk, steady, long_len, short_len, gens, ticks = \
        make_latency_trace(smoke)
    rng = np.random.default_rng(0)
    mk = lambda uid, n, g: Request(
        uid=uid, prompt=rng.integers(0, cfg.vocab, n).astype(np.int32),
        max_new_tokens=g)
    sched = [(0, mk(i, p, g)) for i, (p, g) in enumerate(steady)]
    for j, t in enumerate(ticks):
        sched.append((t, mk(100 + j, long_len, gens[0])))
        sched.append((t, mk(200 + j, short_len, gens[1])))
    return sched


def _serve_timed(sched, schedule):
    """Drive the scheduler tick by tick, submitting each request at its
    arrival tick, and timestamp every emitted token at the end of the
    tick that produced it (the step's np.asarray already synced the
    device).  Returns (submit_time, emit_times) keyed by uid."""
    import collections

    submit_time: dict[int, float] = {}
    emit_times: dict[int, list[float]] = collections.defaultdict(list)
    pending = sorted(schedule, key=lambda x: x[0])
    idx = 0
    while idx < len(pending) or sched.queue or sched.n_active:
        while idx < len(pending) and pending[idx][0] <= sched.step_count:
            req = pending[idx][1]
            submit_time[req.uid] = time.perf_counter()
            sched.submit(req)
            idx += 1
        fin = sched.step()
        now = time.perf_counter()
        counts = {s.req.uid: len(s.emitted)
                  for s in sched.slots if s is not None}
        counts.update({c.uid: len(c.tokens) for c in fin})
        for uid, n in counts.items():
            et = emit_times[uid]
            while len(et) < n:
                et.append(now)
    return submit_time, emit_times


def _latency_metrics(submit, emits):
    """p50/p99 TTFT (all + interactive class) and inter-token gaps."""
    import numpy as np

    ttft = {u: (emits[u][0] - submit[u]) * 1e3
            for u in submit if emits.get(u)}
    inter = [u for u in ttft if u >= 200]
    gaps = [(b - a) * 1e3 for ts in emits.values()
            for a, b in zip(ts, ts[1:])]
    pct = lambda xs, q: float(np.percentile(np.asarray(xs), q))
    return {
        "ttft_p50_ms": round(pct(list(ttft.values()), 50), 3),
        "ttft_p99_ms": round(pct(list(ttft.values()), 99), 3),
        "interactive_ttft_p50_ms": round(
            pct([ttft[u] for u in inter], 50), 3),
        "interactive_ttft_p99_ms": round(
            pct([ttft[u] for u in inter], 99), 3),
        "inter_token_p50_ms": round(pct(gaps, 50), 3),
        "inter_token_p99_ms": round(pct(gaps, 99), 3),
    }


def run_latency_mode(cfg, params, scfg, smoke: bool, bucket: int):
    """Serve the latency schedule 1 warm-up + 3 timed times; per-metric
    median across the timed runs (wall-clock noise), plus token parity
    data from the last run."""
    import numpy as np

    from repro.serve_lib.scheduler import Scheduler

    runs = []
    toks = None
    for it in range(4):
        sched = Scheduler(params, cfg, scfg, prefill_bucket=bucket)
        submit, emits = _serve_timed(sched, _latency_schedule(cfg, smoke))
        if it:  # run 0 is the jit warm-up
            runs.append(_latency_metrics(submit, emits))
        toks = {u: c.tokens.tolist() for u, c in sched.completions.items()}
    med = {k: round(float(np.median([r[k] for r in runs])), 3)
           for k in runs[0]}
    return med, toks


def run_engine_posture_chunked(arch, pool, max_seq, chunk, bucket, smoke):
    """Serve the latency schedule twice through ONE engine warm-started
    with `plan_arch(..., prefill_chunk=...)`: the second pass must add
    ZERO new plan misses — chunked ingestion introduces exactly one new
    width (the chunk), and the plan pre-decides it."""
    import dataclasses

    from repro import engine as engine_mod
    from repro.serve_lib.scheduler import Scheduler

    cfg, params, scfg = _build(arch, pool, max_seq, backend="xla-einsum")
    scfg = dataclasses.replace(scfg, prefill_chunk=chunk)
    plan = engine_mod.plan_arch(
        cfg, seq_len=chunk, dtype_bytes=4, decode_batch=pool,
        admit_widths=tuple(range(bucket, chunk + 1, bucket)),
        backend="xla-einsum", prefill_chunk=chunk)
    eng = engine_mod.Engine(backend="xla-einsum", plan=plan)
    planned = len(plan)
    reqs = lambda: [r for _, r in _latency_schedule(cfg, smoke)]
    Scheduler(params, cfg, scfg, engine=eng, prefill_bucket=bucket).run(reqs())
    warm = dict(plan.stats)
    Scheduler(params, cfg, scfg, engine=eng, prefill_bucket=bucket).run(reqs())
    final = dict(plan.stats)
    return {
        "backend": "xla-einsum",
        "planned_decisions": planned,
        "after_warmup": warm,
        "final": final,
        "steady_state_new_misses": final["misses"] - warm["misses"],
        "steady_state_new_hits": final["hits"] - warm["hits"],
    }


def run_latency(args) -> tuple[dict, list[str]]:
    """PR-10 mode: chunked vs unchunked ingestion on the adversarial
    prompt-mix trace; gates p99 TTFT of the interactive class."""
    import dataclasses

    pool, chunk, steady, long_len, short_len, gens, ticks = \
        make_latency_trace(args.smoke)
    max_seq = max(long_len + gens[0], short_len + gens[1],
                  max(p + g for p, g in steady)) + 1
    cfg, params, scfg = _build(args.arch, pool, max_seq)
    scfg_chunked = dataclasses.replace(scfg, prefill_chunk=chunk)

    unchunked, un_toks = run_latency_mode(cfg, params, scfg, args.smoke,
                                          args.prefill_bucket)
    chunked, ch_toks = run_latency_mode(cfg, params, scfg_chunked,
                                        args.smoke, args.prefill_bucket)
    parity = un_toks == ch_toks
    engine = run_engine_posture_chunked(args.arch, pool, max_seq, chunk,
                                        args.prefill_bucket, args.smoke)

    report = {
        "bench": "serve_chunked_latency",
        "arch": args.arch, "smoke": args.smoke, "pool_slots": pool,
        "prefill_chunk": chunk,
        "trace": {"steady": steady, "long_len": long_len,
                  "short_len": short_len, "pair_gens": list(gens),
                  "arrival_ticks": ticks},
        "unchunked": unchunked,
        "chunked": chunked,
        # host-invariant same-run ratios (trend-gated): how much
        # head-of-line blocking the chunked scheduler removes
        "p99_ttft_ratio": round(
            unchunked["interactive_ttft_p99_ms"]
            / chunked["interactive_ttft_p99_ms"], 3),
        "inter_token_ratio": round(
            unchunked["inter_token_p99_ms"]
            / chunked["inter_token_p99_ms"], 3),
        "greedy_parity": parity,
        "engine": engine,
    }

    failures = []
    if not parity:
        failures.append("chunked and unchunked emitted different tokens")
    if args.check:
        if report["p99_ttft_ratio"] < 2.0:
            failures.append(
                f"chunked prefill did not improve interactive p99 TTFT "
                f">= 2x ({report['p99_ttft_ratio']}x)")
        if engine["steady_state_new_misses"] != 0:
            failures.append(
                f"chunked serve re-planned after warm-up "
                f"({engine['steady_state_new_misses']} new misses)")
    return report, failures


def run_shared_prefix(args) -> tuple[dict, list[str]]:
    """PR-6 mode: contiguous vs paged Scheduler on a shared-prefix
    trace.  Returns (report, check_failures)."""
    import dataclasses

    pool, page, prefix_len, trace = make_shared_trace(args.smoke)
    max_seq = prefix_len + max(s + g for s, g in trace) + 1
    cfg, params, scfg = _build(args.arch, pool, max_seq)
    scfg_paged = dataclasses.replace(scfg, cache_layout="paged",
                                     page_size=page)
    reqs = lambda: _shared_requests(cfg, prefix_len, trace)

    cont, cont_toks = run_continuous(cfg, params, scfg, trace,
                                     args.prefill_bucket, reqs_fn=reqs)
    paged, paged_toks = run_continuous(cfg, params, scfg_paged, trace,
                                       args.prefill_bucket, reqs_fn=reqs)
    parity = all(paged_toks[u] == cont_toks[u] for u in cont_toks)
    engine = run_engine_posture_paged(args.arch, pool, page, prefix_len,
                                      max_seq, trace, args.prefill_bucket)

    report = {
        "bench": "serve_paged_shared_prefix",
        "arch": args.arch, "smoke": args.smoke, "pool_slots": pool,
        "page_size": page, "prefix_len": prefix_len, "trace": trace,
        "contiguous": cont,
        "paged": paged,
        "speedup_tokens_per_s": round(
            paged["tokens_per_s"] / cont["tokens_per_s"], 3),
        # host-invariant: prefilled-token counts, not wall clock
        "prefix_reuse_ratio": round(
            cont["prefill_tokens"] / paged["prefill_tokens"], 3),
        "greedy_parity": parity,
        "engine": engine,
    }

    failures = []
    if not parity:
        failures.append("paged and contiguous emitted different tokens")
    if args.check:
        if report["speedup_tokens_per_s"] < 1.5:
            failures.append(
                f"paged did not reach 1.5x over contiguous "
                f"({report['speedup_tokens_per_s']}x)")
        if report["prefix_reuse_ratio"] <= 1.0:
            failures.append(
                f"prefix sharing saved no prefill tokens "
                f"(reuse ratio {report['prefix_reuse_ratio']})")
        if engine["steady_state_new_misses"] != 0:
            failures.append(
                f"paged serve re-planned after warm-up "
                f"({engine['steady_state_new_misses']} new misses)")
    return report, failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--prefill-bucket", type=int, default=8)
    ap.add_argument("--shared-prefix", action="store_true",
                    help="PR-6 mode: contiguous vs paged cache layout on "
                         "a shared-prefix trace (emits BENCH_PR6.json)")
    ap.add_argument("--latency", action="store_true",
                    help="PR-10 mode: chunked vs unchunked prefill on an "
                         "adversarial prompt-length mix, reporting p50/"
                         "p99 TTFT + inter-token latency (emits "
                         "BENCH_PR10.json)")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless continuous wins and the "
                         "engine steady state re-plans nothing")
    args = ap.parse_args(argv)
    if args.shared_prefix and args.latency:
        ap.error("--shared-prefix and --latency are separate modes")
    if args.out is None:
        args.out = ("BENCH_PR10.json" if args.latency
                    else "BENCH_PR6.json" if args.shared_prefix
                    else "BENCH_PR4.json")

    if args.shared_prefix or args.latency:
        report, failures = (run_latency(args) if args.latency
                            else run_shared_prefix(args))
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1, sort_keys=True)
            f.write("\n")
        print(json.dumps(report, indent=1, sort_keys=True))
        for msg in failures:
            print(f"FAIL: {msg}", file=sys.stderr)
        return len(failures)

    pool, trace = make_trace(args.smoke)
    max_seq = max(p + g for p, g in trace) + 1
    cfg, params, scfg = _build(args.arch, pool, max_seq)

    cont, cont_toks = run_continuous(cfg, params, scfg, trace,
                                     args.prefill_bucket)
    stat, stat_toks = run_static(cfg, params, scfg, trace)
    parity = all(cont_toks[u] == stat_toks[u] for u in cont_toks)
    engine = run_engine_posture(args.arch, pool, max_seq, trace,
                                args.prefill_bucket)

    report = {
        "bench": "serve_continuous_vs_static",
        "arch": args.arch, "smoke": args.smoke, "pool_slots": pool,
        "trace": trace,
        "continuous": cont,
        "static": stat,
        "speedup_tokens_per_s": round(
            cont["tokens_per_s"] / stat["tokens_per_s"], 3),
        "greedy_parity": parity,
        "engine": engine,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
        f.write("\n")
    print(json.dumps(report, indent=1, sort_keys=True))

    failures = []
    if not parity:
        failures.append("continuous and static emitted different tokens")
    if args.check:
        if report["speedup_tokens_per_s"] <= 1.0:
            failures.append(
                f"continuous batching did not beat static "
                f"({report['speedup_tokens_per_s']}x)")
        if engine["steady_state_new_misses"] != 0:
            failures.append(
                f"decode path re-planned after warm-up "
                f"({engine['steady_state_new_misses']} new misses)")
    for msg in failures:
        print(f"FAIL: {msg}", file=sys.stderr)
    return len(failures)


if __name__ == "__main__":
    sys.exit(main())
