"""Fig. 11: speedup of each accelerator over TPU on the 8 DNN workloads.

Paper claims: ReDas ~4.6x geomean vs TPU; ~2.31x vs Gemmini, ~1.62x vs
Planaria, ~1.83x vs DyNNamic, ~parity with SARA; DeepSpeech2 8.19x,
GNMT 5.66x, ViT 6.01x vs TPU."""

from __future__ import annotations

from .common import (ACCELERATORS, MODELS, csv_row, geomean, timed,
                     total_runtime_cycles)


def compute() -> dict:
    base = {m: total_runtime_cycles("tpu", m) for m in MODELS}
    table = {
        acc: {m: base[m] / total_runtime_cycles(acc, m) for m in MODELS}
        for acc in ACCELERATORS
    }
    summary = {acc: geomean(table[acc].values()) for acc in ACCELERATORS}
    return {"per_model": table, "geomean": summary}


def main() -> list[str]:
    with timed() as t:
        r = compute()
    rows = []
    g = r["geomean"]
    rows.append(csv_row("fig11.redas_geomean_speedup_vs_tpu", t.us,
                        f"{g['redas']:.2f}x (paper 4.6x)"))
    for acc in ("gemmini", "planaria", "dynnamic", "sara"):
        rows.append(csv_row(f"fig11.redas_vs_{acc}", 0,
                            f"{g['redas'] / g[acc]:.2f}x"))
    for m in MODELS:
        rows.append(csv_row(f"fig11.redas_speedup.{m}", 0,
                            f"{r['per_model']['redas'][m]:.2f}x"))
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
