"""Fig. 14: PE utilization per accelerator x DNN.

Paper: ReDas 4.79x TPU, 1.67x Planaria, 2.42x Gemmini on average; RNNs
(GNMT, DeepSpeech2) lowest absolute utilization (matrix-vector GEMMs)."""

from __future__ import annotations

from .common import ACCELERATORS, MODELS, csv_row, geomean, mapping_for, timed


def compute() -> dict:
    return {acc: {m: mapping_for(acc, m).pe_utilization(128) for m in MODELS}
            for acc in ACCELERATORS}


def main() -> list[str]:
    with timed() as t:
        u = compute()
    rows = []
    for ref, paper in (("tpu", 4.79), ("planaria", 1.67), ("gemmini", 2.42)):
        g = geomean(u["redas"][m] / u[ref][m] for m in MODELS)
        rows.append(csv_row(f"fig14.redas_util_vs_{ref}", t.us if ref == "tpu" else 0,
                            f"{g:.2f}x (paper {paper}x)"))
    for m in MODELS:
        rows.append(csv_row(f"fig14.redas_util.{m}", 0,
                            f"{u['redas'][m]:.3f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
