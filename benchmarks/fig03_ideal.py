"""Fig. 3: potential of ideal per-layer shape/dataflow adaptation.

Four situations, all idealized (no reshaping cost, no reconfig cycles):
  Fixed            128x128, WS
  Ideal dataflow   128x128, WS/OS/IS per layer
  Ideal shape      any shape with <= 128^2 PEs, WS
  Ideal both       any shape x any dataflow

Paper claim: >6.3x for EfficientNet-B0 with ideal shape & dataflow."""

from __future__ import annotations

import dataclasses

from repro.core.accelerators import SPECS, AcceleratorSpec
from repro.core.dataflow import ALL_DATAFLOWS, Dataflow, LogicalShape
from repro.core.mapper import ReDasMapper
from repro.core.workloads import WORKLOADS

from .common import MODELS, csv_row, geomean, timed


def _ideal_shapes(budget: int = 128 * 128) -> tuple[LogicalShape, ...]:
    """All (r, c) with r*c <= budget on a geometric grid (the paper explores
    all combinations; the grid keeps search tractable at <2% loss)."""
    sides = [1, 2, 4, 8, 16, 24, 32, 48, 64, 96, 128, 192, 256, 384, 512,
             768, 1024, 2048, 4096, 8192, 16384]
    out = []
    for r in sides:
        for c in sides:
            if r * c <= budget:
                out.append(LogicalShape(r, c))
    return tuple(out)


def _spec(name: str, dataflows, shapes) -> AcceleratorSpec:
    return dataclasses.replace(
        SPECS["tpu"], name=name, dataflows=tuple(dataflows),
        shapes=tuple(shapes), config_cycles=0, bypass_enabled=False)


def compute() -> dict:
    fixed_shape = (LogicalShape(128, 128),)
    specs = {
        "fixed": _spec("fixed", (Dataflow.WS,), fixed_shape),
        "ideal_dataflow": _spec("ideal-df", ALL_DATAFLOWS, fixed_shape),
        "ideal_shape": _spec("ideal-sh", (Dataflow.WS,), _ideal_shapes()),
        "ideal_both": _spec("ideal-both", ALL_DATAFLOWS, _ideal_shapes()),
    }
    out: dict = {}
    for m in MODELS:
        gemms = WORKLOADS[m].gemms
        cycles = {k: ReDasMapper(s).map_model(gemms).total_cycles
                  for k, s in specs.items()}
        out[m] = {k: cycles["fixed"] / v for k, v in cycles.items()}
    return out


def main() -> list[str]:
    with timed() as t:
        r = compute()
    rows = [csv_row("fig03.efficientnet_ideal_both", t.us,
                    f"{r['EF']['ideal_both']:.2f}x (paper >6.3x)")]
    for k in ("ideal_dataflow", "ideal_shape", "ideal_both"):
        rows.append(csv_row(f"fig03.geomean.{k}", 0,
                            f"{geomean(r[m][k] for m in MODELS):.2f}x"))
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
