"""Fig. 16: energy-delay product vs TPU (lower better; we report the TPU/
ReDas ratio as 'reduction').  Paper: ReDas ~8.3x EDP reduction vs TPU,
~2.0x avg (up to 3.3x) vs SARA."""

from __future__ import annotations

from .common import ACCELERATORS, MODELS, csv_row, energy_for, geomean, timed


def compute() -> dict:
    edp = {acc: {m: energy_for(acc, m).edp for m in MODELS}
           for acc in ACCELERATORS}
    return edp


def main() -> list[str]:
    with timed() as t:
        edp = compute()
    rows = [csv_row(
        "fig16.redas_edp_reduction_vs_tpu", t.us,
        f"{geomean(edp['tpu'][m] / edp['redas'][m] for m in MODELS):.2f}x "
        f"(paper ~8.3x)")]
    rows.append(csv_row(
        "fig16.redas_edp_reduction_vs_sara", 0,
        f"{geomean(edp['sara'][m] / edp['redas'][m] for m in MODELS):.2f}x "
        f"(paper ~2.0x)"))
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
