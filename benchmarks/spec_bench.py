"""Speculative-decoding bench: accepted-tokens/s vs the plain decode path.

A decode-heavy request trace (short prompts, long generation budgets —
the regime where every emitted token costs one fused decode dispatch)
is served twice over the same model, slot pool and admit bucketing:

  baseline     the PR-4 continuous-batching Scheduler: one fused
               1-wide decode step per emitted token per tick.
  speculative  ``ServeConfig(speculate_k=K)``: each tick drafts K
               tokens (one jitted K-step scan), scores all K+1
               positions in ONE fused verify pass, and commits the
               longest matching prefix via per-slot clock rollback —
               so a tick emits up to K+1 tokens per slot in three
               dispatches instead of K+1.

Speculation is greedy-only and must emit byte-identical tokens to the
baseline (checked — the accept rule keeps every token the target model
itself would have picked).  The headline gate is the wall-clock
``accepted_tokens_ratio`` — speculative useful tokens/s over baseline
useful tokens/s, same machine, same run — which must reach 1.3x.
``accept_rate`` (accepted draft tokens / drafted tokens) is also
reported and trend-gated; with the default self-draft it is exact.

A separate engine-posture pass serves the trace twice through ONE
``plan_arch(..., verify_k=K)``-warmed engine: the second pass must add
ZERO new plan misses (the K+1-wide verify shape is pre-declared, so
the speculative steady state never re-plans).

Emits ``BENCH_PR7.json``; with ``--check`` exits nonzero on any gate.

    PYTHONPATH=src python -m benchmarks.spec_bench --smoke --check \
        --out BENCH_PR7.json
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys

from benchmarks.serve_bench import _build, _requests, run_continuous


def make_trace(smoke: bool) -> tuple[int, list[tuple[int, int]]]:
    """(pool_size, [(prompt_len, gen_len), ...]) — short prompts with
    long ragged budgets, so decode ticks dominate wall clock and the
    draft/verify plane has room to compress them."""
    if smoke:
        pool = 3
        lens = [6, 8, 6, 10, 8, 6]
        gens = [16, 12, 20, 14, 18, 12]
    else:
        pool = 4
        lens = [8, 12, 8, 16, 12, 8, 16, 12, 8, 12]
        gens = [32, 24, 40, 28, 36, 24, 32, 40, 28, 36]
    return pool, list(zip(lens, gens, strict=True))


def run_engine_posture_spec(arch, pool, max_seq, trace, bucket, k, draft):
    """Serve the trace twice through ONE warm-started engine with the
    speculative posture on: ``plan_arch(..., verify_k=k)`` pre-declares
    the K+1-wide verify GEMMs next to the 1-wide decode and the admit
    widths, so the second identical pass must add ZERO new plan
    misses."""
    from repro import engine as engine_mod
    from repro.serve_lib.scheduler import Scheduler

    cfg, params, scfg = _build(arch, pool, max_seq, backend="xla-einsum")
    scfg = dataclasses.replace(scfg, speculate_k=k, draft=draft)
    width = -(-max(p for p, _ in trace) // bucket) * bucket
    plan = engine_mod.plan_arch(
        cfg, seq_len=width, dtype_bytes=4, decode_batch=pool,
        admit_widths=tuple(range(bucket, width + 1, bucket)),
        verify_k=k, backend="xla-einsum")
    eng = engine_mod.Engine(backend="xla-einsum", plan=plan)
    planned = len(plan)
    reqs = lambda: _requests(cfg, trace)
    Scheduler(params, cfg, scfg, engine=eng, prefill_bucket=bucket).run(reqs())
    warm = dict(plan.stats)
    Scheduler(params, cfg, scfg, engine=eng, prefill_bucket=bucket).run(reqs())
    final = dict(plan.stats)
    return {
        "backend": "xla-einsum",
        "planned_decisions": planned,
        "after_warmup": warm,
        "final": final,
        # draft, verify and admit shapes are all pre-declared: a repeat
        # serve of the same trace re-plans nothing
        "steady_state_new_misses": final["misses"] - warm["misses"],
        "steady_state_new_hits": final["hits"] - warm["hits"],
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="BENCH_PR7.json")
    ap.add_argument("--prefill-bucket", type=int, default=8)
    ap.add_argument("--speculate", type=int, default=4, metavar="K",
                    help="draft tokens per tick (verify width K+1)")
    ap.add_argument("--draft", default="self", choices=("self", "self-int8"),
                    help="draft model: 'self' shares the target params "
                         "(accept rate 1 under greedy), 'self-int8' drafts "
                         "with an int8-quantized copy")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless speculation reaches 1.3x "
                         "accepted tokens/s with exact greedy parity and "
                         "a miss-free engine steady state")
    args = ap.parse_args(argv)
    k = args.speculate

    pool, trace = make_trace(args.smoke)
    # same max_seq both ways: the verify pass writes k rows past the
    # last accepted token, and parity needs identical cache geometry
    max_seq = max(p + g for p, g in trace) + k + 1
    cfg, params, scfg = _build(args.arch, pool, max_seq)
    scfg_spec = dataclasses.replace(scfg, speculate_k=k, draft=args.draft)

    base, base_toks = run_continuous(cfg, params, scfg, trace,
                                     args.prefill_bucket)
    spec, spec_toks = run_continuous(cfg, params, scfg_spec, trace,
                                     args.prefill_bucket)
    parity = all(spec_toks[u] == base_toks[u] for u in base_toks)
    engine = run_engine_posture_spec(args.arch, pool, max_seq, trace,
                                     args.prefill_bucket, k, args.draft)

    report = {
        "bench": "serve_speculative_decode",
        "arch": args.arch, "smoke": args.smoke, "pool_slots": pool,
        "speculate_k": k, "draft": args.draft, "trace": trace,
        "baseline": base,
        "speculative": spec,
        # wall-clock headline: useful (accepted) tokens/s, same run
        "accepted_tokens_ratio": round(
            spec["tokens_per_s"] / base["tokens_per_s"], 3),
        # host-invariant: how many drafted tokens the verify pass kept
        "accept_rate": round(
            spec["accepted_draft_tokens"] / max(1, spec["draft_tokens"]), 4),
        "greedy_parity": parity,
        "engine": engine,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
        f.write("\n")
    print(json.dumps(report, indent=1, sort_keys=True))

    failures = []
    if not parity:
        failures.append("speculative and baseline emitted different tokens")
    if args.check:
        if report["accepted_tokens_ratio"] < 1.3:
            failures.append(
                f"speculation did not reach 1.3x accepted tokens/s "
                f"({report['accepted_tokens_ratio']}x)")
        if report["accept_rate"] <= 0.0:
            failures.append("verify pass accepted no draft tokens")
        if engine["steady_state_new_misses"] != 0:
            failures.append(
                f"speculative serve re-planned after warm-up "
                f"({engine['steady_state_new_misses']} new misses)")
    for msg in failures:
        print(f"FAIL: {msg}", file=sys.stderr)
    return len(failures)


if __name__ == "__main__":
    sys.exit(main())
