"""Render the §Roofline / §Dry-run tables from runs/dryrun JSONs.

    PYTHONPATH=src python -m benchmarks.roofline_table [--mesh single]
"""

from __future__ import annotations

import argparse
import json
import os

RUNS = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "runs", "dryrun")

NOTE = {
    "compute": "more useful FLOPs/step: raise per-device batch or cut remat",
    "memory": "cut HBM streams: fuse attention (Pallas kernel) / bf16 interms",
    "collective": "cut resharding: fewer grad-accum trips / better placement",
}


def rows(mesh: str):
    d = os.path.join(RUNS, mesh)
    for f in sorted(os.listdir(d)):
        with open(os.path.join(d, f)) as fh:
            yield json.load(fh)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=("single", "multi"))
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    sep = " | " if args.markdown else "  "
    hdr = ["arch", "shape", "status", "compute_s", "memory_s", "coll_s",
           "bottleneck", "MODEL/HLO", "roofline%"]
    if args.markdown:
        print("| " + " | ".join(hdr) + " |")
        print("|" + "---|" * len(hdr))
    else:
        print(sep.join(hdr))
    for r in rows(args.mesh):
        if r["status"] != "ok":
            cells = [r["arch"], r["shape"], f"SKIP: {r.get('reason', '?')}",
                     "", "", "", "", "", ""]
        else:
            rl = r["roofline"]
            cells = [
                r["arch"], r["shape"], "ok",
                f"{rl['compute_s']:.4g}", f"{rl['memory_s']:.4g}",
                f"{rl['collective_s']:.4g}", rl["bottleneck"],
                f"{rl['useful_flops_ratio']:.3f}",
                f"{100 * rl['roofline_fraction']:.2f}%",
            ]
        if args.markdown:
            print("| " + " | ".join(str(c) for c in cells) + " |")
        else:
            print(sep.join(str(c) for c in cells))


if __name__ == "__main__":
    main()
