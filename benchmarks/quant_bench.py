"""Quantization-plane bench: int8 KV cache + int8 matmul backends vs bf16.

A mixed-length greedy trace (serve_bench's smoke trace) is served by the
continuous-batching scheduler in three postures over the same weights:

  baseline   bf16 KV cache, float matmuls (no engine).
  kv-int8    ``cache_dtype="int8"`` — quantized KV rows + per-row scales
             (quant.kv_quantize); everything else identical.  Gated on
             EXACT greedy token parity with the baseline: the codec's
             ~0.4% row error must not flip any token on the smoke trace.
  full-int8  ``ServeConfig(quantize=True)`` + `quant.quantize_params`
             weights + int8 cache: every dense matmul dispatches the
             engine's `gemm_w8` int8 kernel.  Reported as STEPWISE top-1
             agreement (sequences may legally diverge after a near-tie
             flip cascades); soft-gated at >= 0.5.

An engine-posture pass serves the full-int8 trace through a
`plan_arch(..., quantized_weights=True)`-warmed int8 engine and gates
zero steady-state plan misses — the int8 backend dispatches through the
engine, and after warm-up the decode path re-plans nothing.

The bench model is the smoke arch with a production head_dim (64): the
cache-byte ratio is a *layout* property, 2 / (1 + 4/head_dim) per
element, and the smoke configs' head_dim=16 would understate what any
real config gets (gemma/qwen/mistral all serve head_dim >= 64).

Emits ``BENCH_PR5.json``; with ``--check`` exits nonzero unless the
cache shrinks >= 1.8x, kv-int8 greedy parity is exact, the full posture
agrees >= 0.5 stepwise, and the steady state re-plans nothing.

    PYTHONPATH=src python -m benchmarks.quant_bench --smoke --check \\
        --out BENCH_PR5.json
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

from benchmarks.serve_bench import make_trace


def _build(arch: str, head_dim: int):
    import dataclasses

    import jax

    from repro.configs import get_config
    from repro.models import transformer as T

    cfg = dataclasses.replace(get_config(arch, smoke=True), head_dim=head_dim)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _requests(cfg, trace):
    import numpy as np

    from repro.serve_lib.scheduler import Request

    rng = np.random.default_rng(0)
    return [Request(uid=i, prompt=rng.integers(0, cfg.vocab, p)
                    .astype(np.int32), max_new_tokens=g)
            for i, (p, g) in enumerate(trace)]


def _serve(cfg, params, scfg, trace, bucket, engine=None):
    from repro.serve_lib.scheduler import Scheduler

    def once():
        sched = Scheduler(params, cfg, scfg, engine=engine,
                          prefill_bucket=bucket)
        t0 = time.time()
        comps = sched.run(_requests(cfg, trace))
        return time.time() - t0, sched, comps

    once()  # warm-up: jit compiles
    dt, sched, comps = min((once() for _ in range(3)), key=lambda r: r[0])
    tokens = sum(len(c.tokens) for c in comps.values())
    row = {"seconds": round(dt, 4), "useful_tokens": tokens,
           "tokens_per_s": round(tokens / dt, 2)}
    return row, {u: c.tokens.tolist() for u, c in comps.items()}


def _agreement(base_toks: dict, toks: dict) -> dict:
    exact = agree = total = 0
    for uid, tb in base_toks.items():
        tq = toks[uid]
        n = min(len(tb), len(tq))
        agree += sum(a == b for a, b in zip(tb[:n], tq[:n], strict=True))
        total += n
        exact += int(tb == tq)
    return {"exact_requests": exact, "requests": len(base_toks),
            "agreeing_tokens": agree, "compared_tokens": total,
            "stepwise_agreement": round(agree / total, 4)}


def run_engine_posture(cfg, params, scfg, trace, bucket, pool,
                       warmup_steps=3):
    """Full-int8 serving through a warm-started int8 engine: decision-
    cache stats + the steady-state miss delta (must be 0)."""
    from repro import engine as engine_mod
    from repro.serve_lib.scheduler import Scheduler

    width = -(-max(p for p, _ in trace) // bucket) * bucket
    plan = engine_mod.plan_arch(
        cfg, seq_len=width, decode_batch=pool,
        admit_widths=tuple(range(bucket, width + 1, bucket)),
        backend=scfg.kernel_backend, quantized_weights=True,
        # compute width: int8 requests key in at 1 byte but OUT at the
        # float width the kernels rescale to (Engine._resolve).
        dtype_bytes=scfg.compute_dtype.itemsize)
    eng = engine_mod.Engine(backend=scfg.kernel_backend, plan=plan)
    sched = Scheduler(params, cfg, scfg, engine=eng, prefill_bucket=bucket)
    for r in _requests(cfg, trace):
        sched.submit(r)
    for _ in range(warmup_steps):
        sched.step()
    warm = dict(plan.stats)
    while sched.queue or sched.n_active:
        sched.step()
    final = dict(plan.stats)
    ops = sorted({req.op for req, _ in plan})
    return {
        "backend": scfg.kernel_backend,
        "planned_decisions": len(plan),
        "planned_ops": ops,
        "after_warmup": warm,
        "final": final,
        "steady_state_new_misses": final["misses"] - warm["misses"],
        "steady_state_new_hits": final["hits"] - warm["hits"],
    }


def pallas_xla_parity() -> dict:
    """The Pallas int8 kernel dispatches through the engine and matches
    the xla-int8 reference bit-for-bit (same int32 accumulation)."""
    import jax.numpy as jnp
    import numpy as np

    from repro import engine as engine_mod

    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(48, 192)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(192, 96)), jnp.float32)
    outs = {}
    for backend in engine_mod.INT8_BACKENDS:
        with engine_mod.use_engine(backend=backend) as eng:
            outs[backend] = np.asarray(eng.matmul(a, b))
    exact = bool(np.array_equal(outs["pallas-tpu-int8"], outs["xla-int8"]))
    return {"shapes": [[48, 192], [192, 96]], "bit_exact": exact}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--head-dim", type=int, default=64,
                    help="production head_dim for the bench model (the "
                         "smoke configs' 16 understates the cache ratio)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="BENCH_PR5.json")
    ap.add_argument("--prefill-bucket", type=int, default=8)
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless the quantization gates hold")
    args = ap.parse_args(argv)

    import jax.numpy as jnp

    from repro.quant import quantize_params, tree_bytes
    from repro.serve_lib import serve as serve_lib

    pool, trace = make_trace(args.smoke)
    max_seq = max(p + g for p, g in trace) + 1
    cfg, params = _build(args.arch, args.head_dim)

    mk_scfg = lambda **kw: serve_lib.ServeConfig(
        max_seq=max_seq, batch=pool, compute_dtype=jnp.float32, **kw)
    scfg_base = mk_scfg(cache_dtype=jnp.bfloat16)
    scfg_kv = mk_scfg(cache_dtype=jnp.int8)
    scfg_full = mk_scfg(cache_dtype=jnp.int8, quantize=True)

    # -- footprints (layout properties, measured on the real pytrees) ------
    cache_bytes_bf16 = tree_bytes(serve_lib.init_cache(cfg, scfg_base))
    cache_bytes_int8 = tree_bytes(serve_lib.init_cache(cfg, scfg_kv))
    qparams = quantize_params(params)
    bytes_row = {
        "cache_bytes_bf16": cache_bytes_bf16,
        "cache_bytes_int8": cache_bytes_int8,
        "cache_reduction": round(cache_bytes_bf16 / cache_bytes_int8, 3),
        "param_bytes_float": tree_bytes(params),
        "param_bytes_quant": tree_bytes(qparams),
        "param_reduction": round(tree_bytes(params) / tree_bytes(qparams), 3),
    }

    # -- the three serving postures ----------------------------------------
    base_row, base_toks = _serve(cfg, params, scfg_base, trace,
                                 args.prefill_bucket)
    kv_row, kv_toks = _serve(cfg, params, scfg_kv, trace,
                             args.prefill_bucket)
    full_row, full_toks = _serve(cfg, qparams, scfg_full, trace,
                                 args.prefill_bucket)
    kv_row["vs_bf16"] = _agreement(base_toks, kv_toks)
    full_row["vs_bf16"] = _agreement(base_toks, full_toks)
    # same-run ratios: host-invariant, gated by benchmarks/trend.py
    kv_row["relative_throughput"] = round(
        kv_row["tokens_per_s"] / base_row["tokens_per_s"], 3)
    full_row["relative_throughput"] = round(
        full_row["tokens_per_s"] / base_row["tokens_per_s"], 3)

    engine_row = run_engine_posture(cfg, qparams, scfg_full, trace,
                                    args.prefill_bucket, pool)
    parity_row = pallas_xla_parity()

    report = {
        "bench": "quant_int8_vs_bf16",
        "arch": args.arch, "head_dim": args.head_dim, "smoke": args.smoke,
        "pool_slots": pool, "trace": trace,
        # Explicit gating posture (ISSUE 8): the nightly bench-full lane
        # runs this bench WITHOUT --check — exact int8 greedy parity is a
        # smoke-trace gate, and on the full trace quantization error
        # compounds over longer generations (one request may drift).
        # Mark that in the artifact so the nightly table shows WHY it is
        # not gated instead of looking green by omission.
        "gate": "checked" if args.check else "report-only",
        "gate_note": (None if args.check else
                      "run without --check: full-trace int8 parity is "
                      "report-only (drift compounds past the smoke trace)"),
        "bytes": bytes_row,
        "baseline_bf16": base_row,
        "kv_int8": kv_row,
        "full_int8": full_row,
        "engine": engine_row,
        "pallas_vs_xla_int8": parity_row,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
        f.write("\n")
    print(json.dumps(report, indent=1, sort_keys=True))
    if not args.check:
        summary = os.environ.get("GITHUB_STEP_SUMMARY")
        if summary:
            with open(summary, "a") as f:
                f.write("**quant_bench: report-only** — no --check; "
                        "full-trace int8 parity gates only on the smoke "
                        "trace (see BENCH JSON `gate` field)\n")

    failures = []
    if args.check:
        if bytes_row["cache_reduction"] < 1.8:
            failures.append(
                f"KV-cache bytes shrank only "
                f"{bytes_row['cache_reduction']}x (< 1.8x)")
        kv_agree = kv_row["vs_bf16"]
        if kv_agree["exact_requests"] != kv_agree["requests"]:
            failures.append(
                f"int8 KV cache broke greedy parity "
                f"({kv_agree['exact_requests']}/{kv_agree['requests']} "
                f"requests exact)")
        if full_row["vs_bf16"]["stepwise_agreement"] < 0.5:
            failures.append(
                f"full int8 posture stepwise agreement "
                f"{full_row['vs_bf16']['stepwise_agreement']} < 0.5")
        if engine_row["steady_state_new_misses"] != 0:
            failures.append(
                f"int8 decode path re-planned after warm-up "
                f"({engine_row['steady_state_new_misses']} new misses)")
        if not parity_row["bit_exact"]:
            failures.append("pallas-tpu-int8 diverged from xla-int8")
    for msg in failures:
        print(f"FAIL: {msg}", file=sys.stderr)
    return len(failures)


if __name__ == "__main__":
    sys.exit(main())
