"""Structured-sparsity bench: N:M (2:4) sparse plane vs dense (ISSUE 8).

Four planes of evidence, one JSON:

  density sweep   DRACO-style: every Table-3 DNN GEMM trace
                  (core.workloads) is planned twice through `TPUModel`
                  — dense (density 1.0) and 2:4 sparse (density 0.5) —
                  and the ratio of modeled trace seconds is the
                  workload's *effective-throughput* gain.  Host-
                  invariant (a ratio of two analytic decisions), so the
                  per-workload `effective_speedup` rows and their
                  `geomean_effective_speedup` are trend-gated; --check
                  enforces geomean >= 1.3x (the FlexSA argument: at 2:4
                  half the MACs and weight bytes vanish, index stream
                  overhead eats some of it back).
  mapper ranking  `AnalyticalCostModel` (the paper's Sec. 4 mapper) at
                  a headline ResNet-50 shape: the sparse candidate must
                  rank ABOVE its dense sibling at equal shape.
  kernel parity   `pallas-tpu-sparse` vs `xla-sparse` through
                  `Engine.sparse_matmul` — bit-exact (both scatter the
                  same dense tile; float accumulation in f32).
  serve posture   the serve_bench smoke trace through the continuous-
                  batching scheduler: `prune_params` weights +
                  `ServeConfig(sparsity="2:4")` vs the densified-oracle
                  params on the float path — greedy parity must be
                  EXACT (densify(sparsify(w)) is the same matmul by
                  construction).  A `plan_arch(..., sparse_weights=
                  True)`-warmed engine then replays the trace and must
                  log zero steady-state plan misses.

Wall-clock rows (tokens/s, `wallclock_sparse_over_dense`) are report-
only: interpret-mode Pallas on a CPU host measures dispatch overhead,
not the HBM savings the cost models account — their metric names carry
no trend-gate marker on purpose.

Emits ``BENCH_PR8.json``:

    PYTHONPATH=src python -m benchmarks.sparse_bench --smoke --check \\
        --out BENCH_PR8.json
"""

from __future__ import annotations

import argparse
import json
import math
import sys
import time

from benchmarks.serve_bench import make_trace

#: WORKLOADS keys (Table-3 abbreviations): ResNet-50, ViT, BERT-Large
SMOKE_WORKLOADS = ("RE", "VI", "BE")


# ---------------------------------------------------------------------------
# Plane sweeps (no jax: pure cost-model arithmetic)
# ---------------------------------------------------------------------------


def density_sweep(smoke: bool) -> dict:
    """Modeled trace seconds per Table-3 workload, dense vs 2:4."""
    from repro.core.workloads import WORKLOADS
    from repro.engine import KernelRequest, TPUModel

    model = TPUModel()
    names = SMOKE_WORKLOADS if smoke else tuple(WORKLOADS)
    rows, ratios = {}, []
    for name in names:
        wl = WORKLOADS[name]
        dense_s = sparse_s = 0.0
        for g in wl.gemms:
            dense = model.decide(KernelRequest("gemm", g.M, g.K, g.N))
            sparse = model.decide(
                KernelRequest("gemm_sparse", g.M, g.K, g.N, density=0.5))
            dense_s += dense.seconds * g.count
            sparse_s += sparse.seconds * g.count
        ratio = dense_s / sparse_s
        ratios.append(ratio)
        rows[wl.abbr] = {
            "gemms": wl.n_layers,
            "dense_seconds": dense_s,
            "sparse_seconds": sparse_s,
            "effective_speedup": round(ratio, 4),
        }
    geomean = math.exp(sum(math.log(r) for r in ratios) / len(ratios))
    return {"densities": [1.0, 0.5], "nm": "2:4", "workloads": rows,
            "geomean_effective_speedup": round(geomean, 4)}


def mapper_ranking() -> dict:
    """The ASIC mapper must rank the sparse candidate above dense at an
    equal headline shape (ResNet-50's (49, 2048, 512))."""
    from repro.engine import AnalyticalCostModel, KernelRequest

    model = AnalyticalCostModel()
    m, k, n = 49, 2048, 512
    dense = model.decide(KernelRequest("gemm", m, k, n, name="res50"))
    sparse = model.decide(
        KernelRequest("gemm_sparse", m, k, n, density=0.5, name="res50"))
    return {
        "shape": [m, k, n],
        "dense_seconds": dense.seconds,
        "sparse_seconds": sparse.seconds,
        "mapper_speedup": round(dense.seconds / sparse.seconds, 4),
        "sparse_ranked_above_dense": sparse.seconds < dense.seconds,
    }


# ---------------------------------------------------------------------------
# Kernel parity + serve postures (jax)
# ---------------------------------------------------------------------------


def pallas_xla_parity() -> dict:
    """Both sparse backends dispatch through the engine and agree
    bit-for-bit (shared scatter-to-dense tile, f32 accumulation)."""
    import jax.numpy as jnp
    import numpy as np

    from repro import engine as engine_mod
    from repro.sparse import sparsify

    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(48, 256)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(256, 128)), jnp.float32)
    st = sparsify(w, 2, 4)
    outs = {}
    for backend in engine_mod.SPARSE_BACKENDS:
        with engine_mod.use_engine(backend=backend) as eng:
            outs[backend] = np.asarray(eng.sparse_matmul(a, st))
    exact = bool(np.array_equal(outs["pallas-tpu-sparse"],
                                outs["xla-sparse"]))
    return {"shapes": [[48, 256], [256, 128]], "bit_exact": exact}


def _requests(cfg, trace):
    import numpy as np

    from repro.serve_lib.scheduler import Request

    rng = np.random.default_rng(0)
    return [Request(uid=i, prompt=rng.integers(0, cfg.vocab, p)
                    .astype(np.int32), max_new_tokens=g)
            for i, (p, g) in enumerate(trace)]


def _serve(cfg, params, scfg, trace, bucket, engine=None):
    from repro.serve_lib.scheduler import Scheduler

    def once():
        sched = Scheduler(params, cfg, scfg, engine=engine,
                          prefill_bucket=bucket)
        t0 = time.time()
        comps = sched.run(_requests(cfg, trace))
        return time.time() - t0, sched, comps

    once()  # warm-up: jit compiles
    dt, sched, comps = min((once() for _ in range(3)), key=lambda r: r[0])
    tokens = sum(len(c.tokens) for c in comps.values())
    row = {"seconds": round(dt, 4), "useful_tokens": tokens,
           "tokens_per_s": round(tokens / dt, 2)}
    return row, {u: c.tokens.tolist() for u, c in comps.items()}


def _agreement(base_toks: dict, toks: dict) -> dict:
    exact = agree = total = 0
    for uid, tb in base_toks.items():
        tq = toks[uid]
        n = min(len(tb), len(tq))
        agree += sum(a == b for a, b in zip(tb[:n], tq[:n], strict=True))
        total += n
        exact += int(tb == tq)
    return {"exact_requests": exact, "requests": len(base_toks),
            "agreeing_tokens": agree, "compared_tokens": total,
            "stepwise_agreement": round(agree / total, 4)}


def run_engine_posture(cfg, params, scfg, trace, bucket, pool,
                       warmup_steps=3):
    """Pruned serving through a `plan_arch(..., sparse_weights=True)`-
    warmed sparse engine: decision-cache stats + the steady-state miss
    delta (must be 0 — density keys the cache, so a collision with a
    dense plan would show here as a miss)."""
    from repro import engine as engine_mod
    from repro.serve_lib.scheduler import Scheduler

    width = -(-max(p for p, _ in trace) // bucket) * bucket
    plan = engine_mod.plan_arch(
        cfg, seq_len=width, decode_batch=pool,
        admit_widths=tuple(range(bucket, width + 1, bucket)),
        backend=scfg.kernel_backend, sparse_weights=True,
        dtype_bytes=scfg.compute_dtype.itemsize)
    eng = engine_mod.Engine(backend=scfg.kernel_backend, plan=plan)
    sched = Scheduler(params, cfg, scfg, engine=eng, prefill_bucket=bucket)
    for r in _requests(cfg, trace):
        sched.submit(r)
    for _ in range(warmup_steps):
        sched.step()
    warm = dict(plan.stats)
    while sched.queue or sched.n_active:
        sched.step()
    final = dict(plan.stats)
    return {
        "backend": scfg.kernel_backend,
        "planned_decisions": len(plan),
        "planned_ops": sorted({req.op for req, _ in plan}),
        "after_warmup": warm,
        "final": final,
        "steady_state_new_misses": final["misses"] - warm["misses"],
        "steady_state_new_hits": final["hits"] - warm["hits"],
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="BENCH_PR8.json")
    ap.add_argument("--prefill-bucket", type=int, default=8)
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless the sparsity gates hold")
    args = ap.parse_args(argv)

    sweep_row = density_sweep(args.smoke)
    mapper_row = mapper_ranking()
    parity_row = pallas_xla_parity()

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.quant import tree_bytes
    from repro.serve_lib import serve as serve_lib
    from repro.sparse import densify_params, prune_params

    pool, trace = make_trace(args.smoke)
    max_seq = max(p + g for p, g in trace) + 1
    cfg = get_config(args.arch, smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    sparams = prune_params(params, 2, 4)
    oracle = densify_params(sparams)

    bytes_row = {
        "param_bytes_dense": tree_bytes(params),
        "param_bytes_sparse": tree_bytes(sparams),
        "param_reduction": round(
            tree_bytes(params) / tree_bytes(sparams), 3),
    }

    mk_scfg = lambda **kw: serve_lib.ServeConfig(
        max_seq=max_seq, batch=pool, compute_dtype=jnp.float32, **kw)
    scfg_dense = mk_scfg()
    scfg_sparse = mk_scfg(sparsity="2:4")

    dense_row, dense_toks = _serve(cfg, oracle, scfg_dense, trace,
                                   args.prefill_bucket)
    sparse_row, sparse_toks = _serve(cfg, sparams, scfg_sparse, trace,
                                     args.prefill_bucket)
    sparse_row["vs_dense"] = _agreement(dense_toks, sparse_toks)
    # wall-clock ratio: interpret-mode dispatch overhead, NOT gated (the
    # name intentionally avoids every trend.py THROUGHPUT_MARKER)
    sparse_row["wallclock_sparse_over_dense"] = round(
        sparse_row["tokens_per_s"] / dense_row["tokens_per_s"], 3)

    engine_row = run_engine_posture(cfg, sparams, scfg_sparse, trace,
                                    args.prefill_bucket, pool)

    report = {
        "bench": "sparse_nm_vs_dense",
        "arch": args.arch, "smoke": args.smoke,
        "pool_slots": pool, "trace": trace,
        "gate": "checked" if args.check else "report-only",
        "density_sweep": sweep_row,
        "mapper": mapper_row,
        "pallas_vs_xla_sparse": parity_row,
        "bytes": bytes_row,
        "baseline_dense": dense_row,
        "sparse_2_4": sparse_row,
        "engine": engine_row,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
        f.write("\n")
    print(json.dumps(report, indent=1, sort_keys=True))

    failures = []
    if args.check:
        if sweep_row["geomean_effective_speedup"] < 1.3:
            failures.append(
                f"2:4 effective-throughput geomean "
                f"{sweep_row['geomean_effective_speedup']}x < 1.3x")
        if not mapper_row["sparse_ranked_above_dense"]:
            failures.append(
                f"mapper ranked dense above sparse at equal shape "
                f"({mapper_row['dense_seconds']:.3g}s <= "
                f"{mapper_row['sparse_seconds']:.3g}s)")
        if not parity_row["bit_exact"]:
            failures.append("pallas-tpu-sparse diverged from xla-sparse")
        agree = sparse_row["vs_dense"]
        if agree["exact_requests"] != agree["requests"]:
            failures.append(
                f"pruned model broke greedy parity vs its densified "
                f"oracle ({agree['exact_requests']}/{agree['requests']} "
                f"requests exact)")
        if engine_row["steady_state_new_misses"] != 0:
            failures.append(
                f"sparse decode path re-planned after warm-up "
                f"({engine_row['steady_state_new_misses']} new misses)")
    for msg in failures:
        print(f"FAIL: {msg}", file=sys.stderr)
    return len(failures)


if __name__ == "__main__":
    sys.exit(main())
