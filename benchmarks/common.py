"""Shared benchmark plumbing: one mapping pass per (accelerator, DNN),
cached for the whole process so every figure module reuses it.

Mapping runs on the mapper's default *batched* search engine (flat
candidate tensor + argmin); the scalar loop survives behind
``ReDasMapper(..., vectorized=False)`` and is exercised (with a 0.1%
parity gate) by benchmarks/bench.py."""

from __future__ import annotations

import functools
import time

from repro.core.accelerators import make_specs
from repro.core.energy import EnergyReport, model_energy, vector_cycles
from repro.core.mapper import ModelMapping, ReDasMapper
from repro.core.workloads import WORKLOADS

ACCELERATORS = ("tpu", "gemmini", "planaria", "dynnamic", "sara", "redas")
MODELS = tuple(WORKLOADS)  # RE EF TY FR VI BE GN DS


@functools.lru_cache(maxsize=None)
def mapping_for(acc: str, model: str, array_size: int = 128) -> ModelMapping:
    spec = make_specs(array_size)[acc]
    return ReDasMapper(spec, array_size=array_size).map_model(
        WORKLOADS[model].gemms)


@functools.lru_cache(maxsize=None)
def energy_for(acc: str, model: str, array_size: int = 128) -> EnergyReport:
    spec = make_specs(array_size)[acc]
    return model_energy(spec, mapping_for(acc, model, array_size),
                        WORKLOADS[model].vector_elements, array_size)


def total_runtime_cycles(acc: str, model: str, array_size: int = 128) -> float:
    """GEMM cycles + exposed vector (activation) time — Fig. 11's metric."""
    m = mapping_for(acc, model, array_size)
    return m.total_cycles + vector_cycles(WORKLOADS[model].vector_elements)


def geomean(xs) -> float:
    xs = list(xs)
    p = 1.0
    for x in xs:
        p *= x
    return p ** (1.0 / len(xs))


class timed:
    """Context manager for each figure's us_per_call column."""

    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *exc):
        self.us = (time.time() - self.t0) * 1e6


def csv_row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.0f},{derived}"
