"""Fig. 15: ReDas runtime breakdown — GEMM compute, exposed memory time,
PE-array configuration, activation (SIMD) time, bypass overhead.

Paper: non-overlapping memory 7-25%; config 0.4-7%; activation 0.1-6.9%;
roundabout bypass ~1.2% average."""

from __future__ import annotations

from repro.core.energy import vector_cycles
from repro.core.workloads import WORKLOADS

from .common import MODELS, csv_row, geomean, mapping_for, timed


def compute() -> dict:
    out = {}
    for m in MODELS:
        mp = mapping_for("redas", m)
        gemm = mp.total_cycles
        vec = vector_cycles(WORKLOADS[m].vector_elements)
        total = gemm + vec
        compute_c = sum(min(d.report.compute_cycles,
                            d.report.cycles - d.report.start_cycles
                            - d.report.end_cycles) for d in mp.decisions)
        exposed_mem = gemm - compute_c
        out[m] = {
            "compute": compute_c / total,
            "memory": exposed_mem / total,
            "config": mp.total_config_cycles / total,
            "activation": vec / total,
            "bypass": mp.total_bypass_cycles / total,
        }
    return out


def main() -> list[str]:
    with timed() as t:
        r = compute()
    rows = []
    mem = [r[m]["memory"] for m in MODELS]
    rows.append(csv_row("fig15.exposed_memory_range", t.us,
                        f"{min(mem) * 100:.1f}-{max(mem) * 100:.1f}% (paper 7-25%)"))
    cfgs = [r[m]["config"] for m in MODELS]
    rows.append(csv_row("fig15.config_range", 0,
                        f"{min(cfgs) * 100:.2f}-{max(cfgs) * 100:.2f}% (paper 0.4-7%)"))
    acts = [r[m]["activation"] for m in MODELS]
    rows.append(csv_row("fig15.activation_range", 0,
                        f"{min(acts) * 100:.2f}-{max(acts) * 100:.2f}% (paper 0.1-6.9%)"))
    byp = geomean(max(r[m]["bypass"], 1e-9) for m in MODELS)
    rows.append(csv_row("fig15.bypass_mean", 0,
                        f"{byp * 100:.2f}% (paper ~1.2%)"))
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
