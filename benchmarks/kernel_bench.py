"""Plane-2 kernel benchmark: the ReDas mapper decision surface on TPU.

For the paper's headline GEMMs, compare the fixed 128^3 OS schedule
against the mapper-chosen (dataflow, block) Pallas config on the v5e
cost model, and validate the chosen config numerically in interpret
mode on a scaled-down version of the same shape."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.tpu_model import fixed_square_cost
from repro.engine import Engine, KernelRequest, TPUModel
from repro.kernels.ref import matmul_ref

from .common import csv_row, geomean, timed

GEMMS = {
    "tinyyolo_l2": (43264, 144, 32),
    "vit_ffn1": (50, 3072, 768),
    "vit_ffn2": (50, 768, 3072),
    "bert_qkv": (128, 1024, 1024),
    "bert_ffn1": (128, 1024, 4096),
    "gnmt_cell": (1, 1024, 4096),
    "resnet_conv12544": (12544, 147, 64),
    "square_4k": (4096, 4096, 4096),
}


def compute() -> dict:
    out = {}
    model = TPUModel()
    eng = Engine(model, backend="pallas-interpret")
    for name, (m, k, n) in GEMMS.items():
        dec = model.decide(KernelRequest("gemm", m, k, n, name=name))
        fix = fixed_square_cost(m, k, n)
        # numeric validation at reduced scale (same aspect, <=256 per dim):
        # the engine re-plans the small shape and dispatches the Pallas
        # kernel through the unified decision cache.
        sm = max(8, min(m, 96))
        sk = max(8, min(k, 128))
        sn = max(8, min(n, 64))
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.normal(size=(sm, sk)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(sk, sn)), jnp.float32)
        got = eng.matmul(a, b)
        err = float(jnp.abs(got - matmul_ref(a, b)).max())
        out[name] = {
            "config": f"{dec.dataflow}({dec.bm},{dec.bk},{dec.bn})",
            "speedup": fix.seconds / dec.seconds,
            "util": dec.meta_dict["mxu_utilization"],
            "fixed_util": fix.mxu_utilization,
            "numeric_err": err,
        }
    return out


def main() -> list[str]:
    with timed() as t:
        r = compute()
    rows = [csv_row(
        "kernel.mapper_speedup_geomean_vs_fixed128", t.us,
        f"{geomean(v['speedup'] for v in r.values()):.2f}x")]
    for name, v in r.items():
        rows.append(csv_row(
            f"kernel.{name}", 0,
            f"{v['config']} {v['speedup']:.2f}x util={v['util']:.2f} "
            f"err={v['numeric_err']:.1e}"))
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
