"""Fig. 20/21: distribution of chosen dataflows and logical shapes across
all DNN layers.  Paper: OS ~40.9%, WS ~39.7% of layers; 256x64 the most
prevalent shape (~27.3%)."""

from __future__ import annotations

from collections import Counter

from .common import MODELS, csv_row, mapping_for, timed


def compute() -> tuple[Counter, Counter]:
    dataflows: Counter = Counter()
    shapes: Counter = Counter()
    for m in MODELS:
        for d in mapping_for("redas", m).decisions:
            dataflows[d.config.dataflow.value] += 1
            shapes[str(d.config.shape)] += 1
    return dataflows, shapes


def main() -> list[str]:
    with timed() as t:
        df, sh = compute()
    total = sum(df.values())
    rows = [csv_row("fig20.dataflow_share", t.us,
                    " ".join(f"{k}={100 * v / total:.1f}%"
                             for k, v in df.most_common()))]
    top = sh.most_common(5)
    rows.append(csv_row(
        "fig21.top_shapes", 0,
        " ".join(f"{k}={100 * v / total:.1f}%" for k, v in top)))
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
