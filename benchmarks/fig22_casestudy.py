"""Fig. 22: per-layer configuration landscape case study.

Paper: TinyYOLO-V2 layer 2, GEMM (M,K,N)=(43264,144,32): optimum at
384x32 logical shape, OS dataflow, 3.79x faster than the 128x128 OS
mapping; 75% of PEs active vs 25%."""

from __future__ import annotations

from repro.core.accelerators import SPECS
from repro.core.analytical_model import GEMM
from repro.core.dataflow import Dataflow, LogicalShape, pe_usage
from repro.engine import AnalyticalCostModel, KernelRequest

from .common import csv_row, timed

LAYERS = {
    "tinyyolo_l2": GEMM(43264, 144, 32),
    "vit_ffn1": GEMM(50, 768, 3072),
    "bert_ffn1": GEMM(128, 1024, 4096),
    "gnmt_cell": GEMM(1, 1024, 4096),
}


def compute() -> dict:
    out = {}
    # the engine's plane-1 cost model: same mapper, unified decisions
    acm = AnalyticalCostModel(SPECS["redas"])
    mapper, model = acm.mapper, acm.mapper.model
    for name, g in LAYERS.items():
        best = acm.decide(KernelRequest("gemm", g.M, g.K, g.N, name=name))
        shape = LogicalShape(int(best.meta_dict["shape_rows"]),
                             int(best.meta_dict["shape_cols"]))
        # reference: same dataflow, native 128x128 shape
        ref_best = None
        for cfg in mapper.candidates(g):
            if cfg.shape == LogicalShape(128, 128) and \
                    cfg.dataflow == Dataflow(best.dataflow):
                rep = model.estimate(g, cfg)
                if rep.valid and (ref_best is None or rep.cycles < ref_best.cycles):
                    ref_best = rep
        out[name] = {
            "shape": str(shape),
            "dataflow": best.dataflow,
            "speedup_vs_square": (ref_best.cycles / best.meta_dict["cycles"]
                                  if ref_best else float("nan")),
            "pe_usage": pe_usage(shape, 128),
        }
    return out


def main() -> list[str]:
    with timed() as t:
        r = compute()
    ty = r["tinyyolo_l2"]
    rows = [csv_row(
        "fig22.tinyyolo_l2_optimum", t.us,
        f"{ty['shape']} {ty['dataflow']} {ty['speedup_vs_square']:.2f}x "
        f"pe={ty['pe_usage']:.0%} (paper 384x32 os 3.79x pe=75%)")]
    for name in ("vit_ffn1", "bert_ffn1", "gnmt_cell"):
        c = r[name]
        rows.append(csv_row(f"fig22.{name}", 0,
                            f"{c['shape']} {c['dataflow']} "
                            f"{c['speedup_vs_square']:.2f}x_vs_square"))
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
