"""Bench-trend gate: fresh ``BENCH_*.json`` vs the committed baselines.

The CI bench lane regenerates every perf artifact into a scratch dir;
this tool pairs each fresh file with the baseline of the same name
committed at the repo root, flattens both JSONs to dotted-path numeric
leaves, and renders a per-metric delta table.  When
``$GITHUB_STEP_SUMMARY`` is set (or ``--summary`` given) the table is
appended there as markdown, so the perf trajectory shows up in the PR
UI instead of buried in artifacts.

Gate: HOST-INVARIANT throughput metrics — ratios of two measurements
taken on the same machine in the same run (``speedup``, ``geomean``,
``relative_throughput``) — are higher-is-better and fail the run when
the fresh value regresses more than ``--max-regression`` (default 10%).
Absolute tokens/s and raw seconds are reported in the table but NOT
gated: the committed baselines were measured on a different host than
the CI runner, so an absolute-throughput gate would track runner speed,
not code regressions.  Byte/parity invariants have their own hard gates
inside each bench's ``--check``.

    PYTHONPATH=src python -m benchmarks.trend \\
        --baseline-dir . --fresh-dir bench-out
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys

#: dotted-path substrings marking a higher-is-better, host-invariant
#: throughput metric (same-run ratios; absolute tokens/s is reported
#: but never gated — see the module docstring).
THROUGHPUT_MARKERS = ("speedup", "geomean", "relative_throughput",
                      "reuse_ratio", "accept_rate", "accepted_tokens_ratio",
                      # latency-SLO ratios (PR 10): unchunked / chunked
                      # p99 latency on the same trace in the same run —
                      # higher means chunked prefill removes more
                      # head-of-line blocking
                      "p99_ttft_ratio", "inter_token_ratio")

#: noisy / non-metric paths never worth a table row.
SKIP_MARKERS = ("trace", "shapes", "prefill_widths")


def flatten(node, prefix="") -> dict[str, float]:
    """JSON -> {dotted.path: numeric leaf} (bools and strings dropped)."""
    out: dict[str, float] = {}
    if isinstance(node, dict):
        for k in sorted(node):
            out.update(flatten(node[k], f"{prefix}{k}."))
        return out
    if isinstance(node, (list, tuple)):
        return out  # traces / shape lists: not metrics
    if isinstance(node, bool) or not isinstance(node, (int, float)):
        return out
    path = prefix.rstrip(".")
    if not any(m in path for m in SKIP_MARKERS):
        out[path] = float(node)
    return out


def is_throughput(path: str) -> bool:
    return any(m in path for m in THROUGHPUT_MARKERS)


def compare(baseline: dict, fresh: dict, max_regression: float):
    """Per-metric rows + the throughput regressions past the gate."""
    rows, regressions = [], []
    for path in sorted(set(baseline) | set(fresh)):
        b, f = baseline.get(path), fresh.get(path)
        if b is None or f is None:
            if b is not None and is_throughput(path):
                # a GATED metric vanished: that silently kills its
                # regression gate — fail, don't shrug.
                rows.append((path, b, f, None, "REMOVED"))
                regressions.append((path, b, None, None))
                continue
            rows.append((path, b, f, None, "added" if b is None else "removed"))
            continue
        delta = (f - b) / abs(b) if b else (0.0 if f == b else float("inf"))
        gated = is_throughput(path)
        status = ""
        if gated:
            status = "ok"
            if delta < -max_regression:
                status = "REGRESSION"
                regressions.append((path, b, f, delta))
        rows.append((path, b, f, delta, status))
    return rows, regressions


def _fmt(x) -> str:
    if x is None:
        return "—"
    if abs(x) >= 1000:
        return f"{x:,.0f}"
    return f"{x:.4g}"


def render_markdown(name: str, rows, max_regression: float) -> str:
    lines = [f"### {name}", "",
             "| metric | baseline | fresh | delta | gate |",
             "|---|---:|---:|---:|---|"]
    for path, b, f, delta, status in rows:
        d = "—" if delta is None else f"{delta:+.1%}"
        gate = {"": "", "ok": "✓", "REGRESSION": f"❌ > {max_regression:.0%}",
                "REMOVED": "❌ gated metric removed",
                "added": "new", "removed": "gone"}[status]
        lines.append(f"| `{path}` | {_fmt(b)} | {_fmt(f)} | {d} | {gate} |")
    lines.append("")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline-dir", default=".",
                    help="directory holding the committed BENCH_*.json")
    ap.add_argument("--fresh-dir", default="bench-out",
                    help="directory the CI lane wrote fresh artifacts to")
    ap.add_argument("--max-regression", type=float, default=0.10,
                    help="throughput regression gate (fraction, default 0.10)")
    ap.add_argument("--summary", default=None,
                    help="markdown output path (defaults to "
                         "$GITHUB_STEP_SUMMARY when set)")
    args = ap.parse_args(argv)

    fresh_paths = sorted(glob.glob(os.path.join(args.fresh_dir,
                                                "BENCH_*.json")))
    if not fresh_paths:
        print(f"no fresh BENCH_*.json under {args.fresh_dir!r}",
              file=sys.stderr)
        return 1

    all_md, failures = [], []
    for fp in fresh_paths:
        name = os.path.basename(fp)
        bp = os.path.join(args.baseline_dir, name)
        if not os.path.exists(bp):
            all_md.append(f"### {name}\n\n(no committed baseline — "
                          f"first run of this artifact)\n")
            print(f"{name}: no baseline, skipping comparison")
            continue
        with open(bp) as fh:
            baseline = flatten(json.load(fh))
        with open(fp) as fh:
            fresh = flatten(json.load(fh))
        rows, regressions = compare(baseline, fresh, args.max_regression)
        all_md.append(render_markdown(name, rows, args.max_regression))
        for path, b, f, delta in regressions:
            if f is None:
                failures.append(
                    f"{name}:{path} gated metric removed (baseline {b:g})")
            else:
                failures.append(f"{name}:{path} {b:g} -> {f:g} ({delta:+.1%})")
        print(f"{name}: {len(rows)} metrics, "
              f"{len(regressions)} throughput regressions")

    md = "## Bench trend (fresh vs committed baselines)\n\n" + \
        "\n".join(all_md)
    summary_path = args.summary or os.environ.get("GITHUB_STEP_SUMMARY")
    if summary_path:
        with open(summary_path, "a") as fh:
            fh.write(md + "\n")
    else:
        print(md)

    for msg in failures:
        print(f"FAIL: throughput regression {msg}", file=sys.stderr)
    return min(len(failures), 125)


if __name__ == "__main__":
    sys.exit(main())
