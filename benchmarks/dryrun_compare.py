"""Baseline vs optimized dry-run comparison (EXPERIMENTS.md §Perf table).

    PYTHONPATH=src python -m benchmarks.dryrun_compare [--mesh single]
"""

from __future__ import annotations

import argparse
import json
import os

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def load(d: str, mesh: str) -> dict:
    out = {}
    p = os.path.join(ROOT, d, mesh)
    if not os.path.isdir(p):
        return out
    for f in os.listdir(p):
        with open(os.path.join(p, f)) as fh:
            r = json.load(fh)
        if r.get("status") == "ok":
            out[(r["arch"], r["shape"])] = r["roofline"]
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    base = load("runs/dryrun", args.mesh)
    opt = load("runs/dryrun_opt", args.mesh)
    hdr = ["arch", "shape", "base step_s", "opt step_s", "speedup",
           "base roofl%", "opt roofl%"]
    fmt = ("| " + " | ".join("{}" for _ in hdr) + " |") if args.markdown \
        else "  ".join("{:>12s}" for _ in hdr)
    if args.markdown:
        print("| " + " | ".join(hdr) + " |")
        print("|" + "---|" * len(hdr))
    else:
        print(fmt.format(*hdr))
    gains = []
    for key in sorted(base):
        if key not in opt:
            continue
        b, o = base[key], opt[key]
        sp = b["step_s"] / o["step_s"] if o["step_s"] else float("nan")
        gains.append(sp)
        print(fmt.format(
            key[0], key[1], f"{b['step_s']:.4g}", f"{o['step_s']:.4g}",
            f"{sp:.2f}x", f"{100 * b['roofline_fraction']:.2f}",
            f"{100 * o['roofline_fraction']:.2f}"))
    if gains:
        g = 1.0
        for x in gains:
            g *= x
        print(f"\ngeomean step-bound speedup: {g ** (1 / len(gains)):.2f}x "
              f"over {len(gains)} cells")


if __name__ == "__main__":
    main()
