"""Sensitivity companion to Fig. 11: why our geomean is conservative.

EXPERIMENTS.md §Paper-validation argues the 2.59x-vs-4.6x gap comes from
our fixed baseline being allowed to stream the whole free tile dimension
(input-bandwidth-optimal).  Here we bound the baselines' free dim to one
array side (128) — modeling a baseline that re-preloads per tile — while
ReDas keeps the full mapper.  If the argument is right, the geomean
moves toward the paper's 4.6x.
"""

from __future__ import annotations

from repro.core.accelerators import SPECS
from repro.core.energy import vector_cycles
from repro.core.mapper import ReDasMapper
from repro.core.workloads import WORKLOADS

from .common import MODELS, csv_row, geomean, timed


def compute(bound: int = 128) -> dict:
    out = {}
    for m in MODELS:
        gemms = WORKLOADS[m].gemms
        vec = vector_cycles(WORKLOADS[m].vector_elements)
        tpu_b = ReDasMapper(SPECS["tpu"], max_free_dim=bound).map_model(gemms)
        redas = ReDasMapper(SPECS["redas"]).map_model(gemms)
        out[m] = (tpu_b.total_cycles + vec) / (redas.total_cycles + vec)
    return out


def main() -> list[str]:
    with timed() as t:
        r = compute()
    rows = [csv_row(
        "fig11s.geomean_vs_bounded_baseline", t.us,
        f"{geomean(r.values()):.2f}x (unbounded-baseline 2.59x; paper 4.6x)")]
    for m in MODELS:
        rows.append(csv_row(f"fig11s.{m}", 0, f"{r[m]:.2f}x"))
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
