"""Run every paper-table/figure benchmark; print name,us_per_call,derived
CSV.  ``PYTHONPATH=src python -m benchmarks.run [--only fig11,...] [--list]``

Exit code is the number of failed modules (capped at 125 so it never
collides with signal exit statuses); ``--list`` prints the module names
and exits without importing anything heavy (no jax import)."""

from __future__ import annotations

import argparse
import importlib
import sys
import traceback

MODULES = (
    "fig03_ideal",
    "fig11_speedup",
    "fig12_power",
    "fig14_util",
    "fig15_breakdown",
    "fig16_edp",
    "fig17_adp",
    "fig18_sensitivity",
    "fig19_mapper",
    "fig11_sensitivity",
    "fig20_21_distribution",
    "fig22_casestudy",
    "kernel_bench",
)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module subset")
    ap.add_argument("--list", action="store_true",
                    help="print module names and exit (imports nothing)")
    args = ap.parse_args()
    if args.list:
        print("\n".join(MODULES))
        return 0
    mods = args.only.split(",") if args.only else MODULES
    print("name,us_per_call,derived")
    failures = 0
    for name in mods:
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            for row in mod.main():
                print(row, flush=True)
        except Exception:
            failures += 1
            print(f"{name},0,ERROR", flush=True)
            traceback.print_exc(file=sys.stderr)
    return min(failures, 125)  # exit status == failure count


if __name__ == "__main__":
    raise SystemExit(main())
