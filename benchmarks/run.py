"""Run every paper-table/figure benchmark; print name,us_per_call,derived
CSV.  ``PYTHONPATH=src python -m benchmarks.run [--only fig11,...] [--list]``

The module list is DISCOVERED from ``benchmarks/*.py`` (nothing to edit
when a new bench lands) and classified by each module's ``main``
signature — parsed with ``ast``, so ``--list`` imports nothing heavy:

  * ``rows`` — ``main() -> list[str]`` (fig*, kernel_bench): run here,
    rows printed as CSV;
  * ``standalone`` — ``main(argv=None) -> int`` (bench, serve_bench,
    quant_bench, spec_bench, sparse_bench): own CLI, JSON output and
    hard gates; run individually by the CI bench lane
    (``benchmarks.check_baselines`` lints that every one appears there),
    listed but not run from this driver;
  * ``viewer`` — ``main() -> None`` (roofline_table, dryrun_compare):
    render ``runs/`` artifacts; listed but not run from this driver.

Exit code is the number of failed modules (capped at 125 so it never
collides with signal exit statuses)."""

from __future__ import annotations

import argparse
import ast
import importlib
import pathlib
import sys
import traceback

EXCLUDE = {"__init__", "common", "run", "trend", "check_baselines"}


def _classify(path: pathlib.Path) -> str:
    """rows / standalone / viewer, from the module's main() signature
    (ast-parsed: no import, so --list stays jax-free)."""
    tree = ast.parse(path.read_text())
    for node in tree.body:
        if isinstance(node, ast.FunctionDef) and node.name == "main":
            if node.args.args or node.args.kwonlyargs:
                return "standalone"
            ret = ast.unparse(node.returns) if node.returns else ""
            return "rows" if "list" in ret else "viewer"
    return "viewer"


def discover() -> list[tuple[str, str]]:
    """Sorted (module_name, kind) for every bench under benchmarks/."""
    here = pathlib.Path(__file__).resolve().parent
    return sorted((p.stem, _classify(p)) for p in here.glob("*.py")
                  if p.stem not in EXCLUDE)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module subset")
    ap.add_argument("--list", action="store_true",
                    help="print discovered module names (+ kind) and exit "
                         "(imports nothing heavy)")
    args = ap.parse_args()
    found = discover()
    if args.list:
        for name, kind in found:
            print(f"{name:24s} {kind}")
        return 0
    kinds = dict(found)
    mods = args.only.split(",") if args.only else [
        n for n, k in found if k == "rows"]
    print("name,us_per_call,derived")
    failures = 0
    for name in mods:
        if kinds.get(name) == "standalone":
            failures += 1
            print(f"{name},0,STANDALONE", flush=True)
            print(f"{name}: standalone bench with its own CLI; run "
                  f"`python -m benchmarks.{name}` directly",
                  file=sys.stderr)
            continue
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            # viewers argparse sys.argv; present them their defaults
            saved, sys.argv = sys.argv, [f"benchmarks.{name}"]
            try:
                rows = mod.main() or ()
            finally:
                sys.argv = saved
            for row in rows:
                print(row, flush=True)
        except Exception:
            failures += 1
            print(f"{name},0,ERROR", flush=True)
            traceback.print_exc(file=sys.stderr)
    return min(failures, 125)  # exit status == failure count


if __name__ == "__main__":
    raise SystemExit(main())
