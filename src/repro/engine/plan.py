"""The unified decision surface: requests, decisions, and the plan cache.

ReDas's core contribution is *one* per-layer decision (logical shape x
dataflow x buffer split) chosen ahead of execution (Sec. 4.3).  This
module is that surface as an API, shared by both planes:

  KernelRequest   — what the caller wants computed (op + problem dims),
                    the engine analogue of `core.analytical_model.GEMM`.
  KernelDecision  — how to compute it (dataflow, blocks, backend, modeled
                    cost) — replaces the old `MappingConfig`-vs-
                    `TPUKernelConfig` split with one dataclass both the
                    ASIC and TPU cost models emit.
  ExecutionPlan   — the per-op decision table: the paper's "repeated GEMM
                    shapes reuse the previous choice" decision cache,
                    with hit/miss stats and byte-stable JSON save/load so
                    a serving process can warm-start from a previous
                    planning run instead of re-searching at first trace.

No jax imports here: plans are plain data and load without pulling in
the compute stack (`import repro` stays lightweight).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Iterator

PLAN_FORMAT = "redas-execution-plan-v1"

#: ops the engine knows how to plan and dispatch.  "gemm_w8" is a gemm
#: whose right operand is pre-quantized int8 storage (ISSUE 5): it plans
#: through the same search as "gemm" but keys separately so a plan can
#: hold both postures side by side.  "gemm_sparse" is a gemm whose right
#: operand is N:M structured-sparse storage (ISSUE 8, `repro.sparse`):
#: the request's `density` scales its effective FLOPs/bytes in both cost
#: models and keys it apart from any dense sibling.
KNOWN_OPS = ("gemm", "grouped_gemm", "attention", "gemm_w8",
             "paged_attention", "gemm_sparse")


@dataclasses.dataclass(frozen=True)
class KernelRequest:
    """One kernel invocation the engine must decide a schedule for.

    `m, k, n` are the GEMM dims ((M, K) @ (K, N)); for `grouped_gemm`
    they are the per-group dims and `groups` is the expert count E; for
    `attention` m = query length, n = kv length, k = head dim.
    `density` is the kept-weight fraction of a structured-sparse right
    operand (N/M for N:M storage, 1.0 = dense) — part of the cache key
    so sparse and dense siblings of the same shape never share a
    decision.  `name` is a human label only — it is excluded from the
    cache key so repeated shapes share one decision regardless of which
    layer asked.
    """

    op: str
    m: int
    k: int
    n: int
    groups: int = 1
    in_bytes: int = 2
    out_bytes: int = 2
    density: float = 1.0
    name: str = ""

    def __post_init__(self):
        if self.op not in KNOWN_OPS:
            raise ValueError(f"unknown op {self.op!r} (known: {KNOWN_OPS})")
        if min(self.m, self.k, self.n, self.groups) < 1:
            raise ValueError(f"degenerate request {self}")
        if not 0.0 < self.density <= 1.0:
            raise ValueError(f"density must be in (0, 1], got {self.density}")

    def key(self) -> tuple:
        """The decision-cache key (shape identity, name excluded)."""
        return (self.op, self.m, self.k, self.n, self.groups,
                self.in_bytes, self.out_bytes, self.density)


@dataclasses.dataclass(frozen=True)
class KernelDecision:
    """A chosen schedule for one `KernelRequest`.

    `dataflow` + (`bm`, `bk`, `bn`) are the per-op schedule (on the ASIC
    plane the tile dims; on TPU the Pallas block dims), `backend` names
    the `KernelRegistry` entry that executes it, `seconds` is the cost
    model's estimate for one call, and `meta` carries plane-specific
    extras (loop order, logical shape, buffer allocation, cycles) as a
    sorted tuple of (key, value) pairs so decisions stay hashable and
    JSON-stable.
    """

    op: str
    dataflow: str
    bm: int
    bk: int
    bn: int
    backend: str = ""
    cost_model: str = ""
    seconds: float = 0.0
    meta: tuple[tuple[str, object], ...] = ()

    @property
    def meta_dict(self) -> dict:
        return dict(self.meta)

    def as_json_dict(self, request: KernelRequest) -> dict:
        return {
            "request": {
                "op": request.op, "m": request.m, "k": request.k,
                "n": request.n, "groups": request.groups,
                "in_bytes": request.in_bytes, "out_bytes": request.out_bytes,
                "density": request.density,
            },
            "dataflow": self.dataflow,
            "bm": self.bm, "bk": self.bk, "bn": self.bn,
            "backend": self.backend,
            "cost_model": self.cost_model,
            "seconds": self.seconds,
            "meta": {str(k): v for k, v in self.meta},
        }

    @classmethod
    def from_json_dict(cls, d: dict) -> tuple[KernelRequest, "KernelDecision"]:
        req = KernelRequest(**d["request"])
        dec = cls(
            op=req.op, dataflow=d["dataflow"],
            bm=d["bm"], bk=d["bk"], bn=d["bn"],
            backend=d["backend"], cost_model=d["cost_model"],
            seconds=d["seconds"],
            meta=tuple(sorted(d["meta"].items())),
        )
        return req, dec


@dataclasses.dataclass
class ExecutionPlan:
    """Per-op decisions + the unified decision cache (Sec. 4.3).

    One plan serves one (cost model, backend) posture: `lookup` counts
    hits/misses, `save`/`load` round-trip byte-identically (sorted keys,
    fixed indentation, trailing newline) so a plan artifact can be
    diffed and shipped to a serving job for warm-start.
    """

    cost_model: str = ""
    backend: str = ""
    decisions: dict[tuple, KernelDecision] = dataclasses.field(default_factory=dict)
    requests: dict[tuple, KernelRequest] = dataclasses.field(default_factory=dict)
    hits: int = 0
    misses: int = 0

    def __len__(self) -> int:
        return len(self.decisions)

    def __iter__(self) -> Iterator[tuple[KernelRequest, KernelDecision]]:
        for key in sorted(self.decisions):
            yield self.requests[key], self.decisions[key]

    # -- cache protocol ----------------------------------------------------

    def lookup(self, request: KernelRequest) -> KernelDecision | None:
        """Cache probe with hit/miss accounting."""
        dec = self.decisions.get(request.key())
        if dec is None:
            self.misses += 1
        else:
            self.hits += 1
        return dec

    def add(self, request: KernelRequest, decision: KernelDecision) -> None:
        key = request.key()
        self.decisions[key] = decision
        self.requests[key] = request

    @property
    def stats(self) -> dict:
        total = self.hits + self.misses
        return {
            "decisions": len(self.decisions),
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": (self.hits / total) if total else 0.0,
        }

    # -- persistence -------------------------------------------------------

    def to_json(self) -> str:
        payload = {
            "format": PLAN_FORMAT,
            "cost_model": self.cost_model,
            "backend": self.backend,
            "stats": {"hits": self.hits, "misses": self.misses},
            "decisions": [self.decisions[k].as_json_dict(self.requests[k])
                          for k in sorted(self.decisions)],
        }
        return json.dumps(payload, indent=2, sort_keys=True) + "\n"

    def save(self, path) -> None:
        with open(path, "w") as fh:
            fh.write(self.to_json())

    @classmethod
    def from_json(cls, text: str) -> "ExecutionPlan":
        payload = json.loads(text)
        if payload.get("format") != PLAN_FORMAT:
            raise ValueError(
                f"not an execution plan (format={payload.get('format')!r})")
        plan = cls(cost_model=payload["cost_model"],
                   backend=payload["backend"],
                   hits=payload["stats"]["hits"],
                   misses=payload["stats"]["misses"])
        for d in payload["decisions"]:
            req, dec = KernelDecision.from_json_dict(d)
            plan.add(req, dec)
        return plan

    @classmethod
    def load(cls, path) -> "ExecutionPlan":
        with open(path) as fh:
            return cls.from_json(fh.read())
