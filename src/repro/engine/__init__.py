"""repro.engine — the unified "decide then execute" API (ISSUE 3).

One decision surface for both planes:

  * `CostModel` protocol — `TPUModel` (plane-2 v5e roofline) and
    `AnalyticalCostModel` (plane-1 ReDas ASIC mapper) both emit unified
    `KernelDecision`s for `KernelRequest`s.
  * `KernelRegistry` — named backends ("pallas-tpu", "pallas-interpret",
    "xla-einsum", "simulator") the kernels register into.
  * `ExecutionPlan` — the per-op decision cache (hit/miss stats, JSON
    save/load for serve warm-start), produced ahead of time by
    `plan_arch` over `core.workloads.arch_gemms` traces.
  * `Engine` / `use_engine` — the context models route matmuls through
    (replaces `use_redas_kernels` + direct `auto_matmul` calls).

Importing this package is jax-free; jax loads at first dispatch.
"""

from .context import (INT8_BACKENDS, SPARSE_BACKENDS, Engine,
                      active_engine, backend_in_bytes, decode_requests,
                      default_engine, int8_sibling, matmul, plan_arch,
                      sparse_sibling, use_engine)
from .cost import AnalyticalCostModel, CostModel, TPUModel
from .plan import ExecutionPlan, KernelDecision, KernelRequest
from .registry import BACKENDS, KernelRegistry, default_registry

__all__ = [
    "Engine", "INT8_BACKENDS", "SPARSE_BACKENDS", "active_engine",
    "backend_in_bytes", "decode_requests", "default_engine",
    "int8_sibling", "sparse_sibling", "matmul", "plan_arch", "use_engine",
    "AnalyticalCostModel", "CostModel", "TPUModel",
    "ExecutionPlan", "KernelDecision", "KernelRequest",
    "BACKENDS", "KernelRegistry", "default_registry",
]
