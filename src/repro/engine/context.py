"""The Engine: decide (cost model + plan cache) then execute (registry).

This is the single entry point the models route matmuls through
(it replaced the pre-engine `layers.USE_REDAS_KERNEL` global + direct
per-op dispatch, both long gone):

    from repro import engine

    with engine.use_engine():                  # mapper-planned Pallas
        logits, _ = transformer.forward(params, cfg, tokens)

    plan = engine.plan_arch(arch_cfg, seq_len=512)   # plan ahead of time
    plan.save("plan.json")                           # serve warm-start

    with engine.use_engine(backend="xla-einsum"):    # baseline numerics,
        ...                                          # same decision path

Decisions happen at jit-trace time (shapes are static there), exactly
like the old per-shape lru caches — but now every op shares ONE
`ExecutionPlan` cache with hit/miss stats, one `CostModel`, and one
backend namespace.  Everything here is jax-free until the first
dispatch; planning an arch imports only numpy-level code.

Trace-time caveat (same as the old USE_REDAS_KERNEL global): the engine
context is consulted when a function is TRACED, not when a compiled
executable re-runs.  A callable jitted outside `use_engine` and then
called inside it (with the same avals) hits the jit cache and keeps its
XLA-native kernels — jit inside the context, as train_lib/serve_lib do.
"""

from __future__ import annotations

import contextlib
import dataclasses

from .cost import CostModel, TPUModel
from .plan import ExecutionPlan, KernelDecision, KernelRequest
from .registry import KernelRegistry, default_registry

_STACK: list["Engine"] = []

#: backends that execute through the int8 quantization plane (ISSUE 5).
#: Their requests are keyed at in_bytes=1 regardless of the float dtype
#: the arrays arrive in — the kernel quantizes operands before the MXU,
#: so the cost model must size tiles (and the plan cache must key) on
#: the bytes that actually move.
INT8_BACKENDS = ("pallas-tpu-int8", "xla-int8")


def backend_in_bytes(backend: str | None, itemsize: int) -> int:
    """The in_bytes a request dispatched on `backend` is keyed with:
    the operand itemsize, except int8 backends pin it to 1 (precision
    is part of the decision-cache key)."""
    return 1 if backend in INT8_BACKENDS else itemsize


#: backends that execute through the N:M structured-sparsity plane
#: (ISSUE 8).  Their `gemm_sparse` requests carry the storage density
#: in the decision key; everything else dispatches like the float plane.
SPARSE_BACKENDS = ("pallas-tpu-sparse", "xla-sparse")


#: float backend -> its int8 sibling (quantize=True config upgrade).
#: Both Pallas spellings map to "pallas-tpu-int8", which auto-resolves
#: interpret mode off-TPU; int8 names pass through.
_INT8_SIBLING = {
    "xla-einsum": "xla-int8",
    "pallas-tpu": "pallas-tpu-int8",
    "pallas-interpret": "pallas-tpu-int8",
    "pallas-tpu-int8": "pallas-tpu-int8",
    "xla-int8": "xla-int8",
}


#: backend -> its sparse sibling (sparsity=<N:M> config upgrade).  Both
#: Pallas spellings map to "pallas-tpu-sparse" (interpret auto-resolves
#: off-TPU); the int8 names also upgrade — sparse×int8 stores int8
#: values + scales INSIDE the SparseTensor, so the sparse backends
#: subsume the int8 ones when both knobs are set; sparse names pass
#: through.
_SPARSE_SIBLING = {
    "xla-einsum": "xla-sparse",
    "pallas-tpu": "pallas-tpu-sparse",
    "pallas-interpret": "pallas-tpu-sparse",
    "xla-int8": "xla-sparse",
    "pallas-tpu-int8": "pallas-tpu-sparse",
    "pallas-tpu-sparse": "pallas-tpu-sparse",
    "xla-sparse": "xla-sparse",
}


def sparse_sibling(backend: str | None) -> str:
    """The sparse backend a `sparsity="N:M"` Serve/Train config executes
    on instead of `backend`; raises with the known names otherwise.
    `None` resolves per host like `int8_sibling`: the Pallas sparse
    kernel on a TPU, the XLA reference elsewhere."""
    if backend is None:
        import jax  # deferred: config construction must not force jax early

        return ("pallas-tpu-sparse" if jax.default_backend() == "tpu"
                else "xla-sparse")
    sibling = _SPARSE_SIBLING.get(backend)
    if sibling is None:
        raise ValueError(
            f"sparsity cannot upgrade kernel_backend {backend!r} to a "
            f"sparse sibling (known: {sorted(_SPARSE_SIBLING)})")
    return sibling


def int8_sibling(backend: str | None) -> str:
    """The int8 backend a `quantize=True` Serve/Train config executes
    on instead of `backend`; raises with the known names otherwise.
    `None` resolves per host exactly like the float plane's default
    (`Engine._resolve_backend`): the Pallas int8 kernel on a TPU, the
    XLA reference elsewhere (interpret-mode Pallas would crawl on CPU
    serving paths)."""
    if backend is None:
        import jax  # deferred: config construction must not force jax early

        return ("pallas-tpu-int8" if jax.default_backend() == "tpu"
                else "xla-int8")
    sibling = _INT8_SIBLING.get(backend)
    if sibling is None:
        raise ValueError(
            f"quantize=True cannot upgrade kernel_backend {backend!r} to "
            f"an int8 sibling (known: {sorted(_INT8_SIBLING)})")
    return sibling


def _dtype_bytes(x) -> int:
    return int(x.dtype.itemsize)


def _as_arrays(*xs):
    """jax arrays (and tracers) pass through untouched; plain numpy /
    python inputs — which the pre-engine `auto_matmul` accepted via jit
    auto-conversion — are converted so `.aval` keying works."""
    if all(hasattr(x, "aval") for x in xs):
        return xs
    import jax.numpy as jnp

    return tuple(jnp.asarray(x) for x in xs)


class Engine:
    """One (cost model, backend, plan, registry) posture.

    `backend=None` auto-resolves at first dispatch: the cost model's
    `default_backend` if set (the ASIC plane's "simulator"), else
    "pallas-tpu" on a TPU host and "pallas-interpret" elsewhere.
    """

    def __init__(self, cost_model: CostModel | None = None, *,
                 backend: str | None = None,
                 plan: ExecutionPlan | None = None,
                 registry: KernelRegistry | None = None):
        self.cost_model = cost_model if cost_model is not None else TPUModel()
        self._backend = backend
        self.registry = registry if registry is not None else default_registry()
        self.plan = plan if plan is not None else ExecutionPlan(
            cost_model=self.cost_model.name, backend=backend or "auto")
        # Steady-state dispatch memo: raw shape key -> (decision, kernel).
        # The hot path (repeated shape, the common case by construction —
        # Sec. 4.3's decision reuse) costs one tuple build + dict hit; the
        # full KernelRequest/plan/registry machinery runs on miss only
        # (BENCH_PR3 gates the overhead at 5% of a direct kernel call).
        self._memo: dict[tuple, tuple] = {}

    # -- backend resolution ------------------------------------------------

    @property
    def backend(self) -> str:
        if self._backend is None:
            self._backend = self._resolve_backend()
        return self._backend

    def _resolve_backend(self) -> str:
        if getattr(self.cost_model, "default_backend", None):
            return self.cost_model.default_backend
        import jax  # deferred: planning alone must not import jax

        return "pallas-tpu" if jax.default_backend() == "tpu" else "pallas-interpret"

    @property
    def int8(self) -> bool:
        """True when this engine executes on the quantized plane."""
        return self.backend in INT8_BACKENDS

    @property
    def sparse(self) -> bool:
        """True when this engine executes on the structured-sparsity
        plane (`sparse_matmul` is dispatchable)."""
        return self.backend in SPARSE_BACKENDS

    # -- decide ------------------------------------------------------------

    def _rebind(self, request: KernelRequest,
                decision: KernelDecision) -> KernelDecision:
        """Bind a decision to this engine's backend.  ASIC-plane
        schedules (tile dims need not be Pallas/VREG aligned) only
        execute on the simulator backend — fail with intent instead of a
        Mosaic block-alignment error, whether the decision came from a
        warm-start plan or a fresh cost-model search."""
        if decision.backend == self.backend:
            return decision
        if "shape_rows" in dict(decision.meta) and self.backend != "simulator":
            raise ValueError(
                f"decision for {request.key()} was produced by an ASIC "
                f"cost model ({decision.cost_model!r}); its tile dims are "
                f"not Pallas-aligned — re-plan with a TPU cost model for "
                f"backend {self.backend!r}")
        return dataclasses.replace(decision, backend=self.backend)

    def decide(self, request: KernelRequest) -> KernelDecision:
        """Plan-cache lookup, cost-model search on miss."""
        hit = self.plan.lookup(request)
        if hit is not None:
            rebound = self._rebind(request, hit)
            if rebound is not hit:
                # warm-start plan recorded on another host kind: keep the
                # schedule, execute on this engine's backend.
                self.plan.add(request, rebound)
            return rebound
        decision = self._rebind(request, self.cost_model.decide(request))
        self.plan.add(request, decision)
        return decision

    def plan_gemms(self, gemms, *, in_bytes: int = 2,
                   out_bytes: int | None = None) -> "Engine":
        """Warm the plan from a GEMM trace (`core.analytical_model.GEMM`
        or (m, k, n) tuples); repeated shapes dedupe through the cache.
        `in_bytes` must match the serving dtype (2 = bf16, 4 = f32) or
        the runtime requests will miss the warm decisions."""
        out_bytes = out_bytes if out_bytes is not None else in_bytes
        for g in gemms:
            m, k, n = (g.M, g.K, g.N) if hasattr(g, "M") else g
            name = getattr(g, "name", "")
            self.decide(KernelRequest("gemm", m, k, n, in_bytes=in_bytes,
                                      out_bytes=out_bytes, name=name))
        return self

    # -- execute -----------------------------------------------------------

    def _resolve(self, key: tuple, op: str, m: int, k: int, n: int,
                 groups: int, item_bytes: int, *, density: float = 1.0,
                 in_bytes: int | None = None) -> tuple:
        """Miss path: full request -> decide -> registry, then memoize.
        On an int8 backend requests key at in_bytes=1 (the width the
        kernel actually moves in), so the same float shapes plan larger
        tiles and never collide with a full-precision plan entry; the
        OUTPUT stays the float compute width — the int8 kernels rescale
        the int32 accumulator to a float result, and the cost model must
        not undercount that output stream.  `density` keys sparse
        requests apart from dense siblings; `in_bytes` overrides the
        backend rule (sparse×int8 storage moves at 1 byte even though
        the sparse backends are not int8 backends)."""
        req = KernelRequest(op, m, k, n, groups=groups,
                            in_bytes=(in_bytes if in_bytes is not None
                                      else backend_in_bytes(self.backend,
                                                            item_bytes)),
                            out_bytes=item_bytes, density=density)
        dec = self.decide(req)
        entry = (dec, self.registry.get(dec.backend, op))
        self._memo[key] = entry
        return entry

    def matmul(self, a, b, *, out_dtype=None):
        """(M, K) @ (K, N) through the planned schedule for this shape."""
        # .aval (hashable shape+dtype) is the cheapest stable identity on
        # both concrete arrays and tracers — ~30x cheaper than touching
        # .shape/.dtype.name per call (BENCH_PR3 is sensitive to this).
        a, b = _as_arrays(a, b)
        key = ("gemm", a.aval, b.aval)
        hit = self._memo.get(key)
        if hit is not None:
            self.plan.hits += 1
            dec, fn = hit
            return fn(dec, a, b, out_dtype=out_dtype)
        m, k = a.shape
        k2, n = b.shape
        if k != k2:
            raise ValueError(f"matmul dim mismatch {a.shape} @ {b.shape}")
        dec, fn = self._resolve(key, "gemm", m, k, n, 1, _dtype_bytes(a))
        return fn(dec, a, b, out_dtype=out_dtype)

    def quant_matmul(self, a, w_q, w_scale, *, out_dtype=None):
        """(M, K) float @ pre-quantized (K, N) int8 weight storage
        (`quant.quantize_params`): dispatches the planned `gemm_w8`
        kernel — activations quantize dynamically inside it, the stored
        weight never materializes in float.  Only int8 backends register
        the op; call sites guard on `engine.int8`."""
        a, w_q, w_scale = _as_arrays(a, w_q, w_scale)
        key = ("gemm_w8", a.aval, w_q.aval)
        hit = self._memo.get(key)
        if hit is not None:
            self.plan.hits += 1
            dec, fn = hit
            return fn(dec, a, w_q, w_scale, out_dtype=out_dtype)
        m, k = a.shape
        k2, n = w_q.shape
        if k != k2:
            raise ValueError(f"matmul dim mismatch {a.shape} @ {w_q.shape}")
        dec, fn = self._resolve(key, "gemm_w8", m, k, n, 1, _dtype_bytes(a))
        return fn(dec, a, w_q, w_scale, out_dtype=out_dtype)

    def sparse_matmul(self, a, st, *, out_dtype=None):
        """(M, K) float @ N:M structured-sparse weight storage
        (`sparse.prune_params`): dispatches the planned `gemm_sparse`
        kernel — the compressed values/indices never densify in HBM.
        The request carries the storage density (N/M), so the plan
        never collides with a dense sibling of the same shape;
        sparse×int8 storage (int8 values + scales) keys at in_bytes=1.
        Only sparse backends register the op; call sites guard on
        `engine.sparse`."""
        scale = st.scale
        if scale is None:
            a, v, i = _as_arrays(a, st.values, st.indices)
            s_aval = None
        else:
            a, v, i, scale = _as_arrays(a, st.values, st.indices, scale)
            s_aval = scale.aval
        key = ("gemm_sparse", a.aval, v.aval, i.aval, s_aval, st.n, st.m)
        hit = self._memo.get(key)
        if hit is not None:
            self.plan.hits += 1
            dec, fn = hit
            return fn(dec, a, v, i, scale, n_keep=st.n, m_group=st.m,
                      out_dtype=out_dtype)
        m, k = a.shape
        n = v.shape[-1]
        if k != st.k_dense:
            raise ValueError(
                f"sparse matmul dim mismatch {a.shape} @ {st!r}")
        item_bytes = _dtype_bytes(a)
        dec, fn = self._resolve(
            key, "gemm_sparse", m, k, n, 1, item_bytes,
            density=st.n / st.m,
            in_bytes=1 if st.quantized else None)
        return fn(dec, a, v, i, scale, n_keep=st.n, m_group=st.m,
                  out_dtype=out_dtype)

    def grouped_matmul(self, x, w, *, out_dtype=None):
        """x (E, C, D) @ w (E, D, F) -> (E, C, F), per-expert."""
        x, w = _as_arrays(x, w)
        key = ("grouped_gemm", x.aval, w.aval)
        hit = self._memo.get(key)
        if hit is not None:
            self.plan.hits += 1
            dec, fn = hit
            return fn(dec, x, w, out_dtype=out_dtype)
        e, c, d = x.shape
        e2, d2, f = w.shape
        if (e, d) != (e2, d2):
            raise ValueError(f"grouped dim mismatch {x.shape} @ {w.shape}")
        dec, fn = self._resolve(key, "grouped_gemm", c, d, f, e,
                                _dtype_bytes(x))
        return fn(dec, x, w, out_dtype=out_dtype)

    def attention(self, q, k, v, *, causal: bool = True, window: int = 0):
        """q (B, H, Sq, D); k/v (B, H, Sk, D) (GQA heads pre-expanded)."""
        q, k, v = _as_arrays(q, k, v)
        key = ("attention", q.aval, k.aval, causal, window)
        hit = self._memo.get(key)
        if hit is not None:
            self.plan.hits += 1
            dec, fn = hit
            return fn(dec, q, k, v, causal=causal, window=window)
        b, h, sq, d = q.shape
        sk = k.shape[2]
        dec, fn = self._resolve(key, "attention", sq, d, sk, b * h,
                                _dtype_bytes(q))
        return fn(dec, q, k, v, causal=causal, window=window)

    def paged_attention(self, q, k_pages, v_pages, block_tables, kv_len, *,
                        k_scale=None, v_scale=None):
        """Paged decode attention (DESIGN.md §8): q (B, 1, H, D) over
        pools (P, page, KV, D) addressed through `block_tables`
        (B, n_bt); int8 pools pass their per-row scale pools alongside.
        Keyed like the runtime shape it is: n = the full page span the
        table can address (n_bt * page), groups = B * H."""
        q, k_pages, v_pages, block_tables, kv_len = _as_arrays(
            q, k_pages, v_pages, block_tables, kv_len)
        key = ("paged_attention", q.aval, k_pages.aval, block_tables.aval)
        hit = self._memo.get(key)
        if hit is not None:
            self.plan.hits += 1
            dec, fn = hit
            return fn(dec, q, k_pages, v_pages, block_tables, kv_len,
                      k_scale=k_scale, v_scale=v_scale)
        b, sq, h, d = q.shape
        span = block_tables.shape[1] * k_pages.shape[1]
        dec, fn = self._resolve(key, "paged_attention", sq, d, span, b * h,
                                _dtype_bytes(q))
        return fn(dec, q, k_pages, v_pages, block_tables, kv_len,
                  k_scale=k_scale, v_scale=v_scale)


# ---------------------------------------------------------------------------
# Context management
# ---------------------------------------------------------------------------


def active_engine() -> Engine | None:
    """The innermost `use_engine` engine, or None (XLA-native path)."""
    return _STACK[-1] if _STACK else None


@contextlib.contextmanager
def use_engine(engine: Engine | None = None, *, backend: str | None = None,
               cost_model: CostModel | None = None,
               plan: ExecutionPlan | None = None):
    """Route every `models.layers.dense` / `models.moe` matmul in scope
    through an engine.  Pass an existing `Engine` to share its plan
    across contexts, or kwargs to build a scoped one."""
    if engine is None:
        engine = Engine(cost_model, backend=backend, plan=plan)
    elif backend is not None or cost_model is not None or plan is not None:
        raise ValueError("pass either an engine or engine kwargs, not both")
    _STACK.append(engine)
    try:
        yield engine
    finally:
        _STACK.pop()


_DEFAULT: Engine | None = None


def default_engine() -> Engine:
    """Process-wide engine backing the module-level `matmul` when no
    `use_engine` context is active."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = Engine()
    return _DEFAULT


def matmul(a, b, *, out_dtype=None):
    """Module-level sugar: active engine if any, else the default one."""
    eng = active_engine() or default_engine()
    return eng.matmul(a, b, out_dtype=out_dtype)


# ---------------------------------------------------------------------------
# Ahead-of-time planning over a model's GEMM trace
# ---------------------------------------------------------------------------


def decode_requests(cfg, *, batch: int, dtype_bytes: int = 2,
                    seq: int = 1, quantized_weights: bool = False,
                    sparse_weights: bool = False, density: float = 0.5,
                    out_bytes: int | None = None, paged_pages: int = 0,
                    page_size: int = 0) -> tuple[KernelRequest, ...]:
    """The exact engine requests one `models.transformer.decode_step`
    issues at slot-pool size `batch` (M = batch: one token per slot).

    Unlike `core.workloads.arch_gemms` — the mapper's fused *search*
    view of a prefill pass — these mirror the runtime
    `models.layers.dense` / `models.moe._expert_ffn` calls
    per-projection, so a warm-started serving plan turns first-trace
    decode planning into pure cache lookups (the continuous-batching
    scheduler's decode shapes never change, so this one set covers
    every step it ever takes).  SSM in/out projections and the lm head
    are raw matmuls (not engine-routed) and do not appear.

    `seq > 1` instead describes one ragged ADMIT prefill at that padded
    width (M = batch * seq) — the scheduler's other fixed call shape.

    `quantized_weights=True` mirrors a `quant.quantize_params` server:
    the dense projections dispatch as `gemm_w8` (MoE expert stacks stay
    float grouped GEMMs — quantize_params skips them).
    `sparse_weights=True` mirrors a `sparse.prune_params` server the
    same way: dense projections dispatch as `gemm_sparse` at `density`
    (N/M of the pruning spec; grouped GEMMs stay dense — prune_params
    skips expert stacks too), and combined with `quantized_weights=True`
    the storage is sparse×int8, which the runtime keys at in_bytes=1.
    `out_bytes` (default: `dtype_bytes`) is the OUTPUT width — on an
    int8 posture pass dtype_bytes=1, out_bytes=<compute width>,
    matching how the runtime keys its requests (`Engine._resolve`)."""
    d, f, hd = cfg.d_model, cfg.d_ff, cfg.head_dim_
    nh, nkv = cfg.n_heads, cfg.n_kv
    tokens = batch * seq
    out_b = out_bytes if out_bytes is not None else dtype_bytes
    dense_in, dense_density = dtype_bytes, 1.0
    if sparse_weights:
        dense_op, dense_density = "gemm_sparse", density
        if quantized_weights:
            dense_in = 1  # sparse×int8: values move at one byte
    elif quantized_weights:
        dense_op = "gemm_w8"
    else:
        dense_op = "gemm"
    reqs: list[KernelRequest] = []

    def gemm(m, k, n, name):
        reqs.append(KernelRequest(dense_op, m, k, n, in_bytes=dense_in,
                                  out_bytes=out_b, density=dense_density,
                                  name=name))

    def mlp_reqs(prefix):
        if cfg.moe is not None:
            moe = cfg.moe
            # MoEConfig.capacity is jax-free (models.moe itself is not)
            rows = batch * moe.capacity(seq)  # _expert_ffn: (E, B, C, D)
            for m, k, n, nm in ((rows, d, f, "expert_up"),
                                (rows, f, d, "expert_down")):
                reqs.append(KernelRequest(
                    "grouped_gemm", m, k, n, groups=moe.n_experts,
                    in_bytes=dtype_bytes, out_bytes=out_b,
                    name=f"{prefix}/{nm}"))
        else:
            gemm(tokens, d, f, f"{prefix}/ffn_up")  # wi and wg share a shape
            gemm(tokens, f, d, f"{prefix}/ffn_down")

    for kind in sorted(set(cfg.layer_pattern)):
        if kind in ("attn", "local"):
            gemm(tokens, d, nh * hd, f"{kind}/wq")
            gemm(tokens, d, nkv * hd, f"{kind}/wk")  # wv is the same shape
            gemm(tokens, nh * hd, d, f"{kind}/wo")
            mlp_reqs(kind)
            if kind == "attn" and paged_pages and page_size and seq == 1:
                # paged decode gather-attention: n = the page span one
                # block-table row can address — exactly how the runtime
                # Engine.paged_attention keys its request
                reqs.append(KernelRequest(
                    "paged_attention", seq, hd, paged_pages * page_size,
                    groups=batch * nh, in_bytes=dtype_bytes,
                    out_bytes=out_b, name="attn/paged"))
        elif kind == "rglru":
            w = cfg.rglru_width or d
            gemm(tokens, d, w, "rglru/lin_x")  # lin_y is the same shape
            gemm(tokens, w, w, "rglru/gates")  # w_a and w_x
            gemm(tokens, w, d, "rglru/lin_out")
            mlp_reqs("rglru")
        # "ssm": no engine-routed matmuls in the decode path
    return tuple(reqs)


def plan_arch(cfg, *, seq_len: int | None = None, batch: int = 1,
              cost_model: CostModel | None = None,
              backend: str | None = None,
              dtype_bytes: int = 2,
              decode_batch: int | None = None,
              admit_widths: tuple[int, ...] = (),
              quantized_weights: bool = False,
              sparse_weights: bool = False, sparse_density: float = 0.5,
              paged_pages: int = 0, page_size: int = 0,
              verify_k: int = 0, prefill_chunk: int = 0) -> ExecutionPlan:
    """Plan every GEMM of one `repro.models.config.ArchConfig` prefill
    pass via the `core.workloads.arch_gemms` lowering and return the
    warm `ExecutionPlan` (save it for serve warm-start).  `dtype_bytes`
    is the serving compute dtype width (2 = bf16 default, 4 = f32); on
    an int8 `backend` the requests' INPUT width is forced to 1 (runtime
    requests there key at the quantized width, whatever float dtype the
    arrays carry) while outputs keep the compute width — the int8
    kernels rescale to float results.
    `decode_batch` additionally plans the fixed decode-step shapes for
    a slot pool of that size (see `decode_requests`) so a continuous-
    batching server's decode trace re-plans nothing; `admit_widths`
    does the same for its ragged-prefill admit widths (the scheduler's
    `prefill_bucket` multiples).  `quantized_weights` plans the decode/
    admit dense projections as `gemm_w8` (a `quant.quantize_params`
    server dispatches those instead of `gemm`); `sparse_weights` plans
    them as `gemm_sparse` at `sparse_density` (a `sparse.prune_params`
    server — both flags together describe sparse×int8 storage, keyed
    at in_bytes=1 like the runtime does).  `paged_pages` /
    `page_size` (a `cache_layout="paged"` server: slot_pages and the
    page size) additionally plan the paged decode gather-attention
    shape, so the paged scheduler's steady state also re-plans
    nothing.  `verify_k` (a `speculate_k=k` server) adds the k+1-wide
    speculative verify width — the only extra decode shape the
    speculative tick introduces (the draft's propose steps are the
    width-1 shapes, its prefill the admit widths; the paged verify
    bypasses the engine's paged_attention op entirely).  `prefill_chunk`
    (a `ServeConfig.prefill_chunk` server, DESIGN.md §12) adds the
    chunk width — every chunked-ingestion call is exactly that wide, so
    it is the ONE extra shape chunking introduces; the scheduler aligns
    the chunk to `prefill_bucket`, so when `admit_widths` covers the
    bucket multiples the chunk width is already planned and this kwarg
    merely makes the posture explicit."""
    from repro.core.workloads import ARCH_TRACE_SEQ, arch_gemms

    in_bytes = backend_in_bytes(backend, dtype_bytes)
    eng = Engine(cost_model, backend=backend)
    eng.backend  # resolve now so the plan records a concrete backend
    eng.plan.backend = eng.backend
    eng.plan_gemms(arch_gemms(cfg, seq_len=seq_len or ARCH_TRACE_SEQ,
                              batch=batch), in_bytes=in_bytes,
                   out_bytes=dtype_bytes)
    if decode_batch:
        widths = (1,) + tuple(admit_widths)
        if prefill_chunk and prefill_chunk not in widths:
            widths = widths + (prefill_chunk,)
        if verify_k:
            widths = widths + (verify_k + 1,)
        for width in widths:
            for req in decode_requests(cfg, batch=decode_batch,
                                       dtype_bytes=in_bytes, seq=width,
                                       quantized_weights=quantized_weights,
                                       sparse_weights=sparse_weights,
                                       density=sparse_density,
                                       out_bytes=dtype_bytes,
                                       paged_pages=paged_pages,
                                       page_size=page_size):
                eng.decide(req)
    return eng.plan
