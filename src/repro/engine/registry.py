"""KernelRegistry: named execution backends for planned decisions.

A backend is a name ("pallas-tpu", "pallas-interpret", "xla-einsum",
"simulator") mapping each op to a callable
``fn(decision, *arrays, **kw) -> array``.  The kernels own their
registrations: `kernels/redas_gemm.py`, `kernels/grouped_gemm.py` and
`kernels/flash_attention.py` each expose ``register_into(registry)``
(the FlexSA posture — one compile-time planner feeding heterogeneous
kernel modes), and `engine/backends.py` contributes the XLA-einsum
reference and the plane-1 cycle-level simulator backends.

Registration is lazy: the registry imports nothing until the first
dispatch, so building/planning with an Engine never drags in jax.
"""

from __future__ import annotations

from typing import Callable

#: the backends the default registry guarantees (ISSUE 3 surface; the
#: int8 pair is the ISSUE 5 quantization plane, the sparse pair the
#: ISSUE 8 structured-sparsity plane).
BACKENDS = ("pallas-tpu", "pallas-interpret", "xla-einsum", "simulator",
            "pallas-tpu-int8", "xla-int8",
            "pallas-tpu-sparse", "xla-sparse")


class KernelRegistry:
    """(backend, op) -> kernel dispatch table."""

    def __init__(self):
        self._kernels: dict[tuple[str, str], Callable] = {}
        self._loaders: list[Callable[["KernelRegistry"], None]] = []

    def register(self, backend: str, op: str, fn: Callable) -> None:
        self._kernels[(backend, op)] = fn

    def add_loader(self, loader: Callable[["KernelRegistry"], None]) -> None:
        """Defer `loader(registry)` until the first lookup (keeps kernel
        imports — and therefore jax — off the planning path)."""
        self._loaders.append(loader)

    def _materialize(self) -> None:
        while self._loaders:
            # pop only after success: a loader that raises (e.g. broken
            # jax install) stays queued, so the real ImportError resurfaces
            # on every dispatch instead of a misleading empty-registry
            # KeyError, and a later retry can still succeed.
            self._loaders[0](self)
            self._loaders.pop(0)

    def get(self, backend: str, op: str) -> Callable:
        self._materialize()
        try:
            return self._kernels[(backend, op)]
        except KeyError:
            raise KeyError(
                f"no kernel registered for backend={backend!r} op={op!r}; "
                f"have {sorted(self._kernels)}") from None

    def has(self, backend: str, op: str) -> bool:
        self._materialize()
        return (backend, op) in self._kernels

    def backends(self) -> tuple[str, ...]:
        self._materialize()
        return tuple(sorted({b for b, _ in self._kernels}))

    def ops(self, backend: str) -> tuple[str, ...]:
        self._materialize()
        return tuple(sorted(op for b, op in self._kernels if b == backend))


_DEFAULT: KernelRegistry | None = None


def _load_kernel_registrations(reg: KernelRegistry) -> None:
    from repro.kernels import (flash_attention, grouped_gemm,
                               paged_attention, quant_gemm, redas_gemm,
                               sparse_gemm)

    from . import backends

    redas_gemm.register_into(reg)
    grouped_gemm.register_into(reg)
    flash_attention.register_into(reg)
    quant_gemm.register_into(reg)
    sparse_gemm.register_into(reg)
    paged_attention.register_into(reg)
    backends.register_into(reg)


def default_registry() -> KernelRegistry:
    """The process-wide registry with all four named backends."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = KernelRegistry()
        _DEFAULT.add_loader(_load_kernel_registrations)
    return _DEFAULT
