"""Execution backends: shape-safe kernel entry points + registrations.

`pallas_gemm` is the shape-safe Pallas entry point: it pads arbitrary
(M, K, N) to the chosen block multiples, invokes
`kernels.redas_gemm.gemm`, and slices the result.  The engine's Pallas
backends dispatch planned decisions through it.

This module also registers the two non-Pallas backends:

  xla-einsum — plain XLA contractions (the dry-run / baseline path);
               decisions are still planned and cached, XLA just ignores
               the schedule.
  simulator  — functional execution of an ASIC-plane decision through
               `core.simulator.simulate_mapping` (the cycle-level
               logical-array model); requires the decision's meta to
               carry the full mapping (AnalyticalCostModel emits it).

Import cost: this is the one engine module that imports jax — the
Engine only imports it at first dispatch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import redas_gemm
from repro.kernels.redas_gemm import (VMEM_BYTES, DataflowName,
                                      default_blocks, vmem_bytes)
from repro.kernels.redas_gemm import round_up as _round_up

from .plan import KernelDecision

__all__ = ["auto_interpret", "default_blocks", "pallas_gemm", "register_into"]


def auto_interpret(interpret: bool | None) -> bool:
    """Pallas TPU lowering needs a real TPU; interpret elsewhere."""
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@functools.partial(
    jax.jit,
    static_argnames=("dataflow", "bm", "bk", "bn", "interpret", "out_dtype"))
def pallas_gemm(
    a: jax.Array,
    b: jax.Array,
    *,
    dataflow: DataflowName = "os",
    bm: int | None = None,
    bk: int | None = None,
    bn: int | None = None,
    interpret: bool | None = None,
    out_dtype=None,
) -> jax.Array:
    """(M, K) @ (K, N) for arbitrary dims: pad -> blocked Pallas GEMM -> slice."""
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"matmul dim mismatch {a.shape} @ {b.shape}")
    out_dtype = out_dtype or a.dtype
    dbm, dbk, dbn = default_blocks(m, k, n)
    bm, bk, bn = bm or dbm, bk or dbk, bn or dbn
    if vmem_bytes(bm, bk, bn, a.dtype) > VMEM_BYTES:
        raise ValueError(
            f"blocks ({bm},{bk},{bn}) exceed VMEM budget {VMEM_BYTES} (Eq. 2)")

    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    a_p = jnp.pad(a, ((0, mp - m), (0, kp - k))) if (mp, kp) != (m, k) else a
    b_p = jnp.pad(b, ((0, kp - k), (0, np_ - n))) if (kp, np_) != (k, n) else b
    out = redas_gemm.gemm(
        a_p, b_p, dataflow=dataflow, bm=bm, bk=bk, bn=bn,
        interpret=auto_interpret(interpret), out_dtype=out_dtype)
    return out[:m, :n] if (mp, np_) != (m, n) else out


# ---------------------------------------------------------------------------
# Backend adapters (decision -> kernel call)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _diff_gemm(dataflow: str, bm: int, bk: int, bn: int, interpret: bool,
               out_dtype):
    """Differentiable wrapper: the Pallas kernels have no JVP/transpose
    rules (scratch accumulators, input/output aliasing), so the VJP is
    defined at the dispatch layer — both cotangents are themselves GEMMs
    and run through the same Pallas entry point with VMEM-gated default
    blocks (dA = g @ B^T, dB = A^T @ g)."""

    @jax.custom_vjp
    def f(a, b):
        return pallas_gemm(a, b, dataflow=dataflow, bm=bm, bk=bk, bn=bn,
                           interpret=interpret, out_dtype=out_dtype)

    def fwd(a, b):
        return f(a, b), (a, b)

    def bwd(res, g):
        a, b = res
        da = pallas_gemm(g, b.T, interpret=interpret, out_dtype=a.dtype)
        db = pallas_gemm(a.T, g, interpret=interpret, out_dtype=b.dtype)
        return da, db

    f.defvjp(fwd, bwd)
    # jit the wrapper: an un-jitted custom_vjp call re-traces eagerly
    # (~200 us/call); jit keeps the C++ fast path AND the custom rule.
    return jax.jit(f)


def _gemm_backend(interpret: bool):
    def run(decision: KernelDecision, a, b, *, out_dtype=None):
        fn = _diff_gemm(decision.dataflow, decision.bm, decision.bk,
                        decision.bn, interpret, out_dtype)
        return fn(a, b)
    return run


def _xla_gemm(decision: KernelDecision, a, b, *, out_dtype=None):
    out = jnp.dot(a, b, preferred_element_type=jnp.float32)
    return out.astype(out_dtype or a.dtype)


def _xla_grouped(decision: KernelDecision, x, w, *, out_dtype=None):
    out = jnp.einsum("ecd,edf->ecf", x, w,
                     preferred_element_type=jnp.float32)
    return out.astype(out_dtype or x.dtype)


def _xla_attention(decision: KernelDecision, q, k, v, *, causal=True,
                   window=0):
    """Reference attention via the pure-jax chunked online softmax.
    q/k/v: (B, H, S, D) — the flash-kernel layout (GQA pre-expanded)."""
    from repro.models.layers import flash_attention  # lazy: models import

    b, h, sq, d = q.shape
    qs = q.transpose(0, 2, 1, 3)
    ks = k.transpose(0, 2, 1, 3)
    vs = v.transpose(0, 2, 1, 3)
    positions = jnp.broadcast_to(jnp.arange(sq)[None], (b, sq))
    kv_len = jnp.full((b,), k.shape[2], jnp.int32)
    o = flash_attention(qs, ks, vs, positions, kv_len, causal, window,
                        min(512, sq))
    return o.transpose(0, 2, 1, 3)


def _simulator_gemm(decision: KernelDecision, a, b, *, out_dtype=None):
    """Execute an ASIC-plane decision on the cycle-level simulator."""
    from repro.core.simulator import simulate_mapping

    from .cost import AnalyticalCostModel

    meta = decision.meta_dict
    if "shape_rows" not in meta:
        raise ValueError(
            "simulator backend needs an ASIC mapping in decision.meta "
            "(plan with AnalyticalCostModel, not TPUModel)")
    cfg = AnalyticalCostModel.mapping_config(decision)
    out, _ = simulate_mapping(a, b, cfg)
    return out.astype(out_dtype or jnp.asarray(a).dtype)


def register_into(registry) -> None:
    """xla-einsum + simulator backends (the Pallas backends are
    registered by the kernels themselves)."""
    registry.register("xla-einsum", "gemm", _xla_gemm)
    registry.register("xla-einsum", "grouped_gemm", _xla_grouped)
    registry.register("xla-einsum", "attention", _xla_attention)
    registry.register("simulator", "gemm", _simulator_gemm)
