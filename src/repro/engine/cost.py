"""Cost models behind the engine: one protocol, two planes.

`CostModel` is the structural interface the `Engine` plans through:
given a `KernelRequest`, produce a `KernelDecision`.  Two
implementations cover the repo's two decision planes:

  TPUModel            — the refactored plane-2 v5e roofline
                        (`core.tpu_model` holds the numeric primitives;
                        this class owns the search surface and emits
                        unified decisions instead of `TPUKernelConfig`).
  AnalyticalCostModel — the plane-1 ReDas ASIC: wraps `ReDasMapper` +
                        `AnalyticalModel` (Sec. 4.2-4.3) so the paper's
                        mapper answers through the same protocol and its
                        mapping lands in the same `KernelDecision`/plan
                        cache as the TPU dispatch.

Neither class imports jax; decisions are data.  Execution is the
`KernelRegistry`'s job (engine/registry.py, engine/backends.py).
"""

from __future__ import annotations

import dataclasses
from typing import Protocol, runtime_checkable

from .plan import KernelDecision, KernelRequest


def _meta(**kw) -> tuple[tuple[str, object], ...]:
    """Sorted (key, value) pairs — the canonical KernelDecision.meta form."""
    return tuple(sorted(kw.items()))


@runtime_checkable
class CostModel(Protocol):
    """What the Engine needs from a decision plane (structural typing:
    both planes satisfy this without inheriting anything)."""

    name: str
    #: backend the model's decisions execute on when the Engine has no
    #: override (None -> Engine picks a Pallas backend for the host).
    default_backend: str | None

    def decide(self, request: KernelRequest) -> KernelDecision:
        """Search the model's schedule space for `request` and return the
        chosen schedule (backend field may be left "" for the Engine to
        fill in)."""
        ...  # pragma: no cover - protocol


# ---------------------------------------------------------------------------
# Plane 2: TPU v5e roofline
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TPUModel:
    """The plane-2 decision surface as a CostModel.

    Wraps the `core.tpu_model` primitives (Pallas block ladders, Eq. 2
    VMEM gate, dataflow-aware HBM traffic, MXU ramp) behind `decide`.
    The interval-sampled search itself is `choose_kernel_config`, which
    stays module-level lru-cached in core — this class adds no second
    cache; the unified cache is the Engine's `ExecutionPlan`.
    """

    name: str = "tpu-v5e"
    default_backend: str | None = None  # Engine resolves a Pallas backend

    def decide(self, request: KernelRequest) -> KernelDecision:
        if request.op in ("attention", "paged_attention"):
            # paged decode is the same flash roofline with n = the page
            # span the block table can address (pages stream exactly once)
            return self._decide_attention(request)
        if request.op == "grouped_gemm":
            return self._decide_grouped(request)
        if request.op == "gemm_sparse":
            return self._decide_gemm_sparse(request)
        return self._decide_gemm(request)

    # -- gemm --------------------------------------------------------------

    def _decide_gemm(self, req: KernelRequest) -> KernelDecision:
        from repro.core import tpu_model as tm

        cfg = tm.choose_kernel_config(req.m, req.k, req.n, req.in_bytes)
        cost = tm.estimate(req.m, req.k, req.n, cfg, req.in_bytes,
                           req.out_bytes)
        return KernelDecision(
            op=req.op, dataflow=cfg.dataflow,
            bm=cfg.bm, bk=cfg.bk, bn=cfg.bn,
            cost_model=self.name, seconds=cost.seconds,
            meta=_meta(hbm_bytes=cost.hbm_bytes,
                       mxu_utilization=cost.mxu_utilization,
                       padding_efficiency=cost.padding_efficiency))

    # -- structured-sparse gemm (ISSUE 8) ----------------------------------

    def _decide_gemm_sparse(self, req: KernelRequest) -> KernelDecision:
        """Effective-FLOPs roofline for N:M weight sparsity: the MACs
        and weight bytes that matter scale by `density`, so the search
        runs at K_eff = density x K (the FlexSA view — a sparsity-aware
        array skips pruned groups), plus one index byte per kept value
        streamed with the weights.  The executed Pallas kernel
        reconstructs dense tiles in VMEM and realigns blocks itself
        (kernels/sparse_gemm.py), so the decision stays the planning
        identity — what matters is that sparse candidates RANK above
        their dense siblings in proportion to the work sparsity
        removes."""
        from repro.core import tpu_model as tm

        k_eff = max(1, round(req.k * req.density))
        cfg = tm.choose_kernel_config(req.m, k_eff, req.n, req.in_bytes)
        cost = tm.estimate(req.m, k_eff, req.n, cfg, req.in_bytes,
                           req.out_bytes)
        idx_bytes = float(k_eff * req.n)  # int8 in-group offsets
        return KernelDecision(
            op=req.op, dataflow=cfg.dataflow,
            bm=cfg.bm, bk=cfg.bk, bn=cfg.bn,
            cost_model=self.name,
            seconds=cost.seconds + idx_bytes / tm.HBM_BW,
            meta=_meta(hbm_bytes=cost.hbm_bytes + idx_bytes,
                       mxu_utilization=cost.mxu_utilization,
                       padding_efficiency=cost.padding_efficiency,
                       density=req.density, k_effective=k_eff))

    # -- grouped gemm ------------------------------------------------------

    def _decide_grouped(self, req: KernelRequest) -> KernelDecision:
        """Per-expert blocks through the same Eq.-2 VMEM gate as the
        dense path; the grouped kernel is OS-style (VMEM accumulator
        over the reduction sweep), so the search is pinned to OS."""
        from repro.core import tpu_model as tm

        best, best_t = None, float("inf")
        for bm in tm._ladder(req.m, tm.SUBLANE, 512):
            for bk in tm._ladder(req.k, tm.LANE, 2048):
                for bn in tm._ladder(req.n, tm.LANE, 512):
                    cfg = tm.TPUKernelConfig("os", bm, bk, bn)
                    if cfg.vmem_bytes(req.in_bytes) > tm.VMEM:
                        continue
                    t = tm.estimate(req.m, req.k, req.n, cfg,
                                    req.in_bytes, req.out_bytes).seconds
                    if t < best_t:
                        best, best_t = cfg, t
        assert best is not None, req
        return KernelDecision(
            op=req.op, dataflow="os",
            bm=best.bm, bk=best.bk, bn=best.bn,
            cost_model=self.name, seconds=best_t * req.groups,
            meta=_meta(groups=req.groups,
                       vmem_bytes=best.vmem_bytes(req.in_bytes)))

    # -- attention ---------------------------------------------------------

    def _decide_attention(self, req: KernelRequest) -> KernelDecision:
        """Flash-attention roofline: q/k/v/o HBM traffic only (the VMEM-
        resident online-softmax state never hits HBM).  m = Sq, n = Sk,
        k = head dim, groups = batch x heads."""
        from repro.core import tpu_model as tm

        sq, sk, d, bh = req.m, req.n, req.k, req.groups
        flops = 4.0 * bh * sq * sk * d            # QK^T + PV
        hbm = req.in_bytes * bh * d * (2 * sq + 2 * sk)
        seconds = max(flops / tm.PEAK_FLOPS, hbm / tm.HBM_BW)
        bq = min(512, sq)
        bk = min(512, sk)
        return KernelDecision(
            op=req.op, dataflow="os", bm=bq, bk=d, bn=bk,
            cost_model=self.name, seconds=seconds,
            meta=_meta(hbm_bytes=float(hbm), groups=bh))


# ---------------------------------------------------------------------------
# Plane 1: the ReDas ASIC (Sec. 4 mapper + Eq. 3-5 analytical model)
# ---------------------------------------------------------------------------


class AnalyticalCostModel:
    """The paper's mapper as a CostModel.

    One instance owns one `ReDasMapper` (bound to an `AcceleratorSpec`,
    default the ReDas array itself); `decide` lowers the request to a
    `core.analytical_model.GEMM`, runs the interval-sampling search, and
    re-expresses the winning `MappingConfig` as a `KernelDecision` whose
    meta carries the full ASIC mapping (logical shape, loop order,
    buffer allocation, modeled cycles) — enough for the `simulator`
    backend to execute it functionally.
    """

    default_backend: str | None = "simulator"

    def __init__(self, spec=None, *, array_size: int | None = None, **mapper_kw):
        from repro.core.accelerators import REDAS
        from repro.core.mapper import ReDasMapper

        self.spec = spec if spec is not None else REDAS
        self._array_size = array_size
        self._mapper_kw = mapper_kw
        self.mapper = ReDasMapper(self.spec, array_size=array_size, **mapper_kw)
        # word_bytes -> mapper: requests carry their operand width and
        # the multi-mode buffer holds capacity/word_bytes words, so a
        # wider dtype halves the tile space the search may allocate.
        self._mappers = {self.spec.word_bytes: self.mapper}
        self.name = f"redas-asic/{self.spec.name}"

    def _mapper_for(self, in_bytes: int):
        """The mapper sized for `in_bytes`-wide operands (the spec's
        native width — int8, Table 4 — reuses the primary mapper)."""
        mapper = self._mappers.get(in_bytes)
        if mapper is None:
            import dataclasses as _dc

            from repro.core.mapper import ReDasMapper

            spec = _dc.replace(self.spec, word_bytes=in_bytes)
            mapper = ReDasMapper(spec, array_size=self._array_size,
                                 **self._mapper_kw)
            self._mappers[in_bytes] = mapper
        return mapper

    def decide(self, request: KernelRequest) -> KernelDecision:
        from repro.core.analytical_model import GEMM

        if request.op in ("attention", "paged_attention"):
            raise ValueError(
                "the ASIC plane plans GEMMs; lower attention to its "
                "score/context GEMMs first (core.workloads.arch_gemms)")
        count = request.groups if request.op == "grouped_gemm" else 1
        k = request.k
        if request.op == "gemm_sparse":
            # effective-FLOPs lowering: the mapper sizes the logical
            # array for the contraction a sparsity-aware PE grid
            # actually performs (density x K), so a sparse candidate
            # ranks above its dense sibling at equal shape.
            k = max(1, round(k * request.density))
        gemm = GEMM(request.m, k, request.n, count=count,
                    name=request.name or "engine")
        d = self._mapper_for(request.in_bytes).map_gemm(gemm)
        cfg, rep = d.config, d.report
        return KernelDecision(
            op=request.op, dataflow=cfg.dataflow.value,
            bm=cfg.tile_m, bk=cfg.tile_k, bn=cfg.tile_n,
            cost_model=self.name,
            seconds=rep.cycles / self.spec.freq_hz,
            meta=_meta(shape_rows=cfg.shape.rows,
                       shape_cols=cfg.shape.cols,
                       loop_order=cfg.loop_order,
                       alloc_input=cfg.alloc[0],
                       alloc_weight=cfg.alloc[1],
                       alloc_output=cfg.alloc[2],
                       cycles=rep.cycles,
                       pe_utilization=rep.pe_utilization))

    @staticmethod
    def mapping_config(decision: KernelDecision):
        """Rebuild the ASIC `MappingConfig` a decision encodes (the
        simulator backend's input)."""
        from repro.core.analytical_model import MappingConfig
        from repro.core.dataflow import Dataflow, LogicalShape

        meta = decision.meta_dict
        return MappingConfig(
            dataflow=Dataflow(decision.dataflow),
            shape=LogicalShape(int(meta["shape_rows"]), int(meta["shape_cols"])),
            tile_m=decision.bm, tile_k=decision.bk, tile_n=decision.bn,
            loop_order=str(meta["loop_order"]),
            alloc=(float(meta["alloc_input"]), float(meta["alloc_weight"]),
                   float(meta["alloc_output"])),
        )
