"""AdamW in pure JAX: fp32 master weights + moments over bf16 compute
params, global-norm clipping, decoupled weight decay.

State is a plain pytree so the checkpoint layer and the FSDP sharding
rules treat it like params (moments inherit each param's PartitionSpec —
optimizer state is sharded exactly as its parameter).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: Callable[[jax.Array], jax.Array] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def init_state(params) -> dict[str, Any]:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "mu": jax.tree.map(f32, params),
        "nu": jax.tree.map(f32, params),
        # jnp.array copies: master must never alias the compute params
        # (donation would otherwise free one while the other lives).
        "master": jax.tree.map(lambda p: jnp.array(p, jnp.float32), params),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def _decayable(path) -> bool:
    """No decay on norms / scalars / biases (ndim < 2)."""
    return True  # resolved per-leaf by ndim below


def apply_updates(cfg: AdamWConfig, state, grads, param_dtype=jnp.bfloat16):
    """One AdamW step.  grads match params' structure (any float dtype —
    bf16 grads are the 'compressed all-reduce' path; moments are fp32).

    Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = cfg.lr(step) if callable(cfg.lr) else cfg.lr
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, mu, nu, master):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mhat = mu / b1c
        vhat = nu / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if master.ndim >= 2:  # decoupled decay on matrices only
            delta = delta + cfg.weight_decay * master
        master = master - lr * delta
        return mu, nu, master

    flat, treedef = jax.tree.flatten(grads)
    mu_f = treedef.flatten_up_to(state["mu"])
    nu_f = treedef.flatten_up_to(state["nu"])
    ma_f = treedef.flatten_up_to(state["master"])
    out = [upd(g, m, n, w) for g, m, n, w in zip(flat, mu_f, nu_f, ma_f, strict=True)]
    mu = jax.tree.unflatten(treedef, [o[0] for o in out])
    nu = jax.tree.unflatten(treedef, [o[1] for o in out])
    master = jax.tree.unflatten(treedef, [o[2] for o in out])
    params = jax.tree.map(lambda w: w.astype(param_dtype), master)
    new_state = {"step": step, "mu": mu, "nu": nu, "master": master}
    return params, new_state, {"grad_norm": gnorm, "lr": jnp.asarray(lr)}
