"""repro.quant — the int8 precision plane (ISSUE 5).

ReDas's multi-mode buffers win by reallocating a fixed on-chip budget to
match each layer's dataflow; the software analogue on TPU is shrinking
the bytes each operand occupies.  This package owns the quantized
representations:

  * `QuantizedTensor` — int8 values + float per-channel scales, a pytree
    (scans/jits slice it like any param leaf).
  * `quantize` / `dequantize` — symmetric per-channel round-trip with
    max-abs scaling (error <= scale/2 per element, property-tested).
  * `quantize_params` — swap every `models.layers.dense` weight for its
    quantized form (engine-routed call sites only; see the skip list).
  * `kv_quantize` / `kv_dequantize` — the per-row KV-cache codec behind
    ``ServeConfig(cache_dtype="int8")``.

Execution lives elsewhere: `kernels/quant_gemm.py` is the Pallas
int8 x int8 -> int32 kernel, registered into the engine as the
"pallas-tpu-int8" / "xla-int8" backends (DESIGN.md §7).
"""

from .quantize import (QuantizedTensor, dequantize, kv_dequantize,
                       kv_quantize, quantize, quantize_params, tree_bytes)

__all__ = [
    "QuantizedTensor", "dequantize", "kv_dequantize", "kv_quantize",
    "quantize", "quantize_params", "tree_bytes",
]
