"""Symmetric per-channel int8 quantization (weights + KV-cache codec).

Scale placement (DESIGN.md §7): scales sit on the axis that is NOT
contracted by the consuming GEMM, so dequantization commutes with the
matmul and the int32 accumulator can be rescaled once per output
element instead of once per multiply:

  weights  (…, K, N)  -> scale (…, 1, N): per OUTPUT channel, reduced
           over the contraction axis K.  y = (x_q @ w_q) * s_x * s_w.
  KV rows  (…, hd)    -> scale (…,): per stored row per kv head — each
           cache row is written once and read many times, so its scale
           rides along in the cache next to it.

Symmetric (zero-point-free) because every consumer feeds a GEMM whose
accumulator is int32: an asymmetric zero point would add a per-tile
correction GEMM for ~0.2 bits of range on weight distributions that are
centered anyway.  Max-abs scaling bounds round-trip error at scale/2
per element (tests/test_quant.py property-tests the bound).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

#: int8 symmetric range: +-127 keeps the codomain symmetric (no -128).
QMAX = 127.0

#: param-dict keys whose "w" leaf is consumed by a RAW `@` instead of
#: `models.layers.dense` — quantizing them would crash the caller, and
#: they are tiny (router) or fused-projection (ssm) anyway.
SKIP_KEYS = ("router", "in_proj", "out_proj")


@jax.tree_util.register_pytree_node_class
class QuantizedTensor:
    """int8 values + broadcastable float32 scales, as one pytree node.

    `q * scale` reconstructs the tensor; both children carry the same
    leading dims, so `lax.scan` over stacked params slices a
    QuantizedTensor exactly like a raw weight leaf.
    """

    def __init__(self, q, scale):
        self.q = q
        self.scale = scale

    @property
    def shape(self):
        return self.q.shape

    @property
    def ndim(self):
        return self.q.ndim

    def dequantize(self, dtype=jnp.float32):
        return (self.q.astype(jnp.float32) * self.scale).astype(dtype)

    def tree_flatten(self):
        return (self.q, self.scale), None

    @classmethod
    def tree_unflatten(cls, _aux, children):
        return cls(*children)

    def __repr__(self):
        return (f"QuantizedTensor(shape={tuple(self.q.shape)}, "
                f"scale_shape={tuple(jnp.shape(self.scale))})")


def _scale_for(x, axis: int):
    """Max-abs symmetric scale reducing `axis` (the contraction dim),
    kept as a broadcastable dim so q * scale reconstructs in place."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis, keepdims=True)
    return jnp.where(amax > 0.0, amax / QMAX, 1.0)


def quantize(x, axis: int = -2) -> QuantizedTensor:
    """Symmetric per-channel quantization of `x`, reducing `axis`.

    The default `axis=-2` is the matmul-weight convention: a (K, N)
    weight gets one scale per output channel N (shape (1, N)); stacked
    or grouped weights (P, K, N) / (E, K, N) get (P, 1, N) — per group
    per channel."""
    scale = _scale_for(x, axis)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -QMAX, QMAX)
    return QuantizedTensor(q.astype(jnp.int8), scale)


def dequantize(qt: QuantizedTensor, dtype=jnp.float32):
    return qt.dequantize(dtype)


def quantize_params(params):
    """Swap every `models.layers.dense` weight for its QuantizedTensor.

    Targets: dicts shaped `{"w": <float array, ndim >= 2>}` — the
    layers.dense param convention — EXCEPT under `SKIP_KEYS` (weights
    consumed by a raw `@`: the MoE router and the SSM in/out
    projections).  Everything else (norm scales, biases, conv filters,
    embeddings, MoE expert stacks) keeps its dtype; expert stacks stay
    float because `moe._expert_ffn` feeds the grouped-GEMM path whose
    activations dominate its footprint anyway."""

    def walk(node, skip: bool):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                child_skip = skip or k in SKIP_KEYS
                if (k == "w" and not skip
                        and hasattr(v, "ndim") and v.ndim >= 2
                        and jnp.issubdtype(v.dtype, jnp.floating)):
                    out[k] = quantize(v)
                else:
                    out[k] = walk(v, child_skip)
            return out
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v, skip) for v in node)
        return node

    return walk(params, False)


def tree_bytes(tree) -> int:
    """Total bytes of every array leaf (QuantizedTensor counts q + scale)."""
    return sum(leaf.size * leaf.dtype.itemsize
               for leaf in jax.tree.leaves(tree)
               if hasattr(leaf, "dtype"))


# ---------------------------------------------------------------------------
# KV-cache codec (ServeConfig.cache_dtype == "int8")
# ---------------------------------------------------------------------------


def kv_quantize(x):
    """Per-row cache codec: x (..., hd) float -> (q int8 (..., hd),
    scale float32 (...,)).  One scale per stored row per kv head — the
    row is the cache's write granularity (`layers.slot_update` writes
    whole rows), so the scale lives next to it and eviction/overwrite
    stay O(1) with no rescaling of neighbours."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scale = jnp.where(amax > 0.0, amax / QMAX, 1.0)
    q = jnp.clip(jnp.round(xf / scale[..., None]), -QMAX, QMAX)
    return q.astype(jnp.int8), scale


def kv_dequantize(q, scale, dtype=jnp.float32):
    """Inverse of `kv_quantize`: q (..., hd) int8, scale (...,) -> float."""
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)
