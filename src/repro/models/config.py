"""Architecture configuration schema covering all 10 assigned archs.

One dataclass describes every family (dense / MoE / SSM / hybrid /
encoder-only / VLM); per-arch modules in repro/configs instantiate it.
`layer_pattern` is the repeating block-kind period, e.g.:

    ("attn",)                      homogeneous decoder (qwen2, mistral, ...)
    ("local",)*5 + ("attn",)      gemma3 5:1 local:global
    ("rglru", "rglru", "local")   recurrentgemma 1:2 attn:RG-LRU
    ("ssm",)                       mamba2
"""

from __future__ import annotations

import dataclasses
import math
from typing import Literal

Kind = Literal["decoder", "encoder", "vlm"]
BlockKind = Literal["attn", "local", "ssm", "rglru"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    # dispatch implementation: "einsum" (GShard one-hot dispatch — the
    # §Roofline baseline) or "sort" (argsort + scatter/gather; removes the
    # tokens x E x C one-hot GEMMs — §Perf iteration for the MoE cells).
    impl: str = "einsum"

    def capacity(self, seq: int) -> int:
        """Per-expert buffer slots for a length-`seq` dispatch.  The
        single owner of the formula: `models.moe.capacity` (runtime) and
        `engine.decode_requests` (jax-free shape planning) both call it,
        so plan coverage can never drift from the runtime shapes."""
        c = math.ceil(seq * self.top_k * self.capacity_factor
                      / self.n_experts)
        return max(4 * ((c + 3) // 4), 4)  # pad to a lane-friendly multiple


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 256          # SSD chunk length


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    kind: Kind
    n_layers: int
    d_model: int
    n_heads: int          # 0 for attention-free archs
    n_kv: int
    d_ff: int
    vocab: int
    layer_pattern: tuple[BlockKind, ...] = ("attn",)
    head_dim: int = 0     # 0 -> d_model // n_heads
    window: int = 0       # sliding-window size for "local" blocks / SWA
    qkv_bias: bool = False
    qk_norm: bool = False
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    rglru_width: int = 0  # 0 -> d_model
    gated_mlp: bool = True        # SwiGLU; False -> GELU (encoder archs)
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # VLM / audio stub frontends: inputs arrive as precomputed embeddings.
    embed_inputs: bool = False    # audio: whole input is frame embeddings
    prefix_tokens: int = 0        # vlm: image patch embeds prepended
    # §Perf knob: cast the norm output to compute dtype before the scale
    # multiply (wins on every attention cell; see EXPERIMENTS.md §Perf
    # for the attention-free regression it can cause).
    norm_cast_early: bool = True
    sub_quadratic: bool = False   # eligible for long_500k decode
    max_seq: int = 131072

    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def is_causal(self) -> bool:
        return self.kind != "encoder"

    def param_count(self) -> int:
        """Exact dense-equivalent parameter count N (for 6*N*D roofline)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd, nh, nkv = self.head_dim_, self.n_heads, self.n_kv
        total = v * d * (1 if self.tie_embeddings else 2)
        per = {  # per block kind
            "attn": d * hd * (nh + 2 * nkv) + nh * hd * d + 3 * d * f + 2 * d,
            "local": d * hd * (nh + 2 * nkv) + nh * hd * d + 3 * d * f + 2 * d,
        }
        if self.ssm is not None:
            s = self.ssm
            d_in = s.expand * d
            heads = d_in // s.head_dim
            conv_ch = d_in + 2 * s.n_groups * s.d_state
            per["ssm"] = (
                d * (2 * d_in + 2 * s.n_groups * s.d_state + heads)  # in_proj
                + conv_ch * s.conv_width + 2 * heads + d_in * d + d)
        if self.rglru_width or "rglru" in self.layer_pattern:
            w = self.rglru_width or d
            per["rglru"] = d * w * 2 + w * d + 3 * w + w * 4 + 3 * d * f + 2 * d
        if self.moe is not None:
            e = self.moe.n_experts
            per["attn"] = d * hd * (nh + 2 * nkv) + nh * hd * d + d * e + e * 3 * d * f + 2 * d
            per["local"] = per["attn"]
        if not self.gated_mlp:
            for k in ("attn", "local"):
                per[k] = d * hd * (nh + 2 * nkv) + nh * hd * d + 2 * d * f + 2 * d
        for i in range(self.n_layers):
            total += per[self.layer_pattern[i % len(self.layer_pattern)]]
        return total

    def active_param_count(self) -> int:
        """MoE: experts scaled to top_k/n_experts (for 6*N_active*D)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        e, k = self.moe.n_experts, self.moe.top_k
        moe_blocks = self.n_layers  # all blocks are MoE in assigned archs
        expert_params = moe_blocks * e * 3 * self.d_model * self.d_ff
        return full - expert_params + math.ceil(expert_params * k / e)
