"""Mamba-2 SSD (state-space duality) blocks — mamba2-780m.

Chunked SSD form (Dao & Gu 2024): within a chunk the recurrence is the
masked matrix product (C B^T ⊙ L) x̄ (the "dual" attention-like GEMM —
exactly the irregular-GEMM payload the ReDas mapper schedules); across
chunks a short `lax.scan` carries the (H, N, P) state.  Decode is the
O(1) recurrent update on the same state, so long_500k runs with constant
memory.

Layer i/o follows Mamba-2: in_proj -> (z, x, B, C, dt), causal depthwise
conv over (x, B, C), SSD, gated RMSNorm, out_proj.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import dense_init, rms_norm

Array = jax.Array


def _dims(cfg):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    heads = d_in // s.head_dim
    conv_ch = d_in + 2 * s.n_groups * s.d_state
    return s, d_in, heads, conv_ch


def ssm_init(key, cfg) -> dict:
    s, d_in, heads, conv_ch = _dims(cfg)
    ks = jax.random.split(key, 4)
    d_proj = 2 * d_in + 2 * s.n_groups * s.d_state + heads
    return {
        "in_proj": dense_init(ks[0], cfg.d_model, d_proj),
        "conv_w": jax.random.normal(ks[1], (s.conv_width, conv_ch), jnp.float32)
        / math.sqrt(s.conv_width),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, heads)),
        "D": jnp.ones((heads,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[2], (heads,), jnp.float32,
                                       math.log(1e-3), math.log(1e-1))))),
        "norm": jnp.zeros((d_in,), jnp.float32),
        "out_proj": dense_init(ks[3], d_in, cfg.d_model),
    }


def _causal_conv(w: Array, b: Array, x: Array, state: Array | None = None,
                 act: bool = True):
    """Depthwise causal conv, width W.  x (B, L, C); state (B, W-1, C) for
    decode.  Returns (y, new_state)."""
    width = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype) for i in range(width))
    y = y + b.astype(x.dtype)
    new_state = xp[:, -(width - 1):]
    return (jax.nn.silu(y) if act else y), new_state


def ragged_conv_state(x: Array, lengths: Array, width: int) -> Array:
    """Per-slot decode state of `_causal_conv` after a ragged prefill.

    x (B, S, C) is the *raw* conv input (pre-activation); lengths (B,)
    the per-slot valid prefix.  Returns (B, width-1, C): the last
    width-1 valid rows of each slot, zero-padded on the left for slots
    shorter than the conv window — exactly the `new_state` a
    length-L un-padded `_causal_conv` call would have produced."""
    b, s, _ = x.shape
    w1 = width - 1
    idx = lengths[:, None].astype(jnp.int32) - w1 + jnp.arange(w1)[None, :]
    valid = idx >= 0
    st = jnp.take_along_axis(x, jnp.clip(idx, 0, s - 1)[:, :, None], axis=1)
    return jnp.where(valid[:, :, None], st, 0).astype(x.dtype)


def _split(p, cfg, u: Array):
    s, d_in, heads, _ = _dims(cfg)
    gn = s.n_groups * s.d_state
    z, xbc, dt = jnp.split(u, [d_in, 2 * d_in + 2 * gn], axis=-1)
    return z, xbc, dt, (s, d_in, heads, gn)


def ssd_chunked(x, dt, a_log, b_mat, c_mat, d_skip, chunk: int, h0=None):
    """x (B,L,H,P); dt (B,L,H) (post-softplus); b_mat,c_mat (B,L,G,N).

    Returns (y (B,L,H,P), final_state (B,H,N,P))."""
    bsz, slen, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    nc = -(-slen // chunk)
    pad = nc * chunk - slen
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    a = -jnp.exp(a_log.astype(jnp.float32))                    # (H,) negative
    da = dt.astype(jnp.float32) * a                            # (B,L,H)
    xbar = (x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None])

    def reshape_c(t, extra):  # (B, L, ...) -> (nc, B, chunk, ...)
        return t.reshape((bsz, nc, chunk) + extra).transpose(1, 0, 2, *range(3, 3 + len(extra)))

    da_c = reshape_c(da, (h,))
    x_c = reshape_c(xbar, (h, p))
    b_c = reshape_c(b_mat.astype(jnp.float32), (g, n))
    c_c = reshape_c(c_mat.astype(jnp.float32), (g, n))

    cs = jnp.cumsum(da_c, axis=2)                              # (nc,B,C,H)
    seg = cs[:, :, :, None, :] - cs[:, :, None, :, :]          # t,s
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    l_mat = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)

    # intra-chunk: (C B^T ⊙ L) x̄  — heads grouped over G
    cb = jnp.einsum("ubtgn,ubsgn->ubtsg", c_c, b_c)
    hpg = h // g
    cb_h = jnp.repeat(cb, hpg, axis=-1)                        # (nc,B,C,C,H)
    y_intra = jnp.einsum("ubtsh,ubtsh,ubshp->ubthp", cb_h, l_mat, x_c)

    # per-chunk terminal state and decay-to-end
    decay_end = jnp.exp(cs[:, :, -1:, :] - cs)                 # (nc,B,C,H)
    s_chunk = jnp.einsum("ubsh,ubshn,ubshp->ubhnp",
                         decay_end, _expand_groups(b_c, h), x_c)
    chunk_decay = jnp.exp(jnp.sum(da_c, axis=2))               # (nc,B,H)

    def scan_body(carry, inp):
        s_prev = carry
        s_new, dec = inp
        s_next = s_prev * dec[..., None, None] + s_new
        return s_next, s_prev

    init = (jnp.zeros((bsz, h, n, p), jnp.float32) if h0 is None
            else h0.astype(jnp.float32))
    s_final, s_starts = jax.lax.scan(scan_body, init, (s_chunk, chunk_decay))

    # inter-chunk: C_t · exp(cs_t) S_start
    y_inter = jnp.einsum("ubth,ubthn,ubhnp->ubthp",
                         jnp.exp(cs), _expand_groups(c_c, h), s_starts)
    y = (y_intra + y_inter).transpose(1, 0, 2, 3, 4).reshape(bsz, nc * chunk, h, p)
    y = y + d_skip.astype(jnp.float32)[None, None, :, None] * x.astype(jnp.float32)
    return y[:, :slen].astype(jnp.float32), s_final


def _expand_groups(t: Array, h: int) -> Array:
    """(nc,B,C,G,N) -> (nc,B,C,H,N) by repeating groups."""
    g = t.shape[3]
    if g == h:
        return t
    return jnp.repeat(t, h // g, axis=3)


def ssm_block(p, cfg, x: Array) -> Array:
    """Full-sequence SSD block (train / prefill). x (B, S, D)."""
    u = x @ p["in_proj"]["w"].astype(x.dtype)
    z, xbc, dt, (s, d_in, heads, gn) = _split(p, cfg, u)
    xbc, _ = _causal_conv(p["conv_w"], p["conv_b"], xbc)
    xs, b_mat, c_mat = jnp.split(xbc, [d_in, d_in + gn], axis=-1)
    bsz, length = x.shape[0], x.shape[1]
    xs = xs.reshape(bsz, length, heads, s.head_dim)
    b_mat = b_mat.reshape(bsz, length, s.n_groups, s.d_state)
    c_mat = c_mat.reshape(bsz, length, s.n_groups, s.d_state)
    dt_full = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    y, _ = ssd_chunked(xs, dt_full, p["A_log"], b_mat, c_mat, p["D"], s.chunk)
    y = y.reshape(bsz, length, d_in).astype(x.dtype)
    y = rms_norm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return y @ p["out_proj"]["w"].astype(x.dtype)


def ssm_decode_step(p, cfg, x, conv_state, ssd_state):
    """Single-token recurrent update.  x (B, 1, D); conv_state
    (B, W-1, conv_ch); ssd_state (B, H, N, P)."""
    u = x @ p["in_proj"]["w"].astype(x.dtype)
    z, xbc, dt, (s, d_in, heads, gn) = _split(p, cfg, u)
    xbc, conv_state = _causal_conv(p["conv_w"], p["conv_b"], xbc, conv_state)
    xs, b_mat, c_mat = jnp.split(xbc, [d_in, d_in + gn], axis=-1)
    bsz = x.shape[0]
    xs = xs.reshape(bsz, heads, s.head_dim).astype(jnp.float32)
    b_mat = _expand_groups(
        b_mat.reshape(1, bsz, 1, s.n_groups, s.d_state), heads)[0, :, 0]
    c_mat = _expand_groups(
        c_mat.reshape(1, bsz, 1, s.n_groups, s.d_state), heads)[0, :, 0]
    dt_f = jax.nn.softplus(dt.astype(jnp.float32)[:, 0] + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt_f * a)                                   # (B,H)
    xbar = xs * dt_f[..., None]
    ssd_state = (ssd_state * decay[..., None, None]
                 + jnp.einsum("bhn,bhp->bhnp", b_mat.astype(jnp.float32), xbar))
    y = jnp.einsum("bhn,bhnp->bhp", c_mat.astype(jnp.float32), ssd_state)
    y = y + p["D"].astype(jnp.float32)[None, :, None] * xs
    y = y.reshape(bsz, 1, d_in).astype(x.dtype)
    y = rms_norm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return y @ p["out_proj"]["w"].astype(x.dtype), conv_state, ssd_state
