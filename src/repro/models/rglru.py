"""RG-LRU recurrent block (RecurrentGemma / Griffin).

The temporal-mixing branch: linear_x -> causal conv(4) -> RG-LRU gated
linear recurrence, multiplied by a GELU side branch, projected back.

    r_t = sigmoid(W_a u_t)            recurrence gate
    i_t = sigmoid(W_x u_t)            input gate
    a_t = exp(-c * softplus(Lambda) * r_t),  c = 8
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

Training/prefill uses `jax.lax.associative_scan` over the sequence (the
recurrence h_t = a_t h_{t-1} + b_t is associative), so the whole layer is
parallel — this is what makes long_500k viable for the hybrid arch.
Decode is the O(1) per-token update.  W_a/W_x are dense here (the paper
uses block-diagonal; recorded in DESIGN.md §Assumptions).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from .layers import dense, dense_init
from .ssm import _causal_conv

Array = jax.Array
_C = 8.0


def rglru_init(key, cfg) -> dict:
    w = cfg.rglru_width or cfg.d_model
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    return {
        "lin_x": dense_init(ks[0], d, w),
        "lin_y": dense_init(ks[1], d, w),
        "conv_w": jax.random.normal(ks[2], (4, w), jnp.float32) / 2.0,
        "conv_b": jnp.zeros((w,), jnp.float32),
        "w_a": dense_init(ks[3], w, w),
        "w_x": dense_init(ks[4], w, w),
        # Lambda init so a^(1/c) ~ U[0.9, 0.999] as in the paper
        "lam": jnp.log(jnp.expm1(-jnp.log(
            jnp.linspace(0.9, 0.999, w, dtype=jnp.float32)))),
        "lin_out": dense_init(ks[5], w, d),
    }


def _gates(p, u: Array):
    r = jax.nn.sigmoid(dense(p["w_a"], u).astype(jnp.float32))
    i = jax.nn.sigmoid(dense(p["w_x"], u).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i * u.astype(jnp.float32))
    return a, b


def rglru_scan(p, u: Array, h0: Array | None = None,
               valid: Array | None = None):
    """u (B, S, W) -> (h (B, S, W), h_last (B, W)) via associative scan.

    `valid` (B, S) bool marks real rows in a ragged (right-padded)
    batch: pad rows become the identity element (a=1, b=0), so h is
    frozen past each slot's length and `h_last` is the state at that
    slot's final valid token."""
    a, b = _gates(p, u)
    if valid is not None:
        a = jnp.where(valid[..., None], a, 1.0)
        b = jnp.where(valid[..., None], b, 0.0)
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(u.dtype), h[:, -1]


def rglru_block(p, cfg, x: Array) -> Array:
    """Full-sequence temporal-mixing block (train / prefill)."""
    y = jax.nn.gelu(dense(p["lin_y"], x))
    u = dense(p["lin_x"], x)
    u, _ = _causal_conv(p["conv_w"], p["conv_b"], u, act=False)
    h, _ = rglru_scan(p, u)
    return dense(p["lin_out"], h * y)


def rglru_decode_step(p, cfg, x: Array, conv_state: Array, h: Array):
    """x (B, 1, D); conv_state (B, 3, W); h (B, W) -> (out, states)."""
    y = jax.nn.gelu(dense(p["lin_y"], x))
    u, conv_state = _causal_conv(p["conv_w"], p["conv_b"],
                                 dense(p["lin_x"], x), conv_state, act=False)
    a, b = _gates(p, u)
    h_new = (a[:, 0] * h.astype(jnp.float32) + b[:, 0])
    out = dense(p["lin_out"], h_new[:, None].astype(x.dtype) * y)
    return out, conv_state, h_new.astype(x.dtype)
