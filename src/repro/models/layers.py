"""Core model layers, pure JAX (no flax): params are plain dict pytrees.

Numerics follow the assigned-architecture families: RMSNorm, rotary
embeddings, grouped-query attention (optional QKV bias / qk-norm /
sliding window / bidirectional), SwiGLU or GELU MLPs.

Attention is a chunked online-softmax ("flash") implementation with a
custom VJP so the S x S logits never materialize in either pass — the
requirement that makes prefill_32k / train_4k shapes fit HBM.  Sliding-
window ("local") attention slices exactly the two KV chunks a query
chunk can see, so its FLOPs are O(S * window), not O(S^2).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

Array = jax.Array

# --------------------------------------------------------------------------
# Param init helpers
# --------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, *, bias: bool = False, scale: float | None = None):
    std = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": jax.random.normal(key, (d_in, d_out), jnp.float32) * std}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def dense(p, x: Array) -> Array:
    """Inside a `repro.engine.use_engine` context every dense matmul
    routes through the engine's planned kernel (mapper-chosen dataflow +
    blocks, unified decision cache — DESIGN.md §3); outside it, XLA
    einsum (the dry-run path; Pallas does not lower on the CPU
    host-device backend).

    `quant.quantize_params` weights (QuantizedTensor: int8 storage +
    per-channel scales) dispatch the planned `gemm_w8` kernel on an int8
    engine (the stored weight never materializes in float); on any other
    posture they dequantize to the compute dtype first (DESIGN.md §7).
    `sparse.prune_params` weights (SparseTensor: N:M compressed values +
    index metadata) dispatch the planned `gemm_sparse` kernel on a
    sparse engine (DESIGN.md §10) and densify on any other posture."""
    from repro.engine import active_engine
    from repro.quant import QuantizedTensor
    from repro.sparse import SparseTensor
    w = p["w"]
    eng = active_engine()
    quantized = isinstance(w, QuantizedTensor)
    sparse = isinstance(w, SparseTensor)
    x2d = x.reshape(-1, x.shape[-1])
    if sparse and eng is not None and eng.sparse:
        y2d = eng.sparse_matmul(x2d, w, out_dtype=x.dtype)
    elif quantized and eng is not None and eng.int8:
        y2d = eng.quant_matmul(x2d, w.q, w.scale, out_dtype=x.dtype)
    else:
        if sparse:
            wf = w.densify(x.dtype)
        elif quantized:
            wf = w.dequantize(x.dtype)
        else:
            wf = w.astype(x.dtype)
        y2d = (eng.matmul(x2d, wf, out_dtype=x.dtype) if eng is not None
               else x2d @ wf)
    y = y2d.reshape(*x.shape[:-1], w.shape[-1])
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def rms_norm(scale: Array, x: Array, eps: float = 1e-6,
             cast_early: bool = True) -> Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    if cast_early:
        # Cast back to the compute dtype BEFORE the scale multiply: the
        # norm output feeds matmuls whose operands GSPMD may reshard —
        # keeping the f32 intermediate out of that path halves any
        # resharding traffic (§Perf iteration 4/H8).  On attention-free
        # cells the partitioner instead trades collectives for local
        # traffic; ArchConfig.norm_cast_early=False restores the f32 path
        # per arch (EXPERIMENTS.md §Perf regressions note).
        normed = (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype)
        return normed * (1.0 + scale).astype(x.dtype)
    return ((x32 * jax.lax.rsqrt(var + eps))
            * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


# --------------------------------------------------------------------------
# Per-slot cache plumbing (continuous batching)
# --------------------------------------------------------------------------


def slot_update(cache: Array, idx: Array, new: Array,
                active: Array | None = None) -> Array:
    """Write one row per batch slot at that slot's own clock position.

    cache (B, S, ...); idx (B,) int32 row per slot; new (B, ...) the row
    values.  `active` (B,) bool masks the write — inactive slots keep
    their stored row untouched, which is what lets one fused decode step
    serve a pool of sequences at different clocks."""
    rows = jnp.arange(cache.shape[0])
    val = new.astype(cache.dtype)
    if active is not None:
        old = cache[rows, idx]
        val = jnp.where(active.reshape((-1,) + (1,) * (val.ndim - 1)),
                        val, old)
    return cache.at[rows, idx].set(val)


def gather_rows(x: Array, idx: Array) -> Array:
    """Per-slot row gather: x (B, S, ...), idx (B,) -> (B, 1, ...)."""
    shape = (x.shape[0], 1) + (1,) * (x.ndim - 2)
    return jnp.take_along_axis(x, idx.reshape(shape), axis=1)


def slot_update_many(cache: Array, idx: Array, new: Array) -> Array:
    """Write W rows per batch slot: cache (B, S, ...), idx (B, W) int32,
    new (B, W, ...).  The speculative verify path writes all k+1 rows of
    a slot at once (DESIGN.md §9); callers needing masking choose the
    VALUES (e.g. write back the old row), not the indices — with W > 1
    an index sentinel would need W distinct parking rows."""
    bidx = jnp.arange(cache.shape[0])[:, None]
    return cache.at[bidx, idx].set(new.astype(cache.dtype))


def paged_slot_update(pool: Array, page_idx: Array, offset: Array,
                      new: Array) -> Array:
    """Write one row per batch slot into the paged pool (DESIGN.md §8).

    pool (P, page, ...); page_idx / offset (B,) name each slot's
    physical page and in-page row; new (B, ...).  Masking rides the
    indices: callers pass a sentinel page_idx >= P for slots that must
    not write (inactive, or unadmitted in a ragged prefill) and
    `mode="drop"` discards those scatters — no read-modify-where pass
    over the pool."""
    return pool.at[page_idx, offset].set(new.astype(pool.dtype), mode="drop")


# --------------------------------------------------------------------------
# Rotary position embeddings
# --------------------------------------------------------------------------


def rotary(x: Array, positions: Array, theta: float) -> Array:
    """x: (B, S, H, D), positions: (B, S) -> rotated x."""
    d = x.shape[-1]
    freq = theta ** (-jnp.arange(0, d, 2, dtype=jnp.float32) / d)
    angle = positions[..., None].astype(jnp.float32) * freq  # (B, S, D/2)
    cos, sin = jnp.cos(angle)[:, :, None, :], jnp.sin(angle)[:, :, None, :]
    x1, x2 = x[..., ::2].astype(jnp.float32), x[..., 1::2].astype(jnp.float32)
    out = jnp.stack([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.reshape(x.shape).astype(x.dtype)


# --------------------------------------------------------------------------
# Flash attention (chunked online softmax, custom VJP)
# --------------------------------------------------------------------------

NEG_INF = -1e30


def _chunk_mask(q_pos, k_pos, kv_len, causal: bool, window: int):
    """(B, Sq, C) boolean mask for one KV chunk. q_pos (B,Sq), k_pos (C,)."""
    m = k_pos[None, None, :] < kv_len[:, None, None]
    if causal:
        m &= k_pos[None, None, :] <= q_pos[:, :, None]
    if window > 0:
        m &= q_pos[:, :, None] - k_pos[None, None, :] < window
    return m


def _flash_scan(q, k, v, q_pos, kv_len, causal, window, chunk, also_lse):
    """q (B,Sq,H,D); k,v (B,Sk,K,D); returns o (+ lse).  f32 internally."""
    b, sq, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = 1.0 / math.sqrt(d)
    nck = -(-sk // chunk)
    pad = nck * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, nck, chunk, kv, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nck, chunk, kv, d).transpose(1, 0, 2, 3, 4)
    qg = (q.reshape(b, sq, kv, g, d) * scale).astype(jnp.float32)

    def body(carry, inp):
        acc, m_run, l_run = carry
        ck, k_ck, v_ck = inp
        k_pos = ck * chunk + jnp.arange(chunk)
        s = jnp.einsum("bqkgd,bckd->bkgqc", qg, k_ck.astype(jnp.float32))
        mask = _chunk_mask(q_pos, k_pos, kv_len, causal, window)
        s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
        m_new = jnp.maximum(m_run, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_run - m_new)
        l_run = l_run * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgqc,bckd->bkgqd", p, v_ck.astype(jnp.float32))
        return (acc, m_new, l_run), None

    init = (
        jnp.zeros((b, kv, g, sq, d), jnp.float32),
        jnp.full((b, kv, g, sq), NEG_INF, jnp.float32),
        jnp.zeros((b, kv, g, sq), jnp.float32),
    )
    (acc, m_run, l_run), _ = jax.lax.scan(body, init, (jnp.arange(nck), kc, vc))
    l_safe = jnp.maximum(l_run, 1e-30)
    o = (acc / l_safe[..., None]).transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d)
    o = o.astype(q.dtype)
    if not also_lse:
        return o
    lse = m_run + jnp.log(l_safe)  # (B, KV, G, Sq)
    return o, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def flash_attention(q, k, v, q_pos, kv_len, causal: bool = True,
                    window: int = 0, chunk: int = 512):
    """Memory-efficient attention.

    q: (B, Sq, H, D); k, v: (B, Sk, KV, D) with H % KV == 0 (GQA).
    q_pos: (B, Sq) absolute query positions; kv_len: (B,) valid KV length
    (kv slots at positions >= kv_len are masked — supports ragged decode
    and ring-buffer caches).  causal/window are static.
    """
    return _flash_scan(q, k, v, q_pos, kv_len, causal, window, chunk, False)


def _flash_fwd(q, k, v, q_pos, kv_len, causal, window, chunk):
    o, lse = _flash_scan(q, k, v, q_pos, kv_len, causal, window, chunk, True)
    return o, (q, k, v, q_pos, kv_len, o, lse)


def _flash_bwd(causal, window, chunk, res, do):
    q, k, v, q_pos, kv_len, o, lse = res
    b, sq, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = 1.0 / math.sqrt(d)
    nck = -(-sk // chunk)
    pad = nck * chunk - sk
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else k
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else v
    kc = kp.reshape(b, nck, chunk, kv, d).transpose(1, 0, 2, 3, 4)
    vc = vp.reshape(b, nck, chunk, kv, d).transpose(1, 0, 2, 3, 4)
    qg = (q.reshape(b, sq, kv, g, d) * scale).astype(jnp.float32)
    do_g = do.reshape(b, sq, kv, g, d).transpose(0, 2, 3, 1, 4).astype(jnp.float32)
    o_g = o.reshape(b, sq, kv, g, d).transpose(0, 2, 3, 1, 4).astype(jnp.float32)
    delta = jnp.sum(do_g * o_g, axis=-1)  # (B, KV, G, Sq)

    def body(dq_acc, inp):
        ck, k_ck, v_ck = inp
        k_pos = ck * chunk + jnp.arange(chunk)
        s = jnp.einsum("bqkgd,bckd->bkgqc", qg, k_ck.astype(jnp.float32))
        mask = _chunk_mask(q_pos, k_pos, kv_len, causal, window)
        s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])  # (B, KV, G, Sq, C)
        dv_ck = jnp.einsum("bkgqc,bkgqd->bckd", p, do_g)
        dp = jnp.einsum("bkgqd,bckd->bkgqc", do_g, v_ck.astype(jnp.float32))
        ds = p * (dp - delta[..., None])
        dq_acc = dq_acc + jnp.einsum("bkgqc,bckd->bqkgd", ds, k_ck.astype(jnp.float32))
        dk_ck = jnp.einsum("bkgqc,bqkgd->bckd", ds, qg)
        return dq_acc, (dk_ck, dv_ck)

    dq0 = jnp.zeros((b, sq, kv, g, d), jnp.float32)
    dq, (dk_c, dv_c) = jax.lax.scan(body, dq0, (jnp.arange(nck), kc, vc))
    dq = (dq * scale).reshape(b, sq, h, d).astype(q.dtype)
    dk = dk_c.transpose(1, 0, 2, 3, 4).reshape(b, nck * chunk, kv, d)[:, :sk].astype(k.dtype)
    dv = dv_c.transpose(1, 0, 2, 3, 4).reshape(b, nck * chunk, kv, d)[:, :sk].astype(v.dtype)
    return dq, dk, dv, None, None


flash_attention.defvjp(_flash_fwd, _flash_bwd)


# --------------------------------------------------------------------------
# Exact sliding-window attention: O(S * window) FLOPs via 2-chunk slices
# --------------------------------------------------------------------------


def local_attention(q, k, v, window: int) -> Array:
    """Causal sliding-window attention, chunk == window: each query chunk
    attends (prev chunk, own chunk) only.  q (B,S,H,D); k,v (B,S,KV,D)."""
    b, s, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    c = window
    nc = -(-s // c)
    pad = nc * c - s
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qc = q.reshape(b, nc, c, kv, g, d).astype(jnp.float32) / math.sqrt(d)
    kc = k.reshape(b, nc, c, kv, d)
    vc = v.reshape(b, nc, c, kv, d)
    prev = lambda x: jnp.pad(x, ((0, 0), (1, 0)) + ((0, 0),) * (x.ndim - 2))[:, :-1]
    k2 = jnp.concatenate([prev(kc), kc], axis=2)  # (B, nc, 2C, KV, D)
    v2 = jnp.concatenate([prev(vc), vc], axis=2)
    srel = jnp.einsum("bnqkgd,bnckd->bnkgqc", qc, k2.astype(jnp.float32))
    q_idx = jnp.arange(c)[:, None] + c            # position within [prev|own]
    k_idx = jnp.arange(2 * c)[None, :]
    first = jnp.arange(nc) == 0                   # chunk 0 has no prev
    mask = (k_idx <= q_idx) & (q_idx - k_idx < window)
    mask = mask[None, :, :] & ~(first[:, None, None] & (k_idx < c))
    srel = jnp.where(mask[None, :, None, None, :, :], srel, NEG_INF)
    p = jax.nn.softmax(srel, axis=-1)
    o = jnp.einsum("bnkgqc,bnckd->bnqkgd", p, v2.astype(jnp.float32))
    return o.reshape(b, nc * c, h, d)[:, :s].astype(q.dtype)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------


def mlp_init(key, d_model: int, d_ff: int, gated: bool):
    ks = jax.random.split(key, 3)
    if gated:
        return {
            "wi": dense_init(ks[0], d_model, d_ff),
            "wg": dense_init(ks[1], d_model, d_ff),
            "wo": dense_init(ks[2], d_ff, d_model),
        }
    return {
        "wi": dense_init(ks[0], d_model, d_ff),
        "wo": dense_init(ks[2], d_ff, d_model),
    }


def mlp(p, x: Array) -> Array:
    h = dense(p["wi"], x)
    if "wg" in p:
        h = jax.nn.silu(dense(p["wg"], x)) * h
    else:
        h = jax.nn.gelu(h)
    return dense(p["wo"], h)


# --------------------------------------------------------------------------
# GQA attention block (projections + rotary + flash / local / cached)
# --------------------------------------------------------------------------


def attn_init(key, cfg) -> dict:
    hd, nh, nkv = cfg.head_dim_, cfg.n_heads, cfg.n_kv
    ks = jax.random.split(key, 5)
    p = {
        "wq": dense_init(ks[0], cfg.d_model, nh * hd, bias=cfg.qkv_bias),
        "wk": dense_init(ks[1], cfg.d_model, nkv * hd, bias=cfg.qkv_bias),
        "wv": dense_init(ks[2], cfg.d_model, nkv * hd, bias=cfg.qkv_bias),
        "wo": dense_init(ks[3], nh * hd, cfg.d_model),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), jnp.float32)
        p["k_norm"] = jnp.zeros((hd,), jnp.float32)
    return p


def attn_qkv(p, cfg, x: Array, positions: Array):
    """Project + (qk-norm) + rotary.  Returns q (B,S,H,D), k/v (B,S,KV,D)."""
    b, s, _ = x.shape
    hd = cfg.head_dim_
    q = dense(p["wq"], x).reshape(b, s, cfg.n_heads, hd)
    k = dense(p["wk"], x).reshape(b, s, cfg.n_kv, hd)
    v = dense(p["wv"], x).reshape(b, s, cfg.n_kv, hd)
    if cfg.qk_norm:
        q = rms_norm(p["q_norm"], q, cfg.norm_eps)
        k = rms_norm(p["k_norm"], k, cfg.norm_eps)
    q = rotary(q, positions, cfg.rope_theta)
    k = rotary(k, positions, cfg.rope_theta)
    return q, k, v


def attention_block(p, cfg, x: Array, positions: Array, *, window: int = 0) -> Array:
    """Self-attention over the full sequence (train / prefill path)."""
    from ..dist.sharding import active_mesh, constrain
    b, s, _ = x.shape
    q, k, v = attn_qkv(p, cfg, x, positions)
    # Pin the model-axis placement of the flash scan explicitly — GSPMD
    # otherwise reshards the (B, KV, G, Sq, C) chunk tensors per step
    # (§Perf iteration 5's 27 TB/device failure mode).  Heads shard when
    # they divide the model axis; otherwise fall back to sharding the
    # QUERY sequence (context parallelism) — without it, archs whose head
    # count is model-axis-hostile (internvl2: 14 heads on model=16)
    # replicate the whole attention 16x (§Perf iteration 9).
    mesh = active_mesh()
    model = dict(mesh.shape).get("model", 1) if mesh is not None else 1
    if model > 1 and cfg.n_heads % model == 0:
        q = constrain(q, "batch", None, "heads", None)
        k = constrain(k, "batch", None, "kv_heads", None)
        v = constrain(v, "batch", None, "kv_heads", None)
    elif model > 1 and s % model == 0:
        q = constrain(q, "batch", "residual", None, None)  # seq over model
        k = constrain(k, "batch", None, None, None)
        v = constrain(v, "batch", None, None, None)
    kv_len = jnp.full((b,), s, jnp.int32)
    if window > 0 and cfg.is_causal:
        o = local_attention(q, k, v, window)
    else:
        o = flash_attention(q, k, v, positions, kv_len,
                            cfg.is_causal, window, min(512, s))
    return dense(p["wo"], o.reshape(b, s, cfg.n_heads * cfg.head_dim_))


def cached_attention(p, cfg, q: Array, k_cache: Array, v_cache: Array,
                     q_pos: Array, kv_len: Array, *,
                     k_scale: Array | None = None,
                     v_scale: Array | None = None,
                     exclude: Array | None = None) -> Array:
    """Decode-path attention: q (B,1,H,D) over a cache (B,Smax,KV,D) whose
    slots beyond kv_len are masked.  The caller inserts the new token's
    k/v into the cache *before* calling (see serve_lib), so causality is
    already structural; ring caches work because keys are stored rotated
    at absolute positions and softmax is permutation-invariant over kv
    slots.

    Direct (non-chunked) masked softmax: with q_len == 1 the logits are
    (B, H, 1, Smax) — tiny — and a plain einsum over the cache keeps the
    SPMD story clean when the cache's sequence dim is sharded over 'data'
    (long_500k): GSPMD turns the softmax reductions into psums instead of
    gathering the cache.

    Speculative verify (DESIGN.md §9) widens q to (B,W,H,D) with all W
    rows pre-written: `kv_len` may then be (B, W) — a per-QUERY valid
    length, which is what makes the W-wide pass causal (query j sees
    rows < t+j+1 only; full attention only — ring caches step
    sequentially, see transformer._spec_block).  `exclude`
    (B, Sq, Smax) bool additionally masks arbitrary cache slots per
    query for callers whose validity isn't a prefix.

    int8 cache codec (DESIGN.md §7): pass the stored rows RAW with their
    per-row scales `k_scale`/`v_scale` (B, Smax, KV).  Scales are
    constant along head_dim, so they factor out of both contractions —
    scores are scaled after the QK^T einsum and v_scale folds into the
    softmax weights — and no dequantized float copy of the cache is ever
    materialized."""
    b, sq, h, d = q.shape
    kv = k_cache.shape[2]
    g = h // kv
    row = lambda sc: sc.astype(jnp.float32).transpose(0, 2, 1)[:, :, None, None, :]
    qg = (q.reshape(b, sq, kv, g, d) / math.sqrt(d)).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_cache.astype(jnp.float32))
    if k_scale is not None:
        s = s * row(k_scale)
    srange = jnp.arange(k_cache.shape[1])
    if kv_len.ndim == 1:
        valid = (srange[None, :] < kv_len[:, None])[:, None, :]   # (B,1,S)
    else:  # per-query lengths (B, Sq)
        valid = srange[None, None, :] < kv_len[:, :, None]        # (B,Sq,S)
    if exclude is not None:
        valid = valid & ~exclude
    s = jnp.where(valid[:, None, None, :, :], s, NEG_INF)
    p_attn = jax.nn.softmax(s, axis=-1)
    if v_scale is not None:
        p_attn = p_attn * row(v_scale)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p_attn, v_cache.astype(jnp.float32))
    o = o.reshape(b, sq, h, d).astype(q.dtype)
    return dense(p["wo"], o.reshape(b, sq, cfg.n_heads * cfg.head_dim_))


def paged_cached_attention(p, cfg, q: Array, c: dict, block_tables: Array,
                           kv_len: Array) -> Array:
    """Decode attention over the paged pool: q (B,1,H,D) against the
    cache dict's `k_pages`/`v_pages` pools through `block_tables`
    (B, n_bt).  Inside an engine whose backend registers the
    `paged_attention` op the planned kernel runs (scalar-prefetch
    gather, DESIGN.md §8); otherwise the reference gather — which is
    bit-identical to `cached_attention` on the same live rows, the
    property the parity tests pin.  int8 pools ship their per-row scale
    pages through the same block table (scales page with their rows).

    The W-wide speculative verify (sq > 1, per-query `kv_len` (B, W))
    always takes the reference path: the decode kernel is sq==1-shaped,
    and bypassing the engine here keeps the verify pass from minting a
    new `paged_attention` plan key (steady-state misses stay 0)."""
    from repro.engine import active_engine
    b, sq, h, d = q.shape
    k_scale = c.get("k_scale_pages")
    v_scale = c.get("v_scale_pages")
    eng = active_engine()
    if eng is not None and sq == 1 and eng.registry.has(eng.backend,
                                                        "paged_attention"):
        o = eng.paged_attention(q, c["k_pages"], c["v_pages"], block_tables,
                                kv_len, k_scale=k_scale, v_scale=v_scale)
    else:
        from repro.kernels.paged_attention import paged_attention_reference
        o = paged_attention_reference(q, c["k_pages"], c["v_pages"],
                                      block_tables, kv_len,
                                      k_scale=k_scale, v_scale=v_scale)
    return dense(p["wo"], o.reshape(b, sq, cfg.n_heads * cfg.head_dim_))
