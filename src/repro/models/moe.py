"""Token-choice top-k Mixture-of-Experts (mixtral 8e/top2, granite 32e/top8).

GShard-style capacity dispatch: every (token, choice) gets a position in
its expert's buffer by a causal cumulative count; positions beyond the
static capacity C = ceil(S * top_k * cf / E) are dropped (their combine
weight is zero, the residual passes through).  Dispatch/combine are
einsums, so the whole block is one dense program — shardable with the
expert dim on the 'model' mesh axis (expert parallelism) and the token
dims on ('pod','data').

The expert GEMMs are exactly the small/irregular shapes ReDas targets
(granite: d_ff=512); inside a `repro.engine.use_engine` context the
engine plans their grouped-GEMM schedule (Eq.-2 VMEM-gated blocks) and
dispatches the per-expert Pallas kernel.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..dist.sharding import constrain
from .layers import dense_init

Array = jax.Array


def moe_init(key, cfg) -> dict:
    m = cfg.moe
    ks = jax.random.split(key, 4)
    d, f, e = cfg.d_model, cfg.d_ff, m.n_experts
    std = 1.0 / math.sqrt(d)
    return {
        "router": dense_init(ks[0], d, e, scale=std),
        "experts": {
            "wi": jax.random.normal(ks[1], (e, d, f), jnp.float32) * std,
            "wg": jax.random.normal(ks[2], (e, d, f), jnp.float32) * std,
            "wo": jax.random.normal(ks[3], (e, f, d), jnp.float32) / math.sqrt(f),
        },
    }


def capacity(cfg, seq: int) -> int:
    return cfg.moe.capacity(seq)  # formula lives on MoEConfig


def moe_block(p, cfg, x: Array) -> tuple[Array, Array]:
    """x: (B, S, D) -> (y, aux_loss); dispatch impl per cfg.moe.impl."""
    if cfg.moe.impl == "sort":
        return moe_block_sorted(p, cfg, x)
    return moe_block_einsum(p, cfg, x)


def _route(p, cfg, x: Array):
    """Shared router: (gates (B,S,k), sel (B,S,k), aux scalar)."""
    e, k = cfg.moe.n_experts, cfg.moe.top_k
    logits = (x.astype(jnp.float32) @ p["router"]["w"]).astype(jnp.float32)
    gates, sel = jax.lax.top_k(logits, k)
    gates = jax.nn.softmax(gates, axis=-1)
    probs = jax.nn.softmax(logits, axis=-1)
    one_hot_sel = jax.nn.one_hot(sel[..., 0], e, dtype=jnp.float32)
    aux = e * jnp.sum(jnp.mean(one_hot_sel, axis=(0, 1)) *
                       jnp.mean(probs, axis=(0, 1)))
    return gates, sel, aux


def _expert_ffn(we, x_in: Array) -> Array:
    """x_in (E, ..., D) -> (E, ..., D) through per-expert SwiGLU.

    Inside a `repro.engine.use_engine` context the three per-expert
    contractions dispatch through the engine's `grouped_gemm` decision
    (one planned, VMEM-gated Pallas schedule shared by wi/wg, another
    for wo); otherwise plain XLA einsums."""
    from repro.engine import active_engine
    eng = active_engine()
    if eng is not None:
        e, d = x_in.shape[0], x_in.shape[-1]
        xf = x_in.reshape(e, -1, d)
        h = eng.grouped_matmul(xf, we["wi"].astype(x_in.dtype))
        g = eng.grouped_matmul(xf, we["wg"].astype(x_in.dtype))
        out = eng.grouped_matmul(jax.nn.silu(g) * h,
                                 we["wo"].astype(x_in.dtype))
        return out.reshape(x_in.shape)
    h = jnp.einsum("e...d,edf->e...f", x_in, we["wi"].astype(x_in.dtype))
    g = jnp.einsum("e...d,edf->e...f", x_in, we["wg"].astype(x_in.dtype))
    return jnp.einsum("e...f,efd->e...d", jax.nn.silu(g) * h,
                      we["wo"].astype(x_in.dtype))


def moe_block_sorted(p, cfg, x: Array) -> tuple[Array, Array]:
    """Sort-based dispatch: argsort selections by expert, scatter tokens
    into (E, C) buffers, gather back weighted.  Same capacity/priority
    semantics as the einsum path (stable sort keeps token-major priority)
    but with zero dispatch FLOPs — removes the tokens x E x C one-hot
    GEMMs that dominate the granite-moe roofline (useful-FLOPs 0.16 ->
    see EXPERIMENTS.md §Perf)."""
    m = cfg.moe
    b, s, d = x.shape
    e, k = m.n_experts, m.top_k
    c = capacity(cfg, s)
    gates, sel, aux = _route(p, cfg, x)

    def per_example(xb, selb, gateb):
        sk = s * k
        e_flat = selb.reshape(sk)                       # expert id / selection
        order = jnp.argsort(e_flat, stable=True)        # token-major priority
        sorted_e = e_flat[order]
        first = jnp.searchsorted(sorted_e, sorted_e, side="left")
        pos = jnp.arange(sk) - first                    # slot within expert
        keep = pos < c
        dst = jnp.where(keep, sorted_e * c + pos, e * c)  # dump slot at E*C
        tok = order // k                                # source token index
        buf = jnp.zeros((e * c + 1, d), x.dtype).at[dst].set(xb[tok])
        # inverse permutation: where did selection i land?
        slot_of_sel = jnp.zeros((sk,), jnp.int32).at[order].set(dst)
        return buf[: e * c].reshape(e, c, d), slot_of_sel

    bufs, slots = jax.vmap(per_example)(x, sel, gates)   # (B,E,C,D), (B,Sk)
    # Constrain on BOTH sides of the transpose so the token->expert move
    # lowers as an all-to-all over (batch x experts) instead of an
    # all-gather of the whole buffer (§Perf iteration G3).
    bufs = constrain(bufs, "batch", "experts", None, None)
    xin = constrain(bufs.transpose(1, 0, 2, 3), "experts", "batch", None, None)
    out = _expert_ffn(p["experts"], xin)                 # (E,B,C,D)
    out = constrain(out, "experts", "batch", None, None)
    out_be = constrain(out.transpose(1, 0, 2, 3), "batch", "experts",
                       None, None)
    out_b = out_be.reshape(b, e * c, d)
    # pad a zero row so dumped selections gather zeros
    out_b = jnp.concatenate(
        [out_b, jnp.zeros((b, 1, d), out_b.dtype)], axis=1)
    slots = jnp.minimum(slots, e * c)                    # (B, S*k)
    picked = jnp.take_along_axis(out_b, slots[..., None], axis=1)
    y = (picked.reshape(b, s, k, d)
         * gates.astype(x.dtype)[..., None]).sum(axis=2)
    return y, aux


def moe_block_einsum(p, cfg, x: Array) -> tuple[Array, Array]:
    """GShard one-hot dispatch (the §Roofline baseline path)."""
    m = cfg.moe
    b, s, d = x.shape
    e, k = m.n_experts, m.top_k
    c = capacity(cfg, s)

    logits = (x.astype(jnp.float32) @ p["router"]["w"]).astype(jnp.float32)
    gates, sel = jax.lax.top_k(logits, k)            # (B,S,k)
    gates = jax.nn.softmax(gates, axis=-1)           # normalize over chosen k

    # Load-balancing auxiliary loss (Switch): E * mean(frac_tokens * frac_prob)
    probs = jax.nn.softmax(logits, axis=-1)
    one_hot_sel = jax.nn.one_hot(sel[..., 0], e, dtype=jnp.float32)
    aux = e * jnp.sum(jnp.mean(one_hot_sel, axis=(0, 1)) *
                       jnp.mean(probs, axis=(0, 1)))

    # Position of each (token, choice) in its expert's buffer — causal
    # count over the flattened (S*k) selection stream, per example group.
    flat = jax.nn.one_hot(sel.reshape(b, s * k), e, dtype=jnp.int32)  # (B,Sk,E)
    pos = jnp.cumsum(flat, axis=1) - flat            # selections before this one
    pos_sel = jnp.sum(pos * flat, axis=-1)           # (B, S*k)
    keep = (pos_sel < c).astype(x.dtype)
    oh_pos = jax.nn.one_hot(pos_sel, c, dtype=x.dtype)              # (B,Sk,C)
    sel_e = flat.astype(x.dtype) * keep[..., None]                  # (B,Sk,E)
    w_flat = gates.reshape(b, s * k).astype(x.dtype)

    # dispatch (B,S,E,C): sum over the k choice slots
    disp = jnp.einsum("bte,btc->btec", sel_e, oh_pos)
    disp = disp.reshape(b, s, k, e, c).sum(axis=2)
    comb = jnp.einsum("bte,btc,bt->btec", sel_e, oh_pos, w_flat)
    comb = comb.reshape(b, s, k, e, c).sum(axis=2)

    xin = jnp.einsum("bsec,bsd->ebcd", disp, x)      # (E,B,C,D)
    xin = constrain(xin, "experts", "batch", None, None)
    we = p["experts"]
    h = jnp.einsum("ebcd,edf->ebcf", xin, we["wi"].astype(x.dtype))
    g = jnp.einsum("ebcd,edf->ebcf", xin, we["wg"].astype(x.dtype))
    h = jax.nn.silu(g) * h
    out = jnp.einsum("ebcf,efd->ebcd", h, we["wo"].astype(x.dtype))
    out = constrain(out, "experts", "batch", None, None)
    y = jnp.einsum("bsec,ebcd->bsd", comb, out)
    return y, aux
