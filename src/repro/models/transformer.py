"""Model assembly: decoder / encoder / hybrid / SSM / VLM from ArchConfig.

Layers are grouped by the repeating `layer_pattern` period and scanned
(`jax.lax.scan` over stacked period params) with a rematerialized body —
compile time and HLO size are O(period), not O(n_layers), which is what
makes the 88-layer mistral-large dry-run tractable; saved residuals are
sharding-constrained to the 'residual' logical axis so remat checkpoints
spread across the model axis.

Three execution paths share the same block code:
  forward()       full-sequence (training / encoder / prefill-as-forward)
  prefill()       forward + KV/state cache construction
  decode_step()   one token against the cache (scan over periods again)
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..dist.sharding import constrain
from ..quant import kv_dequantize, kv_quantize
from . import layers, moe, rglru, ssm
from .config import ArchConfig
from .layers import dense, mlp, mlp_init, rms_norm

Array = jax.Array


# --------------------------------------------------------------------------
# Init
# --------------------------------------------------------------------------


def _block_init(key, cfg: ArchConfig, kind: str) -> dict:
    ks = jax.random.split(key, 3)
    p: dict[str, Any] = {"norm1": jnp.zeros((cfg.d_model,), jnp.float32)}
    if kind in ("attn", "local"):
        p["attn"] = layers.attn_init(ks[0], cfg)
        p["norm2"] = jnp.zeros((cfg.d_model,), jnp.float32)
        if cfg.moe is not None:
            p["moe"] = moe.moe_init(ks[1], cfg)
        else:
            p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.gated_mlp)
    elif kind == "ssm":
        p["ssm"] = ssm.ssm_init(ks[0], cfg)
    elif kind == "rglru":
        p["rec"] = rglru.rglru_init(ks[0], cfg)
        p["norm2"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.gated_mlp)
    else:
        raise ValueError(kind)
    return p


def _period_split(cfg: ArchConfig) -> tuple[int, int]:
    period = len(cfg.layer_pattern)
    return cfg.n_layers // period, cfg.n_layers % period


def init_params(key, cfg: ArchConfig) -> dict:
    n_periods, n_tail = _period_split(cfg)
    ks = jax.random.split(key, 4 + len(cfg.layer_pattern) + n_tail)
    params: dict[str, Any] = {}
    if not cfg.embed_inputs:
        params["embed"] = (
            jax.random.normal(ks[0], (cfg.vocab, cfg.d_model), jnp.float32)
            / jnp.sqrt(cfg.d_model).astype(jnp.float32))
    stack = {}
    for j, kind in enumerate(cfg.layer_pattern):
        keys = jax.random.split(ks[2 + j], max(n_periods, 1))
        stack[f"b{j}"] = jax.vmap(
            lambda k, kd=kind: _block_init(k, cfg, kd))(keys)
    params["stack"] = stack
    params["tail"] = [
        _block_init(ks[2 + len(cfg.layer_pattern) + t], cfg,
                    cfg.layer_pattern[t])
        for t in range(n_tail)
    ]
    params["final_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init_head(ks[1], cfg)
    return params


def dense_init_head(key, cfg: ArchConfig):
    return (jax.random.normal(key, (cfg.d_model, cfg.vocab), jnp.float32)
            / jnp.sqrt(cfg.d_model).astype(jnp.float32))


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# --------------------------------------------------------------------------
# Blocks (full-sequence path)
# --------------------------------------------------------------------------


def _norm_in(scale, cfg: ArchConfig, x: Array) -> Array:
    """Norm input path.  §Perf iteration 7 tried an explicit bf16
    all-gather here (Megatron-SP style); GSPMD responded by saving the
    gathered replicas across remat (temp 15 -> 111 GB/device) — REFUTED,
    so the norm runs on whatever sharding the residual carries."""
    return rms_norm(scale, x, cfg.norm_eps, cast_early=cfg.norm_cast_early)


def _to_residual(h: Array) -> Array:
    return h


def _block_apply(kind: str, p, cfg: ArchConfig, x: Array, positions: Array):
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn", "local"):
        window = cfg.window if kind == "local" else 0
        h = layers.attention_block(
            p["attn"], cfg, _norm_in(p["norm1"], cfg, x),
            positions, window=window)
        x = x + _to_residual(h)
        h2in = _norm_in(p["norm2"], cfg, x)
        if cfg.moe is not None:
            h2, aux = moe.moe_block(p["moe"], cfg, h2in)
        else:
            h2 = mlp(p["mlp"], h2in)
        x = x + _to_residual(h2)
    elif kind == "ssm":
        x = x + _to_residual(
            ssm.ssm_block(p["ssm"], cfg, _norm_in(p["norm1"], cfg, x)))
    elif kind == "rglru":
        x = x + _to_residual(
            rglru.rglru_block(p["rec"], cfg, _norm_in(p["norm1"], cfg, x)))
        x = x + _to_residual(mlp(p["mlp"], _norm_in(p["norm2"], cfg, x)))
    else:
        raise ValueError(kind)
    return x, aux


def _embed_in(params, cfg: ArchConfig, tokens, embeds, compute_dtype):
    if cfg.embed_inputs:
        x = embeds.astype(compute_dtype)
    else:
        x = params["embed"].astype(compute_dtype)[tokens]
        if embeds is not None:  # VLM: prefix patch embeddings
            x = jnp.concatenate([embeds.astype(compute_dtype), x], axis=1)
    return constrain(x, "batch", "seq", "embed")


def _logits_out(params, cfg: ArchConfig, x: Array):
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = x @ head.astype(x.dtype)
    return constrain(logits, "batch", "seq", "vocab")


def forward(params, cfg: ArchConfig, tokens: Array | None = None, *,
            embeds: Array | None = None, compute_dtype=jnp.bfloat16):
    """Full-sequence logits.  tokens (B, S) int32; embeds (B, P, D) for the
    VLM prefix or (B, S, D) for audio (embed_inputs).  Returns
    (logits (B, S_total, V), aux_loss scalar)."""
    x = _embed_in(params, cfg, tokens, embeds, compute_dtype)
    b, s = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    n_periods, _ = _period_split(cfg)

    def body(carry, pp):
        x, aux = carry
        for j, kind in enumerate(cfg.layer_pattern):
            x, a = _block_apply(kind, pp[f"b{j}"], cfg, x, positions)
            aux = aux + a
        # Carry saved embed-sharded over 'model' (remat memory /16); the
        # per-block bf16 gather lives in _block_apply._norm_in.  (§Perf
        # iterations 3/5 tried seq-sharded and replicated carries: both
        # made GSPMD reshard inside the attention scans — 1.5-6x worse.)
        x = constrain(x, "batch", None, "residual")
        return (x, aux), None

    if n_periods > 0:
        body_rm = jax.checkpoint(body, prevent_cse=False)
        (x, aux), _ = jax.lax.scan(
            body_rm, (x, jnp.zeros((), jnp.float32)), params["stack"])
    else:  # pragma: no cover - all assigned archs have >= 1 period
        aux = jnp.zeros((), jnp.float32)
    for t, p_tail in enumerate(params["tail"]):
        x, a = _block_apply(cfg.layer_pattern[t], p_tail, cfg, x, positions)
        aux = aux + a
    return _logits_out(params, cfg, x), aux


# --------------------------------------------------------------------------
# Cache + decode path
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CacheSpec:
    """Static description of the per-block cache for a serving config.

    `page_size`/`n_pages` select the paged layout (DESIGN.md §8): full
    attention KV moves from per-slot `(B, max_seq, ...)` regions into
    one pool of `n_pages` fixed pages addressed through per-slot block
    tables; sliding-window rings and recurrent state keep their slot
    layout (they are already O(window)/O(1) — paging buys nothing)."""
    max_seq: int
    batch: int
    page_size: int | None = None
    n_pages: int | None = None


def _slot_cache_shape(kind: str, cfg: ArchConfig, spec: CacheSpec,
                      dtype) -> dict:
    b, hd, kv = spec.batch, cfg.head_dim_, cfg.n_kv
    quant = jnp.dtype(dtype) == jnp.int8
    if quant and kind not in ("attn", "local"):
        # int8 quantizes attention KV rows only; recurrent state is
        # read-modify-write every step and would accumulate quantization
        # noise, so it stays bf16 (DESIGN.md §7 — the config-time
        # validator in serve_lib rejects archs where nothing quantizes).
        dtype = jnp.bfloat16
    if kind == "attn" and spec.page_size:
        if not spec.n_pages:
            raise ValueError("paged CacheSpec needs n_pages")
        # page pool: physical page p of every layer lives in that
        # layer's own pool at row p — one block table addresses all
        # layers.  The int8 codec's per-row scales page WITH their rows
        # (same pool index, same block table) so a page is always
        # self-describing.
        c = {"k_pages": jnp.zeros((spec.n_pages, spec.page_size, kv, hd),
                                  dtype),
             "v_pages": jnp.zeros((spec.n_pages, spec.page_size, kv, hd),
                                  dtype)}
        if quant:
            c["k_scale_pages"] = jnp.zeros(
                (spec.n_pages, spec.page_size, kv), jnp.float32)
            c["v_scale_pages"] = jnp.zeros(
                (spec.n_pages, spec.page_size, kv), jnp.float32)
        return c
    if kind in ("attn", "local"):
        s = spec.max_seq if kind == "attn" else min(cfg.window, spec.max_seq)
        c = {"k": jnp.zeros((b, s, kv, hd), dtype),
             "v": jnp.zeros((b, s, kv, hd), dtype)}
        if quant:
            # per-row codec (quant.kv_quantize): one f32 scale per
            # stored row per kv head rides next to the int8 rows.
            c["k_scale"] = jnp.zeros((b, s, kv), jnp.float32)
            c["v_scale"] = jnp.zeros((b, s, kv), jnp.float32)
        return c
    if kind == "ssm":
        sc, d_in = cfg.ssm, cfg.ssm.expand * cfg.d_model
        heads = d_in // sc.head_dim
        conv_ch = d_in + 2 * sc.n_groups * sc.d_state
        return {"conv": jnp.zeros((b, sc.conv_width - 1, conv_ch), dtype),
                "state": jnp.zeros((b, heads, sc.d_state, sc.head_dim),
                                   jnp.float32)}
    if kind == "rglru":
        w = cfg.rglru_width or cfg.d_model
        return {"conv": jnp.zeros((b, 3, w), dtype),
                "h": jnp.zeros((b, w), dtype)}
    raise ValueError(kind)


def init_cache(cfg: ArchConfig, spec: CacheSpec, dtype=jnp.bfloat16) -> dict:
    n_periods, n_tail = _period_split(cfg)
    tile = lambda t: jnp.broadcast_to(t, (n_periods,) + t.shape).copy()
    slots = {
        f"b{j}": jax.tree.map(tile, _slot_cache_shape(kind, cfg, spec, dtype))
        for j, kind in enumerate(cfg.layer_pattern)
    }
    tail = [_slot_cache_shape(cfg.layer_pattern[t], cfg, spec, dtype)
            for t in range(n_tail)]
    # "t" is the per-slot cache clock (B,): each batch slot advances
    # independently, which is what lets a continuous-batching scheduler
    # run one fixed-shape decode step over sequences of different ages.
    return {"t": jnp.zeros((spec.batch,), jnp.int32), "slots": slots,
            "tail": tail}


def _merge_slot(active, new: dict, old: dict) -> dict:
    """Keep `old` cache leaves where a slot is inactive (leaves carry a
    leading batch dim).  `active=None` means every slot updates — the
    single-stream serving path, which then pays no masking cost."""
    if active is None:
        return new
    pick = lambda nw, od: jnp.where(
        active.reshape((-1,) + (1,) * (nw.ndim - 1)), nw, od.astype(nw.dtype))
    return jax.tree.map(pick, new, old)


def _merge_block(active, new: dict, old: dict) -> dict:
    """`_merge_slot`, except paged pools pass through untouched: their
    leading dim is pages (not batch) and every paged write already
    bakes the slot mask into its scatter indices (sentinel page -> the
    scatter's `mode="drop"`), so a post-hoc where() would be both a
    shape error and redundant."""
    if "k_pages" in new:
        return new
    return _merge_slot(active, new, old)


def _decode_block(kind: str, p, cfg: ArchConfig, x: Array, t: Array, c: dict,
                  active: Array | None = None,
                  block_tables: Array | None = None):
    """One-token step for one block; returns (x, new_cache_slice).

    `t` (B,) is the per-slot cache clock: each slot writes its new KV
    row at its own position and attends its own valid prefix, so one
    fused step serves a pool of sequences of different ages.  `active`
    (B,) masks cache updates for empty / evicted slots.  Paged blocks
    (`"k_pages" in c`) resolve the write position through
    `block_tables` (B, n_bt) instead of a per-slot row."""
    pos = t[:, None].astype(jnp.int32)  # (B, 1) per-slot positions
    if kind in ("attn", "local"):
        q, k_new, v_new = layers.attn_qkv(
            p["attn"], cfg, rms_norm(p["norm1"], x, cfg.norm_eps), pos)
        if "k_pages" in c:
            if block_tables is None:
                raise ValueError("paged cache decode needs block_tables")
            n_pool, page = c["k_pages"].shape[0], c["k_pages"].shape[1]
            pidx = (t // page).astype(jnp.int32)
            phys = jnp.take_along_axis(block_tables, pidx[:, None],
                                       axis=1)[:, 0]
            # the slot mask and unallocated holes both route to the
            # sentinel row n_pool: paged_slot_update's mode="drop"
            # discards those writes without touching the pool
            if active is not None:
                phys = jnp.where(active, phys, n_pool)
            phys = jnp.where(phys < 0, n_pool, phys).astype(jnp.int32)
            off = (t % page).astype(jnp.int32)
            if "k_scale_pages" in c:
                kq, ks = kv_quantize(k_new[:, 0])
                vq, vs = kv_quantize(v_new[:, 0])
                store = {"k_pages": kq, "v_pages": vq,
                         "k_scale_pages": ks, "v_scale_pages": vs}
            else:
                store = {"k_pages": k_new[:, 0], "v_pages": v_new[:, 0]}
            new_c = {nm: layers.paged_slot_update(c[nm], phys, off, val)
                     for nm, val in store.items()}
            # full attention never wraps: the slot's whole history is
            # paged in, so the valid length is just the clock
            h = layers.paged_cached_attention(
                p["attn"], cfg, q, new_c, block_tables, t + 1)
        else:
            size = c["k"].shape[1]
            idx = (t % size).astype(jnp.int32)
            if "k_scale" in c:  # int8 codec: quantize the new row, store
                # its scale beside it; attention reads the int8 rows RAW
                # with the scales folded into its einsums (no dequantized
                # float copy of the cache — layers.cached_attention).
                kq, ks = kv_quantize(k_new[:, 0])
                vq, vs = kv_quantize(v_new[:, 0])
                new_c = {"k": layers.slot_update(c["k"], idx, kq, active),
                         "v": layers.slot_update(c["v"], idx, vq, active),
                         "k_scale": layers.slot_update(c["k_scale"], idx, ks,
                                                       active),
                         "v_scale": layers.slot_update(c["v_scale"], idx, vs,
                                                       active)}
            else:
                new_c = {"k": layers.slot_update(c["k"], idx, k_new[:, 0],
                                                 active),
                         "v": layers.slot_update(c["v"], idx, v_new[:, 0],
                                                 active)}
            kv_len = jnp.minimum(t + 1, size)
            h = layers.cached_attention(
                p["attn"], cfg, q, new_c["k"], new_c["v"], pos, kv_len,
                k_scale=new_c.get("k_scale"), v_scale=new_c.get("v_scale"))
        x = x + h
        h2in = rms_norm(p["norm2"], x, cfg.norm_eps)
        if cfg.moe is not None:
            h2, _ = moe.moe_block(p["moe"], cfg, h2in)
        else:
            h2 = mlp(p["mlp"], h2in)
        return x + h2, new_c
    if kind == "ssm":
        h, conv, state = ssm.ssm_decode_step(
            p["ssm"], cfg, rms_norm(p["norm1"], x, cfg.norm_eps),
            c["conv"], c["state"])
        new = {"conv": conv.astype(c["conv"].dtype), "state": state}
        return x + h, _merge_slot(active, new, c)
    if kind == "rglru":
        h, conv, hstate = rglru.rglru_decode_step(
            p["rec"], cfg, rms_norm(p["norm1"], x, cfg.norm_eps),
            c["conv"], c["h"])
        x = x + h
        x = x + mlp(p["mlp"], rms_norm(p["norm2"], x, cfg.norm_eps))
        new = {"conv": conv.astype(c["conv"].dtype),
               "h": hstate.astype(c["h"].dtype)}
        return x, _merge_slot(active, new, c)
    raise ValueError(kind)


def decode_step(params, cfg: ArchConfig, cache: dict, token: Array, *,
                compute_dtype=jnp.bfloat16, active: Array | None = None,
                block_tables: Array | None = None):
    """token (B, 1) int32 -> (logits (B, 1, V), new_cache).

    `cache["t"]` is a per-slot clock (B,); `active` (B,) bool masks
    which slots consume a token this step — inactive slots keep their
    cache and clock and their logits rows are garbage to discard.  The
    call shapes are independent of which slots are active, so a
    continuous-batching scheduler reuses one jitted step (and one
    engine decision cache) for every step it ever takes.
    `block_tables` (B, n_bt) int32 addresses paged attention pools
    (required iff the cache was built with a paged CacheSpec); every
    attention layer reads the same table."""
    b = token.shape[0]
    t = cache["t"]
    if t.ndim == 0:  # legacy scalar clock (pre-vector caches)
        t = jnp.broadcast_to(t, (b,))
    x = params["embed"].astype(compute_dtype)[token]
    x = constrain(x, "batch", None, "embed")

    def body(x, inp):
        pp, cc = inp
        for j, kind in enumerate(cfg.layer_pattern):
            x, cc_new = _decode_block(kind, pp[f"b{j}"], cfg, x, t,
                                      cc[f"b{j}"], active, block_tables)
            cc = {**cc, f"b{j}": cc_new}
        return x, cc

    x, new_slots = jax.lax.scan(body, x, (params["stack"], cache["slots"]))
    new_tail = []
    for i, p_tail in enumerate(params["tail"]):
        x, c_new = _decode_block(cfg.layer_pattern[i], p_tail, cfg, x, t,
                                 cache["tail"][i], active, block_tables)
        new_tail.append(c_new)
    logits = _logits_out(params, cfg, x)
    new_t = t + 1 if active is None else jnp.where(active, t + 1, t)
    return logits, {"t": new_t, "slots": new_slots, "tail": new_tail}


def prefill(params, cfg: ArchConfig, tokens: Array, cache: dict, *,
            embeds: Array | None = None, compute_dtype=jnp.bfloat16,
            lengths: Array | None = None, update_mask: Array | None = None,
            block_tables: Array | None = None,
            hist_len: Array | None = None, hist_pages: int = 0):
    """Run the prompt, filling `cache`; returns (last-token logits, cache).

    Implementation: the full-sequence path plus per-block cache writes —
    attention caches receive rows [0, S); recurrent caches receive the
    final state (recomputed per block kind via its scan).

    Ragged mode (continuous batching): `lengths` (B,) marks each slot's
    valid prompt prefix in a right-padded `tokens` batch.  Every cache
    kind then records per-slot time — attention rows past a slot's
    length are dead weight masked by its clock, ring caches place each
    slot's tail at its own ring offsets, and recurrent scans freeze at
    the slot's final valid token.  Logits come from each slot's own
    last row and the clock is set to `lengths`.  `update_mask` (B,)
    additionally restricts which slots' cache entries (and clocks) are
    written at all — slots outside the mask keep their previous state,
    so a scheduler can admit new requests into free slots of a live
    cache without disturbing in-flight sequences.

    Paged mode: `block_tables` (B, n_bt) addresses the pools of a paged
    CacheSpec cache.  `hist_len` (B,) says how many prompt tokens are
    ALREADY resident in each slot's shared prefix pages (prefix cache
    hit): `tokens` then holds only the un-resident suffix, queries take
    absolute positions `hist_len + i`, and attention runs over the
    gathered history pages plus the suffix.  `hist_pages` (static)
    bounds the history gather: max(hist_len) // page_size.

    Chunked mode (DESIGN.md §12): on a CONTIGUOUS cache `hist_len` (B,)
    instead means "tokens this slot already prefilled in earlier chunk
    calls" — `tokens` holds the next chunk, every cache kind continues
    from the slot's resident state (attention rows land at absolute
    rows, ring rows step write-then-attend like decode, SSM / RG-LRU
    scans seed from the stored recurrent state), and slots with
    `hist_len == 0` behave exactly like a fresh ragged admit, so one
    call can mix first and continuation chunks."""
    if lengths is not None and (embeds is not None or cfg.prefix_tokens):
        raise NotImplementedError(
            "ragged prefill does not support embeds / VLM prefix archs")
    if block_tables is not None and lengths is None:
        raise NotImplementedError(
            "paged prefill is ragged-only (pass lengths)")
    if hist_len is not None and lengths is None:
        raise NotImplementedError(
            "hist_len (chunked/suffix continuation) is ragged-only "
            "(pass lengths)")
    if hist_pages and hist_len is None:
        raise ValueError("hist_pages needs hist_len")
    if hist_pages and block_tables is None:
        raise ValueError("hist_pages needs block_tables (paged cache)")
    if block_tables is not None and hist_pages > block_tables.shape[1]:
        raise ValueError(f"hist_pages {hist_pages} exceeds block table "
                         f"span {block_tables.shape[1]}")
    x = _embed_in(params, cfg, tokens, embeds, compute_dtype)
    b, s = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    if hist_len is not None:
        # suffix-only prefill: rotary and causal masking need the
        # absolute positions past each slot's resident prefix
        positions = positions + hist_len[:, None].astype(jnp.int32)

    def body(carry, inp):
        x, = carry
        pp, cc = inp
        for j, kind in enumerate(cfg.layer_pattern):
            x, cc_new = _prefill_block(kind, pp[f"b{j}"], cfg, x, positions,
                                       cc[f"b{j}"], lengths, update_mask,
                                       block_tables, hist_len, hist_pages)
            cc = {**cc,
                  f"b{j}": _merge_block(update_mask, cc_new, cc[f"b{j}"])}
        x = constrain(x, "batch", "residual", None)
        return (x,), cc

    body_rm = jax.checkpoint(body, prevent_cse=False)
    (x,), new_slots = jax.lax.scan(body_rm, (x,),
                                   (params["stack"], cache["slots"]))
    new_tail = []
    for i, p_tail in enumerate(params["tail"]):
        x, c_new = _prefill_block(cfg.layer_pattern[i], p_tail, cfg, x,
                                  positions, cache["tail"][i], lengths,
                                  update_mask, block_tables, hist_len,
                                  hist_pages)
        new_tail.append(_merge_block(update_mask, c_new, cache["tail"][i]))
    if lengths is None:
        logits = _logits_out(params, cfg, x[:, -1:])
        new_t = jnp.full((b,), s, jnp.int32)
    else:
        last = layers.gather_rows(x, jnp.clip(lengths, 1, s) - 1)
        logits = _logits_out(params, cfg, last)
        new_t = lengths.astype(jnp.int32)
        if hist_len is not None:
            # the clock counts ALL resident rows, shared prefix included
            new_t = new_t + hist_len.astype(jnp.int32)
    if update_mask is not None:
        old_t = cache["t"]
        if old_t.ndim == 0:  # legacy scalar clock
            old_t = jnp.broadcast_to(old_t, (b,))
        new_t = jnp.where(update_mask, new_t, old_t)
    return logits, {"t": new_t, "slots": new_slots, "tail": new_tail}


def _ring_place(k: Array, lengths: Array, size: int) -> Array:
    """Per-slot ring placement: store each slot's last `size` valid rows
    at their absolute ring positions (pos % size).  k (B, S, ...) — any
    trailing dims (KV, hd) for rows, (KV,) for the int8 codec's scales;
    slots shorter than the ring keep rows [0, L) at identity positions
    (rows >= L are garbage, masked by the slot's clock at decode)."""
    s = k.shape[1]
    r = jnp.arange(size, dtype=jnp.int32)[None, :]
    ll = lengths[:, None].astype(jnp.int32)
    pos = jnp.where(ll >= size, ll - size + jnp.mod(r - ll, size), r)
    pos = jnp.clip(pos, 0, s - 1)
    idx = pos.reshape(pos.shape + (1,) * (k.ndim - 2))
    return jnp.take_along_axis(k, idx, axis=1)


def _paged_prefill_attn(cfg: ArchConfig, q, k, v, c: dict, positions,
                        lengths, update_mask, block_tables, hist_len,
                        hist_pages: int):
    """Paged attention prefill: scatter the suffix rows through the
    block table and attend over (gathered history pages + suffix).

    Returns (new_cache, o).  The attention buffer is logical-row
    indexed — row r holds the token at absolute position r — built from
    `hist_pages` gathered pages plus the suffix scattered at its
    absolute rows, so slots with shorter (or no) shared history simply
    overwrite their gathered rows with the live suffix.  With no
    history (h0 == 0) the buffer carries exactly the rows the
    contiguous ragged path hands `flash_attention` (invalid rows are
    zeros instead of pad-token garbage; both mask to an exact 0.0
    contribution), so paged prefill is bit-identical to contiguous."""
    b, s, kv, hd = k.shape
    n_pool, page = c["k_pages"].shape[0], c["k_pages"].shape[1]
    n_bt = block_tables.shape[1]
    ll = (jnp.full((b,), s) if lengths is None else lengths).astype(jnp.int32)
    hist0 = (jnp.zeros((b,)) if hist_len is None else hist_len).astype(
        jnp.int32)
    j = jnp.arange(s, dtype=jnp.int32)[None, :]
    absp = hist0[:, None] + j                       # (B, S) absolute rows
    valid = j < ll[:, None]
    if update_mask is not None:
        valid &= update_mask[:, None]
    pidx = jnp.clip(absp // page, 0, n_bt - 1)
    phys = jnp.take_along_axis(block_tables, pidx, axis=1)
    # invalid rows and unallocated table holes route to the sentinel
    # pool row n_pool; mode="drop" discards those scatters
    phys = jnp.where(valid & (phys >= 0), phys, n_pool).astype(jnp.int32)
    off = (absp % page).astype(jnp.int32)
    if "k_scale_pages" in c:  # int8 codec: scales page with their rows
        kq, ks = kv_quantize(k)
        vq, vs = kv_quantize(v)
        store = {"k_pages": kq, "v_pages": vq,
                 "k_scale_pages": ks, "v_scale_pages": vs}
    else:
        store = {"k_pages": k, "v_pages": v}
    new_c = {nm: c[nm].at[phys, off].set(val.astype(c[nm].dtype),
                                         mode="drop")
             for nm, val in store.items()}

    h0 = hist_pages * page
    bufk = jnp.zeros((b, h0 + s, kv, hd), k.dtype)
    bufv = jnp.zeros((b, h0 + s, kv, hd), v.dtype)
    if h0:
        idx = jnp.clip(block_tables[:, :hist_pages], 0, n_pool - 1)
        hk = c["k_pages"][idx].reshape(b, h0, kv, hd)
        hv = c["v_pages"][idx].reshape(b, h0, kv, hd)
        if "k_scale_pages" in c:
            hk = kv_dequantize(hk, c["k_scale_pages"][idx].reshape(b, h0, kv),
                               k.dtype)
            hv = kv_dequantize(hv, c["v_scale_pages"][idx].reshape(b, h0, kv),
                               v.dtype)
        bufk = bufk.at[:, :h0].set(hk.astype(k.dtype))
        bufv = bufv.at[:, :h0].set(hv.astype(v.dtype))
    rows = jnp.where(valid, absp, h0 + s)           # sentinel -> dropped
    bidx = jnp.arange(b, dtype=jnp.int32)[:, None]
    bufk = bufk.at[bidx, rows].set(k, mode="drop")
    bufv = bufv.at[bidx, rows].set(v, mode="drop")
    o = layers.flash_attention(q, bufk, bufv, positions, hist0 + ll,
                               cfg.is_causal, 0, min(512, h0 + s))
    return new_c, o


def _chunk_prefill_attn(p, cfg: ArchConfig, kind: str, q, store: dict,
                        c: dict, positions, lengths, size: int):
    """Contiguous chunk-continuation attention prefill (DESIGN.md §12):
    the chunk's rows join a cache that already holds each slot's earlier
    chunks at rows [0, hist), `positions` carrying the absolute offsets.

    Full attention writes every chunk row at its absolute cache row in
    one shot (rows past a slot's chunk length write back the old value —
    clipping could alias a live row) and attends with per-query
    `kv_len = pos + 1`, the same masked read the decode step uses.

    Sliding windows can NOT batch the writes: a wrapped write at
    absolute position p destroys the ring row holding p - size, which is
    still inside the window of every earlier query in the chunk.  The
    ring steps write-then-attend sequentially at query width 1 (only the
    attention — QKV and the MLP stay chunk-wide), which is bit-for-bit
    the decode path's operation order; rows past a slot's chunk length
    skip their write so live window rows survive.  This is the
    speculative verify's ring discipline (`_spec_block`) re-applied to
    ingestion."""
    b, s = q.shape[0], q.shape[1]
    if kind == "attn":
        j = jnp.arange(s, dtype=jnp.int32)[None, :]
        valid = j < lengths[:, None]
        rows = jnp.clip(positions, 0, size - 1)
        bidx = jnp.arange(b, dtype=jnp.int32)[:, None]
        new_c = {}
        for nm, val in store.items():
            old = c[nm][bidx, rows]
            vmask = valid.reshape(valid.shape + (1,) * (val.ndim - 2))
            new_c[nm] = layers.slot_update_many(
                c[nm], rows, jnp.where(vmask, val.astype(c[nm].dtype), old))
        o = layers.cached_attention(
            p["attn"], cfg, q, new_c["k"], new_c["v"], positions,
            jnp.minimum(positions + 1, size),
            k_scale=new_c.get("k_scale"), v_scale=new_c.get("v_scale"))
        return new_c, o

    valid = jnp.arange(s, dtype=jnp.int32)[None, :] < lengths[:, None]

    def astep(cc, inp):
        q_i, pos_i, vals, valid_i = inp
        idx_i = jnp.mod(pos_i, size).astype(jnp.int32)
        cc = {nm: layers.slot_update(cc[nm], idx_i, vals[nm],
                                     active=valid_i)
              for nm in cc}
        h_i = layers.cached_attention(
            p["attn"], cfg, q_i[:, None], cc["k"], cc["v"],
            pos_i[:, None], jnp.minimum(pos_i + 1, size),
            k_scale=cc.get("k_scale"), v_scale=cc.get("v_scale"))
        return cc, h_i[:, 0]

    new_c, hs = jax.lax.scan(
        astep, {nm: c[nm] for nm in store},
        (jnp.moveaxis(q, 1, 0), jnp.moveaxis(positions, 1, 0),
         {nm: jnp.moveaxis(val, 1, 0) for nm, val in store.items()},
         jnp.moveaxis(valid, 1, 0)))
    return new_c, jnp.moveaxis(hs, 0, 1)


def _chunk_state(c: dict, hist_len: Array, names: tuple[str, ...]) -> dict:
    """Recurrent state a chunk continuation seeds its scans with: the
    slot's stored state, zeroed for slots whose history is empty — a
    first chunk must start from the fresh-state identity, not whatever
    the slot's previous occupant left behind (zero IS that identity for
    conv windows, SSD state and RG-LRU h alike), so one fused call can
    mix first and continuation chunks."""
    live = hist_len > 0
    return {nm: jnp.where(live.reshape((-1,) + (1,) * (c[nm].ndim - 1)),
                          c[nm], 0)
            for nm in names}


def _prefill_block(kind: str, p, cfg: ArchConfig, x, positions, c,
                   lengths: Array | None = None,
                   update_mask: Array | None = None,
                   block_tables: Array | None = None,
                   hist_len: Array | None = None, hist_pages: int = 0):
    b, s = x.shape[0], x.shape[1]
    if kind in ("attn", "local"):
        window = cfg.window if kind == "local" else 0
        xin = rms_norm(p["norm1"], x, cfg.norm_eps)
        q, k, v = layers.attn_qkv(p["attn"], cfg, xin, positions)
        if "k_pages" in c:
            if block_tables is None:
                raise ValueError("paged cache prefill needs block_tables")
            new_c, o = _paged_prefill_attn(cfg, q, k, v, c, positions,
                                           lengths, update_mask,
                                           block_tables, hist_len,
                                           hist_pages)
        else:
            size = c["k"].shape[1]
            if "k_scale" in c:  # int8 codec: store quantized rows + scales,
                # placed by the SAME ops as the rows they describe
                kq, ks = kv_quantize(k)
                vq, vs = kv_quantize(v)
                store = {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
            else:
                store = {"k": k, "v": v}
            if hist_len is not None:  # chunk continuation (DESIGN.md §12)
                new_c, o = _chunk_prefill_attn(p, cfg, kind, q, store, c,
                                               positions, lengths, size)
            elif size >= s:  # full cache: write rows [0, s)
                new_c = {nm: jax.lax.dynamic_update_slice(
                    c[nm], val.astype(c[nm].dtype), (0,) * c[nm].ndim)
                    for nm, val in store.items()}
            elif lengths is None:  # ring: keep the last `size` rows, rolled
                roll = (s % size)
                new_c = {nm: jnp.roll(val[:, -size:], roll,
                                      axis=1).astype(c[nm].dtype)
                         for nm, val in store.items()}
            else:  # ragged ring: each slot's tail at its own ring offsets
                new_c = {nm: _ring_place(val, lengths,
                                         size).astype(c[nm].dtype)
                         for nm, val in store.items()}
            if hist_len is None:
                kv_len = (jnp.full((b,), s, jnp.int32) if lengths is None
                          else lengths.astype(jnp.int32))
                if window > 0 and cfg.is_causal:
                    o = layers.local_attention(q, k, v, window)
                else:
                    o = layers.flash_attention(q, k, v, positions, kv_len,
                                               cfg.is_causal, window,
                                               min(512, s))
        if hist_len is not None and "k_pages" not in c:
            x = x + o  # cached_attention already applied wo
        else:
            x = x + dense(p["attn"]["wo"],
                          o.reshape(b, s, cfg.n_heads * cfg.head_dim_))
        h2in = rms_norm(p["norm2"], x, cfg.norm_eps)
        if cfg.moe is not None:
            h2, _ = moe.moe_block(p["moe"], cfg, h2in)
        else:
            h2 = mlp(p["mlp"], h2in)
        return x + h2, new_c
    if kind == "ssm":
        xin = rms_norm(p["norm1"], x, cfg.norm_eps)
        st = (None if hist_len is None
              else _chunk_state(c, hist_len, ("conv", "state")))
        h, conv, state = _ssm_prefill(p["ssm"], cfg, xin, lengths, state=st)
        return x + h, {"conv": conv.astype(c["conv"].dtype), "state": state}
    if kind == "rglru":
        xin = rms_norm(p["norm1"], x, cfg.norm_eps)
        st = (None if hist_len is None
              else _chunk_state(c, hist_len, ("conv", "h")))
        h, conv, hstate = _rglru_prefill(p["rec"], cfg, xin, lengths,
                                         state=st)
        x = x + h
        x = x + mlp(p["mlp"], rms_norm(p["norm2"], x, cfg.norm_eps))
        return x, {"conv": conv.astype(c["conv"].dtype),
                   "h": hstate.astype(c["h"].dtype)}
    raise ValueError(kind)


def _ssm_prefill(p, cfg, x, lengths: Array | None = None,
                 state: dict | None = None):
    sc = cfg.ssm
    d_in = sc.expand * cfg.d_model
    u = x @ p["in_proj"]["w"].astype(x.dtype)
    z, xbc, dt, (s_, d_in, heads, gn) = ssm._split(p, cfg, u)
    conv_in = None if state is None else state["conv"]
    xbc_c, conv_state = ssm._causal_conv(p["conv_w"], p["conv_b"], xbc,
                                         conv_in)
    xs, b_mat, c_mat = jnp.split(xbc_c, [d_in, d_in + gn], axis=-1)
    bsz, length = x.shape[0], x.shape[1]
    xs = xs.reshape(bsz, length, heads, s_.head_dim)
    b_mat = b_mat.reshape(bsz, length, s_.n_groups, s_.d_state)
    c_mat = c_mat.reshape(bsz, length, s_.n_groups, s_.d_state)
    dt_full = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    if lengths is not None:
        # dt = 0 past a slot's length makes each pad step the identity on
        # the SSD state (decay exp(0)=1, input contribution x*dt = 0), so
        # the scan's final state is the state at the slot's last valid
        # token; the conv state is re-gathered at per-slot offsets.
        valid = jnp.arange(length)[None, :, None] < lengths[:, None, None]
        dt_full = jnp.where(valid, dt_full, 0.0)
        if conv_in is None:
            conv_state = ssm.ragged_conv_state(xbc, lengths, sc.conv_width)
        else:
            # chunk continuation: the decode state after this chunk may
            # reach back into the PREVIOUS chunk's inputs (chunks shorter
            # than the conv window), so re-gather over [prior state ‖
            # chunk] with the valid prefix shifted by the state rows
            w1 = sc.conv_width - 1
            conv_state = ssm.ragged_conv_state(
                jnp.concatenate([conv_in.astype(xbc.dtype), xbc], axis=1),
                lengths + w1, sc.conv_width)
    y, state_out = ssm.ssd_chunked(xs, dt_full, p["A_log"], b_mat, c_mat,
                                   p["D"], s_.chunk,
                                   h0=None if state is None
                                   else state["state"])
    y = y.reshape(bsz, length, d_in).astype(x.dtype)
    y = rms_norm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return y @ p["out_proj"]["w"].astype(x.dtype), conv_state, state_out


def _rglru_prefill(p, cfg, x, lengths: Array | None = None,
                   state: dict | None = None):
    y = jax.nn.gelu(dense(p["lin_y"], x))
    u_in = dense(p["lin_x"], x)
    conv_in = None if state is None else state["conv"]
    u, conv_state = ssm._causal_conv(p["conv_w"], p["conv_b"], u_in,
                                     conv_in, act=False)
    width = p["conv_w"].shape[0]
    valid = None
    if lengths is not None:
        valid = (jnp.arange(x.shape[1])[None, :] < lengths[:, None])
        if conv_in is None:
            conv_state = ssm.ragged_conv_state(u_in, lengths, width)
        else:  # chunk continuation: same [prior state ‖ chunk] re-gather
            # as `_ssm_prefill` (chunks can be shorter than the window)
            conv_state = ssm.ragged_conv_state(
                jnp.concatenate([conv_in.astype(u_in.dtype), u_in], axis=1),
                lengths + (width - 1), width)
    h, h_last = rglru.rglru_scan(p, u,
                                 h0=None if state is None else state["h"],
                                 valid=valid)
    return dense(p["lin_out"], h * y), conv_state, h_last


# --------------------------------------------------------------------------
# Speculative decoding (DESIGN.md §9)
# --------------------------------------------------------------------------
#
# Two primitives carry the whole plane:
#
#   spec_forward   one W-wide teacher-forced pass (W = k+1 verify tokens)
#                  that writes ALL W rows/states speculatively and hands
#                  back an `undo` record sized to what rollback actually
#                  needs per cache kind;
#   spec_commit    clock = t0 + keep per slot, plus the minimal repair:
#                  nothing for full attention (stale rows sit past the
#                  clock, masked everywhere), restore the overwritten
#                  ring rows beyond `keep` for sliding windows, select
#                  the state after `keep` tokens from a (W+1)-stash for
#                  SSM / RG-LRU.
#
# `verify_step` composes them with the greedy accept rule and
# `spec_advance` reuses them to replay the accepted tokens through the
# draft's own cache with an externally supplied `keep` — so the draft
# and target stay clock-synchronized with three dispatches per tick.
#
# Parity is exact by construction: the recurrent kinds step the SAME
# `*_decode_step` functions the plain decode path uses (scanned per
# token), and attention reads the same cache rows a sequence of 1-wide
# steps would have produced.


def _spec_block(kind: str, p, cfg: ArchConfig, x: Array, t: Array, c: dict,
                active: Array | None, block_tables: Array | None):
    """W-wide teacher-forced step for one block: x (B, W, D), positions
    t..t+W-1 per slot.  Returns (x, new_cache_slice, undo) where `undo`
    holds exactly what `_commit_block` needs to roll this block back to
    any prefix length in [0, W]."""
    b, w = x.shape[0], x.shape[1]
    pos = t[:, None].astype(jnp.int32) + jnp.arange(w, dtype=jnp.int32)[None]
    if kind in ("attn", "local"):
        q, k_new, v_new = layers.attn_qkv(
            p["attn"], cfg, rms_norm(p["norm1"], x, cfg.norm_eps), pos)
        if "k_pages" in c:
            if block_tables is None:
                raise ValueError("paged cache decode needs block_tables")
            n_pool, page = c["k_pages"].shape[0], c["k_pages"].shape[1]
            n_bt = block_tables.shape[1]
            pidx = jnp.clip(pos // page, 0, n_bt - 1).astype(jnp.int32)
            phys = jnp.take_along_axis(block_tables, pidx, axis=1)  # (B, W)
            if active is not None:
                phys = jnp.where(active[:, None], phys, n_pool)
            phys = jnp.where(phys < 0, n_pool, phys).astype(jnp.int32)
            off = (pos % page).astype(jnp.int32)
            if "k_scale_pages" in c:
                kq, ks = kv_quantize(k_new)
                vq, vs = kv_quantize(v_new)
                store = {"k_pages": kq, "v_pages": vq,
                         "k_scale_pages": ks, "v_scale_pages": vs}
            else:
                store = {"k_pages": k_new, "v_pages": v_new}
            new_c = {nm: layers.paged_slot_update(c[nm], phys, off, val)
                     for nm, val in store.items()}
            # per-QUERY valid length pos+1 makes the W-wide pass causal;
            # full attention never wraps, so rejected rows just sit past
            # the rolled-back clock (and their pages are released host-
            # side) — no device-side undo at all
            h = layers.paged_cached_attention(
                p["attn"], cfg, q, new_c, block_tables, pos + 1)
            undo: dict[str, Any] = {}
        else:
            size = c["k"].shape[1]
            idx = (pos % size).astype(jnp.int32)                    # (B, W)
            bidx = jnp.arange(b)[:, None]
            if "k_scale" in c:
                kq, ks = kv_quantize(k_new)
                vq, vs = kv_quantize(v_new)
                store = {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
            else:
                store = {"k": k_new, "v": v_new}
            if kind == "local":
                # Ring caches can't run the fused W-wide attention: a
                # wrapped write for draft token i destroys the ring row
                # holding position t+i-size, which is still INSIDE the
                # window of every earlier query j < i — masking the slot
                # would shrink j's window, not reproduce it.  So the
                # attention (and only the attention — QKV and the MLP
                # stay W-wide) steps the ring sequentially, which is
                # bit-for-bit the decode path's write-then-attend.
                # Rollback still needs the W overwritten rows: capture
                # them before the scan (W <= window, so the scan never
                # writes the same row twice).
                undo = {"idx": idx,
                        "rows": {nm: c[nm][bidx, idx] for nm in store}}

                def astep(cc, inp):
                    q_i, pos_i, idx_i, vals = inp
                    cc = {nm: layers.slot_update(cc[nm], idx_i, vals[nm])
                          for nm in cc}
                    h_i = layers.cached_attention(
                        p["attn"], cfg, q_i[:, None], cc["k"], cc["v"],
                        pos_i[:, None], jnp.minimum(pos_i + 1, size),
                        k_scale=cc.get("k_scale"),
                        v_scale=cc.get("v_scale"))
                    return cc, h_i[:, 0]

                new_c, hs = jax.lax.scan(
                    astep, {nm: c[nm] for nm in store},
                    (jnp.moveaxis(q, 1, 0), jnp.moveaxis(pos, 1, 0),
                     jnp.moveaxis(idx, 1, 0),
                     {nm: jnp.moveaxis(val, 1, 0)
                      for nm, val in store.items()}))
                h = jnp.moveaxis(hs, 0, 1)
            else:
                # full attention never wraps (headroom is validated at
                # submit), so all W rows can land before one fused pass:
                # per-query kv_len = pos+1 masks later rows from earlier
                # queries and no undo is needed — rejected rows sit past
                # the rolled-back clock, masked everywhere.
                undo = {}
                new_c = {nm: layers.slot_update_many(c[nm], idx, val)
                         for nm, val in store.items()}
                h = layers.cached_attention(
                    p["attn"], cfg, q, new_c["k"], new_c["v"], pos,
                    jnp.minimum(pos + 1, size),
                    k_scale=new_c.get("k_scale"),
                    v_scale=new_c.get("v_scale"))
        x = x + h
        h2in = rms_norm(p["norm2"], x, cfg.norm_eps)
        if cfg.moe is not None:
            h2, _ = moe.moe_block(p["moe"], cfg, h2in)
        else:
            h2 = mlp(p["mlp"], h2in)
        return x + h2, new_c, undo
    if kind == "ssm":
        xin = rms_norm(p["norm1"], x, cfg.norm_eps)

        def sstep(carry, xt):
            conv, state = carry
            y, conv2, state2 = ssm.ssm_decode_step(
                p["ssm"], cfg, xt[:, None, :], conv, state)
            # ys carry the PRE-step states: stash[i] = state after i
            # tokens, so commit selects stash[keep] directly
            return ((conv2.astype(conv.dtype), state2.astype(state.dtype)),
                    (y[:, 0], conv, state))

        (convf, statef), (ys, convs, states) = jax.lax.scan(
            sstep, (c["conv"], c["state"]), jnp.moveaxis(xin, 1, 0))
        new = {"conv": convf.astype(c["conv"].dtype), "state": statef}
        undo = {"conv": jnp.concatenate([convs, convf[None]], axis=0),
                "state": jnp.concatenate([states, statef[None]], axis=0)}
        return x + jnp.moveaxis(ys, 0, 1), new, undo
    if kind == "rglru":
        xin = rms_norm(p["norm1"], x, cfg.norm_eps)

        def rstep(carry, xt):
            conv, hst = carry
            o, conv2, h2 = rglru.rglru_decode_step(
                p["rec"], cfg, xt[:, None, :], conv, hst)
            return ((conv2.astype(conv.dtype), h2.astype(hst.dtype)),
                    (o[:, 0], conv, hst))

        (convf, hf), (os_, convs, hs) = jax.lax.scan(
            rstep, (c["conv"], c["h"]), jnp.moveaxis(xin, 1, 0))
        x = x + jnp.moveaxis(os_, 0, 1)
        x = x + mlp(p["mlp"], rms_norm(p["norm2"], x, cfg.norm_eps))
        new = {"conv": convf.astype(c["conv"].dtype),
               "h": hf.astype(c["h"].dtype)}
        undo = {"conv": jnp.concatenate([convs, convf[None]], axis=0),
                "h": jnp.concatenate([hs, hf[None]], axis=0)}
        return x, new, undo
    raise ValueError(kind)


def spec_forward(params, cfg: ArchConfig, cache: dict, tokens: Array, *,
                 compute_dtype=jnp.bfloat16, active: Array | None = None,
                 block_tables: Array | None = None):
    """tokens (B, W) teacher-forced at positions t..t+W-1 -> (logits
    (B, W, V), spec_cache, undo).  All W rows/states are written
    speculatively; `spec_cache` has NOT had its clock advanced — feed it
    with `undo` to `spec_commit` to pick each slot's accepted prefix."""
    b = tokens.shape[0]
    t = cache["t"]
    if t.ndim == 0:  # legacy scalar clock (pre-vector caches)
        t = jnp.broadcast_to(t, (b,))
    x = params["embed"].astype(compute_dtype)[tokens]
    x = constrain(x, "batch", None, "embed")

    def body(x, inp):
        pp, cc = inp
        undos = {}
        for j, kind in enumerate(cfg.layer_pattern):
            x, cc_new, u = _spec_block(kind, pp[f"b{j}"], cfg, x, t,
                                       cc[f"b{j}"], active, block_tables)
            cc = {**cc, f"b{j}": cc_new}
            undos[f"b{j}"] = u
        return x, (cc, undos)

    x, (new_slots, undo_slots) = jax.lax.scan(
        body, x, (params["stack"], cache["slots"]))
    new_tail, undo_tail = [], []
    for i, p_tail in enumerate(params["tail"]):
        x, c_new, u = _spec_block(cfg.layer_pattern[i], p_tail, cfg, x, t,
                                  cache["tail"][i], active, block_tables)
        new_tail.append(c_new)
        undo_tail.append(u)
    logits = _logits_out(params, cfg, x)
    spec_cache = {"t": t, "slots": new_slots, "tail": new_tail}
    return logits, spec_cache, {"t0": t, "slots": undo_slots,
                                "tail": undo_tail}


def _commit_block(kind: str, c: dict, undo: dict, keep: Array) -> dict:
    """Roll one block's speculative writes back to `keep` (B,) tokens."""
    if not undo:  # full attention: clock masking is the whole story
        return c
    if kind == "local":  # restore the ring rows beyond each slot's keep
        idx = undo["idx"]                                           # (B, W)
        w = idx.shape[1]
        bidx = jnp.arange(idx.shape[0])[:, None]
        committed = jnp.arange(w, dtype=jnp.int32)[None, :] < keep[:, None]
        out = dict(c)
        for nm, old in undo["rows"].items():
            cur = c[nm][bidx, idx]
            mask = committed.reshape(committed.shape
                                     + (1,) * (cur.ndim - 2))
            out[nm] = c[nm].at[bidx, idx].set(
                jnp.where(mask, cur, old.astype(cur.dtype)))
        return out
    # recurrent: pick the state after `keep` tokens from the (W+1)-stash
    out = dict(c)
    for nm, stack in undo.items():
        sel = jnp.take_along_axis(
            stack, keep.reshape((1, -1) + (1,) * (stack.ndim - 2)),
            axis=0)[0]
        out[nm] = sel.astype(c[nm].dtype)
    return out


def spec_commit(cfg: ArchConfig, cache: dict, undo: dict,
                keep: Array) -> dict:
    """Accept each slot's first `keep` (B,) of the W speculative tokens:
    clock-decrement rollback (t = t0 + keep) plus the per-kind repairs
    of `_commit_block`.  keep == 0 leaves a slot exactly as it was."""
    keep = keep.astype(jnp.int32)

    def commit_period(inp):
        cc, uu = inp
        return {f"b{j}": _commit_block(kind, cc[f"b{j}"], uu[f"b{j}"], keep)
                for j, kind in enumerate(cfg.layer_pattern)}

    _, slots = jax.lax.scan(lambda carry, inp: (carry, commit_period(inp)),
                            0, (cache["slots"], undo["slots"]))
    tail = [_commit_block(cfg.layer_pattern[i], cache["tail"][i],
                          undo["tail"][i], keep)
            for i in range(len(cache["tail"]))]
    return {"t": undo["t0"] + keep, "slots": slots, "tail": tail}


def verify_step(params, cfg: ArchConfig, cache: dict, tokens: Array, *,
                compute_dtype=jnp.bfloat16, active: Array | None = None,
                block_tables: Array | None = None):
    """Score W = k+1 verify tokens (slot's last committed token + k
    drafts) in one pass; greedy-accept the longest matching prefix.

    Returns (g (B, W) int32, n_acc (B,), new_cache): g[b, :n_acc[b]+1]
    is exactly the token stream target-only greedy decode would emit
    (the n_acc accepted drafts plus one correction/bonus token — every
    tick commits at least one token), and new_cache is committed to
    keep = n_acc + 1 rows per active slot."""
    logits, spec_cache, undo = spec_forward(
        params, cfg, cache, tokens, compute_dtype=compute_dtype,
        active=active, block_tables=block_tables)
    g = jnp.argmax(logits, axis=-1).astype(jnp.int32)               # (B, W)
    match = (g[:, :-1] == tokens[:, 1:]).astype(jnp.int32)          # (B,W-1)
    n_acc = jnp.cumprod(match, axis=1).sum(axis=1)                  # (B,)
    keep = n_acc + 1
    if active is not None:
        keep = jnp.where(active, keep, 0)
        n_acc = jnp.where(active, n_acc, 0)
    return g, n_acc, spec_commit(cfg, spec_cache, undo, keep)


def spec_advance(params, cfg: ArchConfig, cache: dict, tokens: Array,
                 keep: Array, *, compute_dtype=jnp.bfloat16,
                 active: Array | None = None,
                 block_tables: Array | None = None):
    """Replay `tokens` (B, W) through `cache`, committing only `keep`
    (B,) of them — the draft-resync half of a speculative tick: the
    draft's cache consumes the SAME verify window the target scored,
    truncated to what the target accepted."""
    _, spec_cache, undo = spec_forward(
        params, cfg, cache, tokens, compute_dtype=compute_dtype,
        active=active, block_tables=block_tables)
    keep = keep.astype(jnp.int32)
    if active is not None:
        keep = jnp.where(active, keep, 0)
    return spec_commit(cfg, spec_cache, undo, keep)


def draft_propose(params, cfg: ArchConfig, cache: dict, token: Array,
                  n: int, *, compute_dtype=jnp.bfloat16,
                  active: Array | None = None):
    """Greedy-propose `n` draft tokens from `token` (B,): an n-step scan
    of `decode_step` with argmax feedback over a THROWAWAY copy of
    `cache` — the caller's cache is not advanced (the persistent draft
    cache is advanced by `spec_advance` replaying the verify window, so
    it never diverges from what the target committed)."""
    def step(carry, _):
        cc, tok = carry
        logits, cc = decode_step(params, cfg, cc, tok[:, None],
                                 compute_dtype=compute_dtype, active=active)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return (cc, nxt), nxt

    _, drafts = jax.lax.scan(step, (cache, token.astype(jnp.int32)),
                             None, length=n)
    return jnp.moveaxis(drafts, 0, 1)                               # (B, n)
