"""mistral-large-123b [dense]: 88L d_model=12288 96H (GQA kv=8)
d_ff=28672 vocab=32768 [hf:mistralai/Mistral-Large-Instruct-2407].
Pure full attention => long_500k skipped.  The 123B scale is the
dry-run's FSDP + grad-accumulation stress case."""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mistral-large-123b",
    kind="decoder",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv=8,
    d_ff=28672,
    vocab=32768,
    head_dim=128,
    rope_theta=1e6,
)

SMOKE = ArchConfig(
    name="mistral-large-123b-smoke",
    kind="decoder",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv=2,
    d_ff=160,
    vocab=128,
    head_dim=16,
)
