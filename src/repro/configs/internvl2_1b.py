"""internvl2-1b [vlm]: 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655 — InternViT + InternLM2 [arXiv:2404.16821].

The InternViT vision frontend is a STUB per the brief: input_specs
provide 256 precomputed patch embeddings (B, 256, 896) prepended to the
text tokens.  The LM backbone is the assigned config.  Full attention =>
long_500k skipped."""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-1b",
    kind="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv=2,
    d_ff=4864,
    vocab=151655,
    head_dim=64,
    prefix_tokens=256,
    rope_theta=1e6,
)

SMOKE = ArchConfig(
    name="internvl2-1b-smoke",
    kind="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=2,
    d_ff=128,
    vocab=128,
    head_dim=16,
    prefix_tokens=8,
)
