"""The assigned input-shape set and (arch x shape) cell applicability.

    train_4k     seq 4,096   global_batch 256   lowers train_step
    prefill_32k  seq 32,768  global_batch 32    lowers prefill_step
    decode_32k   seq 32,768  global_batch 128   lowers decode (serve) step
    long_500k    seq 524,288 global_batch 1     lowers decode step

Skips (documented in DESIGN.md §Arch-applicability):
  * encoder-only archs have no decode step -> decode_32k/long_500k skipped;
  * long_500k needs sub-quadratic attention -> skipped for pure
    full-attention archs, run for SSM / hybrid / SWA / 5:1-local.
"""

from __future__ import annotations

import dataclasses

from repro.models.config import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    step: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def applicable(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) for one (arch, shape) cell."""
    if cfg.kind == "encoder" and shape.step == "decode":
        return False, "encoder-only: no decode step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full attention: long_500k needs sub-quadratic"
    return True, ""


def cells(configs: dict[str, ArchConfig]):
    """Every (arch, shape) pair with its skip status — the 40-cell grid."""
    for arch, cfg in configs.items():
        for shape in SHAPES.values():
            runs, why = applicable(cfg, shape)
            yield arch, cfg, shape, runs, why
