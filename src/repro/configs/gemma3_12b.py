"""gemma3-12b [dense]: 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144 — 5:1 local:global attention, 128k context
[hf:google/gemma-3-1b-pt].  long_500k RUNS: decode is dominated by the
1024-window local layers; the 8 global layers are O(L) per token."""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-12b",
    kind="decoder",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv=8,
    d_ff=15360,
    vocab=262144,
    layer_pattern=("local",) * 5 + ("attn",),
    window=1024,
    head_dim=240,
    rope_theta=1e6,
    sub_quadratic=True,      # 5:1 local => long_500k viable
)

SMOKE = ArchConfig(
    name="gemma3-12b-smoke",
    kind="decoder",
    n_layers=6,              # one full (5 local + 1 global) period
    d_model=64,
    n_heads=4,
    n_kv=2,
    d_ff=128,
    vocab=128,
    layer_pattern=("local",) * 5 + ("attn",),
    window=16,
    head_dim=16,
    sub_quadratic=True,
)
