"""qwen3-14b [dense]: 40L d_model=5120 40H (GQA kv=8) d_ff=17408
vocab=151936 — qk-norm + GQA [hf:Qwen/Qwen3-8B].  Pure full attention
=> long_500k skipped."""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-14b",
    kind="decoder",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv=8,
    d_ff=17408,
    vocab=151936,
    qk_norm=True,
    head_dim=128,
    rope_theta=1e6,
)

SMOKE = ArchConfig(
    name="qwen3-14b-smoke",
    kind="decoder",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=2,
    d_ff=128,
    vocab=128,
    qk_norm=True,
    head_dim=16,
)
