"""granite-moe-1b-a400m [moe]: 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base].  d_ff=512 expert GEMMs are
tiny — the paper's best-case skinny workload.  Full attention =>
long_500k skipped."""

from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m",
    kind="decoder",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv=8,
    d_ff=512,
    vocab=49155,
    head_dim=64,
    moe=MoEConfig(n_experts=32, top_k=8),
)

SMOKE = ArchConfig(
    name="granite-moe-1b-a400m-smoke",
    kind="decoder",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=2,
    d_ff=32,
    vocab=128,
    head_dim=16,
    moe=MoEConfig(n_experts=8, top_k=4),
)
