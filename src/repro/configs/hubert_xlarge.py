"""hubert-xlarge [audio]: 48L d_model=1280 16H (GQA kv=16) d_ff=5120
vocab=504 — encoder-only, same arch as wav2vec2 [arXiv:2106.07447].

The convolutional waveform frontend is a STUB per the brief: input_specs
provide precomputed frame embeddings (B, S, 1280); the transformer
backbone classifies each frame over the 504-entry codebook.  Encoder-only
=> no decode step (decode_32k / long_500k skipped, DESIGN.md §Arch).
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    kind="encoder",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv=16,
    d_ff=5120,
    vocab=504,
    gated_mlp=False,          # GELU FFN
    embed_inputs=True,        # stub frontend: frame embeddings in
    layer_pattern=("attn",),
)

SMOKE = ArchConfig(
    name="hubert-xlarge-smoke",
    kind="encoder",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=4,
    d_ff=128,
    vocab=59,
    gated_mlp=False,
    embed_inputs=True,
    layer_pattern=("attn",),
)
