"""qwen2-1.5b [dense]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936 — GQA with QKV bias [arXiv:2407.10671].  Pure full
attention => long_500k skipped (DESIGN.md §Arch)."""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-1.5b",
    kind="decoder",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv=2,
    d_ff=8960,
    vocab=151936,
    qkv_bias=True,
    head_dim=128,
    rope_theta=1e6,
)

SMOKE = ArchConfig(
    name="qwen2-1.5b-smoke",
    kind="decoder",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=2,
    d_ff=128,
    vocab=128,
    qkv_bias=True,
    head_dim=16,
)
