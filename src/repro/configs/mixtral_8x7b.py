"""mixtral-8x7b [moe]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, MoE 8 experts top-2, sliding-window attention
[arXiv:2401.04088].  SWA => long_500k runs (ring KV cache).  The
per-expert GEMMs carry token counts that vary with routing — exactly the
irregular-GEMM population the ReDas mapper targets."""

from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    kind="decoder",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv=8,
    d_ff=14336,
    vocab=32000,
    layer_pattern=("local",),   # SWA on every layer
    window=4096,
    head_dim=128,
    moe=MoEConfig(n_experts=8, top_k=2),
    rope_theta=1e6,
    sub_quadratic=True,
)

SMOKE = ArchConfig(
    name="mixtral-8x7b-smoke",
    kind="decoder",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=2,
    d_ff=128,
    vocab=128,
    layer_pattern=("local",),
    window=16,
    head_dim=16,
    moe=MoEConfig(n_experts=4, top_k=2),
    sub_quadratic=True,
)
