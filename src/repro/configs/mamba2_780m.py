"""mamba2-780m [ssm]: 48L d_model=1536 (attention-free) vocab=50280,
ssm_state=128 — SSD / state-space duality [arXiv:2405.21060].
Attention-free => the long_500k decode runs at O(1) state;
the ReDas mapper applies to the SSD chunk GEMMs and in/out projections
(DESIGN.md §Arch-applicability)."""

from repro.models.config import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-780m",
    kind="decoder",
    n_layers=48,
    d_model=1536,
    n_heads=0,            # attention-free
    n_kv=0,
    d_ff=0,
    vocab=50280,
    layer_pattern=("ssm",),
    ssm=SSMConfig(d_state=128, expand=2, head_dim=64, n_groups=1, chunk=256),
    sub_quadratic=True,
)

SMOKE = ArchConfig(
    name="mamba2-780m-smoke",
    kind="decoder",
    n_layers=2,
    d_model=64,
    n_heads=0,
    n_kv=0,
    d_ff=0,
    vocab=128,
    layer_pattern=("ssm",),
    ssm=SSMConfig(d_state=16, expand=2, head_dim=16, n_groups=1, chunk=16),
    sub_quadratic=True,
)
