"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (GQA kv=1) d_ff=7680
vocab=256000 — RG-LRU + local attention, 1 attn : 2 recurrent
[arXiv:2402.19427].  Pattern (rglru, rglru, local) x 8 + 2-layer tail;
window 2048.  Sub-quadratic => long_500k runs (constant-state decode).
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    kind="decoder",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv=1,
    d_ff=7680,
    vocab=256000,
    layer_pattern=("rglru", "rglru", "local"),
    window=2048,
    rglru_width=2560,
    head_dim=256,
    sub_quadratic=True,
)

SMOKE = ArchConfig(
    name="recurrentgemma-2b-smoke",
    kind="decoder",
    n_layers=5,           # 1 full period + (rglru, rglru) tail
    d_model=64,
    n_heads=2,
    n_kv=1,
    d_ff=128,
    vocab=128,
    layer_pattern=("rglru", "rglru", "local"),
    window=16,
    rglru_width=64,
    head_dim=32,
    sub_quadratic=True,
)
