"""Architecture registry: ``--arch <id>`` resolves here.

Each assigned architecture lives in its own module with the exact public
config (CONFIG) and a reduced same-family smoke config (SMOKE).  The
paper's own benchmark suite (ResNet-50 ... DeepSpeech2, Table 3) is the
plane-1 GEMM-trace workload set in repro.core.workloads — it has no LM
backbone, so it appears there rather than here.
"""

from __future__ import annotations

import importlib

from repro.models.config import ArchConfig

_MODULES = {
    "hubert-xlarge": "hubert_xlarge",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "qwen2-1.5b": "qwen2_1_5b",
    "mistral-large-123b": "mistral_large_123b",
    "gemma3-12b": "gemma3_12b",
    "qwen3-14b": "qwen3_14b",
    "mixtral-8x7b": "mixtral_8x7b",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "mamba2-780m": "mamba2_780m",
    "internvl2-1b": "internvl2_1b",
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str, smoke: bool = False) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCH_NAMES}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.SMOKE if smoke else mod.CONFIG


def all_configs(smoke: bool = False) -> dict[str, ArchConfig]:
    return {n: get_config(n, smoke) for n in ARCH_NAMES}
