"""Elastic resharding: move a state pytree onto a (different) mesh.

The checkpoint layer saves host-side arrays keyed by pytree path with no
record of the mesh they were computed on (checkpoint/checkpoint.py,
DESIGN.md §4).  Restoring therefore only needs the *target* placement:
`elastic_restore` derives it from the auto rule table on the target
mesh, so a run saved on a (4, 2) mesh restarts bit-identically on a
(2, 4) — or any other — mesh shape.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro.checkpoint import checkpoint as _ckpt_lib

from . import sharding


def reshard(tree, shardings):
    """device_put every leaf onto its target sharding (host -> device or
    device -> device; XLA inserts the collective moves)."""
    return jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)


def elastic_restore(ckpt, step: int, like, mesh: Mesh):
    """Restore checkpoint `step` placed for `mesh`, whatever mesh shape
    it was saved under.

    `like` is the abstract state tree (jax.eval_shape of the init);
    placement comes from sharding.params_shardings on the target mesh."""
    return ckpt.restore(step, like, sharding.params_shardings(like, mesh))


def resume_or_init(ckpt, init_fn, mesh: Mesh | None = None):
    """checkpoint.resume_or_init with placement derived from `mesh` via
    the auto rule table — the elastic-restart entry point."""
    shardings = (sharding.params_shardings(jax.eval_shape(init_fn), mesh)
                 if mesh is not None else None)
    return _ckpt_lib.resume_or_init(ckpt, init_fn, shardings)
