"""Named-axis sharding rules: the TP/FSDP/EP plane (DESIGN.md §5).

Two rule tables drive every placement decision in the repo:

1.  Activations are constrained by *logical* axis names (MaxText-style):
    model code says what a dimension *is* ("batch", "heads", "residual")
    and `LOGICAL_AXIS_RULES` says which mesh axes that meaning may shard
    over.  `spec()` resolves names -> `PartitionSpec` with two safety
    degradations, so the same constraint works on any mesh:
      * divisibility — a dim that does not divide the mesh-axis product
        replicates instead (e.g. long_500k's batch=1 frees 'data' for
        the kv sequence dim);
      * dedup — a mesh axis already consumed by an earlier dim of the
        same spec is skipped (a PartitionSpec may not repeat axes).

2.  Parameters are sharded by *name pattern* via `_auto_spec`.  The rule
    table (first match wins, matched on the '/'-joined key path):

    | pattern             | rule                                        |
    |---------------------|---------------------------------------------|
    | ndim <= 1           | replicate (norms, biases, scalars)          |
    | last part == embed  | vocab dim (dim 0) on 'model' iff divisible; |
    |                     | the gathered feature dim is NEVER sharded   |
    |                     | (spec has a single entry)                   |
    | stack/...           | leading stacked-layer axis NEVER sharded;   |
    |                     | remaining dims fall through to the rules    |
    |                     | below, shifted by one                       |
    | .../experts/...     | expert dim (first unstacked dim) on 'model' |
    |                     | (expert parallelism); the d_model dim on    |
    |                     | 'data' (FSDP, wi/wg dim 1 / wo last dim);   |
    |                     | d_ff replicated                             |
    | any other matmul    | last dim on 'model' (tensor parallelism),   |
    |                     | second-to-last on 'data' (FSDP)             |

    Every entry degrades to `None` independently when the dim does not
    divide the mesh axis.  Optimizer moments (opt/mu/..., opt/nu/...,
    opt/master/...) contain their parameter's key path as a suffix, so
    they inherit its spec for free — optimizer state is sharded exactly
    as its parameter (optim/adamw.py).

Mesh axes (launch/mesh.py): 'data' (batch + FSDP), 'model' (TP/EP),
optional 'pod' (composes with 'data' for batch parallelism).  All
helpers treat a missing or size-1 axis as "do not shard".
"""

from __future__ import annotations

import contextlib
import math

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# Mesh context
# ---------------------------------------------------------------------------

_MESH_STACK: list[Mesh] = []


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    """Make `mesh` the active mesh for `constrain` within the block.

    Single-controller convention: the stack is process-global (jit
    tracing happens on the thread that entered the context)."""
    _MESH_STACK.append(mesh)
    try:
        yield mesh
    finally:
        _MESH_STACK.pop()


def active_mesh() -> Mesh | None:
    return _MESH_STACK[-1] if _MESH_STACK else None


# ---------------------------------------------------------------------------
# Activation specs: logical axis names -> mesh axes
# ---------------------------------------------------------------------------

LOGICAL_AXIS_RULES: dict[str, tuple[str, ...]] = {
    "batch":    ("pod", "data"),
    "seq":      ("data",),
    "seq_kv":   ("data",),    # long-context: kv sequence over 'data' (SP)
    "embed":    ("model",),
    "residual": ("model",),   # remat carry / context parallelism
    "vocab":    ("model",),
    "heads":    ("model",),
    "kv_heads": ("model",),
    "mlp":      ("model",),
    "experts":  ("model",),
}


def spec(shape, names, mesh: Mesh) -> P:
    """Resolve logical axis `names` (str | None per dim) to a
    PartitionSpec for an array of `shape` on `mesh`.

    Divisibility-safe: a name resolves to the longest suffix of its rule
    tuple whose axis-size product divides the dim (so 'batch' drops
    'pod' before 'data'); anything that still does not fit, or whose
    mesh axes were consumed by an earlier dim, replicates."""
    sizes = dict(mesh.shape)
    used: set[str] = set()
    entries = []
    for dim, name in zip(shape, names, strict=False):
        if name is None:
            entries.append(None)
            continue
        if name not in LOGICAL_AXIS_RULES:
            raise ValueError(
                f"unknown logical axis {name!r}; add it to "
                f"LOGICAL_AXIS_RULES (DESIGN.md §5)")
        axes = tuple(a for a in LOGICAL_AXIS_RULES[name]
                     if sizes.get(a, 1) > 1 and a not in used)
        picked: tuple[str, ...] | None = None
        for i in range(len(axes)):
            cand = axes[i:]
            if dim % math.prod(sizes[a] for a in cand) == 0:
                picked = cand
                break
        if picked:
            used.update(picked)
            entries.append(picked[0] if len(picked) == 1 else picked)
        else:
            entries.append(None)
    return P(*entries)


def constrain(x, *names):
    """with_sharding_constraint under the active mesh; identity (the
    same object) when no mesh is active — model code calls this
    unconditionally and stays single-device-clean."""
    mesh = active_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec(x.shape, names, mesh)))


# ---------------------------------------------------------------------------
# Parameter specs: name patterns -> mesh axes
# ---------------------------------------------------------------------------


def _path_name(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:  # pragma: no cover - GetAttrKey etc.
            parts.append(str(getattr(p, "name", p)))
    return "/".join(parts)


def _auto_spec(name: str, shape, sizes: dict[str, int]) -> tuple:
    """Param-name pattern -> per-dim mesh-axis tuple (see the module
    docstring's rule table; trailing None entries may be omitted —
    PartitionSpec pads with replication).

    Wrapped-tensor leaves (quant.QuantizedTensor, sparse.SparseTensor)
    need no special casing: their pytree children arrive as integer path
    segments (".../w/0" values, ".../w/1" indices) and the shape-driven
    rules place them together — an N:M SparseTensor's values and indices
    share shape (K_eff, N), so both land on the same (data, model) spec
    and every shard holds the index metadata for exactly the kept
    values it owns."""
    data = sizes.get("data", 1)
    model = sizes.get("model", 1)
    ndim = len(shape)
    if ndim <= 1:
        return ()
    off = 1 if (name.startswith("stack/") or "/stack/" in name) else 0
    if off == 0 and name.rsplit("/", 1)[-1] == "embed":
        if model > 1 and shape[0] % model == 0:
            return ("model",)
        return ()
    specs = [None] * ndim
    if "experts/" in name and ndim - off >= 3:
        if model > 1 and shape[off] % model == 0:
            specs[off] = "model"
        # FSDP the d_model dim: last for wo (E, d_ff, d_model), first
        # non-expert dim for wi/wg (E, d_model, d_ff).
        d_model_dim = ndim - 1 if name.rsplit("/", 1)[-1] == "wo" else off + 1
        if data > 1 and shape[d_model_dim] % data == 0:
            specs[d_model_dim] = "data"
        return tuple(specs)
    if ndim - off >= 2:
        if model > 1 and shape[-1] % model == 0:
            specs[-1] = "model"
        if data > 1 and shape[-2] % data == 0:
            specs[-2] = "data"
    return tuple(specs)


def params_pspecs(tree, mesh: Mesh):
    """Same-structure tree of PartitionSpec for a params/opt-state tree
    (leaves: arrays or ShapeDtypeStructs)."""
    sizes = dict(mesh.shape)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: P(*_auto_spec(_path_name(path),
                                         tuple(leaf.shape), sizes)),
        tree)


def params_shardings(tree, mesh: Mesh):
    """Same-structure tree of NamedSharding (for jit in_shardings /
    device_put)."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        params_pspecs(tree, mesh))


# ---------------------------------------------------------------------------
# Cache specs (serving): slot-name -> logical axes
# ---------------------------------------------------------------------------

_CACHE_AXES = {
    "k": (None, "batch", "seq_kv", "kv_heads", None),
    "v": (None, "batch", "seq_kv", "kv_heads", None),
    # int8 KV codec (DESIGN.md §7): per-row scales shard with the rows
    # they describe (same layout minus the head_dim axis).
    "k_scale": (None, "batch", "seq_kv", "kv_heads"),
    "v_scale": (None, "batch", "seq_kv", "kv_heads"),
    "conv": (None, "batch", None, None),
    "state": (None, "batch", "heads", None, None),
    "h": (None, "batch", "mlp"),
    # paged KV pools (DESIGN.md §8): the pool's page axis takes the
    # "seq_kv" role (pages ARE the sequence, shuffled) — any slot's
    # block-table row scatters across shards, so decode gathers balance.
    # There is no batch axis; block tables stay host-side/replicated.
    "k_pages": (None, "seq_kv", None, "kv_heads", None),
    "v_pages": (None, "seq_kv", None, "kv_heads", None),
    "k_scale_pages": (None, "seq_kv", None, "kv_heads"),
    "v_scale_pages": (None, "seq_kv", None, "kv_heads"),
}


def block_table_pspec(mesh: Mesh, shape=None):
    """PartitionSpec for a (B, n_bt) block table: slots over 'batch',
    table entries replicated (every shard of a paged pool needs the
    whole row to resolve its pages)."""
    return spec(shape, ("batch", None), mesh) if shape else P("batch", None)


def cache_shardings(cache_tree, mesh: Mesh):
    """Shardings for a models.transformer.init_cache pytree (abstract or
    concrete).  Slots under 'tail' lack the leading stack dim."""
    def visit(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        in_tail = any(getattr(p, "key", None) == "tail" for p in path)
        axes = _CACHE_AXES.get(name)
        if axes is None or leaf.ndim == 0:
            return NamedSharding(mesh, P())
        axes = axes[1:] if in_tail else axes
        return NamedSharding(mesh, spec(leaf.shape, axes[:leaf.ndim], mesh))
    return jax.tree_util.tree_map_with_path(visit, cache_tree)
