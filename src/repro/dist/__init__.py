"""Distribution plane: named-axis sharding rules + elastic resharding.

The package between the model/optimizer plane and every distributed
entry point (launch/train, launch/serve, launch/dryrun, launch/specs):

  sharding  mesh context (`use_mesh` / `active_mesh`), logical-axis
            activation specs (`spec` / `constrain`), and the auto
            param-sharding rule table (`_auto_spec`,
            `params_pspecs` / `params_shardings`) — DESIGN.md §5;
  reshard   elastic checkpoint restore onto a different mesh shape
            (DESIGN.md §4).

Everything degrades to replication on a single device, so the same
model/train/serve code runs unchanged from a laptop CPU to a multi-pod
mesh.
"""

from . import reshard, sharding

__all__ = ["reshard", "sharding"]
