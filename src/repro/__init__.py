"""ReDas reproduction: reshapeable systolic-array model + a sharded
jax_pallas training/serving stack.

`import repro` is intentionally lightweight: submodules and the public
surface below resolve lazily through module `__getattr__` (PEP 562), so
nothing jax-heavy loads until first use.

    import repro
    plan = repro.plan_arch(repro.configs.get_config("qwen2-1.5b"))
    with repro.use_engine():
        ...
"""

from __future__ import annotations

import importlib

__version__ = "0.1.0"

#: name -> submodule (lazy `repro.<name>` package access)
_SUBMODULES = (
    "configs", "core", "dist", "engine", "kernels", "models",
    "optim", "quant", "roofline",
)

#: name -> "module:attr" (lazy re-exports of the decision-surface API)
_EXPORTS = {
    # engine (the unified decide-then-execute surface, ISSUE 3)
    "Engine": "repro.engine:Engine",
    "use_engine": "repro.engine:use_engine",
    "active_engine": "repro.engine:active_engine",
    "matmul": "repro.engine:matmul",
    "plan_arch": "repro.engine:plan_arch",
    "ExecutionPlan": "repro.engine:ExecutionPlan",
    "KernelRequest": "repro.engine:KernelRequest",
    "KernelDecision": "repro.engine:KernelDecision",
    "KernelRegistry": "repro.engine:KernelRegistry",
    "CostModel": "repro.engine:CostModel",
    "TPUModel": "repro.engine:TPUModel",
    "AnalyticalCostModel": "repro.engine:AnalyticalCostModel",
    # quant (the int8 precision plane, ISSUE 5)
    "QuantizedTensor": "repro.quant:QuantizedTensor",
    "quantize_params": "repro.quant:quantize_params",
    # configs + workloads (numpy-level planning inputs)
    "GEMM": "repro.core.analytical_model:GEMM",
    "WORKLOADS": "repro.core.workloads:WORKLOADS",
    "arch_gemms": "repro.core.workloads:arch_gemms",
    "get_config": "repro.configs:get_config",
    "ArchConfig": "repro.models.config:ArchConfig",
}

__all__ = ["__version__", *_SUBMODULES, *_EXPORTS]


def __getattr__(name: str):
    if name in _SUBMODULES:
        return importlib.import_module(f"repro.{name}")
    target = _EXPORTS.get(name)
    if target is not None:
        module, attr = target.split(":")
        return getattr(importlib.import_module(module), attr)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")


def __dir__():
    return sorted(__all__)
