"""train_step builder: loss, grad-accumulation microbatching, AdamW.

Distribution posture (DESIGN.md §4):
  * params/grads are bf16 -> GSPMD's gradient all-reduces move half the
    bytes (the "gradient compression" trick); moments/master are fp32;
  * microbatches run as a `lax.scan` with an fp32 grad accumulator, so
    global_batch scales without activation memory scaling;
  * remat is inside the model (checkpointed scan body per layer period);
  * the whole step is one jit — XLA's latency-hiding scheduler overlaps
    the backward all-reduces with remaining compute.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro import engine as engine_mod
from repro.dist import sharding as shd
from repro.dist.sharding import constrain
from repro.models import transformer as T
from repro.models.config import ArchConfig
from repro.optim import adamw

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    compute_dtype: Any = jnp.bfloat16
    aux_weight: float = 0.01          # MoE load-balance loss weight
    optimizer: adamw.AdamWConfig = adamw.AdamWConfig()
    # §Perf iteration 1 (EXPERIMENTS.md): constrain the fp32 grad
    # accumulator to the params' PartitionSpecs.  Without it GSPMD keeps
    # the scan carry replicated and all-reduces full f32 gradients every
    # microbatch trip (measured 2.0 TB/device/step on mistral-large);
    # with it the reductions become reduce-scatters into the FSDP shards.
    shard_grad_accum: bool = True
    # repro.engine backend every model matmul traces through (e.g.
    # "pallas-tpu" / "pallas-interpret" / "xla-einsum").  None keeps the
    # XLA-native path.  One Engine (and so one decision cache) spans all
    # microbatch traces of the step.
    kernel_backend: str | None = None
    # int8 forward plane (ISSUE 5): upgrade kernel_backend to its int8
    # sibling, so every matmul quantizes its operands dynamically on the
    # way into the MXU while the dispatch-layer VJP keeps cotangents in
    # the float compute dtype (quantization-aware training posture).
    quantize: bool = False
    # structured-sparsity plane (ISSUE 8): "N:M" (e.g. "2:4") upgrades
    # kernel_backend to its sparse sibling; train with
    # `sparse.prune_params` weights — the dispatch-layer VJP sends dense
    # cotangents to the activations and masked cotangents to the kept
    # values (pruned positions get exactly zero gradient, the mask
    # stays frozen: the sparse QAT posture).
    sparsity: str | None = None

    def __post_init__(self):
        if self.quantize:
            object.__setattr__(
                self, "kernel_backend",
                engine_mod.int8_sibling(self.kernel_backend))
        if self.sparsity is not None:
            from repro.sparse import parse_sparsity

            parse_sparsity(self.sparsity)  # validate "N:M" early
            object.__setattr__(
                self, "kernel_backend",
                engine_mod.sparse_sibling(self.kernel_backend))


def init_state(key, cfg: ArchConfig, tcfg: TrainConfig) -> dict:
    params_f32 = T.init_params(key, cfg)
    params = jax.tree.map(lambda p: p.astype(tcfg.compute_dtype), params_f32)
    return {"params": params, "opt": adamw.init_state(params_f32)}


def _split_batch(batch: dict, cfg: ArchConfig):
    """(inputs, labels, extras) from a host batch dict."""
    if cfg.embed_inputs:
        return {"embeds": batch["embeds"]}, batch["labels"]
    toks = batch["tokens"]
    inputs = {"tokens": toks[:, :-1]}
    labels = toks[:, 1:]
    if cfg.prefix_tokens:
        inputs["embeds"] = batch["pixel_embeds"]
    return inputs, labels


def make_loss_fn(cfg: ArchConfig, tcfg: TrainConfig):
    def loss_fn(params, inputs, labels):
        logits, aux = T.forward(
            params, cfg, inputs.get("tokens"), embeds=inputs.get("embeds"),
            compute_dtype=tcfg.compute_dtype)
        if cfg.prefix_tokens:       # VLM: loss only on text positions
            logits = logits[:, cfg.prefix_tokens:]
        logits = logits.astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, labels[..., None].astype(jnp.int32),
                                 axis=-1)[..., 0]
        ce = -jnp.mean(ll)
        return ce + tcfg.aux_weight * aux, (ce, aux)
    return loss_fn


def make_train_step(cfg: ArchConfig, tcfg: TrainConfig):
    """Returns train_step(state, batch) -> (state, metrics); jit it with
    donate_argnums=(0,) and the state's shardings."""
    loss_fn = make_loss_fn(cfg, tcfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    eng = (engine_mod.Engine(backend=tcfg.kernel_backend)
           if tcfg.kernel_backend else None)

    def _engine_scope():
        return (engine_mod.use_engine(eng) if eng is not None
                else contextlib.nullcontext())

    def _constrain_like_params(tree, params):
        mesh = shd.active_mesh()
        if mesh is None or not tcfg.shard_grad_accum:
            return tree
        pspecs = shd.params_pspecs(params, mesh)
        return jax.tree.map(
            lambda t, s: jax.lax.with_sharding_constraint(
                t, jax.sharding.NamedSharding(mesh, s)), tree, pspecs)

    def train_step(state: dict, batch: dict):
        with _engine_scope():
            return _train_step(state, batch)

    def _train_step(state: dict, batch: dict):
        params = state["params"]
        inputs, labels = _split_batch(batch, cfg)
        n_micro = tcfg.microbatches

        def reshape_micro(x):
            b = x.shape[0]
            return x.reshape((n_micro, b // n_micro) + x.shape[1:])

        micro_inputs = jax.tree.map(reshape_micro, inputs)
        micro_labels = reshape_micro(labels)

        def micro_step(acc, inp):
            mb_in, mb_lab = inp
            (loss, (ce, aux)), grads = grad_fn(params, mb_in, mb_lab)
            grads32 = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), acc["g"], grads)
            grads32 = _constrain_like_params(grads32, params)
            return {"g": grads32, "loss": acc["loss"] + loss,
                    "ce": acc["ce"] + ce, "aux": acc["aux"] + aux}, None

        zeros = _constrain_like_params(
            jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            params)
        acc0 = {"g": zeros, "loss": jnp.zeros((), jnp.float32),
                "ce": jnp.zeros((), jnp.float32),
                "aux": jnp.zeros((), jnp.float32)}
        if n_micro == 1:
            acc, _ = micro_step(acc0, (jax.tree.map(lambda x: x[0], micro_inputs),
                                       micro_labels[0]))
        else:
            acc, _ = jax.lax.scan(micro_step, acc0,
                                  (micro_inputs, micro_labels))
        grads = jax.tree.map(lambda g: g / n_micro, acc["g"])
        new_params, new_opt, om = adamw.apply_updates(
            tcfg.optimizer, state["opt"], grads,
            param_dtype=tcfg.compute_dtype)
        metrics = {
            "loss": acc["loss"] / n_micro,
            "ce": acc["ce"] / n_micro,
            "aux": acc["aux"] / n_micro,
            **om,
        }
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def device_batch(batch: dict) -> dict:
    return jax.tree.map(jnp.asarray, batch)
