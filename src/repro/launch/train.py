"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
        --steps 200 --batch 8 --seq 128 --ckpt-dir runs/ckpt --resume auto

Production posture on real hardware: the same entry point under
`jax.distributed.initialize()` — the mesh comes from launch.mesh, state
sharding from dist.sharding, checkpoints reshard on restore so the run
survives pod-count changes (elastic).  On this CPU host it trains the
reduced configs end-to-end (examples/train_tiny_lm.py drives it).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpoint import Checkpointer
from repro.configs import ARCH_NAMES, get_config
from repro.data.pipeline import DataConfig, make_source
from repro.dist import reshard, sharding as shd
from repro.launch.mesh import make_test_mesh
from repro.optim.adamw import AdamWConfig
from repro.optim.schedule import linear_warmup_cosine
from repro.train_lib import train as train_lib


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", choices=("auto", "none"), default="none")
    ap.add_argument("--data-path", default=None,
                    help="memmap token corpus; default synthetic")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--kernel-backend", default=None,
                    choices=("pallas-tpu", "pallas-interpret", "xla-einsum",
                             "pallas-tpu-sparse", "xla-sparse"),
                    help="repro.engine backend for model matmuls "
                         "(default: XLA-native)")
    ap.add_argument("--sparsity", default=None, metavar="N:M",
                    help="sparse-QAT posture (e.g. '2:4'): upgrade the "
                         "kernel backend to its sparse sibling; pair with "
                         "repro.sparse.prune_params weights")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    tcfg = train_lib.TrainConfig(
        microbatches=args.microbatches,
        compute_dtype=jnp.float32 if args.smoke else jnp.bfloat16,
        optimizer=AdamWConfig(
            lr=linear_warmup_cosine(args.lr, args.warmup, args.steps)),
        kernel_backend=args.kernel_backend,
        sparsity=args.sparsity,
    )
    mesh = make_test_mesh()
    source = make_source(cfg, DataConfig(args.batch, args.seq, args.seed),
                         args.data_path)

    with mesh, shd.use_mesh(mesh):
        def init_fn():
            return train_lib.init_state(jax.random.PRNGKey(args.seed), cfg,
                                        tcfg)

        state_sh = shd.params_shardings(jax.eval_shape(init_fn), mesh)
        step_fn = jax.jit(train_lib.make_train_step(cfg, tcfg),
                          in_shardings=(state_sh, None),
                          donate_argnums=(0,))

        ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
        if ckpt and args.resume == "auto":
            # Elastic: the checkpoint may come from any mesh shape;
            # placement is re-derived for *this* mesh (DESIGN.md §4).
            start, state = reshard.resume_or_init(ckpt, init_fn, mesh)
        else:
            start, state = 0, init_fn()
        if start:
            print(f"resumed from step {start}")
        if start >= args.steps:
            print(f"checkpoint already at step {start} >= --steps "
                  f"{args.steps}; nothing to train")
            return {"final_ce": None, "first_ce": None, "steps": start}
        state = reshard.reshard(state, state_sh)

        losses = []
        t0 = time.time()
        for step in range(start, args.steps):
            batch = jax.tree.map(jnp.asarray, source.batch(step))
            state, metrics = step_fn(state, batch)
            losses.append(float(metrics["ce"]))
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d}  ce {losses[-1]:.4f}  "
                      f"gnorm {float(metrics['grad_norm']):.3f}  "
                      f"lr {float(metrics['lr']):.2e}  "
                      f"{(time.time() - t0):.1f}s", flush=True)
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, state)
        if ckpt:
            ckpt.save(args.steps, state, blocking=True)
        return {"final_ce": losses[-1], "first_ce": losses[0],
                "steps": args.steps}


if __name__ == "__main__":
    out = main()
    print(out)
