"""input_specs: ShapeDtypeStruct stand-ins + shardings for every model
input of every (arch x shape) cell — weak-type-correct, shardable, no
device allocation.  The dry-run lowers against these.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.configs.shapes import ShapeSpec
from repro.dist import sharding as shd
from repro.models import transformer as T
from repro.models.config import ArchConfig
from repro.train_lib import train as train_lib

SDS = jax.ShapeDtypeStruct


def _ns(mesh: Mesh, shape, axes) -> NamedSharding:
    return NamedSharding(mesh, shd.spec(shape, axes, mesh))


def batch_specs(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh):
    """Training-batch SDS + shardings (the {tokens, labels} of the brief)."""
    b, s = shape.global_batch, shape.seq_len
    sds: dict[str, Any] = {}
    if cfg.embed_inputs:
        sds["embeds"] = SDS((b, s, cfg.d_model), jnp.float32)
        sds["labels"] = SDS((b, s), jnp.int32)
    else:
        sds["tokens"] = SDS((b, s + 1), jnp.int32)
        if cfg.prefix_tokens:
            sds["pixel_embeds"] = SDS((b, cfg.prefix_tokens, cfg.d_model),
                                      jnp.float32)
    axes = {"embeds": ("batch", None, "embed"), "labels": ("batch", None),
            "tokens": ("batch", None),
            "pixel_embeds": ("batch", None, "embed")}
    sh = {k: _ns(mesh, v.shape, axes[k]) for k, v in sds.items()}
    return sds, sh


# Cache slot-name -> logical-axis rules live with the rest of the
# sharding tables in dist/sharding.py (DESIGN.md §5).
cache_shardings = shd.cache_shardings


def abstract_train_state(cfg: ArchConfig, tcfg: train_lib.TrainConfig):
    return jax.eval_shape(
        lambda: train_lib.init_state(jax.random.PRNGKey(0), cfg, tcfg))


def abstract_params(cfg: ArchConfig, dtype=jnp.bfloat16):
    out = jax.eval_shape(lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    return jax.tree.map(lambda x: SDS(x.shape, dtype), out)


def abstract_cache(cfg: ArchConfig, max_seq: int, batch: int,
                   dtype=jnp.bfloat16):
    return jax.eval_shape(
        functools.partial(T.init_cache, cfg, T.CacheSpec(max_seq, batch),
                          dtype=dtype))


def input_specs(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh,
                tcfg: train_lib.TrainConfig | None = None):
    """Everything the cell's step function consumes: (args_sds, args_sh).

    train:   (state, batch)
    prefill: (params, tokens[, embeds], cache)
    decode:  (params, cache, token)
    """
    if shape.step == "train":
        assert tcfg is not None
        state = abstract_train_state(cfg, tcfg)
        state_sh = shd.params_shardings(state, mesh)
        batch_sds, batch_sh = batch_specs(cfg, shape, mesh)
        return (state, batch_sds), (state_sh, batch_sh)

    params = abstract_params(cfg)
    params_sh = shd.params_shardings(params, mesh)
    b, s = shape.global_batch, shape.seq_len
    if shape.step == "prefill":
        cache = abstract_cache(cfg, s, b)
        cache_sh = cache_shardings(cache, mesh)
        if cfg.embed_inputs:
            tok = SDS((b, s, cfg.d_model), jnp.float32)
            tok_sh = _ns(mesh, tok.shape, ("batch", None, "embed"))
        else:
            tok = SDS((b, s), jnp.int32)
            tok_sh = _ns(mesh, tok.shape, ("batch", None))
        args = (params, tok, cache)
        shs = (params_sh, tok_sh, cache_sh)
        if cfg.prefix_tokens:
            emb = SDS((b, cfg.prefix_tokens, cfg.d_model), jnp.float32)
            args += (emb,)
            shs += (_ns(mesh, emb.shape, ("batch", None, "embed")),)
        return args, shs

    # decode: cache is pre-filled to seq_len, one new token comes in
    cache = abstract_cache(cfg, s, b)
    cache_sh = cache_shardings(cache, mesh)
    tok = SDS((b, 1), jnp.int32)
    tok_sh = _ns(mesh, tok.shape, ("batch", None))
    return (params, cache, tok), (params_sh, cache_sh, tok_sh)
