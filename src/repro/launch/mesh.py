"""Production mesh construction.

A FUNCTION, not a module constant: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before first jax init).

Axes:
  data   — batch parallelism + FSDP shard axis for params/optimizer
  model  — tensor parallelism (heads / mlp / vocab / experts)
  pod    — the multi-pod axis; composes with data for batch parallelism,
           giving elastic scaling across pod counts (checkpoints restore
           onto any mesh shape, dist/checkpoint reshards).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 1, model: int = 1):
    """Mesh over however many devices the host actually has (tests)."""
    n = len(jax.devices())
    d = min(data, n)
    m = min(model, n // d)
    return jax.make_mesh((d, m), ("data", "model"))
