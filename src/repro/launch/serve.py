"""Serving launcher: batched prefill + decode with the arch's cache kind.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --batch 4 --prompt-len 32 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config
from repro.dist import sharding as shd
from repro.launch.mesh import make_test_mesh
from repro.models import transformer as T
from repro.serve_lib import serve as serve_lib


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--kernel-backend", default=None,
                    choices=("pallas-tpu", "pallas-interpret", "xla-einsum"),
                    help="repro.engine backend for model matmuls")
    ap.add_argument("--plan", default=None,
                    help="ExecutionPlan JSON to warm-start the decision "
                         "cache from (see repro.engine.plan_arch)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.kind == "encoder":
        raise SystemExit("encoder-only arch: no decode step (see DESIGN.md)")
    dtype = jnp.float32 if args.smoke else jnp.bfloat16
    scfg = serve_lib.ServeConfig(
        max_seq=args.prompt_len + args.gen + 1, batch=args.batch,
        compute_dtype=dtype, cache_dtype=dtype,
        kernel_backend=args.kernel_backend, plan_path=args.plan)
    mesh = make_test_mesh()

    with mesh, shd.use_mesh(mesh):
        params = T.init_params(jax.random.PRNGKey(args.seed), cfg)
        params = jax.tree.map(lambda p: p.astype(dtype), params)
        key = jax.random.PRNGKey(args.seed + 1)
        prompt = jax.random.randint(
            key, (args.batch, args.prompt_len), 0, cfg.vocab, jnp.int32)
        embeds = None
        if cfg.prefix_tokens:
            embeds = 0.02 * jax.random.normal(
                key, (args.batch, cfg.prefix_tokens, cfg.d_model), dtype)
        t0 = time.time()
        tokens = serve_lib.generate(
            params, cfg, scfg, prompt, args.gen,
            temperature=args.temperature, key=key, embeds=embeds)
        dt = time.time() - t0
    print(f"generated {tokens.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print(tokens[0][:16])
    return {"tokens_per_s": args.batch * args.gen / dt,
            "shape": tuple(tokens.shape)}


if __name__ == "__main__":
    main()
