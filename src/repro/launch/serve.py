"""Serving launcher: batched prefill + decode with the arch's cache kind.

Static one-batch mode (every prompt the same length, one generate call):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --batch 4 --prompt-len 32 --gen 32

Request-trace mode (`--trace`): a mixed-length request list served by
the continuous-batching `serve_lib.scheduler.Scheduler` over a pool of
`--batch` slots.  Each item is PROMPTxGEN with an optional *COUNT
repeat, e.g.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --batch 4 --trace "24x32,8x8*6,16x48"
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_config
from repro.dist import sharding as shd
from repro.launch.mesh import make_test_mesh
from repro.models import transformer as T
from repro.serve_lib import serve as serve_lib
from repro.serve_lib.scheduler import Request, Scheduler


def parse_trace(spec: str) -> list[tuple[int, int]]:
    """"24x32,8x8*6" -> [(24, 32), (8, 8) x 6] (prompt_len, gen_len)."""
    out: list[tuple[int, int]] = []
    for item in spec.split(","):
        item = item.strip()
        if not item:
            continue
        count = 1
        if "*" in item:
            item, n = item.split("*")
            count = int(n)
        p, g = item.split("x")
        out.extend([(int(p), int(g))] * count)
    if not out:
        raise ValueError(f"empty trace spec {spec!r}")
    return out


def _run_trace(params, cfg, scfg, args, trace) -> dict:
    rng = np.random.default_rng(args.seed + 2)
    key = jax.random.PRNGKey(args.seed + 3)
    reqs = []
    for uid, (plen, gen) in enumerate(trace):
        key, sub = jax.random.split(key)
        reqs.append(Request(
            uid=uid, prompt=rng.integers(0, cfg.vocab, plen).astype(np.int32),
            max_new_tokens=gen, temperature=args.temperature,
            key=sub if args.temperature > 0 else None))
    sched = Scheduler(params, cfg, scfg, prefill_bucket=args.prefill_bucket)
    t0 = time.time()
    if args.async_ingest:
        with sched.serve_async(max_queue=max(len(reqs), 1)) as srv:
            futs = [srv.submit(r) for r in reqs]
            for f in futs:
                f.result()
        comps = sched.completions
    else:
        comps = sched.run(reqs)
    dt = time.time() - t0
    n_tok = sum(len(c.tokens) for c in comps.values())
    print(f"served {len(comps)} requests / {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s) over {scfg.batch} slots")
    print(f"scheduler: {sched.stats}")
    for uid in sorted(comps)[:8]:
        c = comps[uid]
        print(f"  req {uid}: prompt {c.prompt_len} -> {len(c.tokens)} tokens "
              f"({c.finish_reason}, steps {c.admit_step}..{c.finish_step})")
    out = {"tokens_per_s": n_tok / dt, "requests": len(comps),
           "decode_steps": sched.stats["decode_steps"]}
    if sched.engine is not None:
        print(f"engine plan: {sched.engine.plan.stats}")
        out["engine_plan"] = sched.engine.plan.stats
    return out


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="batch (static mode) / slot-pool size (--trace)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--trace", default=None,
                    help="request trace 'PROMPTxGEN[*COUNT],...' served by "
                         "the continuous-batching scheduler")
    ap.add_argument("--prefill-bucket", type=int, default=8,
                    help="round admit widths up to this multiple "
                         "(bounds jit retraces; 1 = exact)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked prefill (trace mode only, DESIGN.md "
                         "§12): stream prompts longer than this into "
                         "their slot CHUNK tokens per tick, interleaved "
                         "with decode, instead of one blocking prefill; "
                         "must be a multiple of --prefill-bucket")
    ap.add_argument("--async-ingest", action="store_true",
                    help="drive the trace through Scheduler.serve_async "
                         "(worker thread + bounded request queue) instead "
                         "of the synchronous run loop")
    ap.add_argument("--kernel-backend", default=None,
                    choices=("pallas-tpu", "pallas-interpret", "xla-einsum",
                             "pallas-tpu-int8", "xla-int8",
                             "pallas-tpu-sparse", "xla-sparse"),
                    help="repro.engine backend for model matmuls")
    ap.add_argument("--quantize", action="store_true",
                    help="full int8 serving posture: quantize the dense "
                         "weights (repro.quant.quantize_params), store the "
                         "KV cache int8 (cache_dtype='int8'), and upgrade "
                         "the kernel backend to its int8 sibling")
    ap.add_argument("--sparsity", default=None, metavar="N:M",
                    help="structured-sparse serving posture (e.g. '2:4'): "
                         "magnitude-prune the dense weights "
                         "(repro.sparse.prune_params) and upgrade the "
                         "kernel backend to its sparse sibling; with "
                         "--quantize the kept values store as sparse×int8")
    ap.add_argument("--plan", default=None,
                    help="ExecutionPlan JSON to warm-start the decision "
                         "cache from (see repro.engine.plan_arch)")
    ap.add_argument("--cache-layout", default="contiguous",
                    choices=("contiguous", "paged"),
                    help="KV-cache layout; 'paged' (trace mode only) pools "
                         "fixed pages behind per-slot block tables and "
                         "shares prefilled prompt pages across requests")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per page for --cache-layout paged")
    ap.add_argument("--speculate", type=int, default=0, metavar="K",
                    help="speculative decoding (trace mode only): draft K "
                         "tokens per tick and verify them in one fused "
                         "K+1-wide pass; greedy-only, outputs bitwise "
                         "identical to --speculate 0")
    ap.add_argument("--draft", default="self", choices=("self", "self-int8"),
                    help="draft model for --speculate: 'self' shares the "
                         "target params, 'self-int8' drafts with an int8-"
                         "quantized copy")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.kind == "encoder":
        raise SystemExit("encoder-only arch: no decode step (see DESIGN.md)")
    dtype = jnp.float32 if args.smoke else jnp.bfloat16
    trace = parse_trace(args.trace) if args.trace else None
    max_seq = (max(p + g for p, g in trace) + 1 if trace
               else args.prompt_len + args.gen + 1)
    if args.cache_layout == "paged" and trace is None:
        raise SystemExit("--cache-layout paged needs --trace (the block-table "
                         "plane lives in the continuous-batching scheduler)")
    if (args.prefill_chunk or args.async_ingest) and trace is None:
        raise SystemExit("--prefill-chunk / --async-ingest need --trace "
                         "(chunked ingestion lives in the continuous-"
                         "batching scheduler)")
    if args.speculate:
        if trace is None:
            raise SystemExit("--speculate needs --trace (the draft/verify "
                             "tick lives in the continuous-batching "
                             "scheduler)")
        if args.temperature > 0:
            raise SystemExit("--speculate is greedy-only (temperature 0)")
        max_seq += args.speculate  # verify writes k rows past the last token
    scfg = serve_lib.ServeConfig(
        max_seq=max_seq, batch=args.batch,
        compute_dtype=dtype,
        cache_dtype=jnp.int8 if args.quantize else dtype,
        kernel_backend=args.kernel_backend, plan_path=args.plan,
        quantize=args.quantize, sparsity=args.sparsity,
        cache_layout=args.cache_layout, page_size=args.page_size,
        speculate_k=args.speculate,
        draft=args.draft if args.speculate else None,
        prefill_chunk=args.prefill_chunk)
    mesh = make_test_mesh()

    with mesh, shd.use_mesh(mesh):
        params = T.init_params(jax.random.PRNGKey(args.seed), cfg)
        params = jax.tree.map(lambda p: p.astype(dtype), params)
        if args.sparsity:
            from repro.sparse import parse_sparsity, prune_params
            n, m = parse_sparsity(args.sparsity)
            # with --quantize the kept values store int8 inside the
            # SparseTensor (sparse×int8) — quantize_params must not run
            params = prune_params(params, n, m, quantize=args.quantize)
        elif args.quantize:
            from repro.quant import quantize_params
            params = quantize_params(params)
        if trace is not None:
            return _run_trace(params, cfg, scfg, args, trace)
        key = jax.random.PRNGKey(args.seed + 1)
        prompt = jax.random.randint(
            key, (args.batch, args.prompt_len), 0, cfg.vocab, jnp.int32)
        embeds = None
        if cfg.prefix_tokens:
            embeds = 0.02 * jax.random.normal(
                key, (args.batch, cfg.prefix_tokens, cfg.d_model), dtype)
        t0 = time.time()
        tokens = serve_lib.generate(
            params, cfg, scfg, prompt, args.gen,
            temperature=args.temperature, key=key, embeds=embeds)
        dt = time.time() - t0
    print(f"generated {tokens.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print(tokens[0][:16])
    return {"tokens_per_s": args.batch * args.gen / dt,
            "shape": tuple(tokens.shape)}


if __name__ == "__main__":
    main()
