import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
cell on the production meshes, prove memory/sharding coherence, and dump
cost/collective numbers for the roofline analysis.

    python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all [--mesh both] [--jobs 2]
    python -m repro.launch.dryrun --summarize

Single-cell mode does the work in-process; --all orchestrates one
subprocess per cell (isolating XLA compile memory and letting a bad cell
fail alone) and writes runs/dryrun/<mesh>/<arch>__<shape>.json.

NOTE the XLA_FLAGS line above runs before any jax import: the dry-run
(and only the dry-run) needs 512 placeholder host devices so
jax.make_mesh can build the (2, 16, 16) production mesh.
"""

import argparse
import json
import subprocess
import sys
import time

import jax

from repro.configs import ARCH_NAMES, get_config
from repro.configs.shapes import SHAPES, applicable
from repro.dist import sharding as shd
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.roofline import analysis as roofline
from repro.train_lib.train import TrainConfig

RESULTS_DIR = os.environ.get(
    "REPRO_DRYRUN_DIR",
    os.path.join(os.path.dirname(__file__), "..", "..", "..",
                 "runs", "dryrun"))
SAVE_HLO = None

# Grad-accumulation microbatch counts per arch for train_4k (global batch
# 256): sized so saved activations fit HBM alongside FSDP state.
# mistral 16 -> 4 was §Perf iteration 1 (collective term ∝ accumulation
# trips); kept at 4 for the optimized sweep, 16 reproduces the baseline
# via --micro 16.
MICROBATCHES = {
    "mistral-large-123b": 4,
    "qwen3-14b": 8,
    "gemma3-12b": 8,
    "mixtral-8x7b": 8,
    "hubert-xlarge": 2,
    "recurrentgemma-2b": 4,
    "qwen2-1.5b": 2,
    "granite-moe-1b-a400m": 2,
    "mamba2-780m": 2,
    "internvl2-1b": 2,
}


def step_fn_for(cfg, shape, tcfg):
    if shape.step == "train":
        from repro.train_lib.train import make_train_step
        return make_train_step(cfg, tcfg), (0,)
    if shape.step == "prefill":
        if cfg.embed_inputs:
            def prefill_embeds(params, embeds, cache):
                return T.prefill(params, cfg, None, cache, embeds=embeds)
            return prefill_embeds, (2,)
        if cfg.prefix_tokens:
            def prefill_vlm(params, tokens, cache, embeds):
                return T.prefill(params, cfg, tokens, cache, embeds=embeds)
            return prefill_vlm, (2,)

        def prefill_step(params, tokens, cache):
            return T.prefill(params, cfg, tokens, cache)
        return prefill_step, (2,)

    def decode_step(params, cache, token):
        return T.decode_step(params, cfg, cache, token)
    return decode_step, (1,)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             micro: int | None = None,
             shard_grad_accum: bool = False,
             moe_impl: str | None = None,
             kernel_backend: str | None = None) -> dict:
    """shard_grad_accum=False reproduces the recorded §Roofline baseline;
    perf iterations re-run cells with overrides (see EXPERIMENTS.md §Perf).
    kernel_backend routes every model matmul through a repro.engine
    context ("xla-einsum" exercises the unified decision path with
    baseline numerics; Pallas backends need the matching host)."""
    import dataclasses as _dc
    cfg = get_config(arch)
    if moe_impl and cfg.moe is not None:
        cfg = _dc.replace(cfg, moe=_dc.replace(cfg.moe, impl=moe_impl))
    shape = SHAPES[shape_name]
    runs, why = applicable(cfg, shape)
    mesh_name = "multi" if multi_pod else "single"
    base = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if not runs:
        return {**base, "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    tcfg = TrainConfig(microbatches=micro or MICROBATCHES.get(arch, 2),
                       shard_grad_accum=shard_grad_accum,
                       kernel_backend=kernel_backend)
    import contextlib

    from repro import engine as engine_mod

    # train cells route through TrainConfig.kernel_backend; prefill/decode
    # cells trace inside an engine context here.
    scope = (engine_mod.use_engine(backend=kernel_backend)
             if kernel_backend and shape.step != "train"
             else contextlib.nullcontext())
    t0 = time.time()
    with mesh, shd.use_mesh(mesh):
        args, shardings = S.input_specs(cfg, shape, mesh, tcfg)
        fn, donate = step_fn_for(cfg, shape, tcfg)
        with scope:
            lowered = jax.jit(fn, in_shardings=shardings,
                              donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        try:
            mem = compiled.memory_analysis()
            mem_report = {
                k: int(getattr(mem, k))
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(mem, k)
            } or {"repr": str(mem)}
        except Exception as e:  # CPU backend may not implement it
            mem_report = {"error": str(e)}

        hlo = compiled.as_text()
        if SAVE_HLO:
            with open(SAVE_HLO, "w") as f:
                f.write(hlo)
        from repro.roofline import hlo_costs
        walk = hlo_costs.ModuleCosts(hlo).total()
        mf = roofline.model_flops(cfg, shape)
        rl = roofline.from_compiled(compiled, model_flops_total=mf,
                                    n_devices=n_dev, hlo_text=hlo)
        top_coll = sorted(walk.coll_by_opname.items(),
                          key=lambda kv: -kv[1])[:12]

    return {
        **base,
        "status": "ok",
        "n_devices": n_dev,
        "microbatches": tcfg.microbatches if shape.step == "train" else None,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory_analysis": mem_report,
        "collective_bytes": dict(walk.coll_by_kind),
        "top_collectives": top_coll,
        "raw_cost_analysis": roofline.raw_cost_analysis(compiled),
        "roofline": rl.as_dict(),
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
    }


def _out_path(arch, shape_name, mesh_name):
    d = os.path.abspath(os.path.join(RESULTS_DIR, mesh_name))
    os.makedirs(d, exist_ok=True)
    return os.path.join(d, f"{arch}__{shape_name}.json")


def run_all(mesh_modes, jobs: int, only_missing: bool) -> None:
    cells = []
    for mesh_name in mesh_modes:
        for arch in ARCH_NAMES:
            for shape_name in SHAPES:
                path = _out_path(arch, shape_name, mesh_name)
                if only_missing and os.path.exists(path):
                    continue
                cells.append((arch, shape_name, mesh_name, path))
    procs: list[tuple[subprocess.Popen, tuple]] = []

    def drain(block_until_below: int):
        while len(procs) >= block_until_below:
            for i, (p, cell) in enumerate(procs):
                if p.poll() is not None:
                    ok = p.returncode == 0
                    print(f"[{'ok' if ok else 'FAIL'}] {cell[0]} {cell[1]} "
                          f"{cell[2]}", flush=True)
                    if not ok:
                        err = {"arch": cell[0], "shape": cell[1],
                               "mesh": cell[2], "status": "error",
                               "returncode": p.returncode}
                        with open(cell[3], "w") as f:
                            json.dump(err, f)
                    procs.pop(i)
                    break
            else:
                time.sleep(1.0)

    for arch, shape_name, mesh_name, path in cells:
        drain(jobs)
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape_name, "--mesh", mesh_name, "--out", path]
        procs.append((subprocess.Popen(cmd), (arch, shape_name, mesh_name, path)))
    drain(1)


def summarize() -> None:
    rows = []
    for mesh_name in ("single", "multi"):
        d = os.path.abspath(os.path.join(RESULTS_DIR, mesh_name))
        if not os.path.isdir(d):
            continue
        for f in sorted(os.listdir(d)):
            with open(os.path.join(d, f)) as fh:
                rows.append(json.load(fh))
    print(f"{'arch':24s} {'shape':12s} {'mesh':6s} {'status':8s} "
          f"{'compute_s':>10s} {'memory_s':>10s} {'coll_s':>10s} "
          f"{'bneck':>10s} {'useful':>7s} {'roofl%':>7s}")
    for r in rows:
        if r["status"] != "ok":
            print(f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:6s} "
                  f"{r['status']:8s} {r.get('reason', '')}")
            continue
        rl = r["roofline"]
        print(f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:6s} ok       "
              f"{rl['compute_s']:10.4g} {rl['memory_s']:10.4g} "
              f"{rl['collective_s']:10.4g} {rl['bottleneck']:>10s} "
              f"{rl['useful_flops_ratio']:7.3f} "
              f"{100 * rl['roofline_fraction']:6.1f}%")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=2)
    ap.add_argument("--only-missing", action="store_true")
    ap.add_argument("--summarize", action="store_true")
    ap.add_argument("--out")
    ap.add_argument("--micro", type=int, default=None,
                    help="override grad-accumulation microbatches")
    ap.add_argument("--shard-grad-accum", action="store_true",
                    help="perf variant: FSDP-shard the grad accumulator")
    ap.add_argument("--save-hlo", default=None,
                    help="dump the partitioned HLO text to this path")
    ap.add_argument("--moe-impl", choices=("einsum", "sort"), default=None)
    ap.add_argument("--kernel-backend", default=None,
                    choices=("pallas-tpu", "pallas-interpret", "xla-einsum"),
                    help="trace model matmuls through a repro.engine "
                         "context instead of XLA-native contractions")
    args = ap.parse_args()
    global SAVE_HLO
    SAVE_HLO = args.save_hlo

    if args.summarize:
        summarize()
        return
    if args.all:
        modes = ("single", "multi") if args.mesh == "both" else (args.mesh,)
        run_all(modes, args.jobs, args.only_missing)
        return
    assert args.arch and args.shape, "--arch and --shape (or --all)"
    report = run_cell(args.arch, args.shape, multi_pod=(args.mesh == "multi"),
                      micro=args.micro,
                      shard_grad_accum=args.shard_grad_accum,
                      moe_impl=args.moe_impl,
                      kernel_backend=args.kernel_backend)
    out = args.out or _out_path(args.arch, args.shape, args.mesh)
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    print(json.dumps({k: v for k, v in report.items()
                      if k != "memory_analysis"}, indent=2))
    if report["status"] == "ok":
        print("memory_analysis:", report["memory_analysis"])


if __name__ == "__main__":
    main()
