"""Straggler / hang mitigation for synchronous SPMD training.

In synchronous data parallelism a straggling or wedged host stalls every
peer at the next collective.  The production recovery path is:
detect -> abandon the step -> relaunch from the last complete checkpoint
on the surviving hosts (the checkpoint layer reshards, the deterministic
data pipeline replays the exact stream).  This module provides the
detect/relaunch harness around a train loop:

  * `StepWatchdog` — arms a timer per step; if a step exceeds
    `timeout_factor` x the trailing-median step time, the registered
    abort hook fires (on real clusters: jax.distributed shutdown + exit
    code for the scheduler to relaunch; here: a KeyboardInterrupt-style
    exception the driver catches).
  * `run_with_recovery` — drives step functions under the watchdog and
    performs restore-and-continue on failure, bounded by `max_restarts`.

tests/test_watchdog.py injects artificial stalls and crashes and asserts
bit-exact continuation (determinism does the heavy lifting).
"""

from __future__ import annotations

import statistics
import threading
import time
from typing import Callable


class StepTimeout(RuntimeError):
    pass


class StepWatchdog:
    def __init__(self, timeout_factor: float = 5.0, min_timeout_s: float = 1.0,
                 history: int = 20):
        self.timeout_factor = timeout_factor
        self.min_timeout_s = min_timeout_s
        self._times: list[float] = []
        self._history = history
        self._timer: threading.Timer | None = None
        self.fired = threading.Event()

    def _budget(self) -> float:
        if not self._times:
            return max(self.min_timeout_s, 60.0)  # first step: generous
        return max(self.min_timeout_s,
                   self.timeout_factor * statistics.median(self._times))

    def __enter__(self):
        self._t0 = time.monotonic()
        self._timer = threading.Timer(self._budget(), self.fired.set)
        self._timer.daemon = True
        self._timer.start()
        return self

    def __exit__(self, *exc):
        assert self._timer is not None
        self._timer.cancel()
        if exc[0] is None:
            self._times.append(time.monotonic() - self._t0)
            del self._times[:-self._history]
        return False

    def check(self) -> None:
        """Call after the step's host-side sync point."""
        if self.fired.is_set():
            self.fired.clear()
            raise StepTimeout(
                f"step exceeded {self._budget():.1f}s "
                f"(median {statistics.median(self._times) if self._times else float('nan'):.2f}s)"
            )


def run_with_recovery(
    *,
    steps: int,
    start_step: int,
    run_step: Callable[[int], float],
    save: Callable[[int], None],
    restore: Callable[[], int],
    ckpt_every: int = 50,
    max_restarts: int = 3,
    watchdog: StepWatchdog | None = None,
) -> dict:
    """Drive `run_step(step)` with checkpoint/restart on StepTimeout or
    crash.  `restore()` returns the step to resume from.  Returns stats."""
    wd = watchdog or StepWatchdog()
    restarts = 0
    step = start_step
    losses: list[float] = []
    while step < steps:
        try:
            with wd:
                loss = run_step(step)
            wd.check()
        except (StepTimeout, RuntimeError) as e:
            restarts += 1
            if restarts > max_restarts:
                raise
            step = restore()
            losses = losses[: max(0, step - start_step)]
            print(f"[watchdog] {type(e).__name__}: {e} -> restored to "
                  f"step {step} (restart {restarts}/{max_restarts})",
                  flush=True)
            continue
        losses.append(loss)
        step += 1
        if step % ckpt_every == 0:
            save(step)
    return {"losses": losses, "restarts": restarts, "final_step": step}
