"""N:M structured-sparse GEMM as a Pallas TPU kernel + sparse backends.

The sparsity plane's execution layer (DESIGN.md §10): the right operand
arrives compressed (`sparse.SparseTensor` storage — kept values + int8
in-group offsets), the kernel scatters each compressed block back to a
dense (bk, bn) VMEM tile with an M-way one-hot accumulation (static
unroll over the group size — no gather instruction needed), and the
MXU runs a dense f32 dot on the reconstructed tile:

    w[g*M + off, n] = sum_j values[g*N + j, n] * [indices[g*N + j, n] == off]
    y = a @ w                                  (f32 accumulate, OS dataflow)

What sparsity buys on this path is BYTES, not MACs: the weight HBM
stream shrinks to density x value-bytes + one index byte per kept value
(1.6x for 2:4 float, 3.5x for sparse×int8), while the reconstruction
lives entirely in VMEM.  The effective-FLOPs story — a sparsity-aware
array skipping pruned groups, FlexSA-style — is the COST MODELS' view
(`TPUModel`/`AnalyticalCostModel` plan `gemm_sparse` at K_eff =
density x K); this kernel is the TPU-honest executor of that decision.

Two backends register into the engine registry:

  pallas-tpu-sparse  this module's OS-dataflow scatter kernel (f32 VMEM
                     scratch accumulator; interpret mode auto-resolves
                     off-TPU like the other Pallas backends);
  xla-sparse         the reference: the same scatter in plain jnp + one
                     `jnp.dot` — numerics oracle and the CPU-CI path.

The two are BIT-EXACT whenever the K reduction fits one block (the
default block chooser covers padded K up to its VMEM-gated cap, so
every test/bench shape takes the single-block path): both sides build
the dense tile with the identical `_scatter_dense` sum and reduce K in
one f32 dot.  Multi-block K accumulates per block and may differ in the
last ulp, like any split reduction.

VJP policy (QAT posture, mirroring the int8 plane): cotangents flow
DENSE to the activations (dA = g @ densify(W)^T in float); the weight
cotangent is gathered back through the index metadata, so pruned
positions get exactly zero gradient — training nudges only the kept
values and the mask stays frozen.  Sparse×int8 storage (int8 values +
per-column scales) is data, not a trainable leaf: its weight cotangent
is None, like `gemm_w8`.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU compiler params are optional off-TPU (interpret mode ignores them)
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None

from ._compat import CompilerParams
from .redas_gemm import SUBLANE, VMEM_BYTES, round_up

LANE = 128


def sparse_vmem_bytes(bm: int, bk: int, bn: int, n_keep: int,
                      m_group: int) -> int:
    """Working set of one grid step, sized at f32 operands (x2 for the
    pipeline's double buffering): the activation block, the compressed
    value + index blocks, the reconstructed dense weight tile, and the
    f32 accumulator."""
    bk_c = bk * n_keep // m_group
    return (2 * (bm * bk * 4 + bk_c * bn * 4 + bk_c * bn)
            + bk * bn * 4 + bm * bn * 4)


def _bk_unit(m_group: int) -> int:
    """K blocks must tile both the VREG lane (128) and the N:M group."""
    return math.lcm(LANE, m_group)


def default_sparse_blocks(m: int, k_dense: int, n: int, n_keep: int,
                          m_group: int) -> tuple[int, int, int]:
    """Hardware-aligned blocks, with bk covering the whole padded K
    reduction when the VMEM gate allows (single-block K keeps the
    Pallas kernel bit-exact against the XLA reference — module
    docstring); halve bk toward the unit otherwise."""
    unit = _bk_unit(m_group)
    bm = min(round_up(m, SUBLANE), 256)
    bk = min(round_up(k_dense, unit), 8 * unit)
    bn = min(round_up(n, LANE), 256)
    while (sparse_vmem_bytes(bm, bk, bn, n_keep, m_group) > VMEM_BYTES
           and bk > unit):  # pragma: no cover - huge-K guard
        bk = max(unit, round_up(bk // 2, unit))
    return bm, bk, bn


def _scatter_dense(values, indices, n_keep: int, m_group: int):
    """Expand compressed (K_c, N) storage to the dense (K_c//N*M, N)
    tile: a one-hot sum over the in-group offset, unrolled statically
    over the group size.  Shared verbatim by the Pallas kernel body and
    the XLA reference so the two construct bit-identical tiles."""
    k_c, bn = values.shape
    groups = k_c // n_keep
    v3 = values.reshape(groups, n_keep, bn)
    i3 = indices.reshape(groups, n_keep, bn)
    planes = [jnp.sum(jnp.where(i3 == off, v3, 0.0), axis=1)
              for off in range(m_group)]
    return jnp.stack(planes, axis=1).reshape(groups * m_group, bn)


# ---------------------------------------------------------------------------
# The Pallas kernel: OS dataflow, f32 VMEM scratch accumulator
# ---------------------------------------------------------------------------


def _sparse_os_kernel(a_ref, v_ref, i_ref, o_ref, acc_ref, *, n_k: int,
                      n_keep: int, m_group: int):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.float32)
    w = _scatter_dense(v_ref[...].astype(jnp.float32), i_ref[...],
                       n_keep, m_group)
    acc_ref[...] += jnp.dot(a, w, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("n_keep", "m_group", "bm", "bk", "bn", "interpret"))
def gemm_sparse(a: jax.Array, values: jax.Array, indices: jax.Array, *,
                n_keep: int, m_group: int, bm: int, bk: int, bn: int,
                interpret: bool = False) -> jax.Array:
    """Blocked (M, K) @ N:M-compressed (K_c, N) -> f32 (M, N); dims
    must be multiples of the blocks (`sparse_gemm` pads arbitrary
    shapes).  OS only: the f32 accumulator and the reconstructed dense
    weight tile both live in VMEM — streaming the scatter through HBM
    would forfeit exactly the byte shrink sparsity buys."""
    m, k = a.shape
    k_c, n = values.shape
    if k_c * m_group != k * n_keep:
        raise ValueError(
            f"compressed K {k_c} does not match dense K {k} at "
            f"{n_keep}:{m_group}")
    if values.shape != indices.shape:
        raise ValueError(
            f"values {values.shape} / indices {indices.shape} mismatch")
    if m % bm or k % bk or n % bn:
        raise ValueError(
            f"({m},{k},{n}) not divisible by blocks ({bm},{bk},{bn})")
    if bm % SUBLANE or bk % _bk_unit(m_group) or bn % LANE:
        raise ValueError(
            f"sparse blocks ({bm},{bk},{bn}) must be multiples of "
            f"({SUBLANE}, {_bk_unit(m_group)}, {LANE})")
    gm, gk, gn = m // bm, k // bk, n // bn
    bk_c = bk * n_keep // m_group
    params = (CompilerParams(dimension_semantics=("arbitrary",) * 3)
              if CompilerParams is not None else None)
    return pl.pallas_call(
        functools.partial(_sparse_os_kernel, n_k=gk, n_keep=n_keep,
                          m_group=m_group),
        grid=(gm, gn, gk),
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
                  pl.BlockSpec((bk_c, bn), lambda i, j, kk: (kk, j)),
                  pl.BlockSpec((bk_c, bn), lambda i, j, kk: (kk, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=params,
        interpret=interpret,
    )(a, values, indices)


# ---------------------------------------------------------------------------
# Shape-safe entry point (pad -> kernel -> rescale -> slice)
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit,
    static_argnames=("n_keep", "m_group", "interpret", "use_pallas",
                     "out_dtype"))
def sparse_gemm(a: jax.Array, values: jax.Array, indices: jax.Array,
                scale: jax.Array | None = None, *, n_keep: int = 2,
                m_group: int = 4, interpret: bool = False,
                use_pallas: bool = True, out_dtype=None) -> jax.Array:
    """Float (M, K) @ N:M-compressed storage for arbitrary dims.

    `values`/`indices` are `sparse.SparseTensor` children (K_c, N) with
    K_c = ceil(K / M) * N; `scale` (1, N) or (N,) float32 marks
    sparse×int8 storage and rescales the f32 accumulator once per
    output column (exact: per-column scales factor out of the
    K-contraction).  Zero-padding is exact — padded compressed rows
    scatter zero tiles."""
    out_dtype = out_dtype or a.dtype
    m, k = a.shape
    k_c, n = values.shape
    groups = k_c // n_keep
    k_store = groups * m_group  # dense K padded to the group size
    if use_pallas:
        bm, bk, bn = default_sparse_blocks(m, k_store, n, n_keep, m_group)
        mp, kp, np_ = round_up(m, bm), round_up(k_store, bk), round_up(n, bn)
        kp_c = kp * n_keep // m_group
        a_p = (jnp.pad(a, ((0, mp - m), (0, kp - k)))
               if (mp, kp) != (m, k) else a)
        if (kp_c, np_) != (k_c, n):
            v_p = jnp.pad(values, ((0, kp_c - k_c), (0, np_ - n)))
            i_p = jnp.pad(indices, ((0, kp_c - k_c), (0, np_ - n)))
        else:
            v_p, i_p = values, indices
        acc = gemm_sparse(a_p, v_p, i_p, n_keep=n_keep, m_group=m_group,
                          bm=bm, bk=bk, bn=bn, interpret=interpret)
        acc = acc[:m, :n] if (mp, np_) != (m, n) else acc
    else:
        w = _scatter_dense(values.astype(jnp.float32), indices,
                           n_keep, m_group)
        a_f = a.astype(jnp.float32)
        if k_store != k:
            a_f = jnp.pad(a_f, ((0, 0), (0, k_store - k)))
        acc = jnp.dot(a_f, w, preferred_element_type=jnp.float32)
    if scale is not None:
        acc = acc * scale.reshape(1, -1)
    return acc.astype(out_dtype)


# ---------------------------------------------------------------------------
# Dispatch-layer custom VJPs (masked weight cotangents — module docstring)
# ---------------------------------------------------------------------------


def _float_gemm(a, b, *, use_pallas, interpret, out_dtype):
    """The dense GEMM the backward pass runs on: Pallas (engine block
    defaults, VMEM-gated) on the Pallas backend, XLA otherwise."""
    if use_pallas:
        from repro.engine.backends import pallas_gemm  # lazy: avoids cycle

        return pallas_gemm(a, b, interpret=interpret, out_dtype=out_dtype)
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(out_dtype)


@functools.lru_cache(maxsize=None)
def _diff_sparse_gemm(n_keep, m_group, interpret, use_pallas, out_dtype):
    """Differentiable sparse GEMM over FLOAT compressed values:
    activations get the dense cotangent (dA = g @ densify(W)^T), the
    values get the dense weight cotangent GATHERED at the kept
    positions (pruned positions receive exactly zero — densifying dV
    reproduces a masked dense gradient), and the frozen index metadata
    gets None."""

    @jax.custom_vjp
    def f(a, values, indices):
        return sparse_gemm(a, values, indices, n_keep=n_keep,
                           m_group=m_group, interpret=interpret,
                           use_pallas=use_pallas, out_dtype=out_dtype)

    def fwd(a, values, indices):
        return f(a, values, indices), (a, values, indices)

    def bwd(res, g):
        a, values, indices = res
        m, k = a.shape
        k_c, n = values.shape
        groups = k_c // n_keep
        k_store = groups * m_group
        g = g.astype(a.dtype)
        w = _scatter_dense(values.astype(jnp.float32), indices,
                           n_keep, m_group).astype(a.dtype)
        da = _float_gemm(g, w[:k].T, use_pallas=use_pallas,
                         interpret=interpret, out_dtype=a.dtype)
        dw = _float_gemm(a.T, g, use_pallas=use_pallas, interpret=interpret,
                         out_dtype=jnp.float32)
        if k_store != k:
            dw = jnp.pad(dw, ((0, k_store - k), (0, 0)))
        dw3 = dw.reshape(groups, m_group, n)
        i3 = indices.reshape(groups, n_keep, n).astype(jnp.int32)
        dv = jnp.take_along_axis(dw3, i3, axis=1)
        return da, dv.reshape(k_c, n).astype(values.dtype), None

    f.defvjp(fwd, bwd)
    # jit the wrapper: an un-jitted custom_vjp call re-traces eagerly
    # (~200 us/call — the BENCH_PR3 lesson).
    return jax.jit(f)


@functools.lru_cache(maxsize=None)
def _diff_sparse_gemm_q(n_keep, m_group, interpret, use_pallas, out_dtype):
    """Differentiable sparse×int8 GEMM: gradients flow to the
    ACTIVATIONS only (int8 storage is data, not a trainable leaf —
    same posture as `gemm_w8`)."""

    @jax.custom_vjp
    def f(a, values, indices, scale):
        return sparse_gemm(a, values, indices, scale, n_keep=n_keep,
                           m_group=m_group, interpret=interpret,
                           use_pallas=use_pallas, out_dtype=out_dtype)

    def fwd(a, values, indices, scale):
        return f(a, values, indices, scale), (a, values, indices, scale)

    def bwd(res, g):
        a, values, indices, scale = res
        k = a.shape[1]
        g = g.astype(a.dtype)
        w = (_scatter_dense(values.astype(jnp.float32), indices,
                            n_keep, m_group)
             * scale.reshape(1, -1)).astype(a.dtype)
        da = _float_gemm(g, w[:k].T, use_pallas=use_pallas,
                         interpret=interpret, out_dtype=a.dtype)
        return da, None, None, None

    f.defvjp(fwd, bwd)
    return jax.jit(f)


# ---------------------------------------------------------------------------
# Engine registration
# ---------------------------------------------------------------------------


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _sparse_backend(use_pallas: bool):
    def run(decision, a, values, indices, scale=None, *, n_keep=2,
            m_group=4, out_dtype=None):
        if scale is not None:
            fn = _diff_sparse_gemm_q(n_keep, m_group, _auto_interpret(),
                                     use_pallas, out_dtype)
            return fn(a, values, indices, scale)
        fn = _diff_sparse_gemm(n_keep, m_group, _auto_interpret(),
                               use_pallas, out_dtype)
        return fn(a, values, indices)
    return run


def _dense_gemm_backend(use_pallas: bool):
    """Float `gemm` on the sparse backends — a sparse server's
    non-pruned matmuls (skip-listed weights, lm head via module matmul)
    still dispatch somewhere."""
    def run(decision, a, b, *, out_dtype=None):
        if use_pallas:
            from repro.engine.backends import _diff_gemm  # lazy: avoids cycle

            fn = _diff_gemm(decision.dataflow, decision.bm, decision.bk,
                            decision.bn, _auto_interpret(), out_dtype)
            return fn(a, b)
        return _float_gemm(a, b, use_pallas=False, interpret=False,
                           out_dtype=out_dtype or a.dtype)
    return run


def register_into(registry) -> None:
    """Register the structured-sparsity execution plane: the Pallas
    backend ("pallas-tpu-sparse", interpret auto-resolved off-TPU) and
    the XLA reference ("xla-sparse")."""
    from repro.engine.backends import (_xla_attention,  # lazy: avoids cycle
                                       _xla_grouped)

    for name, use_pallas in (("pallas-tpu-sparse", True),
                             ("xla-sparse", False)):
        registry.register(name, "gemm_sparse", _sparse_backend(use_pallas))
        registry.register(name, "gemm", _dense_gemm_backend(use_pallas))
        # MoE expert stacks are never pruned (prune_params skips them)
        # and attention stays float; registering the references keeps
        # the backend namespace total — same posture as the int8 plane.
        registry.register(name, "grouped_gemm", _xla_grouped)
        registry.register(name, "attention", _xla_attention)
