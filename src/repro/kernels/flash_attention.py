"""Pallas TPU flash attention — the §Perf fix for the memory-dominated
attention cells.

The dry-run rooflines show the XLA-lowered chunked attention streaming
its (B, H, Sq, C) logits/probability tensors through HBM (e.g.
internvl2-1b prefill_32k: memory term 16.6 s, useful-FLOPs 0.05).  On
TPU these intermediates belong in VMEM: this kernel keeps the online-
softmax state (m, l, acc) in VMEM scratch across the KV-block sweep, so
per-layer HBM traffic drops to q + k + v + o.

Layout: grid (B*H, nq, nk) with the KV axis innermost — the scratch
state for one (batch*head, q-block) survives consecutive nk steps
(same revisiting guarantee the OS GEMM kernel uses).  Causality and
sliding windows are applied via broadcasted iota against the absolute
block offsets, fused in-kernel (no materialized mask).

Validated in interpret mode against models/layers.flash_attention's
naive oracle across shapes x causal x window (tests/test_kernels.py).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int, bq: int, bk: int,
            n_k: int):
    iq, ik = pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale            # (bq, d)
    k = k_ref[0].astype(jnp.float32)                    # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ik == n_k - 1)
    def _flush():
        l_safe = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l_safe[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention_tpu(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        bq: int = 512, bk: int = 512,
                        interpret: bool = False) -> jax.Array:
    """q (B, H, Sq, D); k, v (B, H, Sk, D) (repeat GQA heads outside).
    Sq % bq == 0 and Sk % bk == 0 (callers pad); D should be a multiple
    of 128 on real hardware."""
    b, h, sq, d = q.shape
    sk = k.shape[2]
    if sq % bq or sk % bk:
        raise ValueError(f"seq dims ({sq},{sk}) not divisible by ({bq},{bk})")
    bh = b * h
    qr = q.reshape(bh, sq, d)
    kr = k.reshape(bh, sk, d)
    vr = v.reshape(bh, sk, d)
    n_q, n_k = sq // bq, sk // bk
    grid = (bh, n_q, n_k)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=1.0 / math.sqrt(d), causal=causal,
                          window=window, bq=bq, bk=bk, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh_, iq, ik: (bh_, iq, 0)),
            pl.BlockSpec((1, bk, d), lambda bh_, iq, ik: (bh_, ik, 0)),
            pl.BlockSpec((1, bk, d), lambda bh_, iq, ik: (bh_, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh_, iq, ik: (bh_, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # running max
            pltpu.VMEM((bq,), jnp.float32),      # running denominator
            pltpu.VMEM((bq, d), jnp.float32),    # output accumulator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, sq, d)


def attention_hbm_bytes(b: int, h: int, sq: int, sk: int, d: int,
                        itemsize: int = 2) -> int:
    """Kernelized per-layer HBM traffic: q + k + v + o only — the number
    the §Perf iteration uses to re-model the memory term."""
    return itemsize * b * h * d * (2 * sq + 2 * sk)


def _legal_block(seq: int, want: int) -> int:
    """Largest divisor of `seq` that is <= want (the kernel requires
    Sq % bq == 0 / Sk % bk == 0; engine decisions are hints).  When no
    usable divisor exists near the hint (prime-ish lengths would degrade
    to 1-row blocks), span the sequence with one block — but only while
    that block stays VMEM-sized; beyond that, fail with intent rather
    than hand Mosaic a whole-sequence tile."""
    b = min(want, seq)
    while seq % b:
        b -= 1
    if b >= 8 or b == seq:
        return b
    if seq <= 2048:  # one block spans the seq; the score tile stays VMEM-sized
        return seq
    raise ValueError(
        f"no usable attention block for seq={seq} (largest divisor <= "
        f"{want} is {b}); pad the sequence to a multiple of 8")


def register_into(registry) -> None:
    """Register flash attention as the `attention` op of both Pallas
    backends (repro.engine.KernelRegistry)."""
    def _run(interpret: bool):
        def run(decision, q, k, v, *, causal=True, window=0):
            bq = _legal_block(q.shape[2], decision.bm)
            bk = _legal_block(k.shape[2], decision.bn)
            return flash_attention_tpu(q, k, v, causal=causal, window=window,
                                       bq=bq, bk=bk, interpret=interpret)
        return run

    registry.register("pallas-tpu", "attention", _run(interpret=False))
    registry.register("pallas-interpret", "attention", _run(interpret=True))
