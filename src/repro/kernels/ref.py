"""Pure-jnp oracles for the kernels package.

Every Pallas kernel in this package is validated (tests/test_kernels.py)
against these references across dataflows x tile shapes x dtypes x odd
sizes, in interpret mode on CPU.
"""

from __future__ import annotations

import jax.numpy as jnp


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray, out_dtype=None) -> jnp.ndarray:
    """(M, K) @ (K, N) with f32 accumulation — the GEMM oracle."""
    out = jnp.dot(a, b, preferred_element_type=jnp.float32)
    return out.astype(out_dtype or a.dtype)


def grouped_matmul_ref(x, w, group_sizes, out_dtype=None):
    """Oracle for the MoE grouped GEMM: rows of `x` are partitioned into
    len(group_sizes) contiguous groups; group g is multiplied by w[g].

    x: (tokens, K), w: (G, K, N), group_sizes: (G,) ints summing to tokens.
    """
    outs = []
    start = 0
    for g, size in enumerate(group_sizes):
        outs.append(matmul_ref(x[start:start + size], w[g], out_dtype))
        start += size
    return jnp.concatenate(outs, axis=0)
