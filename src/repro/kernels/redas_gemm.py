"""ReDas GEMM as a Pallas TPU kernel: BlockSpec tiles play the logical
array, grid order + VMEM residency plays the dataflow.

Hardware adaptation (DESIGN.md Sec. 2): the TPU MXU is a fixed 128x128
systolic array — we cannot rewire it.  The paper's *decision surface*
(logical shape x dataflow) maps onto the Pallas schedule:

  logical shape R_l x C_l   -> block tile (bm, bn) (+ depth bk): a tall
                               skinny logical array is a tall skinny
                               output tile; the MXU processes it in
                               ceil(bm/128) x ceil(bn/128) passes without
                               padding the *workload* to a square.
  OS (output stationary)    -> grid (m, n, k), k innermost; the output
                               tile lives in a VMEM scratch accumulator
                               across the whole K-reduction and is written
                               to HBM once (no edge accumulators; exactly
                               the paper's "OS needs no accumulators").
  WS (weight stationary)    -> grid (n, k, m), m innermost; the weight
                               block's index map ignores m so the (bk, bn)
                               weight tile stays VMEM-resident across the
                               M sweep (the preloaded stationary operand);
                               partial outputs stream through HBM via an
                               input/output-aliased accumulator (the
                               paper's edge accumulators in the multi-mode
                               buffer).
  IS (input stationary)     -> grid (m, k, n), n innermost; the (bm, bk)
                               input tile is the resident operand and
                               partial outputs stream, symmetrical to WS.

All three compute identical results (tests sweep dataflows x shapes x
dtypes against kernels/ref.py); they differ in which operand is revisited
from VMEM and which traffic hits HBM — the same trade-off the ReDas
multi-mode buffer manages on the ASIC.

VMEM discipline: one (bm, bk) + one (bk, bn) + one (bm, bn) f32 block
(x2 for the pipeline's double buffering) must fit the ~16 MiB of a v5e
core; `vmem_bytes()` exposes the footprint and ops.py enforces it — the
Pallas realization of the paper's Eq. (2) buffer constraint.
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU compiler params are optional off-TPU (interpret mode ignores them)
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None

from ._compat import CompilerParams

DataflowName = Literal["os", "ws", "is"]

# TPU v5e tiling floor for f32/bf16 operands: (sublane, lane).
SUBLANE = 8
LANE = 128
VMEM_BYTES = 16 * 2**20  # per-core VMEM (v5e)


def _check_block(name: str, b0: int, b1: int) -> None:
    if b0 % SUBLANE or b1 % LANE:
        raise ValueError(
            f"{name} block ({b0}, {b1}) must be multiples of ({SUBLANE}, {LANE}) "
            "for MXU/VREG alignment")


def vmem_bytes(bm: int, bk: int, bn: int, in_dtype=jnp.bfloat16) -> int:
    """VMEM working set of one grid step (x2 double buffering), Eq. 2 analogue."""
    w = jnp.dtype(in_dtype).itemsize
    return 2 * (bm * bk * w + bk * bn * w) + bm * bn * 4  # acc always f32


def round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def default_blocks(m: int, k: int, n: int,
                   in_dtype=jnp.bfloat16) -> tuple[int, int, int]:
    """Hardware-aligned blocks no larger than the (padded) problem, capped
    so the double-buffered working set fits VMEM (Eq. 2 analogue).  The
    single block-selection policy shared by the dense path
    (engine.backends.pallas_gemm) and the grouped path
    (grouped_gemm.default_group_blocks)."""
    bm = min(round_up(m, SUBLANE), 256)
    bk = min(round_up(k, LANE), 256)
    bn = min(round_up(n, LANE), 256)
    while vmem_bytes(bm, bk, bn, in_dtype) > VMEM_BYTES:  # pragma: no cover
        bk = max(LANE, bk // 2)
    return bm, bk, bn


def _mac(a_ref, b_ref):
    return jnp.dot(
        a_ref[...].astype(jnp.float32), b_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32)


# --------------------------------------------------------------------------
# OS: k innermost, VMEM scratch accumulator, single HBM write per out tile.
# --------------------------------------------------------------------------


def _os_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += _mac(a_ref, b_ref)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


# --------------------------------------------------------------------------
# WS / IS: stationary operand's index map ignores the innermost grid axis
# (stays VMEM-resident); partials stream through the aliased accumulator.
# --------------------------------------------------------------------------


def _streaming_kernel(a_ref, b_ref, acc_ref, o_ref):
    o_ref[...] = acc_ref[...] + _mac(a_ref, b_ref)


def _compiler_params(n_axes: int):
    if CompilerParams is None:
        return None
    # Revisited output blocks require sequential ("arbitrary") grid axes.
    return CompilerParams(dimension_semantics=("arbitrary",) * n_axes)


@functools.partial(
    jax.jit, static_argnames=("dataflow", "bm", "bk", "bn", "interpret", "out_dtype"))
def gemm(
    a: jax.Array,
    b: jax.Array,
    *,
    dataflow: DataflowName = "os",
    bm: int = 256,
    bk: int = 256,
    bn: int = 256,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Tiled (M, K) @ (K, N); dims must be multiples of the block dims
    (engine.backends.pallas_gemm pads arbitrary shapes).  Accumulates in f32."""
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"GEMM dim mismatch {a.shape} @ {b.shape}")
    if m % bm or k % bk or n % bn:
        raise ValueError(f"({m},{k},{n}) not divisible by blocks ({bm},{bk},{bn})")
    _check_block("A", bm, bk)
    _check_block("B", bk, bn)
    _check_block("O", bm, bn)
    gm, gk, gn = m // bm, k // bk, n // bn

    a_bs = lambda im: pl.BlockSpec((bm, bk), im)
    b_bs = lambda im: pl.BlockSpec((bk, bn), im)
    o_bs = lambda im: pl.BlockSpec((bm, bn), im)

    if dataflow == "os":
        grid = (gm, gn, gk)
        return pl.pallas_call(
            functools.partial(_os_kernel, n_k=gk),
            grid=grid,
            in_specs=[a_bs(lambda i, j, kk: (i, kk)), b_bs(lambda i, j, kk: (kk, j))],
            out_specs=o_bs(lambda i, j, kk: (i, j)),
            out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
            compiler_params=_compiler_params(3),
            interpret=interpret,
        )(a, b)

    # Streaming dataflows: one pallas_call per K-chunk.  Within a call the
    # stationary operand's block index ignores the innermost grid axis, so
    # it stays VMEM-resident across the whole sweep; partial outputs stream
    # through HBM between calls via XLA-level input/output aliasing (each
    # out block is written exactly once per call, so revisit semantics
    # never arise).  On TPU the gk sequential calls are each fully
    # pipelined and XLA elides accumulator copies (donation).
    if dataflow == "ws":
        grid = (gn, gm)  # weight block (0, j) constant across inner i sweep
        in_specs = [
            a_bs(lambda j, i: (i, 0)),
            b_bs(lambda j, i: (0, j)),
            o_bs(lambda j, i: (i, j)),
        ]
        out_spec = o_bs(lambda j, i: (i, j))
    elif dataflow == "is":
        grid = (gm, gn)  # input block (i, 0) constant across inner j sweep
        in_specs = [
            a_bs(lambda i, j: (i, 0)),
            b_bs(lambda i, j: (0, j)),
            o_bs(lambda i, j: (i, j)),
        ]
        out_spec = o_bs(lambda i, j: (i, j))
    else:
        raise ValueError(f"unknown dataflow {dataflow!r}")

    step = pl.pallas_call(
        _streaming_kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        input_output_aliases={2: 0},
        compiler_params=_compiler_params(2),
        interpret=interpret,
    )

    def body(kk, acc):
        a_k = jax.lax.dynamic_slice(a, (0, kk * bk), (m, bk))
        b_k = jax.lax.dynamic_slice(b, (kk * bk, 0), (bk, n))
        return step(a_k, b_k, acc)

    out_f32 = jax.lax.fori_loop(0, gk, body, jnp.zeros((m, n), jnp.float32))
    return out_f32.astype(out_dtype)


def register_into(registry) -> None:
    """Register the ReDas GEMM as the `gemm` op of both Pallas backends
    (repro.engine.KernelRegistry)."""
    from repro.engine.backends import _gemm_backend  # lazy: avoids cycle

    registry.register("pallas-tpu", "gemm", _gemm_backend(interpret=False))
    registry.register("pallas-interpret", "gemm", _gemm_backend(interpret=True))
