"""Grouped (per-expert) Pallas GEMM — the MoE expert-FFN hot spot.

Computes y[e] = x[e] @ w[e] for e in [0, E) with one pallas_call:
grid (E, C/bc, F/bf, D/bd), OS-style VMEM accumulator over the D sweep.
The expert axis is an independent ("parallel") grid dimension, so on EP
meshes each core runs only its local experts' sub-grid — this is the
kernel the sorted-dispatch path (models/moe.py) feeds its (E, C, D)
buffers through on TPU.

For capacity-padded buffers the padded rows multiply zeros (exact).
Validated against kernels/ref.grouped_matmul_ref in interpret mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams
from .redas_gemm import VMEM_BYTES, default_blocks, vmem_bytes


def default_group_blocks(c: int, d: int, f: int,
                         in_dtype=jnp.bfloat16) -> tuple[int, int, int]:
    """Per-expert blocks through the shared Eq.-2 VMEM gate — literally
    the dense path's policy (`redas_gemm.default_blocks`) applied to the
    per-group (C, D, F) problem."""
    return default_blocks(c, d, f, in_dtype)


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, n_d: int):
    @pl.when(pl.program_id(3) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[0].astype(jnp.float32), w_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(3) == n_d - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bc", "bd", "bf", "interpret"))
def grouped_matmul(x: jax.Array, w: jax.Array, *, bc: int | None = None,
                   bd: int | None = None, bf: int | None = None,
                   interpret: bool = False) -> jax.Array:
    """x (E, C, D) @ w (E, D, F) -> (E, C, F); dims padded to blocks.

    Blocks default through `default_group_blocks` (the shared Eq.-2 VMEM
    gate); explicit blocks that overflow VMEM are rejected like the
    dense path's `pallas_gemm`."""
    e, c, d = x.shape
    _, _, f = w.shape
    dbc, dbd, dbf = default_group_blocks(c, d, f, x.dtype)
    bc, bd, bf = bc or dbc, bd or dbd, bf or dbf
    if vmem_bytes(bc, bd, bf, x.dtype) > VMEM_BYTES:
        raise ValueError(
            f"blocks ({bc},{bd},{bf}) exceed VMEM budget {VMEM_BYTES} (Eq. 2)")
    pad = lambda v, b: -(-v // b) * b
    cp, dp, fp = pad(c, bc), pad(d, bd), pad(f, bf)
    if (cp, dp) != (c, d):
        x = jnp.pad(x, ((0, 0), (0, cp - c), (0, dp - d)))
    if (dp, fp) != (d, f):
        w = jnp.pad(w, ((0, 0), (0, dp - d), (0, fp - f)))
    n_d = dp // bd
    out = pl.pallas_call(
        functools.partial(_kernel, n_d=n_d),
        grid=(e, cp // bc, fp // bf, n_d),
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda ee, i, j, k: (ee, i, k)),
            pl.BlockSpec((1, bd, bf), lambda ee, i, j, k: (ee, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda ee, i, j, k: (ee, i, j)),
        out_shape=jax.ShapeDtypeStruct((e, cp, fp), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(x, w)
    return out[:, :c, :f]


@functools.lru_cache(maxsize=None)
def _diff_grouped(bc: int, bd: int, bf: int, interpret: bool):
    """Differentiable wrapper (the kernel itself has no JVP rule): both
    cotangents are grouped GEMMs on transposed operands and run through
    the same kernel with VMEM-gated default blocks."""

    @jax.custom_vjp
    def f(x, w):
        return grouped_matmul(x, w, bc=bc, bd=bd, bf=bf, interpret=interpret)

    def fwd(x, w):
        return f(x, w), (x, w)

    def bwd(res, g):
        x, w = res
        dx = grouped_matmul(g, w.transpose(0, 2, 1), interpret=interpret)
        dw = grouped_matmul(x.transpose(0, 2, 1), g, interpret=interpret)
        return dx.astype(x.dtype), dw.astype(w.dtype)

    f.defvjp(fwd, bwd)
    # jit the wrapper: an un-jitted custom_vjp call re-traces eagerly.
    return jax.jit(f)


def register_into(registry) -> None:
    """Register the grouped GEMM as the `grouped_gemm` op of both Pallas
    backends (repro.engine.KernelRegistry)."""
    def _run(interpret: bool):
        def run(decision, x, w, *, out_dtype=None):
            fn = _diff_grouped(decision.bm, decision.bk, decision.bn,
                               interpret)
            out = fn(x, w)
            return out.astype(out_dtype or x.dtype)
        return run

    registry.register("pallas-tpu", "grouped_gemm", _run(interpret=False))
    registry.register("pallas-interpret", "grouped_gemm", _run(interpret=True))
