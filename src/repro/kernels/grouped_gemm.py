"""Grouped (per-expert) Pallas GEMM — the MoE expert-FFN hot spot.

Computes y[e] = x[e] @ w[e] for e in [0, E) with one pallas_call:
grid (E, C/bc, F/bf, D/bd), OS-style VMEM accumulator over the D sweep.
The expert axis is an independent ("parallel") grid dimension, so on EP
meshes each core runs only its local experts' sub-grid — this is the
kernel the sorted-dispatch path (models/moe.py) feeds its (E, C, D)
buffers through on TPU.

For capacity-padded buffers the padded rows multiply zeros (exact).
Validated against kernels/ref.grouped_matmul_ref in interpret mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, n_d: int):
    @pl.when(pl.program_id(3) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[0].astype(jnp.float32), w_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(3) == n_d - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bc", "bd", "bf", "interpret"))
def grouped_matmul(x: jax.Array, w: jax.Array, *, bc: int = 128,
                   bd: int = 128, bf: int = 128,
                   interpret: bool = False) -> jax.Array:
    """x (E, C, D) @ w (E, D, F) -> (E, C, F); dims padded to blocks."""
    e, c, d = x.shape
    _, _, f = w.shape
    pad = lambda v, b: -(-v // b) * b
    cp, dp, fp = pad(c, bc), pad(d, bd), pad(f, bf)
    if (cp, dp) != (c, d):
        x = jnp.pad(x, ((0, 0), (0, cp - c), (0, dp - d)))
    if (dp, fp) != (d, f):
        w = jnp.pad(w, ((0, 0), (0, dp - d), (0, fp - f)))
    n_d = dp // bd
    out = pl.pallas_call(
        functools.partial(_kernel, n_d=n_d),
        grid=(e, cp // bc, fp // bf, n_d),
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda ee, i, j, k: (ee, i, k)),
            pl.BlockSpec((1, bd, bf), lambda ee, i, j, k: (ee, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda ee, i, j, k: (ee, i, j)),
        out_shape=jax.ShapeDtypeStruct((e, cp, fp), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(x, w)
    return out[:, :c, :f]
