"""Paged-attention decode: gather KV pages through a block table.

The serving plane's paged layout (DESIGN.md §8) stores attention KV in
a pool `(P, page, KV, hd)` shared by all slots; each slot's logical
rows live at the physical pages named by its block-table row
`(n_bt,) int32` (-1 = unallocated).  Decode attention then needs a
gather the contiguous flash kernel cannot express — so this module
provides the `paged_attention` op in both guises:

  paged_attention_reference   pure-jax gather + EXACTLY the contiguous
                              `models.layers.cached_attention` math
                              (same einsums, same masking) so paged vs
                              contiguous greedy decode is bit-identical
                              — the parity oracle the tests lean on.
  paged_attention_tpu         Pallas kernel, grid (B, KV, n_bt): the
                              block table and per-slot kv_len ride the
                              scalar-prefetch lane and each grid step's
                              k/v BlockSpec index map dereferences
                              bt[b, i] directly — pages stream
                              HBM->VMEM exactly once, no gathered copy
                              of the cache ever materializes.  Online-
                              softmax scratch carries (m, l, acc)
                              across the page sweep, flash-style.

int8 composition (PR 5 codec): per-row scales page with their rows —
`k_scale_pages`/`v_scale_pages` pools `(P, page, KV)` are indexed by
the SAME block table, and the kernel folds scales in where the
contiguous path does (scores *= k_scale before masking, weights *=
v_scale after normalizing by the plain softmax denominator).

Unallocated table entries clamp to page 0; every position of such a
page is >= kv_len, so its scores mask to NEG_INF and contribute an
exact 0 — stale or foreign rows never leak into the output.  A fully
masked slot (kv_len == 0, i.e. inactive) outputs exact zeros in the
kernel via the running-max == NEG_INF guard; the pure-jax reference
softmaxes all-NEG_INF rows to a uniform average instead, so the two
paths agree only for kv_len >= 1 (all live slots).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

NEG_INF = -1e30


def paged_attention_reference(q: jax.Array, k_pages: jax.Array,
                              v_pages: jax.Array, block_tables: jax.Array,
                              kv_len: jax.Array, *,
                              k_scale: jax.Array | None = None,
                              v_scale: jax.Array | None = None) -> jax.Array:
    """q (B, 1, H, d); k/v pools (P, page, KV, hd); block_tables
    (B, n_bt) int32 (-1 = hole); kv_len (B,).  Returns o (B, 1, H, d)
    pre-`wo` (the caller owns the output projection).

    The gather reproduces each slot's logical rows [0, n_bt*page) in
    order, after which the math is line-for-line cached_attention: rows
    at positions >= kv_len score NEG_INF, exp underflows to exact 0.0,
    and x + 0.0 == x — so the result is bitwise what the contiguous
    cache produces for the same live rows."""
    b, sq, h, d = q.shape
    kv = k_pages.shape[2]
    g = h // kv
    n_pool = k_pages.shape[0]
    safe = jnp.clip(block_tables, 0, n_pool - 1)            # (B, n_bt)
    n_bt, page = block_tables.shape[1], k_pages.shape[1]
    s_rows = n_bt * page
    k = k_pages[safe].reshape(b, s_rows, kv, d)
    v = v_pages[safe].reshape(b, s_rows, kv, d)
    row = lambda sc: sc.astype(jnp.float32).transpose(0, 2, 1)[:, :, None, None, :]
    qg = (q.reshape(b, sq, kv, g, d) / math.sqrt(d)).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    if k_scale is not None:
        s = s * row(k_scale[safe].reshape(b, s_rows, kv))
    srange = jnp.arange(s_rows)
    if kv_len.ndim == 1:
        valid = (srange[None, :] < kv_len[:, None])[:, None, :]   # (B,1,S)
    else:  # per-query lengths (B, Sq) — the W-wide speculative verify
        valid = srange[None, None, :] < kv_len[:, :, None]        # (B,Sq,S)
    s = jnp.where(valid[:, None, None, :, :], s, NEG_INF)
    p_attn = jax.nn.softmax(s, axis=-1)
    if v_scale is not None:
        p_attn = p_attn * row(v_scale[safe].reshape(b, s_rows, kv))
    o = jnp.einsum("bkgqs,bskd->bqkgd", p_attn, v.astype(jnp.float32))
    return o.reshape(b, sq, h, d).astype(q.dtype)


def _kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, *rest, scale: float,
            page: int, n_bt: int, quantized: bool):
    if quantized:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    b, i = pl.program_id(0), pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale             # (G, d)
    k = k_ref[0, :, 0, :].astype(jnp.float32)               # (page, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, page)
    if quantized:
        s = s * ks_ref[0, :, 0].astype(jnp.float32)[None, :]

    g = q.shape[0]
    pos = i * page + jax.lax.broadcasted_iota(jnp.int32, (g, page), 1)
    s = jnp.where(pos < len_ref[b], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    # When every position so far is masked (kv_len == 0), m_new is still
    # NEG_INF and exp(s - m_new) would be exp(0) = 1 — guard so fully
    # masked rows contribute an exact 0 instead of averaging page-0 v.
    dead = m_new == NEG_INF
    p = jnp.where(dead[:, None], 0.0, jnp.exp(s - m_new[:, None]))
    corr = jnp.where(dead, 0.0, jnp.exp(m_prev - m_new))
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    m_ref[...] = m_new
    if quantized:
        p = p * vs_ref[0, :, 0].astype(jnp.float32)[None, :]
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v_ref[0, :, 0, :].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(i == n_bt - 1)
    def _flush():
        l_safe = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l_safe[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention_tpu(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                        block_tables: jax.Array, kv_len: jax.Array,
                        k_scale: jax.Array | None = None,
                        v_scale: jax.Array | None = None, *,
                        interpret: bool = False) -> jax.Array:
    """Same contract as `paged_attention_reference` (sq must be 1).

    The block table and kv_len are scalar-prefetched: k/v (and scale)
    index maps read `bt[b, i]` to land each grid step's BlockSpec on
    the right physical page, so the sweep over a slot's pages is the
    only traffic.  Holes (-1) clamp to page 0 and mask to exact zero
    via the kv_len comparison."""
    b, sq, h, d = q.shape
    if sq != 1:
        raise ValueError(f"paged decode kernel is sq==1 only, got {sq}")
    n_pool, page, kv, _ = k_pages.shape
    g = h // kv
    n_bt = block_tables.shape[1]
    quantized = k_scale is not None

    qr = q.reshape(b, kv, g, d)  # head h = kv_idx * g + g_idx, layers.py order

    def page_idx(b_, h_, i_, bt, ln):
        return (jnp.maximum(bt[b_, i_], 0), 0, h_, 0)

    def scale_idx(b_, h_, i_, bt, ln):
        return (jnp.maximum(bt[b_, i_], 0), 0, h_)

    in_specs = [
        pl.BlockSpec((1, 1, g, d), lambda b_, h_, i_, bt, ln: (b_, h_, 0, 0)),
        pl.BlockSpec((1, page, 1, d), page_idx),
        pl.BlockSpec((1, page, 1, d), page_idx),
    ]
    args = [qr, k_pages, v_pages]
    if quantized:
        in_specs += [pl.BlockSpec((1, page, 1), scale_idx),
                     pl.BlockSpec((1, page, 1), scale_idx)]
        args += [k_scale, v_scale]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kv, n_bt),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda b_, h_, i_, bt, ln: (b_, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),      # running max
            pltpu.VMEM((g,), jnp.float32),      # running denominator
            pltpu.VMEM((g, d), jnp.float32),    # output accumulator
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, scale=1.0 / math.sqrt(d), page=page,
                          n_bt=n_bt, quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, g, d), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), kv_len.astype(jnp.int32), *args)
    return out.reshape(b, sq, h, d)


def register_into(registry) -> None:
    """Register the `paged_attention` op across the backend namespace:
    the reference gather on the XLA backends (exact-parity path) and
    the scalar-prefetch kernel on the Pallas ones."""
    def _reference(decision, q, k_pages, v_pages, block_tables, kv_len, *,
                   k_scale=None, v_scale=None):
        return paged_attention_reference(q, k_pages, v_pages, block_tables,
                                         kv_len, k_scale=k_scale,
                                         v_scale=v_scale)

    def _pallas(interpret: bool | None):
        def run(decision, q, k_pages, v_pages, block_tables, kv_len, *,
                k_scale=None, v_scale=None):
            from repro.engine.backends import auto_interpret
            return paged_attention_tpu(q, k_pages, v_pages, block_tables,
                                       kv_len, k_scale, v_scale,
                                       interpret=auto_interpret(interpret))
        return run

    registry.register("xla-einsum", "paged_attention", _reference)
    registry.register("xla-int8", "paged_attention", _reference)
    registry.register("xla-sparse", "paged_attention", _reference)
    registry.register("pallas-tpu", "paged_attention", _pallas(False))
    registry.register("pallas-interpret", "paged_attention", _pallas(True))
    registry.register("pallas-tpu-int8", "paged_attention", _pallas(None))
    registry.register("pallas-tpu-sparse", "paged_attention", _pallas(None))
