"""Public jit'd wrappers around the ReDas Pallas GEMM.

`redas_matmul` is the shape-safe entry point: it pads arbitrary (M, K, N)
to the chosen block multiples, invokes `redas_gemm.gemm`, and slices the
result.  `auto_matmul` consults the plane-2 TPU mapper (core.tpu_model)
to pick (dataflow, bm, bk, bn) per GEMM shape — the software analogue of
ReDas reconfiguring per layer — with a per-shape decision cache standing
in for the paper's "repeated GEMM shapes reuse the previous choice".

On CPU hosts the kernels run in interpret mode (Pallas TPU lowering needs
a real TPU); `interpret=None` auto-detects.  Models route their matmuls
here when `use_redas_kernel=True` and through XLA einsum otherwise (the
dry-run path).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import redas_gemm
from .redas_gemm import LANE, SUBLANE, VMEM_BYTES, DataflowName, vmem_bytes


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def _auto_interpret(interpret: bool | None) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def default_blocks(m: int, k: int, n: int) -> tuple[int, int, int]:
    """Hardware-aligned blocks no larger than the (padded) problem, capped
    so the double-buffered working set fits VMEM (Eq. 2 analogue)."""
    bm = min(_round_up(m, SUBLANE), 256)
    bk = min(_round_up(k, LANE), 256)
    bn = min(_round_up(n, LANE), 256)
    while vmem_bytes(bm, bk, bn) > VMEM_BYTES:  # pragma: no cover - caps above fit
        bk = max(LANE, bk // 2)
    return bm, bk, bn


@functools.partial(
    jax.jit,
    static_argnames=("dataflow", "bm", "bk", "bn", "interpret", "out_dtype"))
def redas_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    dataflow: DataflowName = "os",
    bm: int | None = None,
    bk: int | None = None,
    bn: int | None = None,
    interpret: bool | None = None,
    out_dtype=None,
) -> jax.Array:
    """(M, K) @ (K, N) for arbitrary dims: pad -> blocked Pallas GEMM -> slice."""
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"matmul dim mismatch {a.shape} @ {b.shape}")
    out_dtype = out_dtype or a.dtype
    dbm, dbk, dbn = default_blocks(m, k, n)
    bm, bk, bn = bm or dbm, bk or dbk, bn or dbn
    if vmem_bytes(bm, bk, bn, a.dtype) > VMEM_BYTES:
        raise ValueError(
            f"blocks ({bm},{bk},{bn}) exceed VMEM budget {VMEM_BYTES} (Eq. 2)")

    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    a_p = jnp.pad(a, ((0, mp - m), (0, kp - k))) if (mp, kp) != (m, k) else a
    b_p = jnp.pad(b, ((0, kp - k), (0, np_ - n))) if (kp, np_) != (k, n) else b
    out = redas_gemm.gemm(
        a_p, b_p, dataflow=dataflow, bm=bm, bk=bk, bn=bn,
        interpret=_auto_interpret(interpret), out_dtype=out_dtype)
    return out[:m, :n] if (mp, np_) != (m, n) else out


# --------------------------------------------------------------------------
# Mapper-driven dispatch (per-shape decision cache, Sec. 4.3)
# --------------------------------------------------------------------------


@functools.lru_cache(maxsize=4096)
def _decide(m: int, k: int, n: int) -> tuple[str, int, int, int]:
    from repro.core.tpu_model import choose_kernel_config  # lazy: heavy import

    cfg = choose_kernel_config(m, k, n)
    return cfg.dataflow, cfg.bm, cfg.bk, cfg.bn


def auto_matmul(a: jax.Array, b: jax.Array, *, interpret: bool | None = None,
                out_dtype=None) -> jax.Array:
    """Mapper-selected dataflow + blocks for this GEMM shape."""
    (m, k), (_, n) = a.shape, b.shape
    dataflow, bm, bk, bn = _decide(m, k, n)
    return redas_matmul(
        a, b, dataflow=dataflow, bm=bm, bk=bk, bn=bn,  # type: ignore[arg-type]
        interpret=interpret, out_dtype=out_dtype)


import contextlib  # noqa: E402


@contextlib.contextmanager
def use_redas_kernels():
    """Route every models.layers.dense matmul through the mapper-
    dispatched Pallas GEMM (use_redas_kernel=True in DESIGN.md §3)."""
    from repro.models import layers
    prev = layers.USE_REDAS_KERNEL
    layers.USE_REDAS_KERNEL = True
    try:
        yield
    finally:
        layers.USE_REDAS_KERNEL = prev
