"""DEPRECATED shim — the decision/dispatch surface moved to `repro.engine`.

PR 3 unified the two decision planes behind the `repro.engine`
execution-plan API; everything this module used to own lives there now:

    redas_matmul(...)      -> repro.engine.backends.pallas_gemm(...)
    auto_matmul(a, b)      -> repro.engine.matmul(a, b)  (Engine.matmul)
    use_redas_kernels()    -> repro.engine.use_engine()
    default_blocks(...)    -> repro.engine.backends.default_blocks(...)

The aliases below keep downstream code importable but emit
`DeprecationWarning` (CI's tier1-strict lane runs the suite with
`-W error::DeprecationWarning`, so in-repo callers cannot regress onto
them).  They will be removed once external callers have migrated.
"""

from __future__ import annotations

import warnings


def _deprecated(old: str, new: str) -> None:
    warnings.warn(f"repro.kernels.ops.{old} is deprecated; use {new}",
                  DeprecationWarning, stacklevel=3)


def redas_matmul(a, b, **kwargs):
    """Deprecated alias of `repro.engine.backends.pallas_gemm`."""
    _deprecated("redas_matmul", "repro.engine.backends.pallas_gemm")
    from repro.engine.backends import pallas_gemm

    return pallas_gemm(a, b, **kwargs)


_ALIAS_ENGINES: dict = {}


def auto_matmul(a, b, *, interpret: bool | None = None, out_dtype=None):
    """Deprecated alias of `repro.engine.matmul` (mapper-planned dispatch
    with a per-backend shared plan cache, so `interpret` keeps its old
    per-call meaning and never leaks into other engines)."""
    _deprecated("auto_matmul", "repro.engine.matmul / Engine.matmul")
    from repro.engine import Engine
    from repro.engine.backends import auto_interpret

    backend = ("pallas-interpret" if auto_interpret(interpret)
               else "pallas-tpu")
    eng = _ALIAS_ENGINES.get(backend)
    if eng is None:
        eng = _ALIAS_ENGINES[backend] = Engine(backend=backend)
    return eng.matmul(a, b, out_dtype=out_dtype)


def default_blocks(m: int, k: int, n: int):
    """Deprecated alias of `repro.engine.backends.default_blocks`."""
    _deprecated("default_blocks", "repro.engine.backends.default_blocks")
    from repro.engine.backends import default_blocks as _db

    return _db(m, k, n)


def use_redas_kernels():
    """Deprecated alias of `repro.engine.use_engine()` (mapper-planned
    Pallas dispatch for every models.layers.dense matmul in scope)."""
    _deprecated("use_redas_kernels", "repro.engine.use_engine")
    from repro.engine import use_engine

    return use_engine()
