"""Int8 GEMM as a Pallas TPU kernel + the engine's int8 backends.

The quantization plane's execution layer (DESIGN.md §7): both operands
arrive (or are dynamically made) int8, the MXU accumulates
int8 x int8 -> int32 (`preferred_element_type=jnp.int32` — on v5e the
int8 MXU path doubles peak throughput over bf16), and the int32
accumulator is rescaled ONCE per output element by the product of the
operands' per-channel scales:

    y[m, n] = (sum_k a_q[m, k] * b_q[k, n]) * s_a[m] * s_b[n]

which is exact because symmetric per-channel scales factor out of the
K-contraction (scales reduce the contraction axis — quant/quantize.py).

Two backends register into the engine registry:

  pallas-tpu-int8  this module's OS-dataflow Pallas kernel (int32 VMEM
                   scratch accumulator, one HBM write per output tile;
                   interpret mode auto-resolves off-TPU like the
                   pre-engine `auto_matmul` did, so one backend name
                   serves both hosts);
  xla-int8         the reference: the same quantization decomposition
                   through `lax.dot_general(..., preferred_element_type
                   =jnp.int32)` — numerics oracle and the CPU-CI path.

Both expose three ops: `gemm` (dynamic quantization of both operands),
`gemm_w8` (pre-quantized weights from `quant.quantize_params` + dynamic
per-row activation quantization), `grouped_gemm` (per-expert int8).

VJP policy: the forward is quantized, the backward is NOT — cotangents
are computed by plain float GEMMs in the residuals' compute dtype (bf16
in production), i.e. a straight-through estimator.  Quantization noise
is sub-resolution for gradients and an int8 backward would quantize the
*cotangent*, whose dynamic range per-channel scaling does not cover.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU compiler params are optional off-TPU (interpret mode ignores them)
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None

from repro.quant.quantize import kv_quantize, quantize

from ._compat import CompilerParams
from .redas_gemm import VMEM_BYTES, round_up

# int8 VREG tiling floor: (sublane, lane) = (32, 128) — four times the
# f32 sublane because four int8 rows pack one 32-bit sublane word.
INT8_SUBLANE = 32
LANE = 128


def int8_vmem_bytes(bm: int, bk: int, bn: int) -> int:
    """Working set of one grid step: two int8 operand blocks (x2 for the
    pipeline's double buffering) + the int32 accumulator (Eq. 2 analogue
    at 1-byte operands — the footprint shrink that buys larger tiles)."""
    return 2 * (bm * bk + bk * bn) + bm * bn * 4


def align_int8_blocks(bm: int, bk: int, bn: int) -> tuple[int, int, int]:
    """Snap planner-chosen blocks to the int8 tiling floor and re-gate
    VMEM.  Cost-model decisions ladder from the f32 sublane (8); the
    int8 kernel's floor is (32, 128), so executed blocks round up —
    the decision stays the planning identity, execution aligns."""
    bm = round_up(bm, INT8_SUBLANE)
    bk = round_up(bk, LANE)
    bn = round_up(bn, LANE)
    while int8_vmem_bytes(bm, bk, bn) > VMEM_BYTES:  # pragma: no cover
        bk = max(LANE, bk // 2)
    return bm, bk, bn


def default_int8_blocks(m: int, k: int, n: int) -> tuple[int, int, int]:
    """Hardware-aligned int8 blocks no larger than the padded problem."""
    return align_int8_blocks(min(round_up(m, INT8_SUBLANE), 256),
                             min(round_up(k, LANE), 512),
                             min(round_up(n, LANE), 256))


def quantize_rows(x):
    """Dynamic symmetric per-row activation quantization: x (M, K) float
    -> (q (M, K) int8, scale (M,) float32).  Per-row because the GEMM
    contracts K — the scale must not vary along the contraction.  ONE
    codec: this is the cache codec (`quant.kv_quantize`) applied to the
    last axis, so the property-tested round-trip bound covers both."""
    return kv_quantize(x)


def quantize_cols(x):
    """Per-column twin of `quantize_rows` for the right operand:
    x (K, N) float -> (q int8, scale (N,) float32) — the weight codec
    (`quant.quantize`, reduce axis 0) with the keepdim flattened."""
    qt = quantize(x, axis=0)
    return qt.q, qt.scale.reshape(-1)


# ---------------------------------------------------------------------------
# The Pallas kernel: OS dataflow, int32 VMEM scratch accumulator
# ---------------------------------------------------------------------------


def _int8_os_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def gemm_int8(a_q: jax.Array, b_q: jax.Array, *, bm: int, bk: int, bn: int,
              interpret: bool = False) -> jax.Array:
    """Blocked (M, K) @ (K, N), int8 x int8 -> int32; dims must be
    multiples of the blocks (`quant_gemm` pads arbitrary shapes).

    OS only: the int32 accumulator lives in VMEM scratch across the
    whole K-reduction — the streaming dataflows would push int32
    partial sums through HBM, forfeiting exactly the byte shrink that
    motivates int8 (an int32 partial stream is 4x the int8 operand
    traffic; see DESIGN.md §7)."""
    m, k = a_q.shape
    k2, n = b_q.shape
    if k != k2:
        raise ValueError(f"int8 GEMM dim mismatch {a_q.shape} @ {b_q.shape}")
    if m % bm or k % bk or n % bn:
        raise ValueError(
            f"({m},{k},{n}) not divisible by blocks ({bm},{bk},{bn})")
    if bm % INT8_SUBLANE or bk % LANE or bn % LANE:
        raise ValueError(
            f"int8 blocks ({bm},{bk},{bn}) must be multiples of "
            f"({INT8_SUBLANE}, {LANE}) (int8 VREG tiling floor)")
    gm, gk, gn = m // bm, k // bk, n // bn
    params = (CompilerParams(dimension_semantics=("arbitrary",) * 3)
              if CompilerParams is not None else None)
    return pl.pallas_call(
        functools.partial(_int8_os_kernel, n_k=gk),
        grid=(gm, gn, gk),
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
                  pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=params,
        interpret=interpret,
    )(a_q, b_q)


# ---------------------------------------------------------------------------
# Shape-safe entry points (pad -> kernel -> rescale -> slice)
# ---------------------------------------------------------------------------


def _int32_matmul_q(a_q, b_q, *, bm, bk, bn, interpret, use_pallas):
    """Padded int8 matmul core shared by both backends; returns int32
    (M, N).  Zero padding is exact for integer accumulation."""
    m, k = a_q.shape
    n = b_q.shape[1]
    if not use_pallas:
        return jax.lax.dot_general(
            a_q, b_q, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
    mp, kp, np_ = round_up(m, bm), round_up(k, bk), round_up(n, bn)
    a_p = jnp.pad(a_q, ((0, mp - m), (0, kp - k))) if (mp, kp) != (m, k) else a_q
    b_p = jnp.pad(b_q, ((0, kp - k), (0, np_ - n))) if (kp, np_) != (k, n) else b_q
    out = gemm_int8(a_p, b_p, bm=bm, bk=bk, bn=bn, interpret=interpret)
    return out[:m, :n] if (mp, np_) != (m, n) else out


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bk", "bn", "interpret", "use_pallas", "out_dtype"))
def quant_gemm(a: jax.Array, b: jax.Array, *, bm: int = 256, bk: int = 512,
               bn: int = 256, interpret: bool = False,
               use_pallas: bool = True, out_dtype=None) -> jax.Array:
    """Float (M, K) @ (K, N) through dynamic int8 quantization of BOTH
    operands: per-row scales on A, per-column on B, int32 accumulate,
    one rescale.  The drop-in int8 sibling of `engine.backends.pallas_gemm`."""
    out_dtype = out_dtype or a.dtype
    a_q, s_a = quantize_rows(a)
    b_q, s_b = quantize_cols(b)
    acc = _int32_matmul_q(a_q, b_q, bm=bm, bk=bk, bn=bn,
                          interpret=interpret, use_pallas=use_pallas)
    return (acc.astype(jnp.float32) * s_a[:, None] * s_b[None, :]).astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bk", "bn", "interpret", "use_pallas", "out_dtype"))
def quant_gemm_w8(a: jax.Array, w_q: jax.Array, w_scale: jax.Array, *,
                  bm: int = 256, bk: int = 512, bn: int = 256,
                  interpret: bool = False, use_pallas: bool = True,
                  out_dtype=None) -> jax.Array:
    """Float activations against PRE-quantized weights
    (`quant.quantize_params` storage: w_q (K, N) int8, w_scale (1, N) or
    (N,) float32) — the serving path that never materializes a float
    weight."""
    out_dtype = out_dtype or a.dtype
    a_q, s_a = quantize_rows(a)
    acc = _int32_matmul_q(a_q, w_q, bm=bm, bk=bk, bn=bn,
                          interpret=interpret, use_pallas=use_pallas)
    s_w = w_scale.reshape(-1)
    return (acc.astype(jnp.float32) * s_a[:, None] * s_w[None, :]).astype(out_dtype)


# ---------------------------------------------------------------------------
# Dispatch-layer custom VJPs (bf16 cotangents — see module docstring)
# ---------------------------------------------------------------------------


def _float_gemm(a, b, *, use_pallas, interpret, out_dtype):
    """The unquantized GEMM the backward pass runs on: Pallas (engine
    block defaults, VMEM-gated) on the Pallas backend, XLA otherwise."""
    if use_pallas:
        from repro.engine.backends import pallas_gemm  # lazy: avoids cycle

        return pallas_gemm(a, b, interpret=interpret, out_dtype=out_dtype)
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(out_dtype)


@functools.lru_cache(maxsize=None)
def _diff_quant_gemm(bm, bk, bn, interpret, use_pallas, out_dtype):
    """Differentiable dynamic-quant GEMM: quantized forward, float
    backward (cotangents never quantize — dA = g @ B^T and dB = A^T @ g
    run in the residuals' compute dtype, bf16 in production)."""

    @jax.custom_vjp
    def f(a, b):
        return quant_gemm(a, b, bm=bm, bk=bk, bn=bn, interpret=interpret,
                          use_pallas=use_pallas, out_dtype=out_dtype)

    def fwd(a, b):
        return f(a, b), (a, b)

    def bwd(res, g):
        a, b = res
        g = g.astype(a.dtype)
        da = _float_gemm(g, b.T, use_pallas=use_pallas, interpret=interpret,
                         out_dtype=a.dtype)
        db = _float_gemm(a.T, g, use_pallas=use_pallas, interpret=interpret,
                         out_dtype=b.dtype)
        return da, db

    f.defvjp(fwd, bwd)
    # jit the wrapper: an un-jitted custom_vjp call re-traces eagerly
    # (~200 us/call — the BENCH_PR3 lesson).
    return jax.jit(f)


@functools.lru_cache(maxsize=None)
def _diff_quant_gemm_w8(bm, bk, bn, interpret, use_pallas, out_dtype):
    """Differentiable w8 GEMM: gradients flow to the ACTIVATIONS only
    (dA = g @ dequant(W)^T in float); the stored int8 weight is data,
    not a trainable leaf."""

    @jax.custom_vjp
    def f(a, w_q, w_scale):
        return quant_gemm_w8(a, w_q, w_scale, bm=bm, bk=bk, bn=bn,
                             interpret=interpret, use_pallas=use_pallas,
                             out_dtype=out_dtype)

    def fwd(a, w_q, w_scale):
        return f(a, w_q, w_scale), (a, w_q, w_scale)

    def bwd(res, g):
        a, w_q, w_scale = res
        g = g.astype(a.dtype)
        w_f = (w_q.astype(jnp.float32)
               * w_scale.reshape(1, -1)).astype(a.dtype)
        da = _float_gemm(g, w_f.T, use_pallas=use_pallas,
                         interpret=interpret, out_dtype=a.dtype)
        return da, None, None

    f.defvjp(fwd, bwd)
    return jax.jit(f)


# ---------------------------------------------------------------------------
# Engine registration
# ---------------------------------------------------------------------------


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _blocks(decision) -> tuple[int, int, int]:
    return align_int8_blocks(decision.bm, decision.bk, decision.bn)


def _gemm_backend(use_pallas: bool):
    def run(decision, a, b, *, out_dtype=None):
        bm, bk, bn = _blocks(decision)
        fn = _diff_quant_gemm(bm, bk, bn, _auto_interpret(), use_pallas,
                              out_dtype)
        return fn(a, b)
    return run


def _gemm_w8_backend(use_pallas: bool):
    def run(decision, a, w_q, w_scale, *, out_dtype=None):
        bm, bk, bn = _blocks(decision)
        fn = _diff_quant_gemm_w8(bm, bk, bn, _auto_interpret(), use_pallas,
                                 out_dtype)
        return fn(a, w_q, w_scale)
    return run


def _grouped_backend(use_pallas: bool):
    def run(decision, x, w, *, out_dtype=None):
        """x (E, C, D) @ w (E, D, F) per expert, each through the int8
        path.  E is static, so the trace-time loop stays O(E) kernels —
        same posture as the float grouped kernel's per-expert grid."""
        bm, bk, bn = _blocks(decision)
        fn = _diff_quant_gemm(bm, bk, bn, _auto_interpret(), use_pallas,
                              out_dtype or x.dtype)
        outs = [fn(x[e], w[e]) for e in range(x.shape[0])]
        return jnp.stack(outs, axis=0)
    return run


def register_into(registry) -> None:
    """Register the int8 execution plane: the Pallas backend
    ("pallas-tpu-int8", interpret auto-resolved off-TPU) and the XLA
    reference ("xla-int8")."""
    from repro.engine.backends import _xla_attention  # lazy: avoids cycle

    for name, use_pallas in (("pallas-tpu-int8", True), ("xla-int8", False)):
        registry.register(name, "gemm", _gemm_backend(use_pallas))
        registry.register(name, "gemm_w8", _gemm_w8_backend(use_pallas))
        registry.register(name, "grouped_gemm", _grouped_backend(use_pallas))
        # attention stays float (the KV cache has its own int8 codec);
        # registering the reference keeps the backend namespace total.
        registry.register(name, "attention", _xla_attention)
