# Plane-2 compute kernels (Pallas TPU): the ReDas-scheduled GEMM, the
# grouped (per-expert) GEMM, and flash attention.  Each module exposes
# `register_into(registry)` so the repro.engine KernelRegistry can bind
# them as the "pallas-tpu" / "pallas-interpret" backends.  All dispatch
# goes through repro.engine; the pre-engine `ops.py` surface is gone.
