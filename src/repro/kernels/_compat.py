"""jax version compatibility for Pallas TPU symbols.

jax renamed `pltpu.TPUCompilerParams` to `pltpu.CompilerParams` in 0.5;
off-TPU builds may lack the tpu module entirely (interpret mode ignores
compiler params, so callers treat None as "no params")."""

try:
    from jax.experimental.pallas import tpu as _pltpu
except ImportError:  # pragma: no cover
    _pltpu = None

if _pltpu is None:  # pragma: no cover
    CompilerParams = None
else:
    CompilerParams = getattr(_pltpu, "CompilerParams", None) or \
        _pltpu.TPUCompilerParams
