"""Sharded checkpointing: async save, atomic rename, resharding restore.

Fault-tolerance contract (DESIGN.md §4):
  * saves are step-granular and atomic (write to <dir>/tmp.<step>, then
    rename to <dir>/step_<step>) — a killed host never leaves a torn
    checkpoint visible;
  * `latest_step` picks the newest *complete* checkpoint, so `--resume
    auto` after N host failures restarts from the last good step;
  * restore is mesh-shape agnostic: arrays are loaded on host and
    `jax.device_put` with the *target* mesh's shardings — restarting on a
    different pod count (elastic scaling) reshards transparently;
  * saving runs on a background thread (training continues) with a
    join-on-next-save barrier so at most one save is in flight.

Format: one .npz per checkpoint keyed by pytree key-paths (portable,
dependency-free).  At real scale this becomes a per-host shard store;
the layering (async + atomic + reshard-on-restore) is the part that
carries over.
"""

from __future__ import annotations

import os
import re
import threading

import jax
import numpy as np

_SEP = "|"


def _flatten(state) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(state)[0]
    return {jax.tree_util.keystr(path): np.asarray(leaf)
            for path, leaf in flat}


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, state, blocking: bool = False) -> None:
        self.wait()  # at most one async save in flight
        host_state = jax.tree.map(lambda x: np.asarray(x), state)

        def _write():
            tmp = os.path.join(self.dir, f"tmp.{step}.npz")
            final = os.path.join(self.dir, f"step_{step:09d}.npz")
            with open(tmp, "wb") as f:
                np.savez(f, **_flatten(host_state))
            os.replace(tmp, final)
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            os.remove(os.path.join(self.dir, f"step_{s:09d}.npz"))

    # -- restore --------------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for f in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)\.npz", f)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like, shardings=None):
        """Rebuild the pytree `like` (values ignored, structure/dtype used).

        `shardings`: optional same-structure tree of jax.sharding.Sharding
        — arrays are device_put with them (resharding restore)."""
        path = os.path.join(self.dir, f"step_{step:09d}.npz")
        with np.load(path) as zf:
            flat_like = jax.tree_util.tree_flatten_with_path(like)
            leaves = []
            for keypath, leaf in flat_like[0]:
                arr = zf[jax.tree_util.keystr(keypath)]
                leaves.append(arr.astype(leaf.dtype))
        tree = jax.tree_util.tree_unflatten(flat_like[1], leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree


def resume_or_init(ckpt: Checkpointer, init_fn, shardings=None):
    """--resume auto: latest complete checkpoint, else fresh init."""
    step = ckpt.latest_step()
    if step is None:
        return 0, init_fn()
    like = jax.eval_shape(init_fn)
    return step, ckpt.restore(step, like, shardings)
