"""Dataflows and logical-shape enumeration for the ReDas systolic array.

Implements the paper's Eq. (1): for a physical array of R_p x C_p PEs
(assumed square, R_p == C_p), the roundabout data paths chain four
sub-arrays end-to-end, producing logical shapes

    0 < R_l <= R_p / 2,   C_l = 4 * (C_p - R_l)        (wide shapes)
    0 < C_l <= R_p / 2,   R_l = 4 * (R_p - C_l)        (tall shapes)
    R_l = R_p, C_l = C_p                               (native square)

A R_p x R_p array therefore supports exactly R_p + 1 logical shapes
(R_p/2 wide + R_p/2 tall + 1 native).  The paper's example: a 6x6 array
reshapes to {1x20, 20x1, 2x16, 16x2, 3x12, 12x3, 6x6} -- 7 shapes.

Reshaping granularity: the paper evaluates ReDas with granularity 4x4
(consistent with SARA); `enumerate_logical_shapes(..., granularity=g)`
restricts R_l (resp. C_l) to multiples of g.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Iterator


class Dataflow(str, enum.Enum):
    """The three systolic dataflows (paper Sec. 2.2).

    Each dataflow pins one operand (stationary) into the PE registers and
    streams the other two through the array edges:
      WS: weight (K x N) stationary; inputs stream, outputs accumulate out.
      OS: output (M x N) stationary; inputs and weights stream, partials
          accumulate in-place (no edge accumulators needed).
      IS: input (M x K) stationary; weights stream, outputs accumulate out.
    """

    WS = "ws"
    OS = "os"
    IS = "is"

    @property
    def stationary(self) -> str:
        return {Dataflow.WS: "weight", Dataflow.OS: "output", Dataflow.IS: "input"}[self]


ALL_DATAFLOWS = (Dataflow.OS, Dataflow.WS, Dataflow.IS)


@dataclasses.dataclass(frozen=True, order=True)
class LogicalShape:
    """A logical (rows x cols) view of the physical array.

    `bypass` is True when the shape differs from the physical square, i.e.
    the roundabout data path is active and Eq. (4)'s extra corner-turn
    cycles apply.
    """

    rows: int
    cols: int

    @property
    def n_pe(self) -> int:
        return self.rows * self.cols

    @property
    def is_square(self) -> bool:
        return self.rows == self.cols

    def transposed(self) -> "LogicalShape":
        return LogicalShape(self.cols, self.rows)

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return f"{self.rows}x{self.cols}"


def _check_physical(r_p: int, c_p: int) -> None:
    if r_p != c_p:
        raise ValueError(f"paper assumes a square physical array, got {r_p}x{c_p}")
    if r_p <= 0 or r_p % 2:
        raise ValueError(f"physical array side must be positive and even, got {r_p}")


def iter_logical_shapes(
    r_p: int, c_p: int | None = None, granularity: int = 1
) -> Iterator[LogicalShape]:
    """Yield every logical shape of Eq. (1) for an r_p x r_p physical array.

    Wide shapes first (R_l ascending), then tall, then the native square.
    With granularity g > 1 only R_l (C_l) that are multiples of g are kept,
    matching the paper's evaluated 4x4 reshaping granularity (Sec. 5.1).
    """
    c_p = r_p if c_p is None else c_p
    _check_physical(r_p, c_p)
    half = r_p // 2
    for r_l in range(granularity, half + 1, granularity):
        yield LogicalShape(r_l, 4 * (c_p - r_l))
    for c_l in range(granularity, half + 1, granularity):
        yield LogicalShape(4 * (r_p - c_l), c_l)
    yield LogicalShape(r_p, c_p)


def enumerate_logical_shapes(
    r_p: int, c_p: int | None = None, granularity: int = 1
) -> tuple[LogicalShape, ...]:
    return tuple(iter_logical_shapes(r_p, c_p, granularity))


def n_logical_shapes(r_p: int, granularity: int = 1) -> int:
    """Closed-form count: 2 * floor((R_p/2)/g) + 1 (== R_p + 1 when g == 1)."""
    return 2 * ((r_p // 2) // granularity) + 1


def bypass_cycles(shape: LogicalShape) -> int:
    """Extra roundabout corner-turn cycles of Eq. (4).

    4 * min(R_l, C_l) when reshaped (data turns 90 degrees at each of the
    four corners, min-side cycles per corner); 0 for the native square.
    """
    if shape.is_square:
        return 0
    return 4 * min(shape.rows, shape.cols)


def subarray_decomposition(shape: LogicalShape, r_p: int) -> tuple[tuple[int, int], int]:
    """Return ((R_s, C_s), n_subarrays) realizing `shape` on an r_p x r_p array.

    A wide logical shape R_l x 4*C_s is built by chaining 4 sub-arrays of
    R_s=R_l rows x C_s columns each (Sec. 3.2, Fig. 6/8); tall shapes are the
    transpose.  The native square is a single "sub-array" of the full array.
    Raises if the shape is not realizable on this physical array.
    """
    if shape.rows == r_p and shape.cols == r_p:
        return (r_p, r_p), 1
    if shape.rows <= r_p // 2 and shape.cols == 4 * (r_p - shape.rows):
        return (shape.rows, r_p - shape.rows), 4
    if shape.cols <= r_p // 2 and shape.rows == 4 * (r_p - shape.cols):
        return (r_p - shape.cols, shape.cols), 4
    raise ValueError(f"{shape} is not an Eq.(1) logical shape of a {r_p}x{r_p} array")


def pe_usage(shape: LogicalShape, r_p: int) -> float:
    """Fraction of physical PEs participating in this logical shape.

    Reshaped configurations occupy 4 sub-arrays of R_s x C_s PEs; the
    remaining PEs only forward roundabout traffic or idle (Sec. 3.2 notes
    the paths "may not use all the PEs").
    """
    (r_s, c_s), n = subarray_decomposition(shape, r_p)
    return (r_s * c_s * n) / float(r_p * r_p)


def tile_dims_for(dataflow: Dataflow, shape: LogicalShape) -> dict[str, int]:
    """Which GEMM tile dims are pinned by the logical array (Sec. 4.1).

    The mapper sets two of (M_t, K_t, N_t) equal to the logical dims; the
    third is free (bounded by buffer capacity):
      OS: output tile M_t x N_t lives on the array -> M_t=rows, N_t=cols, K free.
      WS: weight tile K_t x N_t lives on the array -> K_t=rows, N_t=cols, M free.
      IS: input  tile M_t x K_t lives on the array -> M_t=rows, K_t=cols, N free.
    """
    if dataflow == Dataflow.OS:
        return {"M_t": shape.rows, "N_t": shape.cols, "free": "K_t"}
    if dataflow == Dataflow.WS:
        return {"K_t": shape.rows, "N_t": shape.cols, "free": "M_t"}
    return {"M_t": shape.rows, "K_t": shape.cols, "free": "N_t"}
