"""Plane 1: the ReDas accelerator — dataflows/shapes (Eq. 1), the
Eq. 3-5 analytical model, the interval-sampling mapper (§4), the
cycle-level functional simulator, the Table-3 workload traces, the
energy/EDP/ADP model, and the plane-2 TPU v5e cost model."""

from .accelerators import REDAS, SPECS, TPU, AcceleratorSpec, make_specs
from .analytical_model import GEMM, LOOP_ORDERS, AnalyticalModel, MappingConfig
from .dataflow import Dataflow, LogicalShape, enumerate_logical_shapes
from .mapper import CandidateBatch, ReDasMapper
from .workloads import WORKLOADS, arch_gemms

__all__ = [
    "REDAS", "SPECS", "TPU", "AcceleratorSpec", "make_specs",
    "GEMM", "LOOP_ORDERS", "AnalyticalModel", "MappingConfig",
    "Dataflow", "LogicalShape", "enumerate_logical_shapes",
    "CandidateBatch", "ReDasMapper",
    "WORKLOADS", "arch_gemms",
]
