"""Functional cycle-level systolic-array simulator + roundabout geometry.

Two purposes (DESIGN.md Sec. 2):

1.  `simulate_gemm(a, b, dataflow, shape)` executes a logical R x C array
    cycle by cycle (`jax.lax.scan` over cycles, explicit per-PE register
    grids) for all three dataflows and returns (output, cycles).  The
    output must equal a @ b exactly and the cycle count must match the
    streaming term of Eq. 4 — this is the correctness oracle for the
    paper's claim that reshaped/multi-dataflow execution is functionally
    a GEMM.

2.  `pinwheel_decomposition(r_l, r_p)` produces the physical placement of
    a reshaped logical array: the four chained sub-arrays of Sec. 3.2
    occupy a pinwheel around the physical square (top / right / bottom /
    left strips), so every inter-PE hop on the roundabout path is between
    *adjacent* PEs (the paper's "internal connection manner", Fig. 7b),
    with only the center (R_p - 2*R_l)^2 PEs idle.  `roundabout_path`
    emits the per-hop physical route and the validator checks all hops
    are Manhattan-distance-1 — the lightweight-wiring claim.

Cycle-count conventions: the simulator counts cycles in which at least
one PE consumes streaming data; Eq. 4's streaming term (R + C + S - 1)
additionally counts the final writeback cycle, so
`cycles_sim == eq4_stream_term(dataflow, shape, tile) - 1`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .dataflow import Dataflow, LogicalShape


# ---------------------------------------------------------------------------
# Cycle-level dataflow simulation
# ---------------------------------------------------------------------------


def eq4_stream_term(dataflow: Dataflow, shape: LogicalShape, m: int, k: int, n: int) -> int:
    """The (R + C + streaming_dim - 1) pipeline term of Eq. 4."""
    r, c = shape.rows, shape.cols
    stream = {Dataflow.WS: m, Dataflow.OS: k, Dataflow.IS: n}[dataflow]
    return r + c + stream - 1


@functools.partial(jax.jit, static_argnums=(2, 3, 4))
def _simulate_os(a: jax.Array, b: jax.Array, r: int, c: int, k: int):
    """Output-stationary: C[i,j] accumulates in PE(i,j); A streams east
    from the west edge (row-skewed), B streams south from the north edge
    (column-skewed)."""
    n_cycles = r + c + k - 2
    row_idx = jnp.arange(r)
    col_idx = jnp.arange(c)

    def step(carry, t):
        a_reg, b_reg, acc = carry
        # west edge input: A[i, t - i], zero outside [0, K)
        ka = t - row_idx
        a_in = jnp.where((ka >= 0) & (ka < k), a[row_idx, jnp.clip(ka, 0, k - 1)], 0.0)
        # north edge input: B[t - j, j]
        kb = t - col_idx
        b_in = jnp.where((kb >= 0) & (kb < k), b[jnp.clip(kb, 0, k - 1), col_idx], 0.0)
        a_reg = jnp.concatenate([a_in[:, None], a_reg[:, :-1]], axis=1)
        b_reg = jnp.concatenate([b_in[None, :], b_reg[:-1, :]], axis=0)
        acc = acc + a_reg * b_reg
        return (a_reg, b_reg, acc), None

    init = (jnp.zeros((r, c)), jnp.zeros((r, c)), jnp.zeros((r, c)))
    (a_reg, b_reg, acc), _ = jax.lax.scan(step, init, jnp.arange(n_cycles))
    return acc, n_cycles


@functools.partial(jax.jit, static_argnums=(2, 3, 4))
def _simulate_ws(a: jax.Array, b: jax.Array, m: int, k: int, n: int):
    """Weight-stationary: B[k,n] preloaded at PE(k,n) (array is K x N);
    A streams east (element A[t - kk, kk] enters row kk), partial sums
    flow south and exit the bottom edge skewed by column."""
    n_cycles = m + k + n - 2
    row_idx = jnp.arange(k)
    col_idx = jnp.arange(n)

    def step(carry, t):
        a_reg, psum, out = carry
        ma = t - row_idx
        a_in = jnp.where((ma >= 0) & (ma < m), a[jnp.clip(ma, 0, m - 1), row_idx], 0.0)
        a_reg = jnp.concatenate([a_in[:, None], a_reg[:, :-1]], axis=1)
        psum = jnp.concatenate([jnp.zeros((1, n)), psum[:-1, :]], axis=0) + a_reg * b
        # bottom edge: psum[k-1, j] is output row (t - (k-1) - j), column j
        mo = t - (k - 1) - col_idx
        out = out.at[jnp.clip(mo, 0, m - 1), col_idx].add(
            jnp.where((mo >= 0) & (mo < m), psum[k - 1, :], 0.0))
        return (a_reg, psum, out), None

    init = (jnp.zeros((k, n)), jnp.zeros((k, n)), jnp.zeros((m, n)))
    (a_reg, psum, out), _ = jax.lax.scan(step, init, jnp.arange(n_cycles))
    return out, n_cycles


def simulate_gemm(a, b, dataflow: Dataflow, shape: LogicalShape | None = None):
    """Run one (M x K) @ (K x N) tile through the logical array.

    `shape` defaults to the exact array the tile needs (the caller tiles
    larger GEMMs; this simulates a single array pass, the unit of Eq. 4).
    Returns (output [M, N], cycles). Raises if the tile exceeds the array.
    """
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"GEMM dim mismatch: {a.shape} @ {b.shape}")

    if dataflow == Dataflow.OS:
        shape = shape or LogicalShape(m, n)
        if m > shape.rows or n > shape.cols:
            raise ValueError(f"OS tile {m}x{n} exceeds array {shape}")
        a_p = jnp.zeros((shape.rows, k)).at[:m, :].set(a)
        b_p = jnp.zeros((k, shape.cols)).at[:, :n].set(b)
        out, cycles = _simulate_os(a_p, b_p, shape.rows, shape.cols, k)
        return out[:m, :n], cycles
    if dataflow == Dataflow.WS:
        shape = shape or LogicalShape(k, n)
        if k > shape.rows or n > shape.cols:
            raise ValueError(f"WS tile K x N = {k}x{n} exceeds array {shape}")
        a_p = jnp.zeros((m, shape.rows)).at[:, :k].set(a)
        b_p = jnp.zeros((shape.rows, shape.cols)).at[:k, :n].set(b)
        out, cycles = _simulate_ws(a_p, b_p, m, shape.rows, shape.cols)
        return out[:, :n], cycles
    if dataflow == Dataflow.IS:
        # IS is WS on the transposed problem: O^T = B^T @ A^T with the
        # input matrix stationary (array holds A^T: K x M -> rows=M? no:
        # logical shape rows=M, cols=K holds A; streaming dim is N).
        shape = shape or LogicalShape(m, k)
        if m > shape.rows or k > shape.cols:
            raise ValueError(f"IS tile M x K = {m}x{k} exceeds array {shape}")
        out_t, cycles = simulate_gemm(
            b.T, a.T, Dataflow.WS, LogicalShape(shape.cols, shape.rows))
        return out_t.T, cycles
    raise ValueError(dataflow)


def simulate_gemm_batch(a, b, dataflow: Dataflow, shape: LogicalShape | None = None):
    """Batched `simulate_gemm`: run B same-shaped tiles through one
    vmapped cycle-level pass.

    `a` is [B, M, K], `b` is [B, K, N]; returns ([B, M, N], cycles).  The
    per-tile cycle count is identical across the batch (it depends only
    on the static tile dims), matching Eq. 4's single-tile T_exe — this
    is the execution backend `simulate_mapping` uses to validate a whole
    mapper decision in one shot instead of a Python loop over tiles.
    """
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    if a.ndim != 3 or b.ndim != 3 or a.shape[0] != b.shape[0]:
        raise ValueError(f"need [B,M,K] x [B,K,N], got {a.shape} x {b.shape}")
    _, m, k = a.shape
    _, k2, n = b.shape
    if k != k2:
        raise ValueError(f"GEMM dim mismatch: {a.shape} @ {b.shape}")

    if dataflow == Dataflow.OS:
        shape = shape or LogicalShape(m, n)
        if m > shape.rows or n > shape.cols:
            raise ValueError(f"OS tile {m}x{n} exceeds array {shape}")
        a_p = jnp.zeros((a.shape[0], shape.rows, k)).at[:, :m, :].set(a)
        b_p = jnp.zeros((b.shape[0], k, shape.cols)).at[:, :, :n].set(b)
        out, cycles = jax.vmap(
            lambda x, y: _simulate_os(x, y, shape.rows, shape.cols, k))(a_p, b_p)
        return out[:, :m, :n], int(cycles[0])
    if dataflow == Dataflow.WS:
        shape = shape or LogicalShape(k, n)
        if k > shape.rows or n > shape.cols:
            raise ValueError(f"WS tile K x N = {k}x{n} exceeds array {shape}")
        a_p = jnp.zeros((a.shape[0], m, shape.rows)).at[:, :, :k].set(a)
        b_p = jnp.zeros((b.shape[0], shape.rows, shape.cols)).at[:, :k, :n].set(b)
        out, cycles = jax.vmap(
            lambda x, y: _simulate_ws(x, y, m, shape.rows, shape.cols))(a_p, b_p)
        return out[:, :, :n], int(cycles[0])
    if dataflow == Dataflow.IS:
        shape = shape or LogicalShape(m, k)
        if m > shape.rows or k > shape.cols:
            raise ValueError(f"IS tile M x K = {m}x{k} exceeds array {shape}")
        out_t, cycles = simulate_gemm_batch(
            jnp.swapaxes(b, 1, 2), jnp.swapaxes(a, 1, 2), Dataflow.WS,
            LogicalShape(shape.cols, shape.rows))
        return jnp.swapaxes(out_t, 1, 2), cycles
    raise ValueError(dataflow)


def simulate_mapping(a, b, cfg):
    """Functionally execute a mapper-chosen `MappingConfig` end to end.

    Pads (M, K, N) up to tile multiples, carves A and B into the
    (m_t x k_t) / (k_t x n_t) tile grids, streams every (mi, ni, ki)
    tile triple through `simulate_gemm_batch` on the configured logical
    shape + dataflow, and reduces partials over the k grid — the
    functional counterpart of the analytical model's NUM_t tile loop.
    Returns (output [M, N], per_tile_cycles); output must equal a @ b.
    """
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"GEMM dim mismatch: {a.shape} @ {b.shape}")
    m_t, k_t, n_t = min(cfg.tile_m, m), min(cfg.tile_k, k), min(cfg.tile_n, n)
    gm, gk, gn = -(-m // m_t), -(-k // k_t), -(-n // n_t)
    a_p = jnp.zeros((gm * m_t, gk * k_t)).at[:m, :k].set(a)
    b_p = jnp.zeros((gk * k_t, gn * n_t)).at[:k, :n].set(b)
    # [gm, gk, m_t, k_t] / [gk, gn, k_t, n_t] tile grids
    a_tiles = a_p.reshape(gm, m_t, gk, k_t).transpose(0, 2, 1, 3)
    b_tiles = b_p.reshape(gk, k_t, gn, n_t).transpose(0, 2, 1, 3)
    a_all = jnp.broadcast_to(a_tiles[:, None], (gm, gn, gk, m_t, k_t))
    b_all = jnp.broadcast_to(b_tiles.transpose(1, 0, 2, 3)[None], (gm, gn, gk, k_t, n_t))
    out_tiles, cycles = simulate_gemm_batch(
        a_all.reshape(-1, m_t, k_t), b_all.reshape(-1, k_t, n_t),
        cfg.dataflow, cfg.shape)
    out_grid = out_tiles.reshape(gm, gn, gk, m_t, n_t).sum(axis=2)
    out = out_grid.transpose(0, 2, 1, 3).reshape(gm * m_t, gn * n_t)
    return out[:m, :n], cycles


# ---------------------------------------------------------------------------
# Roundabout geometry (pinwheel placement)
# ---------------------------------------------------------------------------


def pinwheel_decomposition(r_l: int, r_p: int) -> list[dict]:
    """Physical placement of the 4 chained sub-arrays for a wide logical
    shape R_l x 4*(R_p - R_l) on an R_p x R_p array (Sec. 3.2, Fig. 6).

    Returns 4 strips in chain order; each strip dict has:
      'coords': np.ndarray [R_l, C_s, 2] physical (row, col) per logical
                (local_row, local_col) position,
      'orientation': degrees the strip's streaming direction is rotated.
    """
    if not (0 < r_l <= r_p // 2):
        raise ValueError(f"need 0 < R_l <= R_p/2, got R_l={r_l}, R_p={r_p}")
    c_s = r_p - r_l
    rows, cols = np.meshgrid(np.arange(r_l), np.arange(c_s), indexing="ij")

    def strip(pr, pc, orientation):
        return {"coords": np.stack([pr, pc], axis=-1), "orientation": orientation}

    # chain order A (top, ->E), B (right, ->S), C (bottom, ->W), D (left, ->N)
    return [
        strip(rows, cols, 0),                                  # top strip
        strip(cols, r_p - 1 - rows, 90),                       # right strip
        strip(r_p - 1 - rows, r_p - 1 - cols, 180),            # bottom strip
        strip(r_p - 1 - cols, rows, 270),                      # left strip
    ]


def logical_to_physical(r_l: int, r_p: int) -> np.ndarray:
    """Map logical (row, col) of the R_l x 4*C_s shape -> physical (row, col).

    Logical columns [s*C_s, (s+1)*C_s) live on strip s; the chain runs
    A->B->C->D so data leaving strip s's last column enters strip s+1's
    first column after a 90-degree corner turn.
    """
    strips = pinwheel_decomposition(r_l, r_p)
    c_s = r_p - r_l
    out = np.zeros((r_l, 4 * c_s, 2), dtype=np.int64)
    for s, st in enumerate(strips):
        out[:, s * c_s:(s + 1) * c_s, :] = st["coords"]
    return out


def _l_route(start: tuple[int, int], end: tuple[int, int], primary: str) -> list[tuple[int, int]]:
    """L-shaped walk from `start` to `end` (exclusive of start, inclusive of
    end) moving first along `primary` ('row' or 'col'), then the other."""
    path = []
    r, c = start
    er, ec = end
    order = ("col", "row") if primary == "col" else ("row", "col")
    for axis in order:
        while (c != ec if axis == "col" else r != er):
            if axis == "col":
                c += 1 if ec > c else -1
            else:
                r += 1 if er > r else -1
            path.append((r, c))
    return path


def roundabout_ring(r_l: int, r_p: int, lane: int) -> tuple[np.ndarray, list[int]]:
    """The closed physical route streaming data of logical row `lane` takes:
    4 strips + 4 corner transits.  Returns (path [steps, 2], corner_hops).

    Corner transits pass through PEs belonging to other lanes' logical
    positions in pass-through mode (Sec. 3.4: a PE can simultaneously MAC
    and forward roundabout traffic).  Each corner costs exactly R_l hops —
    the 4 * R_l bypass term of Eq. 4.
    """
    mapping = logical_to_physical(r_l, r_p)
    c_s = r_p - r_l
    # strip flow axes: top: east (col), right: south (row),
    # bottom: west (col), left: north (row)
    primary = ("col", "row", "col", "row")
    path: list[tuple[int, int]] = []
    corner_hops: list[int] = []
    for s in range(4):
        seg = mapping[lane, s * c_s:(s + 1) * c_s]
        path.extend(map(tuple, seg.tolist()))
        nxt = tuple(mapping[lane, ((s + 1) * c_s) % (4 * c_s)].tolist())
        corner = _l_route(tuple(seg[-1].tolist()), nxt, primary[s])
        corner_hops.append(len(corner))
        path.extend(corner[:-1])  # next strip's first cell re-added next loop
    return np.asarray(path, dtype=np.int64), corner_hops


def validate_roundabout(r_l: int, r_p: int) -> dict:
    """Check the lightweight-wiring claims; returns stats, raises on violation.

    * placement is injective (no PE used twice) and covers exactly
      R_l * C_l == R_p^2 - (R_p - 2*R_l)^2 PEs (center square idles);
    * every hop of every lane's full ring (strips + corner transits) is
      between Manhattan-adjacent PEs — the "internal connection manner"
      uses neighbor links only (Fig. 7b);
    * each of the 4 corner transits costs exactly R_l hops, and the ring
      closes — Eq. 4's 4*R_l bypass term.
    """
    mapping = logical_to_physical(r_l, r_p)
    flat = mapping.reshape(-1, 2)
    seen = {tuple(p) for p in flat.tolist()}
    if len(seen) != flat.shape[0]:
        raise AssertionError(f"pinwheel placement not injective for R_l={r_l}, R_p={r_p}")
    expected = r_p * r_p - (r_p - 2 * r_l) ** 2
    if flat.shape[0] != expected:
        raise AssertionError(f"used {flat.shape[0]} PEs, expected {expected}")
    for lane in range(r_l):
        ring, corner_hops = roundabout_ring(r_l, r_p, lane)
        closed = np.vstack([ring, ring[:1]])
        dist = np.abs(np.diff(closed, axis=0)).sum(axis=1)
        if not np.all(dist == 1):
            bad = int(np.argmax(dist != 1))
            raise AssertionError(
                f"non-adjacent hop lane={lane} step {bad}: {closed[bad]} -> {closed[bad + 1]}")
        if any(h != r_l for h in corner_hops):
            raise AssertionError(
                f"lane {lane}: corner hops {corner_hops}, expected 4 x {r_l}")
    return {
        "used_pes": flat.shape[0],
        "idle_pes": (r_p - 2 * r_l) ** 2,
        "bypass_hops_per_lane": 4 * r_l,
    }
