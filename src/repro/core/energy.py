"""Energy / power-efficiency / EDP / ADP model (paper Sec. 5.3-5.7).

Constants are calibrated against the paper's synthesis results:
  * Table 5 (ReDas, ResNet-50 inference): PE-array energy 5.21 mJ of which
    MACs 1.29 mJ, original muxes/regs 1.61 mJ, additional muxes/regs
    2.31 mJ  ->  per-MAC dynamic energy 1.29 mJ / ~2.05 GMAC = 0.63 pJ and
    a ReDas PE-overhead ratio of (1.61+2.31)/1.29 = 2.79 x MAC energy
    (TPU-like PEs carry only the original 1.61/1.29 = 1.25 x).
  * Sec. 5.4: SRAM access energy — ReDas distributed buffer 4.19 pJ/B,
    TPU concentrated buffer 3.92 pJ/B; SARA/DyNNamic multi-ported SRAMs
    cost 2-2.5x more per access (Fig. 4 trend).
  * Sec. 5.4: off-chip HBM2 13.31 pJ/B.
  * Fig. 4: buffer leakage 56 mW (single-port 1 MB) to 580 mW (SARA).
  * Fig. 13 / Table 5: die areas — ReDas 20.77 mm^2 (TPU +35.3%),
    SARA ~76.9 mm^2 (ReDas is ~27% of SARA), DyNNamic ~35.5 mm^2.

Energy accounting per model inference:
  E = MACs * mac_pj * (1 + overhead_ratio)
    + SRAM_bytes * sram_pj + DRAM_bytes * dram_pj
    + vector_elements * simd_pj + leak_w * runtime.
"""

from __future__ import annotations

import dataclasses

from .accelerators import AcceleratorSpec
from .mapper import ModelMapping

SIMD_PJ_PER_ELEMENT = 1.8   # NN-LUT SIMD op energy (int8 lane, 28 nm)
SIMD_LANES = 4 * 64         # 4 SIMD vector units x 64 lanes (Sec. 3.1)


@dataclasses.dataclass(frozen=True)
class EnergyReport:
    runtime_s: float
    energy_j: float
    mac_j: float
    sram_j: float
    dram_j: float
    simd_j: float
    leak_j: float

    @property
    def power_w(self) -> float:
        return self.energy_j / self.runtime_s if self.runtime_s else 0.0

    @property
    def edp(self) -> float:
        return self.energy_j * self.runtime_s

    def adp(self, area_mm2: float) -> float:
        return area_mm2 * self.runtime_s

    def power_efficiency(self, flops: float) -> float:
        """Throughput per watt: FLOP/s / W == FLOP / J."""
        return flops / self.energy_j if self.energy_j else 0.0


def vector_cycles(vector_elements: int) -> float:
    """SIMD time for the non-GEMM layers; the PE array and SIMD units work
    in a pipeline (Sec. 3.1), so only a fraction is exposed — Fig. 15 shows
    0.1-6.9%; we expose 50% of SIMD time as non-overlapped."""
    return 0.5 * vector_elements / SIMD_LANES


def model_energy(
    spec: AcceleratorSpec,
    mapping: ModelMapping,
    vector_elements: int = 0,
    array_size: int | None = None,
) -> EnergyReport:
    size = array_size or spec.array_size
    scale = (size * size) / float(spec.array_size * spec.array_size)
    gemm_cycles = mapping.total_cycles
    total_cycles = gemm_cycles + vector_cycles(vector_elements)
    runtime = total_cycles / spec.freq_hz

    mac_j = mapping.total_macs * spec.mac_pj * (1.0 + spec.pe_overhead_ratio) * 1e-12
    sram_j = mapping.total_sram_bytes * spec.sram_pj_per_byte * 1e-12
    dram_j = mapping.total_dram_bytes * spec.dram_pj_per_byte * 1e-12
    simd_j = vector_elements * SIMD_PJ_PER_ELEMENT * 1e-12
    leak_j = spec.leak_w * scale * runtime
    return EnergyReport(
        runtime_s=runtime,
        energy_j=mac_j + sram_j + dram_j + simd_j + leak_j,
        mac_j=mac_j, sram_j=sram_j, dram_j=dram_j, simd_j=simd_j, leak_j=leak_j,
    )
