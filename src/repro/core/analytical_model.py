"""ReDas analytical performance model (paper Sec. 4.2, Eq. 3-5).

Estimates cycles / DRAM traffic / SRAM traffic / PE utilization for one
GEMM workload under a concrete (hardware config x GEMM mapping) candidate.

    T_total = T_start + NUM_t * max(T_exe, T_rd&wt) + T_end          (Eq. 3)

with the double-buffered (ping-pong) overlap of compute and DRAM.  Our
implementation evaluates the per-operand DRAM traffic with a closed-form
loop-nest reuse model (equivalent to the paper's "reuse-sensitive tile
access sequence" for uniform traffic) and uses

    T_mid = max(NUM_t * T_exe, total_dram_cycles)

which equals Eq. 3's sum-of-maxes when traffic is uniform across
iterations and is a tight lower bound otherwise; the difference is
second-order and documented in DESIGN.md.

T_exe (Eq. 4) is dataflow-specific.  The paper prints the WS version; OS
replaces the preload term with an output-drain term and streams K_t, IS
streams N_t:

    WS: min(R,C) + (R + C + M_t - 1) + bypass
    OS:            (R + C + K_t - 1) + min(R,C) + bypass
    IS: min(R,C) + (R + C + N_t - 1) + bypass

where bypass = 4*min(R,C) when the logical shape differs from the
physical square (roundabout corner turns), else 0 (Sec. 4.2).

The DRAM access-time functions T_r / T_w (Eq. 5) use the paper's
linear-interpolation-over-prerecorded-latency approach: effective
bandwidth ramps with DMA transaction size.

Every piece of the model (reuse walk, DRAM ramp, Eq. 4 pipeline terms,
Eq. 3 assembly) is written as a *shape-polymorphic* NumPy kernel: the
same code evaluates one candidate (0-d arrays, the scalar oracle used by
`AnalyticalModel.estimate`) or a flat tensor of thousands of candidates
(`AnalyticalModel.estimate_batch`, the mapper's vectorized search
engine).  Scalar and batched paths therefore agree bit-for-bit; the
batched path is what makes full-model mapping cheap enough for compile
time (DESIGN.md §Batched search engine).
"""

from __future__ import annotations

import dataclasses
import math
from functools import lru_cache

import numpy as np

from .dataflow import Dataflow, LogicalShape, bypass_cycles

# Canonical loop-order vocabulary (outermost -> innermost over 'mkn').
# Batched candidates refer to orders by index into this tuple.
LOOP_ORDERS: tuple[str, ...] = ("mnk", "mkn", "nmk", "nkm", "kmn", "knm")

# ---------------------------------------------------------------------------
# Workload and mapping-candidate descriptions
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GEMM:
    """One GEMM workload: (M x K) @ (K x N), `count` back-to-back instances.

    `name` is a human label ("resnet50/conv2_1/im2col"), `count` collapses
    repeated identical GEMMs (e.g. the 8 gate matmuls of an LSTM step x
    timesteps) so model evaluation stays O(#distinct shapes).
    """

    M: int
    K: int
    N: int
    count: int = 1
    name: str = ""

    @property
    def macs(self) -> int:
        return self.M * self.K * self.N * self.count

    @property
    def flops(self) -> int:
        return 2 * self.macs

    def __post_init__(self):
        if min(self.M, self.K, self.N, self.count) < 1:
            raise ValueError(f"degenerate GEMM {self}")


@dataclasses.dataclass(frozen=True)
class MappingConfig:
    """One point of the ReDas search space (Fig. 10).

    Hardware configuration: dataflow + logical shape + buffer allocation.
    GEMM mapping: tile size + loop order (outermost->innermost over 'mkn').
    `alloc` = SRAM capacity fractions for (input A, weight B, output O)
    buffers; sum <= 1 (Eq. 2 generalized to the whole multi-mode SRAM).
    """

    dataflow: Dataflow
    shape: LogicalShape
    tile_m: int
    tile_k: int
    tile_n: int
    loop_order: str = "mnk"
    alloc: tuple[float, float, float] = (0.3, 0.3, 0.4)

    def __post_init__(self):
        if sorted(self.loop_order) != ["k", "m", "n"]:
            raise ValueError(f"loop_order must be a permutation of 'mkn': {self.loop_order}")
        if min(self.tile_m, self.tile_k, self.tile_n) < 1:
            raise ValueError("tile dims must be >= 1")
        if sum(self.alloc) > 1.0 + 1e-9:
            raise ValueError(f"buffer over-allocated: {self.alloc}")


@dataclasses.dataclass(frozen=True)
class CostReport:
    """Everything the mapper / energy model / benchmarks need."""

    cycles: float
    compute_cycles: float
    dram_cycles: float
    start_cycles: float
    end_cycles: float
    config_cycles: float
    bypass_cycles_total: float
    num_tiles: int
    macs: int
    dram_read_bytes: float
    dram_write_bytes: float
    sram_bytes: float
    pe_utilization: float  # MACs / (cycles * physical PEs)
    valid: bool = True
    reason: str = ""

    @property
    def dram_bytes(self) -> float:
        return self.dram_read_bytes + self.dram_write_bytes


INVALID = lambda reason: CostReport(  # noqa: E731 - compact sentinel factory
    cycles=math.inf, compute_cycles=math.inf, dram_cycles=math.inf,
    start_cycles=0, end_cycles=0, config_cycles=0, bypass_cycles_total=0,
    num_tiles=0, macs=0, dram_read_bytes=0, dram_write_bytes=0, sram_bytes=0,
    pe_utilization=0.0, valid=False, reason=reason)


# ---------------------------------------------------------------------------
# DRAM model: T_r(s) / T_w(s) by linear interpolation over a prerecorded
# efficiency table (Sec. 4.2 "approximation method").
# ---------------------------------------------------------------------------

# (transaction bytes, fraction of peak bandwidth actually achieved).
# Shape of the curve follows DRAMsim3-style measurements: small DMA
# transactions are dominated by row activation / command overhead.
_DRAM_EFFICIENCY_TABLE: tuple[tuple[float, float], ...] = (
    (64.0, 0.05),
    (256.0, 0.15),
    (1024.0, 0.31),
    (4096.0, 0.55),
    (16384.0, 0.76),
    (65536.0, 0.89),
    (262144.0, 0.95),
    (1048576.0, 0.97),
    (4194304.0, 0.985),
)
_DRAM_FIXED_LATENCY_CYCLES = 64.0  # CAS + controller queue at 700 MHz

_DRAM_X = np.array([p[0] for p in _DRAM_EFFICIENCY_TABLE])
_DRAM_Y = np.array([p[1] for p in _DRAM_EFFICIENCY_TABLE])


def dram_efficiency(nbytes):
    """Piecewise-linear interpolation of effective-bandwidth fraction.

    Shape-polymorphic: accepts a scalar or an ndarray of transaction
    sizes (clamped to the table's ends, exact at the knots).
    """
    x = np.clip(np.asarray(nbytes, dtype=np.float64), _DRAM_X[0], _DRAM_X[-1])
    i = np.clip(np.searchsorted(_DRAM_X, x, side="right") - 1, 0, len(_DRAM_X) - 2)
    x0, y0 = _DRAM_X[i], _DRAM_Y[i]
    t = (x - x0) / (_DRAM_X[i + 1] - x0)
    out = y0 + t * (_DRAM_Y[i + 1] - y0)
    return float(out) if np.ndim(nbytes) == 0 else out


def dram_access_cycles(nbytes, peak_bytes_per_cycle: float):
    """T_r(s) == T_w(s): fixed latency + size / effective bandwidth.

    Shape-polymorphic like `dram_efficiency` (0 cycles for empty bursts).
    """
    cyc = _DRAM_FIXED_LATENCY_CYCLES + np.asarray(nbytes, dtype=np.float64) / (
        peak_bytes_per_cycle * dram_efficiency(nbytes))
    out = np.where(np.asarray(nbytes) <= 0, 0.0, cyc)
    return float(out) if np.ndim(nbytes) == 0 else out


# ---------------------------------------------------------------------------
# Closed-form loop-nest reuse model
# ---------------------------------------------------------------------------


def operand_fetch_count(loop_order: str, trips_m, trips_k, trips_n,
                        index_dims: frozenset[str], capacity_tiles):
    """How many tile-granularity DRAM fetches operand X needs.

    Walking the 3-deep loop nest from innermost outward: a loop over a dim
    d NOT indexing X reuses the buffered working set iff every distinct X
    tile touched by the loops inner to d fits in X's buffer allocation;
    otherwise each trip of d re-fetches them.  Dims in `index_dims` always
    multiply (they address distinct tiles).  Matches an exhaustive LRU walk
    for all 6 orders (tested in tests/test_analytical_model.py).

    Shape-polymorphic kernel: `trips_*` / `capacity_tiles` are ints (the
    scalar oracle) or equal-shape int arrays (one element per candidate
    sharing `loop_order`).  Returns -1 where the buffer cannot hold one
    tile (invalid mapping).
    """
    trips = {"m": trips_m, "k": trips_k, "n": trips_n}
    cap = np.asarray(capacity_tiles, dtype=np.int64)
    fetches = np.ones_like(cap)
    working_set = np.ones_like(cap)  # distinct X tiles touched inner to current
    for dim in reversed(loop_order):  # innermost -> outermost
        n = np.asarray(trips[dim], dtype=np.int64)
        if dim in index_dims:
            fetches = fetches * n
            working_set = working_set * n
        else:
            # overflow -> no reuse across this loop: refetch per trip;
            # else full reuse across this loop, counts unchanged.
            fetches = np.where(working_set > cap, fetches * n, fetches)
    return np.where(cap < 1, -1, fetches)


def output_k_reuse(loop_order: str, trips_m, trips_k, trips_n, capacity_tiles):
    """True where each output tile's K-reduction completes without HBM spills.

    The output tile (m, n) is revisited across the k loop; partials stay
    on chip iff all distinct output tiles touched by loops inner to k fit
    in the output buffer (OS keeps them in the PE array itself: the
    capacity check still gates the *buffer-side* accumulators for tails).
    Shape-polymorphic like `operand_fetch_count`.
    """
    trips = {"m": trips_m, "k": trips_k, "n": trips_n}
    cap = np.asarray(capacity_tiles, dtype=np.int64)
    working_set = np.ones_like(cap)
    for dim in reversed(loop_order):
        if dim == "k":
            return (working_set <= cap) & (cap >= 1)
        working_set = working_set * np.asarray(trips[dim], dtype=np.int64)
    raise AssertionError("k not in loop order")


def _operand_fetch_count(loop_order: str, trips: dict[str, int],
                         index_dims: frozenset[str], capacity_tiles: int) -> int:
    """Scalar view of `operand_fetch_count` (the oracle-path entry)."""
    return int(operand_fetch_count(loop_order, trips["m"], trips["k"],
                                   trips["n"], index_dims, capacity_tiles))


def _output_k_reuse(loop_order: str, trips: dict[str, int], capacity_tiles: int) -> bool:
    """Scalar view of `output_k_reuse` (the oracle-path entry)."""
    return bool(output_k_reuse(loop_order, trips["m"], trips["k"],
                               trips["n"], capacity_tiles))


# ---------------------------------------------------------------------------
# Per-dataflow T_exe (Eq. 4 family)
# ---------------------------------------------------------------------------


def tile_exe_cycles(cfg: MappingConfig, eff_m: int, eff_k: int, eff_n: int) -> float:
    """Cycles for the array to process one tile (Eq. 4, per dataflow).

    eff_* are the tile dims actually used (tail tiles are smaller, but the
    array still sweeps its pipeline; we charge the configured logical
    dims for ramp terms and the streaming dim's effective length).
    """
    r, c = cfg.shape.rows, cfg.shape.cols
    byp = bypass_cycles(cfg.shape)
    ramp = r + c - 1
    if cfg.dataflow == Dataflow.WS:
        return min(r, c) + (ramp + eff_m) + byp
    if cfg.dataflow == Dataflow.OS:
        return (ramp + eff_k) + min(r, c) + byp
    return min(r, c) + (ramp + eff_n) + byp  # IS


# ---------------------------------------------------------------------------
# The model
# ---------------------------------------------------------------------------


@lru_cache(maxsize=200_000)
def _estimate_cached(gemm: GEMM, cfg: MappingConfig, hw_key: tuple) -> CostReport:
    (r_p, sram_bytes, word_bytes, peak_bpc, config_cycles, bypass_enabled,
     setup_floor) = hw_key

    # --- tile legality -----------------------------------------------------
    m_t = min(cfg.tile_m, gemm.M)
    k_t = min(cfg.tile_k, gemm.K)
    n_t = min(cfg.tile_n, gemm.N)

    s_i = m_t * k_t * word_bytes  # input tile bytes
    s_w = k_t * n_t * word_bytes  # weight tile bytes
    s_o = m_t * n_t * word_bytes  # output tile bytes

    # Ping-pong double buffering halves usable capacity per operand (Eq. 2).
    cap_a = int(cfg.alloc[0] * sram_bytes / 2)
    cap_b = int(cfg.alloc[1] * sram_bytes / 2)
    cap_o = int(cfg.alloc[2] * sram_bytes / 2)
    if s_i > cap_a or s_w > cap_b or s_o > cap_o:
        return INVALID(
            f"tile does not fit buffers: S_i={s_i}/{cap_a} S_w={s_w}/{cap_b} S_o={s_o}/{cap_o}")

    trips = {  # exact integer ceil-div, shared convention with estimate_batch
        "m": -(-gemm.M // m_t),
        "k": -(-gemm.K // k_t),
        "n": -(-gemm.N // n_t),
    }
    num_t = trips["m"] * trips["k"] * trips["n"]

    # --- DRAM traffic via loop-nest reuse (per single GEMM instance) -------
    fetches_a = _operand_fetch_count(cfg.loop_order, trips, frozenset("mk"), cap_a // max(s_i, 1))
    fetches_b = _operand_fetch_count(cfg.loop_order, trips, frozenset("kn"), cap_b // max(s_w, 1))
    if fetches_a < 0 or fetches_b < 0:
        return INVALID("operand buffer cannot hold one tile")
    out_tiles = trips["m"] * trips["n"]
    k_on_chip = _output_k_reuse(cfg.loop_order, trips, cap_o // max(s_o, 1))
    if k_on_chip:
        writes_o, reads_o = out_tiles, 0
    else:
        # partial sums round-trip through DRAM once per k sweep
        writes_o = out_tiles * trips["k"]
        reads_o = out_tiles * (trips["k"] - 1)

    t_r_i = dram_access_cycles(s_i, peak_bpc)
    t_r_w = dram_access_cycles(s_w, peak_bpc)
    t_io_o = dram_access_cycles(s_o, peak_bpc)
    dram_cycles = (fetches_a * t_r_i + fetches_b * t_r_w + (writes_o + reads_o) * t_io_o)
    dram_read_bytes = fetches_a * s_i + fetches_b * s_w + reads_o * s_o
    dram_write_bytes = writes_o * s_o

    # --- compute time ------------------------------------------------------
    t_exe = tile_exe_cycles(cfg, m_t, k_t, n_t)
    if not bypass_enabled and not cfg.shape.is_square:
        # accelerators without roundabout paths pay no bypass (they cannot
        # reshape at all -- their shape space already excludes this).
        t_exe -= bypass_cycles(cfg.shape)
    compute_cycles = num_t * t_exe

    # --- Eq. 3 assembly (per instance) --------------------------------------
    t_start = max(t_r_i + t_r_w, float(max(config_cycles, setup_floor)))
    t_end = t_io_o
    t_mid = max(compute_cycles, dram_cycles)
    cycles_one = t_start + t_mid + t_end
    cycles = cycles_one * gemm.count

    # SRAM traffic: every tile execution streams its operands through the
    # multi-mode buffers; DRAM-side fills/spills add their own port traffic.
    sram_stream = num_t * (s_i + s_w) + (writes_o + reads_o) * s_o
    sram_bytes_total = (sram_stream + dram_read_bytes + dram_write_bytes) * gemm.count

    macs = gemm.macs
    util = macs / (cycles * r_p * r_p) if cycles > 0 else 0.0
    byp_total = (bypass_cycles(cfg.shape) if bypass_enabled else 0) * num_t * gemm.count

    return CostReport(
        cycles=cycles,
        compute_cycles=compute_cycles * gemm.count,
        dram_cycles=dram_cycles * gemm.count,
        start_cycles=t_start * gemm.count,
        end_cycles=t_end * gemm.count,
        config_cycles=float(config_cycles * gemm.count),
        bypass_cycles_total=float(byp_total),
        num_tiles=num_t * gemm.count,
        macs=macs,
        dram_read_bytes=dram_read_bytes * gemm.count,
        dram_write_bytes=dram_write_bytes * gemm.count,
        sram_bytes=sram_bytes_total,
        pe_utilization=util,
    )


class AnalyticalModel:
    """Eq. 3-5 evaluator bound to one accelerator's hardware constants."""

    def __init__(
        self,
        *,
        array_size: int = 128,
        sram_bytes: int = 4 * 2**20,
        word_bytes: int = 1,  # int8 (Table 4)
        freq_hz: float = 700e6,
        dram_bw_bytes_per_s: float = 256e9,
        config_cycles: int = 128,
        bypass_enabled: bool = True,
        setup_floor: int = 0,
    ):
        self.array_size = array_size
        self.sram_bytes = sram_bytes
        self.word_bytes = word_bytes
        self.freq_hz = freq_hz
        self.peak_bytes_per_cycle = dram_bw_bytes_per_s / freq_hz
        self.config_cycles = config_cycles
        self.bypass_enabled = bypass_enabled
        self.setup_floor = setup_floor

    def _hw_key(self) -> tuple:
        return (
            self.array_size, self.sram_bytes, self.word_bytes,
            self.peak_bytes_per_cycle, self.config_cycles,
            self.bypass_enabled, self.setup_floor,
        )

    def estimate(self, gemm: GEMM, cfg: MappingConfig) -> CostReport:
        """Full Eq. 3 cost of `gemm` under mapping `cfg`."""
        return _estimate_cached(gemm, cfg, self._hw_key())

    def estimate_batch(
        self,
        gemm: GEMM,
        *,
        rows: np.ndarray,
        cols: np.ndarray,
        tile_m: np.ndarray,
        tile_k: np.ndarray,
        tile_n: np.ndarray,
        order_ids: np.ndarray,
        stream_dims: np.ndarray,
        alloc: np.ndarray,
    ) -> dict[str, np.ndarray]:
        """Eq. 3 cost of `gemm` under a flat tensor of mapping candidates.

        All per-candidate columns are equal-length arrays: logical shape
        (`rows`/`cols`), raw tile sizes, loop order as an index into
        LOOP_ORDERS, the Eq. 4 streaming dimension (`stream_dims`:
        0 -> M_t, 1 -> K_t, 2 -> N_t, derived from the dataflow), and
        `alloc` as an [n, 3] fraction table.  Runs the same shape-
        polymorphic kernels as the scalar path, so for any candidate
        ``cycles[i]`` equals ``estimate(gemm, cfg_i).cycles`` bit-for-bit
        (invalid candidates get +inf).  Returns a dict of arrays:
        cycles / valid / compute_cycles / dram_cycles / num_tiles.
        """
        rows = np.asarray(rows, dtype=np.int64)
        cols = np.asarray(cols, dtype=np.int64)
        order_ids = np.asarray(order_ids)
        alloc = np.asarray(alloc, dtype=np.float64)

        # --- tile legality (mirrors _estimate_cached line for line) --------
        m_t = np.minimum(np.asarray(tile_m, dtype=np.int64), gemm.M)
        k_t = np.minimum(np.asarray(tile_k, dtype=np.int64), gemm.K)
        n_t = np.minimum(np.asarray(tile_n, dtype=np.int64), gemm.N)

        s_i = m_t * k_t * self.word_bytes
        s_w = k_t * n_t * self.word_bytes
        s_o = m_t * n_t * self.word_bytes

        cap_a = np.floor(alloc[:, 0] * self.sram_bytes / 2).astype(np.int64)
        cap_b = np.floor(alloc[:, 1] * self.sram_bytes / 2).astype(np.int64)
        cap_o = np.floor(alloc[:, 2] * self.sram_bytes / 2).astype(np.int64)
        fits = (s_i <= cap_a) & (s_w <= cap_b) & (s_o <= cap_o)

        trips_m = -(-gemm.M // m_t)
        trips_k = -(-gemm.K // k_t)
        trips_n = -(-gemm.N // n_t)
        num_t = trips_m * trips_k * trips_n

        # --- DRAM traffic via the shared reuse kernels, grouped by order ---
        cap_ta = cap_a // np.maximum(s_i, 1)
        cap_tb = cap_b // np.maximum(s_w, 1)
        cap_to = cap_o // np.maximum(s_o, 1)
        fetches_a = np.empty_like(num_t)
        fetches_b = np.empty_like(num_t)
        k_on_chip = np.empty(num_t.shape, dtype=bool)
        for oid in np.unique(order_ids):
            sel = order_ids == oid
            order = LOOP_ORDERS[int(oid)]
            tm, tk, tn = trips_m[sel], trips_k[sel], trips_n[sel]
            fetches_a[sel] = operand_fetch_count(
                order, tm, tk, tn, frozenset("mk"), cap_ta[sel])
            fetches_b[sel] = operand_fetch_count(
                order, tm, tk, tn, frozenset("kn"), cap_tb[sel])
            k_on_chip[sel] = output_k_reuse(order, tm, tk, tn, cap_to[sel])
        valid = fits & (fetches_a >= 0) & (fetches_b >= 0)

        out_tiles = trips_m * trips_n
        writes_o = np.where(k_on_chip, out_tiles, out_tiles * trips_k)
        reads_o = np.where(k_on_chip, 0, out_tiles * (trips_k - 1))

        peak = self.peak_bytes_per_cycle
        t_r_i = dram_access_cycles(s_i, peak)
        t_r_w = dram_access_cycles(s_w, peak)
        t_io_o = dram_access_cycles(s_o, peak)
        dram_cycles = (fetches_a * t_r_i + fetches_b * t_r_w
                       + (writes_o + reads_o) * t_io_o)

        # --- compute time: Eq. 4 with the dataflow's streaming dim ---------
        byp = np.where(rows == cols, 0,
                       4 * np.minimum(rows, cols)) if self.bypass_enabled else 0
        eff = np.where(stream_dims == 0, m_t,
                       np.where(stream_dims == 1, k_t, n_t))
        t_exe = (np.minimum(rows, cols) + (rows + cols - 1) + eff
                 + byp).astype(np.float64)
        compute_cycles = num_t * t_exe

        # --- Eq. 3 assembly (x count, like the scalar path) ----------------
        t_start = np.maximum(t_r_i + t_r_w,
                             float(max(self.config_cycles, self.setup_floor)))
        t_mid = np.maximum(compute_cycles, dram_cycles)
        cycles_one = t_start + t_mid + t_io_o
        cycles = np.where(valid, cycles_one * gemm.count, np.inf)
        return {
            "cycles": cycles,
            "valid": valid,
            "compute_cycles": compute_cycles * gemm.count,
            "dram_cycles": dram_cycles * gemm.count,
            "num_tiles": num_t * gemm.count,
        }

    def seconds(self, report: CostReport) -> float:
        return report.cycles / self.freq_hz
