"""ReDas analytical performance model (paper Sec. 4.2, Eq. 3-5).

Estimates cycles / DRAM traffic / SRAM traffic / PE utilization for one
GEMM workload under a concrete (hardware config x GEMM mapping) candidate.

    T_total = T_start + NUM_t * max(T_exe, T_rd&wt) + T_end          (Eq. 3)

with the double-buffered (ping-pong) overlap of compute and DRAM.  Our
implementation evaluates the per-operand DRAM traffic with a closed-form
loop-nest reuse model (equivalent to the paper's "reuse-sensitive tile
access sequence" for uniform traffic) and uses

    T_mid = max(NUM_t * T_exe, total_dram_cycles)

which equals Eq. 3's sum-of-maxes when traffic is uniform across
iterations and is a tight lower bound otherwise; the difference is
second-order and documented in DESIGN.md.

T_exe (Eq. 4) is dataflow-specific.  The paper prints the WS version; OS
replaces the preload term with an output-drain term and streams K_t, IS
streams N_t:

    WS: min(R,C) + (R + C + M_t - 1) + bypass
    OS:            (R + C + K_t - 1) + min(R,C) + bypass
    IS: min(R,C) + (R + C + N_t - 1) + bypass

where bypass = 4*min(R,C) when the logical shape differs from the
physical square (roundabout corner turns), else 0 (Sec. 4.2).

The DRAM access-time functions T_r / T_w (Eq. 5) use the paper's
linear-interpolation-over-prerecorded-latency approach: effective
bandwidth ramps with DMA transaction size.
"""

from __future__ import annotations

import dataclasses
import math
from functools import lru_cache

from .dataflow import Dataflow, LogicalShape, bypass_cycles

# ---------------------------------------------------------------------------
# Workload and mapping-candidate descriptions
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GEMM:
    """One GEMM workload: (M x K) @ (K x N), `count` back-to-back instances.

    `name` is a human label ("resnet50/conv2_1/im2col"), `count` collapses
    repeated identical GEMMs (e.g. the 8 gate matmuls of an LSTM step x
    timesteps) so model evaluation stays O(#distinct shapes).
    """

    M: int
    K: int
    N: int
    count: int = 1
    name: str = ""

    @property
    def macs(self) -> int:
        return self.M * self.K * self.N * self.count

    @property
    def flops(self) -> int:
        return 2 * self.macs

    def __post_init__(self):
        if min(self.M, self.K, self.N, self.count) < 1:
            raise ValueError(f"degenerate GEMM {self}")


@dataclasses.dataclass(frozen=True)
class MappingConfig:
    """One point of the ReDas search space (Fig. 10).

    Hardware configuration: dataflow + logical shape + buffer allocation.
    GEMM mapping: tile size + loop order (outermost->innermost over 'mkn').
    `alloc` = SRAM capacity fractions for (input A, weight B, output O)
    buffers; sum <= 1 (Eq. 2 generalized to the whole multi-mode SRAM).
    """

    dataflow: Dataflow
    shape: LogicalShape
    tile_m: int
    tile_k: int
    tile_n: int
    loop_order: str = "mnk"
    alloc: tuple[float, float, float] = (0.3, 0.3, 0.4)

    def __post_init__(self):
        if sorted(self.loop_order) != ["k", "m", "n"]:
            raise ValueError(f"loop_order must be a permutation of 'mkn': {self.loop_order}")
        if min(self.tile_m, self.tile_k, self.tile_n) < 1:
            raise ValueError("tile dims must be >= 1")
        if sum(self.alloc) > 1.0 + 1e-9:
            raise ValueError(f"buffer over-allocated: {self.alloc}")


@dataclasses.dataclass(frozen=True)
class CostReport:
    """Everything the mapper / energy model / benchmarks need."""

    cycles: float
    compute_cycles: float
    dram_cycles: float
    start_cycles: float
    end_cycles: float
    config_cycles: float
    bypass_cycles_total: float
    num_tiles: int
    macs: int
    dram_read_bytes: float
    dram_write_bytes: float
    sram_bytes: float
    pe_utilization: float  # MACs / (cycles * physical PEs)
    valid: bool = True
    reason: str = ""

    @property
    def dram_bytes(self) -> float:
        return self.dram_read_bytes + self.dram_write_bytes


INVALID = lambda reason: CostReport(  # noqa: E731 - compact sentinel factory
    cycles=math.inf, compute_cycles=math.inf, dram_cycles=math.inf,
    start_cycles=0, end_cycles=0, config_cycles=0, bypass_cycles_total=0,
    num_tiles=0, macs=0, dram_read_bytes=0, dram_write_bytes=0, sram_bytes=0,
    pe_utilization=0.0, valid=False, reason=reason)


# ---------------------------------------------------------------------------
# DRAM model: T_r(s) / T_w(s) by linear interpolation over a prerecorded
# efficiency table (Sec. 4.2 "approximation method").
# ---------------------------------------------------------------------------

# (transaction bytes, fraction of peak bandwidth actually achieved).
# Shape of the curve follows DRAMsim3-style measurements: small DMA
# transactions are dominated by row activation / command overhead.
_DRAM_EFFICIENCY_TABLE: tuple[tuple[float, float], ...] = (
    (64.0, 0.05),
    (256.0, 0.15),
    (1024.0, 0.31),
    (4096.0, 0.55),
    (16384.0, 0.76),
    (65536.0, 0.89),
    (262144.0, 0.95),
    (1048576.0, 0.97),
    (4194304.0, 0.985),
)
_DRAM_FIXED_LATENCY_CYCLES = 64.0  # CAS + controller queue at 700 MHz


def dram_efficiency(nbytes: float) -> float:
    """Piecewise-linear interpolation of effective-bandwidth fraction."""
    table = _DRAM_EFFICIENCY_TABLE
    if nbytes <= table[0][0]:
        return table[0][1]
    if nbytes >= table[-1][0]:
        return table[-1][1]
    for (x0, y0), (x1, y1) in zip(table, table[1:]):
        if x0 <= nbytes <= x1:
            t = (nbytes - x0) / (x1 - x0)
            return y0 + t * (y1 - y0)
    raise AssertionError("unreachable")


def dram_access_cycles(nbytes: float, peak_bytes_per_cycle: float) -> float:
    """T_r(s) == T_w(s): fixed latency + size / effective bandwidth."""
    if nbytes <= 0:
        return 0.0
    return _DRAM_FIXED_LATENCY_CYCLES + nbytes / (peak_bytes_per_cycle * dram_efficiency(nbytes))


# ---------------------------------------------------------------------------
# Closed-form loop-nest reuse model
# ---------------------------------------------------------------------------


def _operand_fetch_count(
    loop_order: str,
    trips: dict[str, int],
    index_dims: frozenset[str],
    capacity_tiles: int,
) -> int:
    """How many tile-granularity DRAM fetches operand X needs.

    Walking the 3-deep loop nest from innermost outward: a loop over a dim
    d NOT indexing X reuses the buffered working set iff every distinct X
    tile touched by the loops inner to d fits in X's buffer allocation;
    otherwise each trip of d re-fetches them.  Dims in `index_dims` always
    multiply (they address distinct tiles).  Matches an exhaustive LRU walk
    for all 6 orders (tested in tests/test_analytical_model.py).
    """
    if capacity_tiles < 1:
        return -1  # cannot even hold one tile -> invalid mapping
    fetches = 1
    working_set = 1  # distinct X tiles touched by loops inner to current
    for dim in reversed(loop_order):  # innermost -> outermost
        n = trips[dim]
        if dim in index_dims:
            fetches *= n
            working_set *= n
        else:
            if working_set > capacity_tiles:
                fetches *= n  # no reuse across this loop: refetch per trip
            # else: full reuse across this loop; counts unchanged
    return fetches


def _output_k_reuse(loop_order: str, trips: dict[str, int], capacity_tiles: int) -> bool:
    """True if each output tile's K-reduction completes without HBM spills.

    The output tile (m, n) is revisited across the k loop; partials stay
    on chip iff all distinct output tiles touched by loops inner to k fit
    in the output buffer (OS keeps them in the PE array itself: the
    capacity check still gates the *buffer-side* accumulators for tails).
    """
    if capacity_tiles < 1:
        return False
    working_set = 1
    for dim in reversed(loop_order):
        if dim == "k":
            return working_set <= capacity_tiles
        working_set *= trips[dim]
    raise AssertionError("k not in loop order")


# ---------------------------------------------------------------------------
# Per-dataflow T_exe (Eq. 4 family)
# ---------------------------------------------------------------------------


def tile_exe_cycles(cfg: MappingConfig, eff_m: int, eff_k: int, eff_n: int) -> float:
    """Cycles for the array to process one tile (Eq. 4, per dataflow).

    eff_* are the tile dims actually used (tail tiles are smaller, but the
    array still sweeps its pipeline; we charge the configured logical
    dims for ramp terms and the streaming dim's effective length).
    """
    r, c = cfg.shape.rows, cfg.shape.cols
    byp = bypass_cycles(cfg.shape)
    ramp = r + c - 1
    if cfg.dataflow == Dataflow.WS:
        return min(r, c) + (ramp + eff_m) + byp
    if cfg.dataflow == Dataflow.OS:
        return (ramp + eff_k) + min(r, c) + byp
    return min(r, c) + (ramp + eff_n) + byp  # IS


# ---------------------------------------------------------------------------
# The model
# ---------------------------------------------------------------------------


@lru_cache(maxsize=200_000)
def _estimate_cached(gemm: GEMM, cfg: MappingConfig, hw_key: tuple) -> CostReport:
    (r_p, sram_bytes, word_bytes, peak_bpc, config_cycles, bypass_enabled,
     setup_floor) = hw_key

    # --- tile legality -----------------------------------------------------
    m_t = min(cfg.tile_m, gemm.M)
    k_t = min(cfg.tile_k, gemm.K)
    n_t = min(cfg.tile_n, gemm.N)

    s_i = m_t * k_t * word_bytes  # input tile bytes
    s_w = k_t * n_t * word_bytes  # weight tile bytes
    s_o = m_t * n_t * word_bytes  # output tile bytes

    # Ping-pong double buffering halves usable capacity per operand (Eq. 2).
    cap_a = int(cfg.alloc[0] * sram_bytes / 2)
    cap_b = int(cfg.alloc[1] * sram_bytes / 2)
    cap_o = int(cfg.alloc[2] * sram_bytes / 2)
    if s_i > cap_a or s_w > cap_b or s_o > cap_o:
        return INVALID(
            f"tile does not fit buffers: S_i={s_i}/{cap_a} S_w={s_w}/{cap_b} S_o={s_o}/{cap_o}")

    trips = {
        "m": math.ceil(gemm.M / m_t),
        "k": math.ceil(gemm.K / k_t),
        "n": math.ceil(gemm.N / n_t),
    }
    num_t = trips["m"] * trips["k"] * trips["n"]

    # --- DRAM traffic via loop-nest reuse (per single GEMM instance) -------
    fetches_a = _operand_fetch_count(cfg.loop_order, trips, frozenset("mk"), cap_a // max(s_i, 1))
    fetches_b = _operand_fetch_count(cfg.loop_order, trips, frozenset("kn"), cap_b // max(s_w, 1))
    if fetches_a < 0 or fetches_b < 0:
        return INVALID("operand buffer cannot hold one tile")
    out_tiles = trips["m"] * trips["n"]
    k_on_chip = _output_k_reuse(cfg.loop_order, trips, cap_o // max(s_o, 1))
    if k_on_chip:
        writes_o, reads_o = out_tiles, 0
    else:
        # partial sums round-trip through DRAM once per k sweep
        writes_o = out_tiles * trips["k"]
        reads_o = out_tiles * (trips["k"] - 1)

    t_r_i = dram_access_cycles(s_i, peak_bpc)
    t_r_w = dram_access_cycles(s_w, peak_bpc)
    t_io_o = dram_access_cycles(s_o, peak_bpc)
    dram_cycles = (fetches_a * t_r_i + fetches_b * t_r_w + (writes_o + reads_o) * t_io_o)
    dram_read_bytes = fetches_a * s_i + fetches_b * s_w + reads_o * s_o
    dram_write_bytes = writes_o * s_o

    # --- compute time ------------------------------------------------------
    t_exe = tile_exe_cycles(cfg, m_t, k_t, n_t)
    if not bypass_enabled and not cfg.shape.is_square:
        # accelerators without roundabout paths pay no bypass (they cannot
        # reshape at all -- their shape space already excludes this).
        t_exe -= bypass_cycles(cfg.shape)
    compute_cycles = num_t * t_exe

    # --- Eq. 3 assembly (per instance) --------------------------------------
    t_start = max(t_r_i + t_r_w, float(max(config_cycles, setup_floor)))
    t_end = t_io_o
    t_mid = max(compute_cycles, dram_cycles)
    cycles_one = t_start + t_mid + t_end
    cycles = cycles_one * gemm.count

    # SRAM traffic: every tile execution streams its operands through the
    # multi-mode buffers; DRAM-side fills/spills add their own port traffic.
    sram_stream = num_t * (s_i + s_w) + (writes_o + reads_o) * s_o
    sram_bytes_total = (sram_stream + dram_read_bytes + dram_write_bytes) * gemm.count

    macs = gemm.macs
    util = macs / (cycles * r_p * r_p) if cycles > 0 else 0.0
    byp_total = (bypass_cycles(cfg.shape) if bypass_enabled else 0) * num_t * gemm.count

    return CostReport(
        cycles=cycles,
        compute_cycles=compute_cycles * gemm.count,
        dram_cycles=dram_cycles * gemm.count,
        start_cycles=t_start * gemm.count,
        end_cycles=t_end * gemm.count,
        config_cycles=float(config_cycles * gemm.count),
        bypass_cycles_total=float(byp_total),
        num_tiles=num_t * gemm.count,
        macs=macs,
        dram_read_bytes=dram_read_bytes * gemm.count,
        dram_write_bytes=dram_write_bytes * gemm.count,
        sram_bytes=sram_bytes_total,
        pe_utilization=util,
    )


class AnalyticalModel:
    """Eq. 3-5 evaluator bound to one accelerator's hardware constants."""

    def __init__(
        self,
        *,
        array_size: int = 128,
        sram_bytes: int = 4 * 2**20,
        word_bytes: int = 1,  # int8 (Table 4)
        freq_hz: float = 700e6,
        dram_bw_bytes_per_s: float = 256e9,
        config_cycles: int = 128,
        bypass_enabled: bool = True,
        setup_floor: int = 0,
    ):
        self.array_size = array_size
        self.sram_bytes = sram_bytes
        self.word_bytes = word_bytes
        self.freq_hz = freq_hz
        self.peak_bytes_per_cycle = dram_bw_bytes_per_s / freq_hz
        self.config_cycles = config_cycles
        self.bypass_enabled = bypass_enabled
        self.setup_floor = setup_floor

    def _hw_key(self) -> tuple:
        return (
            self.array_size, self.sram_bytes, self.word_bytes,
            self.peak_bytes_per_cycle, self.config_cycles,
            self.bypass_enabled, self.setup_floor,
        )

    def estimate(self, gemm: GEMM, cfg: MappingConfig) -> CostReport:
        """Full Eq. 3 cost of `gemm` under mapping `cfg`."""
        return _estimate_cached(gemm, cfg, self._hw_key())

    def seconds(self, report: CostReport) -> float:
        return report.cycles / self.freq_hz
