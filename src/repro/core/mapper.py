"""ReDas Mapper (paper Sec. 4): configuration + mapping search per GEMM.

Pipeline per GEMM workload (Fig. 10):
  1. search-space generator — hardware configs (logical shape x dataflow x
     buffer allocation) x GEMM mappings (tile size x loop order);
  2. analytical model (core.analytical_model) estimates runtime;
  3. interval sampling engine prunes the space from ~10^10 raw points to
     ~2k candidates (paper: 1923 avg for ResNet-50) with 0.1-2% loss.

Interval sampling concretely:
  * the free tile dimension (the one not pinned by the logical shape,
    Sec. 4.1) is sampled geometrically + the two boundary points
    (whole-dim, max-that-fits) instead of every legal integer;
  * buffer allocations are sampled on a coarse simplex grid (interval 0.2)
    instead of every bank split;
  * loop orders are derived from the dataflow (the order that keeps the
    stationary operand resident and finishes output reductions on-chip)
    instead of all 6 permutations — matching "ReDas Mapper generates loop
    nests based on the tile size and buffer allocation" (Sec. 4.3);
  * repeated GEMM shapes reuse the previous decision (decision cache).

`space_size()` reports the un-pruned cardinality for the Fig. 19
brute-force comparison.

Search execution (vectorized by default): the pruned candidate set is
enumerated once into flat NumPy columns (`CandidateBatch`, exactly the
order `candidates()` yields) and evaluated in one
`AnalyticalModel.estimate_batch` call + argmin — no per-candidate Python
loop.  The scalar loop survives behind ``vectorized=False`` as the
reference oracle; both paths share the analytical-model kernels, so they
pick identical mappings (tested by tests/test_batched_mapper.py, gated
at 0.1% by benchmarks/bench.py in CI).
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator

import numpy as np

from .accelerators import AcceleratorSpec
from .analytical_model import LOOP_ORDERS, CostReport, GEMM, MappingConfig
from .dataflow import Dataflow, LogicalShape, tile_dims_for

# Simplex grid of (input, weight, output) SRAM fractions at interval 0.2.
ALLOC_CANDIDATES: tuple[tuple[float, float, float], ...] = (
    (0.2, 0.2, 0.6),
    (0.2, 0.4, 0.4),
    (0.4, 0.2, 0.4),
    (0.4, 0.4, 0.2),
    (0.6, 0.2, 0.2),
    (0.2, 0.6, 0.2),
)

# Loop orders derived per dataflow (outermost -> innermost).  Keeping the
# reduction (k) innermost finishes each output tile on-chip; the stationary
# operand's free dim is placed innermost-but-one so its tile is revisited.
_DERIVED_ORDERS: dict[Dataflow, tuple[str, ...]] = {
    Dataflow.OS: ("mnk", "nmk"),
    Dataflow.WS: ("nmk", "nkm"),
    Dataflow.IS: ("mnk", "mkn"),
}

ALL_ORDERS = LOOP_ORDERS

# Eq. 4 streaming dimension per dataflow: 0 -> M_t, 1 -> K_t, 2 -> N_t.
_STREAM_DIM = {Dataflow.WS: 0, Dataflow.OS: 1, Dataflow.IS: 2}


@dataclasses.dataclass(frozen=True)
class CandidateBatch:
    """The pruned search space of one GEMM as flat columns (one row per
    candidate, in exactly the order `ReDasMapper.candidates()` yields so
    argmin tie-breaking matches the scalar first-strict-min loop)."""

    dataflows: tuple[Dataflow, ...]   # decode table for `df`
    df: np.ndarray                    # index into `dataflows`
    rows: np.ndarray
    cols: np.ndarray
    tile_m: np.ndarray
    tile_k: np.ndarray
    tile_n: np.ndarray
    order_ids: np.ndarray             # index into LOOP_ORDERS
    alloc_ids: np.ndarray             # index into ALLOC_CANDIDATES

    def __len__(self) -> int:
        return self.df.shape[0]

    def config(self, i: int) -> MappingConfig:
        """Materialize row `i` as a MappingConfig."""
        return MappingConfig(
            dataflow=self.dataflows[int(self.df[i])],
            shape=LogicalShape(int(self.rows[i]), int(self.cols[i])),
            tile_m=int(self.tile_m[i]),
            tile_k=int(self.tile_k[i]),
            tile_n=int(self.tile_n[i]),
            loop_order=LOOP_ORDERS[int(self.order_ids[i])],
            alloc=ALLOC_CANDIDATES[int(self.alloc_ids[i])],
        )


@dataclasses.dataclass(frozen=True)
class MappingDecision:
    gemm: GEMM
    config: MappingConfig
    report: CostReport
    candidates_evaluated: int = 0


@dataclasses.dataclass
class ModelMapping:
    """Aggregated mapping of a whole DNN (a sequence of GEMMs)."""

    decisions: list[MappingDecision]

    @property
    def total_cycles(self) -> float:
        return sum(d.report.cycles for d in self.decisions)

    @property
    def total_macs(self) -> int:
        return sum(d.report.macs for d in self.decisions)

    @property
    def total_dram_bytes(self) -> float:
        return sum(d.report.dram_bytes for d in self.decisions)

    @property
    def total_sram_bytes(self) -> float:
        return sum(d.report.sram_bytes for d in self.decisions)

    @property
    def total_config_cycles(self) -> float:
        return sum(d.report.config_cycles for d in self.decisions)

    @property
    def total_bypass_cycles(self) -> float:
        return sum(d.report.bypass_cycles_total for d in self.decisions)

    def pe_utilization(self, array_size: int) -> float:
        t = self.total_cycles
        return self.total_macs / (t * array_size * array_size) if t else 0.0


def _geometric_samples(lo: int, hi: int, *, ratio: float = 2.0) -> list[int]:
    """lo, lo*r, lo*r^2, ... capped at hi; always includes hi."""
    if hi <= lo:
        return [max(hi, 1)]
    out, v = [], float(lo)
    while v < hi:
        out.append(int(round(v)))
        v *= ratio
    out.append(hi)
    return sorted(set(out))


class ReDasMapper:
    """Search engine bound to one accelerator spec (works for baselines too:
    their spec's `shapes`/`dataflows` restrict the space, which is exactly
    how the paper constructs fair baseline mappings, Sec. 5.1)."""

    def __init__(
        self,
        spec: AcceleratorSpec,
        *,
        array_size: int | None = None,
        mode: str = "interval",  # "interval" | "exhaustive-orders"
        free_dim_ratio: float = 2.0,
        max_free_dim: int | None = None,
        vectorized: bool = True,
    ):
        """max_free_dim bounds the un-pinned tile dimension.  Our default
        (None) lets the fixed baseline stream the whole free dim, which
        makes it input-bandwidth-optimal on big-M GEMMs; bounding it
        models baselines that re-preload per tile (the sensitivity study
        behind EXPERIMENTS.md §Paper-validation's magnitude analysis).

        vectorized=False drops to the per-candidate scalar loop — the
        reference oracle the batched engine is gated against."""
        self.spec = spec
        self.array_size = array_size or spec.array_size
        self.model = spec.model(self.array_size)
        self.shapes = spec.shapes_for(self.array_size)
        self.mode = mode
        self.free_dim_ratio = free_dim_ratio
        self.max_free_dim = max_free_dim
        self.vectorized = vectorized
        self._decision_cache: dict[tuple[int, int, int], MappingDecision] = {}

    # -- search space ------------------------------------------------------

    def _free_dim_candidates(self, gemm: GEMM, dataflow: Dataflow,
                             shape: LogicalShape) -> tuple[str, list[int]]:
        dims = tile_dims_for(dataflow, shape)
        free = dims["free"]
        workload = {"M_t": gemm.M, "K_t": gemm.K, "N_t": gemm.N}[free]
        if self.max_free_dim is not None:
            workload = min(workload, self.max_free_dim)
        # interval sampling: geometric ladder from the array side upward
        lo = min(self.array_size, workload)
        return free, _geometric_samples(lo, workload, ratio=self.free_dim_ratio)

    def candidates(self, gemm: GEMM) -> Iterator[MappingConfig]:
        for dataflow in self.spec.dataflows:
            orders = (_DERIVED_ORDERS[dataflow] if self.mode == "interval" else ALL_ORDERS)
            for shape in self.shapes:
                dims = tile_dims_for(dataflow, shape)
                free, free_vals = self._free_dim_candidates(gemm, dataflow, shape)
                for fv in free_vals:
                    sizes = dict(dims)
                    sizes[free] = fv
                    tile_m = sizes.get("M_t", fv if free == "M_t" else None)
                    tile_k = sizes.get("K_t", fv if free == "K_t" else None)
                    tile_n = sizes.get("N_t", fv if free == "N_t" else None)
                    for order in orders:
                        for alloc in ALLOC_CANDIDATES:
                            yield MappingConfig(
                                dataflow=dataflow,
                                shape=shape,
                                tile_m=int(tile_m), tile_k=int(tile_k), tile_n=int(tile_n),
                                loop_order=order,
                                alloc=alloc,
                            )

    def candidate_batch(self, gemm: GEMM) -> CandidateBatch:
        """The same pruned space as `candidates()`, as flat columns.

        Row order matches the generator's nesting exactly — dataflow >
        shape > free-dim value > loop order > buffer allocation — so a
        first-occurrence argmin reproduces the scalar loop's choice.
        Built one dataflow at a time with whole-column repeat/tile ops
        (which tile dim is free depends only on the dataflow, Sec. 4.1).
        """
        dfs = tuple(self.spec.dataflows)
        n_a = len(ALLOC_CANDIDATES)
        alloc_pat = np.arange(n_a, dtype=np.int8)
        cols_out: dict[str, list[np.ndarray]] = {
            k: [] for k in ("df", "rows", "cols", "tile_m", "tile_k",
                            "tile_n", "order_ids", "alloc_ids")}
        for di, dataflow in enumerate(dfs):
            orders = (_DERIVED_ORDERS[dataflow] if self.mode == "interval"
                      else ALL_ORDERS)
            oids = np.asarray([LOOP_ORDERS.index(o) for o in orders], np.int8)
            block = len(orders) * n_a  # inner (order x alloc) pattern
            pat_order = np.repeat(oids, n_a)
            pat_alloc = np.tile(alloc_pat, len(orders))
            fv_parts, shape_rows, shape_cols, counts = [], [], [], []
            for shape in self.shapes:
                _, free_vals = self._free_dim_candidates(gemm, dataflow, shape)
                fv_parts.append(np.asarray(free_vals, np.int64))
                shape_rows.append(shape.rows)
                shape_cols.append(shape.cols)
                counts.append(len(free_vals))
            fv = np.concatenate(fv_parts)          # one row per (shape, fv)
            counts = np.asarray(counts)
            rows = np.repeat(np.asarray(shape_rows, np.int64), counts)
            cols = np.repeat(np.asarray(shape_cols, np.int64), counts)
            n_fv = fv.shape[0]
            fv_col = np.repeat(fv, block)
            rows_col = np.repeat(rows, block)
            cols_col = np.repeat(cols, block)
            if dataflow == Dataflow.OS:    # M_t=rows, N_t=cols, K free
                tm, tk, tn = rows_col, fv_col, cols_col
            elif dataflow == Dataflow.WS:  # K_t=rows, N_t=cols, M free
                tm, tk, tn = fv_col, rows_col, cols_col
            else:                          # IS: M_t=rows, K_t=cols, N free
                tm, tk, tn = rows_col, cols_col, fv_col
            cols_out["df"].append(np.full(n_fv * block, di, np.int8))
            cols_out["rows"].append(rows_col)
            cols_out["cols"].append(cols_col)
            cols_out["tile_m"].append(tm)
            cols_out["tile_k"].append(tk)
            cols_out["tile_n"].append(tn)
            cols_out["order_ids"].append(np.tile(pat_order, n_fv))
            cols_out["alloc_ids"].append(np.tile(pat_alloc, n_fv))
        return CandidateBatch(
            dataflows=dfs,
            **{k: np.concatenate(v) for k, v in cols_out.items()})

    def _search_batched(self, gemm: GEMM) -> tuple[MappingConfig, int]:
        """Evaluate the whole candidate tensor at once; first-min argmin
        reproduces the scalar loop's strict-improvement tie-breaking."""
        batch = self.candidate_batch(gemm)
        stream = np.asarray([_STREAM_DIM[d] for d in batch.dataflows],
                            np.int8)[batch.df]
        alloc = np.asarray(ALLOC_CANDIDATES, np.float64)[batch.alloc_ids]
        res = self.model.estimate_batch(
            gemm, rows=batch.rows, cols=batch.cols, tile_m=batch.tile_m,
            tile_k=batch.tile_k, tile_n=batch.tile_n,
            order_ids=batch.order_ids, stream_dims=stream, alloc=alloc)
        best = int(np.argmin(res["cycles"]))
        if not np.isfinite(res["cycles"][best]):
            raise RuntimeError(f"no valid mapping found for {gemm} on {self.spec.name}")
        return batch.config(best), len(batch)

    def space_size(self, gemm: GEMM) -> int:
        """Un-pruned cardinality (Fig. 19's brute-force space): every legal
        free-dim integer x every 1-word buffer split x all 6 orders."""
        total = 0
        d_phy = 4096  # words per bank (Sec. 4.1)
        for dataflow in self.spec.dataflows:
            for shape in self.shapes:
                free = tile_dims_for(dataflow, shape)["free"]
                workload = {"M_t": gemm.M, "K_t": gemm.K, "N_t": gemm.N}[free]
                # free dim (all integers) x D_sta/D_non splits per Eq.2 x orders
                total += workload * (d_phy * (d_phy + 1) // 2) * len(ALL_ORDERS)
        return total

    # -- search --------------------------------------------------------------

    def map_gemm(self, gemm: GEMM) -> MappingDecision:
        key = (gemm.M, gemm.K, gemm.N)
        hit = self._decision_cache.get(key)
        if hit is not None:
            # repeated shape: reuse previous choice (Sec. 4.3), re-costed at
            # this GEMM's count (estimate() is lru-cached, so this is free).
            rep = self.model.estimate(gemm, hit.config)
            return MappingDecision(gemm, hit.config, rep, candidates_evaluated=0)

        base = dataclasses.replace(gemm, count=1)
        if self.vectorized:
            best_cfg, n_eval = self._search_batched(base)
            best_rep = self.model.estimate(base, best_cfg)
        else:
            best_cfg, best_rep, n_eval = None, None, 0
            for cfg in self.candidates(base):
                rep = self.model.estimate(base, cfg)
                n_eval += 1
                if rep.valid and (best_rep is None or rep.cycles < best_rep.cycles):
                    best_cfg, best_rep = cfg, rep
            if best_cfg is None:
                raise RuntimeError(f"no valid mapping found for {gemm} on {self.spec.name}")
        unit = MappingDecision(base, best_cfg, best_rep, n_eval)
        self._decision_cache[key] = unit
        if gemm.count == 1:
            return dataclasses.replace(unit, gemm=gemm)
        scaled = self.model.estimate(gemm, best_cfg)
        return MappingDecision(gemm, best_cfg, scaled, n_eval)

    def map_model(self, gemms: Iterable[GEMM]) -> ModelMapping:
        return ModelMapping([self.map_gemm(g) for g in gemms])


def fixed_baseline_decision(
    spec: AcceleratorSpec, gemm: GEMM, *, array_size: int | None = None
) -> MappingDecision:
    """The conventional fixed-config mapping (Fig. 3 'Fixed'): native square
    shape, WS dataflow, default tiles/alloc — no search at all."""
    size = array_size or spec.array_size
    model = spec.model(size)
    shape = LogicalShape(size, size)
    best = None
    for free_m in _geometric_samples(size, max(gemm.M, 1)):
        cfg = MappingConfig(
            dataflow=Dataflow.WS, shape=shape,
            tile_m=free_m, tile_k=min(size, gemm.K), tile_n=min(size, gemm.N),
            loop_order="nmk", alloc=(0.4, 0.2, 0.4),
        )
        rep = model.estimate(gemm, cfg)
        if rep.valid and (best is None or rep.cycles < best.report.cycles):
            best = MappingDecision(gemm, cfg, rep)
    assert best is not None
    return best
