"""GEMM traces of the paper's eight benchmark DNNs (Table 3).

Every DNN layer is lowered to GEMMs exactly as Sec. 2.1 describes:
  * CONV2D        -> im2col: M = OH*OW, K = kh*kw*Cin, N = Cout
  * depth-wise    -> diagonalwise refactorization / filter gathering [27]:
                     the k x k filter is vectorized, channels become array
                     columns: M = OH*OW, K = kh*kw, N = C (this is why DW
                     utilization is low on fixed arrays, Sec. 5.5)
  * FC / proj     -> plain GEMM (matrix-vector for batch-1 inference)
  * LSTM          -> 8 matrix-vector products per step (Sec. 2.1); we fold
                     the 4 gates into (1, H_in, 4H) / (1, H, 4H) GEMMs with
                     `count` = timesteps (x2 for bidirectional)
  * MHA           -> QKV/proj GEMMs + per-head score/context GEMMs

Exact proprietary traces from the paper are unavailable; these are
reconstructed from the cited model definitions (ResNet-50 [20],
EfficientNet-B0 [10], TinyYOLO-V2, FasterRCNN, ViT-B/32, BERT-Large,
GNMT, DeepSpeech2) at MLPerf-style inference batch 1.  The headline GEMMs
the paper quotes are reproduced exactly: ResNet-50's (49,2048,512) and
(12544,147,64) with 21 distinct shapes, TinyYOLO-V2 layer 2 =
(43264, 144, 32) [quoted (M,N,K)-ordered as (43264,32,144) in Fig. 22],
ViT FFNs (50,768,3072)/(50,3072,768), BERT (128,1024,4096) family.

`vector_elements` approximates the non-GEMM (ReLU/softmax/pool/norm)
element traffic feeding Fig. 15's activation-time slice.
"""

from __future__ import annotations

import dataclasses

from .analytical_model import GEMM


@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    abbr: str
    domain: str
    gemms: tuple[GEMM, ...]
    vector_elements: int = 0

    @property
    def total_macs(self) -> int:
        return sum(g.macs for g in self.gemms)

    @property
    def n_layers(self) -> int:
        return len(self.gemms)


def _conv(oh_ow: int, kh_kw_cin: int, cout: int, name: str, count: int = 1) -> GEMM:
    return GEMM(M=oh_ow, K=kh_kw_cin, N=cout, count=count, name=name)


# ---------------------------------------------------------------------------
# ResNet-50 @ 224x224, batch 1  (54 conv/fc layers)
# ---------------------------------------------------------------------------

def _resnet50() -> Workload:
    g: list[GEMM] = [_conv(112 * 112, 7 * 7 * 3, 64, "conv1")]
    # (stage, spatial, in_c, mid_c, out_c, blocks)
    stages = (
        ("conv2", 56 * 56, 64, 64, 256, 3),
        ("conv3", 28 * 28, 256, 128, 512, 4),
        ("conv4", 14 * 14, 512, 256, 1024, 6),
        ("conv5", 7 * 7, 1024, 512, 2048, 3),
    )
    for name, hw, cin, mid, cout, blocks in stages:
        # block 1 (with projection shortcut)
        g.append(_conv(hw, cin, mid, f"{name}_1/1x1a"))
        g.append(_conv(hw, 9 * mid, mid, f"{name}_1/3x3"))
        g.append(_conv(hw, mid, cout, f"{name}_1/1x1b"))
        g.append(_conv(hw, cin, cout, f"{name}_1/proj"))
        for b in range(2, blocks + 1):
            g.append(_conv(hw, cout, mid, f"{name}_{b}/1x1a"))
            g.append(_conv(hw, 9 * mid, mid, f"{name}_{b}/3x3"))
            g.append(_conv(hw, mid, cout, f"{name}_{b}/1x1b"))
    g.append(GEMM(1, 2048, 1000, name="fc"))
    vec = sum(x.M * x.N * x.count for x in g) * 2  # relu + bn per conv output
    return Workload("ResNet-50", "RE", "Image Classification", tuple(g), vec)


# ---------------------------------------------------------------------------
# EfficientNet-B0 @ 224x224 (82 layers incl. SE FCs)
# ---------------------------------------------------------------------------

def _efficientnet_b0() -> Workload:
    g: list[GEMM] = [_conv(112 * 112, 27, 32, "stem")]
    # (blocks, spatial_in, spatial_out, cin, cout, k, expand)
    mb = (
        (1, 112, 112, 32, 16, 3, 1),
        (2, 112, 56, 16, 24, 3, 6),
        (2, 56, 28, 24, 40, 5, 6),
        (3, 28, 14, 40, 80, 3, 6),
        (3, 14, 14, 80, 112, 5, 6),
        (4, 14, 7, 112, 192, 5, 6),
        (1, 7, 7, 192, 320, 3, 6),
    )
    for blocks, s_in, s_out, cin, cout, k, expand in mb:
        for b in range(blocks):
            c_in = cin if b == 0 else cout
            s_i = s_in if b == 0 else s_out
            c_exp = c_in * expand
            if expand != 1:
                g.append(_conv(s_i * s_i, c_in, c_exp, f"mb{cout}_{b}/expand"))
            g.append(_conv(s_out * s_out, k * k, c_exp, f"mb{cout}_{b}/dw{k}x{k}"))
            c_se = max(1, c_in // 4)
            g.append(GEMM(1, c_exp, c_se, name=f"mb{cout}_{b}/se_reduce"))
            g.append(GEMM(1, c_se, c_exp, name=f"mb{cout}_{b}/se_expand"))
            g.append(_conv(s_out * s_out, c_exp, cout, f"mb{cout}_{b}/project"))
    g.append(_conv(7 * 7, 320, 1280, "head"))
    g.append(GEMM(1, 1280, 1000, name="fc"))
    vec = sum(x.M * x.N * x.count for x in g) * 3  # swish + bn + se-mul
    return Workload("EfficientNet-B0", "EF", "Image Classification", tuple(g), vec)


# ---------------------------------------------------------------------------
# TinyYOLO-V2 @ 416x416 (9 conv layers)
# ---------------------------------------------------------------------------

def _tinyyolo_v2() -> Workload:
    g = (
        _conv(416 * 416, 27, 16, "conv1"),
        _conv(208 * 208, 144, 32, "conv2"),       # Fig. 22 case-study layer
        _conv(104 * 104, 288, 64, "conv3"),
        _conv(52 * 52, 576, 128, "conv4"),
        _conv(26 * 26, 1152, 256, "conv5"),
        _conv(13 * 13, 2304, 512, "conv6"),
        _conv(13 * 13, 4608, 1024, "conv7"),
        _conv(13 * 13, 9216, 1024, "conv8"),
        _conv(13 * 13, 1024, 125, "conv9"),
    )
    vec = sum(x.M * x.N for x in g) * 2
    return Workload("TinyYOLO-V2", "TY", "Object Detection", g, vec)


# ---------------------------------------------------------------------------
# FasterRCNN (ResNet-50 C4 backbone + RPN + ROI head, ~600x800 input)
# ---------------------------------------------------------------------------

def _fasterrcnn() -> Workload:
    g: list[GEMM] = [_conv(300 * 400, 7 * 7 * 3, 64, "conv1")]
    stages = (
        ("conv2", 150 * 200, 64, 64, 256, 3),
        ("conv3", 75 * 100, 256, 128, 512, 4),
        ("conv4", 38 * 50, 512, 256, 1024, 6),
    )
    for name, hw, cin, mid, cout, blocks in stages:
        g.append(_conv(hw, cin, mid, f"{name}_1/1x1a"))
        g.append(_conv(hw, 9 * mid, mid, f"{name}_1/3x3"))
        g.append(_conv(hw, mid, cout, f"{name}_1/1x1b"))
        g.append(_conv(hw, cin, cout, f"{name}_1/proj"))
        for b in range(2, blocks + 1):
            g.append(_conv(hw, cout, mid, f"{name}_{b}/1x1a"))
            g.append(_conv(hw, 9 * mid, mid, f"{name}_{b}/3x3"))
            g.append(_conv(hw, mid, cout, f"{name}_{b}/1x1b"))
    # RPN on the 38x50 C4 map
    g.append(_conv(38 * 50, 9 * 1024, 512, "rpn/3x3"))
    g.append(_conv(38 * 50, 512, 18, "rpn/cls"))
    g.append(_conv(38 * 50, 512, 36, "rpn/bbox"))
    # ROI head: stage-5 bottlenecks over 300 ROIs of 7x7
    roi_m = 300 * 7 * 7
    g.append(_conv(roi_m, 1024, 512, "roi/conv5_1_1x1a"))
    g.append(_conv(roi_m, 9 * 512, 512, "roi/conv5_1_3x3"))
    g.append(_conv(roi_m, 512, 2048, "roi/conv5_1_1x1b"))
    g.append(_conv(roi_m, 1024, 2048, "roi/conv5_1_proj"))
    for b in (2, 3):
        g.append(_conv(roi_m, 2048, 512, f"roi/conv5_{b}_1x1a"))
        g.append(_conv(roi_m, 9 * 512, 512, f"roi/conv5_{b}_3x3"))
        g.append(_conv(roi_m, 512, 2048, f"roi/conv5_{b}_1x1b"))
    g.append(GEMM(300, 2048, 81, name="roi/cls"))
    g.append(GEMM(300, 2048, 324, name="roi/bbox"))
    vec = sum(x.M * x.N * x.count for x in g) * 2
    return Workload("FasterRCNN", "FR", "Object Detection", tuple(g), vec)


# ---------------------------------------------------------------------------
# ViT-B/32 @ 224x224: 50 tokens, d=768, 12 layers (FFN = 55% of MACs)
# ---------------------------------------------------------------------------

def _vit() -> Workload:
    seq, d, heads, dh, ffn, layers = 50, 768, 12, 64, 3072, 12
    g: list[GEMM] = [GEMM(49, 32 * 32 * 3, d, name="patch_embed")]
    per_layer = (
        GEMM(seq, d, 3 * d, name="qkv"),
        GEMM(seq, dh, seq, count=heads, name="attn_scores"),
        GEMM(seq, seq, dh, count=heads, name="attn_ctx"),
        GEMM(seq, d, d, name="attn_proj"),
        GEMM(seq, d, ffn, name="ffn1"),
        GEMM(seq, ffn, d, name="ffn2"),
    )
    for i in range(layers):
        g.extend(dataclasses.replace(x, name=f"l{i}/{x.name}") for x in per_layer)
    g.append(GEMM(1, d, 1000, name="head"))
    vec = layers * (seq * seq * heads * 4 + seq * d * 8)  # softmax + LN + gelu
    return Workload("ViT", "VI", "Image Classification", tuple(g), vec)


# ---------------------------------------------------------------------------
# BERT-Large, seq 128: d=1024, 16 heads, FFN 4096, 24 layers
# ---------------------------------------------------------------------------

def _bert_large() -> Workload:
    seq, d, heads, dh, ffn, layers = 128, 1024, 16, 64, 4096, 24
    g: list[GEMM] = []
    per_layer = (
        GEMM(seq, d, d, count=3, name="qkv"),
        GEMM(seq, dh, seq, count=heads, name="attn_scores"),
        GEMM(seq, seq, dh, count=heads, name="attn_ctx"),
        GEMM(seq, d, d, name="attn_proj"),
        GEMM(seq, d, ffn, name="ffn1"),
        GEMM(seq, ffn, d, name="ffn2"),
    )
    for i in range(layers):
        g.extend(dataclasses.replace(x, name=f"l{i}/{x.name}") for x in per_layer)
    vec = layers * (seq * seq * heads * 4 + seq * d * 8)
    return Workload("BERT-Large", "BE", "Machine Translation", tuple(g), vec)


# ---------------------------------------------------------------------------
# GNMT: 8+8 LSTM layers, h=1024, batch-1 decode (matrix-vector GEMMs)
# ---------------------------------------------------------------------------

def _gnmt() -> Workload:
    h, steps, vocab = 1024, 50, 32000
    g: list[GEMM] = []
    for i in range(8):  # encoder (layer 0 bidirectional)
        mult = 2 if i == 0 else 1
        g.append(GEMM(1, h, 4 * h, count=steps * mult, name=f"enc{i}/Wx"))
        g.append(GEMM(1, h, 4 * h, count=steps * mult, name=f"enc{i}/Wh"))
    for i in range(8):  # decoder
        g.append(GEMM(1, h, 4 * h, count=steps, name=f"dec{i}/Wx"))
        g.append(GEMM(1, h, 4 * h, count=steps, name=f"dec{i}/Wh"))
    g.append(GEMM(1, h, h, count=steps, name="attention"))
    g.append(GEMM(1, h, vocab, count=steps, name="softmax_proj"))
    vec = steps * 16 * 8 * h + steps * vocab  # gates + softmax
    return Workload("GNMT", "GN", "Machine Translation", tuple(g), vec)


# ---------------------------------------------------------------------------
# DeepSpeech2: 2 conv + 5 bidirectional LSTM (h=1024) + FC, T=300 frames
# ---------------------------------------------------------------------------

def _deepspeech2() -> Workload:
    t, h = 150, 1024  # frames after stride-2 conv
    g: list[GEMM] = [
        _conv(81 * 150, 41 * 11 * 1, 32, "conv1"),
        _conv(41 * 150, 21 * 11 * 32, 32, "conv2"),
    ]
    in0 = 41 * 32
    g.append(GEMM(1, in0, 4 * h, count=t * 2, name="lstm0/Wx"))
    g.append(GEMM(1, h, 4 * h, count=t * 2, name="lstm0/Wh"))
    for i in range(1, 5):
        g.append(GEMM(1, 2 * h, 4 * h, count=t * 2, name=f"lstm{i}/Wx"))
        g.append(GEMM(1, h, 4 * h, count=t * 2, name=f"lstm{i}/Wh"))
    g.append(GEMM(1, 2 * h, 29, count=t, name="fc_ctc"))
    vec = t * 2 * 5 * 16 * h
    return Workload("DeepSpeech2", "DS", "Automatic Speech Recognition", tuple(g), vec)


def build_workloads() -> dict[str, Workload]:
    ws = (
        _resnet50(), _efficientnet_b0(), _tinyyolo_v2(), _fasterrcnn(),
        _vit(), _bert_large(), _gnmt(), _deepspeech2(),
    )
    return {w.abbr: w for w in ws}


WORKLOADS = build_workloads()


# ---------------------------------------------------------------------------
# Plane-2 bridge: GEMM traces of the assigned LM architectures
# ---------------------------------------------------------------------------

ARCH_TRACE_SEQ = 512  # default prefill length for arch traces


def arch_gemms(cfg, *, seq_len: int = ARCH_TRACE_SEQ, batch: int = 1) -> tuple[GEMM, ...]:
    """Lower an `repro.models.config.ArchConfig` to its GEMM trace.

    The mapper-facing view of one prefill pass at batch x seq_len: every
    projection / attention / FFN / MoE-expert / SSD-chunk matmul becomes
    a GEMM, with repeated layers collapsed via `count` exactly like the
    Table-3 traces above (decision cache stays O(#distinct shapes)).
    This is a *search workload*, not a cycle-exact lowering: elementwise
    ops (norms, gates, convs, rotary) are out of scope like
    `vector_elements` is for the paper suite.
    """
    d, f, hd = cfg.d_model, cfg.d_ff, cfg.head_dim_
    nh, nkv = cfg.n_heads, cfg.n_kv
    seq = seq_len + cfg.prefix_tokens  # VLM: image patch embeds prepended
    tokens = seq * batch
    pattern = cfg.layer_pattern
    n_of = {k: sum(1 for i in range(cfg.n_layers)
                   if pattern[i % len(pattern)] == k)
            for k in set(pattern)}
    g: list[GEMM] = []

    def mlp(prefix: str, blocks: int) -> list[GEMM]:
        if cfg.moe is not None:
            e, k = cfg.moe.n_experts, cfg.moe.top_k
            per_exp = max(1, -(-tokens * k // e))  # balanced routing
            n_up = 2 if cfg.gated_mlp else 1
            return [
                GEMM(tokens, d, e, count=blocks, name=f"{prefix}/router"),
                GEMM(per_exp, d, f, count=blocks * e * n_up, name=f"{prefix}/expert_up"),
                GEMM(per_exp, f, d, count=blocks * e, name=f"{prefix}/expert_down"),
            ]
        n_up = 2 if cfg.gated_mlp else 1
        return [
            GEMM(tokens, d, f, count=blocks * n_up, name=f"{prefix}/ffn_up"),
            GEMM(tokens, f, d, count=blocks, name=f"{prefix}/ffn_down"),
        ]

    for kind, blocks in sorted(n_of.items()):
        if blocks == 0:
            continue  # pattern kind unused at this n_layers (truncated config)
        if kind in ("attn", "local"):
            ctx = min(seq, cfg.window) if (kind == "local" and cfg.window) else seq
            g += [
                GEMM(tokens, d, hd * (nh + 2 * nkv), count=blocks, name=f"{kind}/qkv"),
                GEMM(seq, hd, ctx, count=blocks * nh * batch, name=f"{kind}/scores"),
                GEMM(seq, ctx, hd, count=blocks * nh * batch, name=f"{kind}/ctx"),
                GEMM(tokens, nh * hd, d, count=blocks, name=f"{kind}/proj"),
            ]
            g += mlp(kind, blocks)
        elif kind == "ssm":
            s = cfg.ssm
            d_in = s.expand * d
            heads = d_in // s.head_dim
            n_chunks = -(-seq // s.chunk)
            per_chunk = blocks * heads * n_chunks * batch
            g += [
                GEMM(tokens, d, 2 * d_in + 2 * s.n_groups * s.d_state + heads,
                     count=blocks, name="ssm/in_proj"),
                GEMM(s.chunk, s.d_state, s.chunk, count=per_chunk, name="ssm/chunk_scores"),
                GEMM(s.chunk, s.chunk, s.head_dim, count=per_chunk, name="ssm/chunk_ctx"),
                GEMM(s.d_state, s.chunk, s.head_dim, count=per_chunk, name="ssm/chunk_state"),
                GEMM(tokens, d_in, d, count=blocks, name="ssm/out_proj"),
            ]
        elif kind == "rglru":
            w = cfg.rglru_width or d
            g += [
                GEMM(tokens, d, w, count=2 * blocks, name="rglru/in_proj"),
                GEMM(tokens, w, d, count=blocks, name="rglru/out_proj"),
            ]
            g += mlp("rglru", blocks)
        else:  # pragma: no cover - schema guards BlockKind
            raise ValueError(f"unknown block kind {kind!r}")
    g.append(GEMM(tokens, d, cfg.vocab, name="lm_head"))
    return tuple(g)


def arch_traces(*, smoke: bool = False, seq_len: int = ARCH_TRACE_SEQ,
                batch: int = 1) -> dict[str, tuple[GEMM, ...]]:
    """GEMM traces for every registered arch in repro.configs."""
    from repro.configs import all_configs  # lazy: keeps core importable alone

    return {name: arch_gemms(c, seq_len=seq_len, batch=batch)
            for name, c in all_configs(smoke=smoke).items()}
