"""Plane-2 cost model: the ReDas mapper decision surface on TPU v5e.

The paper's mapper picks (logical shape, dataflow, buffer split, tile
size, loop order) per GEMM from an analytical cycle model.  On TPU the
same decision surface is (block tile bm x bk x bn, dataflow = residency
schedule) per GEMM, and the analytical model is the v5e roofline:

    t_compute = padded_flops / MXU_peak         (padding waste explicit!)
    t_memory  = hbm_bytes(dataflow, blocks) / HBM_bw
    t_kernel  = max(t_compute, t_memory)        (double-buffered pipeline)

`hbm_bytes` encodes exactly the dataflow trade-off the ReDas multi-mode
buffer manages: OS refetches the streaming operands but writes each
output once; WS keeps the weight resident per K-chunk but streams f32
partial sums through HBM; IS is the transpose.  The search (geometric
tile ladders, per-shape decision cache) is the interval-sampling engine
of Sec. 4.3 re-instantiated against TPU constants.

These primitives back `repro.engine.TPUModel` (the plane-2 CostModel:
per-shape dispatch through the unified engine decision cache) and the
roofline benchmarks that napkin-math candidate changes before
implementing them.
"""

from __future__ import annotations

import dataclasses
import functools
import math

# --- TPU v5e hardware constants (per chip) ---------------------------------
PEAK_FLOPS = 197e12          # bf16 MXU
PEAK_FLOPS_INT8 = 394e12     # int8 MXU path (2x bf16 on v5e)
HBM_BW = 819e9               # bytes / s
ICI_BW = 50e9                # bytes / s / link (rooflines elsewhere)
VMEM = 16 * 2**20            # bytes / core
SUBLANE, LANE = 8, 128       # f32/bf16 VREG tiling floor
MXU = 128                    # systolic side


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclasses.dataclass(frozen=True)
class TPUKernelConfig:
    dataflow: str  # "os" | "ws" | "is"
    bm: int
    bk: int
    bn: int

    def vmem_bytes(self, in_bytes: int = 2) -> int:
        return 2 * (self.bm * self.bk + self.bk * self.bn) * in_bytes + self.bm * self.bn * 4


@dataclasses.dataclass(frozen=True)
class TPUKernelCost:
    seconds: float
    compute_s: float
    memory_s: float
    hbm_bytes: float
    padded_flops: float
    useful_flops: float

    @property
    def mxu_utilization(self) -> float:
        """Useful FLOPs / (time x peak): the plane-2 PE-utilization metric."""
        return self.useful_flops / (self.seconds * PEAK_FLOPS) if self.seconds else 0.0

    @property
    def padding_efficiency(self) -> float:
        return self.useful_flops / self.padded_flops if self.padded_flops else 0.0


def hbm_traffic(m: int, k: int, n: int, cfg: TPUKernelConfig,
                in_bytes: int = 2, out_bytes: int = 2) -> float:
    """HBM bytes moved by kernels/redas_gemm.gemm on padded dims."""
    mp, kp, np_ = _round_up(m, cfg.bm), _round_up(k, cfg.bk), _round_up(n, cfg.bn)
    gm, gk, gn = mp // cfg.bm, kp // cfg.bk, np_ // cfg.bn
    a, b, o = mp * kp * in_bytes, kp * np_ * in_bytes, mp * np_ * out_bytes
    if cfg.dataflow == "os":
        # grid (m, n, k): A refetched per n-trip, B per m-trip, O written once.
        return a * gn + b * gm + o
    acc = mp * np_ * 4  # f32 partial-sum stream
    if cfg.dataflow == "ws":
        # per K-chunk call: B once (resident across m sweep), A per n-trip,
        # accumulator read+written per call.
        return a * gn + b + acc * (2 * gk - 1) + o
    if cfg.dataflow == "is":
        return a + b * gm + acc * (2 * gk - 1) + o
    raise ValueError(cfg.dataflow)


def _ramp_factor(m: int, n: int, cfg: TPUKernelConfig) -> float:
    """MXU pipeline fill/drain — Eq. 4's (R + C + S - 1)/S in TPU form.

    The ramp re-occurs whenever the MXU's resident operand swaps and
    amortizes over the streaming length until the next swap:
      OS: the streaming run is one block's bm rows (B block swaps per grid
          step), so overhead ~ MXU/bm — tiny output tiles pay Eq. 4's
          fill/drain just like a tiny logical array does;
      WS: weights stay resident across the whole padded M sweep -> MXU/Mp;
      IS: the transpose -> MXU/Np.
    """
    mp, np_ = _round_up(m, cfg.bm), _round_up(n, cfg.bn)
    stream = {"os": cfg.bm, "ws": mp, "is": np_}[cfg.dataflow]
    return 1.0 + MXU / stream


def peak_flops(in_bytes: int = 2) -> float:
    """MXU peak for the operand width: 1-byte operands take the int8
    path (2x bf16 on v5e) — the request's `in_bytes` reaches here from
    the engine, so int8-plane plans see both the doubled roofline and
    the halved VMEM footprint (larger tiles pass the Eq. 2 gate)."""
    return PEAK_FLOPS_INT8 if in_bytes == 1 else PEAK_FLOPS


def estimate(m: int, k: int, n: int, cfg: TPUKernelConfig,
             in_bytes: int = 2, out_bytes: int = 2) -> TPUKernelCost:
    mp, kp, np_ = _round_up(m, cfg.bm), _round_up(k, cfg.bk), _round_up(n, cfg.bn)
    padded = 2.0 * mp * kp * np_
    useful = 2.0 * m * k * n
    t_c = padded * _ramp_factor(m, n, cfg) / peak_flops(in_bytes)
    bytes_ = hbm_traffic(m, k, n, cfg, in_bytes, out_bytes)
    t_m = bytes_ / HBM_BW
    return TPUKernelCost(
        seconds=max(t_c, t_m), compute_s=t_c, memory_s=t_m,
        hbm_bytes=bytes_, padded_flops=padded, useful_flops=useful)


def _ladder(dim: int, align: int, cap: int = 1024) -> list[int]:
    """Geometric tile ladder (interval sampling): aligned, <= padded dim."""
    top = min(_round_up(dim, align), cap)
    vals, v = [], align
    while v < top:
        vals.append(v)
        v *= 2
    vals.append(top)
    return sorted(set(vals))


@functools.lru_cache(maxsize=65536)
def choose_kernel_config(m: int, k: int, n: int,
                         in_bytes: int = 2) -> TPUKernelConfig:
    """Mapper search: dataflows x geometric tile ladders, VMEM-constrained."""
    best, best_t = None, math.inf
    for bm in _ladder(m, SUBLANE, 512):
        for bk in _ladder(k, LANE, 2048):
            for bn in _ladder(n, LANE, 512):
                for df in ("os", "ws", "is"):
                    cfg = TPUKernelConfig(df, bm, bk, bn)
                    if cfg.vmem_bytes(in_bytes) > VMEM:
                        continue
                    t = estimate(m, k, n, cfg, in_bytes).seconds
                    if t < best_t:
                        best, best_t = cfg, t
    assert best is not None, (m, k, n)
    return best


@functools.lru_cache(maxsize=65536)
def fixed_square_cost(m: int, k: int, n: int, in_bytes: int = 2) -> TPUKernelCost:
    """The 'conventional' schedule: 128x128x128 OS blocks, no search —
    plane-2's analogue of the fixed 128x128 WS baseline array."""
    return estimate(m, k, n, TPUKernelConfig("os", MXU, MXU, MXU), in_bytes)
