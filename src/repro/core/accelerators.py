"""Accelerator specifications: ReDas and the five baselines of Table 1.

Each spec fixes (i) the legal hardware-configuration space — which logical
shapes and dataflows the mapper may pick — and (ii) the energy/area
constants used by `core.energy`.  All accelerators share Table 4's common
parameters (128x128 PEs, 700 MHz, int8, 4 MB SRAM, 256 GB/s DRAM) so the
comparison isolates dataflow + reshaping capability, exactly like the
paper's methodology (Sec. 5.1: "The same hardware parameters are used for
the above baselines and ReDas for a fair comparison").

Shape spaces:
  TPUv2     fixed 128x128, WS only.
  Gemmini   fixed 128x128, WS + OS (flexible PE, fixed shape).
  Planaria  WS only, coarse-grained: 5 logical shapes composed from
            32x32 sub-arrays (Sec. 2.4: "a limited set of 5 logical
            shapes (without partitioning)").
  DyNNamic  OS only, fine-grained vertical re-chaining (same Eq. 1 family
            at granularity 4), multi-ported buffers.
  SARA      WS+OS+IS, fine-grained (granularity 4), dedicated links and
            1024-ported buffer -> fast setup but costly SRAM/area.
  ReDas     WS+OS+IS, fine-grained Eq. 1 shapes (granularity 4),
            roundabout bypass cycles, 128-cycle reconfiguration.

Energy/area constants are calibrated from Table 5, Fig. 4 and Fig. 13
(derivations in DESIGN.md Sec. 2 and core/energy.py docstrings).
"""

from __future__ import annotations

import dataclasses

from .analytical_model import AnalyticalModel
from .dataflow import ALL_DATAFLOWS, Dataflow, LogicalShape, enumerate_logical_shapes

SRAM_BYTES = 4 * 2**20        # Table 4: 4 MB on-chip SRAM
FREQ_HZ = 700e6               # Table 4: 700 MHz
DRAM_BW = 256e9               # Table 4: 256 GB/s
WORD_BYTES = 1                # Table 4: int8
ARRAY = 128                   # Table 4: 128x128
RESHAPE_GRANULARITY = 4       # Sec. 5.1: granularity limited to 4x4 (as SARA)


@dataclasses.dataclass(frozen=True)
class AcceleratorSpec:
    name: str
    dataflows: tuple[Dataflow, ...]
    shapes: tuple[LogicalShape, ...]
    array_size: int = ARRAY
    sram_bytes: int = SRAM_BYTES
    word_bytes: int = WORD_BYTES
    freq_hz: float = FREQ_HZ
    dram_bw: float = DRAM_BW
    config_cycles: int = 0          # per-GEMM reconfiguration cost
    bypass_enabled: bool = False    # Eq. 4 roundabout corner-turn cycles
    setup_floor: int = 0            # min cycles of T_start (parallel setup etc.)
    # --- energy/area constants (28 nm; see core/energy.py) -----------------
    mac_pj: float = 0.63            # dynamic energy per int8 MAC
    pe_overhead_ratio: float = 1.25 # mux/reg dynamic energy per MAC, x mac_pj
    sram_pj_per_byte: float = 3.92  # concentrated TPU-like buffer (Sec. 5.4)
    dram_pj_per_byte: float = 13.31 # HBM2 (Sec. 5.4)
    leak_w: float = 0.30            # chip leakage (buffer-dominated, Fig. 4)
    area_mm2: float = 15.35         # die area (Fig. 13 ratios)

    def model(self, array_size: int | None = None) -> AnalyticalModel:
        return AnalyticalModel(
            array_size=array_size or self.array_size,
            sram_bytes=self.sram_bytes,
            word_bytes=self.word_bytes,
            freq_hz=self.freq_hz,
            dram_bw_bytes_per_s=self.dram_bw,
            config_cycles=self.config_cycles,
            bypass_enabled=self.bypass_enabled,
            setup_floor=self.setup_floor,
        )

    def shapes_for(self, array_size: int) -> tuple[LogicalShape, ...]:
        """Shape space re-derived for a different physical array size
        (sensitivity study, Fig. 18)."""
        if array_size == self.array_size:
            return self.shapes
        return _shape_space(self.name, array_size)


def _planaria_shapes(r_p: int) -> tuple[LogicalShape, ...]:
    """5 coarse shapes composed from (r_p/4 x r_p/4) sub-arrays."""
    s = r_p // 4  # 32 for a 128 array: 16 sub-arrays
    return (
        LogicalShape(r_p, r_p),
        LogicalShape(r_p // 2, r_p * 2),
        LogicalShape(r_p * 2, r_p // 2),
        LogicalShape(s, r_p * 4),
        LogicalShape(r_p * 4, s),
    )


def _shape_space(name: str, r_p: int) -> tuple[LogicalShape, ...]:
    fixed = (LogicalShape(r_p, r_p),)
    if name in ("tpu", "gemmini"):
        return fixed
    if name == "planaria":
        return _planaria_shapes(r_p)
    # redas / sara / dynnamic: fine-grained Eq. 1 family
    return enumerate_logical_shapes(r_p, granularity=RESHAPE_GRANULARITY)


def make_specs(array_size: int = ARRAY) -> dict[str, AcceleratorSpec]:
    """All six accelerators at a given physical array size."""
    return {
        "tpu": AcceleratorSpec(
            name="tpu",
            dataflows=(Dataflow.WS,),
            shapes=_shape_space("tpu", array_size),
            array_size=array_size,
        ),
        "gemmini": AcceleratorSpec(
            name="gemmini",
            dataflows=(Dataflow.WS, Dataflow.OS),
            shapes=_shape_space("gemmini", array_size),
            array_size=array_size,
            pe_overhead_ratio=1.35,     # dual-dataflow PE muxing
            area_mm2=16.1,
        ),
        "planaria": AcceleratorSpec(
            name="planaria",
            dataflows=(Dataflow.WS,),
            shapes=_shape_space("planaria", array_size),
            array_size=array_size,
            config_cycles=2 * array_size,  # omni-directional fission reconfig
            pe_overhead_ratio=1.45,
            sram_pj_per_byte=4.10,
            leak_w=0.35,
            area_mm2=17.7,
        ),
        "dynnamic": AcceleratorSpec(
            name="dynnamic",
            dataflows=(Dataflow.OS,),
            shapes=_shape_space("dynnamic", array_size),
            array_size=array_size,
            config_cycles=array_size,
            pe_overhead_ratio=1.5,
            sram_pj_per_byte=8.2,       # multi-ported SRAM (Sec. 2.5)
            leak_w=0.42,
            area_mm2=35.5,
        ),
        "sara": AcceleratorSpec(
            name="sara",
            dataflows=ALL_DATAFLOWS,
            shapes=_shape_space("sara", array_size),
            array_size=array_size,
            config_cycles=RESHAPE_GRANULARITY,  # parallel per-sub-array setup
            setup_floor=RESHAPE_GRANULARITY,
            pe_overhead_ratio=1.6,
            sram_pj_per_byte=9.8,       # 1024-ported buffer (Fig. 4)
            leak_w=0.58 + 0.20,         # 580 mW buffer leakage + rest
            area_mm2=76.9,              # ReDas is ~27% of SARA (Sec. 5.4)
        ),
        "redas": AcceleratorSpec(
            name="redas",
            dataflows=ALL_DATAFLOWS,
            shapes=_shape_space("redas", array_size),
            array_size=array_size,
            config_cycles=array_size,   # Sec. 4: 128 cycles for a 128 array
            bypass_enabled=True,
            pe_overhead_ratio=2.79,     # Table 5: (1.61+2.31)/1.29 additional+orig muxes
            sram_pj_per_byte=4.19,      # Sec. 5.4: distributed multi-mode buffer
            leak_w=0.33,
            area_mm2=20.77,             # Table 5
        ),
    }


SPECS = make_specs()
REDAS = SPECS["redas"]
TPU = SPECS["tpu"]
