"""Host-side paged-KV plane: page allocator, prefix index, block tables.

ReDas's multi-mode buffers fine-grain-reallocate one fixed SRAM across
layers so no workload strands capacity; this module applies the same
instinct to serving HBM.  Instead of one contiguous worst-case
`(B, max_seq, ...)` region per slot, attention KV lives in a pool of
fixed-size pages (`models.transformer` builds the device pools; this
module owns every host decision about them):

  PageAllocator  free list + refcounts over one pool of `n_pages`.
  PrefixIndex    a radix tree over FULL-page token chunks: admitted
                 requests reuse already-prefilled prompt pages across
                 requests, +1 refcount per cached page.
  PagedKV        the scheduler-facing state: per-slot block tables
                 (`tables` (B, slot_pages) int32, -1 = unallocated),
                 admission (lookup -> ref shared pages -> allocate the
                 private suffix), the per-step decode-frontier
                 allocation, and release on eviction.

Sharing semantics ("re-own", not copy-on-write): only FULL prompt pages
are ever shared, capped so every request prefills at least one suffix
token into freshly allocated private pages, and the page holding any
slot's write frontier is always refcount-1 private (asserted — a write
into a refcount>1 page is a correctness bug, never a fallback path).
Deallocation frees only unshared pages: eviction derefs, the page
returns to the free list only at refcount zero.

Everything here is numpy/host-side and jax-free; the device side reads
the block tables as a plain int32 array argument to the jitted steps
(NOT part of the cache pytree, so the cache donation story is
unchanged).
"""

from __future__ import annotations

import numpy as np


class PoolExhausted(RuntimeError):
    """Allocation failed even after evicting reclaimable index entries."""


class PageAllocator:
    """Free list + refcounts over a pool of `n_pages` pages.

    `alloc` hands out pages at refcount 1; `ref`/`deref` move shared
    pages up and down; a page returns to the free list exactly when its
    refcount hits zero.  Deterministic: the free list is a LIFO stack
    seeded so first allocations come out 0, 1, 2, ...
    """

    def __init__(self, n_pages: int):
        if n_pages < 1:
            raise ValueError(f"n_pages must be >= 1: {n_pages}")
        self.n_pages = n_pages
        self.refcount = np.zeros((n_pages,), np.int64)
        self._free: list[int] = list(range(n_pages - 1, -1, -1))

    @property
    def free_count(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> list[int]:
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            raise PoolExhausted(
                f"need {n} pages, {len(self._free)} free of {self.n_pages}")
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            assert self.refcount[p] == 0, (p, self.refcount[p])
            self.refcount[p] = 1
        return pages

    def ref(self, pages) -> None:
        for p in pages:
            assert self.refcount[p] > 0, f"ref of dead page {p}"
            self.refcount[p] += 1

    def deref(self, pages) -> list[int]:
        """Drop one reference per page; returns the pages that freed."""
        freed = []
        for p in pages:
            assert self.refcount[p] > 0, f"deref of free page {p}"
            self.refcount[p] -= 1
            if self.refcount[p] == 0:
                self._free.append(int(p))
                freed.append(int(p))
        return freed

    def free_pages(self) -> set[int]:
        return set(self._free)


class _Node:
    __slots__ = ("children", "page", "stamp")

    def __init__(self, page: int, stamp: int):
        self.children: dict[tuple, _Node] = {}
        self.page = page
        self.stamp = stamp


class PrefixIndex:
    """Radix tree over full-page token chunks -> physical pages.

    One node per cached page; a node holds +1 refcount on its page for
    as long as it is indexed, so live slots may evict without the
    prefix disappearing.  `evict` reclaims LRU *leaves* (deepest pages
    of the least recently touched prefix first) until the allocator can
    satisfy a request — dropping an index entry only frees HBM when no
    slot still references the page.
    """

    def __init__(self, page_size: int):
        self.page_size = page_size
        self.root: dict[tuple, _Node] = {}
        self._clock = 0

    def _chunks(self, tokens) -> list[tuple]:
        p = self.page_size
        full = len(tokens) // p
        return [tuple(int(t) for t in tokens[i * p:(i + 1) * p])
                for i in range(full)]

    def lookup(self, tokens) -> list[int]:
        """Pages for the longest indexed full-page prefix of `tokens`."""
        self._clock += 1
        pages, level = [], self.root
        for chunk in self._chunks(tokens):
            node = level.get(chunk)
            if node is None:
                break
            node.stamp = self._clock
            pages.append(node.page)
            level = node.children
        return pages

    def insert(self, tokens, pages, allocator: PageAllocator) -> int:
        """Index `tokens`' full-page chunks at `pages`; each NEW node
        takes +1 ref on its page.  Existing nodes keep their page (two
        identical prefixes prefilled independently do not re-point the
        index).  Returns the number of newly indexed pages."""
        self._clock += 1
        chunks = self._chunks(tokens)
        assert len(pages) >= len(chunks), (len(pages), len(chunks))
        added, level = 0, self.root
        for chunk, page in zip(chunks, pages, strict=False):
            node = level.get(chunk)
            if node is None:
                node = _Node(int(page), self._clock)
                allocator.ref([int(page)])
                level[chunk] = node
                added += 1
            else:
                node.stamp = self._clock
            level = node.children
        return added

    def evict(self, need_free: int, allocator: PageAllocator) -> int:
        """Drop LRU leaves until `allocator.free_count >= need_free` or
        the index is empty; returns the number of entries dropped."""
        dropped = 0
        while allocator.free_count < need_free:
            leaf = self._lru_leaf()
            if leaf is None:
                break
            parent, key, node = leaf
            del parent[key]
            allocator.deref([node.page])
            dropped += 1
        return dropped

    def _lru_leaf(self):
        best = None

        def walk(level):
            nonlocal best
            for key, node in level.items():
                if node.children:
                    walk(node.children)
                elif best is None or node.stamp < best[2].stamp:
                    best = (level, key, node)

        walk(self.root)
        return best

    def pages(self) -> list[int]:
        out = []

        def walk(level):
            for node in level.values():
                out.append(node.page)
                walk(node.children)

        walk(self.root)
        return out

    def __len__(self) -> int:
        return len(self.pages())


class PagedKV:
    """Per-scheduler paged-KV state: block tables + allocator + index.

    `tables` (batch, slot_pages) int32 maps each slot's logical page i
    (rows [i*page, (i+1)*page)) to a physical pool page, -1 where
    unallocated; ALL attention layers share one table (page id p indexes
    every layer's own pool — the vLLM layout), so the table is a single
    host array handed to the jitted steps as a device argument.
    """

    def __init__(self, *, batch: int, max_seq: int, page_size: int,
                 n_pages: int, prefix_sharing: bool = True):
        self.page = page_size
        self.slot_pages = -(-max_seq // page_size)
        self.n_pages = n_pages
        self.alloc = PageAllocator(n_pages)
        self.tables = np.full((batch, self.slot_pages), -1, np.int32)
        self.index = PrefixIndex(page_size) if prefix_sharing else None
        self.shared_tokens = 0  # cumulative prompt tokens served from cache

    # -- allocation --------------------------------------------------------

    def _alloc(self, n: int) -> list[int]:
        if self.alloc.free_count < n and self.index is not None:
            self.index.evict(n, self.alloc)
        return self.alloc.alloc(n)  # raises PoolExhausted when still short

    def admit(self, slot: int, prompt) -> int:
        """Build slot `slot`'s block table for `prompt`; returns the
        shared-prefix length (tokens already resident — the caller
        prefills only `prompt[hist:]`).  Sharing is full-page-granular
        and capped so the suffix keeps >= 1 token: the write frontier is
        never a shared page.  Raises PoolExhausted (state untouched)
        when the private suffix cannot be allocated."""
        assert (self.tables[slot] < 0).all(), f"slot {slot} not released"
        n_tok = len(prompt)
        shared: list[int] = []
        if self.index is not None:
            matched = self.index.lookup(prompt)
            n_share = min(len(matched), (n_tok - 1) // self.page)
            shared = matched[:n_share]
        n_total = (n_tok - 1) // self.page + 1
        # Pin the shared pages BEFORE allocating: under pool pressure
        # _alloc evicts index entries, and without our reference that
        # eviction could free the pages we just matched — and even hand
        # them back out as `fresh`, aliasing the suffix onto the prefix.
        self.alloc.ref(shared)
        try:
            fresh = self._alloc(n_total - len(shared))
        except PoolExhausted:
            self.alloc.deref(shared)
            raise
        row = self.tables[slot]
        row[: len(shared)] = shared
        row[len(shared): n_total] = fresh
        # re-own semantics, asserted: every page the suffix prefill (and
        # later decode divergence) writes is freshly allocated, private.
        assert all(self.alloc.refcount[p] == 1 for p in fresh)
        hist = len(shared) * self.page
        self.shared_tokens += hist
        return hist

    def note_prefilled(self, slot: int, prompt) -> None:
        """Index `prompt`'s full pages (now resident in slot's table) so
        later admissions reuse them.  No-op without prefix sharing."""
        if self.index is None:
            return
        full = len(prompt) // self.page
        if full:
            pages = [int(p) for p in self.tables[slot, :full]]
            self.index.insert(prompt[: full * self.page], pages, self.alloc)

    def ensure_decode_page(self, slot: int, pos: int) -> None:
        """Guarantee the page holding write position `pos` exists and is
        private before a decode step writes it."""
        pi = pos // self.page
        assert pi < self.slot_pages, (pos, self.slot_pages)
        page = int(self.tables[slot, pi])
        if page < 0:
            (page,) = self._alloc(1)
            self.tables[slot, pi] = page
        if self.alloc.refcount[page] != 1:
            raise AssertionError(
                f"decode write frontier of slot {slot} (pos {pos}) is page "
                f"{page} with refcount {self.alloc.refcount[page]} — shared "
                f"pages must never be written (re-own invariant)")

    def rollback(self, slot: int, frontier_pos: int) -> None:
        """Speculative rollback (DESIGN.md §9): the slot's clock was
        decremented so its write frontier is `frontier_pos`; release any
        page whose rows are now entirely past the frontier.  Rolled-back
        pages were decode-frontier allocations, so they are refcount-1
        private (asserted) — a shared page can never be vacated here."""
        first_dead = frontier_pos // self.page + 1
        row = self.tables[slot]
        drop = [int(p) for p in row[first_dead:] if p >= 0]
        for p in drop:
            assert self.alloc.refcount[p] == 1, (
                f"rollback of slot {slot} would free shared page {p} "
                f"(refcount {self.alloc.refcount[p]})")
        self.alloc.deref(drop)
        row[first_dead:] = -1

    def release(self, slot: int) -> None:
        """Evicted slot: drop its references; shared pages survive in
        other slots / the index, private ones return to the free list."""
        row = self.tables[slot]
        self.alloc.deref([int(p) for p in row if p >= 0])
        row[:] = -1

    # -- invariants (the stress test drives this after every tick) ---------

    def check_invariants(self) -> None:
        """Leak/aliasing detection: refcounts equal the number of
        referencing slots (+1 per index entry), no page is both free and
        referenced, and free list + references account for exactly the
        pool."""
        expected = np.zeros((self.n_pages,), np.int64)
        for row in self.tables:
            live = [int(p) for p in row if p >= 0]
            assert len(set(live)) == len(live), f"duplicate page in {row}"
            for p in live:
                expected[p] += 1
        if self.index is not None:
            for p in self.index.pages():
                expected[p] += 1
        assert (expected == self.alloc.refcount).all(), (
            f"refcount drift: expected {expected.tolist()}, "
            f"allocator has {self.alloc.refcount.tolist()}")
        free = self.alloc.free_pages()
        assert len(free) == self.alloc.free_count, "duplicate in free list"
        referenced = {int(p) for p in np.nonzero(expected)[0]}
        assert not (free & referenced), f"pages both free and live: "\
            f"{sorted(free & referenced)}"
        assert free | referenced == set(range(self.n_pages)), (
            f"leaked pages: "
            f"{sorted(set(range(self.n_pages)) - free - referenced)}")
