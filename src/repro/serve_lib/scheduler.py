"""Continuous-batching serve scheduler over one persistent KV cache.

The static-batch `serve.generate` loop pads every request to one
rectangle: same-length prompts only, finished sequences burn decode
compute until the longest one ends, and new requests wait for the whole
batch to drain.  ReDas's own lesson — reconfigure per layer instead of
padding work to a fixed shape — applies to the serving plane too, and
the model layer already supports it: `flash_attention` takes per-slot
`q_pos`/`kv_len`, and the cache clock `cache["t"]` is a per-slot vector.

`Scheduler` owns a fixed pool of `ServeConfig.batch` slots over ONE
persistent cache:

  admit   queued requests enter free slots via a ragged prefill
          (`transformer.prefill(lengths=..., update_mask=...)`): each
          prompt is written at its slot with per-slot positions/clock,
          in-flight slots untouched.  The first output token is sampled
          from the prefill logits.
  decode  one fused `decode_step` over the whole pool with an `active`
          mask — the call shapes NEVER change, so the jitted step (and
          the `repro.engine` decision cache behind it) is reused for
          every step the scheduler ever takes.
  evict   EOS / max-tokens frees the slot immediately for the next
          queued request; no cache scrubbing is needed because a slot's
          clock masks stale rows and the next admit overwrites its
          recurrent state.

Prefill is the only shape-variable call: prompt widths are rounded up
to `prefill_bucket` (1 = exact group max — bitwise-parity mode; larger
buckets bound jit retraces to O(max_seq / bucket) distinct widths).

Chunked prefill (`ServeConfig.prefill_chunk`, DESIGN.md §12) bounds the
other head-of-line blocker: without it, one long arriving prompt
monopolizes a whole tick, stalling every in-flight decode for the full
prompt's prefill latency.  With a chunk size set, a long prompt streams
into its slot `prefill_chunk` tokens per tick (`transformer.prefill`'s
`hist_len` continuation — exact for all four cache kinds), each chunk
sharing its tick with the pool's fused decode, so in-flight slots keep
emitting.  `Scheduler.serve_async()` wraps the tick loop in a worker
thread behind a bounded request queue for callers that want submission
decoupled from stepping.

Greedy outputs match per-request `serve.generate` exactly for every
cache kind; the one caveat is MoE capacity dropping: expert capacity
scales with the CALL's padded width, so at drop-inducing capacity
factors an MoE request's dropped tokens can depend on its admit
group's width (DESIGN.md §6) — exactly the width dependence the
static `generate` path already has versus `forward`.
"""

from __future__ import annotations

import collections
import concurrent.futures
import contextlib
import dataclasses
import functools
import queue
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine as engine_mod
from repro.models import transformer as T
from repro.models.config import ArchConfig

from . import serve as serve_lib
from .paged import PagedKV, PoolExhausted


@dataclasses.dataclass
class Request:
    """One generation request: `prompt` (L,) int32, emit up to
    `max_new_tokens` (stopping early at `eos_id` if given)."""
    uid: int
    prompt: np.ndarray
    max_new_tokens: int
    temperature: float = 0.0
    key: jax.Array | None = None
    eos_id: int | None = None


@dataclasses.dataclass
class Completion:
    uid: int
    tokens: np.ndarray           # (n_emitted,) int32
    finish_reason: str           # "length" | "eos"
    prompt_len: int
    admit_step: int
    finish_step: int


@dataclasses.dataclass
class _Slot:
    req: Request
    key: jax.Array | None
    emitted: list[int]
    last_token: int
    admit_step: int
    # chunked ingestion (DESIGN.md §12): tokens of the prompt already
    # resident in the cache (shared-prefix pages included); while
    # `ingesting` the slot sits out of decode ticks and receives one
    # chunk per `_ingest_tick` until the whole prompt is resident.
    ingest_pos: int = 0
    ingesting: bool = False


@functools.lru_cache(maxsize=64)
def _jitted_steps(cfg: ArchConfig, scfg: serve_lib.ServeConfig, engine,
                  paged: bool = False):
    """One jitted (ragged prefill, masked decode) pair per posture, so
    every Scheduler instance over the same configs reuses the traced
    executables.  The engine joins the key because traces bind the
    engine context active when first taken (DESIGN.md §3).  The paged
    pair additionally threads the block tables (and the shared-prefix
    history: `hist_pages` is static — one retrace per distinct history
    page count, same O(max_seq / page) bound the prefill widths have).

    The third element is the chunk-continuation prefill (DESIGN.md
    §12): the contiguous layout needs a separate trace that threads
    `hist_len`; the paged prefill already does (chunk history rides the
    same gathered-pages path shared prefixes use), so there the chunk
    step IS the admit step."""
    if paged:
        def _paged_prefill(p, tok, cache, lens, mask, bt, hist, *,
                           hist_pages):
            return T.prefill(p, cfg, tok, cache,
                             compute_dtype=scfg.compute_dtype, lengths=lens,
                             update_mask=mask, block_tables=bt,
                             hist_len=hist, hist_pages=hist_pages)

        prefill = jax.jit(_paged_prefill, static_argnames=("hist_pages",))
        decode = jax.jit(
            lambda p, cache, tok, act, bt: T.decode_step(
                p, cfg, cache, tok, compute_dtype=scfg.compute_dtype,
                active=act, block_tables=bt))
        return prefill, decode, prefill
    prefill = jax.jit(
        lambda p, tok, cache, lens, mask: T.prefill(
            p, cfg, tok, cache, compute_dtype=scfg.compute_dtype,
            lengths=lens, update_mask=mask))
    decode = jax.jit(
        lambda p, cache, tok, act: T.decode_step(
            p, cfg, cache, tok, compute_dtype=scfg.compute_dtype,
            active=act))
    chunk_prefill = jax.jit(
        lambda p, tok, cache, lens, mask, hist: T.prefill(
            p, cfg, tok, cache, compute_dtype=scfg.compute_dtype,
            lengths=lens, update_mask=mask, hist_len=hist))
    return prefill, decode, chunk_prefill


@functools.lru_cache(maxsize=64)
def _jitted_spec_steps(cfg: ArchConfig, dcfg: ArchConfig,
                       scfg: serve_lib.ServeConfig, engine,
                       paged: bool = False):
    """The speculative tick's jits (DESIGN.md §9): k-step greedy draft
    `propose` over a throwaway cache copy, fused k+1-wide `verify` of
    the target, `advance` replaying the verify window through the
    persistent draft cache, and the draft's own ragged prefill.  The
    draft cache is always contiguous (it is private per scheduler and
    never shares prefixes), so only `verify` has a paged variant."""
    k = scfg.speculate_k
    if paged:
        verify = jax.jit(
            lambda p, cache, toks, act, bt: T.verify_step(
                p, cfg, cache, toks, compute_dtype=scfg.compute_dtype,
                active=act, block_tables=bt))
    else:
        verify = jax.jit(
            lambda p, cache, toks, act: T.verify_step(
                p, cfg, cache, toks, compute_dtype=scfg.compute_dtype,
                active=act))
    propose = jax.jit(
        lambda p, cache, tok, act: T.draft_propose(
            p, dcfg, cache, tok, k, compute_dtype=scfg.compute_dtype,
            active=act))
    advance = jax.jit(
        lambda p, cache, toks, keep, act: T.spec_advance(
            p, dcfg, cache, toks, keep, compute_dtype=scfg.compute_dtype,
            active=act))
    dprefill = jax.jit(
        lambda p, tok, cache, lens, mask: T.prefill(
            p, dcfg, tok, cache, compute_dtype=scfg.compute_dtype,
            lengths=lens, update_mask=mask))
    return verify, propose, advance, dprefill


class Scheduler:
    """Engine-aware continuous-batching loop over a slot pool.

    `params` must already be in serving dtype.  `engine` overrides the
    `ServeConfig`-derived one (`serve.warm_start_engine`); all jit
    traces happen inside its scope so every matmul shares one decision
    cache (`engine.plan.stats()` shows hits once shapes repeat)."""

    def __init__(self, params, cfg: ArchConfig, scfg: serve_lib.ServeConfig,
                 *, engine: "engine_mod.Engine | None" = None,
                 prefill_bucket: int = 1, draft_params=None,
                 draft_cfg: ArchConfig | None = None):
        if cfg.kind == "encoder":
            raise ValueError("encoder-only arch: no decode step")
        if cfg.embed_inputs or cfg.prefix_tokens:
            raise NotImplementedError(
                "scheduler serves token prompts only (no embeds/VLM prefix)")
        if prefill_bucket < 1:
            raise ValueError(f"prefill_bucket must be >= 1: {prefill_bucket}")
        if (draft_params is None) != (draft_cfg is None):
            raise ValueError("draft_params and draft_cfg come together")
        if draft_params is not None and not scfg.speculate_k:
            raise ValueError("draft_params needs ServeConfig(speculate_k>0)")
        self.params = params
        self.cfg = cfg
        self.scfg = scfg
        self.prefill_bucket = prefill_bucket
        self.engine = (engine if engine is not None
                       else serve_lib.warm_start_engine(scfg))
        self.cache = serve_lib.init_cache(cfg, scfg)
        # the paged plane is live only when the arch HAS full-attention
        # layers to page (on window/SSM/RG-LRU-only archs a paged
        # ServeConfig builds the identical contiguous cache and runs the
        # contiguous code path — paging those kinds buys nothing).
        # Prefix sharing needs EVERY layer's prompt state to live in
        # shareable pages, so it arms on pure-attention archs only.
        self.paged: PagedKV | None = None
        if scfg.cache_layout == "paged" and "attn" in cfg.layer_pattern:
            self.paged = PagedKV(
                batch=scfg.batch, max_seq=scfg.max_seq,
                page_size=scfg.page_size, n_pages=scfg.resolved_n_pages,
                prefix_sharing=set(cfg.layer_pattern) == {"attn"})
        self.slots: list[_Slot | None] = [None] * scfg.batch
        self.queue: collections.deque[Request] = collections.deque()
        self.completions: dict[int, Completion] = {}
        self.step_count = 0
        self.stats = {"admitted": 0, "finished": 0, "prefill_calls": 0,
                      "decode_steps": 0, "decode_tokens": 0,
                      "prefill_widths": set(),
                      # prefilled token/width totals: the FLOP-relevant
                      # counters prefix sharing drives DOWN (the PR 6
                      # bench's reuse ratio and the sharing tests key on
                      # these, like PR 4's decode-call counter).
                      # prefill_width_sum is PER-SLOT: each prefill call
                      # adds its width once per admitted slot, so
                      # bucketing mixed-history admits by hist_pages
                      # shows up as a drop (PR 7)
                      "prefill_tokens": 0, "prefill_width_sum": 0,
                      "shared_prefix_tokens": 0,
                      # speculative plane (DESIGN.md §9)
                      "spec_ticks": 0, "draft_tokens": 0,
                      "accepted_draft_tokens": 0}
        self._live_uids: set[int] = set()
        self._prefill, self._decode, self._chunk_prefill = _jitted_steps(
            cfg, scfg, self.engine, self.paged is not None)
        # chunked ingestion (DESIGN.md §12): chunk calls are always
        # exactly `chunk` wide; aligning the chunk to the prefill bucket
        # keeps it inside the admit-width universe plan_arch pre-decides
        self.chunk = scfg.prefill_chunk
        if self.chunk is not None and self.chunk % prefill_bucket:
            raise ValueError(
                f"prefill_chunk {self.chunk} is not a multiple of "
                f"prefill_bucket {prefill_bucket}: the chunk width must "
                f"sit in the bucketed admit-width universe the engine "
                f"plan pre-decides (zero steady-state misses)")
        # -- speculative plane (DESIGN.md §9) -----------------------------
        self.spec_k = scfg.speculate_k
        self.draft_params = self.draft_cfg = self.draft_cache = None
        if self.spec_k:
            if draft_params is not None:
                self.draft_params, self.draft_cfg = draft_params, draft_cfg
            elif scfg.draft == "self-int8":
                from repro.quant import quantize_params
                self.draft_params, self.draft_cfg = quantize_params(params), cfg
            else:  # None / "self": share the target params outright
                self.draft_params, self.draft_cfg = params, cfg
            w = self.spec_k + 1
            for c in {cfg, self.draft_cfg}:
                if "local" in c.layer_pattern:
                    ring = min(c.window, scfg.max_seq)
                    if w > ring:
                        raise ValueError(
                            f"speculate_k={self.spec_k}: the k+1-wide "
                            f"verify writes {w} ring rows but the sliding "
                            f"window holds only {ring} — rollback could "
                            f"not restore a window it overwrote twice")
            # private contiguous float cache: the draft replays full
            # prompts and the accepted verify windows, sharing nothing
            self.draft_cache = T.init_cache(
                self.draft_cfg, T.CacheSpec(scfg.max_seq, scfg.batch),
                dtype=scfg.compute_dtype)
            self._verify, self._propose, self._advance, self._dprefill = (
                _jitted_spec_steps(cfg, self.draft_cfg, scfg, self.engine,
                                   self.paged is not None))

    # -- request intake ----------------------------------------------------

    def submit(self, req: Request) -> None:
        n = int(np.asarray(req.prompt).size)
        if n < 1:
            raise ValueError(f"request {req.uid}: empty prompt")
        if req.max_new_tokens < 1:
            raise ValueError(f"request {req.uid}: max_new_tokens < 1")
        if n + req.max_new_tokens > self.scfg.max_seq:
            raise ValueError(
                f"request {req.uid}: prompt {n} + max_new "
                f"{req.max_new_tokens} exceeds max_seq {self.scfg.max_seq}")
        if req.temperature > 0.0 and req.key is None:
            raise ValueError(
                f"request {req.uid}: temperature > 0 needs a PRNG key")
        if self.spec_k:
            if req.temperature > 0.0:
                raise ValueError(
                    f"request {req.uid}: speculative decoding is greedy-"
                    f"only (acceptance is computed in-graph via argmax; "
                    f"temperature sampling would need a host RNG round-"
                    f"trip per draft token)")
            if n + req.max_new_tokens + self.spec_k > self.scfg.max_seq:
                raise ValueError(
                    f"request {req.uid}: prompt {n} + max_new "
                    f"{req.max_new_tokens} + speculate_k {self.spec_k} "
                    f"exceeds max_seq {self.scfg.max_seq} — the verify "
                    f"pass writes k rows past the final token")
        if req.uid in self._live_uids:  # queued, in flight, or completed
            raise ValueError(f"duplicate request uid {req.uid}")
        self._live_uids.add(req.uid)
        self.queue.append(req)

    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self.slots)

    def _scope(self):
        return (engine_mod.use_engine(self.engine)
                if self.engine is not None else contextlib.nullcontext())

    # -- sampling (host-side, per slot: each request owns its key) ---------

    def _sample(self, slot: _Slot, logits_row: np.ndarray) -> int:
        if slot.req.temperature > 0.0:
            slot.key, sub = jax.random.split(slot.key)
            return int(jax.random.categorical(
                sub, jnp.asarray(logits_row) / slot.req.temperature))
        return int(np.argmax(logits_row))

    def _emit(self, i: int, tok: int, finished: list[Completion]) -> None:
        """Record one sampled token for slot i; evict on EOS/budget."""
        slot = self.slots[i]
        slot.emitted.append(tok)
        slot.last_token = tok
        done_eos = slot.req.eos_id is not None and tok == slot.req.eos_id
        done_len = len(slot.emitted) >= slot.req.max_new_tokens
        if done_eos or done_len:
            comp = Completion(
                uid=slot.req.uid,
                tokens=np.asarray(slot.emitted, np.int32),
                finish_reason="eos" if done_eos else "length",
                prompt_len=int(np.asarray(slot.req.prompt).size),
                admit_step=slot.admit_step, finish_step=self.step_count)
            self.completions[slot.req.uid] = comp
            finished.append(comp)
            self.slots[i] = None  # slot free for the next queued request
            if self.paged is not None:
                # deref the slot's pages: private ones free immediately,
                # shared ones live on in other slots / the prefix index
                self.paged.release(i)
            self.stats["finished"] += 1

    # -- the two batch calls ----------------------------------------------

    def _admit(self, finished: list[Completion]) -> None:
        free = [i for i, s in enumerate(self.slots) if s is None]
        if not free or not self.queue:
            return
        picks: list[tuple[int, Request]] = []
        hists: dict[int, int] = {}
        if self.paged is not None:
            # peek-then-pop: PoolExhausted leaves the request queued
            # (backpressure — completions will free pages) instead of
            # dropping it.  Stuck with every slot free means the pool
            # genuinely cannot hold the prompt: fail with intent.
            while free and self.queue:
                i, req = free[0], self.queue[0]
                prompt = np.asarray(req.prompt, np.int32).reshape(-1)
                try:
                    hists[i] = self.paged.admit(i, prompt.tolist())
                except PoolExhausted:
                    if not picks and self.n_active == 0:
                        raise RuntimeError(
                            f"page pool ({self.paged.n_pages} pages of "
                            f"{self.paged.page}) cannot hold request "
                            f"{req.uid}'s prompt ({prompt.size} tokens) "
                            f"even with every slot free — raise "
                            f"ServeConfig.n_pages") from None
                    break
                free.pop(0)
                self.queue.popleft()
                picks.append((i, req))
            if not picks:
                return
        else:
            while free and self.queue:
                picks.append((free.pop(0), self.queue.popleft()))
        self.stats["admitted"] += len(picks)
        # Chunked ingestion (DESIGN.md §12): a pick whose un-resident
        # suffix exceeds the chunk does NOT prefill here — its slot
        # enters `ingesting` and `_ingest_tick` streams the prompt in
        # one chunk per tick, alongside the pool's decode.  Short picks
        # keep the single-shot path (their bucketed widths are <= chunk).
        if self.chunk is not None:
            short: list[tuple[int, Request]] = []
            for i, req in picks:
                n = int(np.asarray(req.prompt).size)
                if n - hists.get(i, 0) > self.chunk:
                    self.slots[i] = _Slot(
                        req=req, key=req.key, emitted=[], last_token=0,
                        admit_step=self.step_count,
                        ingest_pos=hists.get(i, 0), ingesting=True)
                else:
                    short.append((i, req))
            picks = short
        # Bucket the admit group by shared-history page count: one
        # prefill call per distinct hist_pages, each at ITS OWN group-max
        # suffix width.  A mixed-history group no longer pays the widest
        # suffix for every slot (the PR 6 width bug): a prefix-cache hit
        # whose suffix is 3 tokens prefills at width 3 even when a fresh
        # 40-token prompt admits in the same tick.
        buckets: dict[int, list[tuple[int, Request]]] = {}
        for i, req in picks:
            hp = hists.get(i, 0) // self.scfg.page_size \
                if self.paged is not None else 0
            buckets.setdefault(hp, []).append((i, req))
        rows: dict[int, np.ndarray] = {}
        for hp in sorted(buckets):
            rows.update(self._prefill_group(buckets[hp], hists, hp))
        if self.paged is not None:
            # index the now-resident full prompt pages so later
            # admissions with the same prefix reuse them (ingesting
            # slots defer to their final chunk — the index must not
            # advertise pages whose rows are not written yet)
            for i, req in picks:
                self.paged.note_prefilled(
                    i, np.asarray(req.prompt, np.int32).tolist())
            self.stats["shared_prefix_tokens"] = self.paged.shared_tokens
        if self.spec_k and picks:
            self._draft_prefill(picks)
        # first output token comes from the prefill logits (same
        # semantics as serve.generate)
        for i, _ in picks:
            self._emit(i, self._sample(self.slots[i], rows[i]), finished)

    def _prefill_group(self, picks: list[tuple[int, Request]],
                       hists: dict[int, int],
                       hist_pages: int) -> dict[int, np.ndarray]:
        """One ragged prefill call over `picks` (all sharing
        `hist_pages` resident history pages); returns each admitted
        slot's last-token logits row."""
        b = self.scfg.batch
        # with a prefix-cache hit only the un-resident suffix prefills
        maxlen = max(int(np.asarray(r.prompt).size) - hists.get(i, 0)
                     for i, r in picks)
        width = -(-maxlen // self.prefill_bucket) * self.prefill_bucket
        width = min(width, self.scfg.max_seq)
        tokens = np.zeros((b, width), np.int32)
        lengths = np.ones((b,), np.int32)
        mask = np.zeros((b,), bool)
        hist_arr = np.zeros((b,), np.int32)
        for i, req in picks:
            prompt = np.asarray(req.prompt, np.int32).reshape(-1)
            suffix = prompt[hists.get(i, 0):]
            tokens[i, : suffix.size] = suffix
            lengths[i] = suffix.size
            hist_arr[i] = hists.get(i, 0)
            mask[i] = True
            self.slots[i] = _Slot(req=req, key=req.key, emitted=[],
                                  last_token=0, admit_step=self.step_count)
        with self._scope():
            if self.paged is not None:
                logits, self.cache = self._prefill(
                    self.params, jnp.asarray(tokens), self.cache,
                    jnp.asarray(lengths), jnp.asarray(mask),
                    jnp.asarray(self.paged.tables), jnp.asarray(hist_arr),
                    hist_pages=hist_pages)
            else:
                logits, self.cache = self._prefill(
                    self.params, jnp.asarray(tokens), self.cache,
                    jnp.asarray(lengths), jnp.asarray(mask))
        out_rows = np.asarray(logits[:, -1], np.float32)
        self.stats["prefill_calls"] += 1
        self.stats["prefill_widths"].add(width)
        self.stats["prefill_tokens"] += int(lengths[mask].sum())
        self.stats["prefill_width_sum"] += width * len(picks)
        return {i: out_rows[i] for i, _ in picks}

    def _ingest_tick(self, finished: list[Completion]) -> None:
        """Advance every ingesting slot by one `prefill_chunk`-wide
        chunk (DESIGN.md §12).  One fused call covers all ingesting
        slots — `hist_len` is a traced array, so slots at different
        depths (including a first chunk at hist 0) share the trace.  On
        the paged layout slots are grouped by resident page count
        (`hist_pages` is a static arg) and the shallowest group goes
        first: deeper slots wait a tick, bounding retraces exactly like
        the shared-prefix admit buckets.  A slot whose prompt completes
        this tick leaves `ingesting`, emits its first output token from
        the chunk logits, registers its prefix pages, and (when
        speculating) replays its full prompt through the draft cache —
        all the steps the single-shot admit runs, just deferred to the
        final chunk."""
        ing = [(i, s) for i, s in enumerate(self.slots)
               if s is not None and s.ingesting]
        if not ing:
            return
        if self.paged is not None:
            groups: dict[int, list[tuple[int, _Slot]]] = {}
            for i, s in ing:
                groups.setdefault(
                    s.ingest_pos // self.scfg.page_size, []).append((i, s))
            hp = min(groups)
            ing = groups[hp]
        else:
            hp = 0
        b, ch = self.scfg.batch, self.chunk
        tokens = np.zeros((b, ch), np.int32)
        lengths = np.ones((b,), np.int32)
        mask = np.zeros((b,), bool)
        hist_arr = np.zeros((b,), np.int32)
        takes: dict[int, int] = {}
        for i, s in ing:
            prompt = np.asarray(s.req.prompt, np.int32).reshape(-1)
            take = min(ch, prompt.size - s.ingest_pos)
            tokens[i, :take] = prompt[s.ingest_pos:s.ingest_pos + take]
            lengths[i] = take
            hist_arr[i] = s.ingest_pos
            mask[i] = True
            takes[i] = take
        with self._scope():
            if self.paged is not None:
                logits, self.cache = self._chunk_prefill(
                    self.params, jnp.asarray(tokens), self.cache,
                    jnp.asarray(lengths), jnp.asarray(mask),
                    jnp.asarray(self.paged.tables), jnp.asarray(hist_arr),
                    hist_pages=hp)
            else:
                logits, self.cache = self._chunk_prefill(
                    self.params, jnp.asarray(tokens), self.cache,
                    jnp.asarray(lengths), jnp.asarray(mask),
                    jnp.asarray(hist_arr))
        rows = np.asarray(logits[:, -1], np.float32)
        self.stats["prefill_calls"] += 1
        self.stats["prefill_widths"].add(ch)
        self.stats["prefill_tokens"] += sum(takes.values())
        self.stats["prefill_width_sum"] += ch * len(ing)
        done: list[tuple[int, Request]] = []
        for i, s in ing:
            s.ingest_pos += takes[i]
            if s.ingest_pos >= int(np.asarray(s.req.prompt).size):
                s.ingesting = False
                done.append((i, s.req))
        if not done:
            return
        if self.paged is not None:
            for i, req in done:
                self.paged.note_prefilled(
                    i, np.asarray(req.prompt, np.int32).tolist())
            self.stats["shared_prefix_tokens"] = self.paged.shared_tokens
        if self.spec_k:
            self._draft_prefill(done)
        for i, _ in done:
            self._emit(i, self._sample(self.slots[i], rows[i]), finished)

    def _draft_prefill(self, picks: list[tuple[int, Request]]) -> None:
        """Prefill the draft cache with the FULL prompts of the slots
        just admitted (the draft shares no prefixes — its cache is
        private and contiguous).  The logits are discarded: the first
        emitted token comes from the TARGET's prefill row, and the next
        spec tick feeds it back through `draft_propose`."""
        b = self.scfg.batch
        maxlen = max(int(np.asarray(r.prompt).size) for _, r in picks)
        width = -(-maxlen // self.prefill_bucket) * self.prefill_bucket
        width = min(width, self.scfg.max_seq)
        tokens = np.zeros((b, width), np.int32)
        lengths = np.ones((b,), np.int32)
        mask = np.zeros((b,), bool)
        for i, req in picks:
            prompt = np.asarray(req.prompt, np.int32).reshape(-1)
            tokens[i, : prompt.size] = prompt
            lengths[i] = prompt.size
            mask[i] = True
        with self._scope():
            _, self.draft_cache = self._dprefill(
                self.draft_params, jnp.asarray(tokens), self.draft_cache,
                jnp.asarray(lengths), jnp.asarray(mask))

    def _decode_active(self, finished: list[Completion]) -> None:
        # ingesting slots sit decode out: their prompt is still streaming
        # in and they have no token to feed back yet (DESIGN.md §12)
        active = np.asarray(
            [s is not None and not s.ingesting for s in self.slots])
        if not active.any():
            return
        toks = np.asarray(
            [s.last_token if s is not None else 0 for s in self.slots],
            np.int32)[:, None]
        if self.paged is not None:
            # make each active slot's write-frontier page exist (and be
            # private — asserted) before the fused step writes it.  The
            # write position is the slot's clock: prompt_len + emitted - 1
            # (the first emitted token came from prefill, not decode).
            for i, s in enumerate(self.slots):
                if active[i]:
                    pos = (int(np.asarray(s.req.prompt).size)
                           + len(s.emitted) - 1)
                    self.paged.ensure_decode_page(i, pos)
        with self._scope():
            if self.paged is not None:
                logits, self.cache = self._decode(
                    self.params, self.cache, jnp.asarray(toks),
                    jnp.asarray(active), jnp.asarray(self.paged.tables))
            else:
                logits, self.cache = self._decode(
                    self.params, self.cache, jnp.asarray(toks),
                    jnp.asarray(active))
        rows = np.asarray(logits[:, -1], np.float32)
        self.stats["decode_steps"] += 1
        self.stats["decode_tokens"] += int(active.sum())
        for i in range(len(self.slots)):
            if active[i]:
                self._emit(i, self._sample(self.slots[i], rows[i]), finished)

    def _spec_tick(self, finished: list[Completion]) -> None:
        """One speculative tick (DESIGN.md §9): draft k tokens, verify
        all k+1 positions in one fused pass, emit each slot's accepted
        prefix plus the target's correction token, resync the draft.
        Three dispatches replace the k+1 sequential decode steps the
        same tokens would otherwise cost."""
        active = np.asarray(
            [s is not None and not s.ingesting for s in self.slots])
        if not active.any():
            return
        k = self.spec_k
        last = np.asarray(
            [s.last_token if s is not None else 0 for s in self.slots],
            np.int32)
        if self.paged is not None:
            # the verify writes span pos..pos+k: make every page on the
            # span exist (and be private) before the fused pass
            for i, s in enumerate(self.slots):
                if active[i]:
                    pos = (int(np.asarray(s.req.prompt).size)
                           + len(s.emitted) - 1)
                    page = self.paged.page
                    for pg in range(pos // page, (pos + k) // page + 1):
                        self.paged.ensure_decode_page(
                            i, max(pos, pg * page))
        act = jnp.asarray(active)
        with self._scope():
            drafts = self._propose(self.draft_params, self.draft_cache,
                                   jnp.asarray(last), act)
            toks = jnp.concatenate([jnp.asarray(last)[:, None], drafts],
                                   axis=1)
            if self.paged is not None:
                g, n_acc, self.cache = self._verify(
                    self.params, self.cache, toks, act,
                    jnp.asarray(self.paged.tables))
            else:
                g, n_acc, self.cache = self._verify(
                    self.params, self.cache, toks, act)
            self.draft_cache = self._advance(
                self.draft_params, self.draft_cache, toks, n_acc + 1, act)
        g_np = np.asarray(g)
        acc_np = np.asarray(n_acc)
        self.stats["decode_steps"] += 1
        self.stats["spec_ticks"] += 1
        self.stats["draft_tokens"] += k * int(active.sum())
        self.stats["accepted_draft_tokens"] += int(acc_np[active].sum())
        for i in range(len(self.slots)):
            if not active[i]:
                continue
            s = self.slots[i]
            # committed write frontier BEFORE this tick's emissions
            t0 = int(np.asarray(s.req.prompt).size) + len(s.emitted) - 1
            for j in range(int(acc_np[i]) + 1):
                if self.slots[i] is None:  # EOS/budget mid-window
                    break
                self._emit(i, int(g_np[i, j]), finished)
                self.stats["decode_tokens"] += 1
            if self.paged is not None and self.slots[i] is not None:
                # clock-decrement rollback happened in-graph; release
                # any page now holding only rejected rows.  The last
                # committed row is t0 + n_acc (keep = n_acc + 1 rows
                # starting at t0).
                self.paged.rollback(i, t0 + int(acc_np[i]))

    # -- driver ------------------------------------------------------------

    def step(self) -> list[Completion]:
        """One scheduler tick: admit into free slots, advance chunked
        ingestion, then one fused decode (or draft/verify/resync, when
        speculating) over the pool.  Returns requests finished this
        tick."""
        finished: list[Completion] = []
        self._admit(finished)
        if self.chunk is not None:
            self._ingest_tick(finished)
        if self.spec_k:
            self._spec_tick(finished)
        else:
            self._decode_active(finished)
        self.step_count += 1
        return finished

    def run(self, requests=(), *, max_steps: int | None = None
            ) -> dict[int, Completion]:
        """Submit `requests`, drive until queue and pool drain, and
        return {uid: Completion}."""
        for r in requests:
            self.submit(r)
        steps = 0
        while self.queue or self.n_active:
            self.step()
            steps += 1
            if max_steps is not None and steps >= max_steps:
                raise RuntimeError(
                    f"scheduler did not drain in {max_steps} steps "
                    f"({self.n_active} active, {len(self.queue)} queued)")
        return self.completions

    def serve_async(self, *, max_queue: int = 0,
                    start: bool = True) -> "AsyncServer":
        """Wrap this scheduler in the async ingestion plane (DESIGN.md
        §12): a worker thread drives the tick loop, callers submit
        through a bounded queue and get a Future per request.  The
        scheduler must not be stepped directly while the server is
        running — the worker owns it."""
        return AsyncServer(self, max_queue=max_queue, start=start)


class AsyncServer:
    """Async ingestion plane over a `Scheduler` (DESIGN.md §12).

    One worker thread owns the scheduler: it drains the submission
    queue into `Scheduler.submit` and drives `step()` while there is
    work, blocking on the queue when idle — the jitted step never runs
    concurrently with itself, so no lock guards the cache.  Callers
    touch only the queue and the returned futures:

        with sched.serve_async(max_queue=32) as srv:
            futs = [srv.submit(r) for r in requests]
            outs = [f.result() for f in futs]

    Backpressure: with `max_queue > 0`, `submit` blocks while the queue
    is full (bounding the submission rate to the service rate); pass
    `timeout=` to get `queue.Full` instead of blocking.  Requests the
    scheduler rejects (validation errors) surface on the request's
    Future, not in the worker.  `shutdown()` stops intake, lets the
    worker drain everything already submitted, and joins it."""

    _IDLE_POLL = 0.05  # seconds the idle worker blocks per queue wait

    def __init__(self, sched: Scheduler, *, max_queue: int = 0,
                 start: bool = True):
        if max_queue < 0:
            raise ValueError(f"max_queue must be >= 0: {max_queue}")
        self._sched = sched
        self._q: queue.Queue = queue.Queue(maxsize=max_queue)
        self._futures: dict[int, concurrent.futures.Future] = {}
        self._stop = threading.Event()
        self._started = False
        self._thread = threading.Thread(
            target=self._worker, name="serve-async-worker", daemon=True)
        if start:
            self.start()

    def start(self) -> None:
        if not self._started:
            self._started = True
            self._thread.start()

    def submit(self, req: Request,
               timeout: float | None = None) -> concurrent.futures.Future:
        """Queue `req`; returns a Future resolving to its Completion.
        Blocks while the bounded queue is full (backpressure); with
        `timeout=` raises `queue.Full` instead.  Raises RuntimeError
        after `shutdown`."""
        if self._stop.is_set():
            raise RuntimeError("submit after shutdown")
        fut: concurrent.futures.Future = concurrent.futures.Future()
        self._q.put((req, fut), timeout=timeout)
        return fut

    def shutdown(self, wait: bool = True) -> None:
        """Stop intake; the worker drains every request already queued
        or in flight, then exits.  `wait=True` joins it."""
        self._stop.set()
        if wait and self._started:
            self._thread.join()

    def __enter__(self) -> "AsyncServer":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # -- worker side -------------------------------------------------------

    def _intake(self, item) -> None:
        req, fut = item
        try:
            self._sched.submit(req)
        except Exception as e:  # validation error -> the caller's future
            fut.set_exception(e)
            return
        self._futures[req.uid] = fut

    def _drain_submissions(self) -> None:
        while True:
            try:
                self._intake(self._q.get_nowait())
            except queue.Empty:
                return

    def _worker(self) -> None:
        sched = self._sched
        while True:
            self._drain_submissions()
            if sched.queue or sched.n_active:
                for comp in sched.step():
                    fut = self._futures.pop(comp.uid, None)
                    if fut is not None:
                        fut.set_result(comp)
            elif self._stop.is_set() and self._q.empty():
                return
            else:  # idle: block on the queue instead of spinning
                try:
                    self._intake(self._q.get(timeout=self._IDLE_POLL))
                except queue.Empty:
                    pass
