"""Continuous-batching serve scheduler over one persistent KV cache.

The static-batch `serve.generate` loop pads every request to one
rectangle: same-length prompts only, finished sequences burn decode
compute until the longest one ends, and new requests wait for the whole
batch to drain.  ReDas's own lesson — reconfigure per layer instead of
padding work to a fixed shape — applies to the serving plane too, and
the model layer already supports it: `flash_attention` takes per-slot
`q_pos`/`kv_len`, and the cache clock `cache["t"]` is a per-slot vector.

`Scheduler` owns a fixed pool of `ServeConfig.batch` slots over ONE
persistent cache:

  admit   queued requests enter free slots via a ragged prefill
          (`transformer.prefill(lengths=..., update_mask=...)`): each
          prompt is written at its slot with per-slot positions/clock,
          in-flight slots untouched.  The first output token is sampled
          from the prefill logits.
  decode  one fused `decode_step` over the whole pool with an `active`
          mask — the call shapes NEVER change, so the jitted step (and
          the `repro.engine` decision cache behind it) is reused for
          every step the scheduler ever takes.
  evict   EOS / max-tokens frees the slot immediately for the next
          queued request; no cache scrubbing is needed because a slot's
          clock masks stale rows and the next admit overwrites its
          recurrent state.

Prefill is the only shape-variable call: prompt widths are rounded up
to `prefill_bucket` (1 = exact group max — bitwise-parity mode; larger
buckets bound jit retraces to O(max_seq / bucket) distinct widths).

Greedy outputs match per-request `serve.generate` exactly for every
cache kind; the one caveat is MoE capacity dropping: expert capacity
scales with the CALL's padded width, so at drop-inducing capacity
factors an MoE request's dropped tokens can depend on its admit
group's width (DESIGN.md §6) — exactly the width dependence the
static `generate` path already has versus `forward`.
"""

from __future__ import annotations

import collections
import contextlib
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine as engine_mod
from repro.models import transformer as T
from repro.models.config import ArchConfig

from . import serve as serve_lib
from .paged import PagedKV, PoolExhausted


@dataclasses.dataclass
class Request:
    """One generation request: `prompt` (L,) int32, emit up to
    `max_new_tokens` (stopping early at `eos_id` if given)."""
    uid: int
    prompt: np.ndarray
    max_new_tokens: int
    temperature: float = 0.0
    key: jax.Array | None = None
    eos_id: int | None = None


@dataclasses.dataclass
class Completion:
    uid: int
    tokens: np.ndarray           # (n_emitted,) int32
    finish_reason: str           # "length" | "eos"
    prompt_len: int
    admit_step: int
    finish_step: int


@dataclasses.dataclass
class _Slot:
    req: Request
    key: jax.Array | None
    emitted: list[int]
    last_token: int
    admit_step: int


@functools.lru_cache(maxsize=64)
def _jitted_steps(cfg: ArchConfig, scfg: serve_lib.ServeConfig, engine,
                  paged: bool = False):
    """One jitted (ragged prefill, masked decode) pair per posture, so
    every Scheduler instance over the same configs reuses the traced
    executables.  The engine joins the key because traces bind the
    engine context active when first taken (DESIGN.md §3).  The paged
    pair additionally threads the block tables (and the shared-prefix
    history: `hist_pages` is static — one retrace per distinct history
    page count, same O(max_seq / page) bound the prefill widths have)."""
    if paged:
        def _paged_prefill(p, tok, cache, lens, mask, bt, hist, *,
                           hist_pages):
            return T.prefill(p, cfg, tok, cache,
                             compute_dtype=scfg.compute_dtype, lengths=lens,
                             update_mask=mask, block_tables=bt,
                             hist_len=hist, hist_pages=hist_pages)

        prefill = jax.jit(_paged_prefill, static_argnames=("hist_pages",))
        decode = jax.jit(
            lambda p, cache, tok, act, bt: T.decode_step(
                p, cfg, cache, tok, compute_dtype=scfg.compute_dtype,
                active=act, block_tables=bt))
        return prefill, decode
    prefill = jax.jit(
        lambda p, tok, cache, lens, mask: T.prefill(
            p, cfg, tok, cache, compute_dtype=scfg.compute_dtype,
            lengths=lens, update_mask=mask))
    decode = jax.jit(
        lambda p, cache, tok, act: T.decode_step(
            p, cfg, cache, tok, compute_dtype=scfg.compute_dtype,
            active=act))
    return prefill, decode


class Scheduler:
    """Engine-aware continuous-batching loop over a slot pool.

    `params` must already be in serving dtype.  `engine` overrides the
    `ServeConfig`-derived one (`serve.warm_start_engine`); all jit
    traces happen inside its scope so every matmul shares one decision
    cache (`engine.plan.stats()` shows hits once shapes repeat)."""

    def __init__(self, params, cfg: ArchConfig, scfg: serve_lib.ServeConfig,
                 *, engine: "engine_mod.Engine | None" = None,
                 prefill_bucket: int = 1):
        if cfg.kind == "encoder":
            raise ValueError("encoder-only arch: no decode step")
        if cfg.embed_inputs or cfg.prefix_tokens:
            raise NotImplementedError(
                "scheduler serves token prompts only (no embeds/VLM prefix)")
        if prefill_bucket < 1:
            raise ValueError(f"prefill_bucket must be >= 1: {prefill_bucket}")
        self.params = params
        self.cfg = cfg
        self.scfg = scfg
        self.prefill_bucket = prefill_bucket
        self.engine = (engine if engine is not None
                       else serve_lib.warm_start_engine(scfg))
        self.cache = serve_lib.init_cache(cfg, scfg)
        # the paged plane is live only when the arch HAS full-attention
        # layers to page (on window/SSM/RG-LRU-only archs a paged
        # ServeConfig builds the identical contiguous cache and runs the
        # contiguous code path — paging those kinds buys nothing).
        # Prefix sharing needs EVERY layer's prompt state to live in
        # shareable pages, so it arms on pure-attention archs only.
        self.paged: PagedKV | None = None
        if scfg.cache_layout == "paged" and "attn" in cfg.layer_pattern:
            self.paged = PagedKV(
                batch=scfg.batch, max_seq=scfg.max_seq,
                page_size=scfg.page_size, n_pages=scfg.resolved_n_pages,
                prefix_sharing=set(cfg.layer_pattern) == {"attn"})
        self.slots: list[_Slot | None] = [None] * scfg.batch
        self.queue: collections.deque[Request] = collections.deque()
        self.completions: dict[int, Completion] = {}
        self.step_count = 0
        self.stats = {"admitted": 0, "finished": 0, "prefill_calls": 0,
                      "decode_steps": 0, "decode_tokens": 0,
                      "prefill_widths": set(),
                      # prefilled token/width totals: the FLOP-relevant
                      # counters prefix sharing drives DOWN (the PR 6
                      # bench's reuse ratio and the sharing tests key on
                      # these, like PR 4's decode-call counter)
                      "prefill_tokens": 0, "prefill_width_sum": 0,
                      "shared_prefix_tokens": 0}
        self._live_uids: set[int] = set()
        self._prefill, self._decode = _jitted_steps(
            cfg, scfg, self.engine, self.paged is not None)

    # -- request intake ----------------------------------------------------

    def submit(self, req: Request) -> None:
        n = int(np.asarray(req.prompt).size)
        if n < 1:
            raise ValueError(f"request {req.uid}: empty prompt")
        if req.max_new_tokens < 1:
            raise ValueError(f"request {req.uid}: max_new_tokens < 1")
        if n + req.max_new_tokens > self.scfg.max_seq:
            raise ValueError(
                f"request {req.uid}: prompt {n} + max_new "
                f"{req.max_new_tokens} exceeds max_seq {self.scfg.max_seq}")
        if req.temperature > 0.0 and req.key is None:
            raise ValueError(
                f"request {req.uid}: temperature > 0 needs a PRNG key")
        if req.uid in self._live_uids:  # queued, in flight, or completed
            raise ValueError(f"duplicate request uid {req.uid}")
        self._live_uids.add(req.uid)
        self.queue.append(req)

    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self.slots)

    def _scope(self):
        return (engine_mod.use_engine(self.engine)
                if self.engine is not None else contextlib.nullcontext())

    # -- sampling (host-side, per slot: each request owns its key) ---------

    def _sample(self, slot: _Slot, logits_row: np.ndarray) -> int:
        if slot.req.temperature > 0.0:
            slot.key, sub = jax.random.split(slot.key)
            return int(jax.random.categorical(
                sub, jnp.asarray(logits_row) / slot.req.temperature))
        return int(np.argmax(logits_row))

    def _emit(self, i: int, tok: int, finished: list[Completion]) -> None:
        """Record one sampled token for slot i; evict on EOS/budget."""
        slot = self.slots[i]
        slot.emitted.append(tok)
        slot.last_token = tok
        done_eos = slot.req.eos_id is not None and tok == slot.req.eos_id
        done_len = len(slot.emitted) >= slot.req.max_new_tokens
        if done_eos or done_len:
            comp = Completion(
                uid=slot.req.uid,
                tokens=np.asarray(slot.emitted, np.int32),
                finish_reason="eos" if done_eos else "length",
                prompt_len=int(np.asarray(slot.req.prompt).size),
                admit_step=slot.admit_step, finish_step=self.step_count)
            self.completions[slot.req.uid] = comp
            finished.append(comp)
            self.slots[i] = None  # slot free for the next queued request
            if self.paged is not None:
                # deref the slot's pages: private ones free immediately,
                # shared ones live on in other slots / the prefix index
                self.paged.release(i)
            self.stats["finished"] += 1

    # -- the two batch calls ----------------------------------------------

    def _admit(self, finished: list[Completion]) -> None:
        free = [i for i, s in enumerate(self.slots) if s is None]
        if not free or not self.queue:
            return
        picks: list[tuple[int, Request]] = []
        hists: dict[int, int] = {}
        if self.paged is not None:
            # peek-then-pop: PoolExhausted leaves the request queued
            # (backpressure — completions will free pages) instead of
            # dropping it.  Stuck with every slot free means the pool
            # genuinely cannot hold the prompt: fail with intent.
            while free and self.queue:
                i, req = free[0], self.queue[0]
                prompt = np.asarray(req.prompt, np.int32).reshape(-1)
                try:
                    hists[i] = self.paged.admit(i, prompt.tolist())
                except PoolExhausted:
                    if not picks and self.n_active == 0:
                        raise RuntimeError(
                            f"page pool ({self.paged.n_pages} pages of "
                            f"{self.paged.page}) cannot hold request "
                            f"{req.uid}'s prompt ({prompt.size} tokens) "
                            f"even with every slot free — raise "
                            f"ServeConfig.n_pages") from None
                    break
                free.pop(0)
                self.queue.popleft()
                picks.append((i, req))
            if not picks:
                return
        else:
            while free and self.queue:
                picks.append((free.pop(0), self.queue.popleft()))
        b = self.scfg.batch
        # with a prefix-cache hit only the un-resident suffix prefills
        maxlen = max(int(np.asarray(r.prompt).size) - hists.get(i, 0)
                     for i, r in picks)
        width = -(-maxlen // self.prefill_bucket) * self.prefill_bucket
        width = min(width, self.scfg.max_seq)
        tokens = np.zeros((b, width), np.int32)
        lengths = np.ones((b,), np.int32)
        mask = np.zeros((b,), bool)
        hist_arr = np.zeros((b,), np.int32)
        for i, req in picks:
            prompt = np.asarray(req.prompt, np.int32).reshape(-1)
            suffix = prompt[hists.get(i, 0):]
            tokens[i, : suffix.size] = suffix
            lengths[i] = suffix.size
            hist_arr[i] = hists.get(i, 0)
            mask[i] = True
            self.slots[i] = _Slot(req=req, key=req.key, emitted=[],
                                  last_token=0, admit_step=self.step_count)
        with self._scope():
            if self.paged is not None:
                hist_pages = int(hist_arr.max()) // self.scfg.page_size
                logits, self.cache = self._prefill(
                    self.params, jnp.asarray(tokens), self.cache,
                    jnp.asarray(lengths), jnp.asarray(mask),
                    jnp.asarray(self.paged.tables), jnp.asarray(hist_arr),
                    hist_pages=hist_pages)
            else:
                logits, self.cache = self._prefill(
                    self.params, jnp.asarray(tokens), self.cache,
                    jnp.asarray(lengths), jnp.asarray(mask))
        if self.paged is not None:
            # index the now-resident full prompt pages so later
            # admissions with the same prefix reuse them
            for i, req in picks:
                self.paged.note_prefilled(
                    i, np.asarray(req.prompt, np.int32).tolist())
            self.stats["shared_prefix_tokens"] = self.paged.shared_tokens
        rows = np.asarray(logits[:, -1], np.float32)
        self.stats["admitted"] += len(picks)
        self.stats["prefill_calls"] += 1
        self.stats["prefill_widths"].add(width)
        self.stats["prefill_tokens"] += int(lengths[mask].sum())
        self.stats["prefill_width_sum"] += width
        # first output token comes from the prefill logits (same
        # semantics as serve.generate)
        for i, _ in picks:
            self._emit(i, self._sample(self.slots[i], rows[i]), finished)

    def _decode_active(self, finished: list[Completion]) -> None:
        active = np.asarray([s is not None for s in self.slots])
        if not active.any():
            return
        toks = np.asarray(
            [s.last_token if s is not None else 0 for s in self.slots],
            np.int32)[:, None]
        if self.paged is not None:
            # make each active slot's write-frontier page exist (and be
            # private — asserted) before the fused step writes it.  The
            # write position is the slot's clock: prompt_len + emitted - 1
            # (the first emitted token came from prefill, not decode).
            for i, s in enumerate(self.slots):
                if s is not None:
                    pos = (int(np.asarray(s.req.prompt).size)
                           + len(s.emitted) - 1)
                    self.paged.ensure_decode_page(i, pos)
        with self._scope():
            if self.paged is not None:
                logits, self.cache = self._decode(
                    self.params, self.cache, jnp.asarray(toks),
                    jnp.asarray(active), jnp.asarray(self.paged.tables))
            else:
                logits, self.cache = self._decode(
                    self.params, self.cache, jnp.asarray(toks),
                    jnp.asarray(active))
        rows = np.asarray(logits[:, -1], np.float32)
        self.stats["decode_steps"] += 1
        self.stats["decode_tokens"] += int(active.sum())
        for i in range(len(self.slots)):
            if active[i]:
                self._emit(i, self._sample(self.slots[i], rows[i]), finished)

    # -- driver ------------------------------------------------------------

    def step(self) -> list[Completion]:
        """One scheduler tick: admit into free slots, then one fused
        decode over the pool.  Returns requests finished this tick."""
        finished: list[Completion] = []
        self._admit(finished)
        self._decode_active(finished)
        self.step_count += 1
        return finished

    def run(self, requests=(), *, max_steps: int | None = None
            ) -> dict[int, Completion]:
        """Submit `requests`, drive until queue and pool drain, and
        return {uid: Completion}."""
        for r in requests:
            self.submit(r)
        steps = 0
        while self.queue or self.n_active:
            self.step()
            steps += 1
            if max_steps is not None and steps >= max_steps:
                raise RuntimeError(
                    f"scheduler did not drain in {max_steps} steps "
                    f"({self.n_active} active, {len(self.queue)} queued)")
        return self.completions
