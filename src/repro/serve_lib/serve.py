"""Serving: batched prefill + decode over the per-arch cache pytree.

Cache kinds (models/transformer.init_cache):
  full attention  -> (B, max_seq, KV, hd) per layer, seq shardable over
                     'data' for long contexts (the SP decode path);
  sliding window  -> ring buffer of `window` slots;
  SSM / RG-LRU    -> O(1) recurrent state.

`generate` is the end-to-end driver: greedy (or temperature) sampling
with the decode loop as a host loop of jitted steps — each step is one
XLA program, so serving latency is step-latency x tokens.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.dist import sharding as shd
from repro.models import transformer as T
from repro.models.config import ArchConfig


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_seq: int
    batch: int
    compute_dtype: object = jnp.bfloat16
    cache_dtype: object = jnp.bfloat16


def init_cache(cfg: ArchConfig, scfg: ServeConfig):
    return T.init_cache(cfg, T.CacheSpec(scfg.max_seq, scfg.batch),
                        dtype=scfg.cache_dtype)


def make_prefill_step(cfg: ArchConfig, scfg: ServeConfig):
    def prefill_step(params, tokens, cache, embeds=None):
        return T.prefill(params, cfg, tokens, cache, embeds=embeds,
                         compute_dtype=scfg.compute_dtype)
    return prefill_step


def make_decode_step(cfg: ArchConfig, scfg: ServeConfig):
    def decode_step(params, cache, token):
        return T.decode_step(params, cfg, cache, token,
                             compute_dtype=scfg.compute_dtype)
    return decode_step


def generate(params, cfg: ArchConfig, scfg: ServeConfig, prompt: jax.Array,
             n_tokens: int, *, temperature: float = 0.0, key=None,
             embeds=None):
    """prompt (B, S_prompt) int32 -> (B, n_tokens) greedy/sampled tokens."""
    prefill_step = jax.jit(make_prefill_step(cfg, scfg))
    decode_step = jax.jit(make_decode_step(cfg, scfg))
    mesh = shd.active_mesh()
    if mesh is not None:
        # Place params (TP/FSDP rule table) before the first step, and
        # build the cache *born sharded* (seq over 'data') — a long-
        # context cache may not fit any single device — DESIGN.md §5.
        params = jax.device_put(params, shd.params_shardings(params, mesh))
        cache_sh = shd.cache_shardings(
            jax.eval_shape(lambda: init_cache(cfg, scfg)), mesh)
        cache = jax.jit(lambda: init_cache(cfg, scfg),
                        out_shardings=cache_sh)()
    else:
        cache = init_cache(cfg, scfg)
    logits, cache = prefill_step(params, prompt, cache, embeds)

    outs = []
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    for i in range(n_tokens):
        outs.append(tok)
        logits, cache = decode_step(params, cache, tok)
        if temperature > 0.0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits[:, -1] / temperature)[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    return jnp.concatenate(outs, axis=1)
