"""Serving: batched prefill + decode over the per-arch cache pytree.

Cache kinds (models/transformer.init_cache):
  full attention  -> (B, max_seq, KV, hd) per layer, seq shardable over
                     'data' for long contexts (the SP decode path);
  sliding window  -> ring buffer of `window` slots;
  SSM / RG-LRU    -> O(1) recurrent state.

`generate` is the end-to-end driver: greedy (or temperature) sampling
with the decode loop as a host loop of jitted steps — each step is one
XLA program, so serving latency is step-latency x tokens.

Kernel dispatch goes through `repro.engine` when
`ServeConfig.kernel_backend` is set: prefill and decode trace inside one
engine context so every matmul shares the unified decision cache, and
`warm_start_engine` loads a saved `ExecutionPlan` JSON so the first
trace reuses decisions planned offline instead of re-searching.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro import engine as engine_mod
from repro.dist import sharding as shd
from repro.models import transformer as T
from repro.models.config import ArchConfig


#: cache dtypes `models.transformer.init_cache` can represent.  int8
#: selects the quantized KV codec (rows + per-row scales, DESIGN.md §7).
SUPPORTED_CACHE_DTYPES = ("float32", "bfloat16", "float16", "int8")

def validate_cache_dtype(cache_dtype, cfg=None):
    """THE cache-dtype validator (ServeConfig and `init_cache` both
    route through it): normalizes to `jnp.dtype`, rejects dtypes the
    cache layout cannot represent, and — given the arch — rejects
    quantized combos that would quantize nothing (int8 SSM / RG-LRU
    state is unsupported; recurrent state stays bf16)."""
    try:
        dt = jnp.dtype(cache_dtype)
    except TypeError as e:
        raise ValueError(f"cache_dtype {cache_dtype!r} is not a dtype: {e}") from None
    if dt.name not in SUPPORTED_CACHE_DTYPES:
        raise ValueError(
            f"cache_dtype {dt.name!r} is not a supported cache dtype "
            f"(supported: {', '.join(SUPPORTED_CACHE_DTYPES)}; 'int8' "
            f"selects the quantized KV codec — DESIGN.md §7)")
    if cfg is not None and dt == jnp.dtype(jnp.int8):
        kinds = set(cfg.layer_pattern)
        if not kinds & {"attn", "local"}:
            raise ValueError(
                f"cache_dtype='int8' quantizes attention/sliding-window "
                f"KV rows only, but this arch's layer pattern "
                f"{cfg.layer_pattern} has no such layers — int8 "
                f"SSM/RG-LRU state is unsupported (recurrent state is "
                f"read-modify-write every step and stays bf16); use "
                f"cache_dtype='bfloat16' for this arch")
    return dt


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_seq: int
    batch: int
    compute_dtype: object = jnp.bfloat16
    cache_dtype: object = jnp.bfloat16
    # repro.engine backend for every model matmul (None -> XLA-native).
    kernel_backend: str | None = None
    # optional ExecutionPlan JSON to warm-start the decision cache from.
    plan_path: str | None = None
    # int8 matmul plane (ISSUE 5): route every engine matmul through an
    # int8 backend (upgrading `kernel_backend` to its int8 sibling) and
    # expect `quant.quantize_params` weights.  Orthogonal to
    # cache_dtype="int8" (the KV codec); launch/serve --quantize sets both.
    quantize: bool = False
    # structured-sparsity plane (ISSUE 8): "N:M" (e.g. "2:4") upgrades
    # `kernel_backend` to its sparse sibling and expects
    # `sparse.prune_params` weights.  Composes with quantize=True
    # (sparse×int8: prune_params(..., quantize=True) storage — the
    # sparse backends dispatch it, the KV codec stays the quantize
    # knob's job).
    sparsity: str | None = None
    # KV layout (DESIGN.md §8): "paged" moves full-attention KV into a
    # page pool behind per-slot block tables (scheduler-only; enables
    # cross-request prefix sharing).  "contiguous" is the PR 4 layout
    # and stays the parity oracle.
    cache_layout: str = "contiguous"
    page_size: int = 16
    # pool size in pages; None -> batch * slot_pages + 2 * slot_pages
    # (every slot can always fill, plus headroom so the prefix index
    # retains entries across evictions)
    n_pages: int | None = None
    # Speculative decoding (DESIGN.md §9): k > 0 makes every scheduler
    # tick propose k draft tokens and verify them in one fused k+1-wide
    # pass.  Greedy-only; accepted streams are bitwise identical to
    # target-only decode.
    speculate_k: int = 0
    # draft choice: None/"self" shares the target params (accept ~= 1 —
    # the fused-dispatch win); "self-int8" drafts with an int8-quantized
    # copy (nearly free under the PR 5 posture, exercises rejection).
    # Scheduler(draft_params=, draft_cfg=) overrides with an explicit
    # small arch.
    draft: str | None = None
    # Chunked prefill (DESIGN.md §12): a prompt whose un-resident suffix
    # exceeds this many tokens streams into its slot one fixed-width
    # chunk per scheduler tick, interleaved with decode — in-flight
    # slots keep emitting instead of stalling behind one long prompt.
    # None (default) keeps the single-shot admit.  Chunk calls are
    # always exactly this wide (ONE extra jit trace); on the paged
    # layout the chunk must be page-aligned so every chunk boundary is
    # a page boundary.
    prefill_chunk: int | None = None

    def __post_init__(self):
        # Normalize to jnp.dtype so "bfloat16", jnp.bfloat16 and
        # np.dtype("bfloat16") spell EQUAL (and equally hashable)
        # configs — otherwise the _ENGINES memo below silently builds
        # one engine (and decision cache) per spelling.
        compute = jnp.dtype(self.compute_dtype)
        if not jnp.issubdtype(compute, jnp.floating):
            raise ValueError(
                f"compute_dtype must be floating ({compute.name!r} given); "
                f"int8 compute is selected via quantize=True / an int8 "
                f"kernel_backend, which quantizes inside the kernel")
        object.__setattr__(self, "compute_dtype", compute)
        object.__setattr__(self, "cache_dtype",
                           validate_cache_dtype(self.cache_dtype))
        if self.quantize:
            object.__setattr__(
                self, "kernel_backend",
                engine_mod.int8_sibling(self.kernel_backend))
        if self.sparsity is not None:
            from repro.sparse import parse_sparsity

            parse_sparsity(self.sparsity)  # validate "N:M" early
            # after the int8 upgrade on purpose: sparse subsumes int8
            # (sparse×int8 stores int8 values inside the SparseTensor)
            object.__setattr__(
                self, "kernel_backend",
                engine_mod.sparse_sibling(self.kernel_backend))
        if self.cache_layout not in ("contiguous", "paged"):
            raise ValueError(
                f"cache_layout {self.cache_layout!r} is not one of "
                f"('contiguous', 'paged')")
        if self.cache_layout == "paged":
            if self.page_size < 1:
                raise ValueError(f"page_size must be >= 1: {self.page_size}")
            if self.n_pages is not None and self.n_pages < self.slot_pages:
                raise ValueError(
                    f"n_pages={self.n_pages} cannot hold even one full "
                    f"slot ({self.slot_pages} pages for max_seq="
                    f"{self.max_seq} at page_size={self.page_size})")
        if self.prefill_chunk is not None:
            if self.prefill_chunk < 1:
                raise ValueError(
                    f"prefill_chunk must be >= 1: {self.prefill_chunk}")
            if self.prefill_chunk > self.max_seq:
                raise ValueError(
                    f"prefill_chunk {self.prefill_chunk} exceeds max_seq "
                    f"{self.max_seq} — a chunk wider than the cache can "
                    f"never fill")
            if (self.cache_layout == "paged"
                    and self.prefill_chunk % self.page_size):
                raise ValueError(
                    f"prefill_chunk {self.prefill_chunk} is not a multiple "
                    f"of page_size {self.page_size}: paged chunk "
                    f"continuation gathers whole resident pages, so every "
                    f"chunk boundary must be a page boundary")
        if self.speculate_k < 0:
            raise ValueError(f"speculate_k must be >= 0: {self.speculate_k}")
        if self.draft is not None:
            if self.speculate_k == 0:
                raise ValueError("draft= needs speculate_k > 0")
            if self.draft not in ("self", "self-int8"):
                raise ValueError(
                    f"draft {self.draft!r} is not one of ('self', "
                    f"'self-int8'); pass an explicit small arch via "
                    f"Scheduler(draft_params=, draft_cfg=)")

    @property
    def slot_pages(self) -> int:
        """Block-table width: pages one slot needs for max_seq rows."""
        return -(-self.max_seq // self.page_size)

    @property
    def resolved_n_pages(self) -> int:
        """Pool size: explicit `n_pages`, or a default sized so every
        slot can always allocate its worst case (no admission deadlock)
        with two slots' worth of headroom for retained prefix pages."""
        if self.n_pages is not None:
            return self.n_pages
        return self.batch * self.slot_pages + 2 * self.slot_pages


# One engine per ServeConfig (frozen, hashable): repeated generate()
# calls share the decision memo instead of re-reading the plan JSON.
_ENGINES: dict[ServeConfig, "engine_mod.Engine"] = {}


def warm_start_engine(scfg: ServeConfig) -> "engine_mod.Engine | None":
    """Build (once per ServeConfig) the serving engine: `kernel_backend`
    selects the registry backend, `plan_path` (an `ExecutionPlan.save`
    artifact) pre-fills the decision cache so first-trace planning cost
    drops to lookups."""
    if scfg.kernel_backend is None:
        return None
    cached = _ENGINES.get(scfg)
    if cached is not None:
        return cached
    plan = None
    if scfg.plan_path:
        plan = engine_mod.ExecutionPlan.load(scfg.plan_path)
        # dtype width is part of the decision-cache key: a plan built for
        # another compute dtype would silently miss on every lookup.  On
        # an int8 backend every request keys at width 1 regardless of the
        # float dtype the arrays carry (engine.backend_in_bytes).
        want = engine_mod.backend_in_bytes(
            scfg.kernel_backend, jnp.dtype(scfg.compute_dtype).itemsize)
        if len(plan) and not any(req.in_bytes == want for req, _ in plan):
            import warnings
            warnings.warn(
                f"warm-start plan {scfg.plan_path!r} holds no decisions "
                f"for in_bytes={want} (compute_dtype="
                f"{jnp.dtype(scfg.compute_dtype).name}, backend="
                f"{scfg.kernel_backend!r}); every lookup "
                f"will miss — re-plan with plan_arch(dtype_bytes={want})",
                UserWarning, stacklevel=2)
    eng = engine_mod.Engine(backend=scfg.kernel_backend, plan=plan)
    _ENGINES[scfg] = eng
    return eng


def init_cache(cfg: ArchConfig, scfg: ServeConfig):
    # arch-aware half of the shared validator: ServeConfig can't see the
    # layer pattern, so unsupported quantized combos (int8 on an
    # attention-free arch) are rejected HERE — config time, with an
    # actionable message, not deep inside a jitted cache init.
    validate_cache_dtype(scfg.cache_dtype, cfg)
    paged = scfg.cache_layout == "paged"
    spec = T.CacheSpec(
        scfg.max_seq, scfg.batch,
        page_size=scfg.page_size if paged else None,
        n_pages=scfg.resolved_n_pages if paged else None)
    return T.init_cache(cfg, spec, dtype=scfg.cache_dtype)


def make_prefill_step(cfg: ArchConfig, scfg: ServeConfig):
    def prefill_step(params, tokens, cache, embeds=None):
        return T.prefill(params, cfg, tokens, cache, embeds=embeds,
                         compute_dtype=scfg.compute_dtype)
    return prefill_step


def make_decode_step(cfg: ArchConfig, scfg: ServeConfig):
    def decode_step(params, cache, token):
        return T.decode_step(params, cfg, cache, token,
                             compute_dtype=scfg.compute_dtype)
    return decode_step


@functools.lru_cache(maxsize=64)
def _jitted_cache_init(cfg: ArchConfig, scfg: ServeConfig, mesh):
    """One jitted sharded-cache initializer per (cfg, scfg, mesh):
    the cache is born sharded (seq over 'data' — a long-context cache
    may not fit any single device, DESIGN.md §5) and repeated
    `generate` calls on the same posture reuse the traced executable
    instead of re-jitting the initializer per call."""
    cache_sh = shd.cache_shardings(
        jax.eval_shape(lambda: init_cache(cfg, scfg)), mesh)
    return jax.jit(lambda: init_cache(cfg, scfg), out_shardings=cache_sh)


@functools.lru_cache(maxsize=64)
def _jitted_steps(cfg: ArchConfig, scfg: ServeConfig, engine):
    """One jitted (prefill, decode) pair per (cfg, scfg, engine):
    repeated `generate` calls reuse the traced executables instead of
    re-jitting (and re-tracing) per call.  The engine is part of the
    key because traces bind the engine context active when they are
    FIRST taken (the §3 trace-time caveat) — a different engine must
    not silently reuse another engine's kernels."""
    return (jax.jit(make_prefill_step(cfg, scfg)),
            jax.jit(make_decode_step(cfg, scfg)))


def generate(params, cfg: ArchConfig, scfg: ServeConfig, prompt: jax.Array,
             n_tokens: int, *, temperature: float = 0.0, key=None,
             embeds=None, engine: "engine_mod.Engine | None" = None):
    """prompt (B, S_prompt) int32 -> (B, n_tokens) greedy/sampled tokens.

    `engine` overrides the `ServeConfig`-derived one (pass a shared
    Engine to keep one decision cache across many generate calls)."""
    if n_tokens < 1:
        raise ValueError(f"n_tokens must be >= 1, got {n_tokens}")
    if scfg.cache_layout == "paged":
        raise NotImplementedError(
            "generate() serves the contiguous layout only; the paged "
            "layout needs the block-table plane the continuous-batching "
            "Scheduler owns (serve_lib.Scheduler, DESIGN.md §8)")
    if temperature > 0.0 and key is None:
        raise ValueError(
            "generate(temperature>0) samples and needs a PRNG key — pass "
            "key=jax.random.PRNGKey(...) (or temperature=0.0 for greedy)")
    eng = engine if engine is not None else warm_start_engine(scfg)
    scope = (engine_mod.use_engine(eng) if eng is not None
             else contextlib.nullcontext())
    with scope:
        return _generate(params, cfg, scfg, prompt, n_tokens,
                         temperature=temperature, key=key, embeds=embeds)


def _generate(params, cfg: ArchConfig, scfg: ServeConfig, prompt: jax.Array,
              n_tokens: int, *, temperature: float = 0.0, key=None,
              embeds=None):
    prefill_step, decode_step = _jitted_steps(
        cfg, scfg, engine_mod.active_engine())
    mesh = shd.active_mesh()
    if mesh is not None:
        # Place params (TP/FSDP rule table) before the first step; the
        # cache initializer is memoized on (cfg, scfg, mesh) above.
        params = jax.device_put(params, shd.params_shardings(params, mesh))
        cache = _jitted_cache_init(cfg, scfg, mesh)()
    else:
        cache = init_cache(cfg, scfg)
    logits, cache = prefill_step(params, prompt, cache, embeds)

    def sample(logits, key):
        if temperature > 0.0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits[:, -1] / temperature)
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)
        return tok[:, None].astype(jnp.int32), key

    # The FIRST output token comes from the prefill logits (sampled with
    # the same temperature as the rest, not argmax'd), so n_tokens
    # outputs cost exactly n_tokens - 1 decode steps — no trailing
    # decode whose logits would be discarded.
    tok, key = sample(logits, key)
    outs = [tok]
    for _ in range(n_tokens - 1):
        logits, cache = decode_step(params, cache, tok)
        tok, key = sample(logits, key)
        outs.append(tok)
    return jnp.concatenate(outs, axis=1)
