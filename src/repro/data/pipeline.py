"""Deterministic, stateless data pipeline.

The batch for global step s is a pure function of (seed, s): restarts,
elastic resizes and straggler re-execution all regenerate identical
streams with no iterator state to checkpoint — the fault-tolerance story
leans on this.  Two sources:

  * SyntheticLM  — counting-free PRNG tokens (threefry over (seed, step));
  * MemmapCorpus — fixed-length windows over a token file (np.memmap),
    window index derived from (seed, step, host_shard).

Both emit {"tokens": (B, S+1) int32} host arrays; train_lib shifts into
(inputs, labels).  For embed-input archs (audio) the pipeline emits frame
embeddings instead; for VLM it adds pixel patch embeddings.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.models.config import ArchConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch: int
    seq_len: int
    seed: int = 0


class SyntheticLM:
    """step -> batch, deterministically.  Vocabulary-uniform tokens with a
    planted bigram structure so tiny-model training loss visibly drops."""

    def __init__(self, cfg: ArchConfig, data: DataConfig):
        self.cfg, self.data = cfg, data

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.data.seed, step]))

    def batch(self, step: int) -> dict:
        cfg, d = self.cfg, self.data
        rng = self._rng(step)
        out: dict = {}
        if cfg.embed_inputs:  # audio frontend stub: frame embeddings
            out["embeds"] = rng.normal(
                size=(d.batch, d.seq_len, cfg.d_model)).astype(np.float32)
            out["labels"] = rng.integers(
                0, cfg.vocab, size=(d.batch, d.seq_len), dtype=np.int32)
            return out
        toks = rng.integers(0, cfg.vocab,
                            size=(d.batch, d.seq_len + 1), dtype=np.int32)
        # plant learnable structure: even positions repeat (token % 97)
        toks[:, 2::2] = (toks[:, 1:-1:2] * 31 + 7) % min(cfg.vocab, 97)
        out["tokens"] = toks
        if cfg.prefix_tokens:  # VLM frontend stub: patch embeddings
            out["pixel_embeds"] = 0.02 * rng.normal(
                size=(d.batch, cfg.prefix_tokens, cfg.d_model)).astype(np.float32)
        return out


class MemmapCorpus:
    """Windows over a flat int32 token file; deterministic per step."""

    def __init__(self, path: str, cfg: ArchConfig, data: DataConfig):
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        self.cfg, self.data = cfg, data
        self.n_windows = max(len(self.tokens) - data.seq_len - 1, 1)

    def batch(self, step: int) -> dict:
        d = self.data
        rng = np.random.default_rng(np.random.SeedSequence([d.seed, step, 1]))
        starts = rng.integers(0, self.n_windows, size=d.batch)
        toks = np.stack([
            np.asarray(self.tokens[s:s + d.seq_len + 1]) for s in starts])
        return {"tokens": np.clip(toks, 0, self.cfg.vocab - 1).astype(np.int32)}


def make_source(cfg: ArchConfig, data: DataConfig, path: str | None = None):
    if path:
        return MemmapCorpus(path, cfg, data)
    return SyntheticLM(cfg, data)
