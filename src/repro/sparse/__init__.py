"""N:M structured-sparsity plane (ISSUE 8) — see DESIGN.md §10.

Mirrors `repro.quant`: `SparseTensor` (registered pytree: compressed
values + int8 in-group index metadata), `sparsify`/`densify`
round-trip, `prune_params` (magnitude N:M pruning of `layers.dense`
weights, same skip-list as `quantize_params`), and the sparse×int8
composition (int8 values + per-channel scales in one SparseTensor).
"""

from .nm import (  # noqa: F401
    SKIP_KEYS,
    SparseTensor,
    densify,
    densify_params,
    parse_sparsity,
    prune_params,
    sparsify,
)

__all__ = [
    "SKIP_KEYS",
    "SparseTensor",
    "densify",
    "densify_params",
    "parse_sparsity",
    "prune_params",
    "sparsify",
]
