"""N:M structured sparsity (weights) — the pruned-workload plane.

Layout (DESIGN.md §10): a dense (…, K, N) weight is pruned per group of
M consecutive K elements per output column — keep the N largest
magnitudes, drop the rest — and stored compressed:

  values   (…, K_eff, N)  kept values (float, or int8 under sparse×int8)
  indices  (…, K_eff, N)  int8 in-group offsets (0..M-1) of each kept
                          value, ascending within its group
  scale    (…, 1, N)      per-output-channel float32 scale, only when
                          the values are int8 (sparse×int8 composition)

with K_eff = ceil(K / M) * N.  The index metadata is the whole cost of
reconstruction — one byte per kept value — which is what makes the 2:4
default a 1.6x (float) / 3.5x (int8) weight-footprint shrink while the
consuming GEMM keeps its dense activation layout.

Mirrors the `repro.quant` recipe on purpose: `SparseTensor` is a
registered pytree whose children share leading dims (so `lax.scan` over
stacked params slices it exactly like a raw weight leaf), and
`prune_params` walks the same `{"w": …}` convention with the same
skip-list as `quantize_params`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant.quantize import QMAX, SKIP_KEYS


def parse_sparsity(spec: str) -> tuple[int, int]:
    """Parse an "N:M" sparsity spec ("2:4" -> (2, 4)) with validation:
    1 <= N < M.  N == M would be dense storage with pure overhead, and
    the in-group indices are int8, so M is capped at 128."""
    try:
        n_s, m_s = str(spec).split(":")
        n, m = int(n_s), int(m_s)
    except ValueError:
        raise ValueError(f"sparsity must look like 'N:M' (e.g. '2:4'), "
                         f"got {spec!r}") from None
    if not 1 <= n < m:
        raise ValueError(f"sparsity {spec!r}: need 1 <= N < M")
    if m > 128:
        raise ValueError(f"sparsity {spec!r}: M is capped at 128 "
                         f"(in-group indices are int8)")
    return n, m


@jax.tree_util.register_pytree_node_class
class SparseTensor:
    """Compressed N:M values + int8 index metadata, as one pytree node.

    `shape`/`ndim` report the DENSE shape (…, K, N) so consumers like
    `models.layers.dense` reshape on `w.shape[-1]` unchanged.  `n`, `m`
    and the dense contraction length `k_dense` ride in the static aux
    data — `lax.scan` over stacked params slices values/indices/scale
    together and the group structure survives unchanged.
    """

    def __init__(self, values, indices, scale=None, *, n: int = 2,
                 m: int = 4, k_dense: int | None = None):
        self.values = values
        self.indices = indices
        self.scale = scale
        self.n = int(n)
        self.m = int(m)
        if k_dense is None:
            k_dense = values.shape[-2] // self.n * self.m
        self.k_dense = int(k_dense)

    @property
    def shape(self):
        return (*self.values.shape[:-2], self.k_dense,
                self.values.shape[-1])

    @property
    def ndim(self):
        return self.values.ndim

    @property
    def density(self) -> float:
        return self.n / self.m

    @property
    def quantized(self) -> bool:
        """True for sparse×int8 storage (int8 values + per-col scales)."""
        return self.scale is not None

    def densify(self, dtype=jnp.float32):
        """Scatter the kept values back into a dense (…, K, N) array
        (zeros at pruned positions); dequantizes int8 values first."""
        v = self.values.astype(jnp.float32)
        if self.scale is not None:
            v = v * self.scale
        lead = v.shape[:-2]
        k_eff, ncols = v.shape[-2:]
        groups = k_eff // self.n
        v4 = v.reshape(*lead, groups, self.n, ncols)
        i4 = self.indices.reshape(*lead, groups, self.n, ncols)
        iota = jnp.arange(self.m, dtype=self.indices.dtype).reshape(self.m, 1)
        # one-hot scatter over the in-group offset: (…, g, n, m, ncols)
        hit = i4[..., :, None, :] == iota
        dense = jnp.sum(jnp.where(hit, v4[..., :, None, :], 0.0), axis=-3)
        dense = dense.reshape(*lead, groups * self.m, ncols)
        return dense[..., :self.k_dense, :].astype(dtype)

    def tree_flatten(self):
        return (self.values, self.indices, self.scale), \
            (self.n, self.m, self.k_dense)

    @classmethod
    def tree_unflatten(cls, aux, children):
        n, m, k_dense = aux
        values, indices, scale = children
        return cls(values, indices, scale, n=n, m=m, k_dense=k_dense)

    def __repr__(self):
        return (f"SparseTensor({self.n}:{self.m}, dense_shape="
                f"{tuple(self.shape)}, values_shape="
                f"{tuple(self.values.shape)}, "
                f"quantized={self.quantized})")


def sparsify(x, n: int = 2, m: int = 4, *,
             quantize: bool = False) -> SparseTensor:
    """Magnitude-based N:M pruning of a dense (…, K, N) weight.

    Per group of `m` consecutive K elements per output column, keep the
    `n` largest magnitudes (stable on ties: earlier offset wins) and
    record their in-group offsets ascending, so densify is a
    deterministic scatter.  K is zero-padded up to a multiple of `m`
    first — padded positions never displace real values (magnitude 0)
    and `densify` slices them back off.  `quantize=True` additionally
    stores the kept values as int8 with per-output-channel symmetric
    scales (the sparse×int8 composition)."""
    if not 1 <= n < m:
        raise ValueError(f"need 1 <= N < M, got {n}:{m}")
    lead = x.shape[:-2]
    k, ncols = x.shape[-2:]
    groups = -(-k // m)
    pad = groups * m - k
    xf = x.astype(jnp.float32)
    if pad:
        xf = jnp.concatenate(
            [xf, jnp.zeros((*lead, pad, ncols), jnp.float32)], axis=-2)
    xg = xf.reshape(*lead, groups, m, ncols)
    order = jnp.argsort(-jnp.abs(xg), axis=-2, stable=True)
    keep = jnp.sort(order[..., :n, :], axis=-2)
    vals = jnp.take_along_axis(xg, keep, axis=-2)
    vals = vals.reshape(*lead, groups * n, ncols)
    idx = keep.reshape(*lead, groups * n, ncols).astype(jnp.int8)
    if not quantize:
        return SparseTensor(vals.astype(x.dtype), idx, n=n, m=m, k_dense=k)
    amax = jnp.max(jnp.abs(vals), axis=-2, keepdims=True)
    scale = jnp.where(amax > 0.0, amax / QMAX, 1.0)
    q = jnp.clip(jnp.round(vals / scale), -QMAX, QMAX).astype(jnp.int8)
    return SparseTensor(q, idx, scale, n=n, m=m, k_dense=k)


def densify(st: SparseTensor, dtype=jnp.float32):
    return st.densify(dtype)


def prune_params(params, n: int = 2, m: int = 4, *,
                 quantize: bool = False):
    """Swap every `models.layers.dense` weight for its SparseTensor.

    Same targeting as `quant.quantize_params`: dicts shaped
    `{"w": <float array, ndim >= 2>}` EXCEPT under `SKIP_KEYS` (weights
    consumed by a raw `@`).  MoE expert stacks, norms, biases, conv
    filters and embeddings keep their dtype.  `quantize=True` composes
    sparse×int8: kept values stored int8 with per-channel scales."""

    def walk(node, skip: bool):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                child_skip = skip or k in SKIP_KEYS
                if (k == "w" and not skip
                        and hasattr(v, "ndim") and v.ndim >= 2
                        and jnp.issubdtype(v.dtype, jnp.floating)):
                    out[k] = sparsify(v, n, m, quantize=quantize)
                else:
                    out[k] = walk(v, child_skip)
            return out
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v, skip) for v in node)
        return node

    return walk(params, False)


def densify_params(params, dtype=jnp.float32):
    """The densified oracle: every SparseTensor scattered back to a
    dense array (pruned positions zero), everything else untouched —
    serving it plain must match serving the sparse original exactly."""
    return jax.tree.map(
        lambda leaf: leaf.densify(dtype)
        if isinstance(leaf, SparseTensor) else leaf,
        params, is_leaf=lambda leaf: isinstance(leaf, SparseTensor))
