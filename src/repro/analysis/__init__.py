"""Static invariant checker for kernels, plans, sharding, and jit use.

ReDas's mapper story (Sec. 4.3) is that configuration legality — the
Eq. 2-5 constraints — is decidable *before* execution.  The same holds
for this repo's execution stack, and this package checks it at lint
time instead of TPU time.  Five passes (DESIGN.md §11):

  kernel-legality   Pallas tile floors, the Eq. 2 VMEM gate, and
                    grid/index_map rank consistency, re-derived from the
                    registered block ladders across the full
                    `arch_gemms` corpus (10 configs x float/int8/sparse).
  plan-coverage     `plan_arch` pre-declares a superset of every shape
                    the continuous-batching scheduler can request —
                    admit-width buckets, the k+1 verify width, the paged
                    gather shape — so "zero steady-state misses" is a
                    theorem, not a bench observation.
  sharding-rules    every param leaf and cache leaf matches exactly one
                    `_auto_spec` / `_CACHE_AXES` rule (orphans and
                    ambiguous double-matches are the silently-replicated
                    -leaf failure mode).
  jit-discipline    AST scan for per-call `jax.jit` construction,
                    Python `if` on traced values, and module-level
                    jitted closures over mutable globals.
  docs-consistency  README/DESIGN linted against the tree: every
                    `DESIGN.md §N` citation resolves to a real section,
                    every `src/repro` package has a module-map row, and
                    no doc references a deleted module or symbol.

Stdlib-only at the import surface, like `benchmarks/check_baselines.py`:
the passes import only the jax-free half of the repo (engine planning,
configs, core cost models) so the whole CLI runs in the lint lane with
no jax installed.  Findings that are intentional live in
`allowlist.txt` next to this module, one line each with a justification.
"""

from __future__ import annotations

import dataclasses
import os

#: the installed repro package directory — passes analyse the tree under
#: a --root (tests point it at planted fixtures); dynamic checks that
#: need importable code only run when root IS the real package.
REAL_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def is_real_root(root: str) -> bool:
    return os.path.abspath(root) == REAL_ROOT


def rel(path: str) -> str:
    """Repo-relative spelling for findings/allowlist entries: stable
    across checkouts when the file lives under the repo root (the
    grandparent of src/repro), cwd-relative otherwise (fixtures)."""
    path = os.path.abspath(path)
    repo = os.path.dirname(os.path.dirname(REAL_ROOT))
    for base in (repo, os.getcwd()):
        if path.startswith(base + os.sep):
            return os.path.relpath(path, base)
    return path


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation: a stable identity (for the allowlist) plus a
    file:line anchor (for editors and `--format=github` annotations)."""

    check_id: str   # e.g. "KL002"
    file: str       # repo-relative path
    line: int
    symbol: str     # stable anchor: function / rule / config name
    message: str

    @property
    def ident(self) -> str:
        """The allowlist key: path + symbol, no line number — so an
        unrelated edit shifting lines does not invalidate entries."""
        return f"{self.check_id} {self.file}::{self.symbol}"

    def text(self) -> str:
        return f"{self.file}:{self.line}: {self.check_id} [{self.symbol}] {self.message}"

    def github(self) -> str:
        # '%0A'-style escaping is only needed for newlines; messages are
        # single-line by construction.
        return (f"::error file={self.file},line={self.line},"
                f"title={self.check_id}::[{self.symbol}] {self.message}")


DEFAULT_ALLOWLIST = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                 "allowlist.txt")


def load_allowlist(path: str | None = DEFAULT_ALLOWLIST) -> dict[str, str]:
    """Parse the committed allowlist: one entry per line,

        CHECKID path::symbol -- one-line justification

    Returns {ident: justification}.  A missing justification is itself
    an error (raised, not a finding: the allowlist is hand-maintained
    and a silent bad line would un-suppress nothing visibly)."""
    if path is None or not os.path.exists(path):
        return {}
    entries: dict[str, str] = {}
    with open(path) as fh:
        for ln, line in enumerate(fh, 1):
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            parts = line.split(None, 2)
            if len(parts) < 3 or "::" not in parts[1]:
                raise ValueError(
                    f"{path}:{ln}: malformed allowlist entry {line!r} "
                    f"(want: 'CHECKID path::symbol -- justification')")
            ident = f"{parts[0]} {parts[1]}"
            just = parts[2].lstrip("-— ").strip()
            if not just:
                raise ValueError(
                    f"{path}:{ln}: allowlist entry {ident!r} has no "
                    f"justification — every suppression must say why")
            entries[ident] = just
    return entries


def run_passes(root: str | None = None,
               passes: tuple[str, ...] | None = None) -> list[Finding]:
    """Run the selected passes over `root` (default: the real package)
    and return every finding, allowlisted or not."""
    from . import (docs_consistency, jit_discipline, kernel_legality,
                   plan_coverage, sharding_rules)

    table = {
        "kernel-legality": kernel_legality.run,
        "plan-coverage": plan_coverage.run,
        "sharding-rules": sharding_rules.run,
        "jit-discipline": jit_discipline.run,
        "docs-consistency": docs_consistency.run,
    }
    root = REAL_ROOT if root is None else os.path.abspath(root)
    selected = passes or tuple(table)
    unknown = [p for p in selected if p not in table]
    if unknown:
        raise ValueError(f"unknown pass(es) {unknown}; known: {sorted(table)}")
    findings: list[Finding] = []
    for name in selected:
        findings.extend(table[name](root))
    return sorted(findings, key=lambda f: (f.file, f.line, f.check_id,
                                           f.message))


PASS_NAMES = ("kernel-legality", "plan-coverage", "sharding-rules",
              "jit-discipline", "docs-consistency")
