"""Pass 2: plan coverage — zero steady-state misses as a theorem.

BENCH_PR4/PR6/PR7/PR8 prove empirically that a warm-started scheduler
re-plans nothing (`steady_state_new_misses == 0`).  This pass proves the
same set-inclusion statically: for every serving surface a
`ServeConfig` can express (cache layout x quantize x sparsity x
speculate_k x decode batch), the shapes the continuous-batching
scheduler can request at runtime — derived here INDEPENDENTLY of
`engine.decode_requests`, from the scheduler's own admission rules —
must all be pre-declared by `plan_arch`.

  PC001  a runtime-reachable request the plan does not hold (a removed
         verify_k width, a dropped admit bucket, a forgotten paged
         gather shape... the first trace would re-search mid-serve).
  PC002  a surface combination that fails to plan at all.

The runtime shape mirror follows the scheduler contract:
  * decode ticks are width 1 at the full slot pool;
  * admits prefill at `ceil(maxlen / prefill_bucket) * prefill_bucket`
    capped at max_seq — so every bucket multiple up to max_seq is
    reachable;
  * a `speculate_k=k` server adds exactly the fused k+1 verify width;
  * a paged server adds the gather-attention shape spanning
    `slot_pages * page_size` addressable rows;
and the scheduler's own refusals (encoder archs, embedding frontends,
a verify window overflowing a sliding-window ring) prune unreachable
surfaces rather than demanding coverage for them.
"""

from __future__ import annotations

import dataclasses
import os

from . import Finding, is_real_root, rel
from ._astutil import def_line

#: reference serving posture the coverage proof runs at.  The widths /
#: pool sizes are small (fast to plan) but structurally complete: a
#: multi-bucket admit ladder, a non-trivial page table, a k+1 verify
#: width that differs from every admit width.
BATCH = 4
MAX_SEQ = 64
PREFILL_BUCKET = 16
PAGE_SIZE = 16
SPECULATE_K = 2
#: chunked-ingestion width (DESIGN.md §12).  The Scheduler aligns the
#: chunk to the prefill bucket, so every legal chunk width is already a
#: member of the admit-width ladder — passing it to plan_arch makes the
#: posture explicit without adding a shape (the proof would catch a
#: future chunk width escaping the ladder).
PREFILL_CHUNK = 32
SEED_BACKEND = "pallas-tpu"


@dataclasses.dataclass(frozen=True)
class Surface:
    """One ServeConfig posture (the runtime-visible axes only)."""

    layout: str            # "contiguous" | "paged"
    quantize: bool
    sparse: bool
    speculate_k: int

    def label(self) -> str:
        bits = [self.layout]
        if self.quantize:
            bits.append("int8")
        if self.sparse:
            bits.append("2:4")
        if self.speculate_k:
            bits.append(f"spec_k={self.speculate_k}")
        return "+".join(bits)


def servable(cfg) -> bool:
    """Mirror of the Scheduler constructor's arch guards."""
    return (cfg.kind != "encoder" and not cfg.embed_inputs
            and not cfg.prefix_tokens)


def surfaces(cfg):
    """Every Surface the scheduler would accept for this arch."""
    layouts = ["contiguous"]
    if "attn" in cfg.layer_pattern:
        # a paged ServeConfig on an attention-free arch arms no paged
        # plane (Scheduler leaves self.paged None) — same shapes as
        # contiguous, so only attention archs add the paged surface.
        layouts.append("paged")
    for layout in layouts:
        for quantize in (False, True):
            for sparse in (False, True):
                for k in (0, SPECULATE_K):
                    if k and "local" in cfg.layer_pattern:
                        ring = min(cfg.window, MAX_SEQ)
                        if k + 1 > ring:
                            continue  # the Scheduler refuses this combo
                    yield Surface(layout, quantize, sparse, k)


def backend_for(surface: Surface) -> str:
    """Mirror the ServeConfig.__post_init__ backend upgrade chain using
    the real sibling maps (explicit seed: no jax-importing None path)."""
    from repro.engine.context import int8_sibling, sparse_sibling

    backend = SEED_BACKEND
    if surface.quantize:
        backend = int8_sibling(backend)
    if surface.sparse:
        backend = sparse_sibling(backend)
    return backend


def admit_widths() -> tuple[int, ...]:
    """Every admit width `_prefill_group` can compute: bucket multiples
    of maxlen in [1, max_seq], capped at max_seq."""
    widths = sorted({min(-(-maxlen // PREFILL_BUCKET) * PREFILL_BUCKET,
                         MAX_SEQ)
                     for maxlen in range(1, MAX_SEQ + 1)})
    return tuple(widths)


def expected_requests(cfg, surface: Surface):
    """The KernelRequests a steady-state scheduler can issue on this
    surface — derived from the arch + scheduler contract, NOT from
    `engine.decode_requests` (this is the independent re-derivation the
    coverage proof needs; tests pin the two against each other)."""
    from repro.engine.context import backend_in_bytes
    from repro.engine.plan import KernelRequest

    backend = backend_for(surface)
    plan_bytes = backend_in_bytes(backend, 2)
    out_b = 2
    if surface.sparse:
        dense_op, density = "gemm_sparse", 0.5
        dense_in = 1 if surface.quantize else plan_bytes
    elif surface.quantize:
        dense_op, density, dense_in = "gemm_w8", 1.0, plan_bytes
    else:
        dense_op, density, dense_in = "gemm", 1.0, plan_bytes

    d, f, hd = cfg.d_model, cfg.d_ff, cfg.head_dim_
    nh, nkv = cfg.n_heads, cfg.n_kv
    widths = (1,) + admit_widths()
    if surface.speculate_k:
        widths = widths + (surface.speculate_k + 1,)

    reqs = []

    def dense(m, k, n, label):
        reqs.append((KernelRequest(dense_op, m, k, n, in_bytes=dense_in,
                                   out_bytes=out_b, density=density), label))

    for width in sorted(set(widths)):
        tokens = BATCH * width
        for kind in sorted(set(cfg.layer_pattern)):
            if kind in ("attn", "local"):
                dense(tokens, d, nh * hd, f"{kind}/q w={width}")
                dense(tokens, d, nkv * hd, f"{kind}/kv w={width}")
                dense(tokens, nh * hd, d, f"{kind}/o w={width}")
            elif kind == "rglru":
                w = cfg.rglru_width or d
                dense(tokens, d, w, f"rglru/in w={width}")
                dense(tokens, w, w, f"rglru/gate w={width}")
                dense(tokens, w, d, f"rglru/out w={width}")
            elif kind == "ssm":
                continue  # raw matmuls, not engine-routed
            if cfg.moe is not None:
                rows = BATCH * cfg.moe.capacity(width)
                for m, k, n in ((rows, d, f), (rows, f, d)):
                    reqs.append((KernelRequest(
                        "grouped_gemm", m, k, n, groups=cfg.moe.n_experts,
                        in_bytes=plan_bytes, out_bytes=out_b),
                        f"{kind}/expert w={width}"))
            else:
                dense(tokens, d, f, f"{kind}/ffn_up w={width}")
                dense(tokens, f, d, f"{kind}/ffn_down w={width}")
    if surface.layout == "paged" and "attn" in cfg.layer_pattern:
        slot_pages = -(-MAX_SEQ // PAGE_SIZE)
        reqs.append((KernelRequest(
            "paged_attention", 1, hd, slot_pages * PAGE_SIZE,
            groups=BATCH * nh, in_bytes=plan_bytes, out_bytes=out_b),
            "attn/paged-gather"))
    return reqs


def build_plan(cfg, surface: Surface):
    """The plan a serving harness would warm-start this surface from."""
    from repro.engine.context import plan_arch

    slot_pages = -(-MAX_SEQ // PAGE_SIZE)
    return plan_arch(
        cfg, backend=backend_for(surface), decode_batch=BATCH,
        admit_widths=admit_widths(),
        quantized_weights=surface.quantize,
        sparse_weights=surface.sparse, sparse_density=0.5,
        paged_pages=slot_pages if surface.layout == "paged" else 0,
        page_size=PAGE_SIZE if surface.layout == "paged" else 0,
        verify_k=surface.speculate_k, prefill_chunk=PREFILL_CHUNK)


def check_plan(cfg, surface: Surface, plan, *, file: str, line: int
               ) -> list[Finding]:
    """PC001 for every runtime-reachable request `plan` cannot answer."""
    findings = []
    for req, label in expected_requests(cfg, surface):
        if plan.decisions.get(req.key()) is None:
            findings.append(Finding(
                "PC001", file, line, cfg.name,
                f"[{surface.label()}] runtime shape {label} "
                f"{req.key()} is not in the warm plan — the scheduler "
                f"would re-search mid-serve (steady-state miss)"))
    return findings


def run(root: str) -> list[Finding]:
    if not is_real_root(root):
        return []  # dynamic pass: needs the importable planning plane
    from repro.configs import all_configs

    ctx = os.path.join(root, "engine", "context.py")
    file, line = rel(ctx), def_line(ctx, "plan_arch")
    findings: list[Finding] = []
    for cfg in all_configs().values():
        if not servable(cfg):
            continue
        for surface in surfaces(cfg):
            try:
                plan = build_plan(cfg, surface)
            except Exception as e:  # noqa: BLE001 - any failure is the finding
                findings.append(Finding(
                    "PC002", file, line, cfg.name,
                    f"[{surface.label()}] plan_arch failed: {e}"))
                continue
            findings.extend(check_plan(cfg, surface, plan,
                                       file=file, line=line))
    return findings
