"""Shared AST plumbing for the analysis passes (stdlib only).

The passes work on source text, never imports, for anything that lives
in a jax-importing module (kernels, sharding, serving) — parsing is the
only way to stay jax-free.  Helpers here keep that honest: constant
folding for module-level int constants, name->assignment environments
per function, and dotted-name rendering.
"""

from __future__ import annotations

import ast
import os


def parse_file(path: str) -> ast.Module | None:
    if not os.path.exists(path):
        return None
    with open(path) as fh:
        return ast.parse(fh.read(), filename=path)


def dotted(node: ast.AST) -> str | None:
    """'jax.numpy.sum' for an Attribute/Name chain; None otherwise."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def fold_int(node: ast.AST, env: dict[str, int]) -> int | None:
    """Constant-fold an int expression over known module constants
    (`16 * 2**20`, `SUBLANE`, ...).  None when not statically an int."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int) \
            and not isinstance(node.value, bool):
        return node.value
    if isinstance(node, ast.Name):
        return env.get(node.id)
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        v = fold_int(node.operand, env)
        return None if v is None else -v
    if isinstance(node, ast.BinOp):
        lhs, rhs = fold_int(node.left, env), fold_int(node.right, env)
        if lhs is None or rhs is None:
            return None
        if isinstance(node.op, ast.Add):
            return lhs + rhs
        if isinstance(node.op, ast.Sub):
            return lhs - rhs
        if isinstance(node.op, ast.Mult):
            return lhs * rhs
        if isinstance(node.op, ast.FloorDiv) and rhs:
            return lhs // rhs
        if isinstance(node.op, ast.Pow) and rhs >= 0:
            return lhs ** rhs
    return None


def module_int_constants(tree: ast.Module) -> dict[str, int]:
    """Module-level `NAME = <int expr>` bindings, including tuple
    unpacking (`SUBLANE, LANE = 8, 128`), folded in source order."""
    env: dict[str, int] = {}
    for stmt in tree.body:
        if not isinstance(stmt, ast.Assign) or len(stmt.targets) != 1:
            continue
        tgt, val = stmt.targets[0], stmt.value
        if isinstance(tgt, ast.Name):
            v = fold_int(val, env)
            if v is not None:
                env[tgt.id] = v
        elif isinstance(tgt, ast.Tuple) and isinstance(val, ast.Tuple) \
                and len(tgt.elts) == len(val.elts):
            for t, e in zip(tgt.elts, val.elts, strict=True):
                if isinstance(t, ast.Name):
                    v = fold_int(e, env)
                    if v is not None:
                        env[t.id] = v
    return env


def find_def(tree: ast.AST, name: str) -> ast.FunctionDef | None:
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef) and node.name == name:
            return node
    return None


def def_line(path: str, name: str, default: int = 1) -> int:
    """Anchor line for a finding about function `name` in `path`."""
    tree = parse_file(path)
    if tree is None:
        return default
    fn = find_def(tree, name)
    return fn.lineno if fn is not None else default


def assignments_in(fn: ast.AST) -> dict[str, list[ast.AST]]:
    """name -> every value expression assigned to it inside `fn`
    (Assign + AugAssign; AugAssign contributes its RHS so `in_specs +=
    [...]` extends the candidate set instead of replacing it)."""
    env: dict[str, list[ast.AST]] = {}
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    env.setdefault(tgt.id, []).append(node.value)
        elif isinstance(node, ast.AugAssign) and isinstance(node.target,
                                                            ast.Name):
            env.setdefault(node.target.id, []).append(node.value)
    return env


def resolve(expr: ast.AST, env: dict[str, list[ast.AST]]) -> list[ast.AST]:
    """An expression, or — when it is a bare Name — every value ever
    assigned to that name in the enclosing function."""
    if isinstance(expr, ast.Name) and expr.id in env:
        return env[expr.id]
    return [expr]


def lambda_arity(fn: ast.Lambda | ast.FunctionDef) -> int:
    a = fn.args
    return len(a.posonlyargs) + len(a.args)


def return_tuple_len(fn: ast.Lambda | ast.FunctionDef) -> int | None:
    """Length of the tuple an index map returns, when statically a
    tuple literal; None otherwise (degrade, never guess)."""
    if isinstance(fn, ast.Lambda):
        return len(fn.body.elts) if isinstance(fn.body, ast.Tuple) else None
    lens = {len(n.value.elts) for n in ast.walk(fn)
            if isinstance(n, ast.Return) and isinstance(n.value, ast.Tuple)}
    return lens.pop() if len(lens) == 1 else None


def py_files(root: str) -> list[str]:
    out = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        out.extend(os.path.join(dirpath, f) for f in filenames
                   if f.endswith(".py"))
    return sorted(out)
