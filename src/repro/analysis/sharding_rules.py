"""Pass 3: sharding consistency — no silently-replicated leaves.

`dist.sharding` places every array by rule tables: `_auto_spec` pattern
rules for params, `_CACHE_AXES` for cache leaves.  Both FAIL OPEN — an
unmatched name replicates silently — which is exactly the bug class
this pass closes: every leaf either config can produce must match
exactly one rule, and every rule must still be reachable.

  SH001  a cache leaf some (arch, layout, dtype) combination produces
         with no `_CACHE_AXES` entry (it would replicate onto every
         device — a paged pool or long-context KV that must shard).
  SH002  a `_CACHE_AXES` rule no combination produces (dead rule: its
         leaf was renamed and the rename now replicates, see SH001).
  SH003  a rule whose axis tuple does not match its leaf's rank
         (1 + slot ndim: the leading entry covers the stacked-layer
         dim, `cache_shardings` strips it for tail blocks).
  SH007  a rule naming a logical axis missing from
         `LOGICAL_AXIS_RULES` (`spec()` raises at serve time).
  SH004  a param leaf matching NO `_auto_spec` family (orphan: the
         catch-all replicates it — fatal for a multi-GB matmul weight).
  SH005  a param leaf matching MORE THAN ONE name-pattern family
         (`_auto_spec` resolves by order; which rule wins is silent).
  SH006  a matmul/expert/embed leaf whose mirror spec degrades to full
         replication on the reference 2x2 (data, model) mesh — legal,
         but the silently-replicated failure mode by another route.

The leaf sets come from stdlib mirrors of `models.transformer.
init_params` / `init_cache` over every real ArchConfig (the configs
are jax-free); the rule tables are AST-extracted from the sharding
module under --root, so fixture trees can plant table violations.
tests/test_analysis.py drift-checks both mirrors against the real
jax-built trees and the mirror classifier against `_auto_spec`.
"""

from __future__ import annotations

import ast
import os

from . import Finding, rel
from ._astutil import find_def, parse_file

#: reference mesh for the SH006 degradation probe.
_MESH = {"data": 2, "model": 2}


# ---------------------------------------------------------------------------
# Rule-table extraction (AST: the sharding module imports jax)
# ---------------------------------------------------------------------------


def _module_dict_literal(tree: ast.Module, name: str):
    """(value_dict, {key: line}) for a module-level `NAME = {...}`."""
    for stmt in tree.body:
        tgt = None
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                and isinstance(stmt.targets[0], ast.Name):
            tgt = stmt.targets[0].id
        elif isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target,
                                                            ast.Name):
            tgt = stmt.target.id
        if tgt != name:
            continue
        value = stmt.value
        if not isinstance(value, ast.Dict):
            return None, {}
        try:
            d = ast.literal_eval(value)
        except ValueError:
            return None, {}
        lines = {k.value: k.lineno for k in value.keys
                 if isinstance(k, ast.Constant)}
        return d, lines
    return None, {}


def extract_tables(root: str):
    """(cache_axes, key_lines, logical_axis_names, auto_spec_line, path)
    from `<root>/dist/sharding.py`; None when the file is absent."""
    path = os.path.join(root, "dist", "sharding.py")
    tree = parse_file(path)
    if tree is None:
        return None
    cache_axes, lines = _module_dict_literal(tree, "_CACHE_AXES")
    logical, _ = _module_dict_literal(tree, "LOGICAL_AXIS_RULES")
    fn = find_def(tree, "_auto_spec")
    return (cache_axes or {}, lines, set(logical or {}),
            fn.lineno if fn else 1, path)


# ---------------------------------------------------------------------------
# Cache-leaf mirror (models.transformer._slot_cache_shape, stdlib)
# ---------------------------------------------------------------------------


def cache_slot_leaves(cfg, *, paged: bool, int8: bool) -> dict[str, int]:
    """slot-leaf name -> per-slot ndim for one (arch, layout, dtype)."""
    leaves: dict[str, int] = {}
    for kind in sorted(set(cfg.layer_pattern)):
        if kind == "attn" and paged:
            leaves["k_pages"] = leaves["v_pages"] = 4
            if int8:
                leaves["k_scale_pages"] = leaves["v_scale_pages"] = 3
        elif kind in ("attn", "local"):
            leaves["k"] = leaves["v"] = 4
            if int8:
                leaves["k_scale"] = leaves["v_scale"] = 3
        elif kind == "ssm":
            leaves["conv"] = 3
            leaves["state"] = 4
        elif kind == "rglru":
            leaves["conv"] = 3
            leaves["h"] = 2
    return leaves


def all_cache_leaves(configs) -> dict[str, int]:
    """Every slot leaf any (servable-or-not arch, layout, dtype) combo
    can produce, with its per-slot ndim (consistent across combos)."""
    leaves: dict[str, int] = {}
    for cfg in configs:
        kinds = set(cfg.layer_pattern)
        int8_ok = bool(kinds & {"attn", "local"})  # validate_cache_dtype
        for paged in (False, True) if "attn" in kinds else (False,):
            for int8 in (False, True) if int8_ok else (False,):
                leaves.update(cache_slot_leaves(cfg, paged=paged, int8=int8))
    return leaves


# ---------------------------------------------------------------------------
# Param-leaf mirror (models.transformer.init_params, stdlib)
# ---------------------------------------------------------------------------


def _dense_leaves(prefix, d_in, d_out, *, bias=False):
    out = [(f"{prefix}/w", (d_in, d_out))]
    if bias:
        out.append((f"{prefix}/b", (d_out,)))
    return out


def _block_leaves(cfg, kind: str):
    d, f, hd = cfg.d_model, cfg.d_ff, cfg.head_dim_
    nh, nkv = cfg.n_heads, cfg.n_kv
    leaves = [("norm1", (d,))]
    if kind in ("attn", "local"):
        leaves += _dense_leaves("attn/wq", d, nh * hd, bias=cfg.qkv_bias)
        leaves += _dense_leaves("attn/wk", d, nkv * hd, bias=cfg.qkv_bias)
        leaves += _dense_leaves("attn/wv", d, nkv * hd, bias=cfg.qkv_bias)
        leaves += _dense_leaves("attn/wo", nh * hd, d)
        if cfg.qk_norm:
            leaves += [("attn/q_norm", (hd,)), ("attn/k_norm", (hd,))]
        leaves.append(("norm2", (d,)))
        if cfg.moe is not None:
            e = cfg.moe.n_experts
            leaves += _dense_leaves("moe/router", d, e)
            leaves += [("moe/experts/wi", (e, d, f)),
                       ("moe/experts/wg", (e, d, f)),
                       ("moe/experts/wo", (e, f, d))]
        else:
            leaves += _mlp_leaves(cfg)
    elif kind == "ssm":
        s = cfg.ssm
        d_in = s.expand * d
        heads = d_in // s.head_dim
        conv_ch = d_in + 2 * s.n_groups * s.d_state
        d_proj = 2 * d_in + 2 * s.n_groups * s.d_state + heads
        leaves += _dense_leaves("ssm/in_proj", d, d_proj)
        leaves += [("ssm/conv_w", (s.conv_width, conv_ch)),
                   ("ssm/conv_b", (conv_ch,)), ("ssm/A_log", (heads,)),
                   ("ssm/D", (heads,)), ("ssm/dt_bias", (heads,)),
                   ("ssm/norm", (d_in,))]
        leaves += _dense_leaves("ssm/out_proj", d_in, d)
    elif kind == "rglru":
        w = cfg.rglru_width or d
        leaves += _dense_leaves("rec/lin_x", d, w)
        leaves += _dense_leaves("rec/lin_y", d, w)
        leaves += [("rec/conv_w", (4, w)), ("rec/conv_b", (w,))]
        leaves += _dense_leaves("rec/w_a", w, w)
        leaves += _dense_leaves("rec/w_x", w, w)
        leaves += [("rec/lam", (w,))]
        leaves += _dense_leaves("rec/lin_out", w, d)
        leaves += [("norm2", (d,))] + _mlp_leaves(cfg)
    return leaves


def _mlp_leaves(cfg):
    d, f = cfg.d_model, cfg.d_ff
    leaves = _dense_leaves("mlp/wi", d, f)
    if cfg.gated_mlp:
        leaves += _dense_leaves("mlp/wg", d, f)
    return leaves + _dense_leaves("mlp/wo", f, d)


def param_leaves(cfg) -> list[tuple[str, tuple[int, ...]]]:
    """('/'-joined path, shape) for every init_params leaf of `cfg`."""
    period = len(cfg.layer_pattern)
    n_periods, n_tail = cfg.n_layers // period, cfg.n_layers % period
    leaves: list[tuple[str, tuple[int, ...]]] = []
    if not cfg.embed_inputs:
        leaves.append(("embed", (cfg.vocab, cfg.d_model)))
    for j, kind in enumerate(cfg.layer_pattern):
        for name, shape in _block_leaves(cfg, kind):
            leaves.append((f"stack/b{j}/{name}", (n_periods,) + shape))
    for t in range(n_tail):
        for name, shape in _block_leaves(cfg, cfg.layer_pattern[t]):
            leaves.append((f"tail/{t}/{name}", shape))
    leaves.append(("final_norm", (cfg.d_model,)))
    if not cfg.tie_embeddings:
        leaves.append(("lm_head", (cfg.d_model, cfg.vocab)))
    return leaves


# ---------------------------------------------------------------------------
# Param classification: the _auto_spec rule families, checked exactly-one
# ---------------------------------------------------------------------------


def classify_param(name: str, shape: tuple[int, ...]):
    """(families, rule) — `families` is every name-pattern family that
    claims this leaf (>1 = ambiguous), `rule` the shape family that
    would place it (None = orphan)."""
    ndim = len(shape)
    off = 1 if (name.startswith("stack/") or "/stack/" in name) else 0
    families = []
    if off == 0 and name.rsplit("/", 1)[-1] == "embed" and ndim >= 2:
        families.append("embed")
    if "experts/" in name and ndim - off >= 3:
        families.append("experts")
    if len(families) > 1:
        return families, None
    if families == ["embed"]:
        return families, "embed" if ndim == 2 else None
    if families == ["experts"]:
        last = name.rsplit("/", 1)[-1]
        return families, ("experts" if ndim - off == 3
                          and last in ("wi", "wg", "wo") else None)
    if ndim - off <= 1:
        return families, "replicate"
    if ndim - off == 2:
        return families, "matmul"
    return families, None


def mirror_spec(name: str, shape: tuple[int, ...],
                sizes: dict[str, int]) -> tuple:
    """Stdlib mirror of `_auto_spec` (same divisibility degradation);
    drift-tested against the real function under jax."""
    data, model = sizes.get("data", 1), sizes.get("model", 1)
    ndim = len(shape)
    if ndim <= 1:
        return ()
    off = 1 if (name.startswith("stack/") or "/stack/" in name) else 0
    if off == 0 and name.rsplit("/", 1)[-1] == "embed":
        return ("model",) if model > 1 and shape[0] % model == 0 else ()
    specs: list[str | None] = [None] * ndim
    if "experts/" in name and ndim - off >= 3:
        if model > 1 and shape[off] % model == 0:
            specs[off] = "model"
        dm = ndim - 1 if name.rsplit("/", 1)[-1] == "wo" else off + 1
        if data > 1 and shape[dm] % data == 0:
            specs[dm] = "data"
        return tuple(specs)
    if ndim - off >= 2:
        if model > 1 and shape[-1] % model == 0:
            specs[-1] = "model"
        if data > 1 and shape[-2] % data == 0:
            specs[-2] = "data"
    return tuple(specs)


def check_param_leaves(leaves, *, file: str, line: int,
                       arch: str) -> list[Finding]:
    findings = []
    for name, shape in leaves:
        families, rule = classify_param(name, shape)
        if len(families) > 1:
            findings.append(Finding(
                "SH005", file, line, arch,
                f"param leaf {name!r} {shape} matches multiple rule "
                f"families ({', '.join(families)}): _auto_spec resolves "
                f"by order and the winner is silent"))
            continue
        if rule is None:
            findings.append(Finding(
                "SH004", file, line, arch,
                f"param leaf {name!r} {shape} matches no _auto_spec rule "
                f"family — the fall-through would replicate it onto "
                f"every device"))
            continue
        if rule in ("matmul", "experts", "embed") \
                and not any(mirror_spec(name, shape, _MESH)):
            findings.append(Finding(
                "SH006", file, line, arch,
                f"param leaf {name!r} {shape} degrades to full "
                f"replication on a {_MESH} mesh (no dim divisible): a "
                f"weight matrix every device holds whole"))
    return findings


# ---------------------------------------------------------------------------
# The pass
# ---------------------------------------------------------------------------


def run(root: str) -> list[Finding]:
    tables = extract_tables(root)
    if tables is None:
        return []
    cache_axes, key_lines, logical, auto_line, path = tables
    file = rel(path)
    from repro.configs import all_configs

    configs = list(all_configs().values())
    findings: list[Finding] = []

    # -- cache rules -------------------------------------------------------
    produced = all_cache_leaves(configs)
    for name, ndim in sorted(produced.items()):
        axes = cache_axes.get(name)
        if axes is None:
            findings.append(Finding(
                "SH001", file, 1, name,
                f"cache leaf {name!r} has no _CACHE_AXES rule — "
                f"cache_shardings would replicate it onto every device"))
            continue
        if len(axes) != 1 + ndim:
            findings.append(Finding(
                "SH003", file, key_lines.get(name, 1), name,
                f"_CACHE_AXES[{name!r}] has {len(axes)} entries but the "
                f"leaf is rank {1 + ndim} (stack dim + {ndim} slot dims)"))
    for name, axes in sorted(cache_axes.items()):
        if name not in produced:
            findings.append(Finding(
                "SH002", file, key_lines.get(name, 1), name,
                f"_CACHE_AXES[{name!r}] matches no cache leaf any config "
                f"produces — dead rule (was its leaf renamed?)"))
        for ax in axes:
            if ax is not None and ax not in logical:
                findings.append(Finding(
                    "SH007", file, key_lines.get(name, 1), name,
                    f"_CACHE_AXES[{name!r}] names logical axis {ax!r} "
                    f"missing from LOGICAL_AXIS_RULES — spec() raises at "
                    f"serve time"))

    # -- param rules -------------------------------------------------------
    for cfg in configs:
        findings.extend(check_param_leaves(
            param_leaves(cfg), file=file, line=auto_line, arch=cfg.name))
    return findings
