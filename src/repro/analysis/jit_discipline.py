"""Pass 4: jit discipline — retrace/trace bugs as lint findings.

The pre-PR-4 serving path rebuilt `jax.jit(decode_step)` per `generate`
call: every invocation re-traced, a 50x slowdown nothing but a profiler
would surface.  The fix (memoized jit factories keyed on the posture)
is a *pattern*, and patterns are AST-checkable:

  JD001  `jax.jit(...)` (or `functools.partial(jax.jit, ...)`)
         constructed inside a function whose enclosing-function chain
         carries no `functools.lru_cache`/`cache` memoization: every
         call builds a fresh jit wrapper whose trace cache starts
         empty.  Intentional one-shot drivers go in the allowlist.
  JD002  a Python `if`/`while`/`assert` whose test calls into
         `jnp.` / `jax.numpy.` / `jax.lax.`: under jit those produce
         tracers, and branching on a tracer raises
         TracerBoolConversionError at trace time (dtype/shape metadata
         helpers are exempt — they return host values).
  JD003  a module-level jitted function whose body reads a module-level
         name bound to a mutable literal (list/dict/set): the closure
         captures the object at definition time, later mutation
         invisibly changes (or fails to change) traced behavior.
"""

from __future__ import annotations

import ast

from . import Finding, rel
from ._astutil import dotted, py_files

#: jnp/jax.lax attributes that return host metadata, not tracers —
#: branching on them is ordinary config code.
_METADATA_FNS = frozenset({
    "dtype", "issubdtype", "result_type", "promote_types", "can_cast",
    "finfo", "iinfo", "isdtype", "ndim", "shape",
})

_CACHE_DECORATORS = frozenset({"lru_cache", "cache"})


def _is_jit_call(node: ast.Call) -> bool:
    name = dotted(node.func)
    if name in ("jax.jit", "jit", "pjit", "jax.pjit"):
        return True
    # functools.partial(jax.jit, ...) delays construction but still
    # builds a fresh jit per call of the enclosing function.
    if name in ("functools.partial", "partial") and node.args:
        return dotted(node.args[0]) in ("jax.jit", "jit", "jax.pjit")
    return False


def _is_cached(fn: ast.FunctionDef) -> bool:
    for dec in fn.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        name = dotted(target) or ""
        if name.rsplit(".", 1)[-1] in _CACHE_DECORATORS:
            return True
    return False


def _traced_test_call(test: ast.AST) -> str | None:
    """Dotted name of the first tracer-producing call in an if/while/
    assert test, or None."""
    for node in ast.walk(test):
        if not isinstance(node, ast.Call):
            continue
        name = dotted(node.func)
        if not name:
            continue
        root_, _, attr = name.partition(".")
        base, leaf = name.rsplit(".", 1)[0], name.rsplit(".", 1)[-1]
        if base in ("jnp", "jax.numpy", "jax.lax") \
                and leaf not in _METADATA_FNS:
            return name
    return None


class _Scanner:
    """One file: walks with an explicit enclosing-function stack so
    decorators are attributed to the OUTER scope (a `@jax.jit` on a
    module-level def is module-level construction, not 'inside' it)."""

    def __init__(self, path: str, tree: ast.Module):
        self.path = rel(path)
        self.tree = tree
        self.findings: list[Finding] = []
        # module-level names bound to mutable literals (for JD003)
        self.mutable_globals: dict[str, int] = {}
        for stmt in tree.body:
            if isinstance(stmt, (ast.Assign, ast.AnnAssign)):
                targets = stmt.targets if isinstance(stmt, ast.Assign) \
                    else [stmt.target]
                value = stmt.value
                if value is not None and self._is_mutable_literal(value):
                    for tgt in targets:
                        if isinstance(tgt, ast.Name):
                            self.mutable_globals[tgt.id] = stmt.lineno
        self.module_defs = {n.name: n for n in tree.body
                            if isinstance(n, ast.FunctionDef)}

    @staticmethod
    def _is_mutable_literal(node: ast.AST) -> bool:
        if isinstance(node, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                             ast.DictComp, ast.SetComp)):
            return True
        if isinstance(node, ast.Call):
            name = (dotted(node.func) or "").rsplit(".", 1)[-1]
            return name in ("list", "dict", "set", "defaultdict", "deque")
        return False

    def scan(self):
        self._walk(self.tree.body, stack=())
        self._scan_module_level_jits()
        return self.findings

    # -- JD001 + JD002 -----------------------------------------------------

    def _walk(self, body, stack):
        for stmt in body:
            self._visit(stmt, stack)

    def _visit(self, node: ast.AST, stack):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                self._check_exprs(dec, stack)
            inner = stack + (node,)
            self._walk(node.body, inner)
            return
        if isinstance(node, (ast.If, ast.While)):
            self._check_test(node.test, node.lineno, stack)
        elif isinstance(node, ast.Assert):
            self._check_test(node.test, node.lineno, stack)
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._visit(child, stack)
            elif isinstance(child, (ast.stmt,)):
                self._visit(child, stack)
            else:
                self._check_exprs(child, stack)

    def _check_exprs(self, node: ast.AST, stack):
        for sub in ast.walk(node):
            if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue  # handled via _visit with its own stack
            if isinstance(sub, ast.Call) and _is_jit_call(sub):
                self._jit_site(sub, stack)

    def _check_test(self, test: ast.AST, line: int, stack):
        name = _traced_test_call(test)
        if name is not None:
            enclosing = stack[-1].name if stack else "<module>"
            self.findings.append(Finding(
                "JD002", self.path, line, enclosing,
                f"Python branch tests {name}(...): under jit this is a "
                f"tracer and the branch raises at trace time — use "
                f"jnp.where / lax.cond, or hoist to config time"))
        self._check_exprs(test, stack)

    def _jit_site(self, call: ast.Call, stack):
        if not stack:
            return  # module-level construction: once per import (JD003's job)
        if any(_is_cached(fn) for fn in stack
               if isinstance(fn, ast.FunctionDef)):
            return  # memoized factory — the sanctioned pattern
        enclosing = stack[-1].name
        self.findings.append(Finding(
            "JD001", self.path, call.lineno, enclosing,
            f"jax.jit constructed inside {enclosing}() with no memoized "
            f"(lru_cache) factory in scope: every call builds a fresh "
            f"jit whose trace cache starts empty (the pre-PR-4 50x "
            f"retrace bug)"))

    # -- JD003 -------------------------------------------------------------

    def _scan_module_level_jits(self):
        jitted: list[tuple[ast.AST, str, int]] = []  # (body src, name, line)
        for stmt in self.tree.body:
            if isinstance(stmt, ast.FunctionDef):
                for dec in stmt.decorator_list:
                    target = dec.func if isinstance(dec, ast.Call) else dec
                    name = dotted(target) or ""
                    is_jit = name in ("jax.jit", "jit", "jax.pjit") or (
                        isinstance(dec, ast.Call)
                        and name in ("functools.partial", "partial")
                        and dec.args
                        and dotted(dec.args[0]) in ("jax.jit", "jit"))
                    if is_jit:
                        jitted.append((stmt, stmt.name, stmt.lineno))
            elif isinstance(stmt, ast.Assign) and isinstance(
                    stmt.value, ast.Call) and _is_jit_call(stmt.value):
                args = stmt.value.args
                target = args[0] if args else None
                if isinstance(target, ast.Lambda):
                    jitted.append((target, _first_target(stmt), stmt.lineno))
                elif isinstance(target, ast.Name) \
                        and target.id in self.module_defs:
                    jitted.append((self.module_defs[target.id],
                                   _first_target(stmt), stmt.lineno))
        for body, name, line in jitted:
            loads = {n.id for n in ast.walk(body)
                     if isinstance(n, ast.Name)
                     and isinstance(n.ctx, ast.Load)}
            captured = sorted(loads & set(self.mutable_globals))
            if captured:
                self.findings.append(Finding(
                    "JD003", self.path, line, name,
                    f"module-level jitted {name!r} reads mutable "
                    f"module global(s) {', '.join(captured)}: the trace "
                    f"captures their value once and later mutation "
                    f"silently diverges from traced behavior"))


def _first_target(stmt: ast.Assign) -> str:
    for tgt in stmt.targets:
        if isinstance(tgt, ast.Name):
            return tgt.id
    return "<assign>"


def run(root: str) -> list[Finding]:
    import os

    findings: list[Finding] = []
    for path in py_files(root):
        if os.path.basename(path).startswith("test_"):
            continue
        with open(path) as fh:
            try:
                tree = ast.parse(fh.read(), filename=path)
            except SyntaxError:
                continue
        findings.extend(_Scanner(path, tree).scan())
    return findings
