"""CLI for the static invariant checker.

    python -m repro.analysis                       # all passes, text
    python -m repro.analysis --format=github       # CI annotations
    python -m repro.analysis --passes kernel-legality,jit-discipline
    python -m repro.analysis --root tests/fixtures/analysis/bad_ladder

Stdlib-only, jax-free (same contract as benchmarks/check_baselines.py):
the lint lane runs this before any heavyweight test collection.  Exit
status is the number of unsuppressed findings, capped at 125; unused
allowlist entries are themselves findings so suppressions cannot rot.
"""

from __future__ import annotations

import argparse
import sys

from . import (DEFAULT_ALLOWLIST, PASS_NAMES, Finding, load_allowlist,
               run_passes)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static invariant checker: kernels, plans, sharding, "
                    "jit discipline")
    ap.add_argument("--format", choices=("text", "github"), default="text",
                    help="finding format (github = workflow annotations)")
    ap.add_argument("--passes", default=None,
                    help="comma-separated subset of: " + ", ".join(PASS_NAMES))
    ap.add_argument("--root", default=None,
                    help="tree to analyze (default: the installed repro "
                         "package; dynamic corpus checks only run there)")
    ap.add_argument("--allowlist", default=None,
                    help="allowlist file ('-' disables; default: the "
                         "committed allowlist.txt)")
    args = ap.parse_args(argv)

    passes = None
    if args.passes:
        passes = tuple(p.strip() for p in args.passes.split(",") if p.strip())

    if args.allowlist == "-":
        allow: dict[str, str] = {}
    else:
        allow = load_allowlist(args.allowlist or DEFAULT_ALLOWLIST)

    try:
        findings = run_passes(root=args.root, passes=passes)
    except ValueError as e:
        print(f"FAIL: {e}", file=sys.stderr)
        return 2

    used: set[str] = set()
    reported: list[Finding] = []
    for f in findings:
        if f.ident in allow:
            used.add(f.ident)
            continue
        reported.append(f)
    for ident in sorted(set(allow) - used):
        reported.append(Finding(
            "AL000", "src/repro/analysis/allowlist.txt", 1, ident,
            f"allowlist entry {ident!r} suppresses nothing — the "
            f"violation is gone; delete the entry"))

    for f in reported:
        print(f.github() if args.format == "github" else f.text())
    n = len(reported)
    if n:
        print(f"FAIL: {n} finding(s) "
              f"({len(used)} suppressed by allowlist)", file=sys.stderr)
    return min(n, 125)


if __name__ == "__main__":
    sys.exit(main())
