"""Pass 1: kernel legality — tile floors, Eq. 2 VMEM, grid/index ranks.

Three static sub-checks over the kernel sources plus one dynamic sweep:

  KL001  registration matrix: every `registry.register(backend, op, ..)`
         names a known op and backend, nothing registers twice, and (on
         the real tree) every backend can execute "gemm".
  KL002  ladder alignment: the `_ladder(dim, align, cap)` calls inside
         `choose_kernel_config` must emit tiles the Pallas kernels
         accept — align and cap both multiples of the kernel's VREG
         floors (SUBLANE for bm, LANE for bk/bn) — and the cost model's
         SUBLANE/LANE/VMEM constants must equal the kernel modules'.
  KL005  grid rank vs index_map arity (a Pallas call whose index maps
         take the wrong number of grid coordinates fails only at
         dispatch on a TPU; here it fails lint).
  KL006  BlockSpec block rank vs index_map return-tuple length.
  KL003  dynamic corpus sweep: for every `arch_gemms` shape of all 10
         configs x {float, int8, sparse} (+ the MoE grouped shapes),
         the block triple the kernels would EXECUTE satisfies the VREG
         floors...
  KL004  ...and the Eq. 2 VMEM budget (`vmem_bytes <= VMEM`).

The int8/sparse executed blocks are re-derived by stdlib mirrors of
`quant_gemm.align_int8_blocks` / `sparse_gemm.default_sparse_blocks`
(drift-tested against the real functions under jax in
tests/test_analysis.py — the analysis itself must not import jax).
"""

from __future__ import annotations

import ast
import os

from . import Finding, is_real_root, rel
from ._astutil import (assignments_in, def_line, dotted, find_def, fold_int,
                       lambda_arity, module_int_constants, parse_file,
                       py_files, resolve, return_tuple_len)

#: VREG tiling floors when the kernel sources are absent under --root
#: (fixture trees); the real tree overrides these from the parsed
#: kernel constants so the check tracks the source of truth.
_DEFAULT_FLOORS = {"SUBLANE": 8, "LANE": 128, "INT8_SUBLANE": 32,
                   "VMEM": 16 * 2**20}

_BASE_BACKENDS = ("pallas-tpu", "pallas-interpret", "xla-einsum", "simulator")


def run(root: str) -> list[Finding]:
    findings: list[Finding] = []
    findings += _check_registrations(root)
    findings += _check_ladders(root)
    findings += _check_pallas_grids(root)
    if is_real_root(root):
        findings += _check_decision_corpus(root)
    return findings


# ---------------------------------------------------------------------------
# KL001: the registration matrix
# ---------------------------------------------------------------------------


def _loop_values(fn: ast.AST, name: str) -> list[str]:
    """Backend names a loop variable can take, when the enclosing `for`
    iterates a literal tuple/list of tuples (the quant/sparse
    `for name, use_pallas in ((...),)` idiom)."""
    vals: list[str] = []
    for node in ast.walk(fn):
        if not isinstance(node, ast.For):
            continue
        tgt = node.target
        names = [e.id for e in tgt.elts if isinstance(e, ast.Name)] \
            if isinstance(tgt, ast.Tuple) else \
            ([tgt.id] if isinstance(tgt, ast.Name) else [])
        if name not in names:
            continue
        pos = names.index(name)
        if isinstance(node.iter, (ast.Tuple, ast.List)):
            for elt in node.iter.elts:
                item = elt.elts[pos] if isinstance(elt, ast.Tuple) else elt
                if isinstance(item, ast.Constant) and isinstance(item.value,
                                                                 str):
                    vals.append(item.value)
    return vals


def _registrations(path: str) -> list[tuple[str, str, int]]:
    """(backend, op, line) for every `*.register(backend, op, fn)` call."""
    tree = parse_file(path)
    if tree is None:
        return []
    out = []
    for fn in [n for n in ast.walk(tree) if isinstance(n, ast.FunctionDef)]:
        for node in ast.walk(fn):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "register"
                    and len(node.args) >= 2):
                continue
            b_node, op_node = node.args[0], node.args[1]
            if not (isinstance(op_node, ast.Constant)
                    and isinstance(op_node.value, str)):
                continue
            if isinstance(b_node, ast.Constant) and isinstance(b_node.value,
                                                               str):
                backends = [b_node.value]
            elif isinstance(b_node, ast.Name):
                backends = _loop_values(fn, b_node.id)
            else:
                backends = []
            for b in backends:
                out.append((b, op_node.value, node.lineno))
    return out


def _check_registrations(root: str) -> list[Finding]:
    from repro.engine.context import INT8_BACKENDS, SPARSE_BACKENDS
    from repro.engine.plan import KNOWN_OPS

    known_backends = set(_BASE_BACKENDS) | set(INT8_BACKENDS) \
        | set(SPARSE_BACKENDS)
    files = [os.path.join(root, "engine", "backends.py")]
    kdir = os.path.join(root, "kernels")
    if os.path.isdir(kdir):
        files += [os.path.join(kdir, f) for f in sorted(os.listdir(kdir))
                  if f.endswith(".py")]
    findings: list[Finding] = []
    seen: dict[tuple[str, str], tuple[str, int]] = {}
    for path in files:
        for backend, op, line in _registrations(path):
            if op not in KNOWN_OPS:
                findings.append(Finding(
                    "KL001", rel(path), line, backend,
                    f"registers unknown op {op!r} (KNOWN_OPS: "
                    f"{', '.join(KNOWN_OPS)})"))
            if backend not in known_backends:
                findings.append(Finding(
                    "KL001", rel(path), line, op,
                    f"registers unknown backend {backend!r} (known: "
                    f"{', '.join(sorted(known_backends))})"))
            prev = seen.get((backend, op))
            if prev is not None:
                findings.append(Finding(
                    "KL001", rel(path), line, backend,
                    f"({backend!r}, {op!r}) registered twice — also at "
                    f"{prev[0]}:{prev[1]}; last registration silently "
                    f"wins"))
            else:
                seen[(backend, op)] = (rel(path), line)
    if is_real_root(root):
        # completeness: a backend without "gemm" cannot even serve the
        # dense projections; only meaningful over the full tree.
        for backend in sorted({b for b, _ in seen}):
            if (backend, "gemm") not in seen:
                path, line = next(v for (b, _), v in seen.items()
                                  if b == backend)
                findings.append(Finding(
                    "KL001", path, line, backend,
                    f"backend {backend!r} registers ops but no 'gemm' — "
                    f"every backend must execute the dense projections"))
    return findings


# ---------------------------------------------------------------------------
# KL002: ladder alignment + cross-module constant drift
# ---------------------------------------------------------------------------


def _kernel_floors(root: str) -> dict[str, int]:
    floors = dict(_DEFAULT_FLOORS)
    redas = parse_file(os.path.join(root, "kernels", "redas_gemm.py"))
    if redas is not None:
        consts = module_int_constants(redas)
        for k in ("SUBLANE", "LANE"):
            if k in consts:
                floors[k] = consts[k]
        if "VMEM_BYTES" in consts:
            floors["VMEM"] = consts["VMEM_BYTES"]
    quant = parse_file(os.path.join(root, "kernels", "quant_gemm.py"))
    if quant is not None:
        consts = module_int_constants(quant)
        if "INT8_SUBLANE" in consts:
            floors["INT8_SUBLANE"] = consts["INT8_SUBLANE"]
    return floors


def _check_ladders(root: str) -> list[Finding]:
    path = os.path.join(root, "core", "tpu_model.py")
    tree = parse_file(path)
    if tree is None:
        return []
    findings: list[Finding] = []
    floors = _kernel_floors(root)
    consts = module_int_constants(tree)

    # cross-module drift: the cost model must gate with the same
    # constants the kernels enforce, or legal-by-model tiles fail floor
    # validation (or worse: pass a stale VMEM budget) at dispatch.
    for model_name, kernel_name in (("SUBLANE", "SUBLANE"),
                                    ("LANE", "LANE"), ("VMEM", "VMEM")):
        if model_name in consts and consts[model_name] != floors[kernel_name]:
            findings.append(Finding(
                "KL002", rel(path), 1, model_name,
                f"core.tpu_model.{model_name} = {consts[model_name]} but "
                f"the kernel modules enforce {floors[kernel_name]} — the "
                f"cost model would emit blocks the kernels reject"))
    if floors["INT8_SUBLANE"] % floors["SUBLANE"] != 0:
        findings.append(Finding(
            "KL002", rel(os.path.join(root, "kernels", "quant_gemm.py")), 1,
            "INT8_SUBLANE",
            f"INT8_SUBLANE={floors['INT8_SUBLANE']} is not a multiple of "
            f"SUBLANE={floors['SUBLANE']}: int8 re-alignment of a "
            f"float-laddered bm can undershoot the int8 floor"))

    fn = find_def(tree, "choose_kernel_config")
    if fn is None:
        return findings
    calls = [n for n in ast.walk(fn)
             if isinstance(n, ast.Call) and isinstance(n.func, ast.Name)
             and n.func.id == "_ladder" and len(n.args) >= 2]
    if len(calls) != 3:
        return findings  # search restructured; the dynamic sweep still gates
    # loop nesting order is bm, bk, bn (matches the kernel's A/B floors)
    for call, dim, floor_name in zip(calls, ("bm", "bk", "bn"),
                                     ("SUBLANE", "LANE", "LANE"),
                                     strict=True):
        floor = floors[floor_name]
        env = {**floors, **consts}
        align = fold_int(call.args[1], env)
        cap = fold_int(call.args[2], env) if len(call.args) >= 3 else None
        if align is not None and align % floor != 0:
            findings.append(Finding(
                "KL002", rel(path), call.lineno, "choose_kernel_config",
                f"{dim} ladder align={align} is not a multiple of the "
                f"kernel {floor_name} floor ({floor}): the search can "
                f"emit tiles the Pallas kernel rejects"))
        if cap is not None and cap % floor != 0:
            findings.append(Finding(
                "KL002", rel(path), call.lineno, "choose_kernel_config",
                f"{dim} ladder cap={cap} is not a multiple of the kernel "
                f"{floor_name} floor ({floor}): min(round_up(dim), cap) "
                f"can emit a misaligned top rung"))
    return findings


# ---------------------------------------------------------------------------
# KL005/KL006: Pallas grid / index_map / BlockSpec rank consistency
# ---------------------------------------------------------------------------


def _grid_info(call: ast.Call, env) -> tuple[int | None, int, list[ast.AST]]:
    """(grid rank, scalar-prefetch count, extra spec exprs) for one
    `pl.pallas_call(...)`.  None rank = not statically resolvable."""
    grid_node = None
    prefetch = 0
    extra_specs: list[ast.AST] = []
    for kw in call.keywords:
        if kw.arg == "grid":
            grid_node = kw.value
        elif kw.arg == "grid_spec":
            for cand in resolve(kw.value, env):
                if isinstance(cand, ast.Call) and \
                        (dotted(cand.func) or "").endswith(
                            "PrefetchScalarGridSpec"):
                    for skw in cand.keywords:
                        if skw.arg == "grid":
                            grid_node = skw.value
                        elif skw.arg == "num_scalar_prefetch":
                            v = fold_int(skw.value, {})
                            prefetch = v if v is not None else prefetch
                        elif skw.arg in ("in_specs", "out_specs"):
                            extra_specs.append(skw.value)
    if grid_node is None:
        return None, prefetch, extra_specs
    ranks = {len(g.elts) for g in resolve(grid_node, env)
             if isinstance(g, ast.Tuple)}
    rank = ranks.pop() if len(ranks) == 1 else None
    return rank, prefetch, extra_specs


def _index_maps(spec_exprs, env, local_defs):
    """(map_node, block_rank|None) pairs found in the spec expressions:
    lambdas/named functions inside BlockSpec calls carry their block
    rank; bare lambdas passed through helper calls carry None (arity is
    still checkable against the grid)."""
    out, seen = [], set()

    def block_rank_of(bs_call: ast.Call):
        shp = bs_call.args[0] if bs_call.args else None
        for kw in bs_call.keywords:
            if kw.arg == "block_shape":
                shp = kw.value
        return len(shp.elts) if isinstance(shp, ast.Tuple) else None

    for expr in spec_exprs:
        for cand in resolve(expr, env):
            for node in ast.walk(cand):
                if not isinstance(node, ast.Call):
                    continue
                name = dotted(node.func) or ""
                if name.endswith("BlockSpec"):
                    imap = node.args[1] if len(node.args) >= 2 else None
                    for kw in node.keywords:
                        if kw.arg == "index_map":
                            imap = kw.value
                    if imap is None:
                        continue
                    fn = imap if isinstance(imap, ast.Lambda) else \
                        local_defs.get(imap.id) \
                        if isinstance(imap, ast.Name) else None
                    if fn is not None and id(fn) not in seen:
                        seen.add(id(fn))
                        out.append((fn, block_rank_of(node)))
                else:
                    # helper-call idiom: a_bs(lambda i, j, kk: ...) — the
                    # lambda still receives the grid coordinates.
                    for arg in node.args:
                        if isinstance(arg, ast.Lambda) \
                                and id(arg) not in seen:
                            seen.add(id(arg))
                            out.append((arg, None))
    return out


def _check_pallas_grids(root: str) -> list[Finding]:
    kdir = os.path.join(root, "kernels")
    if not os.path.isdir(kdir):
        return []
    findings: list[Finding] = []
    for path in py_files(kdir):
        tree = parse_file(path)
        if tree is None:
            continue
        for fn in [n for n in ast.walk(tree)
                   if isinstance(n, ast.FunctionDef)]:
            env = assignments_in(fn)
            local_defs = {n.name: n for n in ast.walk(fn)
                          if isinstance(n, ast.FunctionDef) and n is not fn}
            for call in ast.walk(fn):
                if not (isinstance(call, ast.Call)
                        and (dotted(call.func) or "").endswith("pallas_call")):
                    continue
                rank, prefetch, spec_exprs = _grid_info(call, env)
                for kw in call.keywords:
                    if kw.arg in ("in_specs", "out_specs", "out_spec"):
                        spec_exprs.append(kw.value)
                maps = _index_maps(spec_exprs, env, local_defs)
                if rank is None:
                    continue
                arity = rank + prefetch
                for imap, block_rank in maps:
                    got = lambda_arity(imap)
                    if got != arity:
                        findings.append(Finding(
                            "KL005", rel(path), imap.lineno, fn.name,
                            f"index_map takes {got} args but the grid "
                            f"is rank {rank}"
                            + (f" + {prefetch} scalar-prefetch args"
                               if prefetch else "")
                            + f" (= {arity}): Pallas would fail at "
                              f"dispatch"))
                        continue
                    ret = return_tuple_len(imap)
                    if block_rank is not None and ret is not None \
                            and ret != block_rank:
                        findings.append(Finding(
                            "KL006", rel(path), imap.lineno, fn.name,
                            f"index_map returns a {ret}-tuple but its "
                            f"BlockSpec block has {block_rank} dims"))
    return findings


# ---------------------------------------------------------------------------
# KL003/KL004: the dynamic corpus sweep (real tree only, still jax-free)
# ---------------------------------------------------------------------------

# Stdlib mirrors of the executed-block derivations.  These MUST track
# kernels/quant_gemm.align_int8_blocks and
# kernels/sparse_gemm.{_bk_unit,default_sparse_blocks} — tests/
# test_analysis.py compares them against the real functions under jax.


def _ru(x: int, m: int) -> int:
    return -(-x // m) * m


def _int8_vmem(bm: int, bk: int, bn: int) -> int:
    return 2 * (bm * bk + bk * bn) * 1 + bm * bn * 4


def mirror_align_int8(bm: int, bk: int, bn: int, *, sublane: int = 32,
                      lane: int = 128, vmem: int = 16 * 2**20):
    """quant_gemm.align_int8_blocks: round the float-planned triple up
    to int8 floors, then halve bk while the int8 footprint overflows."""
    bm, bk, bn = _ru(bm, sublane), _ru(bk, lane), _ru(bn, lane)
    while _int8_vmem(bm, bk, bn) > vmem and bk > lane:
        bk = max(lane, bk // 2)
    return bm, bk, bn


def _lcm(a: int, b: int) -> int:
    import math
    return a * b // math.gcd(a, b)


def _sparse_vmem(bm: int, bk: int, bn: int, n_keep: int, m_group: int) -> int:
    bk_c = bk * n_keep // m_group
    return (2 * (bm * bk * 4 + bk_c * bn * 4 + bk_c * bn)
            + bk * bn * 4 + bm * bn * 4)


def mirror_sparse_blocks(m: int, k_dense: int, n: int, n_keep: int,
                         m_group: int, *, lane: int = 128,
                         vmem: int = 16 * 2**20):
    """sparse_gemm.default_sparse_blocks: bk quantized to the dense-K
    unit lcm(LANE, m_group), halved toward it under the Eq. 2 gate."""
    unit = _lcm(lane, m_group)
    bm = min(_ru(m, 8), 256)
    bk = min(_ru(k_dense, unit), 8 * unit)
    bn = min(_ru(n, lane), 256)
    while _sparse_vmem(bm, bk, bn, n_keep, m_group) > vmem and bk > unit:
        bk = max(unit, _ru(bk // 2, unit))
    return bm, bk, bn


def _check_decision_corpus(root: str) -> list[Finding]:
    from repro.configs import all_configs
    from repro.core import tpu_model as tm
    from repro.core.workloads import arch_gemms
    from repro.engine.context import decode_requests
    from repro.engine.cost import TPUModel
    from repro.engine.plan import KernelRequest

    floors = _kernel_floors(root)
    sub, lane = floors["SUBLANE"], floors["LANE"]
    isub, vmem = floors["INT8_SUBLANE"], floors["VMEM"]
    anchors = {
        "gemm": (rel(os.path.join(root, "core", "tpu_model.py")),
                 def_line(os.path.join(root, "core", "tpu_model.py"),
                          "choose_kernel_config"), "choose_kernel_config"),
        "int8": (rel(os.path.join(root, "kernels", "quant_gemm.py")),
                 def_line(os.path.join(root, "kernels", "quant_gemm.py"),
                          "align_int8_blocks"), "align_int8_blocks"),
        "sparse": (rel(os.path.join(root, "kernels", "sparse_gemm.py")),
                   def_line(os.path.join(root, "kernels", "sparse_gemm.py"),
                            "default_sparse_blocks"),
                   "default_sparse_blocks"),
        "grouped": (rel(os.path.join(root, "engine", "cost.py")),
                    def_line(os.path.join(root, "engine", "cost.py"),
                             "_decide_grouped"), "_decide_grouped"),
    }
    model = TPUModel()
    findings: list[Finding] = []
    emitted: set[tuple] = set()

    def emit(kind: str, check: str, msg: str):
        file, line, symbol = anchors[kind]
        key = (check, kind, msg)
        if key not in emitted:
            emitted.add(key)
            findings.append(Finding(check, file, line, symbol, msg))

    def check_blocks(kind, shape, bm, bk, bn, fm, fk, fn_, used, label):
        if bm % fm or bk % fk or bn % fn_:
            emit(kind, "KL003",
                 f"{label} shape {shape}: executed blocks ({bm},{bk},{bn}) "
                 f"violate the ({fm},{fk},{fn_}) floors")
        if used > vmem:
            emit(kind, "KL004",
                 f"{label} shape {shape}: executed blocks ({bm},{bk},{bn}) "
                 f"need {used} B VMEM > the Eq. 2 budget {vmem} B")

    shapes: set[tuple[int, int, int, str]] = set()
    grouped: set[tuple] = set()
    for cfg in all_configs().values():
        for g in arch_gemms(cfg):
            shapes.add((g.M, g.K, g.N, cfg.name))
        if cfg.moe is not None:
            for seq in (1, 8):
                for req in decode_requests(cfg, batch=4, seq=seq):
                    if req.op == "grouped_gemm":
                        grouped.add((req.m, req.k, req.n, req.groups,
                                     cfg.name))

    for m, k, n, cname in sorted(shapes):
        shape = (m, k, n)
        # float plane: the decision IS the executed block triple
        d = model.decide(KernelRequest("gemm", m, k, n))
        used = tm.TPUKernelConfig(d.dataflow, d.bm, d.bk, d.bn).vmem_bytes(2)
        check_blocks("gemm", shape, d.bm, d.bk, d.bn, sub, lane, lane,
                     used, f"{cname} float")
        # int8 plane: the kernel re-aligns the planned triple first
        d8 = model.decide(KernelRequest("gemm_w8", m, k, n, in_bytes=1))
        bm, bk, bn = mirror_align_int8(d8.bm, d8.bk, d8.bn, sublane=isub,
                                       lane=lane, vmem=vmem)
        check_blocks("int8", shape, bm, bk, bn, isub, lane, lane,
                     _int8_vmem(bm, bk, bn), f"{cname} int8")
        # sparse plane (2:4): the kernel derives its own default blocks
        # from the stored (dense-equivalent) K
        k_store = _ru(k, 4)
        bm, bk, bn = mirror_sparse_blocks(m, k_store, n, 2, 4, lane=lane,
                                          vmem=vmem)
        unit = _lcm(lane, 4)
        check_blocks("sparse", shape, bm, bk, bn, sub, unit, lane,
                     _sparse_vmem(bm, bk, bn, 2, 4), f"{cname} 2:4 sparse")

    for m, k, n, groups, cname in sorted(grouped):
        d = model.decide(KernelRequest("grouped_gemm", m, k, n,
                                       groups=groups))
        used = tm.TPUKernelConfig("os", d.bm, d.bk, d.bn).vmem_bytes(2)
        check_blocks("grouped", (m, k, n), d.bm, d.bk, d.bn, sub, lane, lane,
                     used, f"{cname} grouped E={groups}")
    return findings
