"""Pass 5: docs consistency — the documentation layer linted like code.

PR 9 deleted `kernels/ops.py`; the §3 migration table kept describing
it as a live DeprecationWarning shim until a human noticed.  That class
of rot is mechanically checkable: documentation references *name* parts
of the tree, and the tree is right here.

  DC001  a `DESIGN.md §N` citation (src docstrings/comments,
         benchmarks, README, DESIGN itself) naming a section that does
         not exist — the renumbered-section failure mode.
  DC002  a package under `src/repro/` with no row in the README module
         map — a plane that shipped undocumented.
  DC003  a backticked code reference in README/DESIGN (a `pkg/mod.py`
         path or a dotted `repro.x.y` / `serve_lib.scheduler` module
         path) that no longer resolves against the tree.  A paragraph
         that itself says "removed"/"deleted" is exempt: documenting a
         removal (the §3 migration table) is the fix, not the bug.

Stale-doc findings are burned down in the docs, never allowlisted.

Fixture trees (`tests/fixtures/analysis/*_docs`) carry their own
README.md/DESIGN.md next to a miniature package tree; on the real
package the docs live at the repo root, two levels above ``REAL_ROOT``.
"""

from __future__ import annotations

import ast
import os
import re

from . import Finding, is_real_root, rel
from ._astutil import py_files

#: ``DESIGN.md §<token>`` citation, token = section number ("12"),
#: dotted subsection ("2.7"), or named section ("Arch-applicability").
_CITE = re.compile(r"DESIGN\.md\s*§([\w][\w.-]*)")

#: §-tokens on a DESIGN header line ("## §12 ...", "(a.k.a. §Arch)").
_HEADER_TOKEN = re.compile(r"§([\w][\w.-]*)")

_BACKTICK = re.compile(r"`([^`]+)`")
_PATH_REF = re.compile(r"[\w][\w/-]*\.py\b")
_EXEMPT = re.compile(r"\b(removed|deleted|renamed)\b", re.IGNORECASE)


def _docroot(root: str) -> str:
    """README/DESIGN live at the repo root for the real package, and in
    the fixture directory itself for planted trees."""
    if is_real_root(root):
        return os.path.dirname(os.path.dirname(root))
    return root


def _doc_files(root: str) -> list[str]:
    out = []
    for name in ("README.md", "DESIGN.md"):
        path = os.path.join(_docroot(root), name)
        if os.path.exists(path):
            out.append(path)
    return out


def _cite_files(root: str) -> list[str]:
    """Where DC001 looks for citations: the package sources, the
    benchmarks (real tree only), and the docs themselves."""
    files = [p for p in py_files(root)
             if not os.path.basename(p).startswith("test_")]
    if is_real_root(root):
        bench = os.path.join(_docroot(root), "benchmarks")
        if os.path.isdir(bench):
            files.extend(py_files(bench))
    return files + _doc_files(root)


# -- DC001 -----------------------------------------------------------------


def _section_tokens(root: str) -> set[str] | None:
    """Every §-token the DESIGN headers declare, plus the words of those
    header lines (so `§Batched` may cite "§2.7 Batched search engine").
    None when there is no DESIGN.md to resolve against."""
    path = os.path.join(_docroot(root), "DESIGN.md")
    if not os.path.exists(path):
        return None
    tokens: set[str] = set()
    with open(path) as fh:
        for line in fh:
            if not line.startswith("#") or "§" not in line:
                continue
            tokens.update(m.group(1).rstrip(".-")
                          for m in _HEADER_TOKEN.finditer(line))
            tokens.update(re.findall(r"\w+", line))
    return tokens


def _check_citations(root: str) -> list[Finding]:
    valid = _section_tokens(root)
    if valid is None:
        return []
    findings = []
    for path in _cite_files(root):
        with open(path) as fh:
            for ln, line in enumerate(fh, 1):
                for m in _CITE.finditer(line):
                    token = m.group(1).rstrip(".-")
                    if token in valid or token.split(".")[0] in valid:
                        continue
                    findings.append(Finding(
                        "DC001", rel(path), ln, f"§{token}",
                        f"cites DESIGN.md §{token}, which matches no "
                        f"DESIGN.md section header — a renumbered or "
                        f"deleted section"))
    return findings


# -- DC002 -----------------------------------------------------------------


def _packages(root: str) -> list[str]:
    return sorted(
        name for name in os.listdir(root)
        if os.path.isdir(os.path.join(root, name))
        and os.path.exists(os.path.join(root, name, "__init__.py")))


def _check_module_map(root: str) -> list[Finding]:
    readme = os.path.join(_docroot(root), "README.md")
    if not os.path.exists(readme):
        return []
    with open(readme) as fh:
        lines = fh.read().splitlines()
    anchor = next((i for i, line in enumerate(lines, 1)
                   if "module map" in line.lower()), 1)
    text = "\n".join(lines)
    findings = []
    for pkg in _packages(root):
        if f"`{pkg}/`" in text or f"repro.{pkg}" in text:
            continue
        findings.append(Finding(
            "DC002", rel(readme), anchor, pkg,
            f"package src/repro/{pkg}/ has no README module-map row "
            f"(`{pkg}/`) — a plane shipped without documentation"))
    return findings


# -- DC003 -----------------------------------------------------------------


def _py_index(root: str) -> list[str]:
    """Relative paths ('/'-joined) of every .py file reachable from the
    doc root — the universe a doc path reference may name."""
    base = _docroot(root)
    out = []
    for dirpath, dirnames, filenames in os.walk(base):
        dirnames[:] = [d for d in dirnames
                       if d != "__pycache__" and not d.startswith(".")]
        for f in filenames:
            if f.endswith(".py"):
                full = os.path.join(dirpath, f)
                out.append(os.path.relpath(full, base).replace(os.sep, "/"))
    return out


def _path_resolves(ref: str, index: list[str]) -> bool:
    if "/" in ref:
        return any(p == ref or p.endswith("/" + ref) for p in index)
    base = ref.rsplit("/", 1)[-1]
    return any(p.rsplit("/", 1)[-1] == base for p in index)


def _init_names(pkg_dir: str) -> set[str]:
    """Top-level names a package's __init__.py binds (defs, classes,
    assignments, import aliases) — the statically-visible attributes."""
    init = os.path.join(pkg_dir, "__init__.py")
    try:
        with open(init) as fh:
            tree = ast.parse(fh.read(), filename=init)
    except (OSError, SyntaxError):
        return set()
    names: set[str] = set()
    for stmt in tree.body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            names.add(stmt.name)
        elif isinstance(stmt, ast.Assign):
            for tgt in stmt.targets:
                if isinstance(tgt, ast.Name):
                    names.add(tgt.id)
        elif isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target,
                                                            ast.Name):
            names.add(stmt.target.id)
        elif isinstance(stmt, (ast.Import, ast.ImportFrom)):
            names.update(a.asname or a.name.split(".")[0]
                         for a in stmt.names)
    return names


def _dotted_resolves(parts: list[str], base: str) -> bool:
    """Walk `parts` down from package dir `base`: directories descend,
    a `part.py` or an __init__-bound name terminates (the remainder is
    attribute access on a module/object — out of static reach)."""
    cur = base
    for part in parts:
        nxt = os.path.join(cur, part)
        if os.path.isdir(nxt) and os.path.exists(
                os.path.join(nxt, "__init__.py")):
            cur = nxt
            continue
        if os.path.exists(nxt + ".py"):
            return True
        return part in _init_names(cur)
    return True


def _check_code_refs(root: str) -> list[Finding]:
    heads = set(_packages(root))
    dotted_re = re.compile(
        r"\b((?:repro|benchmarks|%s)(?:\.[A-Za-z_]\w+)+)" %
        "|".join(map(re.escape, sorted(heads))) if heads else
        r"\b((?:repro|benchmarks)(?:\.[A-Za-z_]\w+)+)")
    index = _py_index(root)
    bench_dir = os.path.join(_docroot(root), "benchmarks")
    findings = []
    for doc in _doc_files(root):
        with open(doc) as fh:
            lines = fh.read().splitlines()
        # paragraph = blank-line-delimited block; the exemption keyword
        # is looked up per paragraph because markdown wraps sentences.
        para_start = 0
        paras: list[tuple[int, list[str]]] = []
        block: list[str] = []
        for i, line in enumerate(lines, 1):
            if line.strip():
                if not block:
                    para_start = i
                block.append(line)
            elif block:
                paras.append((para_start, block))
                block = []
        if block:
            paras.append((para_start, block))
        for start, block in paras:
            if _EXEMPT.search("\n".join(block)):
                continue
            for off, line in enumerate(block):
                for span in _BACKTICK.findall(line):
                    for ref in _PATH_REF.findall(span):
                        if not _path_resolves(ref, index):
                            findings.append(Finding(
                                "DC003", rel(doc), start + off, ref,
                                f"references {ref}, which matches no "
                                f".py file in the tree — deleted or "
                                f"renamed module"))
                    for m in dotted_re.finditer(span):
                        parts = m.group(1).split(".")
                        head, tail = parts[0], parts[1:]
                        if head == "repro":
                            ok = _dotted_resolves(tail, root)
                        elif head == "benchmarks":
                            if not os.path.isdir(bench_dir):
                                continue  # fixtures carry no benchmarks
                            ok = _dotted_resolves(tail, bench_dir)
                        else:
                            ok = _dotted_resolves(tail,
                                                  os.path.join(root, head))
                        if not ok:
                            findings.append(Finding(
                                "DC003", rel(doc), start + off, m.group(1),
                                f"references {m.group(1)}, which does not "
                                f"resolve in the tree — deleted or renamed "
                                f"module/symbol"))
    return findings


def run(root: str) -> list[Finding]:
    return (_check_citations(root) + _check_module_map(root)
            + _check_code_refs(root))
