"""Loop-aware cost extraction from compiled (partitioned) HLO text.

`compiled.cost_analysis()` visits each while-loop body ONCE — with
scan-over-layers and grad-accumulation scans that undercounts FLOPs,
bytes and collective traffic by the product of trip counts (verified
~12-28x on our cells; see EXPERIMENTS.md §Roofline-methodology).  The
compiled HLO, however, carries every loop's exact trip count in
`backend_config={"known_trip_count":{"n":...}}` — so this module parses
the module text into a computation graph and walks it with loop
multipliers:

  FLOPs   — every `dot` (2 * prod(result dims) * contract size), including
            dots inside fusion computations;
  bytes   — operand + result bytes of every non-free op at its call site;
            fusion internals are on-chip by definition, so a fusion's
            traffic is exactly its call-site operands + result (this is
            the post-fusion HBM traffic model, same as HloCostAnalysis);
  coll    — result bytes of all-gather / all-reduce / reduce-scatter /
            all-to-all / collective-permute, trip-multiplied, plus a
            per-op-name attribution map for the §Perf hillclimb.
"""

from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops that move no HBM bytes at their call site
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "call", "conditional", "after-all", "add-dependency",
    "partition-id", "replica-id", "custom-call",  # custom-call: see below
}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(shape_str: str) -> list[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Op:
    name: str
    shape: str            # result shape string
    kind: str
    operands: list[str]
    attrs: str            # everything after the operand list


_NAME_EQ = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*")
_KIND = re.compile(r"^([\w\-]+)\(")


def _parse_op_line(line: str) -> tuple[str, str, str, str, str] | None:
    """(name, shape, kind, operand_str, attrs) — robust to tuple shapes
    containing /*index=N*/ comments (regexes are not)."""
    m = _NAME_EQ.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():]
    if rest.startswith("("):  # tuple-shaped result: bracket-match
        depth, i = 0, 0
        for i, ch in enumerate(rest):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                break
        shape, rest = rest[: i + 1], rest[i + 1:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        shape, rest = rest[:sp], rest[sp + 1:].lstrip()
    k = _KIND.match(rest)
    if not k:
        return None
    kind = k.group(1)
    rest = rest[k.end() - 1:]
    depth, i = 0, 0
    for i, ch in enumerate(rest):
        depth += ch == "("
        depth -= ch == ")"
        if depth == 0:
            break
    operands, attrs = rest[1:i], rest[i + 1:]
    return name, shape, kind, operands, attrs

_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%([\w.\-]+)\s+\(.*->.*\{\s*$")
_OPERAND = re.compile(r"%([\w.\-]+)")
_TRIPS = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS = re.compile(r"calls=%([\w.\-]+)")
_TO_APPLY = re.compile(r"to_apply=%([\w.\-]+)")
_BODY = re.compile(r"body=%([\w.\-]+)")
_COND = re.compile(r"condition=%([\w.\-]+)")
_OPNAME_META = re.compile(r'op_name="([^"]*)"')


@dataclasses.dataclass
class Computation:
    name: str
    ops: dict[str, Op]
    is_entry: bool = False


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        h = _COMP_HEADER.match(line)
        if h:
            cur = Computation(h.group(2), {}, is_entry=bool(h.group(1)))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        parsed = _parse_op_line(line)
        if parsed:
            name, shape, kind, opnds, attrs = parsed
            cur.ops[name] = Op(name, shape, kind,
                               _OPERAND.findall(opnds), attrs)
    return comps


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    coll_by_opname: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    bytes_by_opname: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    flops_by_opname: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        self.coll_bytes += mult * other.coll_bytes
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] += mult * v
        for k, v in other.coll_by_opname.items():
            self.coll_by_opname[k] += mult * v
        for k, v in other.bytes_by_opname.items():
            self.bytes_by_opname[k] += mult * v
        for k, v in other.flops_by_opname.items():
            self.flops_by_opname[k] += mult * v


class ModuleCosts:
    def __init__(self, text: str):
        self.comps = parse_module(text)
        self._memo: dict[str, Cost] = {}
        entries = [c for c in self.comps.values() if c.is_entry]
        assert entries, "no ENTRY computation found"
        self.entry = entries[0]

    # -- per-op helpers ------------------------------------------------------

    def _dot_flops(self, comp: Computation, op: Op) -> float:
        lhs = comp.ops.get(op.operands[0]) if op.operands else None
        if lhs is None:
            return 0.0
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
        cdims = [int(x) for x in m.group(1).split(",") if x] if m else []
        ldims = _shape_dims(lhs.shape)
        contract = math.prod(ldims[i] for i in cdims) if cdims else 1
        out = math.prod(_shape_dims(op.shape)) if _shape_dims(op.shape) else 1
        return 2.0 * out * contract

    def _op_bytes(self, comp: Computation, op: Op) -> float:
        if op.kind in _FREE_OPS and op.kind != "custom-call":
            return 0.0
        total = float(_shape_bytes(op.shape))
        for o in op.operands:
            d = comp.ops.get(o)
            if d is not None:
                total += _shape_bytes(d.shape)
        return total

    def _flops_only(self, name: str) -> float:
        """dot FLOPs inside a fusion computation (bytes stay at call site)."""
        comp = self.comps[name]
        total = 0.0
        for op in comp.ops.values():
            if op.kind == "dot":
                total += self._dot_flops(comp, op)
            elif op.kind == "fusion":
                m = _CALLS.search(op.attrs)
                if m and m.group(1) in self.comps:
                    total += self._flops_only(m.group(1))
        return total

    def _trip_count(self, op: Op) -> int:
        m = _TRIPS.search(op.attrs)
        if m:
            return int(m.group(1))
        # fallback: max s32 constant in the condition computation
        c = _COND.search(op.attrs)
        if c and c.group(1) in self.comps:
            consts = [
                int(x) for o in self.comps[c.group(1)].ops.values()
                for x in re.findall(r"constant\((\d+)\)", o.kind + "(" + ",".join(o.operands) + ")" + o.attrs)
            ]
            if consts:
                return max(consts)
        return 1

    # -- the walk --------------------------------------------------------------

    def cost_of(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = Cost()  # break cycles defensively
        comp = self.comps[name]
        c = Cost()
        for op in comp.ops.values():
            ob = self._op_bytes(comp, op)
            c.bytes += ob
            if ob:
                meta_b = _OPNAME_META.search(op.attrs)
                c.bytes_by_opname[
                    (op.kind, meta_b.group(1) if meta_b else op.name)] += ob
            if op.kind == "dot":
                df = self._dot_flops(comp, op)
                c.flops += df
                meta_f = _OPNAME_META.search(op.attrs)
                c.flops_by_opname[
                    meta_f.group(1) if meta_f else op.name] += df
            elif op.kind == "fusion":
                m = _CALLS.search(op.attrs)
                if m and m.group(1) in self.comps:
                    c.flops += self._flops_only(m.group(1))
            elif op.kind == "while":
                b, cond = _BODY.search(op.attrs), _COND.search(op.attrs)
                trips = self._trip_count(op)
                if b and b.group(1) in self.comps:
                    c.add(self.cost_of(b.group(1)), mult=trips)
                if cond and cond.group(1) in self.comps:
                    c.add(self.cost_of(cond.group(1)), mult=trips + 1)
            elif op.kind == "call":
                m = _TO_APPLY.search(op.attrs)
                if m and m.group(1) in self.comps:
                    c.add(self.cost_of(m.group(1)))
            elif op.kind == "conditional":
                for br in re.findall(r"%([\w.\-]+)", op.attrs):
                    if br in self.comps:
                        c.add(self.cost_of(br))
            if op.kind in COLLECTIVES:
                nbytes = float(_shape_bytes(op.shape))
                c.coll_bytes += nbytes
                c.coll_by_kind[op.kind] += nbytes
                meta = _OPNAME_META.search(op.attrs)
                key = meta.group(1) if meta else op.name
                c.coll_by_opname[key] += nbytes
        self._memo[name] = c
        return c

    def total(self) -> Cost:
        return self.cost_of(self.entry.name)


def module_costs(hlo_text: str) -> Cost:
    return ModuleCosts(hlo_text).total()
