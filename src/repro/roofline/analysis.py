"""Three-term roofline from a compiled dry-run artifact (TPU v5e target).

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s        (197 TF bf16)
    memory term     = HLO_bytes_per_device / HBM_bw             (819 GB/s)
    collective term = collective_bytes_per_device / link_bw     (~50 GB/s)

`compiled.cost_analysis()` reports the *partitioned per-device* module,
so the terms come out per-chip directly.  collective_bytes is parsed
from the partitioned HLO text: we sum the result-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (ring-algorithm factors ~2(n-1)/n are folded into the
single-link bandwidth constant; documented approximation).

MODEL_FLOPS uses 6*N*D (train) / 2*N*D (inference) with N = active
params — the ratio MODEL_FLOPS / HLO_FLOPs exposes remat recompute,
attention quadratic terms and padding waste.
"""

from __future__ import annotations

import dataclasses
import re

# --- TPU v5e constants (per chip) -------------------------------------------
PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g. "bf16[2,4096,1536]{2,1,0}" — possibly inside a tuple "(bf16[..], ..)"
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?\S+\s*=\s*(\([^)]*\)|\S+)\s+(" +
    "|".join(_COLLECTIVES) + r")\(", re.M)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Result-shape bytes per collective kind in a partitioned HLO dump."""
    out = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        out[kind] += _shape_bytes(shape_str)
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass(frozen=True)
class Roofline:
    flops_per_device: float
    hbm_bytes_per_device: float
    coll_bytes_per_device: float
    model_flops_per_device: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes_per_device / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Perfect-overlap bound: the dominant term IS the step time."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs: how much compiled compute is useful."""
        if self.flops_per_device <= 0:
            return 0.0
        return self.model_flops_per_device / self.flops_per_device

    @property
    def roofline_fraction(self) -> float:
        """Achievable MFU bound for this program: useful flops / (step
        time x peak) under perfect overlap of the three engines."""
        if self.step_s <= 0:
            return 0.0
        return self.model_flops_per_device / (self.step_s * PEAK_FLOPS)

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "hbm_bytes_per_device": self.hbm_bytes_per_device,
            "coll_bytes_per_device": self.coll_bytes_per_device,
            "model_flops_per_device": self.model_flops_per_device,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "step_s": self.step_s,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def from_compiled(compiled, *, model_flops_total: float, n_devices: int,
                  hlo_text: str | None = None) -> Roofline:
    """Loop-aware terms from the partitioned HLO (roofline/hlo_costs).

    NB: `compiled.cost_analysis()` visits while bodies once and therefore
    undercounts scanned programs by the product of trip counts; the
    hlo_costs walker multiplies by each loop's known_trip_count.  The raw
    cost_analysis numbers are kept in the dry-run reports for comparison.
    """
    from . import hlo_costs
    text = hlo_text if hlo_text is not None else compiled.as_text()
    cost = hlo_costs.module_costs(text)
    return Roofline(
        flops_per_device=cost.flops,
        hbm_bytes_per_device=cost.bytes,
        coll_bytes_per_device=cost.coll_bytes,
        model_flops_per_device=model_flops_total / n_devices,
    )


def raw_cost_analysis(compiled) -> dict:
    """XLA's own (loop-body-once) numbers, for the methodology comparison."""
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    if hbm == 0.0:
        hbm = sum(float(v) for k, v in cost.items()
                  if k.startswith("bytes accessed"))
    return {"flops": flops, "bytes_accessed": hbm}


def model_flops(cfg, shape, *, active: bool = True) -> float:
    """6*N*D (train) / 2*N*D (prefill) / 2*N per token (decode), N=active."""
    n = cfg.active_param_count() if active else cfg.param_count()
    if shape.step == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.step == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence per step
    return 2.0 * n * shape.global_batch
